//! Integration tests: HTTP server ⇄ remote executor round trips, the
//! paper's correctness property end-to-end, persistence recovery, and a
//! from-scratch property-test sweep over random trajectories.

use std::sync::Arc;

use tvcache::cache::{LpmConfig, TaskCache, ToolCall};
use tvcache::client::{ExecutorConfig, LocalBinding, RemoteBinding, ToolCallExecutor};
use tvcache::sandbox::{SandboxFactory, TerminalFactory, ToolExecutionEnvironment};
use tvcache::server::serve;
use tvcache::util::rng::Rng;

fn bash(cmd: &str) -> ToolCall {
    let stateless =
        cmd.starts_with("cat ") || cmd.starts_with("ls") || cmd.starts_with("grep ");
    ToolCall { tool: "bash".into(), args: cmd.into(), mutates_state: !stateless }
}

/// Remote executor over a real HTTP server: second rollout hits, divergent
/// stateful reads stay correct.
#[test]
fn remote_executor_end_to_end() {
    let (server, _svc) = serve("127.0.0.1:0", 4).unwrap();
    let binding = Arc::new(RemoteBinding::connect(server.addr(), "task-42"));
    let factory = Arc::new(TerminalFactory { medium: false });

    let script = ["cat README.md", "make", "make test"];
    let mut r1 = ToolCallExecutor::new(
        Arc::clone(&binding) as Arc<_>,
        Arc::clone(&factory) as Arc<_>,
        7,
        ExecutorConfig::default(),
    );
    for c in script {
        assert!(!r1.call(bash(c)).hit, "cold cache must miss: {c}");
    }

    let mut r2 = ToolCallExecutor::new(
        Arc::clone(&binding) as Arc<_>,
        Arc::clone(&factory) as Arc<_>,
        7,
        ExecutorConfig::default(),
    );
    let outputs_r1: Vec<String> =
        r1.history().iter().map(|(_, r)| r.output.clone()).collect();
    for (i, c) in script.iter().enumerate() {
        let o = r2.call(bash(c));
        assert!(o.hit, "warm cache must hit: {c}");
        assert_eq!(o.result.output, outputs_r1[i], "cached output mismatch");
    }

    // Diverge statefully: must execute, not serve stale.
    let o = r2.call(bash("patch src/module_0.py s/return x - 8/return x + 8/"));
    assert!(!o.hit);
}

/// The paper's correctness theorem, tested as a property over random
/// trajectories: for any interleaving of rollouts over a shared cache, the
/// output of every call equals a fresh cacheless execution of the same
/// prefix on a clean sandbox.
#[test]
fn property_cached_equals_uncached_replay() {
    let commands = [
        "cat README.md",
        "cat Makefile",
        "pip install libdep1",
        "make",
        "make test",
        "patch src/module_1.py s/return x - 2/return x + 2/",
        "echo note > scratch.txt",
        "cat scratch.txt",
        "grep return src/module_1.py",
        "cp README.md copy.md",
    ];
    let mut rng = Rng::new(0xC0FFEE);
    let task_seed = 1;

    for trial in 0..20 {
        let cache = Arc::new(TaskCache::with_defaults());
        let binding = Arc::new(LocalBinding::new(cache));
        let factory = Arc::new(TerminalFactory { medium: false });

        // 3 rollouts with random trajectories sharing one cache.
        for _rollout in 0..3 {
            let mut exec = ToolCallExecutor::new(
                Arc::clone(&binding) as Arc<_>,
                Arc::clone(&factory) as Arc<_>,
                task_seed,
                ExecutorConfig::default(),
            );
            let n = 2 + rng.below(7) as usize;
            let calls: Vec<&str> = (0..n)
                .map(|_| commands[rng.below(commands.len() as u64) as usize])
                .collect();

            // Reference: replay the same prefix on a fresh sandbox.
            let mut reference = factory.create(task_seed);
            for c in &calls {
                let got = exec.call(bash(c)).result.output;
                let want = reference.execute(&bash(c)).output;
                assert_eq!(got, want, "trial {trial}: divergence at {c} in {calls:?}");
            }
        }
    }
}

/// Sandbox state fingerprints agree between cached reconstruction paths and
/// direct execution (the stronger internal invariant).
#[test]
fn property_fingerprints_match_direct_execution() {
    let factory = TerminalFactory { medium: false };
    let mut rng = Rng::new(99);
    let pool = [
        "echo a > f1",
        "echo b >> f1",
        "pip install libdep1",
        "make",
        "cp f1 f2",
        "rm f2",
    ];
    for _ in 0..30 {
        let n = 1 + rng.below(6) as usize;
        let calls: Vec<&str> =
            (0..n).map(|_| pool[rng.below(pool.len() as u64) as usize]).collect();
        let mut a = factory.create(5);
        let mut b = factory.create(5);
        for c in &calls {
            a.execute(&bash(c));
        }
        // b executes via snapshot/restore mid-way.
        let mid = calls.len() / 2;
        for c in &calls[..mid] {
            b.execute(&bash(c));
        }
        let snap = b.snapshot();
        let mut b2 = factory.restore(&snap);
        for c in &calls[mid..] {
            b2.execute(&bash(c));
        }
        assert_eq!(
            a.state_fingerprint(),
            b2.state_fingerprint(),
            "snapshot round-trip diverged on {calls:?}"
        );
    }
}

/// Server persistence: a cache serialized to JSON and rebuilt serves the
/// same hits (sandboxes are gone, results remain — §3.4).
#[test]
fn persistence_recovery_after_crash() {
    let cache = TaskCache::with_defaults();
    let traj: Vec<(ToolCall, tvcache::cache::ToolResult)> = [
        ("git clone repo", "ok"),
        ("make", "build OK"),
        ("make test", "12 passed"),
    ]
    .iter()
    .map(|(c, r)| (bash(c), tvcache::cache::ToolResult::new(*r, 5.0)))
    .collect();
    cache.record_trajectory(&traj);

    let dump = cache.to_persistent_json().to_string();
    // "Crash": rebuild from disk bytes.
    let parsed = tvcache::util::json::parse(&dump).unwrap();
    let rebuilt = TaskCache::from_persistent_json(&parsed, LpmConfig::default()).unwrap();
    let q: Vec<ToolCall> = traj.iter().map(|(c, _)| c.clone()).collect();
    match rebuilt.lookup(&q) {
        tvcache::cache::Lookup::Hit { result, .. } => {
            assert_eq!(result.output, "12 passed")
        }
        m => panic!("expected hit after recovery, got {m:?}"),
    }
}

/// Concurrent rollouts over one HTTP server: no lost updates, consistent
/// hit accounting.
#[test]
fn concurrent_remote_rollouts() {
    let (server, svc) = serve("127.0.0.1:0", 4).unwrap();
    let addr = server.addr();
    let handles: Vec<_> = (0..4)
        .map(|t| {
            std::thread::spawn(move || {
                let binding = Arc::new(RemoteBinding::connect(addr, "shared-task"));
                let factory = Arc::new(TerminalFactory { medium: false });
                let mut exec = ToolCallExecutor::new(
                    binding as Arc<_>,
                    factory as Arc<_>,
                    3,
                    ExecutorConfig::default(),
                );
                for c in ["cat README.md", "make", &format!("echo t{t} > own.txt")] {
                    exec.call(bash(c));
                }
                exec.hits
            })
        })
        .collect();
    let total_hits: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let stats = svc.task("shared-task").stats();
    assert_eq!(stats.hits, total_hits);
    assert!(stats.lookups >= 12);
    // The shared prefix exists once; the divergent writes branch.
    assert!(svc.task("shared-task").node_count() >= 4);
}
