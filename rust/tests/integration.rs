//! Integration tests: HTTP server ⇄ remote executor round trips, the
//! paper's correctness property end-to-end, backend parity (the same
//! `CacheBackend` contract over the in-process sharded service and the HTTP
//! binding), persistence recovery, and a property-test sweep over random
//! trajectories.

use std::sync::Arc;

use tvcache::cache::{
    CacheBackend, Lookup, LpmConfig, ShardedCacheService, TaskCache, ToolCall, ToolResult,
};
use tvcache::client::{ExecutorConfig, RemoteBinding, ToolCallExecutor};
use tvcache::sandbox::{SandboxFactory, SandboxSnapshot, TerminalFactory, ToolExecutionEnvironment};
use tvcache::server::serve;
use tvcache::util::rng::Rng;

fn bash(cmd: &str) -> ToolCall {
    let stateless =
        cmd.starts_with("cat ") || cmd.starts_with("ls") || cmd.starts_with("grep ");
    ToolCall { tool: "bash".into(), args: cmd.into(), mutates_state: !stateless }
}

/// Remote executor over a real HTTP server: second rollout hits, divergent
/// stateful reads stay correct.
#[test]
fn remote_executor_end_to_end() {
    let (server, _svc) = serve("127.0.0.1:0", 4).unwrap();
    let binding = Arc::new(RemoteBinding::connect(server.addr()));
    let factory = Arc::new(TerminalFactory { medium: false });

    let script = ["cat README.md", "make", "make test"];
    let mut r1 = ToolCallExecutor::new(
        Arc::clone(&binding) as Arc<_>,
        "task-42",
        Arc::clone(&factory) as Arc<_>,
        7,
        ExecutorConfig::default(),
    );
    for c in script {
        assert!(!r1.call(bash(c)).hit, "cold cache must miss: {c}");
    }

    let mut r2 = ToolCallExecutor::new(
        Arc::clone(&binding) as Arc<_>,
        "task-42",
        Arc::clone(&factory) as Arc<_>,
        7,
        ExecutorConfig::default(),
    );
    let outputs_r1: Vec<String> =
        r1.history().iter().map(|(_, r)| r.output.clone()).collect();
    for (i, c) in script.iter().enumerate() {
        let o = r2.call(bash(c));
        assert!(o.hit, "warm cache must hit: {c}");
        assert_eq!(o.result.output, outputs_r1[i], "cached output mismatch");
    }

    // Diverge statefully: must execute, not serve stale.
    let o = r2.call(bash("patch src/module_0.py s/return x - 8/return x + 8/"));
    assert!(!o.hit);
}

/// The acceptance contract: the in-process sharded service and the HTTP
/// binding implement the *same* `CacheBackend` behaviour — one test body,
/// both backends.
fn exercise_backend(backend: &dyn CacheBackend, task: &str) {
    let traj: Vec<(ToolCall, ToolResult)> = [("git clone repo", "ok"), ("make", "build OK")]
        .iter()
        .map(|(c, r)| (bash(c), ToolResult::new(*r, 5.0)))
        .collect();
    let q: Vec<ToolCall> = traj.iter().map(|(c, _)| c.clone()).collect();

    // Cold miss, insert, warm hit.
    assert!(!backend.lookup(task, &q).is_hit());
    let node = backend.insert(task, &traj);
    assert!(node > 0);
    match backend.lookup(task, &q) {
        Lookup::Hit { result, .. } => assert_eq!(result.output, "build OK"),
        m => panic!("expected hit, got {m:?}"),
    }

    // Snapshot store/fetch round trip.
    let snap = SandboxSnapshot {
        bytes: b"sandbox-state".to_vec(),
        serialize_cost: 0.4,
        restore_cost: 0.6,
    };
    let id = backend.store_snapshot(task, node, snap);
    assert!(id > 0, "store must return the real id");
    let fetched = backend.fetch_snapshot(task, id).expect("snapshot fetchable");
    assert_eq!(fetched.bytes, b"sandbox-state");
    assert!((fetched.restore_cost - 0.6).abs() < 1e-9);

    // A longer trajectory misses but offers the snapshot as resume; the
    // resume pin is released afterwards.
    let mut longer = q.clone();
    longer.push(bash("make test"));
    match backend.lookup(task, &longer) {
        Lookup::Miss(m) => {
            let (rnode, sref, replay_from) = m.resume.expect("resume offered");
            assert_eq!(rnode, node);
            assert_eq!(sref.id, id);
            assert_eq!(replay_from, 2);
            backend.release(task, rnode);
        }
        h => panic!("expected miss, got {h:?}"),
    }

    // Warm-fork flag round trip.
    assert!(!backend.has_warm_fork(task, node));
    backend.set_warm_fork(task, node, true);
    assert!(backend.has_warm_fork(task, node));
    backend.set_warm_fork(task, node, false);
    assert!(!backend.has_warm_fork(task, node));

    // Statistics flow through the same surface.
    let stats = backend.stats(task);
    assert_eq!(stats.lookups, 3);
    assert_eq!(stats.hits, 1);
    assert_eq!(stats.snapshot_resumes, 1);
    assert!(stats.inserts >= 2);
    let agg = backend.service_stats();
    assert!(agg.lookups >= 3);
    assert!(agg.tasks >= 1);
    assert!(agg.snapshots >= 1);
}

#[test]
fn backend_parity_inprocess_and_http() {
    let sharded = ShardedCacheService::new(4);
    exercise_backend(&sharded, "parity-task");

    let (server, _svc) = serve("127.0.0.1:0", 4).unwrap();
    let remote = RemoteBinding::connect(server.addr());
    exercise_backend(&remote, "parity-task");
}

/// The paper's correctness theorem, tested as a property over random
/// trajectories: for any interleaving of rollouts over a shared cache, the
/// output of every call equals a fresh cacheless execution of the same
/// prefix on a clean sandbox.
#[test]
fn property_cached_equals_uncached_replay() {
    let commands = [
        "cat README.md",
        "cat Makefile",
        "pip install libdep1",
        "make",
        "make test",
        "patch src/module_1.py s/return x - 2/return x + 2/",
        "echo note > scratch.txt",
        "cat scratch.txt",
        "grep return src/module_1.py",
        "cp README.md copy.md",
    ];
    let mut rng = Rng::new(0xC0FFEE);
    let task_seed = 1;

    for trial in 0..20 {
        let backend = Arc::new(ShardedCacheService::new(2));
        let factory = Arc::new(TerminalFactory { medium: false });

        // 3 rollouts with random trajectories sharing one cache.
        for _rollout in 0..3 {
            let mut exec = ToolCallExecutor::new(
                Arc::clone(&backend) as Arc<_>,
                "prop-task",
                Arc::clone(&factory) as Arc<_>,
                task_seed,
                ExecutorConfig::default(),
            );
            let n = 2 + rng.below(7) as usize;
            let calls: Vec<&str> = (0..n)
                .map(|_| commands[rng.below(commands.len() as u64) as usize])
                .collect();

            // Reference: replay the same prefix on a fresh sandbox.
            let mut reference = factory.create(task_seed);
            for c in &calls {
                let got = exec.call(bash(c)).result.output;
                let want = reference.execute(&bash(c)).output;
                assert_eq!(got, want, "trial {trial}: divergence at {c} in {calls:?}");
            }
        }
    }
}

/// Sandbox state fingerprints agree between cached reconstruction paths and
/// direct execution (the stronger internal invariant).
#[test]
fn property_fingerprints_match_direct_execution() {
    let factory = TerminalFactory { medium: false };
    let mut rng = Rng::new(99);
    let pool = [
        "echo a > f1",
        "echo b >> f1",
        "pip install libdep1",
        "make",
        "cp f1 f2",
        "rm f2",
    ];
    for _ in 0..30 {
        let n = 1 + rng.below(6) as usize;
        let calls: Vec<&str> =
            (0..n).map(|_| pool[rng.below(pool.len() as u64) as usize]).collect();
        let mut a = factory.create(5);
        let mut b = factory.create(5);
        for c in &calls {
            a.execute(&bash(c));
        }
        // b executes via snapshot/restore mid-way.
        let mid = calls.len() / 2;
        for c in &calls[..mid] {
            b.execute(&bash(c));
        }
        let snap = b.snapshot();
        let mut b2 = factory.restore(&snap);
        for c in &calls[mid..] {
            b2.execute(&bash(c));
        }
        assert_eq!(
            a.state_fingerprint(),
            b2.state_fingerprint(),
            "snapshot round-trip diverged on {calls:?}"
        );
    }
}

/// Server persistence: a cache serialized to JSON and rebuilt serves the
/// same hits (sandboxes are gone, results remain — §3.4).
#[test]
fn persistence_recovery_after_crash() {
    let cache = TaskCache::with_defaults();
    let traj: Vec<(ToolCall, tvcache::cache::ToolResult)> = [
        ("git clone repo", "ok"),
        ("make", "build OK"),
        ("make test", "12 passed"),
    ]
    .iter()
    .map(|(c, r)| (bash(c), tvcache::cache::ToolResult::new(*r, 5.0)))
    .collect();
    cache.record_trajectory(&traj);

    let dump = cache.to_persistent_json().to_string();
    // "Crash": rebuild from disk bytes.
    let parsed = tvcache::util::json::parse(&dump).unwrap();
    let rebuilt = TaskCache::from_persistent_json(&parsed, LpmConfig::default()).unwrap();
    let q: Vec<ToolCall> = traj.iter().map(|(c, _)| c.clone()).collect();
    match rebuilt.lookup(&q) {
        tvcache::cache::Lookup::Hit { result, .. } => {
            assert_eq!(result.output, "12 passed")
        }
        m => panic!("expected hit after recovery, got {m:?}"),
    }
}

/// Concurrent rollouts over one HTTP server: no lost updates, consistent
/// hit accounting.
#[test]
fn concurrent_remote_rollouts() {
    let (server, svc) = serve("127.0.0.1:0", 4).unwrap();
    let addr = server.addr();
    let handles: Vec<_> = (0..4)
        .map(|t| {
            std::thread::spawn(move || {
                let binding = Arc::new(RemoteBinding::connect(addr));
                let factory = Arc::new(TerminalFactory { medium: false });
                let mut exec = ToolCallExecutor::new(
                    binding as Arc<_>,
                    "shared-task",
                    factory as Arc<_>,
                    3,
                    ExecutorConfig::default(),
                );
                for c in ["cat README.md", "make", &format!("echo t{t} > own.txt")] {
                    exec.call(bash(c));
                }
                exec.hits
            })
        })
        .collect();
    let total_hits: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let stats = svc.task("shared-task").stats();
    assert_eq!(stats.hits, total_hits);
    assert!(stats.lookups >= 12);
    // The shared prefix exists once; the divergent writes branch.
    assert!(svc.task("shared-task").node_count() >= 4);
}
