//! Integration tests: HTTP server ⇄ remote executor round trips, the
//! paper's correctness property end-to-end, backend parity (the same
//! `CacheBackend` contract over the in-process sharded service and the HTTP
//! binding — including spill/warm-start stats), persistence recovery, the
//! resume-offer eviction race, and a property-test sweep over random
//! trajectories.

use std::sync::Arc;

use tvcache::cache::{
    BackendStats, CacheBackend, CacheStats, CursorStep, Lookup, LpmConfig, NodeId,
    SessionBackend, ShardedCacheService, SnapshotCosts, TaskCache, ToolCall, ToolResult,
};
use tvcache::client::{ExecutorConfig, RemoteBinding, ToolCallExecutor};
use tvcache::sandbox::{SandboxFactory, SandboxSnapshot, TerminalFactory, ToolExecutionEnvironment};
use tvcache::server::serve;
use tvcache::util::rng::Rng;

fn bash(cmd: &str) -> ToolCall {
    let stateless =
        cmd.starts_with("cat ") || cmd.starts_with("ls") || cmd.starts_with("grep ");
    ToolCall::with_flag("bash", cmd, !stateless)
}

/// Remote executor over a real HTTP server: second rollout hits, divergent
/// stateful reads stay correct.
#[test]
fn remote_executor_end_to_end() {
    let (server, _svc) = serve("127.0.0.1:0", 4).unwrap();
    let binding = Arc::new(RemoteBinding::connect(server.addr()));
    let factory = Arc::new(TerminalFactory { medium: false });

    let script = ["cat README.md", "make", "make test"];
    let mut r1 = ToolCallExecutor::new(
        Arc::clone(&binding) as Arc<_>,
        "task-42",
        Arc::clone(&factory) as Arc<_>,
        7,
        ExecutorConfig::default(),
    );
    for c in script {
        assert!(!r1.call(bash(c)).hit, "cold cache must miss: {c}");
    }

    let mut r2 = ToolCallExecutor::new(
        Arc::clone(&binding) as Arc<_>,
        "task-42",
        Arc::clone(&factory) as Arc<_>,
        7,
        ExecutorConfig::default(),
    );
    let outputs_r1: Vec<String> =
        r1.history().iter().map(|(_, r)| r.output.clone()).collect();
    for (i, c) in script.iter().enumerate() {
        let o = r2.call(bash(c));
        assert!(o.hit, "warm cache must hit: {c}");
        assert_eq!(o.result.output, outputs_r1[i], "cached output mismatch");
    }

    // Diverge statefully: must execute, not serve stale.
    let o = r2.call(bash("patch src/module_0.py s/return x - 8/return x + 8/"));
    assert!(!o.hit);
}

/// The acceptance contract: the in-process sharded service and the HTTP
/// binding implement the *same* `CacheBackend` behaviour — one test body,
/// both backends.
fn exercise_backend(backend: &dyn CacheBackend, task: &str) {
    let traj: Vec<(ToolCall, ToolResult)> = [("git clone repo", "ok"), ("make", "build OK")]
        .iter()
        .map(|(c, r)| (bash(c), ToolResult::new(*r, 5.0)))
        .collect();
    let q: Vec<ToolCall> = traj.iter().map(|(c, _)| c.clone()).collect();

    // Cold miss, insert, warm hit.
    assert!(!backend.lookup(task, &q).is_hit());
    let node = backend.insert(task, &traj).expect("insert over healthy backend");
    assert!(node > 0);
    match backend.lookup(task, &q) {
        Lookup::Hit { result, .. } => assert_eq!(result.output, "build OK"),
        m => panic!("expected hit, got {m:?}"),
    }

    // Snapshot store/fetch round trip.
    let snap = SandboxSnapshot {
        bytes: b"sandbox-state".to_vec(),
        serialize_cost: 0.4,
        restore_cost: 0.6,
    };
    let id = backend.store_snapshot(task, node, snap);
    assert!(id > 0, "store must return the real id");
    let fetched = backend.fetch_snapshot(task, id).expect("snapshot fetchable");
    assert_eq!(fetched.bytes, b"sandbox-state");
    assert!((fetched.restore_cost - 0.6).abs() < 1e-9);

    // A longer trajectory misses but offers the snapshot as resume; the
    // resume pin is released afterwards.
    let mut longer = q.clone();
    longer.push(bash("make test"));
    match backend.lookup(task, &longer) {
        Lookup::Miss(m) => {
            let (rnode, sref, replay_from) = m.resume.expect("resume offered");
            assert_eq!(rnode, node);
            assert_eq!(sref.id, id);
            assert_eq!(replay_from, 2);
            backend.release(task, rnode);
        }
        h => panic!("expected miss, got {h:?}"),
    }

    // Warm-fork flag round trip.
    assert!(!backend.has_warm_fork(task, node));
    backend.set_warm_fork(task, node, true);
    assert!(backend.has_warm_fork(task, node));
    backend.set_warm_fork(task, node, false);
    assert!(!backend.has_warm_fork(task, node));

    // Statistics flow through the same surface.
    let stats = backend.stats(task);
    assert_eq!(stats.lookups, 3);
    assert_eq!(stats.hits, 1);
    assert_eq!(stats.snapshot_resumes, 1);
    assert!(stats.inserts >= 2);
    let agg = backend.service_stats();
    assert!(agg.lookups >= 3);
    assert!(agg.tasks >= 1);
    assert!(agg.snapshots >= 1);
}

#[test]
fn backend_parity_inprocess_and_http() {
    let sharded = ShardedCacheService::new(4);
    exercise_backend(&sharded, "parity-task");

    let (server, _svc) = serve("127.0.0.1:0", 4).unwrap();
    let remote = RemoteBinding::connect(server.addr());
    exercise_backend(&remote, "parity-task");
}

/// The cursor acceptance contract: identical step/record/seek behaviour —
/// including resume offers and statistics — over both backends.
fn exercise_cursor_backend(backend: &dyn SessionBackend, task: &str) {
    let traj: Vec<(ToolCall, ToolResult)> = [("git clone repo", "ok"), ("make", "build OK")]
        .iter()
        .map(|(c, r)| (bash(c), ToolResult::new(*r, 5.0)))
        .collect();
    let node = backend.insert(task, &traj).expect("insert over healthy backend");
    let snap = SandboxSnapshot {
        bytes: b"cursor-state".to_vec(),
        serialize_cost: 0.2,
        restore_cost: 0.4,
    };
    let snap_id = backend.store_snapshot(task, node, snap);
    assert!(snap_id > 0);

    let cur = backend.cursor_open(task);
    assert!(cur != 0, "both backends must support cursors");

    // Delta steps along the recorded chain: hits, O(1) each.
    match backend.cursor_step(task, cur, &bash("git clone repo")) {
        CursorStep::Hit { result, .. } => assert_eq!(result.output, "ok"),
        s => panic!("expected hit, got {s:?}"),
    }
    match backend.cursor_step(task, cur, &bash("make")) {
        CursorStep::Hit { node: n, result } => {
            assert_eq!(n, node);
            assert_eq!(result.output, "build OK");
        }
        s => panic!("expected hit, got {s:?}"),
    }

    // Divergent delta: a miss whose resume offer matches the full-prefix
    // walk's (the cursor node *is* the LPM match).
    match backend.cursor_step(task, cur, &bash("make test")) {
        CursorStep::Miss(m) => {
            assert_eq!(m.matched_node, node);
            assert_eq!(m.matched_calls, 2);
            let (rnode, sref, replay_from) = m.resume.expect("snapshot offered");
            assert_eq!((rnode, sref.id, replay_from), (node, snap_id, 2));
            backend.release(task, rnode);
        }
        s => panic!("expected miss, got {s:?}"),
    }

    // Record the executed delta; the extended chain is immediately live.
    let n2 = backend
        .cursor_record(task, cur, &bash("make test"), &ToolResult::new("12 passed", 7.0))
        .expect("record over healthy backend");
    assert!(n2 != 0 && n2 != node, "record must create the new node");

    // Next divergent step misses at the *new* node, with the ancestor's
    // snapshot as the resume offer.
    match backend.cursor_step(task, cur, &bash("echo done > s.txt")) {
        CursorStep::Miss(m) => {
            assert_eq!(m.matched_node, n2);
            assert_eq!(m.matched_calls, 3);
            let (rnode, sref, replay_from) = m.resume.expect("ancestor snapshot offered");
            assert_eq!((rnode, sref.id, replay_from), (node, snap_id, 2));
            backend.release(task, rnode);
        }
        s => panic!("expected miss, got {s:?}"),
    }

    // Seek back to the root replays the chain as hits.
    assert!(backend.cursor_seek(task, cur, 0, 0));
    match backend.cursor_step(task, cur, &bash("git clone repo")) {
        CursorStep::Hit { result, .. } => assert_eq!(result.output, "ok"),
        s => panic!("expected hit after seek, got {s:?}"),
    }
    backend.cursor_close(task, cur);

    // Cursor traffic flows through the same statistics as full lookups.
    let stats = backend.stats(task);
    assert_eq!(stats.lookups, 5);
    assert_eq!(stats.hits, 3);
    assert_eq!(stats.partial_hits, 2);
    assert_eq!(stats.snapshot_resumes, 2);
    assert!(stats.inserts >= 3);
}

#[test]
fn backend_parity_cursors_inprocess_and_http() {
    let sharded = ShardedCacheService::new(4);
    exercise_cursor_backend(&sharded, "cursor-parity");

    let (server, _svc) = serve("127.0.0.1:0", 4).unwrap();
    let remote = RemoteBinding::connect(server.addr());
    exercise_cursor_backend(&remote, "cursor-parity");
}

/// Forced cursor invalidation mid-rollout, on both backends: after every
/// call, the node the cursor pins is evicted server-side (subtree removal),
/// so the next step reports `Invalid` and the executor must fall back to a
/// full-prefix lookup + insert + re-seek — outputs must equal a clean
/// cacheless execution, and no pin may leak.
fn exercise_cursor_invalidation_mid_rollout(
    backend: Arc<dyn SessionBackend>,
    evict: &dyn Fn(&str, usize) -> bool,
    pinned: &dyn Fn(&str) -> usize,
    task: &str,
) {
    let factory = Arc::new(TerminalFactory { medium: false });
    let script =
        ["pip install libdep1", "make", "echo go > f.txt", "make test", "cat f.txt"];

    // Rollout 1 populates the cache (cursor path).
    let mut warm = ToolCallExecutor::new(
        Arc::clone(&backend),
        task,
        Arc::clone(&factory) as Arc<_>,
        13,
        ExecutorConfig::default(),
    );
    for c in script {
        warm.call(bash(c));
    }
    warm.finish();

    // Rollout 2: evict the cursor's node after every call.
    let mut exec = ToolCallExecutor::new(
        Arc::clone(&backend),
        task,
        Arc::clone(&factory) as Arc<_>,
        13,
        ExecutorConfig::default(),
    );
    let mut reference = factory.create(13);
    let mut evictions = 0;
    for (i, c) in script.iter().enumerate() {
        let got = exec.call(bash(c)).result.output;
        let want = reference.execute(&bash(c)).output;
        assert_eq!(got, want, "{task}: cursor invalidation corrupted call {i} ({c})");
        // Locate the rollout's current TCG position via a full-prefix
        // lookup, then remove its subtree out from under the cursor.
        let q: Vec<ToolCall> = script[..=i].iter().map(|s| bash(s)).collect();
        match backend.lookup(task, &q) {
            Lookup::Hit { node, .. } => {
                if evict(task, node) {
                    evictions += 1;
                }
            }
            Lookup::Miss(m) => {
                // Unexpected here, but a miss's resume offer pins on the
                // in-process backend: hand the pin back.
                if let Some((rnode, _, _)) = m.resume {
                    backend.release(task, rnode);
                }
            }
        }
    }
    exec.finish();
    assert!(evictions >= 3, "{task}: the test must actually force invalidations");
    assert_eq!(pinned(task), 0, "{task}: invalidation fallback leaked a pin");
}

#[test]
fn cursor_invalidation_mid_rollout_on_both_backends() {
    let sharded = Arc::new(ShardedCacheService::new(2));
    {
        let white = Arc::clone(&sharded);
        let pin_svc = Arc::clone(&sharded);
        exercise_cursor_invalidation_mid_rollout(
            Arc::clone(&sharded) as Arc<dyn SessionBackend>,
            &move |task, node| white.evict_node(task, node),
            &move |task| pin_svc.task(task).pinned_node_count(),
            "inval-inproc",
        );
    }

    let (server, svc) = serve("127.0.0.1:0", 4).unwrap();
    let binding = Arc::new(RemoteBinding::connect(server.addr()));
    let white = Arc::clone(&svc);
    let pin_svc = Arc::clone(&svc);
    exercise_cursor_invalidation_mid_rollout(
        binding as Arc<dyn SessionBackend>,
        &move |task, node| white.evict_node(task, node),
        &move |task| pin_svc.task(task).pinned_node_count(),
        "inval-http",
    );
}

/// Persist from one backend, warm-start another, and report what the
/// warm-started side observes — shared by both backend kinds below.
fn exercise_warm_start(
    src: &dyn CacheBackend,
    dst: &dyn CacheBackend,
    dir: &str,
) -> BackendStats {
    let traj: Vec<(ToolCall, ToolResult)> = [("git clone repo", "ok"), ("make", "built")]
        .iter()
        .map(|(c, r)| (bash(c), ToolResult::new(*r, 5.0)))
        .collect();
    let q: Vec<ToolCall> = traj.iter().map(|(c, _)| c.clone()).collect();
    let node = src.insert("ws-task", &traj).expect("insert over healthy backend");
    let snap = SandboxSnapshot {
        bytes: vec![5u8; 96],
        serialize_cost: 0.2,
        restore_cost: 0.4,
    };
    let id = src.store_snapshot("ws-task", node, snap);
    assert!(id > 0);
    assert!(src.persist(dir), "persist must succeed");

    assert!(dst.warm_start(dir), "warm-start must succeed");
    assert!(dst.lookup("ws-task", &q).is_hit(), "warm-started TCG must hit");
    // The snapshot ref survived as a spilled payload and faults in with
    // its content intact and the disk penalty on the restore cost.
    let fetched = dst.fetch_snapshot("ws-task", id).expect("payload faults in");
    assert_eq!(fetched.bytes, vec![5u8; 96]);
    assert!(
        fetched.restore_cost >= 0.4,
        "restore cost lost in the spill manifest: {}",
        fetched.restore_cost
    );
    dst.service_stats()
}

/// The eviction/spill statistics and warm-start behaviour are identical
/// between the in-process service and the HTTP binding.
#[test]
fn backend_parity_warm_start_and_spill_stats() {
    let dir_a = std::env::temp_dir()
        .join(format!("tvcache-parity-a-{}", std::process::id()));
    let dir_b = std::env::temp_dir()
        .join(format!("tvcache-parity-b-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);

    let src = ShardedCacheService::new(4);
    let dst = ShardedCacheService::new(4);
    let stats_inproc = exercise_warm_start(&src, &dst, dir_a.to_str().unwrap());

    let (server_src, _s1) = tvcache::server::serve_with("127.0.0.1:0", 2, 4).unwrap();
    let (server_dst, _s2) = tvcache::server::serve_with("127.0.0.1:0", 2, 4).unwrap();
    let remote_src = RemoteBinding::connect(server_src.addr());
    let remote_dst = RemoteBinding::connect(server_dst.addr());
    let stats_http = exercise_warm_start(&remote_src, &remote_dst, dir_b.to_str().unwrap());

    assert_eq!(
        stats_inproc, stats_http,
        "spill/warm-start statistics diverged between backends"
    );
    assert_eq!(stats_inproc.spilled_snapshots, 1);
    assert_eq!(stats_inproc.spilled_bytes, 96);
    assert_eq!(stats_inproc.spill_faults, 1);
    assert_eq!(stats_inproc.snapshots, 1);

    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
}

/// The content-addressed payload tier is visible end-to-end: identical
/// snapshots stored under different tasks collapse to one stored copy, and
/// `dedup_hits` surfaces through `service_stats` on the in-process service
/// and over HTTP alike.
#[test]
fn dedup_hits_visible_on_both_backends() {
    fn store_twins(b: &dyn CacheBackend) -> BackendStats {
        for t in ["twin-a", "twin-b", "twin-c"] {
            let node = b
                .insert(t, &[(bash("make"), ToolResult::new("ok", 2.0))])
                .expect("insert over healthy backend");
            let snap = SandboxSnapshot {
                bytes: vec![0xCD; 512],
                serialize_cost: 0.1,
                restore_cost: 0.2,
            };
            assert!(b.store_snapshot(t, node, snap) > 0);
        }
        b.service_stats()
    }

    let svc = ShardedCacheService::new(4);
    let stats_inproc = store_twins(&svc);

    let (server, _svc2) = tvcache::server::serve_with("127.0.0.1:0", 2, 4).unwrap();
    let remote = RemoteBinding::connect(server.addr());
    let stats_http = store_twins(&remote);

    for stats in [&stats_inproc, &stats_http] {
        assert_eq!(stats.snapshots, 3);
        assert_eq!(stats.dedup_hits, 2, "identical payloads must dedup");
        assert_eq!(stats.dedup_resident_bytes_saved, 2 * 512);
        assert_eq!(stats.snapshot_bytes, 512, "shared payload charged once");
    }
    assert_eq!(stats_inproc, stats_http, "payload-tier stats diverged");
}

/// A `CacheBackend` decorator that evicts the offered resume node right
/// after every lookup returns — the narrowest possible reproduction of the
/// resume-offer eviction race the server comment warns about (offers over
/// HTTP are unpinned): the offer is outstanding while the snapshot dies.
struct EvictAfterLookup {
    inner: RemoteBinding,
    svc: Arc<tvcache::server::CacheService>,
}

impl CacheBackend for EvictAfterLookup {
    fn lookup(&self, task: &str, q: &[ToolCall]) -> Lookup {
        let out = self.inner.lookup(task, q);
        if let Lookup::Miss(m) = &out {
            if let Some((node, _, _)) = m.resume {
                // Server-side eviction lands between the offer and the
                // client's fetch.
                self.svc.evict_snapshot(task, node);
            }
        }
        out
    }

    fn insert(&self, task: &str, traj: &[(ToolCall, ToolResult)]) -> Option<NodeId> {
        self.inner.insert(task, traj)
    }

    fn release(&self, task: &str, node: NodeId) {
        self.inner.release(task, node);
    }

    fn should_snapshot(&self, task: &str, costs: SnapshotCosts) -> bool {
        self.inner.should_snapshot(task, costs)
    }

    fn store_snapshot(&self, task: &str, node: NodeId, snap: SandboxSnapshot) -> u64 {
        self.inner.store_snapshot(task, node, snap)
    }

    fn fetch_snapshot(&self, task: &str, id: u64) -> Option<SandboxSnapshot> {
        self.inner.fetch_snapshot(task, id)
    }

    fn set_warm_fork(&self, task: &str, node: NodeId, warm: bool) {
        self.inner.set_warm_fork(task, node, warm);
    }

    fn has_warm_fork(&self, task: &str, node: NodeId) -> bool {
        self.inner.has_warm_fork(task, node)
    }

    fn stats(&self, task: &str) -> CacheStats {
        self.inner.stats(task)
    }

    fn service_stats(&self) -> BackendStats {
        self.inner.service_stats()
    }

    fn persist(&self, dir: &str) -> bool {
        self.inner.persist(dir)
    }

    fn warm_start(&self, dir: &str) -> bool {
        self.inner.warm_start(dir)
    }
}

// The decorator opts into the session surface with the defaults: no
// capabilities, no cursors — executors negotiate down to the full-prefix
// path (where the lookup decoration applies), exactly the transparent
// fallback the v2 API promises decorator backends.
impl SessionBackend for EvictAfterLookup {}

/// Regression for the race noted in `rust/src/server/mod.rs` (`lookup`):
/// an outstanding resume offer whose node is evicted before the fetch must
/// degrade to replay — correct output, no panic, no leaked pin.
#[test]
fn resume_offer_eviction_race_degrades_to_replay() {
    let (server, svc) = serve("127.0.0.1:0", 2).unwrap();
    let binding = RemoteBinding::connect(server.addr());

    // Wire-level shape first: offer → evict → fetch misses → release no-ops.
    let traj: Vec<(ToolCall, ToolResult)> =
        vec![(bash("make"), ToolResult::new("built", 9.0))];
    let node = binding.insert("race-task", &traj).expect("insert over live server");
    let id = binding.store_snapshot(
        "race-task",
        node,
        SandboxSnapshot { bytes: b"payload".to_vec(), serialize_cost: 0.2, restore_cost: 0.4 },
    );
    assert!(id > 0);
    let q = vec![bash("make"), bash("echo x > f")];
    let Lookup::Miss(m) = binding.lookup("race-task", &q) else { panic!("expected miss") };
    let (rnode, sref, _) = m.resume.expect("resume offered");
    assert!(svc.evict_snapshot("race-task", rnode), "white-box eviction failed");
    assert!(binding.fetch_snapshot("race-task", sref.id).is_none());
    binding.release("race-task", rnode); // saturating no-op, must not panic
    assert_eq!(svc.task("race-task").pinned_node_count(), 0);

    // Full executor drive across the same race: every miss's offer is
    // evicted before the executor can fetch; outputs must still match a
    // clean cacheless execution.
    let factory = Arc::new(TerminalFactory { medium: false });
    let racing = Arc::new(EvictAfterLookup {
        inner: RemoteBinding::connect(server.addr()),
        svc: Arc::clone(&svc),
    });
    let script = ["pip install libdep1", "make", "make test", "echo done > s.txt", "cat s.txt"];

    let mut warm = ToolCallExecutor::new(
        Arc::clone(&racing) as Arc<_>,
        "race-exec",
        Arc::clone(&factory) as Arc<_>,
        11,
        ExecutorConfig::default(),
    );
    for c in script {
        warm.call(bash(c));
    }
    let mut second = ToolCallExecutor::new(
        racing as Arc<_>,
        "race-exec",
        Arc::clone(&factory) as Arc<_>,
        11,
        ExecutorConfig::default(),
    );
    let outputs: Vec<String> =
        script.iter().map(|c| second.call(bash(c)).result.output).collect();

    let mut reference = factory.create(11);
    for (c, got) in script.iter().zip(&outputs) {
        let want = reference.execute(&bash(c)).output;
        assert_eq!(got, &want, "race degraded incorrectly at {c}");
    }
    assert_eq!(
        svc.task("race-exec").pinned_node_count(),
        0,
        "the race leaked a resume pin"
    );
}

/// The paper's correctness theorem, tested as a property over random
/// trajectories: for any interleaving of rollouts over a shared cache, the
/// output of every call equals a fresh cacheless execution of the same
/// prefix on a clean sandbox.
#[test]
fn property_cached_equals_uncached_replay() {
    let commands = [
        "cat README.md",
        "cat Makefile",
        "pip install libdep1",
        "make",
        "make test",
        "patch src/module_1.py s/return x - 2/return x + 2/",
        "echo note > scratch.txt",
        "cat scratch.txt",
        "grep return src/module_1.py",
        "cp README.md copy.md",
    ];
    let mut rng = Rng::new(0xC0FFEE);
    let task_seed = 1;

    for trial in 0..20 {
        let backend = Arc::new(ShardedCacheService::new(2));
        let factory = Arc::new(TerminalFactory { medium: false });

        // 3 rollouts with random trajectories sharing one cache.
        for _rollout in 0..3 {
            let mut exec = ToolCallExecutor::new(
                Arc::clone(&backend) as Arc<_>,
                "prop-task",
                Arc::clone(&factory) as Arc<_>,
                task_seed,
                ExecutorConfig::default(),
            );
            let n = 2 + rng.below(7) as usize;
            let calls: Vec<&str> = (0..n)
                .map(|_| commands[rng.below(commands.len() as u64) as usize])
                .collect();

            // Reference: replay the same prefix on a fresh sandbox.
            let mut reference = factory.create(task_seed);
            for c in &calls {
                let got = exec.call(bash(c)).result.output;
                let want = reference.execute(&bash(c)).output;
                assert_eq!(got, want, "trial {trial}: divergence at {c} in {calls:?}");
            }
        }
    }
}

/// Sandbox state fingerprints agree between cached reconstruction paths and
/// direct execution (the stronger internal invariant).
#[test]
fn property_fingerprints_match_direct_execution() {
    let factory = TerminalFactory { medium: false };
    let mut rng = Rng::new(99);
    let pool = [
        "echo a > f1",
        "echo b >> f1",
        "pip install libdep1",
        "make",
        "cp f1 f2",
        "rm f2",
    ];
    for _ in 0..30 {
        let n = 1 + rng.below(6) as usize;
        let calls: Vec<&str> =
            (0..n).map(|_| pool[rng.below(pool.len() as u64) as usize]).collect();
        let mut a = factory.create(5);
        let mut b = factory.create(5);
        for c in &calls {
            a.execute(&bash(c));
        }
        // b executes via snapshot/restore mid-way.
        let mid = calls.len() / 2;
        for c in &calls[..mid] {
            b.execute(&bash(c));
        }
        let snap = b.snapshot();
        let mut b2 = factory.restore(&snap);
        for c in &calls[mid..] {
            b2.execute(&bash(c));
        }
        assert_eq!(
            a.state_fingerprint(),
            b2.state_fingerprint(),
            "snapshot round-trip diverged on {calls:?}"
        );
    }
}

/// Server persistence: a cache serialized to JSON and rebuilt serves the
/// same hits (sandboxes are gone, results remain — §3.4).
#[test]
fn persistence_recovery_after_crash() {
    let cache = TaskCache::with_defaults();
    let traj: Vec<(ToolCall, tvcache::cache::ToolResult)> = [
        ("git clone repo", "ok"),
        ("make", "build OK"),
        ("make test", "12 passed"),
    ]
    .iter()
    .map(|(c, r)| (bash(c), tvcache::cache::ToolResult::new(*r, 5.0)))
    .collect();
    cache.record_trajectory(&traj);

    let dump = cache.to_persistent_json().to_string();
    // "Crash": rebuild from disk bytes.
    let parsed = tvcache::util::json::parse(&dump).unwrap();
    let rebuilt = TaskCache::from_persistent_json(&parsed, LpmConfig::default()).unwrap();
    let q: Vec<ToolCall> = traj.iter().map(|(c, _)| c.clone()).collect();
    match rebuilt.lookup(&q) {
        tvcache::cache::Lookup::Hit { result, .. } => {
            assert_eq!(result.output, "12 passed")
        }
        m => panic!("expected hit after recovery, got {m:?}"),
    }
}

/// Concurrent rollouts over one HTTP server: no lost updates, consistent
/// hit accounting.
#[test]
fn concurrent_remote_rollouts() {
    let (server, svc) = serve("127.0.0.1:0", 4).unwrap();
    let addr = server.addr();
    let handles: Vec<_> = (0..4)
        .map(|t| {
            std::thread::spawn(move || {
                let binding = Arc::new(RemoteBinding::connect(addr));
                let factory = Arc::new(TerminalFactory { medium: false });
                let mut exec = ToolCallExecutor::new(
                    binding as Arc<_>,
                    "shared-task",
                    factory as Arc<_>,
                    3,
                    ExecutorConfig::default(),
                );
                for c in ["cat README.md", "make", &format!("echo t{t} > own.txt")] {
                    exec.call(bash(c));
                }
                exec.hits
            })
        })
        .collect();
    let total_hits: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let stats = svc.task("shared-task").stats();
    assert_eq!(stats.hits, total_hits);
    assert!(stats.lookups >= 12);
    // The shared prefix exists once; the divergent writes branch.
    assert!(svc.task("shared-task").node_count() >= 4);
}

// ---- session API v2 ----------------------------------------------------

/// Session/legacy parity: the batched turn path, the per-call cursor path,
/// and the cursorless full-prefix path must make *identical* hit/miss
/// decisions and produce identical outputs — on both backends.
#[test]
fn session_parity_batched_percall_and_legacy_on_both_backends() {
    let script = [
        "pip install libdep1",
        "cat README.md",
        "make",
        "ls -la",
        "make test",
        "echo done > s.txt",
        "cat s.txt",
    ];
    let configs = [
        ExecutorConfig::default(), // batched turns
        ExecutorConfig { batch_turns: false, ..ExecutorConfig::default() },
        ExecutorConfig { use_cursor: false, ..ExecutorConfig::default() },
    ];

    let drive = |backend: Arc<dyn SessionBackend>, tag: &str, cfg: ExecutorConfig| {
        let factory = Arc::new(TerminalFactory { medium: false });
        let mut decisions = Vec::new();
        let mut outputs = Vec::new();
        for rollout in 0..3 {
            let mut exec = ToolCallExecutor::new(
                Arc::clone(&backend),
                format!("parity-{tag}"),
                Arc::clone(&factory) as Arc<_>,
                21,
                cfg,
            );
            for c in script {
                let o = exec.call(bash(c));
                decisions.push((rollout, c, o.hit));
                outputs.push(o.result.output);
            }
            exec.finish();
        }
        (decisions, outputs)
    };

    // In-process: three fresh services, one per mode.
    let mut inproc = Vec::new();
    for (i, cfg) in configs.iter().enumerate() {
        let svc = Arc::new(ShardedCacheService::new(2));
        inproc.push(drive(svc as Arc<dyn SessionBackend>, &format!("in{i}"), *cfg));
    }
    assert_eq!(inproc[0], inproc[1], "batched vs per-call cursor decisions diverged");
    assert_eq!(inproc[0], inproc[2], "session vs legacy full-prefix decisions diverged");

    // HTTP: three fresh servers, one per mode.
    let mut http = Vec::new();
    for (i, cfg) in configs.iter().enumerate() {
        let (server, _svc) = serve("127.0.0.1:0", 4).unwrap();
        let binding = Arc::new(RemoteBinding::connect(server.addr()));
        http.push(drive(binding as Arc<dyn SessionBackend>, &format!("ht{i}"), *cfg));
    }
    assert_eq!(http[0], http[1], "HTTP batched vs per-call decisions diverged");
    assert_eq!(http[0], http[2], "HTTP session vs legacy decisions diverged");
    assert_eq!(inproc[0], http[0], "in-process vs HTTP session decisions diverged");
}

/// Regression (PR 4 satellite): an executor leaked mid-run — dropped
/// without `finish()`, as a panicking rollout would be — must free its
/// server-side session entry and every resume pin, on both backends.
#[test]
fn leaked_executor_frees_server_side_session_state() {
    let factory = Arc::new(TerminalFactory { medium: false });

    // In-process.
    let sharded = Arc::new(ShardedCacheService::new(2));
    let mut exec = ToolCallExecutor::new(
        Arc::clone(&sharded) as Arc<dyn SessionBackend>,
        "leak-inproc",
        Arc::clone(&factory) as Arc<_>,
        5,
        ExecutorConfig::default(),
    );
    exec.call(bash("pip install libdep1"));
    exec.call(bash("make"));
    assert_eq!(sharded.session_count(), 1);
    drop(exec); // no finish()
    assert_eq!(sharded.session_count(), 0, "in-process session entry leaked");
    assert_eq!(sharded.task("leak-inproc").pinned_node_count(), 0);

    // HTTP: the Drop guard must reach across the wire.
    let (server, svc) = serve("127.0.0.1:0", 4).unwrap();
    let binding = Arc::new(RemoteBinding::connect(server.addr()));
    let mut exec = ToolCallExecutor::new(
        Arc::clone(&binding) as Arc<dyn SessionBackend>,
        "leak-http",
        Arc::clone(&factory) as Arc<_>,
        5,
        ExecutorConfig::default(),
    );
    exec.call(bash("pip install libdep1"));
    exec.call(bash("make"));
    assert_eq!(svc.session_count(), 1);
    drop(exec);
    assert_eq!(svc.session_count(), 0, "HTTP session entry leaked");
    assert_eq!(svc.session_pin_count(), 0);
    assert_eq!(svc.task("leak-http").pinned_node_count(), 0);
}

/// The v2 pin contract over the wire: a `/session_turn` step-miss keeps
/// its resume offer *pinned* (unlike the legacy unpinned-offer lookups),
/// owned by the server-side session entry — and closing the session
/// releases whatever the client never did.
#[test]
fn turn_step_miss_pin_owned_by_session_until_close() {
    let (server, svc) = serve("127.0.0.1:0", 2).unwrap();
    let binding = RemoteBinding::connect(server.addr());
    let task = "turn-pin";

    let traj = vec![(bash("make"), ToolResult::new("built", 9.0))];
    let node = binding.insert(task, &traj).expect("insert over live server");
    let id = binding.store_snapshot(
        task,
        node,
        SandboxSnapshot { bytes: b"state".to_vec(), serialize_cost: 0.2, restore_cost: 0.4 },
    );
    assert!(id > 0);
    assert_eq!(binding.capabilities(), tvcache::cache::Capabilities::V2);

    // Turn 1: step hit on "make". Turn 2: divergent step miss — the offer
    // must arrive pinned and stay pinned (no unpin-before-reply).
    use tvcache::cache::{TurnBatch, TurnOp};
    let r1 = binding.session_turn(
        task,
        0,
        &TurnBatch { probes: Vec::new(), op: TurnOp::Step(bash("make")) },
    );
    assert!(r1.cursor != 0, "first turn frame must open the session");
    assert!(matches!(r1.step, Some(CursorStep::Hit { .. })));
    let r2 = binding.session_turn(
        task,
        r1.cursor,
        &TurnBatch { probes: Vec::new(), op: TurnOp::Step(bash("echo x > f")) },
    );
    let Some(CursorStep::Miss(m)) = r2.step else { panic!("expected miss: {r2:?}") };
    let (rnode, _, _) = m.resume.expect("resume offered");
    assert_eq!(rnode, node);
    assert_eq!(svc.task(task).pinned_node_count(), 1, "turn offer must stay pinned");
    assert_eq!(svc.session_pin_count(), 1);

    // An eviction attempt while pinned must fail (the §3.4 guarantee the
    // legacy wire protocol could not give).
    assert!(!svc.evict_snapshot(task, rnode), "pinned snapshot must not evict");

    // Close without releasing: the session entry owns the pin and returns it.
    binding.cursor_close(task, r1.cursor);
    assert_eq!(svc.task(task).pinned_node_count(), 0, "close must release the pin");
    assert_eq!(svc.session_pin_count(), 0);
    assert_eq!(svc.session_count(), 0);
}

/// Capability negotiation against an old (pre-v2) server: simulated by a
/// server that 404s `/capabilities` — the binding must fall back to the
/// legacy binary+cursor profile with turn batching off, and the executor
/// must still work end-to-end through the per-call path.
#[test]
fn capability_fallback_for_old_servers() {
    use tvcache::util::http::{Handler, Request, Response, Server};

    // A "legacy" façade: forwards everything except /capabilities and the
    // session endpoints (which a pre-v2 server would 404) to a real
    // service.
    let (inner_server, inner_svc) = serve("127.0.0.1:0", 2).unwrap();
    let inner_addr = inner_server.addr();
    let handler: Handler = Arc::new(move |req: &Request| {
        if req.path == "/capabilities"
            || req.path == "/session_turn"
            || req.path == "/session_release"
        {
            return Response::not_found();
        }
        // Forward body + method (and the parsed query, reassembled) to the
        // real server.
        let mut path = req.path.clone();
        let mut sep = '?';
        for (k, v) in &req.query {
            path.push(sep);
            sep = '&';
            path.push_str(&tvcache::util::http::url_encode(k));
            path.push('=');
            path.push_str(&tvcache::util::http::url_encode(v));
        }
        let mut c = tvcache::util::http::HttpClient::connect(inner_addr);
        let out = if req.method == "GET" {
            c.get(&path)
        } else {
            c.post(&path, &req.body)
        };
        match out {
            Ok((200, body)) => Response::binary(body),
            Ok((status, _)) => Response::text_static(if status == 400 { 400 } else { 404 }, "err"),
            Err(_) => Response::text_static(500, "proxy error"),
        }
    });
    let facade = Server::bind("127.0.0.1:0", 2, handler).unwrap();

    let binding = Arc::new(RemoteBinding::connect(facade.addr()));
    let caps = binding.capabilities();
    assert_eq!(caps, tvcache::cache::Capabilities::LEGACY, "handshake must fall back");

    let factory = Arc::new(TerminalFactory { medium: false });
    let script = ["make", "make test"];
    for rollout in 0..2 {
        let mut exec = ToolCallExecutor::new(
            Arc::clone(&binding) as Arc<dyn SessionBackend>,
            "old-server-task",
            Arc::clone(&factory) as Arc<_>,
            9,
            ExecutorConfig::default(),
        );
        for c in script {
            let o = exec.call(bash(c));
            assert_eq!(o.hit, rollout > 0, "legacy fallback broke caching: {c}");
        }
        exec.finish();
    }
    assert!(inner_svc.task("old-server-task").stats().hits >= 2);
}
