//! Cluster-layer integration suite: consistent-hash routing over several
//! in-process replication groups.
//!
//! The acceptance scenario mirrors `tests/faults.rs::kill_primary_scenario`
//! one level up: three primary+follower groups serve 64 tasks through a
//! [`ClusterRouter`], one group's primary dies mid-run, and the failover
//! must stay *inside* that group — rewards bit-identical to a cacheless
//! run, exactly one promote-and-switch on the victim binding, zero on the
//! others, and the `/cluster_stats` fan-in reflecting the new epoch. The
//! suite also covers the server-side placement guard (421 on misrouted
//! tasks) and the extended-hello identity tripwire.
//!
//! Every test installs a [`fault::FaultScope`] — even a quiet one —
//! because installation holds a process-global lock: I/O tests serialize
//! instead of arming each other's seams.

use std::sync::Arc;
use std::time::Duration;

use tvcache::cache::{
    CacheBackend, Capabilities, ServiceConfig, SessionBackend, ShardedCacheService, TaskCache,
    ToolCall, ToolResult,
};
use tvcache::client::{BindingConfig, RemoteBinding};
use tvcache::cluster::{ClusterMap, ClusterRouter, GroupSpec};
use tvcache::server::{serve_follower, serve_service, CacheService};
use tvcache::train::{run_concurrent, run_concurrent_on, ConcurrentOptions};
use tvcache::util::fault;
use tvcache::util::http::Server;
use tvcache::workloads::{Workload, WorkloadConfig};

fn bash(cmd: &str) -> ToolCall {
    ToolCall::with_flag("bash", cmd, true)
}

fn traj(cmds: &[&str]) -> Vec<(ToolCall, ToolResult)> {
    cmds.iter().map(|c| (bash(c), ToolResult::new(format!("out-{c}"), 3.0))).collect()
}

/// Short deadlines, a breaker that cannot half-open mid-test, and no
/// promote-probe gating (failover paths here want every pass to probe).
fn fast_cfg() -> BindingConfig {
    BindingConfig {
        connect_timeout: Duration::from_millis(500),
        read_timeout: Duration::from_secs(2),
        retries: 1,
        backoff_base: Duration::from_millis(1),
        backoff_max: Duration::from_millis(4),
        breaker_threshold: 1000,
        breaker_cooldown: Duration::from_secs(60),
        seed: 0xC1EED,
        probe_cooldown: Duration::ZERO,
        endpoints: Vec::new(),
    }
}

/// A 2-shard service with an op-log window, the building block of every
/// replication group here.
fn replicated_svc() -> ShardedCacheService {
    ShardedCacheService::with_config(
        ServiceConfig { shards: 2, replicate_window: Some(1 << 16), ..Default::default() },
        Arc::new(TaskCache::with_defaults),
    )
    .unwrap()
}

/// One in-process replication group: primary + tailing follower.
/// `primary` is an `Option` so a test can kill it while the follower (and
/// the group's slot in the vector) lives on.
struct GroupNodes {
    primary: Option<Server>,
    follower: Server,
    follower_svc: Arc<CacheService>,
}

fn spawn_group() -> GroupNodes {
    let (p_server, _p_svc) = serve_service("127.0.0.1:0", 4, replicated_svc()).unwrap();
    let (f_server, f_svc) =
        serve_follower("127.0.0.1:0", 4, replicated_svc(), p_server.addr()).unwrap();
    assert!(f_svc.is_follower());
    GroupNodes { primary: Some(p_server), follower: f_server, follower_svc: f_svc }
}

/// Poll a remote lookup until it hits (followers tail on a millisecond
/// tick, so convergence is quick). HTTP on purpose: resume offers over
/// the wire are unpinned server-side, so polling cannot leak pins.
fn await_remote_hit(probe: &RemoteBinding, task: &str, call: &ToolCall) {
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while !probe.lookup(task, std::slice::from_ref(call)).is_hit() {
        assert!(
            std::time::Instant::now() < deadline,
            "follower never served {task:?} — replication stalled"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// The acceptance bar for the cluster layer: 64 tasks over three
/// replicated groups, one primary killed between epochs. The victim
/// group fails over to its own follower; the others never notice; the
/// rewards are bit-identical to running with no cache at all.
#[test]
fn kill_one_primary_fails_over_only_that_group() {
    let _scope = fault::install(fault::FaultPlan::quiet(31)); // serialize I/O tests
    let cfg = WorkloadConfig::config_for(Workload::TerminalEasy);
    let mut opts = ConcurrentOptions::from_config(&cfg, 64);
    opts.epochs = 1;
    opts.threads = 4;
    let mut base_opts = opts.clone();
    base_opts.cached = false;
    let baseline = run_concurrent(&cfg, &base_opts);

    // Three primary+follower groups, mapped on a 32-vnode ring.
    let mut nodes: Vec<GroupNodes> = (0..3).map(|_| spawn_group()).collect();
    let groups: Vec<GroupSpec> = nodes
        .iter()
        .enumerate()
        .map(|(i, n)| GroupSpec {
            name: format!("g{i}"),
            primary: n.primary.as_ref().unwrap().addr(),
            follower: Some(n.follower.addr()),
        })
        .collect();
    let map = ClusterMap::new(0xC1A5, 32, groups).unwrap();

    // The driver names its tasks `task-{i}`: the ring must spread these
    // 64 across all three groups or the isolation claim is vacuous.
    let mut placed = vec![0usize; 3];
    for t in 0..opts.n_tasks {
        placed[map.group_for(&format!("task-{t}"))] += 1;
    }
    assert!(placed.iter().all(|&n| n > 0), "ring left a group idle: {placed:?}");
    // Kill the busiest group's primary: the failover must happen under
    // real traffic, not on an idle corner of the ring.
    let victim = (0..3).max_by_key(|&g| placed[g]).unwrap();

    // Threshold 6 > the 4 worker threads (stale in-flight dials against
    // the dead endpoint can never re-trip the breaker post-failover),
    // retries 0 so the trip happens within the first rollouts.
    let router = Arc::new(ClusterRouter::connect(
        map.clone(),
        BindingConfig {
            retries: 0,
            breaker_threshold: 6,
            breaker_cooldown: Duration::from_millis(200),
            ..fast_cfg()
        },
    ));
    assert!(router.check_identity(), "unarmed nodes must pass the identity tripwire");

    // Warm epoch across the whole cluster.
    let warm = run_concurrent_on(&cfg, &opts, Arc::clone(&router) as Arc<dyn SessionBackend>);
    assert_eq!(warm.rewards, baseline.rewards, "a cold cluster cache changed rewards");
    assert!(warm.rollouts_run > 0);
    for g in 0..3 {
        assert!(
            router.binding(g).service_stats().lookups > 0,
            "group {g} saw no traffic during the warm epoch"
        );
    }

    // The op-log is ordered: once this sentinel — the newest entry on the
    // victim group — is served by its follower, everything the warm epoch
    // wrote there is too.
    let sentinel = (0..)
        .map(|k| format!("sentinel-{k}"))
        .find(|t| map.group_for(t) == victim)
        .unwrap();
    router.insert(&sentinel, &traj(&["sentinel"])).expect("sentinel insert on the victim group");
    let probe = RemoteBinding::connect_with(nodes[victim].follower.addr(), fast_cfg());
    await_remote_hit(&probe, &sentinel, &bash("sentinel"));
    assert_eq!(nodes[victim].follower_svc.replica_lag_ops(), 0);

    // Kill the victim primary. The next epoch starts with one group dead:
    // its breaker trips within the first rollouts, the binding promotes
    // the follower mid-run, and only that group's sessions re-seed.
    nodes[victim].primary = None;
    let t0 = std::time::Instant::now();
    let failed_over =
        run_concurrent_on(&cfg, &opts, Arc::clone(&router) as Arc<dyn SessionBackend>);

    assert_eq!(
        failed_over.rollouts_run, baseline.rollouts_run,
        "every rollout must finish through the failover"
    );
    assert_eq!(failed_over.rewards, baseline.rewards, "cluster failover changed rewards");
    for g in 0..3 {
        let expect = u64::from(g == victim);
        assert_eq!(
            router.binding(g).failovers(),
            expect,
            "group {g}: failover blast radius must stay on the victim"
        );
    }
    assert!(!nodes[victim].follower_svc.is_follower(), "victim follower must be promoted");
    assert!(nodes[victim].follower_svc.epoch() >= 2, "promotion must bump the fencing epoch");

    // The `/cluster_stats` fan-in reflects the event: the victim group
    // now routes to its follower at a bumped epoch, the others still sit
    // on their epoch-1 primaries.
    let cs = router.cluster_stats();
    assert_eq!(cs.groups.len(), 3);
    for (g, status) in cs.groups.iter().enumerate() {
        assert!(status.reachable, "group {g} must answer /stats");
        assert_eq!(status.role, "primary", "group {g} active node must serve as primary");
        assert_eq!(status.replica_lag_ops, 0);
        if g == victim {
            assert_eq!(status.endpoint, nodes[g].follower.addr());
            assert_eq!(status.failovers, 1);
            assert!(status.epoch >= 2, "victim epoch must reflect the promotion");
        } else {
            assert_eq!(status.failovers, 0);
            assert_eq!(status.epoch, 1);
        }
    }
    assert!(cs.merged.lookups > 0);
    assert!(cs.merged.epoch >= 2, "merged epoch is the max across groups");
    assert!(
        t0.elapsed() < Duration::from_secs(60),
        "failed-over cluster run must stay deadline-bounded"
    );
}

/// The server-side half of placement enforcement: a map-armed node
/// answers `421 Misdirected Request` to any task the ring places
/// elsewhere, so a stale router cannot silently populate the wrong cache.
#[test]
fn armed_server_rejects_misrouted_tasks() {
    let _scope = fault::install(fault::FaultPlan::quiet(32)); // serialize I/O tests
    let (server, svc) = serve_service("127.0.0.1:0", 2, replicated_svc()).unwrap();
    // g1's endpoint is never contacted — it only exists so the ring has
    // somewhere else to place tasks.
    let map = ClusterMap::new(
        7,
        32,
        vec![
            GroupSpec { name: "g0".into(), primary: server.addr(), follower: None },
            GroupSpec {
                name: "g1".into(),
                primary: "127.0.0.1:1".parse().unwrap(),
                follower: None,
            },
        ],
    )
    .unwrap();
    svc.set_node_id("g0/primary");
    svc.set_cluster_guard(map.clone(), 0);

    let local = (0..).map(|k| format!("mine-{k}")).find(|t| map.group_for(t) == 0).unwrap();
    let foreign = (0..).map(|k| format!("theirs-{k}")).find(|t| map.group_for(t) == 1).unwrap();

    let binding = RemoteBinding::connect_with(server.addr(), fast_cfg());
    // The task the map places here flows normally…
    binding.insert(&local, &traj(&["make"])).expect("placed task must be served");
    assert!(binding.lookup(&local, &[bash("make")]).is_hit());
    assert_eq!(svc.misroutes(), 0);

    // …the misplaced one degrades like any other backend failure: insert
    // to the `None` sentinel, lookup to a full miss, and the rejection is
    // visible in the server's misroute counter.
    assert_eq!(binding.insert(&foreign, &traj(&["make"])), None);
    assert!(!binding.lookup(&foreign, &[bash("make")]).is_hit());
    assert!(svc.misroutes() >= 2, "both misrouted ops must be counted");

    // The guard never poisoned the placed task's path.
    assert!(binding.insert(&local, &traj(&["make", "two"])).is_some());
}

/// The identity tripwire: the extended `/capabilities` hello carries the
/// node identity, and [`ClusterRouter::check_identity`] compares it with
/// what the map expects at that endpoint.
#[test]
fn identity_check_flags_a_swapped_node() {
    let _scope = fault::install(fault::FaultPlan::quiet(33)); // serialize I/O tests
    let (server, svc) = serve_service("127.0.0.1:0", 2, replicated_svc()).unwrap();
    let single = |name: &str| {
        ClusterMap::new(
            1,
            8,
            vec![GroupSpec { name: name.into(), primary: server.addr(), follower: None }],
        )
        .unwrap()
    };

    // No identity configured: nothing to disprove, the check passes (the
    // tripwire must not fail a fleet that simply predates --node-id).
    let router = ClusterRouter::connect(single("g0"), fast_cfg());
    assert!(router.check_identity());
    assert_eq!(router.identity_mismatches(), 0);

    // The right identity passes, and the plain-hello path still works —
    // the extended frame is an upgrade, not a break.
    svc.set_node_id("g0/primary");
    assert!(router.check_identity());
    assert_eq!(router.identity_mismatches(), 0);
    assert_eq!(router.binding(0).capabilities(), Capabilities::V2);

    // A map that believes this endpoint is group "gx" is a wiring error:
    // the node answers the mismatched expectation with 421 and the check
    // flags it.
    let wrong = ClusterRouter::connect(single("gx"), fast_cfg());
    assert!(!wrong.check_identity());
    assert_eq!(wrong.identity_mismatches(), 1);
    assert!(svc.misroutes() >= 1, "the node counts the identity rejection");
}
