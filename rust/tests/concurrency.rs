//! Concurrency stress tests for the sharded cache service: 8 threads ×
//! 1000 mixed lookup/insert/release operations, verifying that statistics
//! balance exactly, that the snapshot path never loses bytes, and — by
//! virtue of finishing — that no lock ordering deadlocks.

use std::sync::Arc;

use tvcache::cache::{
    CacheBackend, Lookup, ShardedCacheService, ToolCall, ToolResult,
};
use tvcache::sandbox::SandboxSnapshot;

const THREADS: usize = 8;
const OPS_PER_THREAD: usize = 1000;
const TASKS: usize = 16;

fn call(s: String) -> ToolCall {
    ToolCall::new("bash", s)
}

fn traj(calls: &[String]) -> Vec<(ToolCall, ToolResult)> {
    calls
        .iter()
        .map(|c| (call(c.clone()), ToolResult::new(format!("out-{c}"), 1.0)))
        .collect()
}

#[test]
fn sharded_service_stress_8x1000_mixed_ops() {
    let svc = Arc::new(ShardedCacheService::new(4));

    // Per-thread tallies returned at join; compared against service stats.
    struct Tally {
        lookups: u64,
        hits: u64,
        snapshots_stored: u64,
    }

    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let svc = Arc::clone(&svc);
            std::thread::spawn(move || {
                let mut tally = Tally { lookups: 0, hits: 0, snapshots_stored: 0 };
                for i in 0..OPS_PER_THREAD {
                    // Tasks are shared across threads so shard task maps,
                    // TCG locks, and snapshot stores all see contention.
                    let task = format!("task-{}", (t + i) % TASKS);
                    // Depth decoupled from the op selector so inserts and
                    // lookups cover the same trajectory family.
                    let depth = 1 + ((i / 3) % 3);
                    let calls: Vec<String> =
                        (0..depth).map(|d| format!("step-{d}-{}", i % 7)).collect();
                    match i % 3 {
                        0 => {
                            // Insert a trajectory, occasionally snapshot it.
                            let node = svc
                                .insert(&task, &traj(&calls))
                                .expect("in-process insert cannot fail");
                            if i % 9 == 0 {
                                let snap = SandboxSnapshot {
                                    bytes: vec![t as u8; 32],
                                    serialize_cost: 0.1,
                                    restore_cost: 0.2,
                                };
                                // id 0 = attach rejected (node briefly
                                // pinned by a racing lookup): legitimate.
                                let id = svc.store_snapshot(&task, node, snap);
                                if id > 0 {
                                    tally.snapshots_stored += 1;
                                }
                            }
                        }
                        1 => {
                            // Lookup the same family of trajectories.
                            let q: Vec<ToolCall> =
                                calls.iter().map(|c| call(c.clone())).collect();
                            tally.lookups += 1;
                            match svc.lookup(&task, &q) {
                                Lookup::Hit { .. } => tally.hits += 1,
                                Lookup::Miss(m) => {
                                    // Release any resume pin immediately.
                                    if let Some((node, _, _)) = m.resume {
                                        svc.release(&task, node);
                                    }
                                }
                            }
                        }
                        _ => {
                            // Divergent lookup: exercises partial hits.
                            let mut q: Vec<ToolCall> =
                                calls.iter().map(|c| call(c.clone())).collect();
                            q.push(call(format!("divergent-{t}-{i}")));
                            tally.lookups += 1;
                            if let Lookup::Miss(m) = svc.lookup(&task, &q) {
                                if let Some((node, _, _)) = m.resume {
                                    svc.release(&task, node);
                                }
                            } else {
                                panic!("divergent call can never hit");
                            }
                        }
                    }
                }
                tally
            })
        })
        .collect();

    let mut issued_lookups = 0u64;
    let mut observed_hits = 0u64;
    let mut stored = 0u64;
    for h in handles {
        let t = h.join().expect("stress thread panicked (deadlock or poison)");
        issued_lookups += t.lookups;
        observed_hits += t.hits;
        stored += t.snapshots_stored;
    }

    // Stats balance exactly: every issued lookup was counted once, no more.
    let mut stat_lookups = 0u64;
    let mut stat_hits = 0u64;
    let mut stat_stored = 0u64;
    for i in 0..TASKS {
        let s = svc.stats(&format!("task-{i}"));
        assert!(s.hits <= s.lookups, "task-{i}: more hits than lookups");
        stat_lookups += s.lookups;
        stat_hits += s.hits;
        stat_stored += s.snapshots_stored;
    }
    assert_eq!(stat_lookups, issued_lookups, "lost or duplicated lookups");
    assert_eq!(stat_hits, observed_hits, "hit accounting diverged");
    assert_eq!(stat_stored, stored, "snapshot-store accounting diverged");
    assert!(observed_hits > 0, "the shared trajectory family must hit");

    // The aggregate view must agree with the per-task sums.
    let agg = svc.service_stats();
    assert_eq!(agg.lookups, stat_lookups);
    assert_eq!(agg.hits, stat_hits);
    assert_eq!(agg.tasks, TASKS);

    // All resume pins were released: every stored snapshot is evictable,
    // so the shard stores and the TCGs agree on what is left.
    let tcg_snapshots: usize =
        (0..TASKS).map(|i| svc.task(&format!("task-{i}")).snapshot_count()).sum();
    assert_eq!(svc.snapshot_count(), tcg_snapshots, "shard stores leaked snapshots");
}

/// Lookups against disjoint shards never serialize on a shared lock; this
/// is the "no global lock" smoke check — N threads hammer N different
/// tasks with zero shared state beyond the service object itself.
#[test]
fn disjoint_tasks_scale_without_interference() {
    let svc = Arc::new(ShardedCacheService::new(8));
    for t in 0..8 {
        let task = format!("solo-{t}");
        svc.insert(&task, &traj(&["a".to_string(), "b".to_string()]));
    }
    let handles: Vec<_> = (0..8)
        .map(|t| {
            let svc = Arc::clone(&svc);
            std::thread::spawn(move || {
                let task = format!("solo-{t}");
                let q =
                    vec![call("a".to_string()), call("b".to_string())];
                for _ in 0..2000 {
                    assert!(svc.lookup(&task, &q).is_hit());
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let agg = svc.service_stats();
    assert_eq!(agg.lookups, 8 * 2000);
    assert_eq!(agg.hits, 8 * 2000);
}
