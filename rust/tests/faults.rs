//! Deterministic fault-injection regression suite (the chaos CI job runs
//! this binary in release mode across several `TVCACHE_FAULT_SEED`s).
//!
//! Every test that performs I/O installs a [`fault::FaultScope`] — even a
//! quiet one — because installation holds a process-global lock: fault
//! tests serialize instead of arming each other's seams. The suite proves
//! the cache-as-optimization invariant end to end: each degradation ladder
//! on both the in-process `ShardedCacheService` and the HTTP
//! `RemoteBinding`, breaker trip + recovery, spill resident-only mode, and
//! — the acceptance bar — rollout rewards under a faulty or dead backend
//! identical to a cacheless run.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use tvcache::cache::{
    BackendStats, CacheBackend, CacheStats, Capabilities, CursorStep, Lookup, Miss, NodeId,
    ServiceConfig, SessionBackend, ShardedCacheService, SnapshotCosts, TaskCache, ToolCall,
    ToolResult, TurnBatch, TurnReply,
};
use tvcache::client::{BindingConfig, RemoteBinding};
use tvcache::cluster::{ClusterMap, ClusterRouter, GroupSpec};
use tvcache::sandbox::SandboxSnapshot;
use tvcache::server::{serve, serve_follower, serve_service};
use tvcache::train::{
    run_concurrent, run_concurrent_on, run_workload, run_workload_on, ConcurrentOptions,
    SimOptions,
};
use tvcache::util::fault;
use tvcache::util::http::HttpClient;
use tvcache::workloads::{Workload, WorkloadConfig};

fn bash(cmd: &str) -> ToolCall {
    ToolCall::with_flag("bash", cmd, true)
}

fn traj(cmds: &[&str]) -> Vec<(ToolCall, ToolResult)> {
    cmds.iter().map(|c| (bash(c), ToolResult::new(format!("out-{c}"), 3.0))).collect()
}

fn snap(fill: u8, n: usize) -> SandboxSnapshot {
    SandboxSnapshot { bytes: vec![fill; n], serialize_cost: 0.1, restore_cost: 0.2 }
}

/// A binding config with short deadlines and a breaker that cannot
/// half-open mid-test (recovery is exercised explicitly where wanted).
fn fast_cfg() -> BindingConfig {
    BindingConfig {
        connect_timeout: Duration::from_millis(500),
        read_timeout: Duration::from_secs(2),
        retries: 1,
        backoff_base: Duration::from_millis(1),
        backoff_max: Duration::from_millis(4),
        breaker_threshold: 1000,
        breaker_cooldown: Duration::from_secs(60),
        seed: 0x5EED,
        // Failover tests want every try_failover pass to actually probe.
        probe_cooldown: Duration::ZERO,
        endpoints: Vec::new(),
    }
}

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("tvcache-faults-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

// ───────────────────────── transport seam ladders ──────────────────────────

/// Client-side transport faults (connection drops while sending): every
/// remote op degrades along its documented ladder — lookup to a full miss,
/// insert/record to the `None` failure sentinel — and the injection is
/// visible in the seam counters.
#[test]
fn client_transport_faults_degrade_to_miss_and_none() {
    let (server, _svc) = serve("127.0.0.1:0", 2).unwrap();
    let binding = RemoteBinding::connect_with(server.addr(), fast_cfg());
    // Healthy first: the warm entry the faulty lookups must NOT corrupt.
    let node = binding.insert("ft", &traj(&["make"])).expect("healthy insert");
    assert!(node > 0);
    assert!(binding.lookup("ft", &[bash("make")]).is_hit());

    let sends_before = fault::injected(fault::Seam::ClientSend);
    {
        let mut plan = fault::FaultPlan::quiet(11);
        plan.p_send_drop = 1.0;
        let _scope = fault::install(plan);
        match binding.lookup("ft", &[bash("make")]) {
            Lookup::Miss(m) => {
                assert_eq!(m.matched_calls, 0, "degraded lookup must be a full miss");
                assert!(m.resume.is_none());
            }
            h => panic!("transport fault must degrade to a miss, got {h:?}"),
        }
        assert_eq!(binding.insert("ft", &traj(&["make", "x"])), None);
        assert_eq!(
            binding.cursor_record("ft", 1, &bash("y"), &ToolResult::new("r", 1.0)),
            None
        );
        assert!(fault::injected(fault::Seam::ClientSend) > sends_before);
    }
    // Disarmed: the warm entry still hits — degradation never corrupted it.
    assert!(binding.lookup("ft", &[bash("make")]).is_hit());
}

/// Server-side reply faults that still produce *an* HTTP answer (500s,
/// garbled bodies) degrade every op without tripping the breaker: the
/// server is alive, just unwell, and cutting it off would turn a partial
/// outage into a total one.
#[test]
fn server_reply_faults_degrade_without_tripping_breaker() {
    let (server, _svc) = serve("127.0.0.1:0", 2).unwrap();
    let binding = RemoteBinding::connect_with(server.addr(), fast_cfg());
    binding.insert("sf", &traj(&["make"])).expect("healthy insert");
    {
        let mut plan = fault::FaultPlan::quiet(12);
        plan.p_server_500 = 1.0;
        let _scope = fault::install(plan);
        assert!(!binding.lookup("sf", &[bash("make")]).is_hit());
        assert_eq!(binding.insert("sf", &traj(&["make", "t"])), None);
        // A 5xx is transient, not a protocol downgrade: LEGACY now…
        assert_eq!(binding.capabilities(), Capabilities::LEGACY);
        assert_eq!(binding.breaker_state(), "closed");
    }
    // …and a clean re-probe (plus full protocol) once the server recovers.
    assert_eq!(binding.capabilities(), Capabilities::V2);
    assert!(binding.lookup("sf", &[bash("make")]).is_hit());
}

/// Garbled response frames (bit flips in flight) are indistinguishable
/// from a miss to the caller — never a panic, never a bogus hit.
#[test]
fn garbled_frames_degrade_to_miss_not_panic() {
    let (server, _svc) = serve("127.0.0.1:0", 2).unwrap();
    let binding = RemoteBinding::connect_with(server.addr(), fast_cfg());
    binding.insert("gf", &traj(&["make"])).expect("healthy insert");
    let mut plan = fault::FaultPlan::quiet(13);
    plan.p_recv_garble = 1.0;
    let _scope = fault::install(plan);
    for _ in 0..16 {
        assert!(!binding.lookup("gf", &[bash("make")]).is_hit());
        assert_eq!(binding.insert("gf", &traj(&["make", "q"])), None);
    }
    assert!(fault::injected(fault::Seam::ClientRecv) >= 32);
}

/// The failure sentinel is not the ROOT sentinel: a *transport* failure
/// records as `None`, while a server that definitively answered "that
/// record failed" (unknown cursor) travels the wire as 0.
#[test]
fn record_failure_sentinel_distinct_from_root() {
    let (server, _svc) = serve("127.0.0.1:0", 2).unwrap();
    let binding = RemoteBinding::connect_with(server.addr(), fast_cfg());
    let _scope = fault::install(fault::FaultPlan::quiet(14)); // quiet: serialize only
    // Definitive server-side refusal: unknown cursor → wire 0 → Some(0).
    assert_eq!(
        binding.cursor_record("rs", 9999, &bash("a"), &ToolResult::new("r", 1.0)),
        Some(0),
        "a server-side refusal is an answer, not a transport failure"
    );
    drop(server);
    // Dead server: transport failure → None, never confusable with ROOT.
    assert_eq!(
        binding.cursor_record("rs", 9999, &bash("a"), &ToolResult::new("r", 1.0)),
        None
    );
}

// ─────────────────────── breaker trip and recovery ──────────────────────────

/// The full breaker lifecycle under injected faults: consecutive transport
/// failures trip it open, `degraded()` stays true while the server is
/// still sick (failed half-open probes), and once the faults clear a
/// half-open probe closes it — with every transition counted.
#[test]
fn breaker_trips_under_faults_and_recovers_after() {
    let (server, _svc) = serve("127.0.0.1:0", 2).unwrap();
    let cfg = BindingConfig {
        retries: 0,
        breaker_threshold: 3,
        breaker_cooldown: Duration::from_millis(100),
        ..fast_cfg()
    };
    let binding = RemoteBinding::connect_with(server.addr(), cfg);
    binding.insert("bt", &traj(&["make"])).expect("healthy insert");
    {
        let mut plan = fault::FaultPlan::quiet(15);
        plan.p_send_drop = 1.0;
        plan.p_connect_fail = 1.0;
        let _scope = fault::install(plan);
        for _ in 0..3 {
            assert_eq!(binding.insert("bt", &traj(&["make", "z"])), None);
        }
        assert_eq!(binding.breaker_state(), "open");
        // Cooldown elapses but the server is still faulty: the inline
        // half-open probe fails and the breaker re-opens.
        std::thread::sleep(Duration::from_millis(120));
        assert!(binding.degraded(), "probe against a faulty server must fail");
        assert_eq!(binding.breaker_state(), "open");
    }
    // Faults cleared: the next post-cooldown probe closes the breaker.
    std::thread::sleep(Duration::from_millis(120));
    assert!(!binding.degraded(), "probe against a healthy server must close");
    assert_eq!(binding.breaker_state(), "closed");
    assert!(binding.insert("bt", &traj(&["make", "w"])).is_some());

    let stats = binding.service_stats();
    assert!(stats.breaker_opens >= 2, "open + failed-probe reopen: {}", stats.breaker_opens);
    assert!(stats.breaker_half_opens >= 2, "{}", stats.breaker_half_opens);
    assert!(stats.breaker_closes >= 1, "{}", stats.breaker_closes);
}

// ───────────────────────── spill seam ladders ───────────────────────────────

/// An injected spill-write failure (ENOSPC) trips the store into
/// resident-only mode: no further spill attempts, background eviction
/// degrades from demote-to-disk to destroy, budgets still enforce, and the
/// flag is visible in `service_stats`.
#[test]
fn spill_write_fault_trips_resident_only_mode() {
    let dir = tmpdir("wfault");
    let svc = ShardedCacheService::with_config(
        ServiceConfig {
            shards: 1,
            shard_byte_budget: Some(64),
            spill_dir: Some(dir.clone()),
            background: false,
            fault_cache_bytes: 0,
            ..Default::default()
        },
        Arc::new(TaskCache::with_defaults),
    )
    .unwrap();
    for i in 0..4 {
        let node = svc
            .insert("sw", &traj(&["p", &format!("leaf{i}")]))
            .expect("in-process insert cannot fail");
        assert!(svc.store_snapshot("sw", node, snap(i as u8, 200)) > 0);
    }
    assert!(!svc.spill_degraded());

    let mut plan = fault::FaultPlan::quiet(16);
    plan.p_spill_write_fail = 1.0;
    let _scope = fault::install(plan);
    svc.drain_over_budget();
    assert!(svc.spill_degraded(), "write fault must trip resident-only mode");
    let stats = svc.service_stats();
    assert!(stats.spill_degraded);
    assert!(stats.injected_faults > 0);
    assert_eq!(stats.spilled_snapshots, 0, "nothing may claim to be spilled");
    // Destroy-eviction replaced demotion: the budget still holds.
    assert!(
        svc.resident_bytes() <= 64 + 200,
        "budget unenforced in resident-only mode: {}",
        svc.resident_bytes()
    );
    // The cache stays correct, just snapshot-poorer.
    assert!(svc.lookup("sw", &[bash("p"), bash("leaf0")]).is_hit());
}

/// An injected spill-read failure on fault-in degrades `fetch_snapshot` to
/// `None` — the executor replays instead of restoring — and clears once
/// the faults do.
#[test]
fn spill_read_fault_degrades_fault_in_to_replay() {
    let dir = tmpdir("rfault");
    let svc = ShardedCacheService::with_config(
        ServiceConfig {
            shards: 1,
            shard_byte_budget: Some(10),
            spill_dir: Some(dir.clone()),
            background: false,
            fault_cache_bytes: 0,
            ..Default::default()
        },
        Arc::new(TaskCache::with_defaults),
    )
    .unwrap();
    let node = svc.insert("sr", &traj(&["make"])).expect("in-process insert");
    let id = svc.store_snapshot("sr", node, snap(7, 300));
    assert!(id > 0);
    svc.drain_over_budget();
    assert_eq!(svc.service_stats().spilled_snapshots, 1, "payload must spill");
    {
        let mut plan = fault::FaultPlan::quiet(17);
        plan.p_spill_read_fail = 1.0;
        let _scope = fault::install(plan);
        assert!(
            svc.fetch_snapshot("sr", id).is_none(),
            "read fault must degrade fault-in to a replay"
        );
        assert!(!svc.spill_degraded(), "read faults are per-fetch, not mode-tripping");
    }
    let back = svc.fetch_snapshot("sr", id).expect("healthy fault-in");
    assert_eq!(back.bytes, vec![7u8; 300]);
}

// ────────────────── degradation counters over the wire ──────────────────────

/// Every degradation counter is visible through HTTP: `/stats` carries
/// `spill_degraded` and `injected_faults` (server side) merged with the
/// binding's retry/breaker counters, and the `/capabilities` debug view
/// carries the health bits.
#[test]
fn degradation_counters_visible_over_http() {
    let dir = tmpdir("stats");
    let svc = ShardedCacheService::with_config(
        ServiceConfig {
            shards: 1,
            shard_byte_budget: Some(32),
            spill_dir: Some(dir.clone()),
            background: false,
            fault_cache_bytes: 0,
            ..Default::default()
        },
        Arc::new(TaskCache::with_defaults),
    )
    .unwrap();
    // Trip resident-only mode before serving.
    {
        let mut plan = fault::FaultPlan::quiet(18);
        plan.p_spill_write_fail = 1.0;
        let _scope = fault::install(plan);
        let node = svc.insert("hs", &traj(&["make"])).expect("insert");
        assert!(svc.store_snapshot("hs", node, snap(1, 200)) > 0);
        svc.drain_over_budget();
        assert!(svc.spill_degraded());
    }
    let (server, _svc) = serve_service("127.0.0.1:0", 2, svc).unwrap();
    let binding = RemoteBinding::connect_with(server.addr(), fast_cfg());

    let stats = binding.service_stats();
    assert!(stats.spill_degraded, "spill_degraded must survive the JSON round trip");
    assert!(stats.injected_faults > 0, "injected_faults must be visible over /stats");

    // The JSON capabilities debug view carries the same health bits.
    let mut c = HttpClient::with_deadlines(
        server.addr(),
        Duration::from_millis(500),
        Duration::from_secs(2),
    );
    let (status, body) = c.get("/capabilities").unwrap();
    assert_eq!(status, 200);
    let v = tvcache::util::json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert_eq!(v.get("spill_degraded").and_then(|b| b.as_bool()), Some(true));
    assert!(v.get("injected_faults").and_then(|n| n.as_f64()).unwrap_or(0.0) > 0.0);
}

// ─────────────────── reward invariance under faults ─────────────────────────

/// A deterministic flaky decorator over the in-process service: every
/// third backend op fails along its documented ladder. Used by the DES
/// reward-equality test — no transport, no fault plan, fully portable.
struct FlakyBackend {
    inner: ShardedCacheService,
    ops: AtomicU64,
}

impl FlakyBackend {
    fn new() -> FlakyBackend {
        FlakyBackend { inner: ShardedCacheService::new(4), ops: AtomicU64::new(0) }
    }

    fn flake(&self) -> bool {
        self.ops.fetch_add(1, Ordering::Relaxed) % 3 == 2
    }
}

impl CacheBackend for FlakyBackend {
    fn lookup(&self, task: &str, q: &[ToolCall]) -> Lookup {
        if self.flake() {
            return Lookup::Miss(Miss { matched_node: 0, matched_calls: 0, resume: None });
        }
        self.inner.lookup(task, q)
    }

    fn insert(&self, task: &str, traj: &[(ToolCall, ToolResult)]) -> Option<NodeId> {
        if self.flake() {
            return None;
        }
        self.inner.insert(task, traj)
    }

    fn release(&self, task: &str, node: NodeId) {
        self.inner.release(task, node);
    }

    fn should_snapshot(&self, task: &str, costs: SnapshotCosts) -> bool {
        self.inner.should_snapshot(task, costs)
    }

    fn store_snapshot(&self, task: &str, node: NodeId, snap: SandboxSnapshot) -> u64 {
        if self.flake() {
            return 0;
        }
        self.inner.store_snapshot(task, node, snap)
    }

    fn fetch_snapshot(&self, task: &str, id: u64) -> Option<SandboxSnapshot> {
        if self.flake() {
            return None;
        }
        self.inner.fetch_snapshot(task, id)
    }

    fn set_warm_fork(&self, task: &str, node: NodeId, warm: bool) {
        self.inner.set_warm_fork(task, node, warm);
    }

    fn has_warm_fork(&self, task: &str, node: NodeId) -> bool {
        self.inner.has_warm_fork(task, node)
    }

    fn stats(&self, task: &str) -> CacheStats {
        self.inner.stats(task)
    }

    fn service_stats(&self) -> BackendStats {
        self.inner.service_stats()
    }

    fn persist(&self, dir: &str) -> bool {
        self.inner.persist(dir)
    }

    fn warm_start(&self, dir: &str) -> bool {
        self.inner.warm_start(dir)
    }
}

impl SessionBackend for FlakyBackend {
    fn capabilities(&self) -> Capabilities {
        self.inner.capabilities()
    }

    fn cursor_open(&self, task: &str) -> u64 {
        if self.flake() {
            return 0;
        }
        self.inner.cursor_open(task)
    }

    fn cursor_step(&self, task: &str, cursor: u64, call: &ToolCall) -> CursorStep {
        if self.flake() {
            return CursorStep::Invalid;
        }
        self.inner.cursor_step(task, cursor, call)
    }

    fn cursor_record(
        &self,
        task: &str,
        cursor: u64,
        call: &ToolCall,
        result: &ToolResult,
    ) -> Option<NodeId> {
        if self.flake() {
            return None;
        }
        self.inner.cursor_record(task, cursor, call, result)
    }

    fn cursor_seek(&self, task: &str, cursor: u64, node: NodeId, steps: usize) -> bool {
        if self.flake() {
            return false;
        }
        self.inner.cursor_seek(task, cursor, node, steps)
    }

    fn cursor_close(&self, task: &str, cursor: u64) {
        self.inner.cursor_close(task, cursor);
    }

    fn session_release(&self, task: &str, cursor: u64, node: NodeId) {
        self.inner.session_release(task, cursor, node);
    }

    fn session_turn(&self, task: &str, cursor: u64, batch: &TurnBatch) -> TurnReply {
        if self.flake() {
            return TurnReply::refused(batch);
        }
        self.inner.session_turn(task, cursor, batch)
    }
}

/// The Figure 6 invariant under failure: a DES run whose backend flakes on
/// every third op produces rollout rewards *identical* to a cacheless run
/// — every degradation ladder lands on plain execution, never on wrong
/// output. (In-process and deterministic: no fault scope needed.)
#[test]
fn des_rewards_with_flaky_backend_match_cacheless() {
    let cfg = WorkloadConfig::config_for(Workload::TerminalEasy);
    let mut opts = SimOptions::from_config(&cfg, 3, true);
    opts.epochs = 3;
    let flaky = run_workload_on(&cfg, &opts, Arc::new(FlakyBackend::new()));
    let mut base_opts = opts.clone();
    base_opts.cached = false;
    let baseline = run_workload(&cfg, &base_opts);

    let rf: Vec<f64> = flaky.rollouts.iter().map(|r| r.reward).collect();
    let rb: Vec<f64> = baseline.rollouts.iter().map(|r| r.reward).collect();
    assert_eq!(rf, rb, "a flaky backend changed rewards");
    // The flaky run still cached *something* between its failures.
    assert!(flaky.overall_hit_rate() > 0.0, "flaky cache should still hit sometimes");
}

/// The acceptance bar: a real-thread `run_concurrent` drive against a dead
/// cache server. The breaker trips open within the first rollouts (mid-run
/// by construction), every executor bypasses into degraded direct
/// execution, the run completes with rewards identical to the no-cache
/// run, and no thread ever blocks past the configured deadlines.
#[test]
fn concurrent_rollouts_with_dead_server_match_cacheless() {
    let cfg = WorkloadConfig::config_for(Workload::TerminalEasy);
    let mut opts = ConcurrentOptions::from_config(&cfg, 3);
    opts.epochs = 2;
    opts.threads = 4;
    let mut base_opts = opts.clone();
    base_opts.cached = false;
    let baseline = run_concurrent(&cfg, &base_opts);

    // A port with nothing listening: every dial fails fast.
    let dead = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let a = l.local_addr().unwrap();
        drop(l);
        a
    };
    let binding = Arc::new(RemoteBinding::connect_with(
        dead,
        BindingConfig {
            retries: 1,
            breaker_threshold: 3,
            breaker_cooldown: Duration::from_secs(60),
            ..fast_cfg()
        },
    ));
    let t0 = std::time::Instant::now();
    let report =
        run_concurrent_on(&cfg, &opts, Arc::clone(&binding) as Arc<dyn SessionBackend>);
    let wall = t0.elapsed();

    assert_eq!(report.rollouts_run, 3 * cfg.rollouts * 2, "every rollout must finish");
    assert_eq!(report.rewards, baseline.rewards, "a dead cache changed rewards");
    assert_eq!(report.hits, 0, "nothing can hit against a dead server");
    assert_eq!(binding.breaker_state(), "open", "the breaker must have tripped mid-run");
    let stats = binding.service_stats();
    assert_eq!(stats.breaker_opens, 1);
    assert!(
        wall < Duration::from_secs(30),
        "degraded run must stay deadline-bounded, took {wall:?}"
    );
}

// ─────────────────────────── seeded chaos run ───────────────────────────────

// ──────────────────── replication, failover, fencing ────────────────────────

/// Poll a remote lookup until it hits (the follower tails on a 5 ms tick,
/// so convergence is quick). HTTP on purpose: resume offers over the wire
/// are unpinned server-side, so polling cannot leak pins the way an
/// in-process lookup would.
fn await_remote_hit(probe: &RemoteBinding, task: &str, call: &ToolCall) {
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while !probe.lookup(task, std::slice::from_ref(call)).is_hit() {
        assert!(
            std::time::Instant::now() < deadline,
            "follower never served {task:?} — replication stalled"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// A 2-shard service with an op-log, optionally with the spill tier armed
/// (budget small enough that background eviction actually demotes to disk).
fn replicated_svc(tag: &str, spill: bool) -> ShardedCacheService {
    ShardedCacheService::with_config(
        ServiceConfig {
            shards: 2,
            replicate_window: Some(1 << 16),
            shard_byte_budget: spill.then_some(64 * 1024),
            spill_dir: spill.then(|| tmpdir(tag)),
            background: spill,
            session_sweep_tick: Duration::from_millis(25),
            ..Default::default()
        },
        Arc::new(TaskCache::with_defaults),
    )
    .unwrap()
}

/// The acceptance bar for this PR, run against one backend flavor: primary
/// + warm follower, a concurrent run warms the pair, the primary dies, and
/// the next epoch's rollouts fail over mid-run. Rewards must be
/// bit-identical to the no-fault reference, the failover must be exactly
/// one promote-and-switch, and the post-failover hit count must recover to
/// ≥ 80% of the no-fault run's.
fn kill_primary_scenario(tag: &str, spill: bool) {
    let _scope = fault::install(fault::FaultPlan::quiet(22)); // serialize I/O tests
    let cfg = WorkloadConfig::config_for(Workload::TerminalEasy);
    let mut opts = ConcurrentOptions::from_config(&cfg, 3);
    opts.epochs = 1;
    opts.threads = 4;

    // No-fault reference: warm epoch + measured epoch on one healthy server.
    let (ref_server, _ref_svc) =
        serve_service("127.0.0.1:0", 4, replicated_svc(&format!("{tag}-ref"), spill)).unwrap();
    let ref_binding = Arc::new(RemoteBinding::connect_with(ref_server.addr(), fast_cfg()));
    let warm_ref =
        run_concurrent_on(&cfg, &opts, Arc::clone(&ref_binding) as Arc<dyn SessionBackend>);
    let nofault =
        run_concurrent_on(&cfg, &opts, Arc::clone(&ref_binding) as Arc<dyn SessionBackend>);
    assert!(nofault.hits > 0, "the no-fault reference must run warm");

    // Replicated pair: the follower tails the primary from sequence 0.
    let (p_server, _p_svc) =
        serve_service("127.0.0.1:0", 4, replicated_svc(&format!("{tag}-p"), spill)).unwrap();
    let (f_server, f_svc) = serve_follower(
        "127.0.0.1:0",
        4,
        replicated_svc(&format!("{tag}-f"), spill),
        p_server.addr(),
    )
    .unwrap();
    assert!(f_svc.is_follower());

    // Threshold 6 > the 4 worker threads: stale in-flight dials against the
    // just-dead endpoint can never re-trip the breaker after the failover
    // resets it. Cooldown short so even a surprise re-open self-heals.
    let binding = Arc::new(RemoteBinding::connect_with(
        p_server.addr(),
        BindingConfig {
            retries: 0,
            breaker_threshold: 6,
            breaker_cooldown: Duration::from_millis(200),
            endpoints: vec![f_server.addr()],
            ..fast_cfg()
        },
    ));

    // Warm epoch on the primary (rewards already match the reference).
    let warm = run_concurrent_on(&cfg, &opts, Arc::clone(&binding) as Arc<dyn SessionBackend>);
    assert_eq!(warm.rewards, warm_ref.rewards, "cold-cache epoch changed rewards");
    // The op-log is ordered, so once this sentinel — the newest entry —
    // is served by the follower, everything the warm epoch wrote is too.
    binding.insert(tag, &traj(&["sentinel"])).expect("sentinel insert on the primary");
    let probe = RemoteBinding::connect_with(f_server.addr(), fast_cfg());
    await_remote_hit(&probe, tag, &bash("sentinel"));
    assert_eq!(f_svc.replica_lag_ops(), 0, "caught-up follower must report zero lag");
    assert_eq!(f_svc.skipped_ops(), 0);

    // Kill the primary. The next epoch starts against a dead endpoint:
    // the breaker trips within the first rollouts, the binding promotes
    // the follower mid-run, and sessions re-seed there.
    drop(p_server);
    let t0 = std::time::Instant::now();
    let failed_over =
        run_concurrent_on(&cfg, &opts, Arc::clone(&binding) as Arc<dyn SessionBackend>);

    assert_eq!(
        failed_over.rollouts_run, nofault.rollouts_run,
        "every rollout must finish through the failover"
    );
    assert_eq!(failed_over.rewards, nofault.rewards, "failover changed rollout rewards");
    assert_eq!(binding.failovers(), 1, "exactly one promote-and-switch");
    assert!(!f_svc.is_follower(), "the follower must have been promoted");
    assert!(f_svc.epoch() >= 2, "promotion must bump the fencing epoch");
    assert!(binding.max_epoch_seen() >= 2);
    assert!(
        failed_over.hits as f64 >= 0.8 * nofault.hits as f64,
        "post-failover hit count must recover to ≥ 80% of no-fault: {} vs {}",
        failed_over.hits,
        nofault.hits
    );
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "failed-over run must stay deadline-bounded"
    );
}

#[test]
fn kill_primary_fails_over_memory_backend() {
    kill_primary_scenario("kp-mem", false);
}

#[test]
fn kill_primary_fails_over_spill_backend() {
    kill_primary_scenario("kp-spill", true);
}

/// The split-brain guard, client side: after the world has moved to epoch
/// 2, a still-alive epoch-1 primary (deposed, but never told) answers
/// `/promote` probes with its stale epoch — the binding must refuse to
/// fail over to it and bypass the cache instead.
#[test]
fn revived_stale_primary_is_fenced_not_failed_over_to() {
    let _scope = fault::install(fault::FaultPlan::quiet(23)); // serialize I/O tests
    let (a_server, a_svc) =
        serve_service("127.0.0.1:0", 2, replicated_svc("fence-a", false)).unwrap();
    let (b_server, b_svc) =
        serve_follower("127.0.0.1:0", 2, ShardedCacheService::new(2), a_server.addr()).unwrap();
    let b_addr = b_server.addr();

    // Warm A; B replicates the entry.
    let seeder = RemoteBinding::connect_with(a_server.addr(), fast_cfg());
    seeder.insert("fence", &traj(&["make"])).expect("insert on the primary");
    let b_probe = RemoteBinding::connect_with(b_addr, fast_cfg());
    await_remote_hit(&b_probe, "fence", &bash("make"));

    // B is promoted out-of-band (some other client's failover): epoch 2.
    // A keeps running at epoch 1 — it is the revived stale primary.
    let mut c =
        HttpClient::with_deadlines(b_addr, Duration::from_millis(500), Duration::from_secs(2));
    assert_eq!(c.post("/promote", b"").unwrap().0, 200);
    assert!(!b_svc.is_follower());
    assert_eq!(b_svc.epoch(), 2);
    assert_eq!(a_svc.epoch(), 1, "the deposed primary never learns it was deposed");

    // A client lands on B and learns epoch 2 from its sealed frames.
    let binding = RemoteBinding::connect_with(
        b_addr,
        BindingConfig {
            retries: 0,
            breaker_threshold: 2,
            endpoints: vec![a_server.addr()],
            ..fast_cfg()
        },
    );
    assert!(binding.lookup("fence", &[bash("make")]).is_hit());
    assert_eq!(binding.max_epoch_seen(), 2);

    // B dies. The breaker opens and the failover probe reaches A — whose
    // promote answer still says epoch 1. The fence rejects it: bypassing
    // the cache entirely beats trusting a server with forked state.
    drop(b_server);
    for _ in 0..2 {
        assert!(!binding.lookup("fence", &[bash("make")]).is_hit());
    }
    assert_eq!(binding.breaker_state(), "open");
    assert_eq!(binding.failovers(), 0, "a stale primary must never win a failover");
    assert!(binding.epoch_rejects() >= 1, "the rejection must be counted");
    assert_eq!(binding.active_endpoint(), b_addr, "the binding must not have switched");
    // Degraded, not wrong: ops fast-fail along the usual ladders.
    assert_eq!(binding.insert("fence", &traj(&["make", "x"])), None);
    let stats = binding.service_stats();
    assert_eq!(stats.failovers, 0);
    assert!(stats.epoch_rejects >= 1);
}

/// The chaos CI entry point: every seam armed at once with moderate
/// probabilities, seed taken from `TVCACHE_FAULT_SEED`, a live server with
/// budgets + spill + background workers behind a retrying/breaking
/// binding — and the run must complete with rewards identical to a
/// cacheless run. Failures print the seed for local replay.
#[test]
fn chaos_run_rewards_match_cacheless_for_seed() {
    let seed: u64 = std::env::var("TVCACHE_FAULT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);
    let cfg = WorkloadConfig::config_for(Workload::TerminalEasy);
    let mut opts = ConcurrentOptions::from_config(&cfg, 3);
    opts.epochs = 2;
    opts.threads = 4;
    let mut base_opts = opts.clone();
    base_opts.cached = false;
    let baseline = run_concurrent(&cfg, &base_opts);

    let dir = tmpdir(&format!("chaos-{seed}"));
    let svc = ShardedCacheService::with_config(
        ServiceConfig {
            shards: 2,
            shard_byte_budget: Some(16 * 1024),
            spill_dir: Some(dir.clone()),
            background: true,
            session_sweep_tick: Duration::from_millis(25),
            replicate_window: Some(1 << 16),
            wal_dir: Some(dir.join("wal")),
            ..Default::default()
        },
        Arc::new(TaskCache::with_defaults),
    )
    .unwrap();
    let (server, _svc) = serve_service("127.0.0.1:0", 4, svc).unwrap();
    // A warm follower tails the chaos primary throughout the run — the
    // replication seam is armed below, so its pull loop sees dropped and
    // garbled batches too. If the breaker trips mid-chaos the binding may
    // legitimately promote it and finish the run there.
    let (f_server, f_svc) =
        serve_follower("127.0.0.1:0", 2, ShardedCacheService::new(2), server.addr()).unwrap();
    let binding = Arc::new(RemoteBinding::connect_with(
        server.addr(),
        BindingConfig {
            connect_timeout: Duration::from_millis(500),
            read_timeout: Duration::from_secs(2),
            retries: 2,
            backoff_base: Duration::from_millis(1),
            backoff_max: Duration::from_millis(8),
            breaker_threshold: 4,
            breaker_cooldown: Duration::from_millis(50),
            seed,
            probe_cooldown: Duration::ZERO,
            endpoints: vec![f_server.addr()],
        },
    ));

    let plan = fault::FaultPlan {
        p_connect_fail: 0.05,
        p_send_drop: 0.05,
        p_recv_drop: 0.05,
        p_recv_garble: 0.05,
        p_server_drop: 0.05,
        p_server_partial: 0.03,
        p_server_500: 0.05,
        p_server_garble: 0.05,
        p_server_stall: 0.02,
        server_stall: Duration::from_millis(50),
        p_spill_write_fail: 0.2,
        p_spill_read_fail: 0.2,
        p_worker_stall: 0.2,
        worker_stall: Duration::from_millis(10),
        p_replicate_fail: 0.2,
        p_wal_write_fail: 0.2,
        p_wal_torn_tail: 0.2,
        p_wal_garble: 0.2,
        ..fault::FaultPlan::quiet(seed)
    };
    let t0 = std::time::Instant::now();
    let report = {
        let _scope = fault::install(plan);
        run_concurrent_on(&cfg, &opts, Arc::clone(&binding) as Arc<dyn SessionBackend>)
    };
    let wall = t0.elapsed();

    assert_eq!(
        report.rollouts_run,
        3 * cfg.rollouts * 2,
        "a rollout died under chaos (TVCACHE_FAULT_SEED={seed})"
    );
    assert_eq!(
        report.rewards, baseline.rewards,
        "chaos changed rollout rewards (TVCACHE_FAULT_SEED={seed})"
    );
    assert!(
        wall < Duration::from_secs(60),
        "chaos run not deadline-bounded: {wall:?} (TVCACHE_FAULT_SEED={seed})"
    );
    // The counters tell the story: faults were actually injected.
    assert!(fault::injected_total() > 0, "chaos plan injected nothing (seed {seed})");

    // Replication converges once the chaos clears: a sentinel inserted now
    // (through the binding — which may by now point at the primary or a
    // mid-run-promoted follower) becomes visible on the follower. Dropped
    // and garbled replication batches may only ever delay the tail, never
    // corrupt or freeze it.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while binding.insert("chaos-sentinel", &traj(&["sentinel"])).is_none() {
        assert!(
            std::time::Instant::now() < deadline,
            "binding never recovered after chaos (TVCACHE_FAULT_SEED={seed})"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    let probe = RemoteBinding::connect_with(f_server.addr(), fast_cfg());
    await_remote_hit(&probe, "chaos-sentinel", &bash("sentinel"));
    drop(f_svc);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The cluster flavor of the chaos entry point: two replicated groups
/// behind a [`ClusterRouter`], transport + replication seams armed with
/// moderate probabilities, seed from `TVCACHE_FAULT_SEED`. Mid-chaos
/// breaker trips may legitimately promote a group's follower — the
/// invariant is reward-neutrality and a deadline-bounded run, not a
/// particular topology.
#[test]
fn cluster_chaos_run_rewards_match_cacheless_for_seed() {
    let seed: u64 = std::env::var("TVCACHE_FAULT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);
    let cfg = WorkloadConfig::config_for(Workload::TerminalEasy);
    let mut opts = ConcurrentOptions::from_config(&cfg, 8);
    opts.epochs = 1;
    opts.threads = 4;
    let mut base_opts = opts.clone();
    base_opts.cached = false;
    let baseline = run_concurrent(&cfg, &base_opts);

    // Two primary+follower groups; each follower's pull loop runs under
    // the same armed seams as the client traffic.
    let mut groups = Vec::new();
    let mut primaries = Vec::new();
    let mut followers = Vec::new();
    for i in 0..2 {
        let (p_server, _p_svc) = serve_service(
            "127.0.0.1:0",
            4,
            replicated_svc(&format!("cchaos-{seed}-p{i}"), false),
        )
        .unwrap();
        let (f_server, f_svc) =
            serve_follower("127.0.0.1:0", 2, ShardedCacheService::new(2), p_server.addr()).unwrap();
        groups.push(GroupSpec {
            name: format!("g{i}"),
            primary: p_server.addr(),
            follower: Some(f_server.addr()),
        });
        primaries.push(p_server);
        followers.push((f_server, f_svc));
    }
    let map = ClusterMap::new(seed, 32, groups).unwrap();
    let router = Arc::new(ClusterRouter::connect(
        map,
        BindingConfig {
            retries: 2,
            backoff_max: Duration::from_millis(8),
            breaker_threshold: 4,
            breaker_cooldown: Duration::from_millis(50),
            seed,
            ..fast_cfg()
        },
    ));

    let plan = fault::FaultPlan {
        p_connect_fail: 0.05,
        p_send_drop: 0.05,
        p_recv_drop: 0.05,
        p_recv_garble: 0.05,
        p_server_drop: 0.05,
        p_server_partial: 0.03,
        p_server_500: 0.05,
        p_server_garble: 0.05,
        p_server_stall: 0.02,
        server_stall: Duration::from_millis(50),
        p_replicate_fail: 0.2,
        ..fault::FaultPlan::quiet(seed)
    };
    let t0 = std::time::Instant::now();
    let report = {
        let _scope = fault::install(plan);
        run_concurrent_on(&cfg, &opts, Arc::clone(&router) as Arc<dyn SessionBackend>)
    };
    let wall = t0.elapsed();

    assert_eq!(
        report.rollouts_run, baseline.rollouts_run,
        "a rollout died under cluster chaos (TVCACHE_FAULT_SEED={seed})"
    );
    assert_eq!(
        report.rewards, baseline.rewards,
        "cluster chaos changed rollout rewards (TVCACHE_FAULT_SEED={seed})"
    );
    assert!(
        wall < Duration::from_secs(60),
        "cluster chaos run not deadline-bounded: {wall:?} (TVCACHE_FAULT_SEED={seed})"
    );
    assert!(fault::injected_total() > 0, "cluster chaos plan injected nothing (seed {seed})");

    // Chaos cleared: the router recovers. A sentinel routed through it
    // lands on its group (the original primary, or a mid-run-promoted
    // follower) and that group's replication tail converges.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while router.insert("cluster-chaos-sentinel", &traj(&["sentinel"])).is_none() {
        assert!(
            std::time::Instant::now() < deadline,
            "router never recovered after cluster chaos (TVCACHE_FAULT_SEED={seed})"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    let g = router.group_of("cluster-chaos-sentinel");
    let probe = RemoteBinding::connect_with(followers[g].0.addr(), fast_cfg());
    await_remote_hit(&probe, "cluster-chaos-sentinel", &bash("sentinel"));
    drop(primaries);
}

// ──────────────────── durable op-log crash recovery ─────────────────────────

/// A two-shard service with a small-segment WAL (512 bytes forces rotation
/// under even a short op stream, so recovery always spans segments).
fn wal_svc(dir: &std::path::Path) -> ShardedCacheService {
    ShardedCacheService::with_config(
        ServiceConfig {
            shards: 2,
            wal_dir: Some(dir.to_path_buf()),
            wal_segment_bytes: 512,
            ..Default::default()
        },
        Arc::new(TaskCache::with_defaults),
    )
    .unwrap()
}

/// Kill-and-restart, the acceptance bar for this PR: a WAL-enabled primary
/// dies with a half-written record on disk (file surgery on the newest
/// segment reproduces exactly what a kill mid-`write` leaves behind). The
/// restart recovers bit-identical state up to the last intact record — the
/// rebuilt TCG matches a never-crashed run of the surviving prefix node for
/// node — the torn record is truncated, never replayed as garbage, and new
/// writes resume densely at the recovered sequence.
#[test]
fn killed_wal_primary_recovers_to_the_last_intact_record() {
    let _scope = fault::install(fault::FaultPlan::quiet(31)); // serialize I/O tests
    let dir = tmpdir("wal-kill");
    let snap_id;
    {
        let svc = wal_svc(&dir);
        for i in 0..12 {
            svc.insert("wk", &traj(&["boot", &format!("step{i}")])).expect("insert");
        }
        let node = svc.insert("wk", &traj(&["boot", "snapme"])).expect("insert");
        snap_id = svc.store_snapshot("wk", node, snap(9, 64));
        assert!(snap_id > 0, "snapshot must attach");
        svc.set_warm_fork("wk", node, true);
        // Drop is graceful and syncs everything; the surgery below un-syncs
        // the tail again, which is what a real kill leaves.
    }
    let mut segs: Vec<_> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "seg"))
        .collect();
    segs.sort();
    assert!(segs.len() > 1, "512-byte segments must have rotated");
    let tail = segs.last().unwrap();
    let mut bytes = std::fs::read(tail).unwrap();
    let n = bytes.len();
    assert!(n > 6, "tail segment must hold at least one record");
    for b in &mut bytes[n - 6..] {
        *b ^= 0x5A;
    }
    std::fs::write(tail, &bytes).unwrap();

    // A never-crashed reference over the surviving prefix: every op except
    // the warm-fork mark, whose record the surgery tore.
    let refdir = tmpdir("wal-kill-ref");
    let reference = wal_svc(&refdir);
    for i in 0..12 {
        reference.insert("wk", &traj(&["boot", &format!("step{i}")])).expect("insert");
    }
    let rnode = reference.insert("wk", &traj(&["boot", "snapme"])).expect("insert");
    assert_eq!(reference.store_snapshot("wk", rnode, snap(9, 64)), snap_id);

    let svc = wal_svc(&dir);
    assert_eq!(
        svc.task("wk").viz_json().to_string(),
        reference.task("wk").viz_json().to_string(),
        "recovered TCG differs from the never-crashed run"
    );
    assert_eq!(svc.service_stats().recoveries, 1);
    assert!(!svc.has_warm_fork("wk", rnode), "the torn record must not replay");
    for i in 0..12 {
        assert!(
            svc.lookup("wk", &[bash("boot"), bash(&format!("step{i}"))]).is_hit(),
            "durable insert {i} lost in recovery"
        );
    }
    let back = svc.fetch_snapshot("wk", snap_id).expect("snapshot survives recovery");
    assert_eq!(back.bytes, vec![9u8; 64]);
    let log = svc.oplog().expect("a WAL service keeps an op-log");
    let resumed_at = log.next_seq();
    assert_eq!(resumed_at, 14, "13 inserts + 1 attach survive; the torn mark does not");
    svc.insert("wk", &traj(&["boot", "after"])).expect("insert");
    assert_eq!(log.next_seq(), resumed_at + 1, "writes resume densely after recovery");
    drop(svc);
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&refdir);
}

/// An injected WAL write failure trips the durable tier into sticky
/// degraded mode — availability over durability, same ladder as the spill
/// tier. The service keeps serving every request; only the post-fault ops
/// stop being durable, so a later restart recovers exactly the pre-fault
/// prefix.
#[test]
fn wal_write_fault_degrades_durability_not_the_service() {
    let dir = tmpdir("wal-fault");
    {
        let svc = wal_svc(&dir);
        svc.insert("wf", &traj(&["a"])).expect("insert");
        svc.insert("wf", &traj(&["a", "b"])).expect("insert");
        {
            let mut plan = fault::FaultPlan::quiet(32);
            plan.p_wal_write_fail = 1.0;
            let _scope = fault::install(plan);
            svc.insert("wf", &traj(&["a", "b", "c"])).expect("a degraded WAL still serves");
        }
        assert!(svc.lookup("wf", &[bash("a"), bash("b"), bash("c")]).is_hit());
        assert!(svc.oplog().unwrap().wal().unwrap().degraded());
        let stats = svc.service_stats();
        assert_eq!(stats.oplog_appended, 3, "the op-log itself never degrades");
        assert!(stats.wal_appended_bytes > 0, "pre-fault appends reached disk");
    }
    let svc = wal_svc(&dir);
    assert!(svc.lookup("wf", &[bash("a"), bash("b")]).is_hit());
    assert!(
        !svc.lookup("wf", &[bash("a"), bash("b"), bash("c")]).is_hit(),
        "the post-fault insert was never durable and must not resurrect"
    );
    drop(svc);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Kill-and-restart over HTTP: a WAL-backed server dies, a fresh process
/// (new service, same WAL dir) comes up on a new port and serves the same
/// state to clients — without `/persist` ever having run.
#[test]
fn http_server_restart_serves_recovered_state() {
    let _scope = fault::install(fault::FaultPlan::quiet(33)); // serialize I/O tests
    let dir = tmpdir("wal-http");
    let (server, svc) = serve_service("127.0.0.1:0", 2, wal_svc(&dir)).unwrap();
    let binding = RemoteBinding::connect_with(server.addr(), fast_cfg());
    binding.insert("hr", &traj(&["make", "test"])).expect("insert over http");
    drop(binding);
    drop(server);
    drop(svc);

    let (server, _svc) = serve_service("127.0.0.1:0", 2, wal_svc(&dir)).unwrap();
    let binding = RemoteBinding::connect_with(server.addr(), fast_cfg());
    assert!(binding.lookup("hr", &[bash("make"), bash("test")]).is_hit());
    assert_eq!(binding.service_stats().recoveries, 1, "/stats must carry the recovery count");
    let _ = std::fs::remove_dir_all(&dir);
}
