//! Property tests for the snapshot lifecycle: seeded randomized TCGs
//! asserting the eviction/spill invariants the paper's §3.3–§3.4 machinery
//! must uphold —
//!
//! * pinned (refcount > 0) snapshots are never evicted or spilled,
//! * the count *and* byte budgets hold after every enforce (unless only
//!   pinned snapshots remain),
//! * eviction order is deterministic for a fixed seed,
//! * spill → fault-in round-trips preserve LPM results node-for-node,
//! * a run killed mid-spill (manifest truncated at arbitrary offsets)
//!   recovers to a consistent TCG with no dangling `SnapshotRef`s,
//! * an 8-thread stress run with background eviction enabled never frees
//!   a pinned snapshot out from under its resume-offer holder.

use std::collections::HashSet;
use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;
use std::sync::Arc;

use tvcache::cache::{
    enforce_budget, CacheBackend, CursorStep, EvictionPolicy, Lookup, ServiceConfig,
    SessionBackend, ShardedCacheService, SnapshotRef, TaskCache, Tcg, ToolCall, ToolResult,
    ROOT,
};
use tvcache::sandbox::SandboxSnapshot;
use tvcache::util::rng::Rng;

fn call(s: String) -> ToolCall {
    ToolCall::new("t", s)
}

fn snap_bytes(n: usize) -> SandboxSnapshot {
    SandboxSnapshot { bytes: vec![3u8; n], serialize_cost: 0.1, restore_cost: 0.2 }
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("tvcache-props-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

/// Grow a random TCG; returns all non-root node ids. Node `exec_time`s are
/// randomized so recreation costs differ across nodes.
fn random_tcg(rng: &mut Rng, n: usize) -> (Tcg, Vec<usize>) {
    let mut g = Tcg::new();
    let mut nodes = vec![ROOT];
    for i in 0..n {
        let parent = nodes[rng.below(nodes.len() as u64) as usize];
        let id = g.insert_child(
            parent,
            call(format!("c{i}")),
            ToolResult::new("r", 0.1 + rng.range_f64(0.0, 5.0)),
        );
        nodes.push(id);
    }
    (g, nodes[1..].to_vec())
}

#[test]
fn prop_pinned_never_evicted_and_budgets_hold() {
    for trial in 0..40u64 {
        let mut rng = Rng::new(0xE51C ^ trial.wrapping_mul(0x9E37_79B9));
        let (mut g, ids) = random_tcg(&mut rng, 5 + rng.below(20) as usize);
        let mut pinned: HashSet<u64> = HashSet::new();
        for &id in &ids {
            if rng.chance(0.6) {
                g.set_snapshot(
                    id,
                    SnapshotRef {
                        id: id as u64,
                        bytes: 50 + rng.below(400),
                        restore_cost: 0.2,
                    },
                );
                if rng.chance(0.3) {
                    g.node_mut(id).unwrap().refcount.store(1, Ordering::Release);
                    pinned.insert(id as u64);
                }
            }
        }
        let policy = EvictionPolicy {
            max_snapshots: rng.below(4) as usize,
            max_snapshot_bytes: 100 + rng.below(900),
            ..Default::default()
        };
        let freed = enforce_budget(&mut g, &policy);
        for s in &freed {
            assert!(!pinned.contains(&s.id), "trial {trial}: pinned snapshot {} freed", s.id);
        }
        // Every pinned snapshot is still attached to its (live) node.
        for &sid in &pinned {
            let node = sid as usize;
            let n = g.node(node).unwrap_or_else(|| {
                panic!("trial {trial}: pinned node {node} removed from the TCG")
            });
            assert_eq!(n.snapshot.map(|s| s.id), Some(sid));
        }
        // The budget holds — or everything still snapshotted is pinned.
        let all_remaining_pinned = (1..=ids.len()).all(|id| {
            g.node(id)
                .map(|n| n.snapshot.is_none() || n.is_pinned())
                .unwrap_or(true)
        });
        assert!(
            !policy.over_budget(&g) || all_remaining_pinned,
            "trial {trial}: budget violated with evictable snapshots left \
             (count {}, bytes {})",
            g.snapshot_count(),
            g.snapshot_bytes()
        );
    }
}

#[test]
fn prop_eviction_order_deterministic_for_fixed_seed() {
    for seed in 0..20u64 {
        let build = |seed: u64| {
            let mut rng = Rng::new(seed);
            let (mut g, ids) = random_tcg(&mut rng, 4 + rng.below(16) as usize);
            for &id in &ids {
                if rng.chance(0.7) {
                    g.set_snapshot(
                        id,
                        SnapshotRef {
                            id: id as u64,
                            bytes: 20 + rng.below(200),
                            restore_cost: 0.1,
                        },
                    );
                }
            }
            g
        };
        let policy = EvictionPolicy {
            max_snapshots: 1,
            max_snapshot_bytes: 64,
            ..Default::default()
        };
        let mut a = build(seed);
        let mut b = build(seed);
        let fa: Vec<u64> = enforce_budget(&mut a, &policy).iter().map(|s| s.id).collect();
        let fb: Vec<u64> = enforce_budget(&mut b, &policy).iter().map(|s| s.id).collect();
        assert_eq!(fa, fb, "seed {seed}: eviction order diverged");
    }
}

/// Build a spill-tiered service, populate it with seeded random
/// trajectories + snapshots, and return the (task, query) list.
fn populated_spill_service(
    dir: &Path,
    seed: u64,
) -> (ShardedCacheService, Vec<(String, Vec<ToolCall>)>) {
    let cfg = ServiceConfig {
        shards: 2,
        // Below a single payload's size: the drain must spill everything,
        // so the round-trip property covers every snapshot.
        shard_byte_budget: Some(50),
        spill_dir: Some(dir.to_path_buf()),
        background: false, // drained deterministically by the test
        ..Default::default()
    };
    let svc =
        ShardedCacheService::with_config(cfg, Arc::new(TaskCache::with_defaults)).unwrap();
    let mut rng = Rng::new(seed);
    let mut queries = Vec::new();
    for t in 0..4 {
        let task = format!("task-{t}");
        for _ in 0..4 {
            let n = 1 + rng.below(5) as usize;
            let traj: Vec<(ToolCall, ToolResult)> = (0..n)
                .map(|_| {
                    (
                        call(format!("c{}", rng.below(6))),
                        ToolResult::new("out", 0.5 + rng.range_f64(0.0, 3.0)),
                    )
                })
                .collect();
            let node = svc.insert(&task, &traj).expect("in-process insert cannot fail");
            if node != ROOT && rng.chance(0.8) {
                svc.store_snapshot(&task, node, snap_bytes(100));
            }
            let q: Vec<ToolCall> = traj.iter().map(|(c, _)| c.clone()).collect();
            let mut probe = q.clone();
            probe.push(call("divergent-probe".to_string()));
            queries.push((task.clone(), q));
            queries.push((task.clone(), probe));
        }
    }
    (svc, queries)
}

/// Look everything up, releasing resume pins immediately so the lookups
/// themselves never block eviction.
fn lookup_all(
    svc: &ShardedCacheService,
    queries: &[(String, Vec<ToolCall>)],
) -> Vec<Lookup> {
    queries
        .iter()
        .map(|(task, q)| {
            let out = svc.lookup(task, q);
            if let Lookup::Miss(m) = &out {
                if let Some((node, _, _)) = m.resume {
                    svc.release(task, node);
                }
            }
            out
        })
        .collect()
}

#[test]
fn prop_spill_fault_roundtrip_preserves_lpm_node_for_node() {
    let dir = tmpdir("lpm-roundtrip");
    let (svc, queries) = populated_spill_service(&dir, 0x5F17 ^ 0xA11CE);
    let before = lookup_all(&svc, &queries);
    svc.drain_over_budget();
    assert!(svc.spilled_count() > 0, "the budget must actually force spills");
    let after = lookup_all(&svc, &queries);
    // Hits return the same node + result; misses offer the same resume
    // (node, snapshot id, replay depth) — spilling must be invisible to LPM.
    assert_eq!(before, after, "spill changed LPM results");
    // And every offered snapshot faults in from disk.
    for l in &after {
        if let Lookup::Miss(m) = l {
            if let Some((_, sref, _)) = m.resume {
                for (task, _) in &queries {
                    if svc.task(task).snapshotted_nodes().iter().any(|(_, s)| s.id == sref.id)
                    {
                        assert!(
                            svc.fetch_snapshot(task, sref.id).is_some(),
                            "snapshot {} unfetchable after spill",
                            sref.id
                        );
                    }
                }
            }
        }
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

fn copy_dir(src: &Path, dst: &Path) {
    std::fs::create_dir_all(dst).unwrap();
    for entry in std::fs::read_dir(src).unwrap() {
        let entry = entry.unwrap();
        std::fs::copy(entry.path(), dst.join(entry.file_name())).unwrap();
    }
}

#[test]
fn crash_mid_spill_recovers_to_consistent_tcg() {
    let dir = tmpdir("crash");
    let (svc, queries) = populated_spill_service(&dir, 0xDEAD_BEEF);
    svc.drain_over_budget();
    svc.persist_to_dir(&dir).unwrap();
    drop(svc);

    let manifest = dir.join("manifest.jsonl");
    let full = std::fs::read(&manifest).unwrap();
    // "Kill the run mid-spill": truncate the manifest at arbitrary offsets
    // (including mid-record) and reload.
    let cuts: Vec<usize> = (0..=8)
        .map(|i| i * full.len() / 8)
        .chain([1, full.len().saturating_sub(1)])
        .collect();
    for cut in cuts {
        let work = tmpdir("crash-work");
        copy_dir(&dir, &work);
        std::fs::write(work.join("manifest.jsonl"), &full[..cut]).unwrap();

        let fresh = ShardedCacheService::new(2);
        fresh.warm_start_from_dir(&work).unwrap();
        // No dangling refs: every snapshot a TCG still references resolves.
        for task in fresh.task_ids() {
            for (_, sref) in fresh.task(&task).snapshotted_nodes() {
                assert!(
                    fresh.fetch_snapshot(&task, sref.id).is_some(),
                    "cut {cut}: dangling SnapshotRef {} in {task}",
                    sref.id
                );
            }
        }
        // Trajectory structure survived in full: cached prefixes still hit.
        for (task, q) in &queries {
            if q.last().map(|c| c.args.as_str()) == Some("divergent-probe") {
                continue;
            }
            assert!(
                fresh.lookup(task, q).is_hit(),
                "cut {cut}: recovered TCG lost a recorded trajectory"
            );
        }
        std::fs::remove_dir_all(&work).unwrap();
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

/// A cursor whose node is *spilled* keeps working — spilling demotes the
/// payload, never the TCG node — and every subsequent step must agree with
/// the full-prefix lookup node-for-node (no stale hit, no lost resume).
#[test]
fn cursor_survives_spill_and_matches_full_lookup() {
    let dir = tmpdir("cursor-spill");
    let cfg = ServiceConfig {
        shards: 2,
        shard_byte_budget: Some(50), // below one payload: spill everything
        spill_dir: Some(dir.clone()),
        background: false,
        ..Default::default()
    };
    let svc =
        ShardedCacheService::with_config(cfg, Arc::new(TaskCache::with_defaults)).unwrap();
    let calls: Vec<ToolCall> = (0..4).map(|i| call(format!("c{i}"))).collect();
    let traj: Vec<(ToolCall, ToolResult)> = calls
        .iter()
        .map(|c| (c.clone(), ToolResult::new(format!("r-{}", c.args), 2.0)))
        .collect();
    let node = svc.insert("t", &traj).expect("in-process insert cannot fail");
    assert!(svc.store_snapshot("t", node, snap_bytes(100)) > 0);

    let cur = svc.cursor_open("t");
    for c in &calls[..2] {
        assert!(svc.cursor_step("t", cur, c).is_hit(), "warm prefix must hit");
    }
    svc.drain_over_budget();
    assert!(svc.spilled_count() > 0, "the budget must actually force the spill");

    // The remaining steps still hit, identical to the full-prefix walk.
    for (i, c) in calls[2..].iter().enumerate() {
        let full = svc.lookup("t", &calls[..2 + i + 1]);
        match (svc.cursor_step("t", cur, c), full) {
            (CursorStep::Hit { node: a, result: ra }, Lookup::Hit { node: b, result: rb }) => {
                assert_eq!(a, b, "spill changed the cursor's position");
                assert_eq!(ra, rb, "spill changed a cursor-served result");
            }
            (s, f) => panic!("outcomes diverged after spill: {s:?} vs {f:?}"),
        }
    }
    // A divergent step still offers the (spilled) snapshot, and it faults in.
    match svc.cursor_step("t", cur, &call("divergent".into())) {
        CursorStep::Miss(m) => {
            let (rnode, sref, _) = m.resume.expect("spilled node must still offer resume");
            assert_eq!(rnode, node);
            assert!(svc.fetch_snapshot("t", sref.id).is_some(), "fault-in failed");
            svc.release("t", rnode);
        }
        s => panic!("expected miss, got {s:?}"),
    }
    svc.cursor_close("t", cur);
    std::fs::remove_dir_all(&dir).unwrap();
}

/// A cursor whose node is destroyed (subtree removal — what destroy-mode
/// background eviction and the count budget's leaf eviction do) must report
/// `Invalid` and never a stale hit; a full-prefix fallback then gives the
/// ground truth and a re-seek re-arms the cursor.
#[test]
fn cursor_invalidated_by_node_removal_never_serves_stale() {
    let svc = ShardedCacheService::new(2);
    let calls: Vec<ToolCall> = (0..3).map(|i| call(format!("c{i}"))).collect();
    let traj: Vec<(ToolCall, ToolResult)> = calls
        .iter()
        .map(|c| (c.clone(), ToolResult::new(format!("r-{}", c.args), 2.0)))
        .collect();
    svc.insert("t", &traj);
    let cur = svc.cursor_open("t");
    for c in &calls {
        assert!(svc.cursor_step("t", cur, c).is_hit());
    }
    // Remove the subtree holding the cursor (depth-2 node: kills 2 and 3).
    let mid = match svc.lookup("t", &calls[..2]) {
        Lookup::Hit { node, .. } => node,
        m => panic!("{m:?}"),
    };
    assert!(svc.evict_node("t", mid));
    // Every further step — hit-shaped or not — must be Invalid.
    assert_eq!(svc.cursor_step("t", cur, &call("c2".into())), CursorStep::Invalid);
    assert_eq!(svc.cursor_step("t", cur, &call("anything".into())), CursorStep::Invalid);
    // The fallback full-prefix lookup reports the truth: only c0 remains.
    match svc.lookup("t", &calls) {
        Lookup::Miss(m) => assert_eq!(m.matched_calls, 1),
        h => panic!("evicted chain cannot hit: {h:?}"),
    }
    // Re-seek onto the surviving ancestor re-arms the cursor.
    let root_child = match svc.lookup("t", &calls[..1]) {
        Lookup::Hit { node, .. } => node,
        m => panic!("{m:?}"),
    };
    assert!(svc.cursor_seek("t", cur, root_child, 1));
    assert!(matches!(svc.cursor_step("t", cur, &call("c1".into())), CursorStep::Miss(_)));
    svc.cursor_close("t", cur);
}

/// 8 threads of cursor-driven rollouts against background eviction plus
/// hostile subtree removals: hits must always return the recorded value
/// (never stale garbage), invalidations must degrade cleanly, and no pin
/// or cursor may leak.
#[test]
fn stress_cursors_under_background_eviction_and_removal() {
    let cfg = ServiceConfig {
        shards: 4,
        shard_byte_budget: Some(400),
        background: true,
        ..Default::default()
    };
    let svc = Arc::new(
        ShardedCacheService::with_config(cfg, Arc::new(TaskCache::with_defaults)).unwrap(),
    );
    let handles: Vec<_> = (0..8)
        .map(|t| {
            let svc = Arc::clone(&svc);
            std::thread::spawn(move || {
                for i in 0..300usize {
                    let task = format!("task-{}", (t + i) % 8);
                    let depth = 1 + (i % 3);
                    let calls: Vec<ToolCall> =
                        (0..depth).map(|d| call(format!("step-{d}-{}", i % 5))).collect();
                    let traj: Vec<(ToolCall, ToolResult)> = calls
                        .iter()
                        .map(|c| (c.clone(), ToolResult::new("r", 2.0)))
                        .collect();
                    let node =
                        svc.insert(&task, &traj).expect("in-process insert cannot fail");
                    if i % 2 == 0 {
                        svc.store_snapshot(&task, node, snap_bytes(100));
                    }
                    // Cursor walk of the same trajectory under churn.
                    let cur = svc.cursor_open(&task);
                    for c in &calls {
                        match svc.cursor_step(&task, cur, c) {
                            CursorStep::Hit { result, .. } => {
                                assert_eq!(result.output, "r", "stale hit under churn");
                            }
                            CursorStep::Miss(m) => {
                                if let Some((rnode, _, _)) = m.resume {
                                    svc.release(&task, rnode);
                                }
                                let recorded = svc
                                    .cursor_record(&task, cur, c, &ToolResult::new("r", 2.0))
                                    .unwrap_or(0);
                                if recorded == 0 {
                                    break; // invalidated mid-walk: a real
                                           // executor would fall back
                                }
                            }
                            CursorStep::Invalid => break,
                        }
                    }
                    svc.cursor_close(&task, cur);
                    // Hostile churn: remove arbitrary subtrees.
                    if i % 7 == 0 {
                        let _ = svc.evict_node(&task, 1 + (i % 5));
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("cursor stress thread panicked");
    }
    svc.quiesce();
    assert_eq!(svc.session_count(), 0, "sessions leaked");
    for task in svc.task_ids() {
        assert_eq!(svc.task(&task).pinned_node_count(), 0, "{task} leaked a pin");
        for (_, sref) in svc.task(&task).snapshotted_nodes() {
            assert!(
                svc.fetch_snapshot(&task, sref.id).is_some(),
                "TCG references snapshot {} the store no longer has",
                sref.id
            );
        }
    }
}

/// Shared-payload refcounting must never violate pin semantics: a pinned
/// snapshot's *payload* stays resident even when an unpinned snapshot in
/// another task shares the same content key — spilling the unpinned handle
/// would demote the shared bytes out from under the pinned holder.
#[test]
fn prop_shared_payload_respects_pins_across_tasks() {
    for trial in 0..12u64 {
        let dir = tmpdir(&format!("shared-pin-{trial}"));
        let cfg = ServiceConfig {
            shards: 2,
            // Far below a single payload: maximum spill pressure, so only
            // the pin guard can keep anything resident.
            shard_byte_budget: Some(10),
            spill_dir: Some(dir.clone()),
            background: false,
            ..Default::default()
        };
        let svc = ShardedCacheService::with_config(cfg, Arc::new(TaskCache::with_defaults))
            .unwrap();
        let mut rng = Rng::new(0x5EED ^ trial.wrapping_mul(0x9E37_79B9));
        // A handful of distinct contents, each stored under several tasks —
        // so pinned and unpinned handles of one content key coexist across
        // task (and shard) boundaries.
        let n_contents = 2 + rng.below(3);
        let mut pins: Vec<(String, usize, u64)> = Vec::new();
        for t in 0..6u64 {
            let task = format!("task-{t}");
            let content = rng.below(n_contents) as u8;
            let traj: Vec<(ToolCall, ToolResult)> = (0..2)
                .map(|d| (call(format!("s{content}-{d}")), ToolResult::new("r", 2.0)))
                .collect();
            let node = svc.insert(&task, &traj).expect("in-process insert cannot fail");
            let snap = SandboxSnapshot {
                bytes: vec![content; 100],
                serialize_cost: 0.1,
                restore_cost: 0.2,
            };
            assert!(svc.store_snapshot(&task, node, snap) > 0);
            if rng.chance(0.35) {
                // Pin through a real resume offer, like a rollout would.
                let mut q: Vec<ToolCall> = traj.iter().map(|(c, _)| c.clone()).collect();
                q.push(call("divergent".to_string()));
                if let Lookup::Miss(m) = svc.lookup(&task, &q) {
                    if let Some((rnode, sref, _)) = m.resume {
                        pins.push((task.clone(), rnode, sref.id));
                    }
                }
            }
        }
        svc.drain_over_budget();
        for (task, _, sid) in &pins {
            assert!(
                svc.snapshot_is_resident(task, *sid),
                "trial {trial}: pinned snapshot {sid} of {task} left the \
                 resident tier (its shared payload was demoted)"
            );
        }
        // Released, the same payloads are fair game: the drain finishes
        // the job and the budget finally holds.
        for (task, rnode, _) in &pins {
            svc.release(task, *rnode);
        }
        svc.drain_over_budget();
        assert_eq!(
            svc.resident_bytes(),
            0,
            "trial {trial}: released payloads must all spill under a 10-byte budget"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }
}

/// 8 threads of insert / evict / fault churn over a *shared* content pool
/// (6 distinct payloads across 8 tasks, so nearly every insert dedups)
/// against background spill workers and a deliberately tiny fault cache:
/// pinned fetches must always succeed wherever the payload currently
/// lives, and the TCGs, the handle stores, and the payload tier must agree
/// when the dust settles.
#[test]
fn stress_shared_payload_insert_evict_fault_churn() {
    let dir = tmpdir("dedup-churn");
    let cfg = ServiceConfig {
        shards: 4,
        shard_byte_budget: Some(300),
        spill_dir: Some(dir.clone()),
        background: true,
        fault_cache_bytes: 256, // a couple of payloads: forces evictions
        ..Default::default()
    };
    let svc = Arc::new(
        ShardedCacheService::with_config(cfg, Arc::new(TaskCache::with_defaults)).unwrap(),
    );
    let handles: Vec<_> = (0..8)
        .map(|t| {
            let svc = Arc::clone(&svc);
            std::thread::spawn(move || {
                for i in 0..250usize {
                    let task = format!("task-{}", (t + i) % 8);
                    let content = ((t * 31 + i) % 6) as u8;
                    let traj: Vec<(ToolCall, ToolResult)> = (0..1 + i % 3)
                        .map(|d| {
                            (call(format!("s{content}-{d}")), ToolResult::new("r", 2.0))
                        })
                        .collect();
                    let node =
                        svc.insert(&task, &traj).expect("in-process insert cannot fail");
                    let snap = SandboxSnapshot {
                        bytes: vec![content; 100],
                        serialize_cost: 0.1,
                        restore_cost: 0.2,
                    };
                    svc.store_snapshot(&task, node, snap);
                    // Fault path: a divergent lookup offers a (possibly
                    // spilled) snapshot — while pinned it must fetch,
                    // whether the bytes come from memory, the fault cache,
                    // or disk.
                    let mut q: Vec<ToolCall> =
                        traj.iter().map(|(c, _)| c.clone()).collect();
                    q.push(call(format!("d-{t}-{i}")));
                    if let Lookup::Miss(m) = svc.lookup(&task, &q) {
                        if let Some((rnode, sref, _)) = m.resume {
                            assert!(
                                svc.fetch_snapshot(&task, sref.id).is_some(),
                                "pinned snapshot {} unfetchable under churn",
                                sref.id
                            );
                            svc.release(&task, rnode);
                        }
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("churn thread panicked");
    }
    svc.quiesce();
    let stats = svc.service_stats();
    assert!(stats.dedup_hits > 0, "a shared content pool must dedup");
    let mut tcg_snapshots = 0usize;
    for task in svc.task_ids() {
        assert_eq!(svc.task(&task).pinned_node_count(), 0, "{task} leaked a pin");
        for (_, sref) in svc.task(&task).snapshotted_nodes() {
            tcg_snapshots += 1;
            assert!(
                svc.fetch_snapshot(&task, sref.id).is_some(),
                "TCG references snapshot {} the store no longer has",
                sref.id
            );
        }
    }
    assert_eq!(svc.snapshot_count(), tcg_snapshots, "store/TCG disagreement");
    drop(svc);
    let _ = std::fs::remove_dir_all(&dir);
}

/// 8 threads × mixed ops against a *destroy-mode* (no spill dir) background
/// eviction service with a tiny byte budget: a resume offer's pin must keep
/// its snapshot fetchable until released, no matter how hard the worker
/// churns. (Acceptance: "no pinned snapshot ever freed".)
#[test]
fn stress_background_eviction_never_frees_pinned() {
    let cfg = ServiceConfig {
        shards: 4,
        shard_byte_budget: Some(400), // ~4 × 100-byte snapshots per shard
        background: true,
        ..Default::default()
    };
    let svc = Arc::new(
        ShardedCacheService::with_config(cfg, Arc::new(TaskCache::with_defaults)).unwrap(),
    );
    let handles: Vec<_> = (0..8)
        .map(|t| {
            let svc = Arc::clone(&svc);
            std::thread::spawn(move || {
                for i in 0..400usize {
                    let task = format!("task-{}", (t + i) % 8);
                    let depth = 1 + (i % 3);
                    let calls: Vec<String> =
                        (0..depth).map(|d| format!("step-{d}-{}", i % 5)).collect();
                    let traj: Vec<(ToolCall, ToolResult)> = calls
                        .iter()
                        .map(|c| (call(c.clone()), ToolResult::new("r", 2.0)))
                        .collect();
                    let node =
                        svc.insert(&task, &traj).expect("in-process insert cannot fail");
                    if i % 2 == 0 {
                        svc.store_snapshot(&task, node, snap_bytes(100));
                    }
                    // Divergent lookup: may return a resume offer, which
                    // pins the node. While pinned, the snapshot must stay
                    // fetchable despite the background destroyer.
                    let mut q: Vec<ToolCall> =
                        calls.iter().map(|c| call(c.clone())).collect();
                    q.push(call(format!("divergent-{t}-{i}")));
                    if let Lookup::Miss(m) = svc.lookup(&task, &q) {
                        if let Some((rnode, sref, _)) = m.resume {
                            for _ in 0..3 {
                                assert!(
                                    svc.fetch_snapshot(&task, sref.id).is_some(),
                                    "pinned snapshot {} was freed", sref.id
                                );
                                std::thread::yield_now();
                            }
                            svc.release(&task, rnode);
                        }
                    }
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("stress thread panicked");
    }
    // Wait for the workers to go idle: only then are TCGs and shard stores
    // guaranteed mutually consistent for white-box inspection.
    svc.quiesce();
    // All pins released; the TCGs and shard stores agree on what is left.
    let mut tcg_snapshots = 0usize;
    for task in svc.task_ids() {
        assert_eq!(svc.task(&task).pinned_node_count(), 0, "{task} leaked a pin");
        for (_, sref) in svc.task(&task).snapshotted_nodes() {
            tcg_snapshots += 1;
            assert!(
                svc.fetch_snapshot(&task, sref.id).is_some(),
                "TCG references snapshot {} the store no longer has", sref.id
            );
        }
    }
    assert_eq!(svc.snapshot_count(), tcg_snapshots, "store/TCG disagreement");
}
