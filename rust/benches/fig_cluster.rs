//! Cluster figure (extension): consistent-hash routing scales the cache
//! out without taxing the hot path or the hit rate.
//!
//! The PR 10 cluster layer puts a seeded consistent-hash ring and one
//! `RemoteBinding` per replication group between the executors and the
//! fleet. This bench pins down what that layer costs and what it keeps:
//!
//! 1. **Routing overhead**: warm depth-32 lookups through a 3-group
//!    [`ClusterRouter`] vs a direct [`RemoteBinding`] to the same node.
//!    The router adds one FNV-1a hash + ring binary-search per call;
//!    asserted ≤ 10% over direct (best-of-3 per-op means).
//! 2. **Aggregate hit rate**: the same concurrent DES workload run once
//!    against a single node and once split across 3 groups. Placement
//!    must not cost hits — asserted within 5 points.
//! 3. **Kill-primary retention**: one group's primary dies between
//!    epochs; the victim group fails over to its own follower. Asserted:
//!    rewards bit-identical, exactly one failover (zero on the other
//!    groups), and ≥ 80% of the no-fault hit count retained.
//!
//! Results are appended as one JSON line to `BENCH_10.json` (override
//! with `TVCACHE_BENCH_OUT`).

use std::io::Write as _;
use std::sync::Arc;
use std::time::{Duration, Instant};

use tvcache::bench::print_table;
use tvcache::cache::{
    CacheBackend, ServiceConfig, SessionBackend, ShardedCacheService, TaskCache, ToolCall,
    ToolResult,
};
use tvcache::client::{BindingConfig, RemoteBinding};
use tvcache::cluster::{ClusterMap, ClusterRouter, GroupSpec};
use tvcache::metrics::CsvWriter;
use tvcache::server::{serve_follower, serve_service};
use tvcache::train::{run_concurrent_on, ConcurrentOptions};
use tvcache::util::http::Server;
use tvcache::workloads::{Workload, WorkloadConfig};

fn replicated_svc() -> ShardedCacheService {
    ShardedCacheService::with_config(
        ServiceConfig { shards: 2, replicate_window: Some(1 << 16), ..Default::default() },
        Arc::new(TaskCache::with_defaults),
    )
    .unwrap()
}

fn binding_cfg() -> BindingConfig {
    BindingConfig {
        connect_timeout: Duration::from_millis(500),
        read_timeout: Duration::from_secs(2),
        retries: 0,
        backoff_base: Duration::from_millis(1),
        backoff_max: Duration::from_millis(4),
        // Above the thread count, so stale in-flight dials against a dead
        // endpoint cannot re-trip the breaker post-failover.
        breaker_threshold: 6,
        breaker_cooldown: Duration::from_millis(200),
        seed: 0xAEED,
        probe_cooldown: Duration::ZERO,
        endpoints: Vec::new(),
    }
}

/// Spawn `n` primary-only groups and the map over them.
fn plain_cluster(n: usize, seed: u64) -> (Vec<Server>, ClusterMap) {
    let mut servers = Vec::with_capacity(n);
    let mut groups = Vec::with_capacity(n);
    for i in 0..n {
        let (server, _svc) = serve_service("127.0.0.1:0", 4, replicated_svc()).unwrap();
        groups.push(GroupSpec { name: format!("g{i}"), primary: server.addr(), follower: None });
        servers.push(server);
    }
    let map = ClusterMap::new(seed, 32, groups).unwrap();
    (servers, map)
}

/// Best-of-`reps` mean seconds per lookup.
fn best_per_op(reps: usize, n: usize, mut op: impl FnMut()) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t = Instant::now();
        for _ in 0..n {
            op();
        }
        best = best.min(t.elapsed().as_secs_f64() / n as f64);
    }
    best
}

fn main() {
    let smoke = std::env::var("TVCACHE_BENCH_SMOKE").is_ok();
    let n_ops: usize = if smoke { 300 } else { 2000 };
    let n_tasks: usize = if smoke { 6 } else { 16 };

    // ── 1. Routing overhead: depth-32 warm lookups, router vs direct ────
    let (oh_servers, oh_map) = plain_cluster(3, 0xC1A5);
    let router = ClusterRouter::connect(oh_map.clone(), binding_cfg());
    let task = "overhead-task";
    let traj: Vec<(ToolCall, ToolResult)> = (0..32)
        .map(|i| {
            (
                ToolCall::with_flag("bash", format!("step-{i}"), true),
                ToolResult::new(format!("out-{i}"), 1.0),
            )
        })
        .collect();
    let calls: Vec<ToolCall> = traj.iter().map(|(c, _)| c.clone()).collect();
    router.insert(task, &traj).expect("warm insert through the router");
    // The direct binding dials the very node the ring placed the task on:
    // the two measured paths differ only by the routing layer.
    let direct = RemoteBinding::connect_with(
        oh_map.groups()[oh_map.group_for(task)].primary,
        binding_cfg(),
    );
    assert!(direct.lookup(task, &calls).is_hit(), "warm entry must hit directly");
    assert!(router.lookup(task, &calls).is_hit(), "warm entry must hit via the router");
    // Alternate reps so drift (allocator warm-up, CPU clocks) hits both.
    let mut direct_best = f64::INFINITY;
    let mut router_best = f64::INFINITY;
    for _ in 0..3 {
        direct_best = direct_best.min(best_per_op(1, n_ops, || {
            assert!(direct.lookup(task, &calls).is_hit());
        }));
        router_best = router_best.min(best_per_op(1, n_ops, || {
            assert!(router.lookup(task, &calls).is_hit());
        }));
    }
    let overhead = router_best / direct_best;
    drop(router);
    drop(direct);
    drop(oh_servers);

    // ── 2. Aggregate hit rate: 3 groups vs one node, same DES workload ──
    let cfg = WorkloadConfig::config_for(Workload::TerminalEasy);
    let mut opts = ConcurrentOptions::from_config(&cfg, n_tasks);
    opts.epochs = 2;
    opts.threads = 4;

    let (single_server, _single_svc) = serve_service("127.0.0.1:0", 4, replicated_svc()).unwrap();
    let single = Arc::new(RemoteBinding::connect_with(single_server.addr(), binding_cfg()));
    let single_run = run_concurrent_on(&cfg, &opts, Arc::clone(&single) as Arc<dyn SessionBackend>);
    drop(single_server);

    let (hr_servers, hr_map) = plain_cluster(3, 0xC1A5);
    let cluster = Arc::new(ClusterRouter::connect(hr_map, binding_cfg()));
    let cluster_run =
        run_concurrent_on(&cfg, &opts, Arc::clone(&cluster) as Arc<dyn SessionBackend>);
    drop(hr_servers);

    assert_eq!(cluster_run.rewards, single_run.rewards, "placement changed rewards");
    let single_hr = single_run.overall_hit_rate();
    let cluster_hr = cluster_run.overall_hit_rate();
    let hr_delta = (single_hr - cluster_hr).abs();

    // ── 3. Kill one primary: the victim group fails over alone ──────────
    let mut primaries = Vec::new();
    let mut followers = Vec::new();
    let mut groups = Vec::new();
    for i in 0..3 {
        let (p_server, _p_svc) = serve_service("127.0.0.1:0", 4, replicated_svc()).unwrap();
        let (f_server, f_svc) =
            serve_follower("127.0.0.1:0", 4, replicated_svc(), p_server.addr()).unwrap();
        groups.push(GroupSpec {
            name: format!("g{i}"),
            primary: p_server.addr(),
            follower: Some(f_server.addr()),
        });
        primaries.push(Some(p_server));
        followers.push((f_server, f_svc));
    }
    let map = ClusterMap::new(0xC1A5, 32, groups).unwrap();
    let mut opts = ConcurrentOptions::from_config(&cfg, n_tasks);
    opts.epochs = 1;
    opts.threads = 4;
    // Kill the busiest group, so the failover happens under real traffic.
    let mut placed = vec![0usize; 3];
    for t in 0..opts.n_tasks {
        placed[map.group_for(&format!("task-{t}"))] += 1;
    }
    let victim = (0..3).max_by_key(|&g| placed[g]).unwrap();

    let router = Arc::new(ClusterRouter::connect(map.clone(), binding_cfg()));
    let _warm = run_concurrent_on(&cfg, &opts, Arc::clone(&router) as Arc<dyn SessionBackend>);
    let nofault = run_concurrent_on(&cfg, &opts, Arc::clone(&router) as Arc<dyn SessionBackend>);
    assert!(nofault.hits > 0, "no-fault cluster epoch must run warm");

    // Sentinel: the newest op on the victim group — once its follower
    // serves it, everything the warm epochs wrote there is replicated.
    let sentinel =
        (0..).map(|k| format!("sentinel-{k}")).find(|t| map.group_for(t) == victim).unwrap();
    let probe_call = ToolCall::with_flag("bash", "sentinel", true);
    router
        .insert(&sentinel, &[(probe_call.clone(), ToolResult::new("ok", 1.0))])
        .expect("sentinel insert on the victim group");
    let probe = RemoteBinding::connect_with(followers[victim].0.addr(), binding_cfg());
    let deadline = Instant::now() + Duration::from_secs(10);
    while !probe.lookup(&sentinel, std::slice::from_ref(&probe_call)).is_hit() {
        assert!(Instant::now() < deadline, "victim follower never caught up");
        std::thread::sleep(Duration::from_millis(2));
    }

    primaries[victim] = None;
    let t_run = Instant::now();
    let failed_over =
        run_concurrent_on(&cfg, &opts, Arc::clone(&router) as Arc<dyn SessionBackend>);
    let failover_run_ms = t_run.elapsed().as_secs_f64() * 1e3;

    assert_eq!(failed_over.rewards, nofault.rewards, "cluster failover changed rewards");
    for g in 0..3 {
        assert_eq!(
            router.binding(g).failovers(),
            u64::from(g == victim),
            "failover must stay on the victim group"
        );
    }
    assert!(!followers[victim].1.is_follower(), "victim follower must be promoted");
    let retention = failed_over.hits as f64 / nofault.hits as f64;

    // ── Report ──────────────────────────────────────────────────────────
    let rows = vec![
        vec!["direct lookup (µs/op)".into(), format!("{:.1}", direct_best * 1e6)],
        vec!["routed lookup (µs/op)".into(), format!("{:.1}", router_best * 1e6)],
        vec!["routing overhead".into(), format!("{overhead:.3}x")],
        vec!["single-node hit rate".into(), format!("{:.3}", single_hr)],
        vec!["3-group hit rate".into(), format!("{:.3}", cluster_hr)],
        vec!["hit-rate delta".into(), format!("{hr_delta:.3}")],
        vec!["no-fault hits".into(), format!("{}", nofault.hits)],
        vec!["post-failover hits".into(), format!("{}", failed_over.hits)],
        vec!["hit retention".into(), format!("{retention:.3}")],
        vec!["failed-over epoch wall (ms)".into(), format!("{failover_run_ms:.1}")],
    ];
    print_table(
        "Cluster (ext): routing overhead, placement hit parity, group-local failover",
        &["metric", "value"],
        &rows,
    );
    let mut csv = CsvWriter::new(&["metric", "value"]);
    for r in &rows {
        csv.rowf(&[&r[0], &r[1]]);
    }
    csv.write("results/fig_cluster.csv").unwrap();
    println!("series -> results/fig_cluster.csv");

    // Machine-readable perf trajectory for future PRs.
    let out = std::env::var("TVCACHE_BENCH_OUT").unwrap_or_else(|_| "../BENCH_10.json".into());
    let line = format!(
        "{{\"bench\":\"fig_cluster\",\"mode\":\"{}\",\
         \"direct_us\":{:.2},\"router_us\":{:.2},\"overhead_ratio\":{overhead:.4},\
         \"single_hit_rate\":{single_hr:.4},\"cluster_hit_rate\":{cluster_hr:.4},\
         \"hit_rate_delta\":{hr_delta:.4},\
         \"nofault_hits\":{},\"failover_hits\":{},\"hit_retention\":{retention:.4},\
         \"failovers\":1,\"failover_run_ms\":{failover_run_ms:.1}}}",
        if smoke { "smoke" } else { "full" },
        direct_best * 1e6,
        router_best * 1e6,
        nofault.hits,
        failed_over.hits,
    );
    match std::fs::OpenOptions::new().create(true).append(true).open(&out) {
        Ok(mut f) => {
            let _ = writeln!(f, "{line}");
            println!("appended -> {out}");
        }
        Err(e) => println!("could not append to {out}: {e}"),
    }

    // Acceptance: the routing layer is ≤ 10% of a warm lookup, placement
    // costs < 5 hit-rate points, and a primary outage stays group-local
    // with ≥ 80% of the hit count retained.
    assert!(
        overhead <= 1.10,
        "router overhead must stay <= 10% over direct: {overhead:.3}x \
         ({:.1}µs vs {:.1}µs)",
        router_best * 1e6,
        direct_best * 1e6
    );
    assert!(
        hr_delta <= 0.05,
        "3-group hit rate must match single-node within 5 points: \
         {cluster_hr:.3} vs {single_hr:.3}"
    );
    assert!(
        retention >= 0.8,
        "post-failover hit count must hold >= 80% of no-fault: {retention:.3}"
    );
    println!(
        "fig_cluster OK: routing {overhead:.3}x, hit-rate delta {hr_delta:.3}, \
         retention {retention:.3} with one group-local failover"
    );
}
