//! Appendix B ablation: stateful prefix matching (skipping annotated
//! stateless tools during LPM) vs treating every call as stateful.
//!
//! Paper claim: on workloads with annotated stateless tools (EgoSchema),
//! skipping them during LPM significantly increases cache-hit and LPM
//! rates, with zero correctness impact (the Appendix B theorem).

use tvcache::bench::print_table;
use tvcache::cache::LpmConfig;
use tvcache::metrics::CsvWriter;
use tvcache::train::{run_workload, SimOptions};
use tvcache::workloads::{Workload, WorkloadConfig};

fn main() {
    let cfg = WorkloadConfig::config_for(Workload::EgoSchema);
    let mut rows = Vec::new();
    let mut csv = CsvWriter::new(&["variant", "hit_rate", "tool_time_s", "reward"]);

    let mut results = Vec::new();
    for (name, filtering) in [("stateful prefix matching", true), ("no filtering", false)] {
        let mut opts = SimOptions::from_config(&cfg, 12, true);
        opts.epochs = 5;
        opts.lpm = LpmConfig { stateful_filtering: filtering, ancestor_resume: true };
        let m = run_workload(&cfg, &opts);
        let tool_time: f64 = m.rollouts.iter().map(|r| r.tool_time).sum();
        let reward: f64 =
            m.rollouts.iter().map(|r| r.reward).sum::<f64>() / m.rollouts.len() as f64;
        rows.push(vec![
            name.to_string(),
            format!("{:.1}%", 100.0 * m.overall_hit_rate()),
            format!("{tool_time:.0}"),
            format!("{reward:.3}"),
        ]);
        csv.rowf(&[
            &name,
            &format!("{:.4}", m.overall_hit_rate()),
            &format!("{tool_time:.1}"),
            &format!("{reward:.4}"),
        ]);
        results.push((m.overall_hit_rate(), reward));
    }

    print_table(
        "Appendix B: stateless-skip ablation on EgoSchema (paper: hit rate up, correctness unchanged)",
        &["variant", "hit_rate", "total_tool_time", "mean_reward"],
        &rows,
    );
    csv.write("results/appendix_b_stateless_skip.csv").unwrap();

    let (hr_on, rw_on) = results[0];
    let (hr_off, rw_off) = results[1];
    assert!(hr_on > hr_off, "filtering must raise the hit rate: {hr_on} vs {hr_off}");
    assert!((rw_on - rw_off).abs() < 1e-9, "correctness must be unchanged");
    println!("\nhit-rate uplift: {:.1} -> {:.1} pp; rewards identical ✓",
        100.0 * hr_off, 100.0 * hr_on);
    println!("series -> results/appendix_b_stateless_skip.csv");
}
