//! Crash-recovery figure (extension): the durable op-log restores a killed
//! primary to its exact pre-crash state, and a checkpoint bounds the replay.
//!
//! Four measured sections, exact accounting plus wall-clock:
//!
//! 1. **WAL append overhead**: the same insert stream runs against a plain
//!    service and a WAL-backed one — the per-op cost of durability with
//!    group fsync off the hot path.
//! 2. **Full-log recovery**: the WAL service drops (a kill, as far as the
//!    disk is concerned) and a fresh service reopens the directory,
//!    replaying every record.
//! 3. **Checkpointed recovery**: a `/persist` into `wal_dir/checkpoint`
//!    anchors the log; the next restart warm-starts the checkpoint and
//!    replays only the tail written after it.
//! 4. **Follower bootstrap**: a follower starting behind a truncated
//!    op-log window installs the primary's `/bootstrap` checkpoint and
//!    reaches zero replication lag instead of freezing.
//!
//! Results are appended as one JSON line to `BENCH_9.json` (override with
//! `TVCACHE_BENCH_OUT`).

use std::io::Write as _;
use std::sync::Arc;
use std::time::{Duration, Instant};

use tvcache::bench::print_table;
use tvcache::cache::{
    CacheBackend, ServiceConfig, ShardedCacheService, TaskCache, ToolCall, ToolResult,
};
use tvcache::client::{BindingConfig, RemoteBinding};
use tvcache::metrics::CsvWriter;
use tvcache::server::{serve_follower_with_tick, serve_service};

fn traj(i: usize) -> Vec<(ToolCall, ToolResult)> {
    vec![
        (ToolCall::new("bash", format!("seed{}", i % 8)), ToolResult::new("ok", 1.0)),
        (ToolCall::new("bash", format!("op{i}")), ToolResult::new(format!("out-{i}"), 2.0)),
    ]
}

fn task(i: usize) -> String {
    format!("t{}", i % 8)
}

fn wal_svc(dir: &std::path::Path) -> ShardedCacheService {
    ShardedCacheService::with_config(
        ServiceConfig {
            shards: 2,
            wal_dir: Some(dir.to_path_buf()),
            wal_segment_bytes: 16 * 1024,
            ..Default::default()
        },
        Arc::new(TaskCache::with_defaults),
    )
    .unwrap()
}

fn probe_cfg() -> BindingConfig {
    BindingConfig {
        connect_timeout: Duration::from_millis(500),
        read_timeout: Duration::from_secs(2),
        retries: 0,
        backoff_base: Duration::from_millis(1),
        backoff_max: Duration::from_millis(4),
        breaker_threshold: 1000,
        breaker_cooldown: Duration::from_secs(60),
        seed: 0x9EED,
        probe_cooldown: Duration::ZERO,
        endpoints: Vec::new(),
    }
}

fn main() {
    let smoke = std::env::var("TVCACHE_BENCH_SMOKE").is_ok();
    let n_ops: usize = if smoke { 400 } else { 4000 };
    let dir = std::env::temp_dir().join(format!("tvcache-figrec-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    // ── 1. WAL append overhead vs a plain in-memory service ─────────────
    let plain = ShardedCacheService::new(2);
    let t0 = Instant::now();
    for i in 0..n_ops {
        plain.insert(&task(i), &traj(i)).expect("plain insert");
    }
    let plain_ops_s = n_ops as f64 / t0.elapsed().as_secs_f64();
    drop(plain);

    let svc = wal_svc(&dir);
    let t0 = Instant::now();
    for i in 0..n_ops {
        svc.insert(&task(i), &traj(i)).expect("wal insert");
    }
    let wal_ops_s = n_ops as f64 / t0.elapsed().as_secs_f64();
    let stats = svc.service_stats();
    let (segments, fsyncs, wal_bytes) =
        (stats.wal_segments, stats.wal_fsyncs, stats.wal_appended_bytes);
    assert!(wal_bytes > 0, "appends must reach the WAL");
    assert!(segments > 1, "16 KiB segments must rotate under {n_ops} ops");

    // ── 2. Full-log recovery (drop == kill, the WAL is all that's left) ──
    drop(svc);
    let t0 = Instant::now();
    let svc = wal_svc(&dir);
    let recover_full_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert_eq!(svc.service_stats().recoveries, 1, "reopen must recover");
    let log = svc.oplog().expect("WAL service keeps an op-log");
    assert_eq!(log.next_seq(), n_ops as u64, "every record must replay");
    for i in [0, n_ops / 2, n_ops - 1] {
        let q: Vec<ToolCall> = traj(i).into_iter().map(|(c, _)| c).collect();
        assert!(svc.lookup(&task(i), &q).is_hit(), "op {i} lost in full-log recovery");
    }

    // ── 3. Checkpoint, write a tail, recover again ───────────────────────
    svc.persist_to_dir(&dir.join("checkpoint")).expect("checkpoint persist");
    assert_eq!(svc.checkpoint_seq(), n_ops as u64, "checkpoint must stamp the log seq");
    let tail_ops = n_ops / 10;
    for i in n_ops..n_ops + tail_ops {
        svc.insert(&task(i), &traj(i)).expect("tail insert");
    }
    drop(svc);
    let t0 = Instant::now();
    let svc = wal_svc(&dir);
    let recover_ckpt_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert_eq!(svc.service_stats().recoveries, 1);
    assert_eq!(svc.checkpoint_seq(), n_ops as u64, "recovery must adopt the checkpoint seq");
    let log = svc.oplog().expect("op-log after checkpointed recovery");
    assert_eq!(log.next_seq(), (n_ops + tail_ops) as u64, "tail must replay on top");
    for i in [0, n_ops - 1, n_ops, n_ops + tail_ops - 1] {
        let q: Vec<ToolCall> = traj(i).into_iter().map(|(c, _)| c).collect();
        assert!(svc.lookup(&task(i), &q).is_hit(), "op {i} lost in checkpointed recovery");
    }
    drop(svc);

    // ── 4. Gapped follower bootstraps instead of freezing ───────────────
    let primary = ShardedCacheService::with_config(
        ServiceConfig { shards: 2, replicate_window: Some(64), ..Default::default() },
        Arc::new(TaskCache::with_defaults),
    )
    .unwrap();
    let n_gap = if smoke { 256 } else { 1024 };
    for i in 0..n_gap {
        primary.insert(&task(i), &traj(i)).expect("primary insert");
    }
    // The window held 64 ops; everything older left the log before the
    // follower ever connected — only /bootstrap can close that gap.
    let (p_server, _p_svc) = serve_service("127.0.0.1:0", 4, primary).unwrap();
    let t0 = Instant::now();
    let (f_server, f_svc) = serve_follower_with_tick(
        "127.0.0.1:0",
        2,
        ShardedCacheService::new(2),
        p_server.addr(),
        Duration::from_millis(2),
    )
    .unwrap();
    // The oldest op predates the window: only the bootstrap checkpoint
    // can carry it, so a hit proves the checkpoint was installed. (Lag
    // alone can't gate this poll — it reads 0 before the first pull.)
    let probe = RemoteBinding::connect_with(f_server.addr(), probe_cfg());
    let q: Vec<ToolCall> = traj(0).into_iter().map(|(c, _)| c).collect();
    let deadline = t0 + Duration::from_secs(10);
    while !probe.lookup(&task(0), &q).is_hit() {
        assert!(Instant::now() < deadline, "gapped follower never bootstrapped");
        std::thread::sleep(Duration::from_millis(2));
    }
    while f_svc.replica_lag_ops() != 0 {
        assert!(Instant::now() < deadline, "bootstrapped follower never reached zero lag");
        std::thread::sleep(Duration::from_millis(2));
    }
    let bootstrap_ms = t0.elapsed().as_secs_f64() * 1e3;

    // ── Report ──────────────────────────────────────────────────────────
    let overhead = (1.0 - wal_ops_s / plain_ops_s) * 100.0;
    let rows = vec![
        vec!["ops appended".into(), format!("{n_ops}")],
        vec!["plain insert (ops/s)".into(), format!("{plain_ops_s:.0}")],
        vec!["WAL insert (ops/s)".into(), format!("{wal_ops_s:.0}")],
        vec!["durability overhead".into(), format!("{overhead:.1}%")],
        vec!["WAL segments / fsyncs".into(), format!("{segments} / {fsyncs}")],
        vec!["WAL bytes".into(), format!("{wal_bytes}")],
        vec!["full-log recovery (ms)".into(), format!("{recover_full_ms:.1}")],
        vec!["checkpointed recovery (ms)".into(), format!("{recover_ckpt_ms:.1}")],
        vec!["tail replayed after ckpt (ops)".into(), format!("{tail_ops}")],
        vec!["follower bootstrap to lag 0 (ms)".into(), format!("{bootstrap_ms:.1}")],
    ];
    print_table(
        "Recovery (ext): WAL replay, checkpoint anchoring, follower bootstrap",
        &["metric", "value"],
        &rows,
    );
    let mut csv = CsvWriter::new(&["metric", "value"]);
    for r in &rows {
        csv.rowf(&[&r[0], &r[1]]);
    }
    csv.write("results/fig_recovery.csv").unwrap();
    println!("series -> results/fig_recovery.csv");

    // Machine-readable perf trajectory for future PRs.
    let out = std::env::var("TVCACHE_BENCH_OUT").unwrap_or_else(|_| "../BENCH_9.json".into());
    let line = format!(
        "{{\"bench\":\"fig_recovery\",\"mode\":\"{}\",\"n_ops\":{n_ops},\
         \"plain_ops_per_s\":{plain_ops_s:.0},\"wal_ops_per_s\":{wal_ops_s:.0},\
         \"wal_segments\":{segments},\"wal_fsyncs\":{fsyncs},\"wal_bytes\":{wal_bytes},\
         \"recover_full_ms\":{recover_full_ms:.2},\"recover_ckpt_ms\":{recover_ckpt_ms:.2},\
         \"ckpt_tail_ops\":{tail_ops},\"bootstrap_ms\":{bootstrap_ms:.2}}}",
        if smoke { "smoke" } else { "full" },
    );
    match std::fs::OpenOptions::new().create(true).append(true).open(&out) {
        Ok(mut f) => {
            let _ = writeln!(f, "{line}");
            println!("appended -> {out}");
        }
        Err(e) => println!("could not append to {out}: {e}"),
    }

    let _ = std::fs::remove_dir_all(&dir);
    println!(
        "fig_recovery OK: {n_ops} ops replayed in {recover_full_ms:.1} ms, checkpoint cut the \
         replay to {tail_ops} ops ({recover_ckpt_ms:.1} ms), gapped follower bootstrapped to \
         zero lag in {bootstrap_ms:.1} ms"
    );
}
