//! Figure 6: reward curves with and without TVCACHE must closely match
//! (exact caching ⇒ no post-training degradation).
//!
//! Two levels of evidence:
//! 1. Simulated workloads (identical seeds): per-epoch mean rewards must be
//!    *identical* with and without the cache, across all three workloads.
//! 2. The real GRPO loop (`examples/e2e_terminal_rl.rs`) provides the
//!    learning-curve version; its CSV is referenced in EXPERIMENTS.md.

use tvcache::bench::print_table;
use tvcache::metrics::CsvWriter;
use tvcache::train::{run_workload, SimOptions};
use tvcache::workloads::{Workload, WorkloadConfig};

fn main() {
    let mut csv = CsvWriter::new(&["workload", "epoch", "reward_cached", "reward_uncached"]);
    let mut rows = Vec::new();

    for (name, wl, tasks) in [
        ("terminal-bench", Workload::TerminalEasy, 8),
        ("SkyRL-SQL", Workload::SkyRlSql, 12),
        ("EgoSchema", Workload::EgoSchema, 8),
    ] {
        let cfg = WorkloadConfig::config_for(wl);
        let opts = SimOptions::from_config(&cfg, tasks, true);
        let cached = run_workload(&cfg, &opts);
        let uncached = run_workload(&cfg, &SimOptions { cached: false, ..opts });

        let mut max_dev = 0.0f64;
        for ((e, rc), (_, ru)) in cached.epoch_rewards.iter().zip(&uncached.epoch_rewards) {
            max_dev = max_dev.max((rc - ru).abs());
            csv.rowf(&[&name, e, &format!("{rc:.4}"), &format!("{ru:.4}")]);
        }
        rows.push(vec![
            name.to_string(),
            format!("{:.3}", cached.epoch_rewards.last().unwrap().1),
            format!("{:.3}", uncached.epoch_rewards.last().unwrap().1),
            format!("{max_dev:.2e}"),
            (if max_dev < 1e-12 { "identical ✓" } else { "DIVERGED ✗" }).to_string(),
        ]);
    }

    print_table(
        "Figure 6: reward curves cached vs uncached (paper: curves closely match)",
        &["workload", "final_reward(tvcache)", "final_reward(no-cache)", "max_dev", "verdict"],
        &rows,
    );
    csv.write("results/fig6_reward_curves.csv").unwrap();
    println!("\nseries -> results/fig6_reward_curves.csv");
    println!("learning-curve variant: results/e2e_terminal_rl.csv (examples/e2e_terminal_rl.rs)");
}
