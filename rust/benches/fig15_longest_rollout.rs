//! Figure 15 (Appendix F): longest rollout time per training step, with and
//! without TVCACHE, for the terminal configurations.
//!
//! Paper shape: TVCACHE reduces the longest rollout per step; gains are
//! larger on easy tasks than medium ones.

use tvcache::bench::print_table;
use tvcache::metrics::CsvWriter;
use tvcache::train::{run_workload, SimOptions};
use tvcache::workloads::{Workload, WorkloadConfig};

fn main() {
    let mut rows = Vec::new();
    let mut csv = CsvWriter::new(&["config", "step", "longest_tvcache", "longest_no_cache"]);

    for (label, wl) in [
        ("4B/easy", Workload::TerminalEasy),
        ("4B/med", Workload::TerminalMedium),
    ] {
        let cfg = WorkloadConfig::config_for(wl);
        let mut opts = SimOptions::from_config(&cfg, 6, true);
        opts.epochs = 8;
        let cached = run_workload(&cfg, &opts);
        let uncached = run_workload(&cfg, &SimOptions { cached: false, ..opts });

        // "Step" = (epoch, task); longest rollout within it.
        let mut savings = Vec::new();
        for (i, (c, u)) in cached.batches.iter().zip(&uncached.batches).enumerate() {
            csv.rowf(&[
                &label,
                &i,
                &format!("{:.1}", c.longest_rollout),
                &format!("{:.1}", u.longest_rollout),
            ]);
            savings.push(1.0 - c.longest_rollout / u.longest_rollout.max(1e-9));
        }
        let mean_saving = savings.iter().sum::<f64>() / savings.len() as f64;
        let frac_improved =
            savings.iter().filter(|&&s| s > 0.0).count() as f64 / savings.len() as f64;
        rows.push(vec![
            label.to_string(),
            format!("{:.1}%", 100.0 * mean_saving),
            format!("{:.0}%", 100.0 * frac_improved),
        ]);
    }

    print_table(
        "Figure 15: longest rollout per step (paper: tvcache lower; easy gains > medium)",
        &["config", "mean longest-rollout saving", "steps improved"],
        &rows,
    );
    csv.write("results/fig15_longest_rollout.csv").unwrap();
    println!("\nseries -> results/fig15_longest_rollout.csv");
}
