//! Figure 10 (extension): per-call lookup cost vs trajectory depth.
//!
//! The cache keys every lookup on the rollout's *full* tool history
//! (§3.1). Paid literally — a root-to-leaf TCG walk per call, a
//! JSON-serialized full prefix per request — that makes the per-call cost
//! O(L) and the per-rollout wire traffic O(L²). Stateful lookup cursors
//! (`SessionBackend::cursor_open/step/record`) pin the rollout's TCG
//! position server-side so each call ships only the delta: O(1) work and
//! bytes per call regardless of depth.
//!
//! This bench measures both claims on the in-process service:
//!
//! 1. **Latency**: per-call lookup latency of a depth-L all-hit replay,
//!    cursor path vs legacy full-prefix path, for L = 1 … 128. The cursor
//!    path must stay flat; the legacy path grows linearly.
//! 2. **Wire bytes**: exact request-frame bytes for a depth-32 all-miss
//!    rollout (the worst case: every call pays a lookup *and* a record),
//!    binary cursor protocol vs the JSON full-prefix protocol. Cursor
//!    bytes are O(L); JSON bytes are O(L²) — the bench asserts ≥5× fewer.
//!
//! 3. **Turn batching** (session API v2): exact frame + byte accounting
//!    for a depth-32 rollout with 4 speculative stateless probes per
//!    reasoning turn — per-call cursor protocol (5+ frames/turn) vs one
//!    `/session_turn` frame per turn. Asserts ≤ 1 round-trip per warm
//!    turn batched and ≥ 5 per-call.
//!
//! `TVCACHE_BENCH_SMOKE=1` shrinks iteration counts and relaxes the
//! timing assertions for CI smoke runs (the byte and frame accounting is
//! exact and stays asserted). Results are appended as one JSON line to
//! `BENCH_4.json` (override the path with `TVCACHE_BENCH_OUT`) so
//! successive PRs build a machine-readable perf trajectory.

use std::io::Write as _;
use std::sync::Arc;
use std::time::Instant;

use tvcache::bench::print_table;
use tvcache::cache::{
    CacheBackend, SessionBackend, ShardedCacheService, ToolCall, ToolResult, TurnBatch, TurnOp,
};
use tvcache::metrics::CsvWriter;
use tvcache::server::lookup_body;
use tvcache::wire;

const TASK: &str = "fig10-task";
const MAX_DEPTH: usize = 128;
const DEPTHS: [usize; 8] = [1, 2, 4, 8, 16, 32, 64, 128];
const BYTES_DEPTH: usize = 32;
/// Speculative stateless probes per reasoning turn in the batching section
/// (the acceptance scenario: 4 probes + 1 stateful step per turn).
const PROBES_PER_TURN: usize = 4;

fn call_at(d: usize) -> ToolCall {
    ToolCall::new("bash", format!("step-{d} --with --some --realistic args"))
}

fn result_at(d: usize) -> ToolResult {
    ToolResult::new(format!("output of step {d}\nline two"), 1.0)
}

/// Mean seconds per lookup over `walks` cursor walks of depth `depth`
/// (seek back to the root between walks, outside the timed region).
fn cursor_ns_per_call(
    svc: &ShardedCacheService,
    chain: &[ToolCall],
    depth: usize,
    walks: usize,
) -> f64 {
    let cur = svc.cursor_open(TASK);
    assert!(cur != 0);
    let mut total = 0.0f64;
    for _ in 0..walks {
        assert!(svc.cursor_seek(TASK, cur, 0, 0), "seek to ROOT");
        let t0 = Instant::now();
        for c in &chain[..depth] {
            let step = svc.cursor_step(TASK, cur, c);
            assert!(step.is_hit(), "warm chain must hit");
        }
        total += t0.elapsed().as_secs_f64();
    }
    svc.cursor_close(TASK, cur);
    total / (walks * depth) as f64 * 1e9
}

/// Mean seconds per legacy full-prefix lookup at exactly `depth`.
fn legacy_ns_per_call(
    svc: &ShardedCacheService,
    chain: &[ToolCall],
    depth: usize,
    iters: usize,
) -> f64 {
    let q = &chain[..depth];
    let t0 = Instant::now();
    for _ in 0..iters {
        assert!(svc.lookup(TASK, q).is_hit(), "warm chain must hit");
    }
    t0.elapsed().as_secs_f64() / iters as f64 * 1e9
}

/// Exact request bytes for a depth-L all-miss rollout under each protocol.
fn wire_bytes(depth: usize) -> (usize, usize) {
    let mut json_bytes = 0usize;
    let mut bin_bytes = 0usize;
    let mut buf = Vec::new();

    // Binary cursor protocol: one open + per call one step + one record.
    buf.clear();
    wire::enc_cursor_open(&mut buf, TASK);
    bin_bytes += buf.len();

    let mut history: Vec<(ToolCall, ToolResult)> = Vec::new();
    for d in 0..depth {
        let call = call_at(d);
        let result = result_at(d);

        buf.clear();
        wire::enc_cursor_step(&mut buf, TASK, 1, &call);
        bin_bytes += buf.len();
        buf.clear();
        wire::enc_cursor_record(&mut buf, TASK, 1, &call, &result);
        bin_bytes += buf.len();

        // Legacy JSON protocol: the full prefix per lookup + the full
        // trajectory per insert.
        history.push((call, result));
        let q: Vec<ToolCall> = history.iter().map(|(c, _)| c.clone()).collect();
        json_bytes += lookup_body(TASK, &q).len();
        json_bytes += json_put_body(&history).len();
    }
    (json_bytes, bin_bytes)
}

fn probe_at(p: usize) -> ToolCall {
    ToolCall::stateless("bash", format!("cat status-{p}.txt"))
}

/// Exact wire frames + bytes for a depth-L rollout with
/// [`PROBES_PER_TURN`] speculative probes per reasoning turn, per-call
/// cursor protocol vs `/session_turn` batching. Returns
/// `(percall_frames, percall_bytes, batch_frames, batch_bytes)`.
///
/// Per-call (the PR 3 protocol): every probe is its own `/cursor_step`
/// frame, the step another, a miss's record one more — ≥ 5 round trips per
/// warm turn at 4 probes. Batched: the probes and the turn's stateful op
/// share a single `/session_turn` frame (the session open rides the first
/// frame; on a miss the record is its own frame, since its result only
/// exists after client-side execution).
fn turn_traffic(depth: usize, warm: bool) -> (usize, usize, usize, usize) {
    let mut buf = Vec::new();
    let (mut pc_frames, mut pc_bytes) = (0usize, 0usize);
    let (mut b_frames, mut b_bytes) = (0usize, 0usize);

    // Per-call path pays an explicit open round trip.
    buf.clear();
    wire::enc_cursor_open(&mut buf, TASK);
    pc_frames += 1;
    pc_bytes += buf.len();

    for d in 0..depth {
        let call = call_at(d);
        let probes: Vec<ToolCall> = (0..PROBES_PER_TURN).map(probe_at).collect();

        // Per-call: each probe and the step is one frame.
        for p in &probes {
            buf.clear();
            wire::enc_cursor_step(&mut buf, TASK, 1, p);
            pc_frames += 1;
            pc_bytes += buf.len();
        }
        buf.clear();
        wire::enc_cursor_step(&mut buf, TASK, 1, &call);
        pc_frames += 1;
        pc_bytes += buf.len();

        // Batched: one turn frame (cursor 0 on the first = open piggyback).
        buf.clear();
        let cursor = if d == 0 { 0 } else { 1 };
        wire::enc_turn(&mut buf, TASK, cursor, &TurnBatch {
            probes,
            op: TurnOp::Step(call.clone()),
        });
        b_frames += 1;
        b_bytes += buf.len();

        if !warm {
            // Cold turn: the executed delta is recorded — one more frame
            // on both paths.
            let result = result_at(d);
            buf.clear();
            wire::enc_cursor_record(&mut buf, TASK, 1, &call, &result);
            pc_frames += 1;
            pc_bytes += buf.len();
            buf.clear();
            wire::enc_turn(&mut buf, TASK, 1, &TurnBatch {
                probes: Vec::new(),
                op: TurnOp::Record(call, result),
            });
            b_frames += 1;
            b_bytes += buf.len();
        }
    }
    (pc_frames, pc_bytes, b_frames, b_bytes)
}

/// End-to-end sanity for the batched path: a warm depth-`depth` rollout
/// with probes per turn, driven through the real in-process service; every
/// step must hit and the probes must answer.
fn drive_batched_session(svc: &ShardedCacheService, depth: usize) {
    let mut cursor = 0u64;
    for d in 0..depth {
        let reply = svc.session_turn(TASK, cursor, &TurnBatch {
            probes: (0..PROBES_PER_TURN).map(probe_at).collect(),
            op: TurnOp::Step(call_at(d)),
        });
        assert!(reply.cursor != 0, "turn frame must open/keep the session");
        cursor = reply.cursor;
        assert!(
            matches!(reply.step, Some(tvcache::cache::CursorStep::Hit { .. })),
            "warm chain must hit at depth {d}"
        );
    }
    svc.cursor_close(TASK, cursor);
}

/// The legacy `/put` JSON body (what `RemoteBinding::insert` used to send).
fn json_put_body(traj: &[(ToolCall, ToolResult)]) -> String {
    use tvcache::util::json::Json;
    let entries: Vec<Json> = traj
        .iter()
        .map(|(c, r)| Json::obj(vec![("call", c.to_json()), ("result", r.to_json())]))
        .collect();
    Json::obj(vec![("task", Json::str(TASK)), ("trajectory", Json::Arr(entries))])
        .to_string()
}

fn main() {
    let smoke = std::env::var("TVCACHE_BENCH_SMOKE").is_ok();
    let (walk_budget, repeats) = if smoke { (2_000usize, 2usize) } else { (40_000, 5) };

    // One task, one warm chain of MAX_DEPTH mutating calls.
    let svc = ShardedCacheService::new(4);
    let chain: Vec<ToolCall> = (0..MAX_DEPTH).map(call_at).collect();
    let traj: Vec<(ToolCall, ToolResult)> =
        (0..MAX_DEPTH).map(|d| (call_at(d), result_at(d))).collect();
    svc.insert(TASK, &traj);

    // Latency sweep: median-of-repeats per depth, both paths.
    let mut cursor_ns = Vec::new();
    let mut legacy_ns = Vec::new();
    for &depth in &DEPTHS {
        let walks = (walk_budget / depth).max(8);
        let mut c_samples: Vec<f64> = (0..repeats)
            .map(|_| cursor_ns_per_call(&svc, &chain, depth, walks))
            .collect();
        let mut l_samples: Vec<f64> = (0..repeats)
            .map(|_| legacy_ns_per_call(&svc, &chain, depth, walks))
            .collect();
        c_samples.sort_by(f64::total_cmp);
        l_samples.sort_by(f64::total_cmp);
        cursor_ns.push(c_samples[repeats / 2]);
        legacy_ns.push(l_samples[repeats / 2]);
    }

    let (json_bytes, bin_bytes) = wire_bytes(BYTES_DEPTH);
    let byte_ratio = json_bytes as f64 / bin_bytes as f64;

    // Turn-level batching (session API v2): a depth-32 rollout with 4
    // speculative probes per reasoning turn, per-call cursor protocol vs
    // one `/session_turn` frame per turn.
    let (pc_frames_warm, pc_bytes_warm, b_frames_warm, b_bytes_warm) =
        turn_traffic(BYTES_DEPTH, true);
    let (pc_frames_cold, pc_bytes_cold, b_frames_cold, b_bytes_cold) =
        turn_traffic(BYTES_DEPTH, false);
    let warm_rt_per_turn = b_frames_warm as f64 / BYTES_DEPTH as f64;
    let pc_rt_per_turn = pc_frames_warm as f64 / BYTES_DEPTH as f64;
    // And prove the batched path actually serves the same warm rollout.
    drive_batched_session(&svc, BYTES_DEPTH);

    let mut rows = Vec::new();
    let mut csv = CsvWriter::new(&["depth", "cursor_ns_per_call", "legacy_ns_per_call"]);
    for (i, &depth) in DEPTHS.iter().enumerate() {
        rows.push(vec![
            format!("{depth}"),
            format!("{:.0}", cursor_ns[i]),
            format!("{:.0}", legacy_ns[i]),
        ]);
        csv.rowf(&[&depth, &format!("{:.1}", cursor_ns[i]), &format!("{:.1}", legacy_ns[i])]);
    }
    print_table(
        "Figure 10 (ext): per-call lookup latency vs trajectory depth (ns/call)",
        &["depth", "cursor", "legacy full-prefix"],
        &rows,
    );
    println!(
        "\nwire bytes, depth-{BYTES_DEPTH} all-miss rollout: JSON {json_bytes} B vs binary \
         cursor {bin_bytes} B  ({byte_ratio:.1}x fewer)"
    );
    println!(
        "\nturn batching, depth-{BYTES_DEPTH} rollout, {PROBES_PER_TURN} probes/turn:\n\
         \x20 warm: per-call {pc_frames_warm} frames / {pc_bytes_warm} B  vs  \
         /session_turn {b_frames_warm} frames / {b_bytes_warm} B  \
         ({pc_rt_per_turn:.2} -> {warm_rt_per_turn:.2} round-trips per reasoning turn)\n\
         \x20 cold: per-call {pc_frames_cold} frames / {pc_bytes_cold} B  vs  \
         /session_turn {b_frames_cold} frames / {b_bytes_cold} B"
    );
    csv.write("results/fig10_lookup_depth.csv").unwrap();
    println!("series -> results/fig10_lookup_depth.csv");

    // Machine-readable perf trajectory for future PRs.
    let out = std::env::var("TVCACHE_BENCH_OUT").unwrap_or_else(|_| "../BENCH_4.json".into());
    let line = format!(
        "{{\"bench\":\"fig10_lookup_depth\",\"mode\":\"{}\",\
         \"cursor_ns_d1\":{:.1},\"cursor_ns_d128\":{:.1},\
         \"legacy_ns_d1\":{:.1},\"legacy_ns_d128\":{:.1},\
         \"json_bytes_d32\":{json_bytes},\"bin_bytes_d32\":{bin_bytes},\
         \"byte_ratio\":{byte_ratio:.2},\
         \"probes_per_turn\":{PROBES_PER_TURN},\
         \"percall_frames_warm_d32\":{pc_frames_warm},\
         \"batch_frames_warm_d32\":{b_frames_warm},\
         \"percall_bytes_warm_d32\":{pc_bytes_warm},\
         \"batch_bytes_warm_d32\":{b_bytes_warm},\
         \"percall_frames_cold_d32\":{pc_frames_cold},\
         \"batch_frames_cold_d32\":{b_frames_cold},\
         \"rt_per_turn_warm\":{warm_rt_per_turn:.3}}}",
        if smoke { "smoke" } else { "full" },
        cursor_ns[0],
        cursor_ns[DEPTHS.len() - 1],
        legacy_ns[0],
        legacy_ns[DEPTHS.len() - 1],
    );
    match std::fs::OpenOptions::new().create(true).append(true).open(&out) {
        Ok(mut f) => {
            let _ = writeln!(f, "{line}");
            println!("appended -> {out}");
        }
        Err(e) => println!("could not append to {out}: {e}"),
    }

    // Acceptance: wire bytes are exact and always asserted.
    assert!(
        byte_ratio >= 5.0,
        "binary cursor protocol must cut depth-{BYTES_DEPTH} rollout bytes ≥5x \
         (got {byte_ratio:.2}x)"
    );
    // Acceptance (PR 4): a depth-32 rollout with 4 speculative probes per
    // turn issues ≤ 1 wire round-trip per reasoning turn batched, vs ≥ 5
    // on the per-call protocol.
    assert!(
        warm_rt_per_turn <= 1.0,
        "turn batching must cost ≤ 1 round-trip per reasoning turn \
         (got {warm_rt_per_turn:.2})"
    );
    assert!(
        pc_rt_per_turn >= 5.0,
        "per-call baseline sanity: {PROBES_PER_TURN} probes + 1 step must be ≥ 5 \
         round-trips per turn (got {pc_rt_per_turn:.2})"
    );
    assert!(
        b_bytes_cold < pc_bytes_cold && b_bytes_warm < pc_bytes_warm,
        "turn frames must not cost more bytes than the per-call frames they replace"
    );

    // Latency shape. The cursor path does identical O(1) work per step at
    // every depth; the legacy path re-walks the prefix. Timing asserts are
    // relaxed under smoke mode (tiny iteration counts on shared CI boxes).
    let cursor_growth = cursor_ns[DEPTHS.len() - 1] / cursor_ns[0];
    let legacy_growth = legacy_ns[DEPTHS.len() - 1] / legacy_ns[0];
    println!(
        "cursor per-call growth 1->128: {cursor_growth:.2}x   \
         legacy per-call growth 1->128: {legacy_growth:.2}x"
    );
    let (flat_bound, growth_floor) = if smoke { (3.0, 2.0) } else { (1.2, 8.0) };
    assert!(
        cursor_growth <= flat_bound,
        "cursor per-call latency must be flat in depth: {cursor_growth:.2}x > {flat_bound}x"
    );
    assert!(
        legacy_growth >= growth_floor,
        "legacy per-call latency should grow with depth (sanity of the baseline): \
         {legacy_growth:.2}x < {growth_floor}x"
    );
    println!("fig10 OK: cursor lookups are O(1) per call; wire bytes O(L) per rollout");
}
