//! Figure 10 (extension): per-call lookup cost vs trajectory depth.
//!
//! The cache keys every lookup on the rollout's *full* tool history
//! (§3.1). Paid literally — a root-to-leaf TCG walk per call, a
//! JSON-serialized full prefix per request — that makes the per-call cost
//! O(L) and the per-rollout wire traffic O(L²). Stateful lookup cursors
//! (`CacheBackend::cursor_open/step/record`) pin the rollout's TCG
//! position server-side so each call ships only the delta: O(1) work and
//! bytes per call regardless of depth.
//!
//! This bench measures both claims on the in-process service:
//!
//! 1. **Latency**: per-call lookup latency of a depth-L all-hit replay,
//!    cursor path vs legacy full-prefix path, for L = 1 … 128. The cursor
//!    path must stay flat; the legacy path grows linearly.
//! 2. **Wire bytes**: exact request-frame bytes for a depth-32 all-miss
//!    rollout (the worst case: every call pays a lookup *and* a record),
//!    binary cursor protocol vs the JSON full-prefix protocol. Cursor
//!    bytes are O(L); JSON bytes are O(L²) — the bench asserts ≥5× fewer.
//!
//! `TVCACHE_BENCH_SMOKE=1` shrinks iteration counts and relaxes the
//! timing assertions for CI smoke runs (the byte accounting is exact and
//! stays asserted). Results are appended as one JSON line to `BENCH_3.json`
//! (override the path with `TVCACHE_BENCH_OUT`) so successive PRs build a
//! machine-readable perf trajectory.

use std::io::Write as _;
use std::sync::Arc;
use std::time::Instant;

use tvcache::bench::print_table;
use tvcache::cache::{CacheBackend, ShardedCacheService, ToolCall, ToolResult};
use tvcache::metrics::CsvWriter;
use tvcache::server::lookup_body;
use tvcache::wire;

const TASK: &str = "fig10-task";
const MAX_DEPTH: usize = 128;
const DEPTHS: [usize; 8] = [1, 2, 4, 8, 16, 32, 64, 128];
const BYTES_DEPTH: usize = 32;

fn call_at(d: usize) -> ToolCall {
    ToolCall::new("bash", format!("step-{d} --with --some --realistic args"))
}

fn result_at(d: usize) -> ToolResult {
    ToolResult::new(format!("output of step {d}\nline two"), 1.0)
}

/// Mean seconds per lookup over `walks` cursor walks of depth `depth`
/// (seek back to the root between walks, outside the timed region).
fn cursor_ns_per_call(
    svc: &ShardedCacheService,
    chain: &[ToolCall],
    depth: usize,
    walks: usize,
) -> f64 {
    let cur = svc.cursor_open(TASK);
    assert!(cur != 0);
    let mut total = 0.0f64;
    for _ in 0..walks {
        assert!(svc.cursor_seek(TASK, cur, 0, 0), "seek to ROOT");
        let t0 = Instant::now();
        for c in &chain[..depth] {
            let step = svc.cursor_step(TASK, cur, c);
            assert!(step.is_hit(), "warm chain must hit");
        }
        total += t0.elapsed().as_secs_f64();
    }
    svc.cursor_close(TASK, cur);
    total / (walks * depth) as f64 * 1e9
}

/// Mean seconds per legacy full-prefix lookup at exactly `depth`.
fn legacy_ns_per_call(
    svc: &ShardedCacheService,
    chain: &[ToolCall],
    depth: usize,
    iters: usize,
) -> f64 {
    let q = &chain[..depth];
    let t0 = Instant::now();
    for _ in 0..iters {
        assert!(svc.lookup(TASK, q).is_hit(), "warm chain must hit");
    }
    t0.elapsed().as_secs_f64() / iters as f64 * 1e9
}

/// Exact request bytes for a depth-L all-miss rollout under each protocol.
fn wire_bytes(depth: usize) -> (usize, usize) {
    let mut json_bytes = 0usize;
    let mut bin_bytes = 0usize;
    let mut buf = Vec::new();

    // Binary cursor protocol: one open + per call one step + one record.
    buf.clear();
    wire::enc_cursor_open(&mut buf, TASK);
    bin_bytes += buf.len();

    let mut history: Vec<(ToolCall, ToolResult)> = Vec::new();
    for d in 0..depth {
        let call = call_at(d);
        let result = result_at(d);

        buf.clear();
        wire::enc_cursor_step(&mut buf, TASK, 1, &call);
        bin_bytes += buf.len();
        buf.clear();
        wire::enc_cursor_record(&mut buf, TASK, 1, &call, &result);
        bin_bytes += buf.len();

        // Legacy JSON protocol: the full prefix per lookup + the full
        // trajectory per insert.
        history.push((call, result));
        let q: Vec<ToolCall> = history.iter().map(|(c, _)| c.clone()).collect();
        json_bytes += lookup_body(TASK, &q).len();
        json_bytes += json_put_body(&history).len();
    }
    (json_bytes, bin_bytes)
}

/// The legacy `/put` JSON body (what `RemoteBinding::insert` used to send).
fn json_put_body(traj: &[(ToolCall, ToolResult)]) -> String {
    use tvcache::util::json::Json;
    let entries: Vec<Json> = traj
        .iter()
        .map(|(c, r)| Json::obj(vec![("call", c.to_json()), ("result", r.to_json())]))
        .collect();
    Json::obj(vec![("task", Json::str(TASK)), ("trajectory", Json::Arr(entries))])
        .to_string()
}

fn main() {
    let smoke = std::env::var("TVCACHE_BENCH_SMOKE").is_ok();
    let (walk_budget, repeats) = if smoke { (2_000usize, 2usize) } else { (40_000, 5) };

    // One task, one warm chain of MAX_DEPTH mutating calls.
    let svc = ShardedCacheService::new(4);
    let chain: Vec<ToolCall> = (0..MAX_DEPTH).map(call_at).collect();
    let traj: Vec<(ToolCall, ToolResult)> =
        (0..MAX_DEPTH).map(|d| (call_at(d), result_at(d))).collect();
    svc.insert(TASK, &traj);

    // Latency sweep: median-of-repeats per depth, both paths.
    let mut cursor_ns = Vec::new();
    let mut legacy_ns = Vec::new();
    for &depth in &DEPTHS {
        let walks = (walk_budget / depth).max(8);
        let mut c_samples: Vec<f64> = (0..repeats)
            .map(|_| cursor_ns_per_call(&svc, &chain, depth, walks))
            .collect();
        let mut l_samples: Vec<f64> = (0..repeats)
            .map(|_| legacy_ns_per_call(&svc, &chain, depth, walks))
            .collect();
        c_samples.sort_by(f64::total_cmp);
        l_samples.sort_by(f64::total_cmp);
        cursor_ns.push(c_samples[repeats / 2]);
        legacy_ns.push(l_samples[repeats / 2]);
    }

    let (json_bytes, bin_bytes) = wire_bytes(BYTES_DEPTH);
    let byte_ratio = json_bytes as f64 / bin_bytes as f64;

    let mut rows = Vec::new();
    let mut csv = CsvWriter::new(&["depth", "cursor_ns_per_call", "legacy_ns_per_call"]);
    for (i, &depth) in DEPTHS.iter().enumerate() {
        rows.push(vec![
            format!("{depth}"),
            format!("{:.0}", cursor_ns[i]),
            format!("{:.0}", legacy_ns[i]),
        ]);
        csv.rowf(&[&depth, &format!("{:.1}", cursor_ns[i]), &format!("{:.1}", legacy_ns[i])]);
    }
    print_table(
        "Figure 10 (ext): per-call lookup latency vs trajectory depth (ns/call)",
        &["depth", "cursor", "legacy full-prefix"],
        &rows,
    );
    println!(
        "\nwire bytes, depth-{BYTES_DEPTH} all-miss rollout: JSON {json_bytes} B vs binary \
         cursor {bin_bytes} B  ({byte_ratio:.1}x fewer)"
    );
    csv.write("results/fig10_lookup_depth.csv").unwrap();
    println!("series -> results/fig10_lookup_depth.csv");

    // Machine-readable perf trajectory for future PRs.
    let out = std::env::var("TVCACHE_BENCH_OUT").unwrap_or_else(|_| "../BENCH_3.json".into());
    let line = format!(
        "{{\"bench\":\"fig10_lookup_depth\",\"mode\":\"{}\",\
         \"cursor_ns_d1\":{:.1},\"cursor_ns_d128\":{:.1},\
         \"legacy_ns_d1\":{:.1},\"legacy_ns_d128\":{:.1},\
         \"json_bytes_d32\":{json_bytes},\"bin_bytes_d32\":{bin_bytes},\
         \"byte_ratio\":{byte_ratio:.2}}}",
        if smoke { "smoke" } else { "full" },
        cursor_ns[0],
        cursor_ns[DEPTHS.len() - 1],
        legacy_ns[0],
        legacy_ns[DEPTHS.len() - 1],
    );
    match std::fs::OpenOptions::new().create(true).append(true).open(&out) {
        Ok(mut f) => {
            let _ = writeln!(f, "{line}");
            println!("appended -> {out}");
        }
        Err(e) => println!("could not append to {out}: {e}"),
    }

    // Acceptance: wire bytes are exact and always asserted.
    assert!(
        byte_ratio >= 5.0,
        "binary cursor protocol must cut depth-{BYTES_DEPTH} rollout bytes ≥5x \
         (got {byte_ratio:.2}x)"
    );

    // Latency shape. The cursor path does identical O(1) work per step at
    // every depth; the legacy path re-walks the prefix. Timing asserts are
    // relaxed under smoke mode (tiny iteration counts on shared CI boxes).
    let cursor_growth = cursor_ns[DEPTHS.len() - 1] / cursor_ns[0];
    let legacy_growth = legacy_ns[DEPTHS.len() - 1] / legacy_ns[0];
    println!(
        "cursor per-call growth 1->128: {cursor_growth:.2}x   \
         legacy per-call growth 1->128: {legacy_growth:.2}x"
    );
    let (flat_bound, growth_floor) = if smoke { (3.0, 2.0) } else { (1.2, 8.0) };
    assert!(
        cursor_growth <= flat_bound,
        "cursor per-call latency must be flat in depth: {cursor_growth:.2}x > {flat_bound}x"
    );
    assert!(
        legacy_growth >= growth_floor,
        "legacy per-call latency should grow with depth (sanity of the baseline): \
         {legacy_growth:.2}x < {growth_floor}x"
    );
    println!("fig10 OK: cursor lookups are O(1) per call; wire bytes O(L) per rollout");
}
