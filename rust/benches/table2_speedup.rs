//! Table 2: median per-tool-call execution time with and without TVCACHE,
//! for the four terminal configurations.
//!
//! Paper rows (s/call no-cache → cached, speedup):
//!   4B/easy 8.67→1.40 (6.18×) | 4B/med 18.68→2.70 (6.92×)
//!   14B/easy 8.07→2.35 (3.44×) | 14B/med 36.23→6.53 (5.55×)
//! Shape to hold: all speedups in the ~3–7× band; medium ≥ easy for 4B.

use tvcache::bench::print_table;
use tvcache::metrics::CsvWriter;
use tvcache::train::{run_workload, SimOptions};
use tvcache::workloads::{Workload, WorkloadConfig};

fn main() {
    let mut rows = Vec::new();
    let mut csv = CsvWriter::new(&["model", "difficulty", "no_cache_s", "tvcache_s", "speedup"]);

    for cfg in WorkloadConfig::table1().into_iter().take(4) {
        let difficulty = match cfg.workload {
            Workload::TerminalEasy => "Easy",
            Workload::TerminalMedium => "Med",
            _ => continue,
        };
        let mut opts = SimOptions::from_config(&cfg, 8, true);
        opts.epochs = 6;
        let cached = run_workload(&cfg, &opts);
        let uncached = run_workload(&cfg, &SimOptions { cached: false, ..opts });

        // Median per-tool-call waiting time over all calls (Appendix F:
        // the no-cache path folds container start/stop into the rollout's
        // tool waits; hits cost only the cache get).
        // We report the *mean* wait per call: the per-call wait distribution
        // here is sharply bimodal (ms-scale hits vs 10s-scale builds), which
        // makes the median numerically unstable; the mean preserves the
        // paper's who-wins-by-what-factor comparison (noted in
        // EXPERIMENTS.md).
        let med = |m: &tvcache::train::RunMetrics| {
            let mut s = tvcache::util::hist::Samples::new();
            for c in &m.calls {
                s.add(c.charged);
            }
            s.mean()
        };
        let no_cache = med(&uncached);
        let with_cache = med(&cached);
        let speedup = no_cache / with_cache.max(1e-9);
        rows.push(vec![
            cfg.agent_name.to_string(),
            difficulty.to_string(),
            format!("{no_cache:.2}"),
            format!("{with_cache:.2}"),
            format!("{speedup:.2}x"),
        ]);
        csv.rowf(&[
            &cfg.agent_name,
            &difficulty,
            &format!("{no_cache:.3}"),
            &format!("{with_cache:.3}"),
            &format!("{speedup:.3}"),
        ]);
    }

    print_table(
        "Table 2: median per-tool-call time (paper speedups: 6.18x / 6.92x / 3.44x / 5.55x)",
        &["model", "difficulty", "no-cache (s/call)", "tvcache (s/call)", "speedup"],
        &rows,
    );
    csv.write("results/table2_speedup.csv").unwrap();
    println!("\nrows -> results/table2_speedup.csv");
}
