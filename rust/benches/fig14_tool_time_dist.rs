//! Figure 14 (Appendix F): distribution of per-rollout total tool-call
//! times for the four terminal configurations, with and without TVCACHE
//! (tail-trimmed at p99 like the paper).
//!
//! Paper shape: the TVCACHE distribution shifts left; most of the gain
//! comes from proactive forking removing container start/stop overheads.

use tvcache::bench::print_table;
use tvcache::metrics::CsvWriter;
use tvcache::train::{run_workload, SimOptions};
use tvcache::util::hist::Samples;
use tvcache::workloads::{Workload, WorkloadConfig};

fn main() {
    let mut rows = Vec::new();
    let mut csv = CsvWriter::new(&["config", "variant", "p25", "p50", "p75", "p95"]);

    for cfg in WorkloadConfig::table1().into_iter().take(4) {
        let label = format!(
            "{}/{}",
            cfg.agent_name.replace("-Instruct", "").replace("-2507", ""),
            match cfg.workload {
                Workload::TerminalEasy => "easy",
                _ => "med",
            }
        );
        let mut opts = SimOptions::from_config(&cfg, 6, true);
        opts.epochs = 5;
        let cached = run_workload(&cfg, &opts);
        let uncached = run_workload(&cfg, &SimOptions { cached: false, ..opts });

        for (variant, m) in [("tvcache", &cached), ("no-cache", &uncached)] {
            let mut s = Samples::new();
            let p99 = {
                let mut all = Samples::new();
                for r in &m.rollouts {
                    all.add(r.tool_time);
                }
                all.percentile(99.0)
            };
            for r in &m.rollouts {
                if r.tool_time <= p99 {
                    s.add(r.tool_time); // trim the last 1% like the paper
                }
            }
            let cells: Vec<String> = [25.0, 50.0, 75.0, 95.0]
                .iter()
                .map(|&p| format!("{:.1}", s.percentile(p)))
                .collect();
            csv.rowf(&[&label, &variant, &cells[0], &cells[1], &cells[2], &cells[3]]);
            rows.push({
                let mut r = vec![label.clone(), variant.to_string()];
                r.extend(cells);
                r
            });
        }
    }

    print_table(
        "Figure 14: per-rollout tool-time distribution (s), p99-trimmed (paper: tvcache shifts left)",
        &["config", "variant", "p25", "p50", "p75", "p95"],
        &rows,
    );
    csv.write("results/fig14_tool_time_dist.csv").unwrap();
    println!("\nseries -> results/fig14_tool_time_dist.csv");
}
