//! Figure 8a: P95 cache-get latency vs offered load, 1 vs N shards — this
//! one runs against *real* TVCACHE HTTP servers with real wall-clock time.
//! Figure 8b: memory footprint of proactive forking over training steps.
//!
//! Paper shape: a single server holds P95 in the low milliseconds at 256
//! RPS but saturates by 512 RPS (P95 > 1 s); sharding sustains ~16× the
//! load at single-digit-ms P95. Memory stays ~1–2 GB (here: scaled-down
//! snapshot store bytes + RSS), with per-step spikes.

use std::sync::Arc;
use std::time::{Duration, Instant};

use tvcache::bench::print_table;
use tvcache::cache::{ToolCall, ShardRouter};
use tvcache::metrics::{rss_bytes, CsvWriter};
use tvcache::server::{lookup_body, serve};
use tvcache::util::hist::Samples;
use tvcache::util::http::HttpClient;

/// Closed-loop load generation at a target RPS for `dur`; returns get
/// latencies. `shards` servers, clients routed by task id.
fn drive(addrs: &[std::net::SocketAddr], rps: f64, dur: Duration, n_keys: usize) -> Samples {
    let router = ShardRouter::new(addrs.len());
    let n_threads = 8.min(((rps / 64.0).ceil() as usize).max(2));
    let per_thread_rps = rps / n_threads as f64;
    let lat = Arc::new(std::sync::Mutex::new(Samples::new()));
    let mut handles = Vec::new();
    for t in 0..n_threads {
        let addrs = addrs.to_vec();
        let lat = Arc::clone(&lat);
        handles.push(std::thread::spawn(move || {
            let mut clients: Vec<HttpClient> =
                addrs.iter().map(|a| HttpClient::connect(*a)).collect();
            let interval = Duration::from_secs_f64(1.0 / per_thread_rps);
            let start = Instant::now();
            let mut next = start;
            let mut i = t;
            let mut local = Samples::new();
            while start.elapsed() < dur {
                let now = Instant::now();
                if now < next {
                    std::thread::sleep(next - now);
                }
                next += interval;
                let task = format!("task-{}", i % n_keys);
                let shard = router.route(&task);
                let q = vec![ToolCall::new("bash", format!("cmd-{}", i % 7))];
                let body = lookup_body(&task, &q);
                let t0 = Instant::now();
                let _ = clients[shard].post("/get", body.as_bytes());
                local.add(t0.elapsed().as_secs_f64());
                i += n_threads;
            }
            lat.lock().unwrap().extend(&local);
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    Arc::try_unwrap(lat).unwrap().into_inner().unwrap()
}

fn main() {
    // ---- Figure 8a ----
    let mut rows = Vec::new();
    let mut csv = CsvWriter::new(&["shards", "rps", "p50_ms", "p95_ms"]);
    // This testbed has 1 core (the paper used 128); load points are scaled
    // ~32× down, preserving the saturation *shape*.
    let load_points = [8.0, 16.0, 32.0, 64.0, 128.0];
    for shards in [1usize, 4] {
        let servers: Vec<_> = (0..shards)
            .map(|_| serve("127.0.0.1:0", 2).unwrap())
            .collect();
        let addrs: Vec<_> = servers.iter().map(|(s, _)| s.addr()).collect();
        // Pre-populate 8K distinct keys spread over tasks.
        {
            let router = ShardRouter::new(shards);
            let mut clients: Vec<HttpClient> =
                addrs.iter().map(|a| HttpClient::connect(*a)).collect();
            for k in 0..1024 {
                let task = format!("task-{}", k % 256);
                let body = format!(
                    r#"{{"task":"{task}","trajectory":[{{"call":{{"tool":"bash","args":"cmd-{}","mutates":true}},"result":{{"output":"r","exec_time":1,"api_tokens":0}}}}]}}"#,
                    k % 7
                );
                let _ = clients[router.route(&task)].post("/put", body.as_bytes());
            }
        }
        for &rps in &load_points {
            let mut lat = drive(&addrs, rps, Duration::from_millis(900), 256);
            let p50 = lat.percentile(50.0) * 1e3;
            let p95 = lat.percentile(95.0) * 1e3;
            rows.push(vec![
                format!("{shards}"),
                format!("{rps:.0}"),
                format!("{p50:.2}"),
                format!("{p95:.2}"),
            ]);
            csv.rowf(&[&shards, &rps, &format!("{p50:.3}"), &format!("{p95:.3}")]);
        }
    }
    print_table(
        "Figure 8a: real cache-get latency vs load (shape: single saturates, shards sustain)",
        &["shards", "offered RPS", "p50 (ms)", "p95 (ms)"],
        &rows,
    );
    csv.write("results/fig8a_latency.csv").unwrap();

    // ---- Figure 8b ----
    use tvcache::train::{run_workload, SimOptions};
    use tvcache::workloads::{Workload, WorkloadConfig};
    let cfg = WorkloadConfig::config_for(Workload::TerminalEasy);
    let mut opts = SimOptions::from_config(&cfg, 4, true); // batch 4 × 8 rollouts
    opts.epochs = 5; // 5 steps like the paper's Figure 8b
    let rss0 = rss_bytes();
    let m = run_workload(&cfg, &opts);
    let rss1 = rss_bytes();
    println!("\nFigure 8b: proactive-forking memory (batch 4 × 8 rollouts, 5 steps)");
    println!("  process RSS {:.1} MB -> {:.1} MB", rss0 as f64 / 1e6, rss1 as f64 / 1e6);
    println!(
        "  cached sandboxes in TCGs: {} calls sampled, hit rate {:.1}%",
        m.calls.len(),
        100.0 * m.overall_hit_rate()
    );
    println!("  (paper: ~1 GB steady, 2 GB peak, 36 sandboxes cached; our snapshots are\n   in-memory state dumps, so absolute bytes are smaller by design)");
    println!("\nseries -> results/fig8a_latency.csv");
}
