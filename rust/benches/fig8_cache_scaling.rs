//! Figure 8a: cache throughput/latency scaling with shard count (§4.5).
//!
//! Two measurements, both against the *same* `ShardedCacheService` that the
//! server and the training loops use (via the `CacheBackend` trait):
//!
//! 1. **In-process throughput** — 8 closed-loop worker threads hammer the
//!    backend with a ~90/10 lookup/insert mix for shards ∈ {1, 2, 4, 8};
//!    reported as ops/sec per shard count (the paper's near-linear scaling
//!    claim, minus the HTTP stack).
//! 2. **HTTP P95 latency vs offered load** — one server process whose
//!    internal shard count varies; the paper shape: a single shard
//!    saturates first, shards sustain the load at low P95.
//!
//! Figure 8b: memory footprint of proactive forking over training steps.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use tvcache::bench::print_table;
use tvcache::cache::{CacheBackend, ShardedCacheService, ToolCall, ToolResult};
use tvcache::metrics::{rss_bytes, CsvWriter};
use tvcache::server::{lookup_body, serve_with};
use tvcache::util::hist::Samples;
use tvcache::util::http::HttpClient;

const N_TASKS: usize = 256;
const N_CMDS: usize = 7;
const DRIVE_THREADS: usize = 8;

fn call(k: usize) -> ToolCall {
    ToolCall::new("bash", format!("cmd-{k}"))
}

fn populate(backend: &dyn CacheBackend) {
    for task in 0..N_TASKS {
        for k in 0..N_CMDS {
            backend.insert(
                &format!("task-{task}"),
                &[(call(k), ToolResult::new("r", 1.0))],
            );
        }
    }
}

/// Closed-loop in-process drive: `DRIVE_THREADS` threads, ~90% lookups /
/// ~10% inserts for `dur`. Returns total ops completed.
fn drive_inprocess(backend: Arc<ShardedCacheService>, dur: Duration) -> u64 {
    let stop = Arc::new(AtomicBool::new(false));
    let ops = Arc::new(AtomicU64::new(0));
    let handles: Vec<_> = (0..DRIVE_THREADS)
        .map(|t| {
            let backend = Arc::clone(&backend);
            let stop = Arc::clone(&stop);
            let ops = Arc::clone(&ops);
            std::thread::spawn(move || {
                let mut i = t;
                let mut local = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let task = format!("task-{}", i % N_TASKS);
                    // Modulus coprime with the thread stride (8), so every
                    // worker sees the same ~89/11 get/put mix.
                    if i % 9 == 0 {
                        backend.insert(
                            &task,
                            &[
                                (call(i % N_CMDS), ToolResult::new("r", 1.0)),
                                (
                                    ToolCall::new("bash", format!("suffix-{}", i % 5)),
                                    ToolResult::new("r2", 1.0),
                                ),
                            ],
                        );
                    } else {
                        let _ = backend.lookup(&task, &[call(i % N_CMDS)]);
                    }
                    local += 1;
                    i += DRIVE_THREADS;
                }
                ops.fetch_add(local, Ordering::Relaxed);
            })
        })
        .collect();
    std::thread::sleep(dur);
    stop.store(true, Ordering::Relaxed);
    for h in handles {
        h.join().unwrap();
    }
    ops.load(Ordering::Relaxed)
}

/// Closed-loop HTTP load at a target RPS for `dur`; returns get latencies.
fn drive_http(addr: std::net::SocketAddr, rps: f64, dur: Duration) -> Samples {
    let n_threads = 8.min(((rps / 64.0).ceil() as usize).max(2));
    let per_thread_rps = rps / n_threads as f64;
    let lat = Arc::new(std::sync::Mutex::new(Samples::new()));
    let mut handles = Vec::new();
    for t in 0..n_threads {
        let lat = Arc::clone(&lat);
        handles.push(std::thread::spawn(move || {
            let mut client = HttpClient::connect(addr);
            let interval = Duration::from_secs_f64(1.0 / per_thread_rps);
            let start = Instant::now();
            let mut next = start;
            let mut i = t;
            let mut local = Samples::new();
            while start.elapsed() < dur {
                let now = Instant::now();
                if now < next {
                    std::thread::sleep(next - now);
                }
                next += interval;
                let task = format!("task-{}", i % N_TASKS);
                let body = lookup_body(&task, &[call(i % N_CMDS)]);
                let t0 = Instant::now();
                let _ = client.post("/get", body.as_bytes());
                local.add(t0.elapsed().as_secs_f64());
                i += n_threads;
            }
            lat.lock().unwrap().extend(&local);
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    Arc::try_unwrap(lat).unwrap().into_inner().unwrap()
}

fn main() {
    // ---- Figure 8a (i): in-process throughput vs shard count ----
    let mut rows = Vec::new();
    let mut csv = CsvWriter::new(&["shards", "ops_per_sec", "speedup_vs_1"]);
    let mut base = 0.0f64;
    for shards in [1usize, 2, 4, 8] {
        let backend = Arc::new(ShardedCacheService::new(shards));
        populate(backend.as_ref());
        // Warmup then measure.
        drive_inprocess(Arc::clone(&backend), Duration::from_millis(100));
        let dur = Duration::from_millis(600);
        let ops = drive_inprocess(Arc::clone(&backend), dur);
        let rate = ops as f64 / dur.as_secs_f64();
        if shards == 1 {
            base = rate;
        }
        rows.push(vec![
            format!("{shards}"),
            format!("{rate:.0}"),
            format!("{:.2}x", rate / base.max(1.0)),
        ]);
        csv.rowf(&[&shards, &format!("{rate:.0}"), &format!("{:.3}", rate / base.max(1.0))]);
    }
    print_table(
        "Figure 8a(i): in-process ShardedCacheService throughput (8 driver threads, ~90/10 get/put)",
        &["shards", "ops/sec", "speedup"],
        &rows,
    );
    csv.write("results/fig8a_shard_throughput.csv").unwrap();

    // ---- Figure 8a (ii): HTTP latency vs offered load, 1 vs 4 shards ----
    let mut rows = Vec::new();
    let mut csv = CsvWriter::new(&["shards", "rps", "p50_ms", "p95_ms"]);
    // This testbed has few cores (the paper used 128); load points are
    // scaled down, preserving the saturation *shape*.
    let load_points = [16.0, 64.0, 128.0];
    for shards in [1usize, 4] {
        let (server, svc) = serve_with("127.0.0.1:0", 4, shards).unwrap();
        {
            let mut client = HttpClient::connect(server.addr());
            for k in 0..1024 {
                let task = format!("task-{}", k % N_TASKS);
                let body = format!(
                    r#"{{"task":"{task}","trajectory":[{{"call":{{"tool":"bash","args":"cmd-{}","mutates":true}},"result":{{"output":"r","exec_time":1,"api_tokens":0}}}}]}}"#,
                    k % N_CMDS
                );
                let _ = client.post("/put", body.as_bytes());
            }
        }
        assert_eq!(svc.shard_count(), shards);
        for &rps in &load_points {
            let mut lat = drive_http(server.addr(), rps, Duration::from_millis(700));
            let p50 = lat.percentile(50.0) * 1e3;
            let p95 = lat.percentile(95.0) * 1e3;
            rows.push(vec![
                format!("{shards}"),
                format!("{rps:.0}"),
                format!("{p50:.2}"),
                format!("{p95:.2}"),
            ]);
            csv.rowf(&[&shards, &rps, &format!("{p50:.3}"), &format!("{p95:.3}")]);
        }
    }
    print_table(
        "Figure 8a(ii): HTTP cache-get latency vs load (single server, internal shards)",
        &["shards", "offered RPS", "p50 (ms)", "p95 (ms)"],
        &rows,
    );
    csv.write("results/fig8a_latency.csv").unwrap();

    // ---- Figure 8b ----
    use tvcache::train::{run_workload, SimOptions};
    use tvcache::workloads::{Workload, WorkloadConfig};
    let cfg = WorkloadConfig::config_for(Workload::TerminalEasy);
    let mut opts = SimOptions::from_config(&cfg, 4, true); // batch 4 × 8 rollouts
    opts.epochs = 5; // 5 steps like the paper's Figure 8b
    let rss0 = rss_bytes();
    let m = run_workload(&cfg, &opts);
    let rss1 = rss_bytes();
    println!("\nFigure 8b: proactive-forking memory (batch 4 × 8 rollouts, 5 steps)");
    println!("  process RSS {:.1} MB -> {:.1} MB", rss0 as f64 / 1e6, rss1 as f64 / 1e6);
    println!(
        "  cached sandboxes in TCGs: {} calls sampled, hit rate {:.1}%",
        m.calls.len(),
        100.0 * m.overall_hit_rate()
    );
    println!("  (paper: ~1 GB steady, 2 GB peak, 36 sandboxes cached; our snapshots are\n   in-memory state dumps, so absolute bytes are smaller by design)");
    println!("\nseries -> results/fig8a_shard_throughput.csv, results/fig8a_latency.csv");
}
