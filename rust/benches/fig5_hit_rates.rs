//! Figure 5: cache hit rate by post-training epoch for the three workloads
//! (and both terminal model sizes).
//!
//! Paper shape: hit rates *increase over epochs* as the TCG grows;
//! terminal 15–32%, SkyRL-SQL 27.0–57.2%, EgoSchema 34–73.9%; larger
//! models (higher competence) hit more.

use tvcache::bench::print_table;
use tvcache::metrics::CsvWriter;
use tvcache::train::{run_workload, SimOptions};
use tvcache::workloads::{Workload, WorkloadConfig};

fn main() {
    let mut csv = CsvWriter::new(&["config", "epoch", "hit_rate"]);
    let mut rows = Vec::new();

    let configs: Vec<(String, WorkloadConfig, usize)> = WorkloadConfig::table1()
        .into_iter()
        .map(|c| {
            let label = format!("{:?}/{}", c.workload, c.agent_name);
            let tasks = match c.workload {
                Workload::SkyRlSql => 16,
                _ => 8,
            };
            (label, c, tasks)
        })
        .collect();

    for (label, cfg, tasks) in configs {
        let opts = SimOptions::from_config(&cfg, tasks, true);
        let m = run_workload(&cfg, &opts);
        let first = m.epoch_hit_rates.first().unwrap().1;
        let last = m.epoch_hit_rates.last().unwrap().1;
        let avg: f64 = m.epoch_hit_rates.iter().map(|(_, h)| h).sum::<f64>()
            / m.epoch_hit_rates.len() as f64;
        for (e, h) in &m.epoch_hit_rates {
            csv.rowf(&[&label, e, &format!("{h:.4}")]);
        }
        rows.push(vec![
            label,
            format!("{:.1}%", 100.0 * first),
            format!("{:.1}%", 100.0 * last),
            format!("{:.1}%", 100.0 * avg),
            format!("{}", if last > first { "rising ✓" } else { "FLAT ✗" }),
        ]);
    }

    print_table(
        "Figure 5: hit rate by epoch (paper: terminal 15-32% | SQL 27-57% | Ego 34-74%, all rising)",
        &["config", "epoch0", "final", "avg", "trend"],
        &rows,
    );
    csv.write("results/fig5_hit_rates.csv").unwrap();
    println!("\nseries -> results/fig5_hit_rates.csv");
}
