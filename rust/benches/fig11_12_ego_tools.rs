//! Figures 11 & 12: EgoSchema per-tool execution-time distributions and
//! per-tool cache hit rates, plus the 3× API-token saving (§4.3).
//!
//! Paper shape: object_memory_querying slowest / least called;
//! load_video + preprocess fastest and highest hit rate (prompt forces them
//! first); string-arg tools (visual_qna, object_memory) lowest hit rates;
//! caption_retrieval in between (integer args).

use std::collections::BTreeMap;

use tvcache::bench::print_table;
use tvcache::metrics::CsvWriter;
use tvcache::train::{run_workload, SimOptions};
use tvcache::util::hist::Samples;
use tvcache::workloads::{Workload, WorkloadConfig};

fn main() {
    let cfg = WorkloadConfig::config_for(Workload::EgoSchema);
    let mut opts = SimOptions::from_config(&cfg, 20, true);
    opts.epochs = 5;
    let m = run_workload(&cfg, &opts);

    struct ToolStats {
        times: Samples,
        hits: u64,
        calls: u64,
    }
    let mut per_tool: BTreeMap<String, ToolStats> = BTreeMap::new();
    for c in &m.calls {
        let e = per_tool
            .entry(c.tool.clone())
            .or_insert_with(|| ToolStats { times: Samples::new(), hits: 0, calls: 0 });
        if c.hit {
            e.hits += 1;
        } else {
            e.times.add(c.charged); // execution-time distribution = misses
        }
        e.calls += 1;
    }

    let mut rows = Vec::new();
    let mut csv = CsvWriter::new(&["tool", "calls", "hit_rate", "p50_exec_s", "p95_exec_s"]);
    for (tool, st) in per_tool.iter_mut() {
        let hr = st.hits as f64 / st.calls as f64;
        let p50 = st.times.percentile(50.0);
        let p95 = st.times.percentile(95.0);
        rows.push(vec![
            tool.clone(),
            format!("{}", st.calls),
            format!("{:.1}%", 100.0 * hr),
            format!("{p50:.2}"),
            format!("{p95:.2}"),
        ]);
        csv.rowf(&[tool, &st.calls, &format!("{hr:.4}"), &format!("{p50:.3}"), &format!("{p95:.3}")]);
    }
    print_table(
        "Figures 11+12: EgoSchema per-tool exec times and hit rates",
        &["tool", "calls", "hit_rate", "p50 exec (s)", "p95 exec (s)"],
        &rows,
    );
    csv.write("results/fig11_12_ego_tools.csv").unwrap();

    let spent = m.api_tokens_spent.max(1);
    let total = m.api_tokens_spent + m.api_tokens_saved;
    println!(
        "\nAPI tokens: would-be {total}, actually spent {spent} => {:.1}x reduction (paper: 3x)",
        total as f64 / spent as f64
    );

    // Shape assertions (the paper's qualitative claims).
    let hr = |t: &str| {
        per_tool.get(t).map(|s| s.hits as f64 / s.calls as f64).unwrap_or(0.0)
    };
    assert!(hr("load_video") > hr("visual_question_answering"), "Fig 12 ordering");
    assert!(hr("caption_retrieval") > hr("object_memory_querying"), "Fig 12 ordering");
    println!("shape checks passed ✓  (series -> results/fig11_12_ego_tools.csv)");
}
