//! Figure 9 (extension): warm-start persistence across training runs.
//!
//! The TCG is "reused across post-training iterations" (§3.1) — but only
//! within one process lifetime unless the cache persists. This bench runs
//! the concurrent driver cold (persisting TCGs + snapshot payloads on
//! exit), then launches a *fresh* run that warm-starts from the persisted
//! directory. The acceptance shape: the warm run's epoch-0 hit rate is at
//! least the cold run's final-epoch hit rate — the new run skips the
//! cold-start miss penalty entirely, compounding the cache's savings
//! across training phases (CacheRL, arXiv 2606.14179).

use tvcache::bench::print_table;
use tvcache::metrics::CsvWriter;
use tvcache::train::{run_concurrent, ConcurrentOptions};
use tvcache::workloads::{Workload, WorkloadConfig};

const N_TASKS: usize = 6;
const COLD_EPOCHS: usize = 4;
const WARM_EPOCHS: usize = 2;

fn main() {
    let cfg = WorkloadConfig::config_for(Workload::TerminalEasy);
    let dir = std::env::temp_dir()
        .join(format!("tvcache-fig9-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let dir_s = dir.to_string_lossy().into_owned();

    // Cold run: empty cache, spill tier + byte budget active, persist at
    // the end.
    let mut cold = ConcurrentOptions::from_config(&cfg, N_TASKS);
    cold.epochs = COLD_EPOCHS;
    cold.shard_byte_budget = Some(64 * 1024);
    cold.spill_dir = Some(dir_s.clone());
    cold.persist_to = Some(dir_s.clone());
    let cold_report = run_concurrent(&cfg, &cold);

    // Warm run: a fresh service (fresh process in production) reloads the
    // persisted TCGs + spilled snapshots before epoch 0.
    let mut warm = ConcurrentOptions::from_config(&cfg, N_TASKS);
    warm.epochs = WARM_EPOCHS;
    warm.warm_start_from = Some(dir_s);
    let warm_report = run_concurrent(&cfg, &warm);

    let mut rows = Vec::new();
    let mut csv = CsvWriter::new(&["run", "epoch", "hit_rate"]);
    for (epoch, rate) in &cold_report.epoch_hit_rates {
        rows.push(vec!["cold".into(), format!("{epoch}"), format!("{:.3}", rate)]);
        csv.rowf(&[&"cold", epoch, &format!("{rate:.4}")]);
    }
    for (epoch, rate) in &warm_report.epoch_hit_rates {
        rows.push(vec!["warm".into(), format!("{epoch}"), format!("{:.3}", rate)]);
        csv.rowf(&[&"warm", epoch, &format!("{rate:.4}")]);
    }
    print_table(
        "Figure 9 (ext): warm-start — epoch hit rates, cold run vs warm-started run",
        &["run", "epoch", "hit rate"],
        &rows,
    );
    csv.write("results/fig9_warm_start.csv").unwrap();

    let cold_final = cold_report.epoch_hit_rates.last().unwrap().1;
    let warm_first = warm_report.epoch_hit_rates[0].1;
    println!(
        "\ncold final-epoch hit rate : {:.3}\nwarm epoch-0 hit rate     : {:.3}",
        cold_final, warm_first
    );
    assert!(
        warm_first >= cold_final,
        "warm-start failed: epoch-0 {warm_first:.3} < cold final {cold_final:.3}"
    );
    println!("warm-start OK: a new run opens at (or above) the cold run's converged rate");
    println!("series -> results/fig9_warm_start.csv");

    let _ = std::fs::remove_dir_all(&dir);
}
