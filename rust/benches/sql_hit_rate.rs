//! §4.2 numbers: SkyRL-SQL hit rate (paper avg 33.11%), per-hit speedup
//! (56.6 ms → 6.5 ms ≈ 8.7×), and the derived expected tool-call speedup
//! (≈2.9×).

use tvcache::bench::print_table;
use tvcache::metrics::CsvWriter;
use tvcache::train::{run_workload, SimOptions};
use tvcache::util::hist::Samples;
use tvcache::workloads::{Workload, WorkloadConfig};

fn main() {
    let cfg = WorkloadConfig::config_for(Workload::SkyRlSql);
    let opts = SimOptions::from_config(&cfg, 32, true);
    let m = run_workload(&cfg, &opts);

    let mut hit_ms = Samples::new();
    let mut miss_ms = Samples::new();
    for c in &m.calls {
        if c.hit {
            hit_ms.add(c.charged * 1e3);
        } else {
            miss_ms.add(c.charged * 1e3);
        }
    }
    let hr = m.overall_hit_rate();
    let per_hit = miss_ms.mean() / hit_ms.mean().max(1e-9);
    let expected = 1.0 / (1.0 - hr + hr * hit_ms.mean() / miss_ms.mean().max(1e-9));

    print_table(
        "§4.2: SkyRL-SQL summary",
        &["metric", "measured", "paper"],
        &[
            vec!["avg hit rate".into(), format!("{:.2}%", 100.0 * hr), "33.11%".into()],
            vec!["tool exec (miss)".into(), format!("{:.1} ms", miss_ms.mean()), "56.6 ms".into()],
            vec!["tool exec (hit)".into(), format!("{:.1} ms", hit_ms.mean()), "6.5 ms".into()],
            vec!["per-hit speedup".into(), format!("{per_hit:.1}x"), "8.7x".into()],
            vec!["expected call speedup".into(), format!("{expected:.1}x"), "2.9x".into()],
        ],
    );

    let mut csv = CsvWriter::new(&["metric", "value"]);
    csv.rowf(&[&"hit_rate", &format!("{hr:.4}")]);
    csv.rowf(&[&"miss_ms", &format!("{:.2}", miss_ms.mean())]);
    csv.rowf(&[&"hit_ms", &format!("{:.2}", hit_ms.mean())]);
    csv.rowf(&[&"per_hit_speedup", &format!("{per_hit:.2}")]);
    csv.rowf(&[&"expected_speedup", &format!("{expected:.2}")]);
    csv.write("results/sql_hit_rate.csv").unwrap();
    println!("\nrows -> results/sql_hit_rate.csv");

    assert!(hr > 0.15 && hr < 0.75, "hit rate out of plausible band: {hr}");
    assert!(per_hit > 3.0, "hits must be much cheaper than misses");
}
