//! Figure 7: total time of each rollout (a) and batch (b) with and without
//! TVCACHE on the EgoSchema workload, sorted by cached-run time.
//!
//! Paper shape: TVCACHE consistently below the baseline for rollouts; batch
//! savings smaller than rollout savings (batch time = slowest rollout).

use tvcache::bench::print_table;
use tvcache::metrics::CsvWriter;
use tvcache::train::{run_concurrent, run_workload, ConcurrentOptions, SimOptions};
use tvcache::workloads::{Workload, WorkloadConfig};

fn main() {
    let cfg = WorkloadConfig::config_for(Workload::EgoSchema);
    let opts = SimOptions::from_config(&cfg, 10, true);
    let cached = run_workload(&cfg, &opts);
    let uncached = run_workload(&cfg, &SimOptions { cached: false, ..opts });

    // Rollouts are generated with identical seeds, so pair them 1:1.
    let mut pairs: Vec<(f64, f64)> = cached
        .rollouts
        .iter()
        .zip(&uncached.rollouts)
        .map(|(c, u)| (c.total(), u.total()))
        .collect();
    pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut csv = CsvWriter::new(&["rank", "rollout_tvcache", "rollout_no_cache"]);
    for (i, (c, u)) in pairs.iter().enumerate() {
        csv.rowf(&[&i, &format!("{c:.2}"), &format!("{u:.2}")]);
    }
    csv.write("results/fig7a_rollout_times.csv").unwrap();

    let mut bpairs: Vec<(f64, f64)> = cached
        .batches
        .iter()
        .zip(&uncached.batches)
        .map(|(c, u)| (c.batch_time, u.batch_time))
        .collect();
    bpairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut bcsv = CsvWriter::new(&["rank", "batch_tvcache", "batch_no_cache"]);
    for (i, (c, u)) in bpairs.iter().enumerate() {
        bcsv.rowf(&[&i, &format!("{c:.2}"), &format!("{u:.2}")]);
    }
    bcsv.write("results/fig7b_batch_times.csv").unwrap();

    let frac_faster =
        pairs.iter().filter(|(c, u)| c <= u).count() as f64 / pairs.len() as f64;
    let mean = |xs: &[(f64, f64)], i: usize| -> f64 {
        xs.iter().map(|p| if i == 0 { p.0 } else { p.1 }).sum::<f64>() / xs.len() as f64
    };
    let rollout_saving = 1.0 - mean(&pairs, 0) / mean(&pairs, 1);
    let batch_saving = 1.0 - mean(&bpairs, 0) / mean(&bpairs, 1);

    print_table(
        "Figure 7: rollout & batch times, EgoSchema (paper: consistent reduction; batch < rollout savings)",
        &["metric", "tvcache_mean", "no_cache_mean", "saving"],
        &[
            vec![
                "rollout total (s)".into(),
                format!("{:.1}", mean(&pairs, 0)),
                format!("{:.1}", mean(&pairs, 1)),
                format!("{:.1}%", 100.0 * rollout_saving),
            ],
            vec![
                "batch total (s)".into(),
                format!("{:.1}", mean(&bpairs, 0)),
                format!("{:.1}", mean(&bpairs, 1)),
                format!("{:.1}%", 100.0 * batch_saving),
            ],
        ],
    );
    println!("\nrollouts faster-or-equal with cache: {:.0}%", frac_faster * 100.0);
    println!("series -> results/fig7a_rollout_times.csv, results/fig7b_batch_times.csv");
    assert!(batch_saving <= rollout_saving + 0.05, "paper shape: batch savings <= rollout savings");

    // B·R rollouts on real threads against the sharded backend: the same
    // workload the DES simulates, but measuring wall-clock service
    // throughput (the §4.5 concurrency regime the batch numbers assume).
    let mut copts = ConcurrentOptions::from_config(&cfg, 10);
    copts.epochs = 3;
    let report = run_concurrent(&cfg, &copts);
    println!(
        "\nconcurrent driver: {} rollouts ({} threads, {} shards) in {:.2}s wall — \
         {:.0} calls/s, hit rate {:.1}%",
        report.rollouts_run,
        copts.threads,
        copts.shards,
        report.wall_secs,
        report.calls_per_sec(),
        100.0 * report.overall_hit_rate()
    );
}
