//! Figure 13 (Appendix E): container creation rate as a function of total
//! concurrent forks, under the four manager configurations.
//!
//! Paper shape to hold: baseline < + precreated networks < + selective
//! allocation ≤ tvcache (rate-limited), with the baseline degrading and the
//! rate-limited config sustaining throughput out to ~640 forks.

use tvcache::bench::print_table;
use tvcache::metrics::CsvWriter;
use tvcache::sandbox::{ContainerManager, ContainerParams, ManagerConfig};

fn main() {
    let configs = [
        ("terminal-bench (baseline)", ManagerConfig::Baseline),
        ("+ precreate networks", ManagerConfig::PrecreateNetworks),
        ("+ selective allocation", ManagerConfig::SelectiveNetworks),
        ("tvcache (rate-limited)", ManagerConfig::RateLimited),
    ];
    let fork_counts = [16usize, 32, 64, 128, 256, 512, 640];

    let mut csv = CsvWriter::new(&["config", "forks", "rate_per_s", "failed"]);
    let mut rows = Vec::new();
    for (name, cfg) in configs {
        let mut cells = vec![name.to_string()];
        for &n in &fork_counts {
            let mut mgr = ContainerManager::new(cfg, ContainerParams::default(), 42);
            let r = mgr.fork_batch(n);
            cells.push(format!("{:.1}{}", r.rate, if r.failed > 0 { "!" } else { "" }));
            csv.rowf(&[&name, &n, &format!("{:.2}", r.rate), &r.failed]);
        }
        rows.push(cells);
    }

    let mut header = vec!["config"];
    let labels: Vec<String> = fork_counts.iter().map(|n| format!("{n} forks")).collect();
    header.extend(labels.iter().map(|s| s.as_str()));
    print_table(
        "Figure 13: container creation rate (creations/s; '!' = failures observed)",
        &header,
        &rows,
    );
    csv.write("results/fig13_container_scaling.csv").unwrap();
    println!("\nseries -> results/fig13_container_scaling.csv");
}
