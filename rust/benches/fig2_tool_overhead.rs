//! Figure 2: per-rollout wall-clock split between reasoning-token
//! generation and tool-call execution, for the three workloads (no cache).
//!
//! Paper shape to reproduce: tool execution is 7–43% of rollout time on
//! average (terminal ≈43%, SQL ≈7%, EgoSchema ≈12%), with tails where tool
//! time exceeds 90% of the rollout.

use tvcache::bench::print_table;
use tvcache::metrics::CsvWriter;
use tvcache::train::{run_workload, SimOptions};
use tvcache::util::hist::Samples;
use tvcache::workloads::{Workload, WorkloadConfig};

fn main() {
    let mut rows = Vec::new();
    let mut csv = CsvWriter::new(&["workload", "rollout", "gen_time", "tool_time", "tool_frac"]);

    for (name, wl, tasks) in [
        ("terminal-bench", Workload::TerminalEasy, 10),
        ("SkyRL-SQL", Workload::SkyRlSql, 16),
        ("EgoSchema", Workload::EgoSchema, 10),
    ] {
        let cfg = WorkloadConfig::config_for(wl);
        let mut opts = SimOptions::from_config(&cfg, tasks, false); // no cache
        opts.epochs = 2;
        let m = run_workload(&cfg, &opts);

        let mut fracs = Samples::new();
        for r in &m.rollouts {
            let frac = r.tool_time / r.total().max(1e-9);
            fracs.add(frac);
            csv.rowf(&[
                &name,
                &format!("{}-{}-{}", r.task, r.epoch, r.rollout),
                &format!("{:.2}", r.gen_time),
                &format!("{:.2}", r.tool_time),
                &format!("{frac:.4}"),
            ]);
        }
        let mean = fracs.mean();
        let p99 = fracs.percentile(99.0);
        rows.push(vec![
            name.to_string(),
            format!("{}", m.rollouts.len()),
            format!("{:.1}%", 100.0 * mean),
            format!("{:.1}%", 100.0 * fracs.percentile(95.0)),
            format!("{:.1}%", 100.0 * p99),
        ]);
    }

    print_table(
        "Figure 2: tool-execution share of rollout time (no cache); paper: 7-43% mean, >90% tail",
        &["workload", "rollouts", "mean_tool%", "p95_tool%", "p99_tool%"],
        &rows,
    );
    csv.write("results/fig2_tool_overhead.csv").unwrap();
    println!("\nseries -> results/fig2_tool_overhead.csv");
}
