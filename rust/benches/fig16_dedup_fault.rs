//! Figure 16 (extension): content-addressed payload dedup + the spill-tier
//! fault cache.
//!
//! Post-training fleets run *many rollouts of the same task family*: K
//! concurrent tasks re-derive the same sandbox states, so a naive snapshot
//! store holds O(K × states) payload bytes. The content-addressed payload
//! tier (`cache/payload.rs`) keys every payload by a strong content hash
//! and refcounts it across tasks and shards, collapsing that footprint to
//! O(distinct states). Below it, a byte-budgeted LRU fault cache absorbs
//! repeat fault-ins of hot spilled payloads so only the *first* fault pays
//! a disk read.
//!
//! Three sections, all exact-accounting (no timing asserts):
//!
//! 1. **Dedup scaling**: K = 6 tasks each snapshot the same tree of
//!    distinct sandbox states. Asserted: total resident bytes with all K
//!    tasks stay within 1.5× the single-task footprint (they are in fact
//!    identical — bytes are O(distinct states), not O(K × states)).
//! 2. **Fault cache**: spill a set of payloads, fault the same one in
//!    twice. Asserted: the repeat fetch is served from the fault cache
//!    with *exactly one* disk read across both fetches.
//! 3. **HTTP parity**: the same dedup counters are visible through the
//!    binary-protocol HTTP backend (`/stats` + the negotiated
//!    `payload_dedup` capability bit), not just in-process.
//!
//! `TVCACHE_BENCH_SMOKE=1` shrinks payload sizes for CI. Results are
//! appended as one JSON line to `BENCH_5.json` (override with
//! `TVCACHE_BENCH_OUT`).

use std::io::Write as _;
use std::path::PathBuf;
use std::sync::Arc;

use tvcache::bench::print_table;
use tvcache::cache::{
    CacheBackend, ServiceConfig, SessionBackend, ShardedCacheService, TaskCache, ToolCall,
    ToolResult,
};
use tvcache::client::RemoteBinding;
use tvcache::metrics::CsvWriter;
use tvcache::sandbox::SandboxSnapshot;

/// Concurrent tasks sharing one state tree in the dedup section.
const K_TASKS: usize = 6;
/// Distinct sandbox states per task.
const STATES: usize = 24;
/// Payloads spilled in the fault-cache section.
const SPILLED: usize = 8;

fn call(s: String) -> ToolCall {
    ToolCall::new("bash", s)
}

/// Deterministic, pairwise-distinct payload for state `s`.
fn payload(s: usize, size: usize) -> Vec<u8> {
    (0..size).map(|i| ((i as u64 * 31 + s as u64 * 131) % 251) as u8).collect()
}

fn snap(s: usize, size: usize) -> SandboxSnapshot {
    SandboxSnapshot { bytes: payload(s, size), serialize_cost: 0.1, restore_cost: 0.2 }
}

/// Snapshot every state of the shared tree under `task`.
fn store_states(svc: &ShardedCacheService, task: &str, size: usize) -> Vec<u64> {
    (0..STATES)
        .map(|s| {
            let traj =
                vec![(call(format!("derive state-{s}")), ToolResult::new("ok", 1.0))];
            let node = svc.insert(task, &traj).expect("in-process insert cannot fail");
            let id = svc.store_snapshot(task, node, snap(s, size));
            assert!(id > 0, "store of state {s} for {task} rejected");
            id
        })
        .collect()
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("tvcache-fig16-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn main() {
    let smoke = std::env::var("TVCACHE_BENCH_SMOKE").is_ok();
    let size: usize = if smoke { 4 * 1024 } else { 64 * 1024 };

    // ── 1. Dedup scaling: K tasks over one state tree ───────────────────
    let svc = ShardedCacheService::with_config(
        ServiceConfig { shards: 4, ..Default::default() },
        Arc::new(TaskCache::with_defaults),
    )
    .unwrap();
    store_states(&svc, "task-0", size);
    let bytes_single = svc.resident_bytes();
    for k in 1..K_TASKS {
        store_states(&svc, &format!("task-{k}"), size);
    }
    let bytes_k = svc.resident_bytes();
    let dedup_stats = svc.service_stats();
    let naive_k = bytes_single * K_TASKS as u64;
    let scale_ratio = bytes_k as f64 / bytes_single as f64;

    // ── 2. Fault cache: repeat fault-ins of one spilled payload ─────────
    let dir = tmpdir("spill");
    let fsvc = ShardedCacheService::with_config(
        ServiceConfig {
            shards: 1,
            shard_byte_budget: Some(10), // far below one payload: spill all
            spill_dir: Some(dir.clone()),
            background: false,
            // Room for half the spilled set, so the full sweep below also
            // exercises LRU eviction.
            fault_cache_bytes: (size * SPILLED / 2) as u64,
            ..Default::default()
        },
        Arc::new(TaskCache::with_defaults),
    )
    .unwrap();
    let ids = store_states(&fsvc, "spiller", size);
    fsvc.drain_over_budget();
    let s0 = fsvc.service_stats();
    assert_eq!(s0.spilled_snapshots, STATES, "budget 10 must spill everything");

    // First fault-in: one disk read, cached on the way through.
    assert!(fsvc.fetch_snapshot("spiller", ids[0]).is_some(), "fault-in failed");
    let s1 = fsvc.service_stats();
    // Repeat fault-in of the same payload: served from the fault cache.
    assert!(fsvc.fetch_snapshot("spiller", ids[0]).is_some(), "repeat fetch failed");
    let s2 = fsvc.service_stats();

    let disk_reads_first = s1.spill_faults - s0.spill_faults;
    let disk_reads_repeat = s2.spill_faults - s1.spill_faults;
    let cache_hits_repeat = s2.fault_cache_hits - s1.fault_cache_hits;

    // A sweep over more payloads than the cache budget holds: the LRU must
    // evict rather than grow.
    for &id in ids.iter().take(SPILLED) {
        assert!(fsvc.fetch_snapshot("spiller", id).is_some());
    }
    let s3 = fsvc.service_stats();

    // ── 3. HTTP parity: counters + capability bit over the wire ────────
    let (server, _svc) = tvcache::server::serve_with("127.0.0.1:0", 2, 4).unwrap();
    let remote = RemoteBinding::connect(server.addr());
    for t in 0..3 {
        let task = format!("twin-{t}");
        let traj = vec![(call("make".into()), ToolResult::new("ok", 1.0))];
        let node = remote.insert(&task, &traj).expect("insert over live server");
        assert!(remote.store_snapshot(&task, node, snap(0, size)) > 0);
    }
    let http_stats = remote.service_stats();
    let http_caps = remote.capabilities();
    drop(server);

    // ── Report ──────────────────────────────────────────────────────────
    let rows = vec![
        vec!["resident bytes, 1 task".into(), format!("{bytes_single}")],
        vec![format!("resident bytes, {K_TASKS} tasks"), format!("{bytes_k}")],
        vec!["naive (no dedup) bytes".into(), format!("{naive_k}")],
        vec!["scale ratio K/1".into(), format!("{scale_ratio:.2}")],
        vec!["dedup hits".into(), format!("{}", dedup_stats.dedup_hits)],
        vec![
            "resident bytes saved".into(),
            format!("{}", dedup_stats.dedup_resident_bytes_saved),
        ],
        vec!["disk reads, first fault".into(), format!("{disk_reads_first}")],
        vec!["disk reads, repeat fault".into(), format!("{disk_reads_repeat}")],
        vec!["fault-cache hits, repeat".into(), format!("{cache_hits_repeat}")],
        vec!["fault-cache evictions, sweep".into(), format!("{}", s3.fault_cache_evictions)],
        vec!["dedup hits over HTTP".into(), format!("{}", http_stats.dedup_hits)],
    ];
    print_table(
        "Figure 16 (ext): payload dedup across tasks + spill-tier fault cache",
        &["metric", "value"],
        &rows,
    );
    let mut csv = CsvWriter::new(&["metric", "value"]);
    for r in &rows {
        csv.rowf(&[&r[0], &r[1]]);
    }
    csv.write("results/fig16_dedup_fault.csv").unwrap();
    println!("series -> results/fig16_dedup_fault.csv");

    // Machine-readable perf trajectory for future PRs.
    let out = std::env::var("TVCACHE_BENCH_OUT").unwrap_or_else(|_| "../BENCH_5.json".into());
    let line = format!(
        "{{\"bench\":\"fig16_dedup_fault\",\"mode\":\"{}\",\
         \"k_tasks\":{K_TASKS},\"distinct_states\":{STATES},\"payload_bytes\":{size},\
         \"bytes_single_task\":{bytes_single},\"bytes_k_tasks\":{bytes_k},\
         \"scale_ratio\":{scale_ratio:.3},\
         \"dedup_hits\":{},\"dedup_resident_bytes_saved\":{},\
         \"disk_reads_first_fault\":{disk_reads_first},\
         \"disk_reads_repeat_fault\":{disk_reads_repeat},\
         \"fault_cache_hits_repeat\":{cache_hits_repeat},\
         \"fault_cache_evictions_sweep\":{},\
         \"http_dedup_hits\":{}}}",
        if smoke { "smoke" } else { "full" },
        dedup_stats.dedup_hits,
        dedup_stats.dedup_resident_bytes_saved,
        s3.fault_cache_evictions,
        http_stats.dedup_hits,
    );
    match std::fs::OpenOptions::new().create(true).append(true).open(&out) {
        Ok(mut f) => {
            let _ = writeln!(f, "{line}");
            println!("appended -> {out}");
        }
        Err(e) => println!("could not append to {out}: {e}"),
    }

    // Acceptance (a): resident bytes are O(distinct states), not
    // O(tasks × states) — K tasks stay within 1.5× one task.
    assert!(
        scale_ratio <= 1.5,
        "dedup failed: {K_TASKS} tasks hold {scale_ratio:.2}x one task's bytes (limit 1.5x)"
    );
    assert_eq!(
        dedup_stats.dedup_hits,
        ((K_TASKS - 1) * STATES) as u64,
        "every repeat store must dedup"
    );
    assert_eq!(
        dedup_stats.dedup_resident_bytes_saved,
        ((K_TASKS - 1) * STATES * size) as u64,
        "bytes-saved gauge must count every shared referent"
    );
    // Acceptance (b): the repeat fault-in is served from the cache with
    // exactly one disk read across both fetches.
    assert_eq!(disk_reads_first, 1, "first fault-in must read the disk once");
    assert_eq!(disk_reads_repeat, 0, "repeat fault-in must not touch the disk");
    assert_eq!(cache_hits_repeat, 1, "repeat fault-in must hit the fault cache");
    assert!(
        s3.fault_cache_evictions > 0,
        "sweeping past the cache budget must evict, not grow"
    );
    // Acceptance (c): dedup visible on BOTH backends.
    assert!(dedup_stats.dedup_hits > 0, "in-process dedup_hits must be visible");
    assert_eq!(http_stats.dedup_hits, 2, "HTTP /stats must carry dedup_hits");
    assert!(http_caps.payload_dedup, "handshake must advertise payload_dedup");

    println!(
        "fig16 OK: {K_TASKS} tasks share one {STATES}-state tree at 1.0x bytes; \
         repeat fault-ins skip the disk"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
