//! Failover figure (extension): op-log replication keeps the hit rate
//! through a primary outage.
//!
//! Since PR 6 the circuit breaker answers a dead primary with "bypass the
//! cache" — correct, but every rollout pays cold-path tool latency for the
//! rest of the run. This PR's replication stack keeps a warm follower
//! tailing the primary's op-log; when the primary dies, the binding
//! promotes the follower (epoch-fenced against the revived original) and
//! the fleet keeps hitting.
//!
//! Three measured sections, exact accounting plus wall-clock:
//!
//! 1. **No-fault reference**: warm epoch + measured epoch against one
//!    healthy primary — the hit count every other section is judged by.
//! 2. **Replication lag**: a concurrent epoch runs against the primary
//!    while a follower tails it; measures how long the follower takes to
//!    serve the log's newest entry after the epoch ends.
//! 3. **Kill-primary failover**: the primary dies, the next epoch's
//!    rollouts trip the breaker, promote the follower mid-run, re-seed
//!    their sessions, and finish. Asserted: rewards bit-identical to the
//!    reference, exactly one failover, promotion bumped the epoch, and the
//!    post-failover hit count holds ≥ 80% of the no-fault run's.
//!
//! Results are appended as one JSON line to `BENCH_8.json` (override with
//! `TVCACHE_BENCH_OUT`).

use std::io::Write as _;
use std::sync::Arc;
use std::time::{Duration, Instant};

use tvcache::bench::print_table;
use tvcache::cache::{
    CacheBackend, ServiceConfig, SessionBackend, ShardedCacheService, TaskCache, ToolCall,
    ToolResult,
};
use tvcache::client::{BindingConfig, RemoteBinding};
use tvcache::metrics::CsvWriter;
use tvcache::server::{serve_follower, serve_service};
use tvcache::train::{run_concurrent_on, ConcurrentOptions};
use tvcache::workloads::{Workload, WorkloadConfig};

fn replicated_svc() -> ShardedCacheService {
    ShardedCacheService::with_config(
        ServiceConfig { shards: 2, replicate_window: Some(1 << 16), ..Default::default() },
        Arc::new(TaskCache::with_defaults),
    )
    .unwrap()
}

fn binding_cfg(follower: Option<std::net::SocketAddr>) -> BindingConfig {
    BindingConfig {
        connect_timeout: Duration::from_millis(500),
        read_timeout: Duration::from_secs(2),
        retries: 0,
        backoff_base: Duration::from_millis(1),
        backoff_max: Duration::from_millis(4),
        // Above the thread count, so stale in-flight dials against the
        // dead endpoint cannot re-trip the breaker post-failover.
        breaker_threshold: 6,
        breaker_cooldown: Duration::from_millis(200),
        seed: 0x8EED,
        probe_cooldown: Duration::ZERO,
        endpoints: follower.into_iter().collect(),
    }
}

fn main() {
    let smoke = std::env::var("TVCACHE_BENCH_SMOKE").is_ok();
    let cfg = WorkloadConfig::config_for(Workload::TerminalEasy);
    let mut opts = ConcurrentOptions::from_config(&cfg, 3);
    opts.epochs = 1;
    opts.threads = 4;

    // ── 1. No-fault reference: warm + measured epoch, one primary ───────
    let (ref_server, _ref_svc) = serve_service("127.0.0.1:0", 4, replicated_svc()).unwrap();
    let ref_binding = Arc::new(RemoteBinding::connect_with(ref_server.addr(), binding_cfg(None)));
    let _warm = run_concurrent_on(&cfg, &opts, Arc::clone(&ref_binding) as Arc<dyn SessionBackend>);
    let nofault =
        run_concurrent_on(&cfg, &opts, Arc::clone(&ref_binding) as Arc<dyn SessionBackend>);
    assert!(nofault.hits > 0, "reference run must be warm");
    drop(ref_server);

    // ── 2. Replicated pair: warm epoch + follower catch-up lag ──────────
    let (p_server, _p_svc) = serve_service("127.0.0.1:0", 4, replicated_svc()).unwrap();
    let (f_server, f_svc) =
        serve_follower("127.0.0.1:0", 4, replicated_svc(), p_server.addr()).unwrap();
    let binding = Arc::new(RemoteBinding::connect_with(
        p_server.addr(),
        binding_cfg(Some(f_server.addr())),
    ));
    let warm = run_concurrent_on(&cfg, &opts, Arc::clone(&binding) as Arc<dyn SessionBackend>);
    assert_eq!(warm.rewards, nofault.rewards, "warm epoch changed rewards");

    // The sentinel is the newest op in the log: the moment the follower
    // serves it, everything the epoch wrote has been replicated.
    let sentinel = vec![(ToolCall::new("bash", "sentinel"), ToolResult::new("ok", 1.0))];
    binding.insert("failover-sentinel", &sentinel).expect("sentinel insert");
    let probe = RemoteBinding::connect_with(f_server.addr(), binding_cfg(None));
    let t_catchup = Instant::now();
    let deadline = t_catchup + Duration::from_secs(10);
    while !probe.lookup("failover-sentinel", &[sentinel[0].0.clone()]).is_hit() {
        assert!(Instant::now() < deadline, "follower never caught up");
        std::thread::sleep(Duration::from_millis(2));
    }
    let catchup_ms = t_catchup.elapsed().as_secs_f64() * 1e3;
    let lag_at_catchup = f_svc.replica_lag_ops();
    assert_eq!(lag_at_catchup, 0, "caught-up follower must report zero lag");
    let epoch_before = f_svc.epoch();

    // ── 3. Kill the primary; the next epoch fails over mid-run ──────────
    drop(p_server);
    let t_run = Instant::now();
    let failed_over =
        run_concurrent_on(&cfg, &opts, Arc::clone(&binding) as Arc<dyn SessionBackend>);
    let failover_run_ms = t_run.elapsed().as_secs_f64() * 1e3;

    assert_eq!(failed_over.rewards, nofault.rewards, "failover changed rollout rewards");
    assert_eq!(binding.failovers(), 1, "exactly one promote-and-switch");
    assert!(!f_svc.is_follower(), "follower must have been promoted");
    let epoch_after = f_svc.epoch();
    assert!(epoch_after > epoch_before, "promotion must bump the fencing epoch");
    let retention = failed_over.hits as f64 / nofault.hits as f64;
    let stats = binding.service_stats();

    // ── Report ──────────────────────────────────────────────────────────
    let rows = vec![
        vec!["no-fault hits".into(), format!("{}", nofault.hits)],
        vec!["post-failover hits".into(), format!("{}", failed_over.hits)],
        vec!["hit retention".into(), format!("{retention:.3}")],
        vec!["follower catch-up (ms)".into(), format!("{catchup_ms:.1}")],
        vec!["replica lag at catch-up (ops)".into(), format!("{lag_at_catchup}")],
        vec!["failovers".into(), format!("{}", stats.failovers)],
        vec!["epoch before -> after".into(), format!("{epoch_before} -> {epoch_after}")],
        vec!["failed-over epoch wall (ms)".into(), format!("{failover_run_ms:.1}")],
    ];
    print_table(
        "Failover (ext): hit retention through a kill-primary outage",
        &["metric", "value"],
        &rows,
    );
    let mut csv = CsvWriter::new(&["metric", "value"]);
    for r in &rows {
        csv.rowf(&[&r[0], &r[1]]);
    }
    csv.write("results/fig_failover.csv").unwrap();
    println!("series -> results/fig_failover.csv");

    // Machine-readable perf trajectory for future PRs.
    let out = std::env::var("TVCACHE_BENCH_OUT").unwrap_or_else(|_| "../BENCH_8.json".into());
    let line = format!(
        "{{\"bench\":\"fig_failover\",\"mode\":\"{}\",\
         \"nofault_hits\":{},\"failover_hits\":{},\"hit_retention\":{retention:.4},\
         \"catchup_ms\":{catchup_ms:.2},\"replica_lag_at_catchup\":{lag_at_catchup},\
         \"failovers\":{},\"epoch_rejects\":{},\
         \"epoch_before\":{epoch_before},\"epoch_after\":{epoch_after},\
         \"failover_run_ms\":{failover_run_ms:.1}}}",
        if smoke { "smoke" } else { "full" },
        nofault.hits,
        failed_over.hits,
        stats.failovers,
        stats.epoch_rejects,
    );
    match std::fs::OpenOptions::new().create(true).append(true).open(&out) {
        Ok(mut f) => {
            let _ = writeln!(f, "{line}");
            println!("appended -> {out}");
        }
        Err(e) => println!("could not append to {out}: {e}"),
    }

    // Acceptance: rewards bit-identical (asserted above), exactly one
    // failover, epoch bumped, and the hit rate survives the outage.
    assert!(
        retention >= 0.8,
        "post-failover hit rate must hold >= 80% of no-fault: {retention:.3}"
    );
    println!(
        "fig_failover OK: primary death cost {:.0}% of the hit rate (>= 80% retained), \
         one failover, epoch {epoch_before} -> {epoch_after}",
        (1.0 - retention) * 100.0
    );
}
