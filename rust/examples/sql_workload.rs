//! SkyRL-SQL workload (§4.2): post-train a SQL agent over the mini SQL
//! engine with TVCACHE and report the paper's §4.2 numbers: hit rate,
//! per-hit latency (56.6 ms → ~6.5 ms) and expected tool-call speedup.
//!
//! Run: `cargo run --release --example sql_workload -- --tasks 24 --epochs 10`

use tvcache::bench::print_table;
use tvcache::train::{run_workload, SimOptions};
use tvcache::util::cli::Args;
use tvcache::workloads::{Workload, WorkloadConfig};

fn main() {
    let args = Args::from_env();
    let cfg = WorkloadConfig::config_for(Workload::SkyRlSql);
    let mut opts = SimOptions::from_config(&cfg, args.usize_or("tasks", 24), true);
    opts.epochs = args.usize_or("epochs", 10);

    let cached = run_workload(&cfg, &opts);
    let uncached = run_workload(&cfg, &SimOptions { cached: false, ..opts.clone() });

    let rows: Vec<Vec<String>> = cached
        .epoch_hit_rates
        .iter()
        .map(|(e, hr)| vec![format!("{e}"), format!("{:.1}%", hr * 100.0)])
        .collect();
    print_table(
        "SkyRL-SQL cache hit rate by epoch (paper: 27.0%-57.2%)",
        &["epoch", "hit_rate"],
        &rows,
    );

    // Per-call latency split (the §4.2 analysis).
    let mut hit_t = tvcache::util::hist::Samples::new();
    let mut miss_t = tvcache::util::hist::Samples::new();
    for c in &cached.calls {
        if c.hit {
            hit_t.add(c.charged * 1000.0);
        } else {
            miss_t.add(c.charged * 1000.0);
        }
    }
    let avg_hr = cached.overall_hit_rate();
    let miss_ms = miss_t.mean();
    let hit_ms = hit_t.mean();
    let per_hit_speedup = miss_ms / hit_ms.max(1e-9);
    let expected = 1.0 / (1.0 - avg_hr + avg_hr * hit_ms / miss_ms.max(1e-9));
    println!("\naverage hit rate over epochs : {:.2}% (paper: 33.11%)", avg_hr * 100.0);
    println!("mean tool exec, miss         : {miss_ms:.1} ms (paper: 56.6 ms)");
    println!("mean tool exec, hit          : {hit_ms:.1} ms (paper: 6.5 ms)");
    println!("per-hit speedup              : {per_hit_speedup:.1}x (paper: 8.7x)");
    println!("expected tool-call speedup   : {expected:.1}x (paper: 2.9x)");
    println!(
        "total tool time: cached {:.1}s vs uncached {:.1}s",
        cached.rollouts.iter().map(|r| r.tool_time).sum::<f64>(),
        uncached.rollouts.iter().map(|r| r.tool_time).sum::<f64>()
    );
}
