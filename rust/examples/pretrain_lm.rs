//! LM pretraining through the full AOT stack: train the Layer-2 transformer
//! (with its Layer-1 Pallas kernels) as a plain language model on a
//! synthetic corpus, entirely from Rust via PJRT — logging the loss curve.
//!
//! This is the `adv = 1` degenerate case of the GRPO train-step artifact
//! (see `python/compile/model.py`): with unit advantages, the policy
//! gradient loss is exactly next-token cross-entropy.
//!
//! Requires `make artifacts`.
//! Run: `cargo run --release --example pretrain_lm -- --steps 120`

use tvcache::metrics::CsvWriter;
use tvcache::runtime::AgentRuntime;
use tvcache::train::{pack_batch, PackedBatch};
use tvcache::util::cli::Args;
use tvcache::util::rng::Rng;

/// Synthetic corpus: a seeded order-1 Markov chain over the vocabulary —
/// enough structure that cross-entropy has real headroom below uniform.
fn sample_sequence(rng: &mut Rng, vocab: usize, len: usize) -> Vec<i32> {
    let mut seq = vec![0i32]; // BOS
    let mut state = 3usize;
    for _ in 0..len - 1 {
        // Next token concentrates on (state*2, state*2+1, 7) mod vocab.
        let choices = [
            (state * 2) % vocab,
            (state * 2 + 1) % vocab,
            7 % vocab,
        ];
        let idx = rng.weighted(&[0.6, 0.3, 0.1]);
        state = choices[idx];
        seq.push(state as i32);
    }
    seq
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::from_env();
    let steps = args.usize_or("steps", 120);
    let art_dir = args.str_or("artifacts", "artifacts");

    let mut rt = AgentRuntime::load(&art_dir)?;
    println!(
        "loaded artifacts: platform={} params={} vocab={} seq={} (pallas kernels: {})",
        rt.platform(),
        rt.meta.param_count,
        rt.meta.vocab,
        rt.meta.seq,
        rt.meta.use_pallas
    );
    rt.init_params(42)?;

    let bt = rt.meta.train_batch;
    let seq = rt.meta.seq;
    let vocab = rt.meta.vocab;
    let mut rng = Rng::new(7);
    let mut csv = CsvWriter::new(&["step", "loss"]);

    let t0 = std::time::Instant::now();
    let mut first = 0.0f32;
    let mut last = 0.0f32;
    for step in 0..steps {
        let rollouts: Vec<Vec<i32>> =
            (0..bt).map(|_| sample_sequence(&mut rng, vocab, seq)).collect();
        let adv = vec![1.0f64; bt]; // unit advantages ⇒ LM cross-entropy
        let batch: PackedBatch = pack_batch(&rollouts, &adv, bt, seq);
        let loss = rt.train_step(&batch)?;
        if step == 0 {
            first = loss;
        }
        last = loss;
        csv.rowf(&[&step, &loss]);
        if step % 10 == 0 || step == steps - 1 {
            println!("step {step:4}  loss {loss:.4}");
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();
    csv.write("results/pretrain_lm_loss.csv")?;
    println!(
        "\n{steps} steps in {elapsed:.1}s ({:.2} s/step); loss {first:.3} -> {last:.3}",
        elapsed / steps as f64
    );
    println!("loss curve written to results/pretrain_lm_loss.csv");
    if last >= first {
        return Err(format!("loss did not decrease: {first} -> {last}").into());
    }
    Ok(())
}
