//! EgoSchema workload (§4.3, Appendix D): video-QA post-training over the
//! simulated VideoAgent tool suite, reporting per-tool hit rates (Figure 12
//! shape: load/preprocess highest, string-arg tools lowest) and the OpenAI
//! API token savings from caption-tool hits.
//!
//! Run: `cargo run --release --example video_workload -- --tasks 16`

use std::collections::BTreeMap;

use tvcache::bench::print_table;
use tvcache::train::{run_workload, SimOptions};
use tvcache::util::cli::Args;
use tvcache::workloads::{Workload, WorkloadConfig};

fn main() {
    let args = Args::from_env();
    let cfg = WorkloadConfig::config_for(Workload::EgoSchema);
    let mut opts = SimOptions::from_config(&cfg, args.usize_or("tasks", 16), true);
    opts.epochs = args.usize_or("epochs", 5);

    let m = run_workload(&cfg, &opts);

    // Per-tool hit rates (Figure 12).
    let mut per_tool: BTreeMap<String, (u64, u64)> = BTreeMap::new();
    for c in &m.calls {
        let e = per_tool.entry(c.tool.clone()).or_default();
        if c.hit {
            e.0 += 1;
        }
        e.1 += 1;
    }
    let rows: Vec<Vec<String>> = per_tool
        .iter()
        .map(|(tool, (h, n))| {
            vec![tool.clone(), format!("{n}"), format!("{:.1}%", 100.0 * *h as f64 / *n as f64)]
        })
        .collect();
    print_table(
        "EgoSchema per-tool hit rates (Fig 12 shape: load/preprocess high, string-arg tools low)",
        &["tool", "calls", "hit_rate"],
        &rows,
    );

    println!("\noverall hit rate  : {:.1}% (paper avg 64.3%)", 100.0 * m.overall_hit_rate());
    let spent = m.api_tokens_spent.max(1);
    let saved = m.api_tokens_saved;
    println!(
        "API tokens        : spent {spent}, saved {saved} ({:.1}x reduction; paper: 3x)",
        (spent + saved) as f64 / spent as f64
    );
    for (e, hr) in &m.epoch_hit_rates {
        println!("epoch {e}: hit rate {:.1}%", hr * 100.0);
    }
}
