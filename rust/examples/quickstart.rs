//! Quickstart: the TVCACHE public API in one file.
//!
//! Builds a per-task cache, runs two "parallel rollouts" of a terminal
//! debugging task through the `ToolCallExecutor`, and shows the second
//! rollout hitting the first one's tool calls — including the stateful
//! `cat → patch → cat` case a naive cache would corrupt.
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;

use tvcache::cache::{CacheBackend, ShardedCacheService, ToolCall};
use tvcache::client::{ExecutorConfig, ToolCallExecutor};
use tvcache::sandbox::TerminalFactory;

fn bash(cmd: &str) -> ToolCall {
    let stateless = cmd.starts_with("cat ") || cmd.starts_with("ls");
    ToolCall::with_flag("bash", cmd, !stateless)
}

fn main() {
    // The sharded cache service: per-task caches, routed by task id.
    let service = Arc::new(ShardedCacheService::new(4));
    let factory = Arc::new(TerminalFactory { medium: false });
    let task = "demo-task";
    let task_seed = 11;

    let script = [
        "cat README.md",
        "cat src/module_4.py",
        "make",
        "make test",
        "patch src/module_4.py s/return x - 3/return x + 3/",
        "make",
        "make test",
    ];

    println!("--- rollout 1 (cold cache) ---");
    let mut r1 = ToolCallExecutor::new(
        Arc::clone(&service) as Arc<_>,
        task,
        Arc::clone(&factory) as Arc<_>,
        task_seed,
        ExecutorConfig::default(),
    );
    for cmd in &script {
        let o = r1.call(bash(cmd));
        println!(
            "  [{}] {:8.3}s  {}",
            if o.hit { "HIT " } else { "MISS" },
            o.charged,
            cmd
        );
    }
    let cold = r1.total_charged;

    println!("--- rollout 2 (warm cache, same trajectory) ---");
    let mut r2 = ToolCallExecutor::new(
        Arc::clone(&service) as Arc<_>,
        task,
        Arc::clone(&factory) as Arc<_>,
        task_seed,
        ExecutorConfig::default(),
    );
    for cmd in &script {
        let o = r2.call(bash(cmd));
        println!(
            "  [{}] {:8.3}s  {}",
            if o.hit { "HIT " } else { "MISS" },
            o.charged,
            cmd
        );
    }
    let warm = r2.total_charged;

    println!("--- rollout 3 (diverges after the build: stateful correctness) ---");
    let mut r3 = ToolCallExecutor::new(
        Arc::clone(&service) as Arc<_>,
        task,
        factory as Arc<_>,
        task_seed,
        ExecutorConfig::default(),
    );
    r3.call(bash("cat README.md"));
    r3.call(bash("cat src/module_4.py"));
    r3.call(bash("make"));
    // Different patch than rollout 1 ⇒ the later `cat` must NOT be served
    // from rollout 1's trajectory.
    r3.call(bash("patch src/module_4.py s/return x - 3/return x * 99/"));
    let o = r3.call(bash("cat src/module_4.py"));
    assert!(o.result.output.contains("x * 99"), "stale result served!");
    println!("  divergent cat returned the rollout's own patch ✓");

    let stats = service.stats(task);
    println!(
        "\ncache: {} lookups, {} hits ({:.0}% hit rate)",
        stats.lookups,
        stats.hits,
        100.0 * stats.hit_rate()
    );
    println!(
        "tool time: cold rollout {cold:.1}s -> warm rollout {warm:.3}s ({:.0}x)",
        cold / warm.max(1e-9)
    );
    let cache = service.task(task);
    println!("TCG nodes: {}, snapshots: {}", cache.node_count(), cache.snapshot_count());
    assert!(warm < cold / 10.0);
}
