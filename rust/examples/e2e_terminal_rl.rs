//! End-to-end driver: GRPO post-training of the PJRT transformer policy on
//! terminal debugging tasks, with TVCACHE serving every tool call.
//!
//! This exercises all three layers on a real (small) RL workload:
//!
//! * **L1/L2** — the policy network (Pallas attention + RMSNorm inside the
//!   JAX-lowered HLO) generates one action token per tool call and updates
//!   via the GRPO train-step artifact, all through PJRT from Rust.
//! * **L3** — every sampled action executes through the `ToolCallExecutor`
//!   against the terminal sandbox, with the per-task TCG shared across the
//!   parallel rollouts and across steps.
//!
//! Rewards follow Appendix C with shaping for the small policy: -1 for a
//! malformed episode (no actions), partial credit for building, full credit
//! for a passing test suite.
//!
//! Requires `make artifacts`.
//! Run: `cargo run --release --example e2e_terminal_rl -- --steps 100`

use std::sync::Arc;

use tvcache::agent::action::{ActionSpace, BOS};
use tvcache::cache::ShardedCacheService;
use tvcache::client::{ExecutorConfig, ToolCallExecutor};
use tvcache::metrics::CsvWriter;
use tvcache::runtime::AgentRuntime;
use tvcache::sandbox::{TerminalFactory, TerminalTask};
use tvcache::train::{advantages, pack_batch};
use tvcache::util::cli::Args;
use tvcache::util::rng::Rng;

const MAX_ACTIONS: usize = 10;

struct TaskCtx {
    seed: u64,
    name: String,
    space: ActionSpace,
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::from_env();
    let steps = args.usize_or("steps", 100);
    let n_tasks = args.usize_or("tasks", 4);
    let temperature = args.f64_or("temperature", 1.0) as f32;
    let art_dir = args.str_or("artifacts", "artifacts");

    let mut rt = AgentRuntime::load(&art_dir)?;
    rt.init_params(args.u64_or("seed", 1) as i32)?;
    let b = rt.meta.rollout_batch; // parallel rollouts per task
    let bt = rt.meta.train_batch;
    let seq = rt.meta.seq;
    let tasks_per_step = bt / b;
    println!(
        "e2e GRPO: {} params, {} rollouts/task, {} tasks/step, {} steps",
        rt.meta.param_count, b, tasks_per_step, steps
    );

    let factory = Arc::new(TerminalFactory { medium: false });
    // One sharded cache service for the whole run; tasks hash across shards.
    let service = Arc::new(ShardedCacheService::new(4));
    // Seeds chosen so `make` needs no package install (seed % 3 != 0):
    // keeps the reward reachable by a randomly initialized policy.
    let tasks: Vec<TaskCtx> = (0..n_tasks)
        .map(|i| {
            let seed = (3 * i + 1) as u64;
            TaskCtx {
                seed,
                name: format!("terminal-task-{i}"),
                space: ActionSpace::terminal(&TerminalTask::generate(seed, false)),
            }
        })
        .collect();

    let mut rng = Rng::new(0xE2E);
    let mut csv = CsvWriter::new(&["step", "loss", "mean_reward", "hit_rate", "tool_time"]);
    let t0 = std::time::Instant::now();

    for step in 0..steps {
        let mut all_tokens: Vec<Vec<i32>> = Vec::with_capacity(bt);
        let mut all_rewards: Vec<f64> = Vec::with_capacity(bt);
        let mut step_hits = 0u64;
        let mut step_calls = 0u64;
        let mut step_tool_time = 0.0;

        for ti in 0..tasks_per_step {
            let task = &tasks[(step * tasks_per_step + ti) % tasks.len()];
            // B parallel rollouts in lockstep: one batched forward per turn.
            let mut tokens: Vec<Vec<i32>> = vec![vec![BOS]; b];
            let mut done = vec![false; b];
            let mut execs: Vec<ToolCallExecutor> = (0..b)
                .map(|_| {
                    ToolCallExecutor::new(
                        Arc::clone(&service) as Arc<_>,
                        task.name.clone(),
                        Arc::clone(&factory) as Arc<_>,
                        task.seed,
                        ExecutorConfig::default(),
                    )
                })
                .collect();
            let valid = task.space.valid_tokens(rt.meta.vocab);

            for _turn in 0..MAX_ACTIONS {
                if done.iter().all(|&d| d) {
                    break;
                }
                // Pack the batched forward inputs.
                let mut toks = vec![0i32; b * seq];
                let mut lens = vec![0i32; b];
                for (r, t) in tokens.iter().enumerate() {
                    let l = t.len().min(seq);
                    toks[r * seq..r * seq + l].copy_from_slice(&t[..l]);
                    lens[r] = l as i32;
                }
                let logits = rt.forward(&toks, &lens)?;
                for r in 0..b {
                    if done[r] || tokens[r].len() >= seq {
                        done[r] = true;
                        continue;
                    }
                    // Mask invalid tokens, sample with temperature.
                    let masked: Vec<f32> = logits[r]
                        .iter()
                        .enumerate()
                        .map(|(i, &l)| if valid.get(i).copied().unwrap_or(false) { l } else { -1e9 })
                        .collect();
                    let tok = rng.softmax_sample(&masked, temperature) as i32;
                    tokens[r].push(tok);
                    if ActionSpace::is_terminal(tok) {
                        done[r] = true;
                    } else if let Some(call) = task.space.decode(tok) {
                        let o = execs[r].call(call.clone());
                        step_tool_time += o.charged;
                        step_hits += o.hit as u64;
                        step_calls += 1;
                    }
                }
            }

            // Rewards (Appendix C + shaping for the small policy).
            for r in 0..b {
                let hist = execs[r].history();
                let reward = if hist.is_empty() {
                    -1.0 // malformed episode: stopped without acting
                } else {
                    let built = hist.iter().any(|(_, res)| res.output == "build OK");
                    let passed = hist
                        .iter()
                        .any(|(_, res)| res.output.contains("12 passed"));
                    let n_actions = tokens[r].len().saturating_sub(2) as f64;
                    (if passed { 1.0 } else if built { 0.3 } else { 0.0 }) - 0.01 * n_actions
                };
                all_rewards.push(reward);
                all_tokens.push(tokens[r].clone());
                execs[r].finish();
            }
        }

        // GRPO update: group-relative advantages per task group.
        let mut advs = Vec::with_capacity(bt);
        for g in all_rewards.chunks(b) {
            advs.extend(advantages(g));
        }
        let batch = pack_batch(&all_tokens, &advs, bt, seq);
        let loss = rt.train_step(&batch)?;

        let mean_reward = all_rewards.iter().sum::<f64>() / all_rewards.len() as f64;
        let hit_rate = if step_calls > 0 { step_hits as f64 / step_calls as f64 } else { 0.0 };
        csv.rowf(&[&step, &loss, &mean_reward, &hit_rate, &step_tool_time]);
        if step % 5 == 0 || step == steps - 1 {
            println!(
                "step {step:4}  loss {loss:7.4}  reward {mean_reward:6.3}  hit {:5.1}%  tool {:7.1}s(sim)",
                hit_rate * 100.0,
                step_tool_time
            );
        }
    }

    csv.write("results/e2e_terminal_rl.csv")?;
    println!(
        "\n{} steps in {:.1}s wall-clock; curves in results/e2e_terminal_rl.csv",
        steps,
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
