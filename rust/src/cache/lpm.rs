//! Longest-prefix matching (§3.2) with optional stateful-prefix filtering
//! (Appendix B).
//!
//! Given a rollout's full tool history `q = [t_1 … t_j]` (the *last* element
//! is the call being looked up), the matcher walks the TCG from the root:
//!
//! * **Hit** — the entire (filtered) trajectory matches a cached path:
//!   return the cached result for `t_j`. The paper's correctness argument:
//!   an identical stateful history guarantees an identical sandbox state.
//! * **Miss** — return the deepest matched node. Per the paper's §3.2
//!   semantics, the client resumes from the final matched node's snapshot if
//!   it has one, otherwise replays the full sequence in a fresh sandbox. An
//!   optional extension (`ancestor_resume`, ablated in
//!   `benches/appendix_b_stateless_skip.rs`) walks up to the nearest
//!   snapshotted ancestor instead of falling all the way back to a fresh
//!   sandbox.
//!
//! With stateful filtering on, calls whose `will_mutate_state()` is false
//! are skipped while walking (they cannot change the sandbox state —
//! Appendix B proves the equivalence) and are looked up in the side index of
//! the last state-mutating node.

use super::key::{ToolCall, ToolResult};
use super::tcg::{NodeId, SnapshotRef, Tcg, ROOT};

/// Matcher configuration.
#[derive(Debug, Clone, Copy)]
pub struct LpmConfig {
    /// Skip `mutates_state == false` calls when matching (Appendix B).
    pub stateful_filtering: bool,
    /// On a miss, resume from the nearest snapshotted *ancestor* of the
    /// deepest match instead of requiring the snapshot exactly at the match.
    pub ancestor_resume: bool,
}

impl Default for LpmConfig {
    fn default() -> Self {
        LpmConfig { stateful_filtering: true, ancestor_resume: true }
    }
}

/// Result of a cache lookup.
#[derive(Debug, Clone, PartialEq)]
pub enum Lookup {
    /// Exact trajectory match: the cached result of the final call.
    Hit { node: NodeId, result: ToolResult },
    /// Partial match: client must execute the suffix.
    Miss(Miss),
}

/// Everything the client needs to handle a miss.
#[derive(Debug, Clone, PartialEq)]
pub struct Miss {
    /// Deepest TCG node whose path matches a prefix of the query.
    pub matched_node: NodeId,
    /// How many *leading calls of the original query* are covered by the
    /// match (informational; drives partial-hit statistics).
    pub matched_calls: usize,
    /// Sandbox to fork, if any: `(node, snapshot, replay_from)` where
    /// `replay_from` is the resume node's *TCG depth* (number of matched
    /// graph edges). With stateful filtering on, that is the count of
    /// state-mutating calls covered; the executor maps it back to a query
    /// index (`client::executor::stateful_depth_to_index`).
    /// `None` ⇒ fresh sandbox, replay from index 0.
    pub resume: Option<(NodeId, SnapshotRef, usize)>,
}

impl Lookup {
    pub fn is_hit(&self) -> bool {
        matches!(self, Lookup::Hit { .. })
    }
}

/// Result of one incremental cursor step (the O(1) hot-path lookup).
///
/// A cursor regime maintains the invariant that every call the rollout has
/// issued so far was either a [`CursorStep::Hit`] or was executed and then
/// recorded at the cursor position — so the cursor's node always *is* the
/// full-prefix LPM match, and a step needs exactly one child-index probe
/// instead of a root-to-leaf walk.
#[derive(Debug, Clone, PartialEq)]
pub enum CursorStep {
    /// The delta call is cached: same payload as [`Lookup::Hit`].
    Hit { node: NodeId, result: ToolResult },
    /// The delta call is new: same payload as [`Lookup::Miss`] — the
    /// cursor's node is the `matched_node`, so resume offers are identical
    /// to the full-prefix walk's.
    Miss(Miss),
    /// The cursor's pinned node was evicted out from under it: the caller
    /// must fall back to a full-prefix [`lookup`] (and re-seek the cursor).
    Invalid,
}

impl CursorStep {
    pub fn is_hit(&self) -> bool {
        matches!(self, CursorStep::Hit { .. })
    }
}

/// One incremental LPM step: classify the single delta call `call` given a
/// cursor pinned at `pos` with `steps` calls already consumed. Returns the
/// step outcome plus the cursor's next position, or `None` when `pos` is no
/// longer live (the caller reports [`CursorStep::Invalid`]).
///
/// Equivalence with [`lookup`]: when the cursor invariant holds (every
/// consumed call hit or was recorded at the then-current position), the
/// outcome — hit node/result, miss `matched_node`/`matched_calls`, and the
/// resume offer — is identical to `lookup(tcg, prefix + [call], cfg)`.
/// `prop_cursor_walk_equals_full_lookup` below checks this over random
/// graphs.
pub fn cursor_step(
    tcg: &Tcg,
    pos: NodeId,
    steps: usize,
    call: &ToolCall,
    cfg: LpmConfig,
) -> Option<(CursorStep, NodeId)> {
    tcg.node(pos)?;
    if cfg.stateful_filtering && !call.mutates_state {
        // Stateless delta: probe the side index of the current (state-
        // mutating) position; the position never advances.
        if let Some(result) = tcg.stateless_result(pos, call) {
            return Some((CursorStep::Hit { node: pos, result: result.clone() }, pos));
        }
    } else if let Some(next) = tcg.child(pos, call) {
        let result = tcg.node(next).unwrap().result.clone();
        return Some((CursorStep::Hit { node: next, result }, next));
    }
    let resume = resume_point(tcg, pos, steps, cfg);
    Some((
        CursorStep::Miss(Miss { matched_node: pos, matched_calls: steps, resume }),
        pos,
    ))
}

/// Walk the TCG along `q` and classify hit/miss.
pub fn lookup(tcg: &Tcg, q: &[ToolCall], cfg: LpmConfig) -> Lookup {
    assert!(!q.is_empty(), "lookup requires at least the current call");
    let (prefix, current) = q.split_at(q.len() - 1);
    let current = &current[0];

    // Walk the (filtered) prefix from the root.
    let mut node = ROOT;
    let mut matched_calls = 0; // index into the original q
    let mut diverged = false;
    for (i, call) in prefix.iter().enumerate() {
        if cfg.stateful_filtering && !call.mutates_state {
            // Stateless prefix calls don't constrain the walk…
            if !diverged {
                matched_calls = i + 1;
            }
            continue;
        }
        if diverged {
            continue;
        }
        match tcg.child(node, call) {
            Some(next) => {
                node = next;
                matched_calls = i + 1;
            }
            None => {
                diverged = true;
            }
        }
    }

    if !diverged {
        // The whole prefix matched — the current call decides hit vs miss.
        if cfg.stateful_filtering && !current.mutates_state {
            if let Some(result) = tcg.stateless_result(node, current) {
                return Lookup::Hit { node, result: result.clone() };
            }
        } else if let Some(hit) = tcg.child(node, current) {
            let result = tcg.node(hit).unwrap().result.clone();
            return Lookup::Hit { node: hit, result };
        }
        // Prefix matched but the current call is new.
        if q.len() > 1 {
            matched_calls = q.len() - 1;
        } else {
            matched_calls = 0;
        }
    }

    // Miss: find the sandbox to resume from.
    let resume = resume_point(tcg, node, matched_calls, cfg);
    Lookup::Miss(Miss { matched_node: node, matched_calls, resume })
}

fn resume_point(
    tcg: &Tcg,
    matched_node: NodeId,
    _matched_calls: usize,
    cfg: LpmConfig,
) -> Option<(NodeId, SnapshotRef, usize)> {
    if matched_node == ROOT {
        return None;
    }
    let node = tcg.node(matched_node)?;
    if let Some(snap) = node.snapshot {
        // Paper semantics: the final matched node has a snapshot.
        return Some((matched_node, snap, node.depth as usize));
    }
    if !cfg.ancestor_resume {
        return None;
    }
    // Extension: nearest snapshotted ancestor. Replay restarts from the call
    // after that ancestor; its TCG depth identifies the point.
    let (anc, snap) = tcg.nearest_snapshot(matched_node)?;
    if anc == ROOT {
        return None;
    }
    let depth = tcg.node(anc)?.depth as usize;
    Some((anc, snap, depth))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::key::{ToolCall, ToolResult};
    use crate::cache::tcg::Tcg;

    fn sf(s: &str) -> ToolCall {
        ToolCall::new("t", s)
    }

    fn sl(s: &str) -> ToolCall {
        ToolCall::stateless("s", s)
    }

    fn res(s: &str) -> ToolResult {
        ToolResult::new(s, 1.0)
    }

    fn build_chain(g: &mut Tcg, calls: &[&str]) -> Vec<NodeId> {
        let mut ids = Vec::new();
        let mut cur = ROOT;
        for c in calls {
            cur = g.insert_child(cur, sf(c), res(&format!("out-{c}")));
            ids.push(cur);
        }
        ids
    }

    #[test]
    fn exact_hit_returns_cached_result() {
        let mut g = Tcg::new();
        build_chain(&mut g, &["a", "b", "c"]);
        let q = vec![sf("a"), sf("b"), sf("c")];
        match lookup(&g, &q, LpmConfig::default()) {
            Lookup::Hit { result, .. } => assert_eq!(result.output, "out-c"),
            m => panic!("expected hit, got {m:?}"),
        }
    }

    #[test]
    fn first_call_hit() {
        let mut g = Tcg::new();
        build_chain(&mut g, &["a"]);
        match lookup(&g, &[sf("a")], LpmConfig::default()) {
            Lookup::Hit { result, .. } => assert_eq!(result.output, "out-a"),
            m => panic!("{m:?}"),
        }
    }

    #[test]
    fn miss_on_empty_graph_full_replay() {
        let g = Tcg::new();
        match lookup(&g, &[sf("a"), sf("b")], LpmConfig::default()) {
            Lookup::Miss(m) => {
                assert_eq!(m.matched_calls, 0);
                assert_eq!(m.matched_node, ROOT);
                assert!(m.resume.is_none());
            }
            h => panic!("{h:?}"),
        }
    }

    #[test]
    fn partial_match_reports_depth() {
        let mut g = Tcg::new();
        build_chain(&mut g, &["a", "b"]);
        let q = vec![sf("a"), sf("b"), sf("x"), sf("y")];
        match lookup(&g, &q, LpmConfig::default()) {
            Lookup::Miss(m) => {
                assert_eq!(m.matched_calls, 2);
                assert!(m.resume.is_none()); // no snapshots anywhere
            }
            h => panic!("{h:?}"),
        }
    }

    #[test]
    fn paper_semantics_snapshot_at_match() {
        let mut g = Tcg::new();
        let ids = build_chain(&mut g, &["a", "b"]);
        g.set_snapshot(ids[1], SnapshotRef { id: 5, bytes: 10, restore_cost: 0.1 });
        let q = vec![sf("a"), sf("b"), sf("x")];
        let cfg = LpmConfig { stateful_filtering: true, ancestor_resume: false };
        match lookup(&g, &q, cfg) {
            Lookup::Miss(m) => {
                let (node, snap, replay_from) = m.resume.unwrap();
                assert_eq!(node, ids[1]);
                assert_eq!(snap.id, 5);
                assert_eq!(replay_from, 2);
            }
            h => panic!("{h:?}"),
        }
    }

    #[test]
    fn paper_semantics_no_snapshot_means_fresh_sandbox() {
        let mut g = Tcg::new();
        let ids = build_chain(&mut g, &["a", "b"]);
        // Snapshot only at `a`, but the match reaches `b`.
        g.set_snapshot(ids[0], SnapshotRef { id: 1, bytes: 1, restore_cost: 0.1 });
        let cfg = LpmConfig { stateful_filtering: true, ancestor_resume: false };
        let q = vec![sf("a"), sf("b"), sf("x")];
        match lookup(&g, &q, cfg) {
            Lookup::Miss(m) => assert!(m.resume.is_none()),
            h => panic!("{h:?}"),
        }
    }

    #[test]
    fn ancestor_resume_walks_up() {
        let mut g = Tcg::new();
        let ids = build_chain(&mut g, &["a", "b", "c"]);
        g.set_snapshot(ids[0], SnapshotRef { id: 1, bytes: 1, restore_cost: 0.1 });
        let cfg = LpmConfig { stateful_filtering: true, ancestor_resume: true };
        let q = vec![sf("a"), sf("b"), sf("c"), sf("x")];
        match lookup(&g, &q, cfg) {
            Lookup::Miss(m) => {
                let (node, snap, replay_from) = m.resume.unwrap();
                assert_eq!(node, ids[0]);
                assert_eq!(snap.id, 1);
                assert_eq!(replay_from, 1); // ancestor depth: replay b, c, x
            }
            h => panic!("{h:?}"),
        }
    }

    #[test]
    fn divergence_midway_stops_matching() {
        let mut g = Tcg::new();
        build_chain(&mut g, &["a", "b", "c"]);
        // Diverges at the 2nd call; later coincidental matches don't count.
        let q = vec![sf("a"), sf("Z"), sf("c"), sf("d")];
        match lookup(&g, &q, LpmConfig::default()) {
            Lookup::Miss(m) => assert_eq!(m.matched_calls, 1),
            h => panic!("{h:?}"),
        }
    }

    // ---- Appendix B: stateful prefix matching ----

    #[test]
    fn stateless_calls_skipped_in_prefix() {
        // Rollout 1 cached: F1, S1, F2. Query: F1, F2 — must match F1→F2.
        let mut g = Tcg::new();
        let f1 = g.insert_child(ROOT, sf("F1"), res("f1"));
        g.insert_stateless(f1, sl("S1"), res("s1"));
        let _f2 = g.insert_child(f1, sf("F2"), res("f2"));
        let q = vec![sf("F1"), sl("S1"), sf("F2")];
        assert!(lookup(&g, &q, LpmConfig::default()).is_hit());
        // And without the stateless call at all:
        let q2 = vec![sf("F1"), sf("F2")];
        assert!(lookup(&g, &q2, LpmConfig::default()).is_hit());
    }

    #[test]
    fn stateless_reordering_still_hits() {
        // Figure 10: rollout 1 ran (t1, t2, t3, t4); rollout 2 asks
        // (t1, t2, t4, t3) where t3, t4 are stateless.
        let mut g = Tcg::new();
        let t1 = g.insert_child(ROOT, sf("t1"), res(""));
        let t2 = g.insert_child(t1, sf("t2"), res(""));
        g.insert_stateless(t2, sl("t3"), res("r3"));
        g.insert_stateless(t2, sl("t4"), res("r4"));
        let q = vec![sf("t1"), sf("t2"), sl("t4"), sl("t3")];
        match lookup(&g, &q, LpmConfig::default()) {
            Lookup::Hit { result, .. } => assert_eq!(result.output, "r3"),
            m => panic!("{m:?}"),
        }
    }

    #[test]
    fn without_filtering_reordering_misses() {
        let mut g = Tcg::new();
        // Without filtering, stateless calls become regular nodes.
        let t1 = g.insert_child(ROOT, sf("t1"), res(""));
        let t3 = g.insert_child(t1, sl("t3"), res("r3"));
        g.insert_child(t3, sl("t4"), res("r4"));
        let cfg = LpmConfig { stateful_filtering: false, ancestor_resume: false };
        let q = vec![sf("t1"), sl("t4"), sl("t3")];
        assert!(!lookup(&g, &q, cfg).is_hit());
        // The same order does hit.
        let q2 = vec![sf("t1"), sl("t3"), sl("t4")];
        assert!(lookup(&g, &q2, cfg).is_hit());
    }

    // ---- property-style invariants over random graphs ----

    /// Random call from a small alphabet; ~1/3 stateless (Appendix B).
    fn random_call(rng: &mut crate::util::rng::Rng) -> ToolCall {
        let idx = rng.below(9);
        if idx < 3 {
            sl(&format!("s{idx}"))
        } else {
            sf(&format!("f{idx}"))
        }
    }

    /// Insert `traj` the way `TaskCache::record_trajectory` does under
    /// stateful filtering: mutating calls chain, stateless calls index on
    /// the last mutating node. Returns the final mutating node.
    fn record(g: &mut Tcg, traj: &[ToolCall]) -> NodeId {
        let mut cur = ROOT;
        for c in traj {
            if c.mutates_state {
                cur = g.insert_child(cur, c.clone(), res(&format!("r-{}", c.args)));
            } else if g.stateless_result(cur, c).is_none() {
                g.insert_stateless(cur, c.clone(), res(&format!("r-{}", c.args)));
            }
        }
        cur
    }

    #[test]
    fn prop_inserted_trajectory_prefixes_always_hit() {
        let mut rng = crate::util::rng::Rng::new(0x11F0);
        for _trial in 0..50 {
            let mut g = Tcg::new();
            let mut trajs = Vec::new();
            for _ in 0..4 {
                let n = 1 + rng.below(8) as usize;
                let t: Vec<ToolCall> = (0..n).map(|_| random_call(&mut rng)).collect();
                record(&mut g, &t);
                trajs.push(t);
            }
            for t in &trajs {
                for k in 1..=t.len() {
                    assert!(
                        lookup(&g, &t[..k], LpmConfig::default()).is_hit(),
                        "prefix of length {k} of an inserted trajectory missed: {t:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn prop_resume_never_deeper_than_query() {
        let mut rng = crate::util::rng::Rng::new(0xBEEF);
        for _trial in 0..100 {
            let mut g = Tcg::new();
            for _ in 0..3 {
                let n = 1 + rng.below(6) as usize;
                let t: Vec<ToolCall> = (0..n).map(|_| random_call(&mut rng)).collect();
                let leaf = record(&mut g, &t);
                if leaf != ROOT && rng.chance(0.7) {
                    g.set_snapshot(
                        leaf,
                        SnapshotRef { id: leaf as u64, bytes: 1, restore_cost: 0.1 },
                    );
                }
            }
            let n = 1 + rng.below(7) as usize;
            let q: Vec<ToolCall> = (0..n).map(|_| random_call(&mut rng)).collect();
            if let Lookup::Miss(m) = lookup(&g, &q, LpmConfig::default()) {
                assert!(m.matched_calls < q.len(), "a miss cannot cover the whole query");
                if let Some((node, _, replay_from)) = m.resume {
                    // The resume node's stateful depth can never exceed the
                    // number of state-mutating calls in the query prefix —
                    // resuming deeper would replay state the rollout never
                    // executed.
                    let prefix_mutating =
                        q[..q.len() - 1].iter().filter(|c| c.mutates_state).count();
                    assert!(
                        replay_from <= prefix_mutating,
                        "resume depth {replay_from} exceeds query stateful depth \
                         {prefix_mutating} (q = {q:?})"
                    );
                    assert_eq!(
                        g.node(node).unwrap().depth as usize,
                        replay_from,
                        "replay_from must equal the resume node's TCG depth"
                    );
                }
            }
        }
    }

    #[test]
    fn prop_partial_hit_depth_monotone_in_prefix_length() {
        let mut rng = crate::util::rng::Rng::new(0x50F7);
        for _trial in 0..50 {
            let mut g = Tcg::new();
            let n = 2 + rng.below(8) as usize;
            // Mutating-only trajectory keeps "depth" unambiguous.
            let t: Vec<ToolCall> =
                (0..n).map(|i| sf(&format!("m{}-{}", i, rng.below(3)))).collect();
            record(&mut g, &t);
            let probe = sf("divergent-probe");
            let mut prev = 0usize;
            for k in 0..=t.len() {
                let mut q: Vec<ToolCall> = t[..k].to_vec();
                q.push(probe.clone());
                match lookup(&g, &q, LpmConfig::default()) {
                    Lookup::Miss(m) => {
                        assert!(
                            m.matched_calls >= prev,
                            "matched_calls regressed from {prev} to {} at k={k}",
                            m.matched_calls
                        );
                        assert_eq!(
                            m.matched_calls, k,
                            "a fully-cached prefix of length {k} must match entirely"
                        );
                        prev = m.matched_calls;
                    }
                    h => panic!("divergent probe can never hit: {h:?}"),
                }
            }
        }
    }

    // ---- incremental cursor steps (the O(1) hot path) ----

    /// Walk `q` with cursor steps, recording misses the way an executor
    /// would; every step outcome must equal the full-prefix lookup of the
    /// same prefix at that moment.
    fn walk_and_compare(g: &mut Tcg, q: &[ToolCall], cfg: LpmConfig) {
        let mut pos = ROOT;
        for (i, c) in q.iter().enumerate() {
            let full = lookup(g, &q[..=i], cfg);
            let (step, next) =
                cursor_step(g, pos, i, c, cfg).expect("live cursor position");
            match (&step, &full) {
                (CursorStep::Hit { node: a, result: ra }, Lookup::Hit { node: b, result: rb }) => {
                    assert_eq!((a, ra), (b, rb), "hit mismatch at step {i} of {q:?}");
                }
                (CursorStep::Miss(ma), Lookup::Miss(mb)) => {
                    assert_eq!(ma, mb, "miss mismatch at step {i} of {q:?}");
                }
                _ => panic!("outcome kind diverged at step {i} of {q:?}: {step:?} vs {full:?}"),
            }
            pos = next;
            if let CursorStep::Miss(_) = step {
                // Executor behaviour: execute + record the delta, then the
                // cursor advances onto the recorded node.
                if cfg.stateful_filtering && !c.mutates_state {
                    if g.stateless_result(pos, c).is_none() {
                        g.insert_stateless(pos, c.clone(), res(&format!("r-{}", c.args)));
                    }
                } else {
                    pos = g.insert_child(pos, c.clone(), res(&format!("r-{}", c.args)));
                }
            }
        }
    }

    #[test]
    fn prop_cursor_walk_equals_full_lookup() {
        for filtering in [true, false] {
            let cfg = LpmConfig { stateful_filtering: filtering, ancestor_resume: true };
            let mut rng = crate::util::rng::Rng::new(0xC0D5E ^ filtering as u64);
            for _trial in 0..60 {
                let mut g = Tcg::new();
                for _ in 0..3 {
                    let n = 1 + rng.below(7) as usize;
                    let t: Vec<ToolCall> = (0..n).map(|_| random_call(&mut rng)).collect();
                    let leaf = record(&mut g, &t);
                    if leaf != ROOT && rng.chance(0.5) {
                        g.set_snapshot(
                            leaf,
                            SnapshotRef { id: leaf as u64, bytes: 1, restore_cost: 0.1 },
                        );
                    }
                }
                let n = 1 + rng.below(8) as usize;
                let q: Vec<ToolCall> = (0..n).map(|_| random_call(&mut rng)).collect();
                walk_and_compare(&mut g, &q, cfg);
            }
        }
    }

    #[test]
    fn cursor_step_on_dead_node_reports_invalid() {
        let mut g = Tcg::new();
        let ids = build_chain(&mut g, &["a", "b"]);
        g.remove_subtree(ids[1]);
        assert!(cursor_step(&g, ids[1], 2, &sf("c"), LpmConfig::default()).is_none());
        // The surviving parent still steps fine.
        let (step, _) = cursor_step(&g, ids[0], 1, &sf("z"), LpmConfig::default()).unwrap();
        assert!(matches!(step, CursorStep::Miss(_)));
    }

    #[test]
    fn cursor_miss_offers_same_resume_as_full_walk() {
        let mut g = Tcg::new();
        let ids = build_chain(&mut g, &["a", "b"]);
        g.set_snapshot(ids[1], SnapshotRef { id: 5, bytes: 10, restore_cost: 0.1 });
        let (step, _) =
            cursor_step(&g, ids[1], 2, &sf("x"), LpmConfig::default()).unwrap();
        let CursorStep::Miss(m) = step else { panic!("{step:?}") };
        let (node, snap, replay_from) = m.resume.unwrap();
        assert_eq!((node, snap.id, replay_from), (ids[1], 5, 2));
    }

    #[test]
    fn stateless_current_call_miss_when_not_cached() {
        let mut g = Tcg::new();
        let t1 = g.insert_child(ROOT, sf("t1"), res(""));
        g.set_snapshot(t1, SnapshotRef { id: 3, bytes: 1, restore_cost: 0.1 });
        let q = vec![sf("t1"), sl("s-new")];
        match lookup(&g, &q, LpmConfig::default()) {
            Lookup::Miss(m) => {
                assert_eq!(m.matched_calls, 1);
                let (node, _, _) = m.resume.unwrap();
                assert_eq!(node, t1);
            }
            h => panic!("{h:?}"),
        }
    }
}
