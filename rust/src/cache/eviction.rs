//! Sandbox-budget enforcement (§3.3 "Bounding number of cached sandboxes").
//!
//! Each task has a budget of stored sandboxes. When exceeded, TVCACHE prunes
//! the least useful snapshots: eviction scores favour keeping nodes that are
//! shallow (common prefixes), well-branched (shared by many trajectories),
//! and frequently hit; refcount-pinned sandboxes are never evicted
//! (§3.4 "Concurrency Control").

use super::tcg::{NodeId, SnapshotRef, Tcg, ROOT};

/// Tunable eviction weights.
#[derive(Debug, Clone, Copy)]
pub struct EvictionPolicy {
    /// Sandbox budget: max snapshots stored per task.
    pub max_snapshots: usize,
    /// Weight of hit count in the keep-score.
    pub hit_weight: f64,
    /// Weight of child count (branching ⇒ common prefix worth keeping).
    pub child_weight: f64,
    /// Depth penalty (deeper ⇒ more specialized ⇒ likelier to evict).
    pub depth_weight: f64,
}

impl Default for EvictionPolicy {
    fn default() -> Self {
        EvictionPolicy {
            max_snapshots: 64,
            hit_weight: 1.0,
            child_weight: 2.0,
            depth_weight: 0.5,
        }
    }
}

impl EvictionPolicy {
    /// Higher = more worth keeping.
    pub fn keep_score(&self, tcg: &Tcg, id: NodeId) -> f64 {
        let Some(n) = tcg.node(id) else { return f64::NEG_INFINITY };
        self.hit_weight * (n.hit_count() as f64 + 1.0).ln()
            + self.child_weight * n.children.len() as f64
            - self.depth_weight * n.depth as f64
    }
}

/// Evict snapshots until the budget holds. Returns the freed snapshot refs
/// (the sandbox manager destroys the corresponding sandboxes). Pinned
/// (refcount > 0) sandboxes are skipped; leaf nodes whose subtree carries no
/// other snapshot are removed from the TCG entirely ("evicting subtrees").
pub fn enforce_budget(tcg: &mut Tcg, policy: &EvictionPolicy) -> Vec<SnapshotRef> {
    let mut freed = Vec::new();
    loop {
        let count = tcg.snapshot_count();
        if count <= policy.max_snapshots {
            break;
        }
        // Candidates: snapshot-bearing, unpinned nodes, worst score first.
        let mut candidates: Vec<(f64, NodeId)> = tcg
            .live_nodes()
            .into_iter()
            .filter(|&id| {
                tcg.node(id)
                    .map(|n| n.snapshot.is_some() && !n.is_pinned())
                    .unwrap_or(false)
            })
            .map(|id| (policy.keep_score(tcg, id), id))
            .collect();
        if candidates.is_empty() {
            break; // everything pinned: cannot enforce further
        }
        candidates.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let (_, victim) = candidates[0];

        let victim_node = tcg.node(victim).unwrap();
        let is_leaf = victim_node.children.is_empty();
        if is_leaf && !tcg.subtree_pinned(victim) && victim != ROOT {
            // Drop the whole leaf subtree (node + snapshot).
            freed.extend(tcg.remove_subtree(victim));
        } else {
            // Interior node: keep the prefix structure, drop the sandbox.
            if let Some(n) = tcg.node_mut(victim) {
                if let Some(s) = n.snapshot.take() {
                    freed.push(s);
                }
            }
        }
    }
    freed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::key::{ToolCall, ToolResult};
    use std::sync::atomic::Ordering;

    fn snap(id: u64) -> SnapshotRef {
        SnapshotRef { id, bytes: 100, restore_cost: 0.1 }
    }

    fn grow_chain(g: &mut Tcg, n: usize) -> Vec<NodeId> {
        let mut ids = Vec::new();
        let mut cur = ROOT;
        for i in 0..n {
            cur = g.insert_child(
                cur,
                ToolCall::new("t", format!("c{i}")),
                ToolResult::new("", 1.0),
            );
            ids.push(cur);
        }
        ids
    }

    #[test]
    fn within_budget_is_noop() {
        let mut g = Tcg::new();
        let ids = grow_chain(&mut g, 3);
        for (i, &id) in ids.iter().enumerate() {
            g.set_snapshot(id, snap(i as u64));
        }
        let policy = EvictionPolicy { max_snapshots: 3, ..Default::default() };
        assert!(enforce_budget(&mut g, &policy).is_empty());
        assert_eq!(g.snapshot_count(), 3);
    }

    #[test]
    fn evicts_deepest_low_hit_first() {
        let mut g = Tcg::new();
        let ids = grow_chain(&mut g, 5);
        for (i, &id) in ids.iter().enumerate() {
            g.set_snapshot(id, snap(i as u64));
        }
        // Hits concentrated near the root.
        g.node_mut(ids[0]).unwrap().hits.store(50, Ordering::Relaxed);
        g.node_mut(ids[1]).unwrap().hits.store(20, Ordering::Relaxed);
        let policy = EvictionPolicy { max_snapshots: 2, ..Default::default() };
        let freed = enforce_budget(&mut g, &policy);
        assert_eq!(freed.len(), 3);
        assert_eq!(g.snapshot_count(), 2);
        // The shallow, hot nodes keep their snapshots.
        assert!(g.node(ids[0]).unwrap().snapshot.is_some());
        assert!(g.node(ids[1]).unwrap().snapshot.is_some());
    }

    #[test]
    fn pinned_sandboxes_survive() {
        let mut g = Tcg::new();
        let ids = grow_chain(&mut g, 3);
        for (i, &id) in ids.iter().enumerate() {
            g.set_snapshot(id, snap(i as u64));
        }
        g.node_mut(ids[2]).unwrap().refcount.store(1, Ordering::Release); // deepest but pinned
        let policy = EvictionPolicy { max_snapshots: 1, ..Default::default() };
        enforce_budget(&mut g, &policy);
        assert!(g.node(ids[2]).unwrap().snapshot.is_some());
    }

    #[test]
    fn all_pinned_cannot_enforce() {
        let mut g = Tcg::new();
        let ids = grow_chain(&mut g, 3);
        for (i, &id) in ids.iter().enumerate() {
            g.set_snapshot(id, snap(i as u64));
            g.node_mut(id).unwrap().refcount.store(1, Ordering::Release);
        }
        let policy = EvictionPolicy { max_snapshots: 1, ..Default::default() };
        assert!(enforce_budget(&mut g, &policy).is_empty());
        assert_eq!(g.snapshot_count(), 3);
    }

    #[test]
    fn leaf_eviction_removes_subtree_interior_keeps_structure() {
        let mut g = Tcg::new();
        let ids = grow_chain(&mut g, 3); // c0 -> c1 -> c2 (leaf)
        g.set_snapshot(ids[0], snap(0));
        g.set_snapshot(ids[2], snap(2));
        g.node_mut(ids[0]).unwrap().hits.store(100, Ordering::Relaxed); // keep the prefix
        let policy = EvictionPolicy { max_snapshots: 1, ..Default::default() };
        enforce_budget(&mut g, &policy);
        // Leaf node c2 should be gone entirely; interior c0, c1 remain.
        assert!(g.node(ids[2]).is_none());
        assert!(g.node(ids[1]).is_some());
        assert!(g.node(ids[0]).unwrap().snapshot.is_some());
    }

    #[test]
    fn branching_nodes_preferred_over_leaves() {
        let mut g = Tcg::new();
        // hub has 3 children; lone is an isolated same-depth chain.
        let hub = g.insert_child(ROOT, ToolCall::new("t", "hub"), ToolResult::new("", 1.0));
        for i in 0..3 {
            g.insert_child(hub, ToolCall::new("t", format!("x{i}")), ToolResult::new("", 1.0));
        }
        let lone = g.insert_child(ROOT, ToolCall::new("t", "lone"), ToolResult::new("", 1.0));
        g.set_snapshot(hub, snap(1));
        g.set_snapshot(lone, snap(2));
        let policy = EvictionPolicy { max_snapshots: 1, ..Default::default() };
        enforce_budget(&mut g, &policy);
        assert!(g.node(hub).unwrap().snapshot.is_some(), "hub must survive");
    }
}
