//! Sandbox-budget enforcement (§3.3 "Bounding number of cached sandboxes").
//!
//! Each task has a budget of stored sandboxes — a count *and* a byte budget.
//! When exceeded, TVCACHE prunes the least useful snapshots: eviction scores
//! favour keeping nodes that are shallow (common prefixes), well-branched
//! (shared by many trajectories), frequently hit, small, and expensive to
//! re-derive by replay (the recorded `exec_time` latencies of the calls a
//! snapshot lets a rollout skip); refcount-pinned sandboxes are never
//! evicted (§3.4 "Concurrency Control"). The same score orders the sharded
//! service's background spill worker (`cache/spill.rs`).

use super::tcg::{NodeId, SnapshotRef, Tcg, ROOT};

/// Tunable eviction weights.
#[derive(Debug, Clone, Copy)]
pub struct EvictionPolicy {
    /// Sandbox budget: max snapshots stored per task.
    pub max_snapshots: usize,
    /// Byte budget for this task's snapshots (`u64::MAX` = unbounded).
    pub max_snapshot_bytes: u64,
    /// Weight of hit count in the keep-score.
    pub hit_weight: f64,
    /// Weight of child count (branching ⇒ common prefix worth keeping).
    pub child_weight: f64,
    /// Depth penalty (deeper ⇒ more specialized ⇒ likelier to evict).
    pub depth_weight: f64,
    /// Size penalty, per MiB of snapshot payload (bigger ⇒ evict sooner).
    pub byte_weight: f64,
    /// Weight of the recreation cost: seconds of recorded replay latency
    /// needed to re-derive the node's state if its snapshot were dropped.
    pub recreate_weight: f64,
}

impl Default for EvictionPolicy {
    fn default() -> Self {
        EvictionPolicy {
            max_snapshots: 64,
            max_snapshot_bytes: u64::MAX,
            hit_weight: 1.0,
            child_weight: 2.0,
            depth_weight: 0.5,
            byte_weight: 1.0,
            recreate_weight: 0.05,
        }
    }
}

/// Seconds of replay needed to rebuild `id`'s sandbox state without its
/// snapshot: the recorded `exec_time` of every call on the path from the
/// nearest snapshotted *ancestor* (exclusive) down to `id` (inclusive).
/// These latencies were sampled by the sandbox latency models
/// (`sandbox/latency.rs`) when the calls first executed.
pub fn recreation_cost(tcg: &Tcg, id: NodeId) -> f64 {
    let mut cost = 0.0;
    let mut cur = id;
    while cur != ROOT {
        let Some(n) = tcg.node(cur) else { break };
        cost += n.result.exec_time;
        let parent = n.parent;
        if parent == ROOT
            || tcg.node(parent).map(|p| p.snapshot.is_some()).unwrap_or(true)
        {
            break;
        }
        cur = parent;
    }
    cost
}

impl EvictionPolicy {
    /// Higher = more worth keeping.
    pub fn keep_score(&self, tcg: &Tcg, id: NodeId) -> f64 {
        let Some(n) = tcg.node(id) else { return f64::NEG_INFINITY };
        let bytes = n.snapshot.map(|s| s.bytes).unwrap_or(0) as f64;
        self.hit_weight * (n.hit_count() as f64 + 1.0).ln()
            + self.child_weight * n.children.len() as f64
            - self.depth_weight * n.depth as f64
            - self.byte_weight * bytes / (1u64 << 20) as f64
            + self.recreate_weight * recreation_cost(tcg, id)
    }

    /// True when `tcg` violates either the count or the byte budget.
    pub fn over_budget(&self, tcg: &Tcg) -> bool {
        tcg.snapshot_count() > self.max_snapshots
            || tcg.snapshot_bytes() > self.max_snapshot_bytes
    }
}

/// Evict snapshots until both the count and the byte budget hold. Returns
/// the freed snapshot refs (the sandbox manager destroys the corresponding
/// sandboxes). Pinned (refcount > 0) sandboxes are skipped; leaf nodes
/// whose subtree carries no other snapshot are removed from the TCG
/// entirely ("evicting subtrees"). Victim order is deterministic: worst
/// keep-score first, node id breaking ties.
pub fn enforce_budget(tcg: &mut Tcg, policy: &EvictionPolicy) -> Vec<SnapshotRef> {
    let mut freed = Vec::new();
    loop {
        if !policy.over_budget(tcg) {
            break;
        }
        // Candidates: snapshot-bearing, unpinned nodes, worst score first.
        let mut candidates: Vec<(f64, NodeId)> = tcg
            .live_nodes()
            .into_iter()
            .filter(|&id| {
                tcg.node(id)
                    .map(|n| n.snapshot.is_some() && !n.is_pinned())
                    .unwrap_or(false)
            })
            .map(|id| (policy.keep_score(tcg, id), id))
            .collect();
        if candidates.is_empty() {
            break; // everything pinned: cannot enforce further
        }
        candidates.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let (_, victim) = candidates[0];

        let victim_node = tcg.node(victim).unwrap();
        let is_leaf = victim_node.children.is_empty();
        if is_leaf && !tcg.subtree_pinned(victim) && victim != ROOT {
            // Drop the whole leaf subtree (node + snapshot).
            freed.extend(tcg.remove_subtree(victim));
        } else {
            // Interior node: keep the prefix structure, drop the sandbox.
            if let Some(n) = tcg.node_mut(victim) {
                if let Some(s) = n.snapshot.take() {
                    freed.push(s);
                }
            }
        }
    }
    freed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::key::{ToolCall, ToolResult};
    use std::sync::atomic::Ordering;

    fn snap(id: u64) -> SnapshotRef {
        SnapshotRef { id, bytes: 100, restore_cost: 0.1 }
    }

    fn grow_chain(g: &mut Tcg, n: usize) -> Vec<NodeId> {
        let mut ids = Vec::new();
        let mut cur = ROOT;
        for i in 0..n {
            cur = g.insert_child(
                cur,
                ToolCall::new("t", format!("c{i}")),
                ToolResult::new("", 1.0),
            );
            ids.push(cur);
        }
        ids
    }

    #[test]
    fn within_budget_is_noop() {
        let mut g = Tcg::new();
        let ids = grow_chain(&mut g, 3);
        for (i, &id) in ids.iter().enumerate() {
            g.set_snapshot(id, snap(i as u64));
        }
        let policy = EvictionPolicy { max_snapshots: 3, ..Default::default() };
        assert!(enforce_budget(&mut g, &policy).is_empty());
        assert_eq!(g.snapshot_count(), 3);
    }

    #[test]
    fn evicts_deepest_low_hit_first() {
        let mut g = Tcg::new();
        let ids = grow_chain(&mut g, 5);
        for (i, &id) in ids.iter().enumerate() {
            g.set_snapshot(id, snap(i as u64));
        }
        // Hits concentrated near the root.
        g.node_mut(ids[0]).unwrap().hits.store(50, Ordering::Relaxed);
        g.node_mut(ids[1]).unwrap().hits.store(20, Ordering::Relaxed);
        let policy = EvictionPolicy { max_snapshots: 2, ..Default::default() };
        let freed = enforce_budget(&mut g, &policy);
        assert_eq!(freed.len(), 3);
        assert_eq!(g.snapshot_count(), 2);
        // The shallow, hot nodes keep their snapshots.
        assert!(g.node(ids[0]).unwrap().snapshot.is_some());
        assert!(g.node(ids[1]).unwrap().snapshot.is_some());
    }

    #[test]
    fn pinned_sandboxes_survive() {
        let mut g = Tcg::new();
        let ids = grow_chain(&mut g, 3);
        for (i, &id) in ids.iter().enumerate() {
            g.set_snapshot(id, snap(i as u64));
        }
        g.node_mut(ids[2]).unwrap().refcount.store(1, Ordering::Release); // deepest but pinned
        let policy = EvictionPolicy { max_snapshots: 1, ..Default::default() };
        enforce_budget(&mut g, &policy);
        assert!(g.node(ids[2]).unwrap().snapshot.is_some());
    }

    #[test]
    fn all_pinned_cannot_enforce() {
        let mut g = Tcg::new();
        let ids = grow_chain(&mut g, 3);
        for (i, &id) in ids.iter().enumerate() {
            g.set_snapshot(id, snap(i as u64));
            g.node_mut(id).unwrap().refcount.store(1, Ordering::Release);
        }
        let policy = EvictionPolicy { max_snapshots: 1, ..Default::default() };
        assert!(enforce_budget(&mut g, &policy).is_empty());
        assert_eq!(g.snapshot_count(), 3);
    }

    #[test]
    fn leaf_eviction_removes_subtree_interior_keeps_structure() {
        let mut g = Tcg::new();
        let ids = grow_chain(&mut g, 3); // c0 -> c1 -> c2 (leaf)
        g.set_snapshot(ids[0], snap(0));
        g.set_snapshot(ids[2], snap(2));
        g.node_mut(ids[0]).unwrap().hits.store(100, Ordering::Relaxed); // keep the prefix
        let policy = EvictionPolicy { max_snapshots: 1, ..Default::default() };
        enforce_budget(&mut g, &policy);
        // Leaf node c2 should be gone entirely; interior c0, c1 remain.
        assert!(g.node(ids[2]).is_none());
        assert!(g.node(ids[1]).is_some());
        assert!(g.node(ids[0]).unwrap().snapshot.is_some());
    }

    #[test]
    fn byte_budget_enforced_independently_of_count() {
        let mut g = Tcg::new();
        let ids = grow_chain(&mut g, 4);
        for (i, &id) in ids.iter().enumerate() {
            g.set_snapshot(id, snap(i as u64)); // 100 bytes each
        }
        // Count budget satisfied (4 ≤ 64) but 400 bytes > 250.
        let policy = EvictionPolicy { max_snapshot_bytes: 250, ..Default::default() };
        let freed = enforce_budget(&mut g, &policy);
        assert_eq!(freed.len(), 2);
        assert_eq!(g.snapshot_count(), 2);
        assert!(g.snapshot_bytes() <= 250);
    }

    #[test]
    fn recreation_cost_spans_to_nearest_snapshotted_ancestor() {
        let mut g = Tcg::new();
        let ids = grow_chain(&mut g, 4); // exec_time 1.0 each
        // Snapshot at depth 1; cost of depth-4 node = replay of depths 2..4.
        g.set_snapshot(ids[0], snap(1));
        assert!((recreation_cost(&g, ids[3]) - 3.0).abs() < 1e-9);
        // The snapshotted node itself replays from the root's fresh state.
        assert!((recreation_cost(&g, ids[0]) - 1.0).abs() < 1e-9);
        // No snapshots above: full replay from the root.
        assert!((recreation_cost(&g, ids[2]) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn eviction_order_is_deterministic() {
        let build = || {
            let mut g = Tcg::new();
            let ids = grow_chain(&mut g, 6);
            for (i, &id) in ids.iter().enumerate() {
                g.set_snapshot(id, snap(i as u64));
            }
            g
        };
        let policy = EvictionPolicy { max_snapshots: 1, ..Default::default() };
        let mut a = build();
        let mut b = build();
        let fa: Vec<u64> = enforce_budget(&mut a, &policy).iter().map(|s| s.id).collect();
        let fb: Vec<u64> = enforce_budget(&mut b, &policy).iter().map(|s| s.id).collect();
        assert_eq!(fa, fb, "identical graphs must evict in identical order");
        assert_eq!(fa.len(), 5);
    }

    #[test]
    fn branching_nodes_preferred_over_leaves() {
        let mut g = Tcg::new();
        // hub has 3 children; lone is an isolated same-depth chain.
        let hub = g.insert_child(ROOT, ToolCall::new("t", "hub"), ToolResult::new("", 1.0));
        for i in 0..3 {
            g.insert_child(hub, ToolCall::new("t", format!("x{i}")), ToolResult::new("", 1.0));
        }
        let lone = g.insert_child(ROOT, ToolCall::new("t", "lone"), ToolResult::new("", 1.0));
        g.set_snapshot(hub, snap(1));
        g.set_snapshot(lone, snap(2));
        let policy = EvictionPolicy { max_snapshots: 1, ..Default::default() };
        enforce_budget(&mut g, &policy);
        assert!(g.node(hub).unwrap().snapshot.is_some(), "hub must survive");
    }
}
