//! Per-task cache facade: thread-safe TCG + LPM + policies + statistics.
//!
//! This is the object the TVCACHE server holds per task (§3.4): every
//! endpoint manipulates the graph through this API, which wraps the TCG in
//! a `RwLock` and wires the selective-snapshot and eviction policies in.

use std::sync::RwLock;

use super::eviction::{enforce_budget, EvictionPolicy};
use super::key::{ToolCall, ToolResult};
use super::lpm::{lookup, Lookup, LpmConfig};
use super::snapshot::{SnapshotCosts, SnapshotPolicy};
use super::tcg::{NodeId, SnapshotRef, Tcg, ROOT};
use crate::util::json::Json;

/// Aggregate cache statistics (served by `/stats`; drives Figures 5/12).
#[derive(Debug, Clone, Default)]
pub struct CacheStats {
    pub lookups: u64,
    pub hits: u64,
    /// Misses that still matched a non-empty prefix (LPM partial hits).
    pub partial_hits: u64,
    /// Misses resumed from a forked snapshot rather than a fresh sandbox.
    pub snapshot_resumes: u64,
    pub inserts: u64,
    pub snapshots_stored: u64,
    pub snapshots_evicted: u64,
    /// External-API tokens saved by hits (EgoSchema §4.3 accounting).
    pub api_tokens_saved: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("lookups", Json::num(self.lookups as f64)),
            ("hits", Json::num(self.hits as f64)),
            ("partial_hits", Json::num(self.partial_hits as f64)),
            ("snapshot_resumes", Json::num(self.snapshot_resumes as f64)),
            ("inserts", Json::num(self.inserts as f64)),
            ("snapshots_stored", Json::num(self.snapshots_stored as f64)),
            ("snapshots_evicted", Json::num(self.snapshots_evicted as f64)),
            ("api_tokens_saved", Json::num(self.api_tokens_saved as f64)),
            ("hit_rate", Json::num(self.hit_rate())),
        ])
    }
}

/// The per-task cache.
pub struct TaskCache {
    inner: RwLock<Inner>,
    pub lpm: LpmConfig,
    pub snapshot_policy: SnapshotPolicy,
    pub eviction: EvictionPolicy,
}

struct Inner {
    tcg: Tcg,
    stats: CacheStats,
}

impl TaskCache {
    pub fn new(lpm: LpmConfig, snapshot_policy: SnapshotPolicy, eviction: EvictionPolicy) -> Self {
        TaskCache {
            inner: RwLock::new(Inner { tcg: Tcg::new(), stats: CacheStats::default() }),
            lpm,
            snapshot_policy,
            eviction,
        }
    }

    pub fn with_defaults() -> Self {
        Self::new(LpmConfig::default(), SnapshotPolicy::default(), EvictionPolicy::default())
    }

    /// §3.2 cache lookup. On a hit, bumps hit counters (and the token-saved
    /// accounting). On a miss with a snapshot resume, *increments the
    /// refcount* of the resume node — the caller must `release` it after
    /// forking (§3.4 Concurrency Control).
    pub fn lookup(&self, q: &[ToolCall]) -> Lookup {
        let mut inner = self.inner.write().unwrap();
        inner.stats.lookups += 1;
        let result = lookup(&inner.tcg, q, self.lpm);
        match &result {
            Lookup::Hit { node, result } => {
                inner.stats.hits += 1;
                inner.stats.api_tokens_saved += result.api_tokens;
                let node = *node;
                if let Some(n) = inner.tcg.node_mut(node) {
                    n.hits += 1;
                }
            }
            Lookup::Miss(m) => {
                if m.matched_calls > 0 {
                    inner.stats.partial_hits += 1;
                }
                if let Some((node, _, _)) = m.resume {
                    inner.stats.snapshot_resumes += 1;
                    if let Some(n) = inner.tcg.node_mut(node) {
                        n.refcount += 1;
                    }
                }
            }
        }
        result
    }

    /// Decrement a node's sandbox refcount (client done forking).
    pub fn release(&self, node: NodeId) {
        let mut inner = self.inner.write().unwrap();
        if let Some(n) = inner.tcg.node_mut(node) {
            n.refcount = n.refcount.saturating_sub(1);
        }
    }

    /// Upsert an executed trajectory (`/put`). Walks the root→leaf path,
    /// creating state-mutating nodes and indexing stateless results on their
    /// parent node (Appendix B "Addition to TCG"). Returns the id of the
    /// final state-mutating node on the path.
    pub fn record_trajectory(&self, traj: &[(ToolCall, ToolResult)]) -> NodeId {
        let mut inner = self.inner.write().unwrap();
        let mut cur = ROOT;
        let mut inserted = 0u64;
        for (call, result) in traj {
            if self.lpm.stateful_filtering && !call.mutates_state {
                if inner.tcg.stateless_result(cur, call).is_none() {
                    inner.tcg.insert_stateless(cur, call.clone(), result.clone());
                    inserted += 1;
                }
            } else {
                let before = inner.tcg.len();
                cur = inner.tcg.insert_child(cur, call.clone(), result.clone());
                if inner.tcg.len() > before {
                    inserted += 1;
                }
            }
        }
        inner.stats.inserts += inserted;
        cur
    }

    /// §3.3 selective snapshotting decision for the node at the end of
    /// `traj`'s state-mutating chain. If the policy approves, the caller
    /// serializes the sandbox and calls [`TaskCache::attach_snapshot`].
    pub fn should_snapshot(&self, costs: SnapshotCosts) -> bool {
        self.snapshot_policy.should_snapshot(costs)
    }

    /// Attach a snapshot to a node, then enforce the sandbox budget.
    /// Returns snapshots freed by eviction (caller destroys the sandboxes).
    pub fn attach_snapshot(&self, node: NodeId, snap: SnapshotRef) -> Vec<SnapshotRef> {
        let mut inner = self.inner.write().unwrap();
        inner.tcg.set_snapshot(node, snap);
        inner.stats.snapshots_stored += 1;
        let freed = enforce_budget(&mut inner.tcg, &self.eviction);
        inner.stats.snapshots_evicted += freed.len() as u64;
        freed
    }

    /// Mark that a background fork for `node` is warm (§3.3 proactive fork).
    pub fn set_warm_fork(&self, node: NodeId, warm: bool) {
        let mut inner = self.inner.write().unwrap();
        if let Some(n) = inner.tcg.node_mut(node) {
            n.warm_fork = warm;
        }
    }

    pub fn has_warm_fork(&self, node: NodeId) -> bool {
        let inner = self.inner.read().unwrap();
        inner.tcg.node(node).map(|n| n.warm_fork).unwrap_or(false)
    }

    pub fn stats(&self) -> CacheStats {
        self.inner.read().unwrap().stats.clone()
    }

    pub fn node_count(&self) -> usize {
        self.inner.read().unwrap().tcg.len()
    }

    pub fn snapshot_count(&self) -> usize {
        self.inner.read().unwrap().tcg.snapshot_count()
    }

    pub fn snapshot_bytes(&self) -> u64 {
        self.inner.read().unwrap().tcg.snapshot_bytes()
    }

    /// Nodes carrying snapshots (candidates for proactive forking).
    pub fn snapshotted_nodes(&self) -> Vec<(NodeId, SnapshotRef)> {
        let inner = self.inner.read().unwrap();
        inner
            .tcg
            .live_nodes()
            .into_iter()
            .filter_map(|id| inner.tcg.node(id).and_then(|n| n.snapshot.map(|s| (id, s))))
            .collect()
    }

    /// `/viz` rendering of the graph (Figure 9).
    pub fn viz_json(&self) -> Json {
        self.inner.read().unwrap().tcg.to_json()
    }

    /// Serialize the full graph (persistence, §3.4 "persists TCG snapshots
    /// periodically to disk").
    pub fn to_persistent_json(&self) -> Json {
        let inner = self.inner.read().unwrap();
        let mut nodes = Vec::new();
        for id in inner.tcg.live_nodes() {
            let n = inner.tcg.node(id).unwrap();
            let mut entry = vec![
                ("id", Json::num(id as f64)),
                ("parent", Json::num(n.parent as f64)),
                ("call", n.call.to_json()),
                ("result", n.result.to_json()),
                ("hits", Json::num(n.hits as f64)),
            ];
            let stateless: Vec<Json> = n
                .stateless
                .values()
                .map(|(c, r)| {
                    Json::obj(vec![("call", c.to_json()), ("result", r.to_json())])
                })
                .collect();
            if !stateless.is_empty() {
                entry.push(("stateless", Json::Arr(stateless)));
            }
            nodes.push(Json::obj(entry));
        }
        Json::obj(vec![("nodes", Json::Arr(nodes))])
    }

    /// Rebuild a cache from [`TaskCache::to_persistent_json`] output.
    /// Snapshots are *not* restored (sandboxes died with the server); the
    /// trajectory/result structure is.
    pub fn from_persistent_json(v: &Json, lpm: LpmConfig) -> Option<TaskCache> {
        let cache = TaskCache::new(lpm, SnapshotPolicy::default(), EvictionPolicy::default());
        {
            let mut inner = cache.inner.write().unwrap();
            let nodes = v.get("nodes")?.as_arr()?;
            // Persistent ids -> rebuilt ids. Entries are serialized in id
            // order, so parents always precede children.
            let mut id_map = std::collections::HashMap::new();
            id_map.insert(ROOT as u64, ROOT);
            for entry in nodes {
                let old_id = entry.get("id")?.as_u64()?;
                let old_parent = entry.get("parent")?.as_u64()?;
                let call = ToolCall::from_json(entry.get("call")?)?;
                let result = ToolResult::from_json(entry.get("result")?)?;
                let parent = *id_map.get(&old_parent)?;
                let new_id = inner.tcg.insert_child(parent, call, result);
                if let Some(hits) = entry.get("hits").and_then(|h| h.as_u64()) {
                    if let Some(n) = inner.tcg.node_mut(new_id) {
                        n.hits = hits;
                    }
                }
                if let Some(stateless) = entry.get("stateless").and_then(|s| s.as_arr()) {
                    for s in stateless {
                        let c = ToolCall::from_json(s.get("call")?)?;
                        let r = ToolResult::from_json(s.get("result")?)?;
                        inner.tcg.insert_stateless(new_id, c, r);
                    }
                }
                id_map.insert(old_id, new_id);
            }
        }
        Some(cache)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sf(s: &str) -> ToolCall {
        ToolCall::new("t", s)
    }

    fn traj(calls: &[&str]) -> Vec<(ToolCall, ToolResult)> {
        calls
            .iter()
            .map(|c| (sf(c), ToolResult::new(format!("out-{c}"), 1.0)))
            .collect()
    }

    #[test]
    fn miss_then_record_then_hit() {
        let cache = TaskCache::with_defaults();
        let q = vec![sf("a"), sf("b")];
        assert!(!cache.lookup(&q).is_hit());
        cache.record_trajectory(&traj(&["a", "b"]));
        match cache.lookup(&q) {
            Lookup::Hit { result, .. } => assert_eq!(result.output, "out-b"),
            m => panic!("{m:?}"),
        }
        let s = cache.stats();
        assert_eq!(s.lookups, 2);
        assert_eq!(s.hits, 1);
        assert!((s.hit_rate() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn lookup_miss_pins_resume_node_until_release() {
        let cache = TaskCache::with_defaults();
        let leaf = cache.record_trajectory(&traj(&["a", "b"]));
        cache.attach_snapshot(leaf, SnapshotRef { id: 7, bytes: 64, restore_cost: 0.2 });
        let q = vec![sf("a"), sf("b"), sf("x")];
        let Lookup::Miss(m) = cache.lookup(&q) else { panic!("expected miss") };
        let (node, _, _) = m.resume.unwrap();
        assert_eq!(node, leaf);
        // Pinned: eviction with budget 0 cannot free it.
        {
            let mut inner = cache.inner.write().unwrap();
            let policy = EvictionPolicy { max_snapshots: 0, ..Default::default() };
            assert!(enforce_budget(&mut inner.tcg, &policy).is_empty());
        }
        cache.release(node);
        {
            let mut inner = cache.inner.write().unwrap();
            let policy = EvictionPolicy { max_snapshots: 0, ..Default::default() };
            assert_eq!(enforce_budget(&mut inner.tcg, &policy).len(), 1);
        }
    }

    #[test]
    fn attach_snapshot_enforces_budget() {
        let cache = TaskCache::new(
            LpmConfig::default(),
            SnapshotPolicy::default(),
            EvictionPolicy { max_snapshots: 2, ..Default::default() },
        );
        let mut freed_total = 0;
        for i in 0..5 {
            let leaf =
                cache.record_trajectory(&traj(&["p", &format!("leaf{i}")]));
            let freed = cache.attach_snapshot(
                leaf,
                SnapshotRef { id: i, bytes: 10, restore_cost: 0.1 },
            );
            freed_total += freed.len();
        }
        assert!(cache.snapshot_count() <= 2);
        assert_eq!(freed_total, 3);
        assert_eq!(cache.stats().snapshots_evicted, 3);
    }

    #[test]
    fn record_trajectory_idempotent_counts() {
        let cache = TaskCache::with_defaults();
        cache.record_trajectory(&traj(&["a", "b", "c"]));
        cache.record_trajectory(&traj(&["a", "b", "c"]));
        assert_eq!(cache.node_count(), 3);
        assert_eq!(cache.stats().inserts, 3);
    }

    #[test]
    fn stateless_results_recorded_on_parent() {
        let cache = TaskCache::with_defaults();
        let mut t = traj(&["load", "preprocess"]);
        t.push((
            ToolCall::stateless("caption", "(0,10)"),
            ToolResult { output: "caps".into(), exec_time: 2.0, api_tokens: 500 },
        ));
        cache.record_trajectory(&t);
        // Hit regardless of a second stateless call in between.
        let q = vec![
            sf("load"),
            sf("preprocess"),
            ToolCall::stateless("other", "x"),
            ToolCall::stateless("caption", "(0,10)"),
        ];
        // Note: "other" isn't cached but it's not the current call.
        match cache.lookup(&q) {
            Lookup::Hit { result, .. } => assert_eq!(result.output, "caps"),
            m => panic!("{m:?}"),
        }
        assert_eq!(cache.stats().api_tokens_saved, 500);
    }

    #[test]
    fn persistence_roundtrip() {
        let cache = TaskCache::with_defaults();
        cache.record_trajectory(&traj(&["a", "b"]));
        cache.record_trajectory(&traj(&["a", "c"]));
        let mut t = traj(&["a"]);
        t.push((ToolCall::stateless("s", "1"), ToolResult::new("sr", 0.1)));
        cache.record_trajectory(&t);

        let json_text = cache.to_persistent_json().to_string();
        let parsed = crate::util::json::parse(&json_text).unwrap();
        let restored =
            TaskCache::from_persistent_json(&parsed, LpmConfig::default()).unwrap();
        assert_eq!(restored.node_count(), cache.node_count());
        assert!(restored.lookup(&[sf("a"), sf("b")]).is_hit());
        assert!(restored.lookup(&[sf("a"), sf("c")]).is_hit());
        assert!(restored
            .lookup(&[sf("a"), ToolCall::stateless("s", "1")])
            .is_hit());
        assert!(!restored.lookup(&[sf("a"), sf("zzz")]).is_hit());
    }

    #[test]
    fn concurrent_lookups_and_records() {
        use std::sync::Arc;
        let cache = Arc::new(TaskCache::with_defaults());
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let c = Arc::clone(&cache);
                std::thread::spawn(move || {
                    for i in 0..50 {
                        let calls = traj(&["shared", &format!("t{}-{}", t % 4, i % 10)]);
                        c.record_trajectory(&calls);
                        let q: Vec<ToolCall> =
                            calls.iter().map(|(c, _)| c.clone()).collect();
                        assert!(c.lookup(&q).is_hit());
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // 1 shared node + 4 t-branches × 10 leaves
        assert_eq!(cache.node_count(), 1 + 4 * 10);
    }
}
