//! Per-task cache facade: thread-safe TCG + LPM + policies + statistics.
//!
//! This is the object the TVCACHE service holds per task (§3.4): every
//! endpoint manipulates the graph through this API. The hot read path
//! (`/get`, `/prefix_match`, `/release`, `/warm`) takes only a *read* lock
//! on the TCG: statistics live in atomics and the per-node counters
//! (`hits`, `refcount`, `warm_fork`) are atomic too, so concurrent lookups
//! never serialize on the graph. Only structural mutation — recording
//! trajectories, attaching snapshots, eviction — takes the write lock.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

use super::eviction::{enforce_budget, EvictionPolicy};
use super::key::{ToolCall, ToolResult};
use super::lpm::{cursor_step, lookup, CursorStep, Lookup, LpmConfig};
use super::snapshot::{SnapshotCosts, SnapshotPolicy};
use super::tcg::{NodeId, SnapshotRef, Tcg, ROOT};
use crate::util::json::Json;

/// Aggregate cache statistics (served by `/stats`; drives Figures 5/12).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub lookups: u64,
    pub hits: u64,
    /// Misses that still matched a non-empty prefix (LPM partial hits).
    pub partial_hits: u64,
    /// Misses resumed from a forked snapshot rather than a fresh sandbox.
    pub snapshot_resumes: u64,
    pub inserts: u64,
    pub snapshots_stored: u64,
    pub snapshots_evicted: u64,
    /// External-API tokens saved by hits (EgoSchema §4.3 accounting).
    pub api_tokens_saved: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        if self.lookups == 0 {
            0.0
        } else {
            self.hits as f64 / self.lookups as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("lookups", Json::num(self.lookups as f64)),
            ("hits", Json::num(self.hits as f64)),
            ("partial_hits", Json::num(self.partial_hits as f64)),
            ("snapshot_resumes", Json::num(self.snapshot_resumes as f64)),
            ("inserts", Json::num(self.inserts as f64)),
            ("snapshots_stored", Json::num(self.snapshots_stored as f64)),
            ("snapshots_evicted", Json::num(self.snapshots_evicted as f64)),
            ("api_tokens_saved", Json::num(self.api_tokens_saved as f64)),
            ("hit_rate", Json::num(self.hit_rate())),
        ])
    }

    /// Parse the `/stats?task=` wire format (the inverse of `to_json`).
    pub fn from_json(v: &Json) -> Option<CacheStats> {
        let g = |k: &str| v.get(k).and_then(Json::as_u64).unwrap_or(0);
        v.get("lookups")?;
        Some(CacheStats {
            lookups: g("lookups"),
            hits: g("hits"),
            partial_hits: g("partial_hits"),
            snapshot_resumes: g("snapshot_resumes"),
            inserts: g("inserts"),
            snapshots_stored: g("snapshots_stored"),
            snapshots_evicted: g("snapshots_evicted"),
            api_tokens_saved: g("api_tokens_saved"),
        })
    }
}

/// Lock-free statistic counters (read path bumps these under a read lock).
#[derive(Debug, Default)]
struct StatCounters {
    lookups: AtomicU64,
    hits: AtomicU64,
    partial_hits: AtomicU64,
    snapshot_resumes: AtomicU64,
    inserts: AtomicU64,
    snapshots_stored: AtomicU64,
    snapshots_evicted: AtomicU64,
    api_tokens_saved: AtomicU64,
}

impl StatCounters {
    fn snapshot(&self) -> CacheStats {
        CacheStats {
            lookups: self.lookups.load(Ordering::Relaxed),
            hits: self.hits.load(Ordering::Relaxed),
            partial_hits: self.partial_hits.load(Ordering::Relaxed),
            snapshot_resumes: self.snapshot_resumes.load(Ordering::Relaxed),
            inserts: self.inserts.load(Ordering::Relaxed),
            snapshots_stored: self.snapshots_stored.load(Ordering::Relaxed),
            snapshots_evicted: self.snapshots_evicted.load(Ordering::Relaxed),
            api_tokens_saved: self.api_tokens_saved.load(Ordering::Relaxed),
        }
    }
}

/// The per-task cache.
pub struct TaskCache {
    tcg: RwLock<Tcg>,
    stats: StatCounters,
    pub lpm: LpmConfig,
    pub snapshot_policy: SnapshotPolicy,
    pub eviction: EvictionPolicy,
}

impl TaskCache {
    pub fn new(lpm: LpmConfig, snapshot_policy: SnapshotPolicy, eviction: EvictionPolicy) -> Self {
        TaskCache {
            tcg: RwLock::new(Tcg::new()),
            stats: StatCounters::default(),
            lpm,
            snapshot_policy,
            eviction,
        }
    }

    pub fn with_defaults() -> Self {
        Self::new(LpmConfig::default(), SnapshotPolicy::default(), EvictionPolicy::default())
    }

    /// §3.2 cache lookup. On a hit, bumps hit counters (and the token-saved
    /// accounting). On a miss with a snapshot resume, *increments the
    /// refcount* of the resume node — the caller must `release` it after
    /// forking (§3.4 Concurrency Control).
    ///
    /// Takes only a read lock: the refcount increment happens under the same
    /// guard that produced the resume offer, so eviction (which needs the
    /// write lock) can never interleave between the offer and the pin.
    pub fn lookup(&self, q: &[ToolCall]) -> Lookup {
        let tcg = self.tcg.read().unwrap();
        self.stats.lookups.fetch_add(1, Ordering::Relaxed);
        let result = lookup(&tcg, q, self.lpm);
        match &result {
            Lookup::Hit { node, result } => {
                self.stats.hits.fetch_add(1, Ordering::Relaxed);
                self.stats.api_tokens_saved.fetch_add(result.api_tokens, Ordering::Relaxed);
                if let Some(n) = tcg.node(*node) {
                    n.hits.fetch_add(1, Ordering::Relaxed);
                }
            }
            Lookup::Miss(m) => {
                if m.matched_calls > 0 {
                    self.stats.partial_hits.fetch_add(1, Ordering::Relaxed);
                }
                if let Some((node, _, _)) = m.resume {
                    self.stats.snapshot_resumes.fetch_add(1, Ordering::Relaxed);
                    if let Some(n) = tcg.node(node) {
                        n.refcount.fetch_add(1, Ordering::AcqRel);
                    }
                }
            }
        }
        result
    }

    /// Eviction generation of this task's TCG (cursor invalidation tag).
    pub fn eviction_generation(&self) -> u64 {
        self.tcg.read().unwrap().generation()
    }

    /// One incremental cursor step (the O(1) hot-path lookup, §3.2 made
    /// stateful). `pos`/`steps`/`gen` are the cursor's pinned position,
    /// consumed-call count, and the eviction generation at which that
    /// position was last verified. Returns the step outcome plus the
    /// updated `(pos, gen)` the cursor should carry forward.
    ///
    /// Statistics and the §3.4 resume-offer pin behave exactly as
    /// [`TaskCache::lookup`]: hits bump hit counters under the read guard,
    /// a miss with a resume offer increments the resume node's refcount
    /// before the guard drops. An [`CursorStep::Invalid`] outcome (the
    /// cursor's node was evicted) bumps *nothing* — the caller falls back
    /// to a full-prefix lookup, which does its own accounting.
    pub fn cursor_step_at(
        &self,
        pos: NodeId,
        steps: usize,
        gen: u64,
        call: &ToolCall,
    ) -> (CursorStep, NodeId, u64) {
        let tcg = self.tcg.read().unwrap();
        let cur_gen = tcg.generation();
        // Invalidation check: an unchanged generation proves no removal
        // happened since this position was last verified under a guard, so
        // the position is still live. On a mismatch, probe the position
        // itself — node ids are never reused (tombstoned arena), so a live
        // probe is conclusive. (A future refactor that recycles ids must
        // turn this mismatch branch into an unconditional invalidation:
        // the probe could then land on an impostor node.)
        if gen != cur_gen && tcg.node(pos).is_none() {
            return (CursorStep::Invalid, pos, gen);
        }
        let Some((step, next)) = cursor_step(&tcg, pos, steps, call, self.lpm) else {
            // Defense in depth; unreachable given the generation check.
            return (CursorStep::Invalid, pos, gen);
        };
        self.stats.lookups.fetch_add(1, Ordering::Relaxed);
        match &step {
            CursorStep::Hit { node, result } => {
                self.stats.hits.fetch_add(1, Ordering::Relaxed);
                self.stats.api_tokens_saved.fetch_add(result.api_tokens, Ordering::Relaxed);
                if let Some(n) = tcg.node(*node) {
                    n.hits.fetch_add(1, Ordering::Relaxed);
                }
            }
            CursorStep::Miss(m) => {
                if m.matched_calls > 0 {
                    self.stats.partial_hits.fetch_add(1, Ordering::Relaxed);
                }
                if let Some((node, _, _)) = m.resume {
                    self.stats.snapshot_resumes.fetch_add(1, Ordering::Relaxed);
                    if let Some(n) = tcg.node(node) {
                        n.refcount.fetch_add(1, Ordering::AcqRel);
                    }
                }
            }
            CursorStep::Invalid => unreachable!("cursor_step never returns Invalid"),
        }
        (step, next, cur_gen)
    }

    /// Record the single delta call a cursor just missed on — the
    /// incremental counterpart of [`TaskCache::record_trajectory`]. Returns
    /// the cursor's new `(pos, gen)`, or `None` when `pos` was evicted (the
    /// caller falls back to a full-trajectory insert).
    pub fn cursor_record_at(
        &self,
        pos: NodeId,
        call: &ToolCall,
        result: &ToolResult,
    ) -> Option<(NodeId, u64)> {
        let mut tcg = self.tcg.write().unwrap();
        tcg.node(pos)?;
        let node = if self.lpm.stateful_filtering && !call.mutates_state {
            if tcg.stateless_result(pos, call).is_none() {
                tcg.insert_stateless(pos, call.clone(), result.clone());
                self.stats.inserts.fetch_add(1, Ordering::Relaxed);
            }
            pos
        } else {
            let before = tcg.len();
            let id = tcg.insert_child(pos, call.clone(), result.clone());
            if tcg.len() > before {
                self.stats.inserts.fetch_add(1, Ordering::Relaxed);
            }
            id
        };
        Some((node, tcg.generation()))
    }

    /// Speculative stateless probe at a session's position: the cached
    /// result of `call` in `pos`'s side index, if any. Unlike
    /// [`TaskCache::cursor_step_at`] this never advances the position,
    /// touches statistics, or pins a resume offer — probes are pure hints
    /// batched alongside a turn's real op, and must not perturb the
    /// hit/miss accounting the real calls produce.
    pub fn probe_stateless(&self, pos: NodeId, call: &ToolCall) -> Option<ToolResult> {
        if call.mutates_state {
            return None;
        }
        let tcg = self.tcg.read().unwrap();
        tcg.node(pos)?;
        tcg.stateless_result(pos, call).cloned()
    }

    /// Validate a cursor re-seek target: `Some(generation)` when `node` is
    /// live (ROOT always is), `None` otherwise.
    pub fn cursor_seek_check(&self, node: NodeId) -> Option<u64> {
        let tcg = self.tcg.read().unwrap();
        tcg.node(node)?;
        Some(tcg.generation())
    }

    /// White-box subtree eviction (tests of cursor invalidation and of the
    /// resume-offer race): remove `node`'s subtree unless any node in it is
    /// refcount-pinned. Returns the freed snapshot refs — the caller owns
    /// dropping the corresponding store bytes.
    pub fn remove_subtree_if_unpinned(&self, node: NodeId) -> Option<Vec<SnapshotRef>> {
        let mut tcg = self.tcg.write().unwrap();
        if node == ROOT || tcg.node(node).is_none() || tcg.subtree_pinned(node) {
            return None;
        }
        let freed = tcg.remove_subtree(node);
        self.stats.snapshots_evicted.fetch_add(freed.len() as u64, Ordering::Relaxed);
        Some(freed)
    }

    /// Decrement a node's sandbox refcount (client done forking).
    pub fn release(&self, node: NodeId) {
        let tcg = self.tcg.read().unwrap();
        if let Some(n) = tcg.node(node) {
            // Saturating decrement: a stray double-release never underflows.
            let _ = n.refcount.fetch_update(Ordering::AcqRel, Ordering::Acquire, |c| {
                c.checked_sub(1)
            });
        }
    }

    /// Upsert an executed trajectory (`/put`). Walks the root→leaf path,
    /// creating state-mutating nodes and indexing stateless results on their
    /// parent node (Appendix B "Addition to TCG"). Returns the id of the
    /// final state-mutating node on the path.
    pub fn record_trajectory(&self, traj: &[(ToolCall, ToolResult)]) -> NodeId {
        let mut tcg = self.tcg.write().unwrap();
        let mut cur = ROOT;
        let mut inserted = 0u64;
        for (call, result) in traj {
            if self.lpm.stateful_filtering && !call.mutates_state {
                if tcg.stateless_result(cur, call).is_none() {
                    tcg.insert_stateless(cur, call.clone(), result.clone());
                    inserted += 1;
                }
            } else {
                let before = tcg.len();
                cur = tcg.insert_child(cur, call.clone(), result.clone());
                if tcg.len() > before {
                    inserted += 1;
                }
            }
        }
        self.stats.inserts.fetch_add(inserted, Ordering::Relaxed);
        cur
    }

    /// §3.3 selective snapshotting decision for the node at the end of
    /// `traj`'s state-mutating chain. If the policy approves, the caller
    /// serializes the sandbox and calls [`TaskCache::attach_snapshot`].
    pub fn should_snapshot(&self, costs: SnapshotCosts) -> bool {
        self.snapshot_policy.should_snapshot(costs)
    }

    /// Attach a snapshot to a node, then enforce the sandbox budget.
    /// Returns the snapshots freed — any ref this attach *replaced* on the
    /// node plus everything eviction pruned — so the caller can drop the
    /// corresponding sandboxes/bytes and the snapshot store never leaks.
    ///
    /// Two attaches are rejected (the *new* ref comes back in the freed
    /// list, for the caller to drop): the node no longer exists (evicted
    /// between the caller's store insert and this attach), or the node is
    /// refcount-pinned while already carrying a snapshot — a resume-offer
    /// holder may be about to fetch that exact id, and since identical
    /// trajectories produce identical states the incumbent snapshot is
    /// just as good as the replacement (§3.4 Concurrency Control).
    pub fn attach_snapshot(&self, node: NodeId, snap: SnapshotRef) -> Vec<SnapshotRef> {
        let mut tcg = self.tcg.write().unwrap();
        let mut freed = Vec::new();
        if node == ROOT {
            // ROOT is the empty-state sentinel (and the wire-protocol
            // failure value): a snapshot of executed state attached at
            // depth 0 would hand later rollouts a sandbox containing
            // mutations they never made.
            freed.push(snap);
            return freed;
        }
        match tcg.node(node) {
            None => {
                freed.push(snap);
                return freed;
            }
            Some(n) => {
                if let Some(old) = n.snapshot {
                    if old.id == snap.id {
                        // Re-attach of the same id: nothing changes.
                        return freed;
                    } else if n.is_pinned() {
                        freed.push(snap);
                        return freed;
                    } else {
                        freed.push(old);
                    }
                }
            }
        }
        tcg.set_snapshot(node, snap);
        let evicted = enforce_budget(&mut tcg, &self.eviction);
        // Accounting matches what actually happened: a newcomer the budget
        // pruned immediately was never stored (and its removal is not an
        // eviction of previously stored state).
        if evicted.iter().any(|e| e.id == snap.id) {
            self.stats
                .snapshots_evicted
                .fetch_add((evicted.len() - 1) as u64, Ordering::Relaxed);
        } else {
            self.stats.snapshots_stored.fetch_add(1, Ordering::Relaxed);
            self.stats.snapshots_evicted.fetch_add(evicted.len() as u64, Ordering::Relaxed);
        }
        freed.extend(evicted);
        freed
    }

    /// Mark that a background fork for `node` is warm (§3.3 proactive fork).
    pub fn set_warm_fork(&self, node: NodeId, warm: bool) {
        let tcg = self.tcg.read().unwrap();
        if let Some(n) = tcg.node(node) {
            n.warm_fork.store(warm, Ordering::Release);
        }
    }

    pub fn has_warm_fork(&self, node: NodeId) -> bool {
        let tcg = self.tcg.read().unwrap();
        tcg.node(node).map(|n| n.warm_fork.load(Ordering::Acquire)).unwrap_or(false)
    }

    pub fn stats(&self) -> CacheStats {
        self.stats.snapshot()
    }

    pub fn node_count(&self) -> usize {
        self.tcg.read().unwrap().len()
    }

    pub fn snapshot_count(&self) -> usize {
        self.tcg.read().unwrap().snapshot_count()
    }

    pub fn snapshot_bytes(&self) -> u64 {
        self.tcg.read().unwrap().snapshot_bytes()
    }

    /// Nodes whose sandbox refcount is non-zero (diagnostics: a steady
    /// non-zero count after all rollouts finished means leaked pins).
    pub fn pinned_node_count(&self) -> usize {
        let tcg = self.tcg.read().unwrap();
        tcg.live_nodes()
            .into_iter()
            .filter(|&id| tcg.node(id).map(|n| n.is_pinned()).unwrap_or(false))
            .count()
    }

    /// Nodes carrying snapshots (candidates for proactive forking).
    pub fn snapshotted_nodes(&self) -> Vec<(NodeId, SnapshotRef)> {
        let tcg = self.tcg.read().unwrap();
        tcg.live_nodes()
            .into_iter()
            .filter_map(|id| tcg.node(id).and_then(|n| n.snapshot.map(|s| (id, s))))
            .collect()
    }

    /// Snapshot refs of refcount-pinned nodes (read lock only). The shard
    /// eviction worker collects these across *every* shard before picking
    /// victims: with content-addressed payloads, spilling an unpinned
    /// handle would demote the shared payload out from under a pinned
    /// handle in another task, so any candidate whose content key is
    /// pinned anywhere must be skipped.
    pub fn pinned_snapshot_refs(&self) -> Vec<SnapshotRef> {
        let tcg = self.tcg.read().unwrap();
        tcg.live_nodes()
            .into_iter()
            .filter_map(|id| {
                let n = tcg.node(id)?;
                if n.is_pinned() {
                    n.snapshot
                } else {
                    None
                }
            })
            .collect()
    }

    /// Snapshot-bearing, *unpinned* nodes with their keep-scores — the
    /// shard eviction/spill worker's candidate list (read lock only).
    /// Pinned nodes are excluded here, so they are never spilled either.
    pub fn eviction_candidates(&self) -> Vec<(f64, NodeId, SnapshotRef)> {
        let tcg = self.tcg.read().unwrap();
        tcg.live_nodes()
            .into_iter()
            .filter_map(|id| {
                let n = tcg.node(id)?;
                let snap = n.snapshot?;
                if n.is_pinned() {
                    return None;
                }
                Some((self.eviction.keep_score(&tcg, id), id, snap))
            })
            .collect()
    }

    /// Background-eviction entry point: detach `node`'s snapshot unless the
    /// node is refcount-pinned (a resume-offer holder may be about to fetch
    /// it). The graph structure is kept; the caller owns dropping the store
    /// bytes of the returned ref.
    pub fn detach_snapshot_if_unpinned(&self, node: NodeId) -> Option<SnapshotRef> {
        let mut tcg = self.tcg.write().unwrap();
        if tcg.node(node).map(|n| n.is_pinned()).unwrap_or(true) {
            return None;
        }
        let taken = tcg.node_mut(node).and_then(|n| n.snapshot.take());
        if taken.is_some() {
            self.stats.snapshots_evicted.fetch_add(1, Ordering::Relaxed);
        }
        taken
    }

    /// `/viz` rendering of the graph (Figure 9).
    pub fn viz_json(&self) -> Json {
        self.tcg.read().unwrap().to_json()
    }

    /// Serialize the full graph (persistence, §3.4 "persists TCG snapshots
    /// periodically to disk"), including each node's snapshot ref so a
    /// warm-started run can re-bind spilled payloads.
    pub fn to_persistent_json(&self) -> Json {
        let tcg = self.tcg.read().unwrap();
        let mut nodes = Vec::new();
        for id in tcg.live_nodes() {
            let n = tcg.node(id).unwrap();
            let mut entry = vec![
                ("id", Json::num(id as f64)),
                ("parent", Json::num(n.parent as f64)),
                ("call", n.call.to_json()),
                ("result", n.result.to_json()),
                ("hits", Json::num(n.hit_count() as f64)),
            ];
            if let Some(s) = n.snapshot {
                entry.push((
                    "snapshot",
                    Json::obj(vec![
                        ("id", Json::num(s.id as f64)),
                        ("bytes", Json::num(s.bytes as f64)),
                        ("restore_cost", Json::num(s.restore_cost)),
                    ]),
                ));
            }
            let stateless: Vec<Json> = n
                .stateless
                .values()
                .map(|(c, r)| {
                    Json::obj(vec![("call", c.to_json()), ("result", r.to_json())])
                })
                .collect();
            if !stateless.is_empty() {
                entry.push(("stateless", Json::Arr(stateless)));
            }
            nodes.push(Json::obj(entry));
        }
        Json::obj(vec![("nodes", Json::Arr(nodes))])
    }

    /// Rebuild a cache from [`TaskCache::to_persistent_json`] output.
    /// Snapshots are *not* restored (sandboxes died with the server); the
    /// trajectory/result structure is.
    pub fn from_persistent_json(v: &Json, lpm: LpmConfig) -> Option<TaskCache> {
        let cache = TaskCache::new(lpm, SnapshotPolicy::default(), EvictionPolicy::default());
        let (_, ok) = cache.load_persistent_json(v, &|_| false);
        if !ok {
            return None;
        }
        Some(cache)
    }

    /// Load [`TaskCache::to_persistent_json`] output into *this* cache
    /// (warm-start, §3.4): trajectories, hit counts, and stateless indices
    /// are merged in; a node's snapshot ref is re-attached only when
    /// `keep_snapshot(id)` confirms its payload survived (the spill
    /// manifest), so a truncated manifest can never leave dangling refs.
    /// Returns the re-attached `(node, ref)` pairs plus a completeness
    /// flag: `false` means the input was malformed part-way — whatever
    /// loaded (including the returned attach list, which the caller must
    /// still register in its store) stays loaded.
    pub fn load_persistent_json(
        &self,
        v: &Json,
        keep_snapshot: &dyn Fn(u64) -> bool,
    ) -> (Vec<(NodeId, SnapshotRef)>, bool) {
        let mut attached = Vec::new();
        let ok = self.load_persistent_inner(v, keep_snapshot, &mut attached).is_some();
        (attached, ok)
    }

    fn load_persistent_inner(
        &self,
        v: &Json,
        keep_snapshot: &dyn Fn(u64) -> bool,
        attached: &mut Vec<(NodeId, SnapshotRef)>,
    ) -> Option<()> {
        let mut tcg = self.tcg.write().unwrap();
        let nodes = v.get("nodes")?.as_arr()?;
        // Persistent ids -> rebuilt ids. Entries are serialized in id
        // order, so parents always precede children.
        let mut id_map = std::collections::HashMap::new();
        id_map.insert(ROOT as u64, ROOT);
        for entry in nodes {
            let old_id = entry.get("id")?.as_u64()?;
            let old_parent = entry.get("parent")?.as_u64()?;
            let call = ToolCall::from_json(entry.get("call")?)?;
            let result = ToolResult::from_json(entry.get("result")?)?;
            let parent = *id_map.get(&old_parent)?;
            let new_id = tcg.insert_child(parent, call, result);
            Self::load_node_extras(&mut tcg, new_id, entry, keep_snapshot, attached)?;
            id_map.insert(old_id, new_id);
        }
        Some(())
    }

    /// Like [`TaskCache::load_persistent_json`] but with node ids preserved
    /// **verbatim** — tombstone-padded holes included (follower bootstrap):
    /// every replicated op the follower is about to tail names the
    /// primary's ids, so a remapping load would corrupt the tail. Must run
    /// against a fresh (empty) cache; an entry that cannot land on its
    /// original id stops the load with `false`.
    pub fn load_bootstrap_json(
        &self,
        v: &Json,
        keep_snapshot: &dyn Fn(u64) -> bool,
    ) -> (Vec<(NodeId, SnapshotRef)>, bool) {
        let mut attached = Vec::new();
        let ok = self.load_bootstrap_inner(v, keep_snapshot, &mut attached).is_some();
        (attached, ok)
    }

    fn load_bootstrap_inner(
        &self,
        v: &Json,
        keep_snapshot: &dyn Fn(u64) -> bool,
        attached: &mut Vec<(NodeId, SnapshotRef)>,
    ) -> Option<()> {
        let mut tcg = self.tcg.write().unwrap();
        let nodes = v.get("nodes")?.as_arr()?;
        for entry in nodes {
            let id = entry.get("id")?.as_u64()? as NodeId;
            let parent = entry.get("parent")?.as_u64()? as NodeId;
            let call = ToolCall::from_json(entry.get("call")?)?;
            let result = ToolResult::from_json(entry.get("result")?)?;
            let node = tcg.insert_child_at(id, parent, call, result)?;
            Self::load_node_extras(&mut tcg, node, entry, keep_snapshot, attached)?;
        }
        Some(())
    }

    /// Shared tail of both persistent loads: hit counts, the snapshot ref
    /// (gated on `keep_snapshot`), and the stateless index of one node.
    fn load_node_extras(
        tcg: &mut Tcg,
        node: NodeId,
        entry: &Json,
        keep_snapshot: &dyn Fn(u64) -> bool,
        attached: &mut Vec<(NodeId, SnapshotRef)>,
    ) -> Option<()> {
        if let Some(hits) = entry.get("hits").and_then(|h| h.as_u64()) {
            if let Some(n) = tcg.node(node) {
                n.hits.store(hits, Ordering::Relaxed);
            }
        }
        if let Some(s) = entry.get("snapshot") {
            let (Some(sid), Some(bytes), Some(restore_cost)) = (
                s.get("id").and_then(|x| x.as_u64()),
                s.get("bytes").and_then(|x| x.as_u64()),
                s.get("restore_cost").and_then(|x| x.as_f64()),
            ) else {
                return None;
            };
            if keep_snapshot(sid)
                && tcg.node(node).map(|n| n.snapshot.is_none()).unwrap_or(false)
            {
                let sref = SnapshotRef { id: sid, bytes, restore_cost };
                tcg.set_snapshot(node, sref);
                attached.push((node, sref));
            }
        }
        if let Some(stateless) = entry.get("stateless").and_then(|s| s.as_arr()) {
            for s in stateless {
                let c = ToolCall::from_json(s.get("call")?)?;
                let r = ToolResult::from_json(s.get("result")?)?;
                tcg.insert_stateless(node, c, r);
            }
        }
        Some(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sf(s: &str) -> ToolCall {
        ToolCall::new("t", s)
    }

    fn traj(calls: &[&str]) -> Vec<(ToolCall, ToolResult)> {
        calls
            .iter()
            .map(|c| (sf(c), ToolResult::new(format!("out-{c}"), 1.0)))
            .collect()
    }

    #[test]
    fn miss_then_record_then_hit() {
        let cache = TaskCache::with_defaults();
        let q = vec![sf("a"), sf("b")];
        assert!(!cache.lookup(&q).is_hit());
        cache.record_trajectory(&traj(&["a", "b"]));
        match cache.lookup(&q) {
            Lookup::Hit { result, .. } => assert_eq!(result.output, "out-b"),
            m => panic!("{m:?}"),
        }
        let s = cache.stats();
        assert_eq!(s.lookups, 2);
        assert_eq!(s.hits, 1);
        assert!((s.hit_rate() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn lookup_miss_pins_resume_node_until_release() {
        let cache = TaskCache::with_defaults();
        let leaf = cache.record_trajectory(&traj(&["a", "b"]));
        cache.attach_snapshot(leaf, SnapshotRef { id: 7, bytes: 64, restore_cost: 0.2 });
        let q = vec![sf("a"), sf("b"), sf("x")];
        let Lookup::Miss(m) = cache.lookup(&q) else { panic!("expected miss") };
        let (node, _, _) = m.resume.unwrap();
        assert_eq!(node, leaf);
        // Pinned: eviction with budget 0 cannot free it.
        {
            let mut tcg = cache.tcg.write().unwrap();
            let policy = EvictionPolicy { max_snapshots: 0, ..Default::default() };
            assert!(enforce_budget(&mut tcg, &policy).is_empty());
        }
        cache.release(node);
        {
            let mut tcg = cache.tcg.write().unwrap();
            let policy = EvictionPolicy { max_snapshots: 0, ..Default::default() };
            assert_eq!(enforce_budget(&mut tcg, &policy).len(), 1);
        }
    }

    #[test]
    fn release_never_underflows() {
        let cache = TaskCache::with_defaults();
        let leaf = cache.record_trajectory(&traj(&["a"]));
        cache.release(leaf); // never pinned: must stay at zero
        cache.attach_snapshot(leaf, SnapshotRef { id: 1, bytes: 8, restore_cost: 0.1 });
        // Still evictable — a double release must not have wrapped to u32::MAX.
        let mut tcg = cache.tcg.write().unwrap();
        let policy = EvictionPolicy { max_snapshots: 0, ..Default::default() };
        assert_eq!(enforce_budget(&mut tcg, &policy).len(), 1);
    }

    #[test]
    fn attach_snapshot_enforces_budget() {
        let cache = TaskCache::new(
            LpmConfig::default(),
            SnapshotPolicy::default(),
            EvictionPolicy { max_snapshots: 2, ..Default::default() },
        );
        let mut freed_total = 0;
        for i in 0..5 {
            let leaf =
                cache.record_trajectory(&traj(&["p", &format!("leaf{i}")]));
            let freed = cache.attach_snapshot(
                leaf,
                SnapshotRef { id: i, bytes: 10, restore_cost: 0.1 },
            );
            freed_total += freed.len();
        }
        assert!(cache.snapshot_count() <= 2);
        assert_eq!(freed_total, 3);
        assert_eq!(cache.stats().snapshots_evicted, 3);
    }

    #[test]
    fn reattach_returns_replaced_snapshot_for_cleanup() {
        let cache = TaskCache::with_defaults();
        let leaf = cache.record_trajectory(&traj(&["a"]));
        let first = SnapshotRef { id: 1, bytes: 10, restore_cost: 0.1 };
        assert!(cache.attach_snapshot(leaf, first).is_empty());
        let freed = cache
            .attach_snapshot(leaf, SnapshotRef { id: 2, bytes: 20, restore_cost: 0.1 });
        assert_eq!(freed, vec![first], "the replaced ref must be handed back");
        assert_eq!(cache.snapshot_count(), 1);
        // Re-attaching the same id is a no-op for cleanup purposes.
        assert!(cache
            .attach_snapshot(leaf, SnapshotRef { id: 2, bytes: 20, restore_cost: 0.1 })
            .is_empty());
    }

    #[test]
    fn pinned_snapshot_survives_replacement_attempt() {
        let cache = TaskCache::with_defaults();
        let leaf = cache.record_trajectory(&traj(&["a", "b"]));
        let first = SnapshotRef { id: 1, bytes: 10, restore_cost: 0.1 };
        cache.attach_snapshot(leaf, first);
        // A miss with a resume offer pins the node: the holder may be about
        // to fetch snapshot id 1.
        let Lookup::Miss(m) = cache.lookup(&[sf("a"), sf("b"), sf("x")]) else {
            panic!("expected miss")
        };
        let (node, sref, _) = m.resume.unwrap();
        assert_eq!(sref.id, 1);
        // A concurrent attach must not drop the pinned holder's bytes: the
        // *new* ref is rejected instead.
        let second = SnapshotRef { id: 2, bytes: 20, restore_cost: 0.1 };
        assert_eq!(cache.attach_snapshot(leaf, second), vec![second]);
        assert_eq!(cache.snapshot_bytes(), 10, "incumbent snapshot kept");
        // After release, replacement proceeds and frees the incumbent.
        cache.release(node);
        assert_eq!(cache.attach_snapshot(leaf, second), vec![first]);
        assert_eq!(cache.snapshot_bytes(), 20);
    }

    #[test]
    fn attach_to_missing_node_hands_back_the_new_ref() {
        let cache = TaskCache::with_defaults();
        let snap = SnapshotRef { id: 9, bytes: 10, restore_cost: 0.1 };
        // Node 999 never existed (or was evicted concurrently): the caller
        // gets the ref back so it can drop the stored bytes.
        let freed = cache.attach_snapshot(999, snap);
        assert_eq!(freed, vec![snap]);
        assert_eq!(cache.snapshot_count(), 0);
        // ROOT (the wire failure sentinel) is rejected the same way: deep
        // state must never be attached at depth 0.
        cache.record_trajectory(&traj(&["a"]));
        let freed = cache.attach_snapshot(ROOT, snap);
        assert_eq!(freed, vec![snap]);
        assert_eq!(cache.snapshot_count(), 0);
    }

    #[test]
    fn record_trajectory_idempotent_counts() {
        let cache = TaskCache::with_defaults();
        cache.record_trajectory(&traj(&["a", "b", "c"]));
        cache.record_trajectory(&traj(&["a", "b", "c"]));
        assert_eq!(cache.node_count(), 3);
        assert_eq!(cache.stats().inserts, 3);
    }

    #[test]
    fn stateless_results_recorded_on_parent() {
        let cache = TaskCache::with_defaults();
        let mut t = traj(&["load", "preprocess"]);
        t.push((
            ToolCall::stateless("caption", "(0,10)"),
            ToolResult { output: "caps".into(), exec_time: 2.0, api_tokens: 500 },
        ));
        cache.record_trajectory(&t);
        // Hit regardless of a second stateless call in between.
        let q = vec![
            sf("load"),
            sf("preprocess"),
            ToolCall::stateless("other", "x"),
            ToolCall::stateless("caption", "(0,10)"),
        ];
        // Note: "other" isn't cached but it's not the current call.
        match cache.lookup(&q) {
            Lookup::Hit { result, .. } => assert_eq!(result.output, "caps"),
            m => panic!("{m:?}"),
        }
        assert_eq!(cache.stats().api_tokens_saved, 500);
    }

    #[test]
    fn cursor_step_at_mirrors_lookup_stats_and_pins() {
        let cache = TaskCache::with_defaults();
        let leaf = cache.record_trajectory(&traj(&["a", "b"]));
        cache.attach_snapshot(leaf, SnapshotRef { id: 7, bytes: 64, restore_cost: 0.2 });
        let gen = cache.eviction_generation();

        // Two hit steps, then a divergent miss that pins the resume node.
        let (s1, pos, gen) = cache.cursor_step_at(ROOT, 0, gen, &sf("a"));
        assert!(matches!(s1, CursorStep::Hit { .. }));
        let (s2, pos, gen) = cache.cursor_step_at(pos, 1, gen, &sf("b"));
        assert!(matches!(s2, CursorStep::Hit { .. }));
        assert_eq!(pos, leaf);
        let (s3, _, _) = cache.cursor_step_at(pos, 2, gen, &sf("zz"));
        let CursorStep::Miss(m) = s3 else { panic!("{s3:?}") };
        let (rnode, sref, replay) = m.resume.unwrap();
        assert_eq!((rnode, sref.id, replay), (leaf, 7, 2));
        assert_eq!(cache.pinned_node_count(), 1, "cursor miss must pin the offer");
        cache.release(rnode);

        let stats = cache.stats();
        assert_eq!(stats.lookups, 3);
        assert_eq!(stats.hits, 2);
        assert_eq!(stats.partial_hits, 1);
        assert_eq!(stats.snapshot_resumes, 1);
    }

    #[test]
    fn cursor_record_at_advances_and_counts_inserts() {
        let cache = TaskCache::with_defaults();
        let (node, gen) =
            cache.cursor_record_at(ROOT, &sf("a"), &ToolResult::new("ra", 1.0)).unwrap();
        assert!(node != ROOT);
        // Stateless record stays at the mutating position.
        let sl = ToolCall::stateless("s", "x");
        let (same, _) = cache.cursor_record_at(node, &sl, &ToolResult::new("rs", 0.1)).unwrap();
        assert_eq!(same, node);
        assert_eq!(cache.stats().inserts, 2);
        assert!(cache.lookup(&[sf("a"), sl.clone()]).is_hit());
        // Recording at a removed node fails (caller falls back).
        assert!(cache.remove_subtree_if_unpinned(node).is_some());
        assert!(cache.cursor_record_at(node, &sf("b"), &ToolResult::new("rb", 1.0)).is_none());
        // And the generation moved, so a stale cursor invalidates.
        assert!(cache.eviction_generation() > gen);
        let (step, _, _) = cache.cursor_step_at(node, 1, gen, &sf("b"));
        assert_eq!(step, CursorStep::Invalid);
    }

    #[test]
    fn remove_subtree_if_unpinned_respects_pins() {
        let cache = TaskCache::with_defaults();
        let leaf = cache.record_trajectory(&traj(&["a", "b"]));
        cache.attach_snapshot(leaf, SnapshotRef { id: 3, bytes: 8, restore_cost: 0.1 });
        let Lookup::Miss(m) = cache.lookup(&[sf("a"), sf("b"), sf("x")]) else {
            panic!("expected miss")
        };
        let (node, _, _) = m.resume.unwrap();
        assert!(cache.remove_subtree_if_unpinned(node).is_none(), "pinned: must refuse");
        cache.release(node);
        let freed = cache.remove_subtree_if_unpinned(node).expect("unpinned: removable");
        assert_eq!(freed.len(), 1);
        assert_eq!(cache.stats().snapshots_evicted, 1);
    }

    #[test]
    fn stats_json_roundtrip() {
        let cache = TaskCache::with_defaults();
        cache.record_trajectory(&traj(&["a", "b"]));
        assert!(cache.lookup(&[sf("a"), sf("b")]).is_hit());
        assert!(!cache.lookup(&[sf("a"), sf("z")]).is_hit());
        let stats = cache.stats();
        let text = stats.to_json().to_string();
        let parsed = crate::util::json::parse(&text).unwrap();
        assert_eq!(CacheStats::from_json(&parsed).unwrap(), stats);
    }

    #[test]
    fn persistence_roundtrip() {
        let cache = TaskCache::with_defaults();
        cache.record_trajectory(&traj(&["a", "b"]));
        cache.record_trajectory(&traj(&["a", "c"]));
        let mut t = traj(&["a"]);
        t.push((ToolCall::stateless("s", "1"), ToolResult::new("sr", 0.1)));
        cache.record_trajectory(&t);

        let json_text = cache.to_persistent_json().to_string();
        let parsed = crate::util::json::parse(&json_text).unwrap();
        let restored =
            TaskCache::from_persistent_json(&parsed, LpmConfig::default()).unwrap();
        assert_eq!(restored.node_count(), cache.node_count());
        assert!(restored.lookup(&[sf("a"), sf("b")]).is_hit());
        assert!(restored.lookup(&[sf("a"), sf("c")]).is_hit());
        assert!(restored
            .lookup(&[sf("a"), ToolCall::stateless("s", "1")])
            .is_hit());
        assert!(!restored.lookup(&[sf("a"), sf("zzz")]).is_hit());
    }

    #[test]
    fn concurrent_lookups_and_records() {
        use std::sync::Arc;
        let cache = Arc::new(TaskCache::with_defaults());
        let handles: Vec<_> = (0..8)
            .map(|t| {
                let c = Arc::clone(&cache);
                std::thread::spawn(move || {
                    for i in 0..50 {
                        let calls = traj(&["shared", &format!("t{}-{}", t % 4, i % 10)]);
                        c.record_trajectory(&calls);
                        let q: Vec<ToolCall> =
                            calls.iter().map(|(c, _)| c.clone()).collect();
                        assert!(c.lookup(&q).is_hit());
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        // 1 shared node + 4 t-branches × 10 leaves
        assert_eq!(cache.node_count(), 1 + 4 * 10);
    }

    #[test]
    fn read_path_lookups_proceed_in_parallel() {
        // The read path must take a *shared* lock: a lookup on another
        // thread completes while this thread holds a read guard. (If
        // `lookup` took the write lock, the join below would hang.)
        use std::sync::Arc;
        let cache = Arc::new(TaskCache::with_defaults());
        cache.record_trajectory(&traj(&["a"]));
        let guard = cache.tcg.read().unwrap();
        let c = Arc::clone(&cache);
        let h = std::thread::spawn(move || c.lookup(&[sf("a")]).is_hit());
        assert!(h.join().unwrap());
        drop(guard);
    }
}
