//! Sequence-numbered op-log for primary → follower replication.
//!
//! Every state mutation a [`super::ShardedCacheService`] applies — TCG
//! inserts and records, snapshot attaches, releases, warm-fork marks, and
//! evictions — is appended here under the same lock that applied it, so
//! the log order *is* the apply order. A follower that replays the ops
//! from sequence 0 builds bit-identical TCGs: node ids are allocated from
//! a tombstoned arena and never reused, so the node-addressed ops
//! (`Record` at a position, `Attach`, `Release`, `Evict*`) land on exactly
//! the nodes they named on the primary.
//!
//! Snapshot payload bytes are content-addressed ([`ContentKey`], PR 5) and
//! expensive, so an [`Op::Attach`] carries them **once per key per log
//! window**: the first attach of a key ships the bytes, later attaches of
//! the same key ship the key alone and the follower re-references its
//! already-stored payload. When the bytes-carrying op falls off the
//! bounded window the key is forgotten and the next attach re-ships.
//!
//! The window is bounded (default [`DEFAULT_OPLOG_WINDOW`] ops): a
//! follower that falls further behind than the window reaches observes a
//! *gap* — `read_from` returns a `start` above the requested `from` — and
//! must stop applying rather than replay node-addressed ops against a
//! divergent tree. Since PR 9 a gapped follower *bootstraps* from the
//! primary's `GET /bootstrap` checkpoint instead of freezing (see the
//! follower loop in `server`).
//!
//! With a [`Wal`] attached ([`OpLog::with_wal`]), every push is also
//! encoded into a CRC32-framed record in an on-disk segment — under the
//! same guard, so the durable order is the apply order and a restarted
//! primary replays back to the exact pre-crash state (`cache/wal.rs`).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use super::wal::Wal;

use super::key::{ToolCall, ToolResult};
use super::payload::ContentKey;
use super::tcg::NodeId;

/// Default bounded window: plenty for a follower polling every few tens of
/// milliseconds, small enough that a wedged follower cannot balloon the
/// primary's memory.
pub const DEFAULT_OPLOG_WINDOW: usize = 65_536;

/// One replicated state mutation. Node fields name primary-side TCG node
/// ids, which replay identically on the follower (never-reused arena ids).
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// Full-trajectory upsert (`CacheBackend::insert`).
    Insert { task: String, traj: Vec<(ToolCall, ToolResult)> },
    /// Single-delta record at position `node` (`cursor_record`); the
    /// follower replays it position-addressed — it has no session table.
    Record { task: String, node: NodeId, call: ToolCall, result: ToolResult },
    /// Snapshot attach. `bytes` carries the payload for the first attach
    /// of `key` in the window; `None` references an already-shipped
    /// payload. `byte_len` is always the payload length (the follower
    /// needs it for the `SnapshotRef` even when the bytes ride earlier).
    /// The payload is an `Arc<[u8]>` so `/replicate` reads and window
    /// trims share the one allocation instead of deep-cloning it under
    /// the log mutex.
    Attach {
        task: String,
        node: NodeId,
        id: u64,
        key: ContentKey,
        bytes: Option<Arc<[u8]>>,
        byte_len: u64,
        serialize_cost: f64,
        restore_cost: f64,
    },
    /// Sandbox refcount decrement (`CacheBackend::release`). Pins are not
    /// replicated, so the follower's replay is a saturating no-op — kept
    /// in the log so a promoted follower starts from released state.
    Release { task: String, node: NodeId },
    /// Warm background-fork mark (`set_warm_fork`).
    WarmFork { task: String, node: NodeId, warm: bool },
    /// A snapshot detached and destroyed (explicit or background
    /// destroy-eviction). Spill *demotions* are residency changes, not
    /// state mutations, and are deliberately not replicated.
    EvictSnapshot { task: String, node: NodeId },
    /// A subtree eviction (`evict_node`).
    EvictNode { task: String, node: NodeId },
}

struct LogInner {
    /// Sequence number the next appended op receives.
    next_seq: u64,
    /// Sequence number of `ops.front()` (== `next_seq` when empty).
    start_seq: u64,
    ops: VecDeque<Op>,
    window: usize,
    /// Content keys whose payload bytes ride an op still in the window,
    /// mapped to that op's sequence number (for window-eviction cleanup).
    logged_keys: HashMap<ContentKey, u64>,
    /// Total ops ever pushed (stats counter; survives window trims).
    appended: u64,
}

/// The primary's replication log. `begin()` hands out a guard that holds
/// the log lock; the caller applies its mutation and appends the matching
/// op under the same guard, so no two mutations can interleave between
/// apply and append — log order is apply order, which is what makes the
/// follower's sequential replay faithful.
pub struct OpLog {
    inner: Mutex<LogInner>,
    /// Highest `from` any follower pull acknowledged (a pull at `from`
    /// proves everything below `from` was applied). Drives `/drain`.
    acked: AtomicU64,
    /// Durable tier: every pushed op is also appended here, under the
    /// same guard (PR 9). `None` = in-memory-only log (PR 8 behavior).
    wal: Option<Arc<Wal>>,
}

impl OpLog {
    pub fn new(window: usize) -> OpLog {
        OpLog::with_wal(window, None, 0)
    }

    /// A log whose pushes are also appended to `wal`, numbering from
    /// `start_seq` — the WAL's recovered `next_seq` on a restarted
    /// primary, so the durable log stays dense across restarts (the
    /// in-memory window restarts empty; followers below `start_seq`
    /// observe a gap and bootstrap).
    pub fn with_wal(window: usize, wal: Option<Arc<Wal>>, start_seq: u64) -> OpLog {
        OpLog {
            inner: Mutex::new(LogInner {
                next_seq: start_seq,
                start_seq,
                ops: VecDeque::new(),
                window: window.max(1),
                logged_keys: HashMap::new(),
                appended: 0,
            }),
            acked: AtomicU64::new(0),
            wal,
        }
    }

    /// Lock the log around a mutation. Hold the guard across the state
    /// change *and* the [`LogGuard::push`] of its op.
    pub fn begin(&self) -> LogGuard<'_> {
        LogGuard { inner: self.inner.lock().unwrap(), wal: self.wal.as_deref() }
    }

    /// The durable tier, when configured.
    pub fn wal(&self) -> Option<&Arc<Wal>> {
        self.wal.as_ref()
    }

    /// Total ops ever pushed (window trims do not decrement).
    pub fn appended(&self) -> u64 {
        self.inner.lock().unwrap().appended
    }

    /// Ops from `from` (capped at `max_ops`), plus the window's reach.
    /// Returns `(start, next, ops)`: `start` is the sequence of `ops[0]`
    /// — above the requested `from` exactly when the window no longer
    /// reaches back that far (the follower's gap signal) — and `next` is
    /// the primary's next sequence number (for lag accounting).
    pub fn read_from(&self, from: u64, max_ops: usize) -> (u64, u64, Vec<Op>) {
        let inner = self.inner.lock().unwrap();
        let start = from.max(inner.start_seq);
        let skip = (start - inner.start_seq) as usize;
        let ops: Vec<Op> = inner.ops.iter().skip(skip).take(max_ops).cloned().collect();
        (start, inner.next_seq, ops)
    }

    /// A follower pulled at `from`: everything below `from` is applied.
    pub fn note_ack(&self, from: u64) {
        self.acked.fetch_max(from, Ordering::AcqRel);
    }

    pub fn acked(&self) -> u64 {
        self.acked.load(Ordering::Acquire)
    }

    pub fn next_seq(&self) -> u64 {
        self.inner.lock().unwrap().next_seq
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Lock guard over the log (see [`OpLog::begin`]).
pub struct LogGuard<'a> {
    inner: MutexGuard<'a, LogInner>,
    wal: Option<&'a Wal>,
}

impl LogGuard<'_> {
    /// Should an [`Op::Attach`] of `key` ship payload bytes? `true` until
    /// a bytes-carrying attach of the key is pushed (and again after that
    /// op ages off the window).
    pub fn wants_bytes(&self, key: &ContentKey) -> bool {
        !self.inner.logged_keys.contains_key(key)
    }

    /// Sequence number the next [`LogGuard::push`] receives. Stable while
    /// the guard is held — what `persist_to_dir` stamps its checkpoint
    /// with.
    pub fn next_seq(&self) -> u64 {
        self.inner.next_seq
    }

    /// Append `op`, returning its sequence number. Trims the window and
    /// forgets content keys whose payload-carrying op aged out. With a
    /// durable tier attached, the op is also appended to the WAL here —
    /// same guard, so disk order == log order == apply order.
    pub fn push(&mut self, op: Op) -> u64 {
        let inner = &mut *self.inner;
        let seq = inner.next_seq;
        if let Op::Attach { key, bytes: Some(_), .. } = &op {
            inner.logged_keys.insert(*key, seq);
        }
        if let Some(wal) = self.wal {
            wal.append(seq, &op);
        }
        inner.ops.push_back(op);
        inner.next_seq += 1;
        inner.appended += 1;
        while inner.ops.len() > inner.window {
            let evicted = inner.ops.pop_front();
            let evicted_seq = inner.start_seq;
            inner.start_seq += 1;
            if let Some(Op::Attach { key, bytes: Some(_), .. }) = evicted {
                if inner.logged_keys.get(&key) == Some(&evicted_seq) {
                    inner.logged_keys.remove(&key);
                }
            }
        }
        seq
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rel(task: &str, node: NodeId) -> Op {
        Op::Release { task: task.to_string(), node }
    }

    fn attach(key: ContentKey, bytes: Option<Vec<u8>>) -> Op {
        Op::Attach {
            task: "t".to_string(),
            node: 1,
            id: 7,
            key,
            byte_len: bytes.as_ref().map(|b| b.len() as u64).unwrap_or(3),
            bytes: bytes.map(Into::into),
            serialize_cost: 0.1,
            restore_cost: 0.2,
        }
    }

    #[test]
    fn sequences_are_dense_and_read_back_in_order() {
        let log = OpLog::new(16);
        for i in 0..5 {
            let mut g = log.begin();
            assert_eq!(g.push(rel("t", i)), i as u64);
        }
        let (start, next, ops) = log.read_from(2, 100);
        assert_eq!((start, next), (2, 5));
        assert_eq!(ops, vec![rel("t", 2), rel("t", 3), rel("t", 4)]);
        // A capped read returns a prefix, not a sample.
        let (start, _, ops) = log.read_from(0, 2);
        assert_eq!(start, 0);
        assert_eq!(ops.len(), 2);
    }

    #[test]
    fn window_eviction_reports_gap_via_start() {
        let log = OpLog::new(4);
        for i in 0..10 {
            log.begin().push(rel("t", i));
        }
        // Seqs 0..6 aged off: a follower at 3 sees start jump to 6.
        let (start, next, ops) = log.read_from(3, 100);
        assert_eq!((start, next), (6, 10));
        assert_eq!(ops.len(), 4);
    }

    #[test]
    fn payload_bytes_ship_once_per_key_until_window_forgets() {
        let log = OpLog::new(3);
        let key = ContentKey::of(b"payload");
        {
            let mut g = log.begin();
            assert!(g.wants_bytes(&key));
            g.push(attach(key, Some(b"payload".to_vec())));
            assert!(!g.wants_bytes(&key), "second attach must not re-ship");
        }
        // Push the bytes-carrying op off the window…
        for i in 0..3 {
            log.begin().push(rel("t", i));
        }
        // …and the key must be re-shippable again.
        assert!(log.begin().wants_bytes(&key));
    }

    #[test]
    fn key_only_attach_does_not_mark_the_key_shipped() {
        let log = OpLog::new(8);
        let key = ContentKey::of(b"x");
        let mut g = log.begin();
        g.push(attach(key, None));
        assert!(g.wants_bytes(&key), "a key-only attach never shipped the bytes");
    }

    #[test]
    fn wal_attached_log_appends_every_push_durably() {
        use crate::cache::wal::WalOptions;
        let dir = std::env::temp_dir().join(format!(
            "tvcache-oplog-wal-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let (wal, rec) = Wal::open(&dir, WalOptions::default()).unwrap();
        let log = OpLog::with_wal(8, Some(Arc::new(wal)), rec.next_seq());
        for i in 0..12 {
            log.begin().push(rel("t", i));
        }
        assert_eq!(log.appended(), 12);
        assert_eq!(log.next_seq(), 12);
        drop(log);
        // The durable log holds the full history, beyond the window of 8.
        let (_, rec) = Wal::open(&dir, WalOptions::default()).unwrap();
        assert_eq!(rec.ops.len(), 12);
        assert_eq!(rec.ops[0], rel("t", 0));
        // A restarted log resumes dense numbering from the WAL's tip.
        let resumed = OpLog::with_wal(8, None, rec.next_seq());
        assert_eq!(resumed.begin().push(rel("t", 99)), 12);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn ack_is_monotonic() {
        let log = OpLog::new(8);
        log.note_ack(5);
        log.note_ack(3);
        assert_eq!(log.acked(), 5);
        log.note_ack(9);
        assert_eq!(log.acked(), 9);
    }
}
