//! Tool-call descriptors and results — the cache's key and value types.
//!
//! A [`ToolCall`] is the paper's *tool descriptor* `t`: tool name plus
//! serialized arguments. A trajectory is a `Vec<ToolCall>`; TVCACHE keys the
//! cache on trajectories, never on individual calls (§3.1). The
//! `mutates_state` annotation is the `will_mutate_state()` hook from
//! Appendix B: `false` lets the LPM skip the call when matching prefixes.

use crate::util::json::Json;
use crate::util::rng::fnv1a;

/// One tool invocation: the cache key component.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ToolCall {
    /// Tool name, e.g. `"bash"`, `"sql"`, `"caption_retrieval"`.
    pub tool: String,
    /// Serialized arguments, e.g. the shell command or the SQL text.
    pub args: String,
    /// `will_mutate_state()` — `true` is the safe default (Appendix B).
    pub mutates_state: bool,
}

impl ToolCall {
    pub fn new(tool: impl Into<String>, args: impl Into<String>) -> ToolCall {
        ToolCall { tool: tool.into(), args: args.into(), mutates_state: true }
    }

    pub fn stateless(tool: impl Into<String>, args: impl Into<String>) -> ToolCall {
        ToolCall { tool: tool.into(), args: args.into(), mutates_state: false }
    }

    /// Canonical descriptor string (what the paper's client serializes).
    pub fn descriptor(&self) -> String {
        format!("{}({})", self.tool, self.args)
    }

    /// 64-bit key used for child indexing in the TCG.
    pub fn key(&self) -> u64 {
        // Tool and args hashed separately to avoid "ab"+"c" vs "a"+"bc".
        fnv1a(self.tool.as_bytes()) ^ fnv1a(self.args.as_bytes()).rotate_left(17)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("tool", Json::str(self.tool.clone())),
            ("args", Json::str(self.args.clone())),
            ("mutates", Json::Bool(self.mutates_state)),
        ])
    }

    pub fn from_json(v: &Json) -> Option<ToolCall> {
        Some(ToolCall {
            tool: v.get("tool")?.as_str()?.to_string(),
            args: v.get("args")?.as_str()?.to_string(),
            mutates_state: v.get("mutates").and_then(|m| m.as_bool()).unwrap_or(true),
        })
    }
}

/// The cached value: tool output plus execution metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct ToolResult {
    /// Tool output as observed by the agent (stdout, query rows, captions…).
    pub output: String,
    /// Wall-clock seconds the original execution took (drives the selective
    /// snapshotting policy, §3.3).
    pub exec_time: f64,
    /// Simulated external-API tokens consumed (EgoSchema caption tool;
    /// backs the "3× token saving" claim in §4.3).
    pub api_tokens: u64,
}

impl ToolResult {
    pub fn new(output: impl Into<String>, exec_time: f64) -> ToolResult {
        ToolResult { output: output.into(), exec_time, api_tokens: 0 }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("output", Json::str(self.output.clone())),
            ("exec_time", Json::num(self.exec_time)),
            ("api_tokens", Json::num(self.api_tokens as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Option<ToolResult> {
        Some(ToolResult {
            output: v.get("output")?.as_str()?.to_string(),
            exec_time: v.get("exec_time")?.as_f64()?,
            api_tokens: v.get("api_tokens").and_then(|t| t.as_u64()).unwrap_or(0),
        })
    }
}

/// Serialize a trajectory for the wire protocol.
pub fn trajectory_to_json(calls: &[ToolCall]) -> Json {
    Json::Arr(calls.iter().map(|c| c.to_json()).collect())
}

pub fn trajectory_from_json(v: &Json) -> Option<Vec<ToolCall>> {
    v.as_arr()?.iter().map(ToolCall::from_json).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn descriptor_format() {
        let c = ToolCall::new("bash", "cat foo.py");
        assert_eq!(c.descriptor(), "bash(cat foo.py)");
    }

    #[test]
    fn key_distinguishes_tool_and_args_split() {
        let a = ToolCall::new("ab", "c");
        let b = ToolCall::new("a", "bc");
        assert_ne!(a.key(), b.key());
    }

    #[test]
    fn key_stable_and_arg_sensitive() {
        let a = ToolCall::new("bash", "ls");
        let b = ToolCall::new("bash", "ls");
        let c = ToolCall::new("bash", "ls -la");
        assert_eq!(a.key(), b.key());
        assert_ne!(a.key(), c.key());
    }

    #[test]
    fn json_roundtrip() {
        let calls = vec![
            ToolCall::new("bash", "make && ./run \"x\""),
            ToolCall::stateless("caption_retrieval", "(0, 10)"),
        ];
        let j = trajectory_to_json(&calls);
        let parsed = crate::util::json::parse(&j.to_string()).unwrap();
        assert_eq!(trajectory_from_json(&parsed).unwrap(), calls);
    }

    #[test]
    fn result_json_roundtrip() {
        let r = ToolResult { output: "12 rows\nline2".into(), exec_time: 0.0566, api_tokens: 42 };
        let parsed = crate::util::json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(ToolResult::from_json(&parsed).unwrap(), r);
    }
}
