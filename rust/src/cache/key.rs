//! Tool-call descriptors and results — the cache's key and value types.
//!
//! A [`ToolCall`] is the paper's *tool descriptor* `t`: tool name plus
//! serialized arguments. A trajectory is a `Vec<ToolCall>`; TVCACHE keys the
//! cache on trajectories, never on individual calls (§3.1). The
//! `mutates_state` annotation is the `will_mutate_state()` hook from
//! Appendix B: `false` lets the LPM skip the call when matching prefixes.
//!
//! The 64-bit FNV fingerprint used for TCG child indexing is computed once
//! at construction and cached in the struct, so the hot probe path
//! (`Tcg::child`, cursor steps, stateless side-index lookups) never
//! re-hashes the tool/args strings. The binary wire protocol carries the
//! fingerprint alongside the descriptor, so a server deserializing a call
//! reuses the client's hash instead of recomputing it
//! ([`ToolCall::from_wire`]).

use crate::util::json::{escape_str, write_num, Json};
use crate::util::rng::fnv1a;

/// One tool invocation: the cache key component.
///
/// Construct through [`ToolCall::new`] / [`ToolCall::stateless`] /
/// [`ToolCall::with_flag`] — the constructors compute the cached child-index
/// fingerprint exactly once.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ToolCall {
    /// Tool name, e.g. `"bash"`, `"sql"`, `"caption_retrieval"`.
    pub tool: String,
    /// Serialized arguments, e.g. the shell command or the SQL text.
    pub args: String,
    /// `will_mutate_state()` — `true` is the safe default (Appendix B).
    pub mutates_state: bool,
    /// Cached [`ToolCall::key`] fingerprint. Private so every construction
    /// path goes through a constructor that fills it; a deterministic
    /// function of `tool`/`args`, so the derived `Eq`/`Hash` stay
    /// consistent.
    key: u64,
}

/// The child-index fingerprint of a `(tool, args)` descriptor. Tool and
/// args are hashed separately to avoid `"ab"+"c"` vs `"a"+"bc"` collisions.
fn fingerprint(tool: &str, args: &str) -> u64 {
    fnv1a(tool.as_bytes()) ^ fnv1a(args.as_bytes()).rotate_left(17)
}

impl ToolCall {
    pub fn new(tool: impl Into<String>, args: impl Into<String>) -> ToolCall {
        Self::with_flag(tool, args, true)
    }

    pub fn stateless(tool: impl Into<String>, args: impl Into<String>) -> ToolCall {
        Self::with_flag(tool, args, false)
    }

    /// Construct with an explicit `will_mutate_state()` flag.
    pub fn with_flag(
        tool: impl Into<String>,
        args: impl Into<String>,
        mutates_state: bool,
    ) -> ToolCall {
        let tool = tool.into();
        let args = args.into();
        let key = fingerprint(&tool, &args);
        ToolCall { tool, args, mutates_state, key }
    }

    /// Rebuild a call from the binary wire protocol, adopting the sender's
    /// precomputed fingerprint instead of re-hashing. A corrupted key can
    /// only cause cache *misses*, never wrong results: every child-index
    /// probe verifies the full descriptor after the hash match
    /// (`Tcg::child`), so the fingerprint is purely an index accelerator.
    /// Deliberately no assert here — this runs on untrusted network input,
    /// and the wire decoder's contract is "malformed input degrades, never
    /// panics" in every build profile.
    pub fn from_wire(tool: &str, args: &str, mutates_state: bool, key: u64) -> ToolCall {
        ToolCall { tool: tool.to_string(), args: args.to_string(), mutates_state, key }
    }

    /// Canonical descriptor string (what the paper's client serializes).
    pub fn descriptor(&self) -> String {
        format!("{}({})", self.tool, self.args)
    }

    /// 64-bit key used for child indexing in the TCG (cached at
    /// construction — this is a field read, not a hash).
    pub fn key(&self) -> u64 {
        self.key
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("tool", Json::str(self.tool.as_str())),
            ("args", Json::str(self.args.as_str())),
            ("mutates", Json::Bool(self.mutates_state)),
        ])
    }

    /// Serialize directly into `out` without building a `Json` tree or
    /// cloning `tool`/`args`. Key order matches [`ToolCall::to_json`]
    /// (alphabetical, as `Json::Obj`'s `BTreeMap` serializes).
    pub fn json_into(&self, out: &mut String) {
        out.push_str("{\"args\":");
        escape_str(&self.args, out);
        out.push_str(",\"mutates\":");
        out.push_str(if self.mutates_state { "true" } else { "false" });
        out.push_str(",\"tool\":");
        escape_str(&self.tool, out);
        out.push('}');
    }

    pub fn from_json(v: &Json) -> Option<ToolCall> {
        Some(ToolCall::with_flag(
            v.get("tool")?.as_str()?,
            v.get("args")?.as_str()?,
            v.get("mutates").and_then(|m| m.as_bool()).unwrap_or(true),
        ))
    }
}

/// The cached value: tool output plus execution metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct ToolResult {
    /// Tool output as observed by the agent (stdout, query rows, captions…).
    pub output: String,
    /// Wall-clock seconds the original execution took (drives the selective
    /// snapshotting policy, §3.3).
    pub exec_time: f64,
    /// Simulated external-API tokens consumed (EgoSchema caption tool;
    /// backs the "3× token saving" claim in §4.3).
    pub api_tokens: u64,
}

impl ToolResult {
    pub fn new(output: impl Into<String>, exec_time: f64) -> ToolResult {
        ToolResult { output: output.into(), exec_time, api_tokens: 0 }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("output", Json::str(self.output.as_str())),
            ("exec_time", Json::num(self.exec_time)),
            ("api_tokens", Json::num(self.api_tokens as f64)),
        ])
    }

    /// Serialize directly into `out` without cloning `output`. Key order
    /// matches [`ToolResult::to_json`].
    pub fn json_into(&self, out: &mut String) {
        out.push_str("{\"api_tokens\":");
        write_num(self.api_tokens as f64, out);
        out.push_str(",\"exec_time\":");
        write_num(self.exec_time, out);
        out.push_str(",\"output\":");
        escape_str(&self.output, out);
        out.push('}');
    }

    pub fn from_json(v: &Json) -> Option<ToolResult> {
        Some(ToolResult {
            output: v.get("output")?.as_str()?.to_string(),
            exec_time: v.get("exec_time")?.as_f64()?,
            api_tokens: v.get("api_tokens").and_then(|t| t.as_u64()).unwrap_or(0),
        })
    }
}

/// Serialize a trajectory for the (legacy JSON) wire protocol.
pub fn trajectory_to_json(calls: &[ToolCall]) -> Json {
    Json::Arr(calls.iter().map(|c| c.to_json()).collect())
}

/// Serialize a trajectory directly into `out` (no `Json` tree, no clones).
pub fn trajectory_json_into(calls: &[ToolCall], out: &mut String) {
    out.push('[');
    for (i, c) in calls.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        c.json_into(out);
    }
    out.push(']');
}

pub fn trajectory_from_json(v: &Json) -> Option<Vec<ToolCall>> {
    v.as_arr()?.iter().map(ToolCall::from_json).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn descriptor_format() {
        let c = ToolCall::new("bash", "cat foo.py");
        assert_eq!(c.descriptor(), "bash(cat foo.py)");
    }

    #[test]
    fn key_distinguishes_tool_and_args_split() {
        let a = ToolCall::new("ab", "c");
        let b = ToolCall::new("a", "bc");
        assert_ne!(a.key(), b.key());
    }

    #[test]
    fn key_stable_and_arg_sensitive() {
        let a = ToolCall::new("bash", "ls");
        let b = ToolCall::new("bash", "ls");
        let c = ToolCall::new("bash", "ls -la");
        assert_eq!(a.key(), b.key());
        assert_ne!(a.key(), c.key());
    }

    #[test]
    fn cached_key_matches_fresh_fingerprint_across_constructors() {
        let a = ToolCall::new("bash", "make");
        let b = ToolCall::stateless("bash", "make");
        let c = ToolCall::with_flag("bash", "make", true);
        let d = ToolCall::from_wire("bash", "make", true, a.key());
        assert_eq!(a.key(), fingerprint("bash", "make"));
        assert_eq!(a.key(), b.key());
        assert_eq!(a.key(), c.key());
        assert_eq!(a.key(), d.key());
    }

    #[test]
    fn json_roundtrip() {
        let calls = vec![
            ToolCall::new("bash", "make && ./run \"x\""),
            ToolCall::stateless("caption_retrieval", "(0, 10)"),
        ];
        let j = trajectory_to_json(&calls);
        let parsed = crate::util::json::parse(&j.to_string()).unwrap();
        assert_eq!(trajectory_from_json(&parsed).unwrap(), calls);
    }

    #[test]
    fn json_into_matches_tree_serialization() {
        let calls = vec![
            ToolCall::new("bash", "echo \"q\" > f\nnl"),
            ToolCall::stateless("sql", "SELECT * FROM t;"),
        ];
        let mut direct = String::new();
        trajectory_json_into(&calls, &mut direct);
        assert_eq!(direct, trajectory_to_json(&calls).to_string());

        let r = ToolResult { output: "a\"b\\c".into(), exec_time: 0.25, api_tokens: 7 };
        let mut direct = String::new();
        r.json_into(&mut direct);
        assert_eq!(direct, r.to_json().to_string());
    }

    #[test]
    fn result_json_roundtrip() {
        let r = ToolResult { output: "12 rows\nline2".into(), exec_time: 0.0566, api_tokens: 42 };
        let parsed = crate::util::json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(ToolResult::from_json(&parsed).unwrap(), r);
    }
}
