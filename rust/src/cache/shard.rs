//! Task-id sharding (§4.5): each task's TCG is independent, so the cache
//! shards by `hash(task_id)` for near-linear throughput scaling (Figure 8a).

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use super::store::TaskCache;
use crate::util::rng::fnv1a;

/// Shared constructor for per-task caches (captures the policies).
pub type CacheFactory = Arc<dyn Fn() -> TaskCache + Send + Sync>;

/// Routes task ids to shard indices.
#[derive(Debug, Clone, Copy)]
pub struct ShardRouter {
    pub shards: usize,
}

impl ShardRouter {
    pub fn new(shards: usize) -> Self {
        ShardRouter { shards: shards.max(1) }
    }

    pub fn route(&self, task_id: &str) -> usize {
        (fnv1a(task_id.as_bytes()) % self.shards as u64) as usize
    }
}

/// One shard: a map of task id → per-task cache. The sharded cache service
/// holds N of these, each fully independent (own task map, own lock).
pub struct Shard {
    tasks: RwLock<HashMap<String, Arc<TaskCache>>>,
    factory: CacheFactory,
}

impl Shard {
    pub fn new<F>(factory: F) -> Self
    where
        F: Fn() -> TaskCache + Send + Sync + 'static,
    {
        Self::from_factory(Arc::new(factory))
    }

    /// Build from an already-shared factory (one factory, many shards).
    pub fn from_factory(factory: CacheFactory) -> Self {
        Shard { tasks: RwLock::new(HashMap::new()), factory }
    }

    /// Get or create the cache for `task_id`.
    pub fn task(&self, task_id: &str) -> Arc<TaskCache> {
        if let Some(c) = self.tasks.read().unwrap().get(task_id) {
            return Arc::clone(c);
        }
        let mut w = self.tasks.write().unwrap();
        Arc::clone(
            w.entry(task_id.to_string())
                .or_insert_with(|| Arc::new((self.factory)())),
        )
    }

    /// Swap in a fresh cache for `task_id` and return it (follower
    /// bootstrap: the checkpoint state supersedes whatever a partial
    /// replay built here). Existing `Arc` holders keep the orphaned old
    /// cache; it simply stops being reachable through the shard.
    pub fn replace(&self, task_id: &str) -> Arc<TaskCache> {
        let fresh = Arc::new((self.factory)());
        self.tasks
            .write()
            .unwrap()
            .insert(task_id.to_string(), Arc::clone(&fresh));
        fresh
    }

    pub fn task_ids(&self) -> Vec<String> {
        self.tasks.read().unwrap().keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.tasks.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routing_is_stable_and_in_range() {
        let r = ShardRouter::new(16);
        for i in 0..100 {
            let id = format!("task-{i}");
            let s = r.route(&id);
            assert!(s < 16);
            assert_eq!(s, r.route(&id));
        }
    }

    #[test]
    fn routing_spreads_tasks() {
        let r = ShardRouter::new(8);
        let mut counts = [0usize; 8];
        for i in 0..800 {
            counts[r.route(&format!("task-{i}"))] += 1;
        }
        // Every shard should get a reasonable share (expected 100 each).
        for (i, c) in counts.iter().enumerate() {
            assert!(*c > 50, "shard {i} got only {c}");
        }
    }

    #[test]
    fn one_shard_routes_everything_to_zero() {
        let r = ShardRouter::new(1);
        assert_eq!(r.route("anything"), 0);
    }

    #[test]
    fn shard_task_caches_are_distinct_and_reused() {
        let shard = Shard::new(TaskCache::with_defaults);
        let a1 = shard.task("a");
        let a2 = shard.task("a");
        let b = shard.task("b");
        assert!(std::sync::Arc::ptr_eq(&a1, &a2));
        assert!(!std::sync::Arc::ptr_eq(&a1, &b));
        assert_eq!(shard.len(), 2);
    }
}
