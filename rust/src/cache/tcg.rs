//! The Tool Call Graph (§3.1): an arena-allocated tree whose root-to-node
//! paths are observed tool-call trajectories.
//!
//! Each node stores the tuple `(t, r, s)` — tool descriptor, result, and an
//! *optional* sandbox snapshot handle (selective snapshotting, §3.3) — plus
//! the bookkeeping the eviction and concurrency-control machinery needs:
//! hit counts, a sandbox refcount (§3.4 "Concurrency Control"), and child
//! indices. Stateless tool results (Appendix B) are indexed in a side map on
//! their parent state-mutating node, so reorderings of stateless calls
//! still hit.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};

use super::key::{ToolCall, ToolResult};
use crate::util::json::Json;

pub type NodeId = usize;

/// Snapshot handle: an id into the sandbox manager's snapshot store plus the
/// serialized size (for the Figure 8b memory accounting).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SnapshotRef {
    pub id: u64,
    pub bytes: u64,
    /// Estimated restore (fork) cost in seconds, recorded at snapshot time.
    pub restore_cost: f64,
}

/// One TCG node.
///
/// `hits`, `refcount`, and `warm_fork` are atomics so the read path
/// (`/get`, `/prefix_match`, `/release`, `/warm`) can update them while
/// holding only a *read* lock on the graph — the structural fields still
/// require the write lock.
#[derive(Debug)]
pub struct Node {
    pub call: ToolCall,
    pub result: ToolResult,
    pub snapshot: Option<SnapshotRef>,
    pub parent: NodeId,
    pub depth: u32,
    /// Children keyed by `ToolCall::key()` of the child's call.
    pub children: HashMap<u64, NodeId>,
    /// Stateless tool results indexed at this (state-mutating) node:
    /// key -> (call, result). See Appendix B "Addition to TCG".
    pub stateless: HashMap<u64, (ToolCall, ToolResult)>,
    /// Cache hits served from this node (drives eviction scoring).
    pub hits: AtomicU64,
    /// Live references to this node's sandbox (LPM returns increment;
    /// clients decrement after forking). Non-zero pins the snapshot.
    pub refcount: AtomicU32,
    /// True once a background fork of this node's sandbox is warm (§3.3).
    pub warm_fork: AtomicBool,
}

impl Node {
    pub fn hit_count(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn is_pinned(&self) -> bool {
        self.refcount.load(Ordering::Acquire) > 0
    }
}

/// The per-task tool call graph.
#[derive(Debug)]
pub struct Tcg {
    nodes: Vec<Option<Node>>,
    /// Count of live (non-tombstoned) nodes, excluding the root.
    live: usize,
    /// Eviction generation: bumped on every structural *removal*
    /// (`remove_subtree`). Lookup cursors tag their pinned position with
    /// the generation observed under the lock; an unchanged generation
    /// proves the position is still live without re-probing — insertions
    /// never invalidate a cursor, only removals can. Node ids are never
    /// reused (tombstoned arena), so a removed position can also always be
    /// detected by a direct liveness probe; the tag keeps that true even
    /// if a future refactor recycles ids.
    generation: u64,
}

pub const ROOT: NodeId = 0;

impl Tcg {
    pub fn new() -> Tcg {
        let root = Node {
            call: ToolCall::new("<root>", ""),
            result: ToolResult::new("", 0.0),
            snapshot: None,
            parent: ROOT,
            depth: 0,
            children: HashMap::new(),
            stateless: HashMap::new(),
            hits: AtomicU64::new(0),
            refcount: AtomicU32::new(0),
            warm_fork: AtomicBool::new(false),
        };
        Tcg { nodes: vec![Some(root)], live: 0, generation: 0 }
    }

    /// Current eviction generation (see the field docs).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    pub fn node(&self, id: NodeId) -> Option<&Node> {
        self.nodes.get(id).and_then(|n| n.as_ref())
    }

    pub fn node_mut(&mut self, id: NodeId) -> Option<&mut Node> {
        self.nodes.get_mut(id).and_then(|n| n.as_mut())
    }

    /// Number of non-root live nodes.
    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Follow `call` from `from`; `None` if no such edge.
    pub fn child(&self, from: NodeId, call: &ToolCall) -> Option<NodeId> {
        let node = self.node(from)?;
        let id = *node.children.get(&call.key())?;
        // Hash-collision guard: verify the descriptor actually matches.
        let child = self.node(id)?;
        if child.call.tool == call.tool && child.call.args == call.args {
            Some(id)
        } else {
            None
        }
    }

    /// Append a new child under `parent` (or return the existing one).
    pub fn insert_child(
        &mut self,
        parent: NodeId,
        call: ToolCall,
        result: ToolResult,
    ) -> NodeId {
        if let Some(existing) = self.child(parent, &call) {
            return existing;
        }
        let depth = self.node(parent).map(|n| n.depth + 1).unwrap_or(1);
        let id = self.nodes.len();
        self.nodes.push(Some(Node {
            call: call.clone(),
            result,
            snapshot: None,
            parent,
            depth,
            children: HashMap::new(),
            stateless: HashMap::new(),
            hits: AtomicU64::new(0),
            refcount: AtomicU32::new(0),
            warm_fork: AtomicBool::new(false),
        }));
        if let Some(p) = self.node_mut(parent) {
            p.children.insert(call.key(), id);
        }
        self.live += 1;
        id
    }

    /// Insert `call` under `parent` at exactly arena id `id`, padding any
    /// skipped ids with tombstones. Follower bootstrap uses this to rebuild
    /// a checkpointed graph with the primary's node ids *verbatim* — holes
    /// from prior evictions included — because every later replicated op
    /// names those ids. Refuses (`None`) when the edge already exists at a
    /// different id, `id` is already allocated, or `parent` is not live.
    pub fn insert_child_at(
        &mut self,
        id: NodeId,
        parent: NodeId,
        call: ToolCall,
        result: ToolResult,
    ) -> Option<NodeId> {
        if let Some(existing) = self.child(parent, &call) {
            return (existing == id).then_some(id);
        }
        if id < self.nodes.len() {
            return None;
        }
        let depth = self.node(parent)?.depth + 1;
        while self.nodes.len() < id {
            self.nodes.push(None);
        }
        self.nodes.push(Some(Node {
            call: call.clone(),
            result,
            snapshot: None,
            parent,
            depth,
            children: HashMap::new(),
            stateless: HashMap::new(),
            hits: AtomicU64::new(0),
            refcount: AtomicU32::new(0),
            warm_fork: AtomicBool::new(false),
        }));
        if let Some(p) = self.node_mut(parent) {
            p.children.insert(call.key(), id);
        }
        self.live += 1;
        Some(id)
    }

    /// Record a stateless tool result under a state-mutating node.
    pub fn insert_stateless(
        &mut self,
        at: NodeId,
        call: ToolCall,
        result: ToolResult,
    ) {
        debug_assert!(!call.mutates_state);
        if let Some(n) = self.node_mut(at) {
            n.stateless.insert(call.key(), (call, result));
        }
    }

    /// Look up a stateless result at `at` (descriptor-verified).
    pub fn stateless_result(&self, at: NodeId, call: &ToolCall) -> Option<&ToolResult> {
        let n = self.node(at)?;
        let (stored, result) = n.stateless.get(&call.key())?;
        if stored.tool == call.tool && stored.args == call.args {
            Some(result)
        } else {
            None
        }
    }

    pub fn set_snapshot(&mut self, id: NodeId, snap: SnapshotRef) {
        if let Some(n) = self.node_mut(id) {
            n.snapshot = Some(snap);
        }
    }

    /// Walk up from `id` to the nearest ancestor (inclusive) that has a
    /// snapshot. Returns `(node, snapshot)`.
    pub fn nearest_snapshot(&self, mut id: NodeId) -> Option<(NodeId, SnapshotRef)> {
        loop {
            let n = self.node(id)?;
            if let Some(s) = n.snapshot {
                return Some((id, s));
            }
            if id == ROOT {
                return None;
            }
            id = n.parent;
        }
    }

    /// Path of node ids from the root (exclusive) down to `id` (inclusive).
    pub fn path_from_root(&self, id: NodeId) -> Vec<NodeId> {
        let mut path = Vec::new();
        let mut cur = id;
        while cur != ROOT {
            path.push(cur);
            cur = match self.node(cur) {
                Some(n) => n.parent,
                None => break,
            };
        }
        path.reverse();
        path
    }

    /// All live node ids (excluding the root).
    pub fn live_nodes(&self) -> Vec<NodeId> {
        (1..self.nodes.len())
            .filter(|&i| self.nodes[i].is_some())
            .collect()
    }

    /// Total bytes of stored snapshots (Figure 8b accounting).
    pub fn snapshot_bytes(&self) -> u64 {
        self.live_nodes()
            .iter()
            .filter_map(|&i| self.node(i).and_then(|n| n.snapshot))
            .map(|s| s.bytes)
            .sum()
    }

    /// Number of nodes currently holding snapshots ("cached sandboxes").
    pub fn snapshot_count(&self) -> usize {
        self.live_nodes()
            .iter()
            .filter(|&&i| self.node(i).map(|n| n.snapshot.is_some()).unwrap_or(false))
            .count()
    }

    /// True if any node in the subtree rooted at `id` is refcount-pinned.
    pub fn subtree_pinned(&self, id: NodeId) -> bool {
        let Some(n) = self.node(id) else { return false };
        if n.is_pinned() {
            return true;
        }
        n.children
            .values()
            .any(|&c| self.subtree_pinned(c))
    }

    /// Remove the subtree rooted at `id` (must not be the root). Returns the
    /// snapshot refs freed, so the sandbox manager can drop the sandboxes.
    pub fn remove_subtree(&mut self, id: NodeId) -> Vec<SnapshotRef> {
        assert_ne!(id, ROOT, "cannot evict the TCG root");
        let Some(node) = self.node(id) else { return Vec::new() };
        let parent = node.parent;
        let key = node.call.key();
        self.generation += 1;
        if let Some(p) = self.node_mut(parent) {
            p.children.remove(&key);
        }
        let mut freed = Vec::new();
        let mut stack = vec![id];
        while let Some(cur) = stack.pop() {
            if let Some(n) = self.nodes.get_mut(cur).and_then(|n| n.take()) {
                if let Some(s) = n.snapshot {
                    freed.push(s);
                }
                stack.extend(n.children.values().copied());
                self.live -= 1;
            }
        }
        freed
    }

    /// Render the graph as JSON (the `/viz` endpoint; Figure 9).
    pub fn to_json(&self) -> Json {
        let mut nodes = Vec::new();
        for id in std::iter::once(ROOT).chain(self.live_nodes()) {
            let n = self.node(id).unwrap();
            nodes.push(Json::obj(vec![
                ("id", Json::num(id as f64)),
                ("parent", Json::num(n.parent as f64)),
                ("tool", Json::str(n.call.descriptor())),
                ("depth", Json::num(n.depth as f64)),
                ("hits", Json::num(n.hit_count() as f64)),
                ("has_snapshot", Json::Bool(n.snapshot.is_some())),
                ("stateless_entries", Json::num(n.stateless.len() as f64)),
            ]));
        }
        Json::obj(vec![("nodes", Json::Arr(nodes))])
    }
}

impl Default for Tcg {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn call(s: &str) -> ToolCall {
        ToolCall::new("bash", s)
    }

    fn res(s: &str) -> ToolResult {
        ToolResult::new(s, 1.0)
    }

    #[test]
    fn insert_and_follow_path() {
        let mut g = Tcg::new();
        let a = g.insert_child(ROOT, call("git clone"), res("ok"));
        let b = g.insert_child(a, call("make"), res("built"));
        assert_eq!(g.child(ROOT, &call("git clone")), Some(a));
        assert_eq!(g.child(a, &call("make")), Some(b));
        assert_eq!(g.child(a, &call("make test")), None);
        assert_eq!(g.node(b).unwrap().depth, 2);
        assert_eq!(g.len(), 2);
    }

    #[test]
    fn insert_is_idempotent() {
        let mut g = Tcg::new();
        let a = g.insert_child(ROOT, call("ls"), res("x"));
        let a2 = g.insert_child(ROOT, call("ls"), res("y"));
        assert_eq!(a, a2);
        assert_eq!(g.len(), 1);
        // first result wins (same trajectory ⇒ same state ⇒ same output)
        assert_eq!(g.node(a).unwrap().result.output, "x");
    }

    #[test]
    fn branching_from_shared_prefix() {
        // Figure 3: rollouts share t1 then diverge.
        let mut g = Tcg::new();
        let t1 = g.insert_child(ROOT, call("t1"), res(""));
        let t2 = g.insert_child(t1, call("t2"), res(""));
        let t4 = g.insert_child(t1, call("t4"), res(""));
        assert_ne!(t2, t4);
        assert_eq!(g.node(t1).unwrap().children.len(), 2);
        assert_eq!(g.path_from_root(t4), vec![t1, t4]);
    }

    #[test]
    fn nearest_snapshot_walks_up() {
        let mut g = Tcg::new();
        let a = g.insert_child(ROOT, call("a"), res(""));
        let b = g.insert_child(a, call("b"), res(""));
        let c = g.insert_child(b, call("c"), res(""));
        assert_eq!(g.nearest_snapshot(c), None);
        g.set_snapshot(a, SnapshotRef { id: 9, bytes: 100, restore_cost: 0.5 });
        let (nid, s) = g.nearest_snapshot(c).unwrap();
        assert_eq!(nid, a);
        assert_eq!(s.id, 9);
        // a node with its own snapshot returns itself
        g.set_snapshot(c, SnapshotRef { id: 10, bytes: 1, restore_cost: 0.1 });
        assert_eq!(g.nearest_snapshot(c).unwrap().0, c);
    }

    #[test]
    fn remove_subtree_frees_snapshots_and_detaches() {
        let mut g = Tcg::new();
        let a = g.insert_child(ROOT, call("a"), res(""));
        let b = g.insert_child(a, call("b"), res(""));
        let c = g.insert_child(b, call("c"), res(""));
        g.set_snapshot(b, SnapshotRef { id: 1, bytes: 10, restore_cost: 0.1 });
        g.set_snapshot(c, SnapshotRef { id: 2, bytes: 20, restore_cost: 0.1 });
        let freed = g.remove_subtree(b);
        let mut ids: Vec<u64> = freed.iter().map(|s| s.id).collect();
        ids.sort();
        assert_eq!(ids, vec![1, 2]);
        assert_eq!(g.child(a, &call("b")), None);
        assert!(g.node(b).is_none());
        assert!(g.node(c).is_none());
        assert_eq!(g.len(), 1); // only `a` left
        assert_eq!(g.snapshot_bytes(), 0);
    }

    #[test]
    fn pinning_detected_in_subtree() {
        let mut g = Tcg::new();
        let a = g.insert_child(ROOT, call("a"), res(""));
        let b = g.insert_child(a, call("b"), res(""));
        assert!(!g.subtree_pinned(a));
        g.node_mut(b).unwrap().refcount.store(1, Ordering::Release);
        assert!(g.subtree_pinned(a));
        assert!(g.subtree_pinned(b));
    }

    #[test]
    fn stateless_results_indexed_on_parent() {
        let mut g = Tcg::new();
        let a = g.insert_child(ROOT, call("preprocess"), res(""));
        let s1 = ToolCall::stateless("caption_retrieval", "(0,10)");
        g.insert_stateless(a, s1.clone(), res("caps"));
        assert_eq!(g.stateless_result(a, &s1).unwrap().output, "caps");
        let other = ToolCall::stateless("caption_retrieval", "(5,15)");
        assert!(g.stateless_result(a, &other).is_none());
    }

    #[test]
    fn generation_bumps_only_on_removal() {
        let mut g = Tcg::new();
        assert_eq!(g.generation(), 0);
        let a = g.insert_child(ROOT, call("a"), res(""));
        let b = g.insert_child(a, call("b"), res(""));
        g.insert_stateless(a, ToolCall::stateless("s", "1"), res("x"));
        g.set_snapshot(b, SnapshotRef { id: 1, bytes: 1, restore_cost: 0.1 });
        assert_eq!(g.generation(), 0, "insertions never invalidate cursors");
        g.remove_subtree(b);
        assert_eq!(g.generation(), 1);
        // Removing an already-dead node is a no-op for the generation.
        g.remove_subtree(b);
        assert_eq!(g.generation(), 1);
    }

    #[test]
    fn viz_json_contains_all_nodes() {
        let mut g = Tcg::new();
        let a = g.insert_child(ROOT, call("a"), res(""));
        g.insert_child(a, call("b"), res(""));
        let j = g.to_json();
        assert_eq!(j.get("nodes").unwrap().as_arr().unwrap().len(), 3); // root+2
    }
}
