//! The spill-to-disk snapshot tier (§3.3 extension).
//!
//! Byte-budgeted eviction would normally *destroy* sandbox snapshots; the
//! spill tier demotes them instead: the payload moves from the in-memory
//! [`super::SnapshotStore`] to a file in a spill directory, the TCG keeps
//! its `SnapshotRef`, and a later LPM resume against the spilled node
//! faults the bytes back in from disk (charged a small read penalty via
//! `restore_cost`). The same directory format doubles as the warm-start
//! persistence layer: a run persists every task's TCG plus the snapshot
//! payloads, and a fresh run reloads them so epoch 0 starts warm.
//!
//! On-disk layout (`<dir>/`):
//!
//! * `snap-<id>.bin`    — one file per snapshot, the raw payload bytes.
//! * `manifest.jsonl`   — append-only log, one JSON record per line:
//!   `{"op":"spill","task":…,"id":…,"bytes":…,"serialize_cost":…,
//!   "restore_cost":…}` when a payload lands on disk, `{"op":"drop",
//!   "id":…}` when it is deleted.
//! * `tcgs.json`        — written atomically (tmp + rename) by
//!   `ShardedCacheService::persist_to_dir`: every task's persistent TCG.
//!
//! Crash safety: the payload file is written (tmp + rename) *before* its
//! manifest line, so a record present in the manifest implies a complete
//! payload file. [`load_manifest`] skips torn or corrupt trailing lines and
//! re-verifies every surviving record against the file's actual length —
//! a run killed mid-spill recovers to a consistent store with no dangling
//! references.

use std::collections::HashMap;
use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::sandbox::SandboxSnapshot;
use crate::util::json::{self, Json};

/// Seconds charged on top of a spilled snapshot's `restore_cost` when it is
/// faulted back in from disk (models the payload read; NVMe-scale).
pub const SPILL_FAULT_PENALTY: f64 = 0.02;

/// A snapshot whose payload lives on disk rather than in memory.
#[derive(Debug, Clone, PartialEq)]
pub struct SpillSlot {
    pub path: PathBuf,
    pub bytes: u64,
    pub serialize_cost: f64,
    pub restore_cost: f64,
}

impl SpillSlot {
    /// Read the payload back (the fault-in path). `None` if the file is
    /// gone or shorter than recorded — callers degrade to replay.
    pub fn fault(&self) -> Option<SandboxSnapshot> {
        let bytes = fs::read(&self.path).ok()?;
        if bytes.len() as u64 != self.bytes {
            return None;
        }
        Some(SandboxSnapshot {
            bytes,
            serialize_cost: self.serialize_cost,
            restore_cost: self.restore_cost,
        })
    }
}

/// One valid manifest record after replaying the log.
#[derive(Debug, Clone)]
pub struct ManifestRecord {
    pub task: String,
    pub id: u64,
    pub bytes: u64,
    pub serialize_cost: f64,
    pub restore_cost: f64,
}

impl ManifestRecord {
    pub fn slot(&self, dir: &Path) -> SpillSlot {
        SpillSlot {
            path: payload_path(dir, self.id),
            bytes: self.bytes,
            serialize_cost: self.serialize_cost,
            restore_cost: self.restore_cost,
        }
    }
}

pub fn payload_path(dir: &Path, id: u64) -> PathBuf {
    dir.join(format!("snap-{id}.bin"))
}

fn manifest_path(dir: &Path) -> PathBuf {
    dir.join("manifest.jsonl")
}

/// Writer side of the spill directory: payload files + append-only manifest.
#[derive(Debug)]
pub struct SpillStore {
    dir: PathBuf,
    manifest: Mutex<fs::File>,
}

impl SpillStore {
    /// Create/open the spill directory, appending to an existing manifest.
    pub fn open(dir: impl Into<PathBuf>) -> std::io::Result<SpillStore> {
        let dir = dir.into();
        fs::create_dir_all(&dir)?;
        let manifest = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(manifest_path(&dir))?;
        Ok(SpillStore { dir, manifest: Mutex::new(manifest) })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Write `snap`'s payload for `id` and record it in the manifest.
    /// `restore_cost` is taken from the caller (the TCG ref's value), not
    /// the payload, so fault penalties never compound across re-spills.
    pub fn write(
        &self,
        task: &str,
        id: u64,
        snap: &SandboxSnapshot,
        restore_cost: f64,
    ) -> std::io::Result<SpillSlot> {
        let path = payload_path(&self.dir, id);
        let tmp = self.dir.join(format!("snap-{id}.tmp"));
        fs::write(&tmp, &snap.bytes)?;
        fs::rename(&tmp, &path)?;
        let record = Json::obj(vec![
            ("op", Json::str("spill")),
            ("task", Json::str(task)),
            ("id", Json::num(id as f64)),
            ("bytes", Json::num(snap.bytes.len() as f64)),
            ("serialize_cost", Json::num(snap.serialize_cost)),
            ("restore_cost", Json::num(restore_cost)),
        ]);
        self.append_line(&record.to_string())?;
        Ok(SpillSlot {
            path,
            bytes: snap.bytes.len() as u64,
            serialize_cost: snap.serialize_cost,
            restore_cost,
        })
    }

    /// Append a manifest record for a payload whose file is already in
    /// place at `slot.path` (persisting an already-spilled snapshot: no
    /// byte rewrite needed).
    pub fn record(
        &self,
        task: &str,
        id: u64,
        slot: &SpillSlot,
        restore_cost: f64,
    ) -> std::io::Result<()> {
        let record = Json::obj(vec![
            ("op", Json::str("spill")),
            ("task", Json::str(task)),
            ("id", Json::num(id as f64)),
            ("bytes", Json::num(slot.bytes as f64)),
            ("serialize_cost", Json::num(slot.serialize_cost)),
            ("restore_cost", Json::num(restore_cost)),
        ]);
        self.append_line(&record.to_string())
    }

    /// Record that `id`'s payload is gone and best-effort delete the file.
    pub fn drop_payload(&self, id: u64) {
        let record =
            Json::obj(vec![("op", Json::str("drop")), ("id", Json::num(id as f64))]);
        let _ = self.append_line(&record.to_string());
        let _ = fs::remove_file(payload_path(&self.dir, id));
    }

    fn append_line(&self, line: &str) -> std::io::Result<()> {
        let mut f = self.manifest.lock().unwrap();
        f.write_all(line.as_bytes())?;
        f.write_all(b"\n")?;
        f.flush()
    }
}

/// Replay `<dir>/manifest.jsonl` into the set of currently valid records.
///
/// Later records for an id supersede earlier ones; `drop` records retract.
/// Torn/corrupt lines (a crash mid-append) and records whose payload file
/// is missing or has the wrong length are skipped, so the result is always
/// self-consistent. An absent manifest is an empty store, not an error.
pub fn load_manifest(dir: &Path) -> HashMap<u64, ManifestRecord> {
    let mut records: HashMap<u64, ManifestRecord> = HashMap::new();
    let Ok(text) = fs::read_to_string(manifest_path(dir)) else {
        return records;
    };
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let Ok(v) = json::parse(line) else {
            continue; // torn or corrupt line: skip
        };
        match v.get("op").and_then(Json::as_str) {
            Some("spill") => {
                let (Some(id), Some(bytes), Some(ser), Some(rest)) = (
                    v.get("id").and_then(Json::as_u64),
                    v.get("bytes").and_then(Json::as_u64),
                    v.get("serialize_cost").and_then(Json::as_f64),
                    v.get("restore_cost").and_then(Json::as_f64),
                ) else {
                    continue;
                };
                let task = v
                    .get("task")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string();
                records.insert(
                    id,
                    ManifestRecord {
                        task,
                        id,
                        bytes,
                        serialize_cost: ser,
                        restore_cost: rest,
                    },
                );
            }
            Some("drop") => {
                if let Some(id) = v.get("id").and_then(Json::as_u64) {
                    records.remove(&id);
                }
            }
            _ => {}
        }
    }
    // Re-verify against the payload files: a record is only as good as the
    // bytes behind it.
    records.retain(|id, r| {
        fs::metadata(payload_path(dir, *id))
            .map(|m| m.len() == r.bytes)
            .unwrap_or(false)
    });
    records
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("tvcache-spill-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn snap(fill: u8, n: usize) -> SandboxSnapshot {
        SandboxSnapshot {
            bytes: vec![fill; n],
            serialize_cost: 0.3,
            restore_cost: 0.7,
        }
    }

    #[test]
    fn spill_and_fault_roundtrip() {
        let dir = tmpdir("roundtrip");
        let store = SpillStore::open(&dir).unwrap();
        let slot = store.write("t", 5, &snap(9, 64), 0.7).unwrap();
        assert_eq!(slot.bytes, 64);
        let back = slot.fault().unwrap();
        assert_eq!(back.bytes, vec![9u8; 64]);
        assert!((back.restore_cost - 0.7).abs() < 1e-12);

        let records = load_manifest(&dir);
        assert_eq!(records.len(), 1);
        assert_eq!(records[&5].bytes, 64);
        assert_eq!(records[&5].task, "t");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn drop_retracts_record_and_file() {
        let dir = tmpdir("drop");
        let store = SpillStore::open(&dir).unwrap();
        store.write("t", 1, &snap(1, 8), 0.5).unwrap();
        store.write("t", 2, &snap(2, 8), 0.5).unwrap();
        store.drop_payload(1);
        let records = load_manifest(&dir);
        assert!(!records.contains_key(&1));
        assert!(records.contains_key(&2));
        assert!(!payload_path(&dir, 1).exists());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn truncated_manifest_recovers_to_valid_prefix() {
        let dir = tmpdir("trunc");
        let store = SpillStore::open(&dir).unwrap();
        for id in 1..=4u64 {
            store.write("t", id, &snap(id as u8, 32), 0.5).unwrap();
        }
        drop(store);
        let full = fs::read(manifest_path(&dir)).unwrap();
        // Truncate at every offset: recovery must never panic, and every
        // surviving record must be backed by an intact payload file.
        for cut in 0..=full.len() {
            fs::write(manifest_path(&dir), &full[..cut]).unwrap();
            let records = load_manifest(&dir);
            for (id, r) in &records {
                let slot = r.slot(&dir);
                assert!(slot.fault().is_some(), "cut {cut}: dangling record {id}");
            }
            assert!(records.len() <= 4);
        }
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn record_with_missing_payload_is_discarded() {
        let dir = tmpdir("missing");
        let store = SpillStore::open(&dir).unwrap();
        store.write("t", 7, &snap(7, 16), 0.5).unwrap();
        fs::remove_file(payload_path(&dir, 7)).unwrap();
        assert!(load_manifest(&dir).is_empty());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_dir_is_empty_not_error() {
        let dir = tmpdir("absent");
        assert!(load_manifest(&dir).is_empty());
    }
}
