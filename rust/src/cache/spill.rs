//! The spill-to-disk snapshot tier (§3.3 extension).
//!
//! Byte-budgeted eviction would normally *destroy* sandbox snapshots; the
//! spill tier demotes them instead: the payload moves from the in-memory
//! [`super::SnapshotStore`] to a file in a spill directory, the TCG keeps
//! its `SnapshotRef`, and a later LPM resume against the spilled node
//! faults the bytes back in from disk (charged a small read penalty via
//! `restore_cost`). The same directory format doubles as the warm-start
//! persistence layer: a run persists every task's TCG plus the snapshot
//! payloads, and a fresh run reloads them so epoch 0 starts warm.
//!
//! On-disk layout (`<dir>/`):
//!
//! * `snap-<id>.bin`    — one file per snapshot, the raw payload bytes.
//! * `manifest.jsonl`   — append-only log, one JSON record per line:
//!   `{"op":"spill","task":…,"id":…,"bytes":…,"serialize_cost":…,
//!   "restore_cost":…}` when a payload lands on disk, `{"op":"drop",
//!   "id":…}` when it is deleted.
//! * `tcgs.json`        — written atomically (tmp + rename) by
//!   `ShardedCacheService::persist_to_dir`: every task's persistent TCG.
//!
//! Crash safety: the payload file is written (tmp + rename) *before* its
//! manifest line, so a record present in the manifest implies a complete
//! payload file. [`load_manifest`] skips torn or corrupt trailing lines and
//! re-verifies every surviving record against the file's actual length —
//! a run killed mid-spill recovers to a consistent store with no dangling
//! references.

use std::collections::HashMap;
use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::sandbox::SandboxSnapshot;
use crate::util::json::{self, Json};

/// Seconds charged on top of a spilled snapshot's `restore_cost` when it is
/// faulted back in from disk (models the payload read; NVMe-scale).
pub const SPILL_FAULT_PENALTY: f64 = 0.02;

/// A snapshot whose payload lives on disk rather than in memory.
#[derive(Debug, Clone, PartialEq)]
pub struct SpillSlot {
    pub path: PathBuf,
    pub bytes: u64,
    pub serialize_cost: f64,
    pub restore_cost: f64,
}

impl SpillSlot {
    /// Read the payload back (the fault-in path). `None` if the file is
    /// gone or shorter than recorded — callers degrade to replay.
    pub fn fault(&self) -> Option<SandboxSnapshot> {
        let bytes = fs::read(&self.path).ok()?;
        if bytes.len() as u64 != self.bytes {
            return None;
        }
        Some(SandboxSnapshot {
            bytes,
            serialize_cost: self.serialize_cost,
            restore_cost: self.restore_cost,
        })
    }
}

/// One valid manifest record after replaying the log.
#[derive(Debug, Clone)]
pub struct ManifestRecord {
    pub task: String,
    pub id: u64,
    pub bytes: u64,
    pub serialize_cost: f64,
    pub restore_cost: f64,
}

impl ManifestRecord {
    pub fn slot(&self, dir: &Path) -> SpillSlot {
        SpillSlot {
            path: payload_path(dir, self.id),
            bytes: self.bytes,
            serialize_cost: self.serialize_cost,
            restore_cost: self.restore_cost,
        }
    }

    /// The record's manifest line — the one serialization both the append
    /// path and the compaction rewrite emit, so the two can never drift.
    fn to_line(&self) -> String {
        Json::obj(vec![
            ("op", Json::str("spill")),
            ("task", Json::str(self.task.as_str())),
            ("id", Json::num(self.id as f64)),
            ("bytes", Json::num(self.bytes as f64)),
            ("serialize_cost", Json::num(self.serialize_cost)),
            ("restore_cost", Json::num(self.restore_cost)),
        ])
        .to_string()
    }
}

pub fn payload_path(dir: &Path, id: u64) -> PathBuf {
    dir.join(format!("snap-{id}.bin"))
}

fn manifest_path(dir: &Path) -> PathBuf {
    dir.join("manifest.jsonl")
}

/// Compaction is considered once the manifest holds at least this many
/// lines (tiny manifests are never worth rewriting).
const COMPACT_MIN_LINES: u64 = 64;

/// The manifest writer behind [`SpillStore`]'s mutex: the append handle
/// plus the bookkeeping compaction needs — total line count and the
/// currently-live records (superseded and dropped lines are *dead*).
#[derive(Debug)]
struct ManifestState {
    file: fs::File,
    /// Lines in the manifest file (live + dead).
    lines: u64,
    /// Live records by id — exactly what a fresh [`load_manifest`] would
    /// return, maintained incrementally so compaction never re-reads.
    live: HashMap<u64, ManifestRecord>,
    /// Lifetime compaction passes (tests / diagnostics).
    compactions: u64,
}

/// Writer side of the spill directory: payload files + append-only manifest
/// with automatic compaction — when dead lines (drops + superseded spills)
/// exceed half the manifest, it is rewritten to just the live records via
/// a temp file + atomic rename, so a crash at any point leaves either the
/// old or the new manifest intact, never a torn one.
#[derive(Debug)]
pub struct SpillStore {
    dir: PathBuf,
    manifest: Mutex<ManifestState>,
    /// Compaction gate: disabled for secondary writers (`persist_to_dir`
    /// into a live spill directory) — a rewrite under an aliased append
    /// handle would strand the other writer's fd on the unlinked inode.
    compact: bool,
}

impl SpillStore {
    /// Create/open the spill directory, appending to an existing manifest.
    /// This primary handle compacts the manifest when it grows mostly dead.
    pub fn open(dir: impl Into<PathBuf>) -> std::io::Result<SpillStore> {
        Self::open_with(dir, true)
    }

    /// As [`SpillStore::open`], but never compacts — for secondary writers
    /// appending to a directory another `SpillStore` may own.
    pub fn open_append_only(dir: impl Into<PathBuf>) -> std::io::Result<SpillStore> {
        Self::open_with(dir, false)
    }

    fn open_with(dir: impl Into<PathBuf>, compact: bool) -> std::io::Result<SpillStore> {
        let dir = dir.into();
        fs::create_dir_all(&dir)?;
        // A stray tmp from a compaction that crashed pre-rename is dead
        // weight; the manifest itself is untouched by such a crash.
        let _ = fs::remove_file(dir.join("manifest.jsonl.tmp"));
        // One read serves both the compaction bookkeeping (line count) and
        // the live-record map.
        let text = fs::read_to_string(manifest_path(&dir)).unwrap_or_default();
        let lines = text.lines().filter(|l| !l.trim().is_empty()).count() as u64;
        let live = parse_manifest(&dir, &text);
        let file = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(manifest_path(&dir))?;
        Ok(SpillStore {
            dir,
            manifest: Mutex::new(ManifestState { file, lines, live, compactions: 0 }),
            compact,
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Manifest lines currently in the file (tests / diagnostics).
    pub fn manifest_lines(&self) -> u64 {
        self.manifest.lock().unwrap().lines
    }

    /// Lifetime compaction passes (tests / diagnostics).
    pub fn compaction_count(&self) -> u64 {
        self.manifest.lock().unwrap().compactions
    }

    /// Write `snap`'s payload for `id` and record it in the manifest.
    /// `restore_cost` is taken from the caller (the TCG ref's value), not
    /// the payload, so fault penalties never compound across re-spills.
    pub fn write(
        &self,
        task: &str,
        id: u64,
        snap: &SandboxSnapshot,
        restore_cost: f64,
    ) -> std::io::Result<SpillSlot> {
        let path = payload_path(&self.dir, id);
        let tmp = self.dir.join(format!("snap-{id}.tmp"));
        fs::write(&tmp, &snap.bytes)?;
        fs::rename(&tmp, &path)?;
        self.append_spill(ManifestRecord {
            task: task.to_string(),
            id,
            bytes: snap.bytes.len() as u64,
            serialize_cost: snap.serialize_cost,
            restore_cost,
        })?;
        Ok(SpillSlot {
            path,
            bytes: snap.bytes.len() as u64,
            serialize_cost: snap.serialize_cost,
            restore_cost,
        })
    }

    /// Append a manifest record for a payload whose file is already in
    /// place at `slot.path` (persisting an already-spilled snapshot: no
    /// byte rewrite needed).
    pub fn record(
        &self,
        task: &str,
        id: u64,
        slot: &SpillSlot,
        restore_cost: f64,
    ) -> std::io::Result<()> {
        self.append_spill(ManifestRecord {
            task: task.to_string(),
            id,
            bytes: slot.bytes,
            serialize_cost: slot.serialize_cost,
            restore_cost,
        })
    }

    /// Record that `id`'s payload is gone and best-effort delete the file.
    pub fn drop_payload(&self, id: u64) {
        let line =
            Json::obj(vec![("op", Json::str("drop")), ("id", Json::num(id as f64))]).to_string();
        {
            let mut st = self.manifest.lock().unwrap();
            if Self::append_line(&mut st, &line).is_ok() {
                st.live.remove(&id);
                self.maybe_compact(&mut st);
            }
        }
        let _ = fs::remove_file(payload_path(&self.dir, id));
    }

    fn append_spill(&self, rec: ManifestRecord) -> std::io::Result<()> {
        let line = rec.to_line();
        let mut st = self.manifest.lock().unwrap();
        Self::append_line(&mut st, &line)?;
        st.live.insert(rec.id, rec);
        self.maybe_compact(&mut st);
        Ok(())
    }

    fn append_line(st: &mut ManifestState, line: &str) -> std::io::Result<()> {
        st.file.write_all(line.as_bytes())?;
        st.file.write_all(b"\n")?;
        st.file.flush()?;
        st.lines += 1;
        Ok(())
    }

    /// Rewrite the manifest to just the live records once dead lines
    /// (drops + superseded spills) exceed 50% of a non-trivial file.
    /// Crash-safe: the replacement is fully written and flushed to a temp
    /// file, then atomically renamed over the manifest — a crash before
    /// the rename leaves the old (correct, just bloated) manifest; a crash
    /// after leaves the new one. Failures are swallowed: compaction is an
    /// optimization, the append-only log stays authoritative.
    fn maybe_compact(&self, st: &mut ManifestState) {
        if !self.compact
            || st.lines < COMPACT_MIN_LINES
            || st.lines <= 2 * st.live.len() as u64
        {
            return;
        }
        let mut ids: Vec<u64> = st.live.keys().copied().collect();
        ids.sort_unstable();
        let mut out = String::with_capacity(ids.len() * 96);
        for id in &ids {
            out.push_str(&st.live[id].to_line());
            out.push('\n');
        }
        let tmp = self.dir.join("manifest.jsonl.tmp");
        let rewrite = || -> std::io::Result<fs::File> {
            fs::write(&tmp, &out)?;
            fs::rename(&tmp, manifest_path(&self.dir))?;
            // The old append handle points at the unlinked inode: reopen.
            fs::OpenOptions::new().append(true).open(manifest_path(&self.dir))
        };
        match rewrite() {
            Ok(file) => {
                st.file = file;
                st.lines = ids.len() as u64;
                st.compactions += 1;
            }
            Err(_) => {
                let _ = fs::remove_file(&tmp);
            }
        }
    }
}

/// Replay `<dir>/manifest.jsonl` into the set of currently valid records.
///
/// Later records for an id supersede earlier ones; `drop` records retract.
/// Torn/corrupt lines (a crash mid-append) and records whose payload file
/// is missing or has the wrong length are skipped, so the result is always
/// self-consistent. An absent manifest is an empty store, not an error.
pub fn load_manifest(dir: &Path) -> HashMap<u64, ManifestRecord> {
    let Ok(text) = fs::read_to_string(manifest_path(dir)) else {
        return HashMap::new();
    };
    parse_manifest(dir, &text)
}

/// Replay already-read manifest text (shared by [`load_manifest`] and the
/// single-read open path).
fn parse_manifest(dir: &Path, text: &str) -> HashMap<u64, ManifestRecord> {
    let mut records: HashMap<u64, ManifestRecord> = HashMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let Ok(v) = json::parse(line) else {
            continue; // torn or corrupt line: skip
        };
        match v.get("op").and_then(Json::as_str) {
            Some("spill") => {
                let (Some(id), Some(bytes), Some(ser), Some(rest)) = (
                    v.get("id").and_then(Json::as_u64),
                    v.get("bytes").and_then(Json::as_u64),
                    v.get("serialize_cost").and_then(Json::as_f64),
                    v.get("restore_cost").and_then(Json::as_f64),
                ) else {
                    continue;
                };
                let task = v
                    .get("task")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string();
                records.insert(
                    id,
                    ManifestRecord {
                        task,
                        id,
                        bytes,
                        serialize_cost: ser,
                        restore_cost: rest,
                    },
                );
            }
            Some("drop") => {
                if let Some(id) = v.get("id").and_then(Json::as_u64) {
                    records.remove(&id);
                }
            }
            _ => {}
        }
    }
    // Re-verify against the payload files: a record is only as good as the
    // bytes behind it.
    records.retain(|id, r| {
        fs::metadata(payload_path(dir, *id))
            .map(|m| m.len() == r.bytes)
            .unwrap_or(false)
    });
    records
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("tvcache-spill-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn snap(fill: u8, n: usize) -> SandboxSnapshot {
        SandboxSnapshot {
            bytes: vec![fill; n],
            serialize_cost: 0.3,
            restore_cost: 0.7,
        }
    }

    #[test]
    fn spill_and_fault_roundtrip() {
        let dir = tmpdir("roundtrip");
        let store = SpillStore::open(&dir).unwrap();
        let slot = store.write("t", 5, &snap(9, 64), 0.7).unwrap();
        assert_eq!(slot.bytes, 64);
        let back = slot.fault().unwrap();
        assert_eq!(back.bytes, vec![9u8; 64]);
        assert!((back.restore_cost - 0.7).abs() < 1e-12);

        let records = load_manifest(&dir);
        assert_eq!(records.len(), 1);
        assert_eq!(records[&5].bytes, 64);
        assert_eq!(records[&5].task, "t");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn drop_retracts_record_and_file() {
        let dir = tmpdir("drop");
        let store = SpillStore::open(&dir).unwrap();
        store.write("t", 1, &snap(1, 8), 0.5).unwrap();
        store.write("t", 2, &snap(2, 8), 0.5).unwrap();
        store.drop_payload(1);
        let records = load_manifest(&dir);
        assert!(!records.contains_key(&1));
        assert!(records.contains_key(&2));
        assert!(!payload_path(&dir, 1).exists());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn truncated_manifest_recovers_to_valid_prefix() {
        let dir = tmpdir("trunc");
        let store = SpillStore::open(&dir).unwrap();
        for id in 1..=4u64 {
            store.write("t", id, &snap(id as u8, 32), 0.5).unwrap();
        }
        drop(store);
        let full = fs::read(manifest_path(&dir)).unwrap();
        // Truncate at every offset: recovery must never panic, and every
        // surviving record must be backed by an intact payload file.
        for cut in 0..=full.len() {
            fs::write(manifest_path(&dir), &full[..cut]).unwrap();
            let records = load_manifest(&dir);
            for (id, r) in &records {
                let slot = r.slot(&dir);
                assert!(slot.fault().is_some(), "cut {cut}: dangling record {id}");
            }
            assert!(records.len() <= 4);
        }
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn record_with_missing_payload_is_discarded() {
        let dir = tmpdir("missing");
        let store = SpillStore::open(&dir).unwrap();
        store.write("t", 7, &snap(7, 16), 0.5).unwrap();
        fs::remove_file(payload_path(&dir, 7)).unwrap();
        assert!(load_manifest(&dir).is_empty());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_dir_is_empty_not_error() {
        let dir = tmpdir("absent");
        assert!(load_manifest(&dir).is_empty());
    }

    // ---- manifest compaction ----

    #[test]
    fn compaction_rewrites_mostly_dead_manifest_without_losing_records() {
        let dir = tmpdir("compact");
        let store = SpillStore::open(&dir).unwrap();
        // 60 spills, 50 of them dropped: 110 lines, 10 live (> 50% dead),
        // crossing COMPACT_MIN_LINES on the way.
        for id in 1..=60u64 {
            store.write("t", id, &snap(id as u8, 8 + id as usize), 0.5).unwrap();
        }
        for id in 1..=50u64 {
            store.drop_payload(id);
        }
        assert!(store.compaction_count() >= 1, "compaction must have triggered");
        assert!(
            store.manifest_lines() <= 20,
            "compacted manifest still bloated: {} lines",
            store.manifest_lines()
        );
        // The compacted manifest is semantically identical: exactly the 10
        // survivors, each backed by its payload.
        let records = load_manifest(&dir);
        assert_eq!(records.len(), 10);
        for id in 51..=60u64 {
            let r = &records[&id];
            assert_eq!(r.bytes, 8 + id);
            assert_eq!(r.task, "t");
            assert_eq!(r.slot(&dir).fault().unwrap().bytes.len() as u64, 8 + id);
        }
        // And the store keeps appending correctly after the rewrite (the
        // handle was re-opened on the new inode).
        store.write("t", 99, &snap(9, 32), 0.5).unwrap();
        assert_eq!(load_manifest(&dir).len(), 11);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn small_or_mostly_live_manifests_are_never_compacted() {
        let dir = tmpdir("compact-skip");
        let store = SpillStore::open(&dir).unwrap();
        for id in 1..=10u64 {
            store.write("t", id, &snap(1, 8), 0.5).unwrap();
        }
        store.drop_payload(1); // 11 lines, far below COMPACT_MIN_LINES
        assert_eq!(store.compaction_count(), 0);
        // Mostly-live large manifest: 100 lines, 90 live — no compaction.
        for id in 11..=100u64 {
            store.write("t", id, &snap(1, 8), 0.5).unwrap();
        }
        assert_eq!(store.compaction_count(), 0, "live manifests must not be rewritten");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn crashed_compaction_tmp_is_ignored_and_cleaned() {
        let dir = tmpdir("compact-crash");
        let store = SpillStore::open(&dir).unwrap();
        for id in 1..=4u64 {
            store.write("t", id, &snap(id as u8, 16), 0.5).unwrap();
        }
        drop(store);
        // Simulate a compaction that died before its atomic rename: a stray
        // tmp full of garbage next to an intact manifest.
        fs::write(dir.join("manifest.jsonl.tmp"), b"{\"op\":\"drop\",\"id\":1}\ngarbage").unwrap();
        // Recovery ignores the tmp entirely…
        assert_eq!(load_manifest(&dir).len(), 4);
        // …and reopening the store clears it.
        let store = SpillStore::open(&dir).unwrap();
        assert!(!dir.join("manifest.jsonl.tmp").exists());
        assert_eq!(store.manifest_lines(), 4);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn truncated_manifest_after_compaction_still_recovers() {
        let dir = tmpdir("compact-trunc");
        let store = SpillStore::open(&dir).unwrap();
        for id in 1..=70u64 {
            store.write("t", id, &snap(id as u8, 16), 0.5).unwrap();
        }
        for id in 1..=60u64 {
            store.drop_payload(id);
        }
        assert!(store.compaction_count() >= 1);
        drop(store);
        // The crash-safety property must hold for the *rewritten* file too:
        // truncate at every offset; every surviving record stays backed.
        let full = fs::read(manifest_path(&dir)).unwrap();
        for cut in 0..=full.len() {
            fs::write(manifest_path(&dir), &full[..cut]).unwrap();
            let records = load_manifest(&dir);
            for (id, r) in &records {
                assert!(r.slot(&dir).fault().is_some(), "cut {cut}: dangling record {id}");
            }
            assert!(records.len() <= 10);
        }
        fs::remove_dir_all(&dir).unwrap();
    }
}
