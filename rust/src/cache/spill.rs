//! The spill-to-disk snapshot tier (§3.3 extension).
//!
//! Byte-budgeted eviction would normally *destroy* sandbox snapshots; the
//! spill tier demotes them instead: the payload moves from the in-memory
//! [`super::SnapshotStore`] to a file in a spill directory, the TCG keeps
//! its `SnapshotRef`, and a later LPM resume against the spilled node
//! faults the bytes back in from disk (charged a small read penalty via
//! `restore_cost`). The same directory format doubles as the warm-start
//! persistence layer: a run persists every task's TCG plus the snapshot
//! payloads, and a fresh run reloads them so epoch 0 starts warm.
//!
//! On-disk layout (`<dir>/`):
//!
//! * `snap-<id>.bin`    — one payload file per snapshot id (legacy,
//!   pre-content-hash records).
//! * `snap-k<hex>.bin`  — one payload file per *content key* (64 hex
//!   chars): deduped snapshots share the file, and a write whose key
//!   already has a complete file on disk skips the byte write entirely.
//! * `manifest.jsonl`   — append-only log, one JSON record per line:
//!   `{"op":"spill","task":…,"id":…,"bytes":…,"serialize_cost":…,
//!   "restore_cost":…,"key":…}` when a payload lands on disk (the `key`
//!   column is absent on legacy lines and reloads fine without it),
//!   `{"op":"drop","id":…}` when a record is retracted. A shared payload
//!   file is only deleted when its *last* referencing record drops.
//! * `tcgs.json`        — written atomically (tmp + rename) by
//!   `ShardedCacheService::persist_to_dir`: every task's persistent TCG.
//!
//! Crash safety: the payload file is written (tmp + rename) *before* its
//! manifest line, so a record present in the manifest implies a complete
//! payload file. [`load_manifest`] skips torn or corrupt trailing lines and
//! re-verifies every surviving record against the file's actual length —
//! a run killed mid-spill recovers to a consistent store with no dangling
//! references.

use std::collections::HashMap;
use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

use super::payload::ContentKey;
use crate::sandbox::SandboxSnapshot;
use crate::util::fault;
use crate::util::json::{self, Json};

/// Seconds charged on top of a spilled snapshot's `restore_cost` when it is
/// faulted back in from disk (models the payload read; NVMe-scale).
pub const SPILL_FAULT_PENALTY: f64 = 0.02;

/// Flush `tmp`'s data blocks, atomically rename it over `dst`, then flush
/// the parent directory entry. Without the first fsync, a power cut after
/// the rename can leave the *name* pointing at unwritten blocks — an
/// atomic rename only orders metadata, not data. The directory flush is
/// best-effort (not every filesystem supports fsync on a directory fd):
/// losing it re-exposes only the old name, which every caller here
/// tolerates by design.
fn durable_rename(tmp: &Path, dst: &Path) -> std::io::Result<()> {
    fs::File::open(tmp)?.sync_all()?;
    fs::rename(tmp, dst)?;
    if let Some(parent) = dst.parent() {
        if let Ok(d) = fs::File::open(parent) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

/// A snapshot whose payload lives on disk rather than in memory.
#[derive(Debug, Clone, PartialEq)]
pub struct SpillSlot {
    pub path: PathBuf,
    /// Content key of the payload (`None` for legacy keyless records).
    pub key: Option<ContentKey>,
    pub bytes: u64,
    pub serialize_cost: f64,
    pub restore_cost: f64,
}

impl SpillSlot {
    /// Read the payload back (the fault-in path). `None` if the file is
    /// gone or shorter than recorded — callers degrade to replay.
    pub fn fault(&self) -> Option<SandboxSnapshot> {
        if fault::spill_read_fails() {
            return None; // injected read fault: degrade to replay
        }
        let bytes = fs::read(&self.path).ok()?;
        if bytes.len() as u64 != self.bytes {
            return None;
        }
        Some(SandboxSnapshot {
            bytes,
            serialize_cost: self.serialize_cost,
            restore_cost: self.restore_cost,
        })
    }
}

/// One valid manifest record after replaying the log.
#[derive(Debug, Clone)]
pub struct ManifestRecord {
    pub task: String,
    pub id: u64,
    /// Content key (`None` on legacy lines written before dedup).
    pub key: Option<ContentKey>,
    pub bytes: u64,
    pub serialize_cost: f64,
    pub restore_cost: f64,
}

impl ManifestRecord {
    pub fn slot(&self, dir: &Path) -> SpillSlot {
        SpillSlot {
            path: self.payload_path(dir),
            key: self.key,
            bytes: self.bytes,
            serialize_cost: self.serialize_cost,
            restore_cost: self.restore_cost,
        }
    }

    /// Where this record's payload bytes live: the content-keyed file for
    /// keyed records, the per-id legacy file otherwise.
    pub fn payload_path(&self, dir: &Path) -> PathBuf {
        match &self.key {
            Some(k) => payload_path_keyed(dir, k),
            None => payload_path(dir, self.id),
        }
    }

    /// The record's manifest line — the one serialization both the append
    /// path and the compaction rewrite emit, so the two can never drift.
    fn to_line(&self) -> String {
        let mut fields = vec![
            ("op", Json::str("spill")),
            ("task", Json::str(self.task.as_str())),
            ("id", Json::num(self.id as f64)),
            ("bytes", Json::num(self.bytes as f64)),
            ("serialize_cost", Json::num(self.serialize_cost)),
            ("restore_cost", Json::num(self.restore_cost)),
        ];
        if let Some(k) = &self.key {
            fields.push(("key", Json::str(k.to_hex())));
        }
        Json::obj(fields).to_string()
    }
}

pub fn payload_path(dir: &Path, id: u64) -> PathBuf {
    dir.join(format!("snap-{id}.bin"))
}

/// Payload file for a content-keyed record: shared by every record whose
/// snapshot hashes to `key`.
pub fn payload_path_keyed(dir: &Path, key: &ContentKey) -> PathBuf {
    dir.join(format!("snap-k{}.bin", key.to_hex()))
}

fn manifest_path(dir: &Path) -> PathBuf {
    dir.join("manifest.jsonl")
}

/// Compaction is considered once the manifest holds at least this many
/// lines (tiny manifests are never worth rewriting).
const COMPACT_MIN_LINES: u64 = 64;

/// The manifest writer behind [`SpillStore`]'s mutex: the append handle
/// plus the bookkeeping compaction needs — total line count and the
/// currently-live records (superseded and dropped lines are *dead*).
#[derive(Debug)]
struct ManifestState {
    file: fs::File,
    /// Lines in the manifest file (live + dead).
    lines: u64,
    /// Live records by id — exactly what a fresh [`load_manifest`] would
    /// return, maintained incrementally so compaction never re-reads.
    live: HashMap<u64, ManifestRecord>,
    /// Lifetime compaction passes (tests / diagnostics).
    compactions: u64,
}

/// Writer side of the spill directory: payload files + append-only manifest
/// with automatic compaction — when dead lines (drops + superseded spills)
/// exceed half the manifest, it is rewritten to just the live records via
/// a temp file + atomic rename, so a crash at any point leaves either the
/// old or the new manifest intact, never a torn one.
#[derive(Debug)]
pub struct SpillStore {
    dir: PathBuf,
    manifest: Mutex<ManifestState>,
    /// Compaction gate: disabled for secondary writers (`persist_to_dir`
    /// into a live spill directory) — a rewrite under an aliased append
    /// handle would strand the other writer's fd on the unlinked inode.
    compact: bool,
    /// Resident-only mode: set (and never cleared for the store's
    /// lifetime) when a payload write or manifest append fails — ENOSPC,
    /// a torn rename, an injected fault. New writes refuse immediately so
    /// eviction falls back to destroying snapshots; payloads already on
    /// disk keep faulting in.
    degraded: AtomicBool,
}

impl SpillStore {
    /// Create/open the spill directory, appending to an existing manifest.
    /// This primary handle compacts the manifest when it grows mostly dead.
    pub fn open(dir: impl Into<PathBuf>) -> std::io::Result<SpillStore> {
        Self::open_with(dir, true)
    }

    /// As [`SpillStore::open`], but never compacts — for secondary writers
    /// appending to a directory another `SpillStore` may own.
    pub fn open_append_only(dir: impl Into<PathBuf>) -> std::io::Result<SpillStore> {
        Self::open_with(dir, false)
    }

    fn open_with(dir: impl Into<PathBuf>, compact: bool) -> std::io::Result<SpillStore> {
        let dir = dir.into();
        fs::create_dir_all(&dir)?;
        // A stray tmp from a compaction that crashed pre-rename is dead
        // weight; the manifest itself is untouched by such a crash.
        let _ = fs::remove_file(dir.join("manifest.jsonl.tmp"));
        // One read serves both the compaction bookkeeping (line count) and
        // the live-record map.
        let text = fs::read_to_string(manifest_path(&dir)).unwrap_or_default();
        let lines = text.lines().filter(|l| !l.trim().is_empty()).count() as u64;
        let live = parse_manifest(&dir, &text);
        let file = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(manifest_path(&dir))?;
        Ok(SpillStore {
            dir,
            manifest: Mutex::new(ManifestState { file, lines, live, compactions: 0 }),
            compact,
            degraded: AtomicBool::new(false),
        })
    }

    /// Whether the store has tripped into resident-only mode (a write
    /// failure disables further spilling; fault-ins keep working).
    pub fn degraded(&self) -> bool {
        self.degraded.load(Ordering::Relaxed)
    }

    /// Record a write-path failure and demote the store to resident-only
    /// mode; returns the error for propagation.
    fn demote(&self, e: std::io::Error) -> std::io::Error {
        self.degraded.store(true, Ordering::Relaxed);
        e
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Manifest lines currently in the file (tests / diagnostics).
    pub fn manifest_lines(&self) -> u64 {
        self.manifest.lock().unwrap().lines
    }

    /// Lifetime compaction passes (tests / diagnostics).
    pub fn compaction_count(&self) -> u64 {
        self.manifest.lock().unwrap().compactions
    }

    /// Write `snap`'s payload for `id` and record it in the manifest.
    /// `restore_cost` is taken from the caller (the TCG ref's value), not
    /// the payload, so fault penalties never compound across re-spills.
    pub fn write(
        &self,
        task: &str,
        id: u64,
        snap: &SandboxSnapshot,
        restore_cost: f64,
    ) -> std::io::Result<SpillSlot> {
        self.write_inner(task, id, None, &snap.bytes, snap.serialize_cost, restore_cost)
    }

    /// As [`SpillStore::write`], but content-addressed: the payload file is
    /// named by `key`, and when a complete file for that key is already on
    /// disk the byte write is skipped — only the (cheap) manifest record
    /// for `id` is appended. This is what makes spilling K handles of the
    /// same sandbox state cost one disk payload, not K.
    pub fn write_keyed(
        &self,
        task: &str,
        id: u64,
        key: ContentKey,
        bytes: &[u8],
        serialize_cost: f64,
        restore_cost: f64,
    ) -> std::io::Result<SpillSlot> {
        self.write_inner(task, id, Some(key), bytes, serialize_cost, restore_cost)
    }

    fn write_inner(
        &self,
        task: &str,
        id: u64,
        key: Option<ContentKey>,
        bytes: &[u8],
        serialize_cost: f64,
        restore_cost: f64,
    ) -> std::io::Result<SpillSlot> {
        if self.degraded() {
            return Err(std::io::Error::other("spill tier degraded (resident-only mode)"));
        }
        if let Some(e) = fault::spill_write_error() {
            return Err(self.demote(e));
        }
        let path = match &key {
            Some(k) => payload_path_keyed(&self.dir, k),
            None => payload_path(&self.dir, id),
        };
        // Content-keyed files are immutable by construction (same name ⇒
        // same bytes), so a complete file means the write already happened.
        let already = key.is_some()
            && fs::metadata(&path).map(|m| m.len() == bytes.len() as u64).unwrap_or(false);
        if !already {
            let tmp = self.dir.join(format!("snap-{id}.tmp"));
            if let Err(e) = fs::write(&tmp, bytes).and_then(|()| durable_rename(&tmp, &path)) {
                // A short write or torn rename leaves at most a stray tmp
                // (swept on the next warm start); nothing references it.
                let _ = fs::remove_file(&tmp);
                return Err(self.demote(e));
            }
        }
        self.append_spill(ManifestRecord {
            task: task.to_string(),
            id,
            key,
            bytes: bytes.len() as u64,
            serialize_cost,
            restore_cost,
        })
        .map_err(|e| self.demote(e))?;
        Ok(SpillSlot { path, key, bytes: bytes.len() as u64, serialize_cost, restore_cost })
    }

    /// Append a manifest record for a payload whose file is already in
    /// place at `slot.path` (persisting an already-spilled snapshot: no
    /// byte rewrite needed).
    pub fn record(
        &self,
        task: &str,
        id: u64,
        slot: &SpillSlot,
        restore_cost: f64,
    ) -> std::io::Result<()> {
        self.append_spill(ManifestRecord {
            task: task.to_string(),
            id,
            key: slot.key,
            bytes: slot.bytes,
            serialize_cost: slot.serialize_cost,
            restore_cost,
        })
    }

    fn drop_line(id: u64) -> String {
        Json::obj(vec![("op", Json::str("drop")), ("id", Json::num(id as f64))]).to_string()
    }

    /// Retract `id`'s manifest record *without* touching its payload file —
    /// for a handle of a still-shared payload: other records keep the
    /// bytes reachable. A no-op when `id` has no live record.
    pub fn drop_record(&self, id: u64) {
        let mut st = self.manifest.lock().unwrap();
        if !st.live.contains_key(&id) {
            return;
        }
        if Self::append_line(&mut st, &Self::drop_line(id)).is_ok() {
            st.live.remove(&id);
            self.maybe_compact(&mut st);
        }
    }

    /// Retract `id`'s record (if any) and delete the payload file at
    /// `path` — unless another live record still references that file.
    /// The per-`id` [`SpillStore::drop_payload`] cannot cover a handle that
    /// was never recorded (a dedup no-op spill): the caller knows the real
    /// file from its slot, so it names the path explicitly.
    pub fn drop_payload_at(&self, id: u64, path: &Path) {
        let victim = {
            let mut st = self.manifest.lock().unwrap();
            if st.live.contains_key(&id)
                && Self::append_line(&mut st, &Self::drop_line(id)).is_ok()
            {
                st.live.remove(&id);
                self.maybe_compact(&mut st);
            }
            !st.live.values().any(|r| r.payload_path(&self.dir) == *path)
        };
        if victim {
            let _ = fs::remove_file(path);
        }
    }

    /// Record that `id`'s payload is gone and best-effort delete the file —
    /// unless another live record still shares the same payload file (a
    /// deduped spill), in which case only the record is retracted.
    pub fn drop_payload(&self, id: u64) {
        let mut victim: Option<PathBuf> = None;
        {
            let mut st = self.manifest.lock().unwrap();
            let path = st
                .live
                .get(&id)
                .map(|r| r.payload_path(&self.dir))
                .unwrap_or_else(|| payload_path(&self.dir, id));
            let shared = st
                .live
                .iter()
                .any(|(rid, r)| *rid != id && r.payload_path(&self.dir) == path);
            if Self::append_line(&mut st, &Self::drop_line(id)).is_ok() {
                st.live.remove(&id);
                self.maybe_compact(&mut st);
            }
            if !shared {
                victim = Some(path);
            }
        }
        if let Some(path) = victim {
            let _ = fs::remove_file(path);
        }
    }

    fn append_spill(&self, rec: ManifestRecord) -> std::io::Result<()> {
        let line = rec.to_line();
        let mut st = self.manifest.lock().unwrap();
        Self::append_line(&mut st, &line)?;
        st.live.insert(rec.id, rec);
        self.maybe_compact(&mut st);
        Ok(())
    }

    fn append_line(st: &mut ManifestState, line: &str) -> std::io::Result<()> {
        st.file.write_all(line.as_bytes())?;
        st.file.write_all(b"\n")?;
        st.file.flush()?;
        st.lines += 1;
        Ok(())
    }

    /// Rewrite the manifest to just the live records once dead lines
    /// (drops + superseded spills) exceed 50% of a non-trivial file.
    /// Crash-safe: the replacement is fully written and flushed to a temp
    /// file, then atomically renamed over the manifest — a crash before
    /// the rename leaves the old (correct, just bloated) manifest; a crash
    /// after leaves the new one. Failures are swallowed: compaction is an
    /// optimization, the append-only log stays authoritative.
    fn maybe_compact(&self, st: &mut ManifestState) {
        if !self.compact
            || st.lines < COMPACT_MIN_LINES
            || st.lines <= 2 * st.live.len() as u64
        {
            return;
        }
        let mut ids: Vec<u64> = st.live.keys().copied().collect();
        ids.sort_unstable();
        let mut out = String::with_capacity(ids.len() * 96);
        for id in &ids {
            out.push_str(&st.live[id].to_line());
            out.push('\n');
        }
        let tmp = self.dir.join("manifest.jsonl.tmp");
        let rewrite = || -> std::io::Result<fs::File> {
            fs::write(&tmp, &out)?;
            durable_rename(&tmp, &manifest_path(&self.dir))?;
            // The old append handle points at the unlinked inode: reopen.
            fs::OpenOptions::new().append(true).open(manifest_path(&self.dir))
        };
        match rewrite() {
            Ok(file) => {
                st.file = file;
                st.lines = ids.len() as u64;
                st.compactions += 1;
            }
            Err(_) => {
                let _ = fs::remove_file(&tmp);
            }
        }
    }
}

/// Replay `<dir>/manifest.jsonl` into the set of currently valid records.
///
/// Later records for an id supersede earlier ones; `drop` records retract.
/// Torn/corrupt lines (a crash mid-append) and records whose payload file
/// is missing or has the wrong length are skipped, so the result is always
/// self-consistent. An absent manifest is an empty store, not an error.
pub fn load_manifest(dir: &Path) -> HashMap<u64, ManifestRecord> {
    let Ok(text) = fs::read_to_string(manifest_path(dir)) else {
        return HashMap::new();
    };
    parse_manifest(dir, &text)
}

/// Replay already-read manifest text (shared by [`load_manifest`] and the
/// single-read open path).
fn parse_manifest(dir: &Path, text: &str) -> HashMap<u64, ManifestRecord> {
    let mut records: HashMap<u64, ManifestRecord> = HashMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let Ok(v) = json::parse(line) else {
            continue; // torn or corrupt line: skip
        };
        match v.get("op").and_then(Json::as_str) {
            Some("spill") => {
                let (Some(id), Some(bytes), Some(ser), Some(rest)) = (
                    v.get("id").and_then(Json::as_u64),
                    v.get("bytes").and_then(Json::as_u64),
                    v.get("serialize_cost").and_then(Json::as_f64),
                    v.get("restore_cost").and_then(Json::as_f64),
                ) else {
                    continue;
                };
                let task = v
                    .get("task")
                    .and_then(Json::as_str)
                    .unwrap_or("")
                    .to_string();
                // Legacy lines have no key column; keyed lines with a
                // malformed key are treated as corrupt and skipped.
                let key = match v.get("key") {
                    None => None,
                    Some(k) => match k.as_str().and_then(ContentKey::from_hex) {
                        Some(parsed) => Some(parsed),
                        None => continue,
                    },
                };
                records.insert(
                    id,
                    ManifestRecord {
                        task,
                        id,
                        key,
                        bytes,
                        serialize_cost: ser,
                        restore_cost: rest,
                    },
                );
            }
            Some("drop") => {
                if let Some(id) = v.get("id").and_then(Json::as_u64) {
                    records.remove(&id);
                }
            }
            _ => {}
        }
    }
    // Re-verify against the payload files: a record is only as good as the
    // bytes behind it.
    records.retain(|_, r| {
        fs::metadata(r.payload_path(dir))
            .map(|m| m.len() == r.bytes)
            .unwrap_or(false)
    });
    records
}

/// Delete stray spill-dir files left by a crash: `manifest.jsonl.tmp`
/// (compaction died pre-rename), `snap-*.tmp` (payload write died
/// pre-rename), and `snap-*.bin` payloads no live record references
/// (their manifest line was torn or never written — nothing can resurrect
/// them). `records` must be the dir's replayed manifest ([`load_manifest`]).
/// Returns how many files were removed. Callers must ensure no other
/// writer is actively spilling into `dir`.
pub fn sweep_orphans(dir: &Path, records: &HashMap<u64, ManifestRecord>) -> usize {
    let keep: std::collections::HashSet<PathBuf> =
        records.values().map(|r| r.payload_path(dir)).collect();
    let Ok(rd) = fs::read_dir(dir) else { return 0 };
    let mut swept = 0;
    for entry in rd.flatten() {
        let path = entry.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
        let stray_payload = name.starts_with("snap-")
            && (name.ends_with(".bin") || name.ends_with(".tmp"))
            && !keep.contains(&path);
        if (stray_payload || name == "manifest.jsonl.tmp") && fs::remove_file(&path).is_ok() {
            swept += 1;
        }
    }
    swept
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("tvcache-spill-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        d
    }

    fn snap(fill: u8, n: usize) -> SandboxSnapshot {
        SandboxSnapshot {
            bytes: vec![fill; n],
            serialize_cost: 0.3,
            restore_cost: 0.7,
        }
    }

    #[test]
    fn spill_and_fault_roundtrip() {
        let dir = tmpdir("roundtrip");
        let store = SpillStore::open(&dir).unwrap();
        let slot = store.write("t", 5, &snap(9, 64), 0.7).unwrap();
        assert_eq!(slot.bytes, 64);
        let back = slot.fault().unwrap();
        assert_eq!(back.bytes, vec![9u8; 64]);
        assert!((back.restore_cost - 0.7).abs() < 1e-12);

        let records = load_manifest(&dir);
        assert_eq!(records.len(), 1);
        assert_eq!(records[&5].bytes, 64);
        assert_eq!(records[&5].task, "t");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn drop_retracts_record_and_file() {
        let dir = tmpdir("drop");
        let store = SpillStore::open(&dir).unwrap();
        store.write("t", 1, &snap(1, 8), 0.5).unwrap();
        store.write("t", 2, &snap(2, 8), 0.5).unwrap();
        store.drop_payload(1);
        let records = load_manifest(&dir);
        assert!(!records.contains_key(&1));
        assert!(records.contains_key(&2));
        assert!(!payload_path(&dir, 1).exists());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn truncated_manifest_recovers_to_valid_prefix() {
        let dir = tmpdir("trunc");
        let store = SpillStore::open(&dir).unwrap();
        for id in 1..=4u64 {
            store.write("t", id, &snap(id as u8, 32), 0.5).unwrap();
        }
        drop(store);
        let full = fs::read(manifest_path(&dir)).unwrap();
        // Truncate at every offset: recovery must never panic, and every
        // surviving record must be backed by an intact payload file.
        for cut in 0..=full.len() {
            fs::write(manifest_path(&dir), &full[..cut]).unwrap();
            let records = load_manifest(&dir);
            for (id, r) in &records {
                let slot = r.slot(&dir);
                assert!(slot.fault().is_some(), "cut {cut}: dangling record {id}");
            }
            assert!(records.len() <= 4);
        }
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn record_with_missing_payload_is_discarded() {
        let dir = tmpdir("missing");
        let store = SpillStore::open(&dir).unwrap();
        store.write("t", 7, &snap(7, 16), 0.5).unwrap();
        fs::remove_file(payload_path(&dir, 7)).unwrap();
        assert!(load_manifest(&dir).is_empty());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_dir_is_empty_not_error() {
        let dir = tmpdir("absent");
        assert!(load_manifest(&dir).is_empty());
    }

    // ---- manifest compaction ----

    #[test]
    fn compaction_rewrites_mostly_dead_manifest_without_losing_records() {
        let dir = tmpdir("compact");
        let store = SpillStore::open(&dir).unwrap();
        // 60 spills, 50 of them dropped: 110 lines, 10 live (> 50% dead),
        // crossing COMPACT_MIN_LINES on the way.
        for id in 1..=60u64 {
            store.write("t", id, &snap(id as u8, 8 + id as usize), 0.5).unwrap();
        }
        for id in 1..=50u64 {
            store.drop_payload(id);
        }
        assert!(store.compaction_count() >= 1, "compaction must have triggered");
        assert!(
            store.manifest_lines() <= 20,
            "compacted manifest still bloated: {} lines",
            store.manifest_lines()
        );
        // The compacted manifest is semantically identical: exactly the 10
        // survivors, each backed by its payload.
        let records = load_manifest(&dir);
        assert_eq!(records.len(), 10);
        for id in 51..=60u64 {
            let r = &records[&id];
            assert_eq!(r.bytes, 8 + id);
            assert_eq!(r.task, "t");
            assert_eq!(r.slot(&dir).fault().unwrap().bytes.len() as u64, 8 + id);
        }
        // And the store keeps appending correctly after the rewrite (the
        // handle was re-opened on the new inode).
        store.write("t", 99, &snap(9, 32), 0.5).unwrap();
        assert_eq!(load_manifest(&dir).len(), 11);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn small_or_mostly_live_manifests_are_never_compacted() {
        let dir = tmpdir("compact-skip");
        let store = SpillStore::open(&dir).unwrap();
        for id in 1..=10u64 {
            store.write("t", id, &snap(1, 8), 0.5).unwrap();
        }
        store.drop_payload(1); // 11 lines, far below COMPACT_MIN_LINES
        assert_eq!(store.compaction_count(), 0);
        // Mostly-live large manifest: 100 lines, 90 live — no compaction.
        for id in 11..=100u64 {
            store.write("t", id, &snap(1, 8), 0.5).unwrap();
        }
        assert_eq!(store.compaction_count(), 0, "live manifests must not be rewritten");
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn crashed_compaction_tmp_is_ignored_and_cleaned() {
        let dir = tmpdir("compact-crash");
        let store = SpillStore::open(&dir).unwrap();
        for id in 1..=4u64 {
            store.write("t", id, &snap(id as u8, 16), 0.5).unwrap();
        }
        drop(store);
        // Simulate a compaction that died before its atomic rename: a stray
        // tmp full of garbage next to an intact manifest.
        fs::write(dir.join("manifest.jsonl.tmp"), b"{\"op\":\"drop\",\"id\":1}\ngarbage").unwrap();
        // Recovery ignores the tmp entirely…
        assert_eq!(load_manifest(&dir).len(), 4);
        // …and reopening the store clears it.
        let store = SpillStore::open(&dir).unwrap();
        assert!(!dir.join("manifest.jsonl.tmp").exists());
        assert_eq!(store.manifest_lines(), 4);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn truncated_manifest_after_compaction_still_recovers() {
        let dir = tmpdir("compact-trunc");
        let store = SpillStore::open(&dir).unwrap();
        for id in 1..=70u64 {
            store.write("t", id, &snap(id as u8, 16), 0.5).unwrap();
        }
        for id in 1..=60u64 {
            store.drop_payload(id);
        }
        assert!(store.compaction_count() >= 1);
        drop(store);
        // The crash-safety property must hold for the *rewritten* file too:
        // truncate at every offset; every surviving record stays backed.
        let full = fs::read(manifest_path(&dir)).unwrap();
        for cut in 0..=full.len() {
            fs::write(manifest_path(&dir), &full[..cut]).unwrap();
            let records = load_manifest(&dir);
            for (id, r) in &records {
                assert!(r.slot(&dir).fault().is_some(), "cut {cut}: dangling record {id}");
            }
            assert!(records.len() <= 10);
        }
        fs::remove_dir_all(&dir).unwrap();
    }

    // ---- resident-only degradation ----

    #[test]
    fn injected_write_fault_trips_resident_only_mode() {
        let dir = tmpdir("degrade");
        let store = SpillStore::open(&dir).unwrap();
        store.write("t", 1, &snap(1, 8), 0.5).unwrap();
        assert!(!store.degraded());
        {
            let plan = fault::FaultPlan {
                p_spill_write_fail: 1.0,
                ..fault::FaultPlan::quiet_local(7)
            };
            let _scope = fault::install(plan);
            assert!(store.write("t", 2, &snap(2, 8), 0.5).is_err());
        }
        assert!(store.degraded(), "a write fault must demote to resident-only");
        // Degraded: further writes refuse without touching the disk (no
        // injector armed any more — the mode itself rejects them)…
        assert!(store.write("t", 3, &snap(3, 8), 0.5).is_err());
        // …but fault-ins of what already spilled keep working.
        let records = load_manifest(&dir);
        assert_eq!(records.len(), 1);
        assert!(records[&1].slot(&dir).fault().is_some());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn injected_read_fault_degrades_fault_in_to_none() {
        let dir = tmpdir("read-fault");
        let store = SpillStore::open(&dir).unwrap();
        let slot = store.write("t", 1, &snap(4, 16), 0.5).unwrap();
        {
            let plan = fault::FaultPlan {
                p_spill_read_fail: 1.0,
                ..fault::FaultPlan::quiet_local(7)
            };
            let _scope = fault::install(plan);
            assert!(slot.fault().is_none(), "read fault must degrade to replay");
        }
        assert!(slot.fault().is_some(), "disarmed: the payload is intact");
        assert!(!store.degraded(), "read faults must not disable spilling");
        fs::remove_dir_all(&dir).unwrap();
    }

    // ---- content-keyed records, dedup, orphan sweep ----

    #[test]
    fn keyed_writes_share_one_payload_file_until_last_record_drops() {
        let dir = tmpdir("keyed");
        let store = SpillStore::open(&dir).unwrap();
        let payload = vec![6u8; 48];
        let key = ContentKey::of(&payload);
        let slot1 = store.write_keyed("a", 1, key, &payload, 0.3, 0.7).unwrap();
        let slot2 = store.write_keyed("b", 2, key, &payload, 0.3, 0.7).unwrap();
        assert_eq!(slot1.path, slot2.path, "same content, same file");
        assert_eq!(slot1.key, Some(key));

        // Two live records, one payload file on disk.
        let records = load_manifest(&dir);
        assert_eq!(records.len(), 2);
        assert_eq!(records[&1].key, Some(key));
        let payload_files = fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .filter(|e| e.file_name().to_string_lossy().ends_with(".bin"))
            .count();
        assert_eq!(payload_files, 1, "dedup must collapse the byte write");

        // Dropping one record keeps the shared file; dropping the last
        // deletes it.
        store.drop_payload(1);
        assert!(slot1.path.exists(), "shared payload must survive");
        assert!(slot2.fault().is_some());
        store.drop_payload(2);
        assert!(!slot1.path.exists(), "last drop retracts the file");
        assert!(load_manifest(&dir).is_empty());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn drop_record_retracts_without_touching_the_file() {
        let dir = tmpdir("drop-record");
        let store = SpillStore::open(&dir).unwrap();
        let payload = vec![3u8; 24];
        let key = ContentKey::of(&payload);
        let slot = store.write_keyed("t", 1, key, &payload, 0.1, 0.2).unwrap();
        store.drop_record(1);
        assert!(load_manifest(&dir).is_empty(), "record retracted");
        assert!(slot.path.exists(), "payload file untouched");
        store.drop_record(99); // unknown id: no-op, no stray drop line
        assert_eq!(store.manifest_lines(), 2);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn legacy_keyless_lines_reload_alongside_keyed_ones() {
        let dir = tmpdir("legacy-mixed");
        let store = SpillStore::open(&dir).unwrap();
        store.write("t", 1, &snap(1, 16), 0.5).unwrap(); // legacy
        let payload = vec![2u8; 32];
        store
            .write_keyed("t", 2, ContentKey::of(&payload), &payload, 0.3, 0.6)
            .unwrap();
        drop(store);

        let records = load_manifest(&dir);
        assert_eq!(records.len(), 2);
        assert_eq!(records[&1].key, None);
        assert_eq!(records[&1].slot(&dir).path, payload_path(&dir, 1));
        assert!(records[&1].slot(&dir).fault().is_some());
        assert_eq!(records[&2].key, Some(ContentKey::of(&payload)));
        assert_eq!(records[&2].slot(&dir).fault().unwrap().bytes, payload);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn sweep_orphans_removes_strays_and_keeps_live_payloads() {
        let dir = tmpdir("sweep");
        let store = SpillStore::open(&dir).unwrap();
        let payload = vec![5u8; 40];
        let keyed = store
            .write_keyed("t", 1, ContentKey::of(&payload), &payload, 0.3, 0.6)
            .unwrap();
        let legacy = store.write("t", 2, &snap(2, 16), 0.5).unwrap();
        drop(store);

        // A crash mid-compaction / mid-spill leaves: a manifest tmp, a
        // payload tmp, and payload files whose manifest line never landed.
        fs::write(dir.join("manifest.jsonl.tmp"), b"garbage").unwrap();
        fs::write(dir.join("snap-9.tmp"), b"torn write").unwrap();
        fs::write(dir.join("snap-777.bin"), b"unreferenced").unwrap();
        fs::write(
            payload_path_keyed(&dir, &ContentKey::of(b"never recorded")),
            b"unreferenced keyed",
        )
        .unwrap();

        let records = load_manifest(&dir);
        assert_eq!(records.len(), 2);
        let swept = sweep_orphans(&dir, &records);
        assert_eq!(swept, 4, "exactly the four stray files go");
        assert!(!dir.join("manifest.jsonl.tmp").exists());
        assert!(!dir.join("snap-9.tmp").exists());
        assert!(!dir.join("snap-777.bin").exists());
        assert!(keyed.fault().is_some(), "live keyed payload survives the sweep");
        assert!(legacy.fault().is_some(), "live legacy payload survives the sweep");
        assert!(manifest_path(&dir).exists());
        fs::remove_dir_all(&dir).unwrap();
    }
}
