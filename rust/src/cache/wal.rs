//! Durable segmented write-ahead log beneath the replication op-log.
//!
//! PR 8's [`super::oplog::OpLog`] is a bounded in-memory window: a primary
//! crash loses every mutation since the last manual `/persist`, and a
//! follower that falls behind the window can never catch up. This module
//! makes the log durable. Every [`Op`] pushed through a
//! [`super::oplog::LogGuard`] is also encoded — with the same binary wire
//! codec the `/replicate` endpoint speaks — into CRC32-framed records in
//! append-only segment files:
//!
//! ```text
//! <wal-dir>/wal-00000000000000000000.seg      records for seqs [0, r0)
//! <wal-dir>/wal-000000000000000000r0.seg      records for seqs [r0, r1)
//! <wal-dir>/checkpoint/                       seq-stamped persist_to_dir
//!
//! record   = len(u32 LE) ++ crc32(u32 LE, over payload) ++ payload
//! payload  = wire::put_op(op)          (sequence is implicit: the file
//!                                       name carries the segment's first
//!                                       seq, records are dense)
//! ```
//!
//! Appends go to the page cache only; a background flusher thread group-
//! fsyncs every [`WalOptions::fsync_every`] records or
//! [`WalOptions::fsync_interval`], whichever comes first — the hot path
//! never pays an inline fsync. Segments rotate at
//! [`WalOptions::segment_bytes`]; [`Wal::retain_below`] deletes sealed
//! segments wholly below `min(follower acks, last checkpoint seq)`.
//!
//! Recovery ([`Wal::open`]) scans the segments in sequence order and
//! replays every record whose CRC verifies. The first short or
//! CRC-mismatched record is a *torn tail* — the crash happened mid-write —
//! and is physically truncated (plus any later segments deleted), never
//! replayed as garbage. The recovered state is therefore bit-identical to
//! a never-crashed run up to the last record that reached the disk.
//!
//! Any write failure (real, or injected through the
//! [`crate::util::fault::Seam::WalWrite`] seam) trips the log into a
//! sticky *degraded* mode: appends stop, the service keeps serving
//! (availability over durability, like the spill tier's resident-only
//! mode), and the already-written prefix stays recoverable.

use std::fs::{self, File, OpenOptions};
use std::io::{self, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::Duration;

use super::oplog::Op;
use crate::util::fault;
use crate::wire;

/// Default segment rotation size (4 MiB).
pub const DEFAULT_SEGMENT_BYTES: u64 = 4 << 20;
/// Default group-fsync record threshold.
pub const DEFAULT_FSYNC_EVERY: u64 = 64;
/// Default group-fsync time threshold.
pub const DEFAULT_FSYNC_INTERVAL: Duration = Duration::from_millis(20);

/// Bytes of framing per record (length + CRC32).
const RECORD_HEADER: usize = 8;

// ---- CRC32 (IEEE 802.3, reflected) -------------------------------------

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

/// CRC32 over a WAL record payload. One flipped bit anywhere in the
/// payload fails verification, which is what turns a torn or garbled tail
/// into a truncation instead of a replayed garbage op.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = !0u32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

/// Tuning knobs, all CLI-exposed except the flush interval.
#[derive(Debug, Clone)]
pub struct WalOptions {
    /// Rotate to a fresh `wal-<seq>.seg` once the live segment exceeds
    /// this many bytes.
    pub segment_bytes: u64,
    /// Group-fsync after this many un-synced records.
    pub fsync_every: u64,
    /// …or after this long with any un-synced record, whichever first.
    pub fsync_interval: Duration,
}

impl Default for WalOptions {
    fn default() -> WalOptions {
        WalOptions {
            segment_bytes: DEFAULT_SEGMENT_BYTES,
            fsync_every: DEFAULT_FSYNC_EVERY,
            fsync_interval: DEFAULT_FSYNC_INTERVAL,
        }
    }
}

/// What [`Wal::open`] found on disk: the contiguous run of CRC-verified
/// ops starting at `start_seq` (`ops[i]` has sequence `start_seq + i`).
/// The caller replays the suffix at or above its checkpoint seq.
pub struct Recovered {
    pub start_seq: u64,
    pub ops: Vec<Op>,
}

impl Recovered {
    /// Sequence number the next appended op receives.
    pub fn next_seq(&self) -> u64 {
        self.start_seq + self.ops.len() as u64
    }
}

struct WalInner {
    dir: PathBuf,
    file: File,
    /// Every live segment in seq order; the last entry is the one
    /// `file` appends to.
    segments: Vec<(u64, PathBuf)>,
    /// First sequence of the live segment.
    seg_start: u64,
    /// Records appended to the live segment so far.
    seg_records: u64,
    /// Bytes appended to the live segment so far.
    seg_len: u64,
    /// Sequence the next appended record receives.
    next_seq: u64,
    /// Records appended since the last fsync.
    unsynced: u64,
    segment_bytes: u64,
    fsync_every: u64,
}

struct WalShared {
    inner: Mutex<WalInner>,
    kick: Condvar,
    stop: AtomicBool,
    degraded: AtomicBool,
    fsyncs: AtomicU64,
    appended_bytes: AtomicU64,
    appended_records: AtomicU64,
}

/// The durable log handle. Owned by the [`super::oplog::OpLog`] (appends
/// happen inside `LogGuard::push`, under the log mutex, so the on-disk
/// order is the apply order).
pub struct Wal {
    shared: Arc<WalShared>,
    flusher: Mutex<Option<thread::JoinHandle<()>>>,
}

fn segment_path(dir: &Path, seq: u64) -> PathBuf {
    dir.join(format!("wal-{seq:020}.seg"))
}

fn parse_segment_seq(name: &str) -> Option<u64> {
    let digits = name.strip_prefix("wal-")?.strip_suffix(".seg")?;
    if digits.is_empty() || !digits.bytes().all(|b| b.is_ascii_digit()) {
        return None;
    }
    digits.parse().ok()
}

/// Scan one segment's bytes, returning the decoded ops and the byte
/// offset of the valid prefix. A short header, short payload, CRC
/// mismatch, or undecodable op ends the scan — everything from that
/// offset on is the torn tail.
fn scan_segment(bytes: &[u8]) -> (Vec<Op>, usize) {
    let mut ops = Vec::new();
    let mut at = 0usize;
    while bytes.len() - at >= RECORD_HEADER {
        let len = u32::from_le_bytes(bytes[at..at + 4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(bytes[at + 4..at + 8].try_into().unwrap());
        let Some(end) = at.checked_add(RECORD_HEADER + len) else { break };
        if end > bytes.len() {
            break; // payload torn mid-write
        }
        let payload = &bytes[at + RECORD_HEADER..end];
        if crc32(payload) != crc {
            break; // garbled record
        }
        let mut r = wire::Reader::raw(payload);
        let Some(op) = wire::read_op(&mut r) else { break };
        if !r.done() {
            break; // trailing bytes inside a verified frame: malformed
        }
        ops.push(op);
        at = end;
    }
    (ops, at)
}

impl Wal {
    /// Open (creating if needed) the WAL at `dir`: recover the verified
    /// prefix, truncate any torn tail, and return a handle appending at
    /// the recovered `next_seq`.
    pub fn open(dir: &Path, opts: WalOptions) -> io::Result<(Wal, Recovered)> {
        fs::create_dir_all(dir)?;
        let mut segments: Vec<(u64, PathBuf)> = Vec::new();
        for entry in fs::read_dir(dir)? {
            let entry = entry?;
            let name = entry.file_name();
            if let Some(seq) = name.to_str().and_then(parse_segment_seq) {
                segments.push((seq, entry.path()));
            }
        }
        segments.sort();

        let mut recovered = Recovered { start_seq: 0, ops: Vec::new() };
        let mut live: Vec<(u64, PathBuf)> = Vec::new();
        let mut torn_from: Option<usize> = None;
        for (i, (seg_seq, path)) in segments.iter().enumerate() {
            if i == 0 {
                recovered.start_seq = *seg_seq;
            } else if *seg_seq != recovered.next_seq() {
                // Non-contiguous successor: everything from here on is
                // unreachable garbage (a half-deleted retention pass or a
                // crash mid-rotation). Drop it.
                torn_from = Some(i);
                break;
            }
            let mut bytes = Vec::new();
            File::open(path)?.read_to_end(&mut bytes)?;
            let (ops, valid_end) = scan_segment(&bytes);
            recovered.ops.extend(ops);
            live.push((*seg_seq, path.clone()));
            if valid_end < bytes.len() {
                // Torn tail: physically truncate so the garbage is never
                // rescanned, and drop every later segment (their seqs no
                // longer connect).
                let f = OpenOptions::new().write(true).open(path)?;
                f.set_len(valid_end as u64)?;
                f.sync_all()?;
                torn_from = Some(i + 1);
                break;
            }
        }
        if let Some(from) = torn_from {
            for (_, path) in &segments[from..] {
                let _ = fs::remove_file(path);
            }
        }

        let next_seq = recovered.next_seq();
        // Continue the last live segment when it has room; otherwise start
        // a fresh one at next_seq.
        let (seg_start, path, reuse) = match live.last() {
            Some((seg_seq, path)) => {
                let len = fs::metadata(path).map(|m| m.len()).unwrap_or(0);
                if len < opts.segment_bytes {
                    (*seg_seq, path.clone(), true)
                } else {
                    (next_seq, segment_path(dir, next_seq), false)
                }
            }
            None => (next_seq, segment_path(dir, next_seq), false),
        };
        let file = OpenOptions::new().create(true).append(true).open(&path)?;
        let seg_len = file.metadata()?.len();
        if !reuse {
            live.push((seg_start, path));
        }
        let seg_records = next_seq - seg_start;

        let shared = Arc::new(WalShared {
            inner: Mutex::new(WalInner {
                dir: dir.to_path_buf(),
                file,
                segments: live,
                seg_start,
                seg_records,
                seg_len,
                next_seq,
                unsynced: 0,
                segment_bytes: opts.segment_bytes.max(1),
                fsync_every: opts.fsync_every.max(1),
            }),
            kick: Condvar::new(),
            stop: AtomicBool::new(false),
            degraded: AtomicBool::new(false),
            fsyncs: AtomicU64::new(0),
            appended_bytes: AtomicU64::new(0),
            appended_records: AtomicU64::new(0),
        });
        let flusher = spawn_flusher(Arc::clone(&shared), opts.fsync_interval);
        Ok((Wal { shared, flusher: Mutex::new(Some(flusher)) }, recovered))
    }

    /// Append `op` as the record for `seq`. Never fsyncs inline (the
    /// flusher thread groups that); never fails the caller — a write
    /// error trips sticky degraded mode instead.
    pub fn append(&self, seq: u64, op: &Op) {
        if self.shared.degraded.load(Ordering::Relaxed) {
            return;
        }
        let mut frame = vec![0u8; RECORD_HEADER];
        wire::put_op(&mut frame, op);
        let payload_len = frame.len() - RECORD_HEADER;
        frame[..4].copy_from_slice(&(payload_len as u32).to_le_bytes());
        let crc = crc32(&frame[RECORD_HEADER..]);
        frame[4..8].copy_from_slice(&crc.to_le_bytes());

        // Fault seams: a garbled or torn write lands (corrupting the
        // tail), then the log degrades so the corruption *stays* a tail —
        // exactly the shape recovery knows how to truncate.
        let mut poison = fault::wal_write_error().is_some();
        if fault::wal_garble_write() {
            fault::garble(&mut frame[RECORD_HEADER..]);
            poison = true;
        }
        let torn_at = fault::wal_torn_write().then(|| frame.len() / 2);

        let mut inner = self.shared.inner.lock().unwrap();
        debug_assert_eq!(seq, inner.next_seq, "WAL appends must be dense");
        let write = match torn_at {
            Some(cut) => {
                poison = true;
                inner.file.write_all(&frame[..cut])
            }
            None if poison => Ok(()), // injected write error: nothing lands
            None => inner.file.write_all(&frame),
        };
        if write.is_err() || poison {
            self.shared.degraded.store(true, Ordering::Relaxed);
            return;
        }
        inner.next_seq = seq + 1;
        inner.seg_records += 1;
        inner.seg_len += frame.len() as u64;
        inner.unsynced += 1;
        self.shared.appended_bytes.fetch_add(frame.len() as u64, Ordering::Relaxed);
        self.shared.appended_records.fetch_add(1, Ordering::Relaxed);
        if inner.seg_len >= inner.segment_bytes {
            self.rotate_locked(&mut inner);
        }
        let kick = inner.unsynced >= inner.fsync_every;
        drop(inner);
        if kick {
            self.shared.kick.notify_one();
        }
    }

    /// Seal the live segment and start a fresh one at `next_seq`. The
    /// sealed file is fsynced here (rotation is rare; this is not the
    /// per-record hot path).
    fn rotate_locked(&self, inner: &mut WalInner) {
        let _ = inner.file.sync_data();
        self.shared.fsyncs.fetch_add(1, Ordering::Relaxed);
        inner.unsynced = 0;
        let path = segment_path(&inner.dir, inner.next_seq);
        match OpenOptions::new().create(true).append(true).open(&path) {
            Ok(f) => {
                inner.file = f;
                inner.seg_start = inner.next_seq;
                inner.seg_records = 0;
                inner.seg_len = 0;
                inner.segments.push((inner.seg_start, path));
            }
            Err(_) => {
                self.shared.degraded.store(true, Ordering::Relaxed);
            }
        }
    }

    /// Force an fsync now (drain, checkpoint, shutdown). Returns the
    /// sequence everything below which is now durable.
    pub fn sync(&self) -> u64 {
        let mut inner = self.shared.inner.lock().unwrap();
        if inner.unsynced > 0 {
            let _ = inner.file.sync_data();
            self.shared.fsyncs.fetch_add(1, Ordering::Relaxed);
            inner.unsynced = 0;
        }
        inner.next_seq
    }

    /// Delete sealed segments that lie wholly below `floor` (= the
    /// retention bound `min(follower acks, last checkpoint seq)`). The
    /// live segment is never deleted.
    pub fn retain_below(&self, floor: u64) {
        let mut inner = self.shared.inner.lock().unwrap();
        while inner.segments.len() >= 2 && inner.segments[1].0 <= floor {
            let (_, path) = inner.segments.remove(0);
            let _ = fs::remove_file(path);
        }
    }

    /// Sequence the next appended record receives.
    pub fn next_seq(&self) -> u64 {
        self.shared.inner.lock().unwrap().next_seq
    }

    /// Live segment files (stats gauge).
    pub fn segment_count(&self) -> u64 {
        self.shared.inner.lock().unwrap().segments.len() as u64
    }

    pub fn fsyncs(&self) -> u64 {
        self.shared.fsyncs.load(Ordering::Relaxed)
    }

    pub fn appended_bytes(&self) -> u64 {
        self.shared.appended_bytes.load(Ordering::Relaxed)
    }

    pub fn appended_records(&self) -> u64 {
        self.shared.appended_records.load(Ordering::Relaxed)
    }

    /// Did a write failure trip the sticky degraded mode?
    pub fn degraded(&self) -> bool {
        self.shared.degraded.load(Ordering::Relaxed)
    }
}

impl Drop for Wal {
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::Release);
        self.shared.kick.notify_all();
        if let Some(h) = self.flusher.lock().unwrap().take() {
            let _ = h.join();
        }
        // Graceful-shutdown durability; a real crash skips this, which is
        // exactly what the torn-tail recovery path covers.
        self.sync();
    }
}

fn spawn_flusher(shared: Arc<WalShared>, interval: Duration) -> thread::JoinHandle<()> {
    thread::Builder::new()
        .name("tvcache-wal-flush".into())
        .spawn(move || loop {
            let file = {
                let inner = shared.inner.lock().unwrap();
                let (mut inner, _) = shared
                    .kick
                    .wait_timeout_while(inner, interval, |i| {
                        i.unsynced < i.fsync_every && !shared.stop.load(Ordering::Acquire)
                    })
                    .unwrap();
                if inner.unsynced == 0 {
                    None
                } else {
                    inner.unsynced = 0;
                    inner.file.try_clone().ok()
                }
            };
            // Sync outside the lock so appends never wait on the disk.
            if let Some(f) = file {
                let _ = f.sync_data();
                shared.fsyncs.fetch_add(1, Ordering::Relaxed);
            }
            if shared.stop.load(Ordering::Acquire) {
                return;
            }
        })
        .expect("spawn wal flusher")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::key::{ToolCall, ToolResult};
    use crate::cache::payload::ContentKey;
    use crate::util::fault::{self, FaultPlan};

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "tvcache-wal-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn ops(n: usize) -> Vec<Op> {
        (0..n)
            .map(|i| match i % 3 {
                0 => Op::Insert {
                    task: format!("t{i}"),
                    traj: vec![(
                        ToolCall::new("bash", &format!("cmd {i}")),
                        ToolResult::new(&format!("out {i}"), 0.5),
                    )],
                },
                1 => Op::Attach {
                    task: format!("t{i}"),
                    node: i,
                    id: i as u64,
                    key: ContentKey([i as u64, 2, 3, 4]),
                    bytes: Some(vec![i as u8; 24].into()),
                    byte_len: 24,
                    serialize_cost: 0.1,
                    restore_cost: 0.2,
                },
                _ => Op::Release { task: format!("t{i}"), node: i },
            })
            .collect()
    }

    fn append_all(wal: &Wal, from: u64, ops: &[Op]) {
        for (i, op) in ops.iter().enumerate() {
            wal.append(from + i as u64, op);
        }
    }

    #[test]
    fn reopen_recovers_every_record_across_rotations() {
        let dir = tmpdir("rotate");
        let want = ops(40);
        {
            let (wal, rec) = Wal::open(&dir, WalOptions {
                segment_bytes: 256, // force several rotations
                ..WalOptions::default()
            })
            .unwrap();
            assert_eq!(rec.next_seq(), 0);
            append_all(&wal, 0, &want);
            assert!(wal.segment_count() > 1, "tiny segments must rotate");
            assert_eq!(wal.appended_records(), 40);
        }
        let (wal, rec) = Wal::open(&dir, WalOptions::default()).unwrap();
        assert_eq!(rec.start_seq, 0);
        assert_eq!(rec.ops, want);
        assert_eq!(wal.next_seq(), 40);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncation_at_every_offset_recovers_a_valid_prefix() {
        let dir = tmpdir("trunc");
        let want = ops(8);
        let (wal, _) = Wal::open(&dir, WalOptions::default()).unwrap();
        append_all(&wal, 0, &want);
        drop(wal);
        let seg = segment_path(&dir, 0);
        let full = fs::read(&seg).unwrap();
        // Record boundaries, for computing the expected surviving prefix.
        let mut ends = Vec::new();
        let mut at = 0usize;
        while at < full.len() {
            let len = u32::from_le_bytes(full[at..at + 4].try_into().unwrap()) as usize;
            at += RECORD_HEADER + len;
            ends.push(at);
        }
        for cut in 0..full.len() {
            let case = tmpdir("trunc-case");
            fs::write(segment_path(&case, 0), &full[..cut]).unwrap();
            let (wal, rec) = Wal::open(&case, WalOptions::default()).unwrap();
            let survive = ends.iter().filter(|&&e| e <= cut).count();
            assert_eq!(rec.ops, want[..survive], "cut at {cut}");
            // The torn tail is physically gone and appends continue clean.
            assert_eq!(fs::metadata(segment_path(&case, 0)).unwrap().len() as usize, {
                if survive == 0 {
                    0
                } else {
                    ends[survive - 1]
                }
            });
            wal.append(rec.next_seq(), &want[survive.min(want.len() - 1)]);
            drop(wal);
            let _ = fs::remove_dir_all(&case);
        }
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn garbled_tail_record_is_dropped_not_replayed() {
        let dir = tmpdir("garble");
        let want = ops(5);
        let (wal, _) = Wal::open(&dir, WalOptions::default()).unwrap();
        append_all(&wal, 0, &want);
        drop(wal);
        let seg = segment_path(&dir, 0);
        let full = fs::read(&seg).unwrap();
        // Flip one byte inside the last record's payload.
        let mut bad = full.clone();
        let last = bad.len() - 3;
        bad[last] ^= 0x41;
        fs::write(&seg, &bad).unwrap();
        let (_, rec) = Wal::open(&dir, WalOptions::default()).unwrap();
        assert_eq!(rec.ops, want[..4], "CRC must reject the garbled record");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn retention_deletes_sealed_segments_below_the_floor() {
        let dir = tmpdir("retain");
        let want = ops(40);
        let (wal, _) =
            Wal::open(&dir, WalOptions { segment_bytes: 256, ..WalOptions::default() }).unwrap();
        append_all(&wal, 0, &want);
        let before = wal.segment_count();
        assert!(before > 2);
        wal.retain_below(0); // nothing below seq 0: no-op
        assert_eq!(wal.segment_count(), before);
        wal.retain_below(u64::MAX);
        assert_eq!(wal.segment_count(), 1, "only the live segment survives");
        // Recovery after retention starts at the surviving segment.
        drop(wal);
        let (_, rec) = Wal::open(&dir, WalOptions::default()).unwrap();
        assert!(rec.start_seq > 0);
        assert_eq!(rec.ops[..], want[rec.start_seq as usize..]);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn injected_write_fault_trips_sticky_degraded_mode() {
        let dir = tmpdir("fault");
        let want = ops(6);
        let (wal, _) = Wal::open(&dir, WalOptions::default()).unwrap();
        append_all(&wal, 0, &want[..3]);
        {
            let _scope = fault::install(FaultPlan {
                p_wal_write_fail: 1.0,
                thread_scoped: true,
                ..FaultPlan::quiet(7)
            });
            wal.append(3, &want[3]);
        }
        assert!(wal.degraded(), "a write fault must trip degraded mode");
        wal.append(4, &want[4]); // silently dropped, no panic
        assert_eq!(wal.appended_records(), 3);
        drop(wal);
        // The durable prefix is intact.
        let (_, rec) = Wal::open(&dir, WalOptions::default()).unwrap();
        assert_eq!(rec.ops, want[..3]);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn injected_torn_and_garbled_writes_recover_to_the_prefix() {
        for (tag, plan) in [
            ("torn", FaultPlan {
                p_wal_torn_tail: 1.0,
                thread_scoped: true,
                ..FaultPlan::quiet(7)
            }),
            ("crc", FaultPlan {
                p_wal_garble: 1.0,
                thread_scoped: true,
                ..FaultPlan::quiet(7)
            }),
        ] {
            let dir = tmpdir(&format!("inj-{tag}"));
            let want = ops(4);
            let (wal, _) = Wal::open(&dir, WalOptions::default()).unwrap();
            append_all(&wal, 0, &want[..2]);
            {
                let _scope = fault::install(plan);
                wal.append(2, &want[2]); // lands corrupted, then degrades
            }
            assert!(wal.degraded());
            drop(wal);
            let (_, rec) = Wal::open(&dir, WalOptions::default()).unwrap();
            assert_eq!(rec.ops, want[..2], "{tag}: corrupted tail must truncate");
            let _ = fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn group_fsync_happens_off_the_append_path() {
        let dir = tmpdir("fsync");
        let (wal, _) = Wal::open(&dir, WalOptions {
            fsync_every: 4,
            fsync_interval: Duration::from_millis(5),
            ..WalOptions::default()
        })
        .unwrap();
        append_all(&wal, 0, &ops(16));
        // The flusher groups the 16 appends into a handful of fsyncs.
        let deadline = std::time::Instant::now() + Duration::from_secs(2);
        while wal.fsyncs() == 0 && std::time::Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(2));
        }
        let n = wal.fsyncs();
        assert!(n >= 1, "flusher must have synced");
        assert!(n <= 16, "appends must not each pay an fsync");
        assert!(wal.sync() == 16);
        let _ = fs::remove_dir_all(&dir);
    }
}
