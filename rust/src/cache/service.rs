//! The in-process sharded cache service (§4.5, Figure 8a).
//!
//! N independent shards, routed by `hash(task_id)`. Each shard owns its own
//! task map **and** its own snapshot store, so there is no global lock
//! anywhere on the lookup *or* the snapshot path: two tasks on different
//! shards never contend, and two tasks on the same shard only share the
//! shard's task-map lock (a read lock in the steady state) and that shard's
//! snapshot-store mutex.
//!
//! Per-shard snapshot stores use a strided id space (shard `i` of `N` hands
//! out ids `i+1, i+1+N, …`), so snapshot ids stay globally unique and
//! `fetch_snapshot` can verify routing.
//!
//! # Snapshot lifecycle (byte budgets, background eviction, spill)
//!
//! [`ServiceConfig`] adds byte-accounted budgets on top of the per-task
//! count budget: a per-shard and a global resident-byte budget. Budgets are
//! enforced *off the hot path* — `store_snapshot` only flags the shard's
//! background worker, which drains the over-budget store by demoting the
//! worst-scoring unpinned snapshots (cost-aware [`EvictionPolicy`] score)
//! either to the disk spill tier (`spill_dir` set — the TCG ref survives
//! and a later resume faults the payload back in) or out of existence.
//! `persist_to_dir`/`warm_start_from_dir` reuse the spill format so a new
//! run reloads the previous run's TCGs + payloads and starts epoch 0 warm.

use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use super::backend::{
    BackendStats, CacheBackend, Capabilities, SessionBackend, TurnBatch, TurnOp, TurnReply,
};
use super::key::{ToolCall, ToolResult};
use super::lpm::{CursorStep, Lookup};
use super::oplog::{LogGuard, Op, OpLog, DEFAULT_OPLOG_WINDOW};
use super::payload::{ContentKey, PayloadStore, DEFAULT_FAULT_CACHE_BYTES};
use super::shard::{CacheFactory, Shard, ShardRouter};
use super::snapshot::{SnapshotCosts, SnapshotStore};
use super::spill::{self, SpillStore};
use super::store::{CacheStats, TaskCache};
use super::tcg::{NodeId, SnapshotRef};
use super::wal::{Wal, WalOptions};
use crate::sandbox::SandboxSnapshot;
use crate::util::fault;
use crate::util::json::{self, Json};

/// Snapshot-lifecycle configuration for a sharded service.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub shards: usize,
    /// Resident-byte budget per shard store (`None` = unbounded).
    pub shard_byte_budget: Option<u64>,
    /// Resident-byte budget across all shards (`None` = unbounded).
    pub global_byte_budget: Option<u64>,
    /// Spill directory: over-budget payloads are demoted to disk here
    /// instead of destroyed. `None` = background eviction destroys.
    pub spill_dir: Option<PathBuf>,
    /// Spawn one background eviction worker per shard. When `false` the
    /// caller drives enforcement with [`ShardedCacheService::drain_over_budget`]
    /// (deterministic; what the property tests use).
    pub background: bool,
    /// Upper bound on live rollout sessions per shard. A session open that
    /// finds the table full first sweeps entries idle longer than
    /// [`ServiceConfig::session_idle_ttl`] (remote rollouts that died
    /// without closing), then refuses (returns 0) if still full — the
    /// client transparently falls back to full-prefix lookups, so this is
    /// a memory bound, not a correctness gate.
    pub max_sessions_per_shard: usize,
    /// A session untouched for this long is presumed abandoned (its
    /// rollout died without closing) and is swept — its table entry is
    /// dropped and every resume pin it still holds is released.
    pub session_idle_ttl: std::time::Duration,
    /// Run the idle-session sweep every K session ops per shard (in
    /// addition to the full-table sweep and the background timer tick), so
    /// abandoned sessions are reclaimed on a steadily busy shard long
    /// before its table ever hits the cap. 0 disables the op-count tick.
    pub session_sweep_every_ops: u64,
    /// Period of the background idle-session sweep timer. On budgeted
    /// `background: true` services this is the idle tick of each shard's
    /// eviction worker; on unbudgeted ones a dedicated sweeper thread
    /// ticks at this period, so idle sessions are reclaimed even with no
    /// eviction workers and no op traffic.
    pub session_sweep_tick: std::time::Duration,
    /// Byte budget of the LRU fault cache layered over spill fault-ins
    /// (shared across shards; a hot spilled payload is read from disk once
    /// and served from memory thereafter). 0 disables the cache. Only
    /// meaningful with a `spill_dir`.
    pub fault_cache_bytes: u64,
    /// Maintain a replication op-log with this bounded window (PR 8): every
    /// state mutation is appended, under the same lock that applied it, for
    /// followers to pull via `/replicate`. `None` (the default) disables
    /// logging entirely — no lock, no clone, no memory cost. The window
    /// bounds primary memory; a follower that falls behind it observes a
    /// gap and freezes (see `read_from`).
    pub replicate_window: Option<usize>,
    /// Durable write-ahead log directory (PR 9): every op-log append is
    /// also CRC32-framed into append-only segment files here, and
    /// `wal_dir/checkpoint` anchors crash recovery — construction
    /// warm-starts the checkpoint and replays the WAL tail, so a restarted
    /// primary is bit-identical to a never-crashed run up to the last
    /// fsynced record. Implies an op-log even when `replicate_window` is
    /// unset (the default window is used).
    pub wal_dir: Option<PathBuf>,
    /// WAL segment rotation threshold in bytes.
    pub wal_segment_bytes: u64,
    /// Group-fsync the WAL once this many records are unsynced (the
    /// flusher also syncs on a timer, so the bound is records *or* time,
    /// whichever comes first — the append hot path never fsyncs inline).
    pub wal_fsync_every: u64,
}

/// Default [`ServiceConfig::session_idle_ttl`].
pub const SESSION_IDLE_TTL: std::time::Duration = std::time::Duration::from_secs(900);

/// Default [`ServiceConfig::session_sweep_tick`]: how often the periodic
/// idle-session sweep wakes (on an eviction worker or the dedicated
/// sweeper thread, whichever the config spawns).
const SESSION_SWEEP_TICK: std::time::Duration = std::time::Duration::from_secs(60);

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            shards: 1,
            shard_byte_budget: None,
            global_byte_budget: None,
            spill_dir: None,
            background: false,
            max_sessions_per_shard: 8192,
            session_idle_ttl: SESSION_IDLE_TTL,
            session_sweep_every_ops: 4096,
            session_sweep_tick: SESSION_SWEEP_TICK,
            fault_cache_bytes: DEFAULT_FAULT_CACHE_BYTES,
            replicate_window: None,
            wal_dir: None,
            wal_segment_bytes: super::wal::DEFAULT_SEGMENT_BYTES,
            wal_fsync_every: super::wal::DEFAULT_FSYNC_EVERY,
        }
    }
}

impl ServiceConfig {
    fn bounded(&self) -> bool {
        self.shard_byte_budget.is_some() || self.global_byte_budget.is_some()
    }
}

/// Wakes a shard's background eviction worker.
struct WorkerSignal {
    state: Mutex<WorkerState>,
    cv: Condvar,
}

#[derive(Default)]
struct WorkerState {
    dirty: bool,
    /// Worker is inside a drain pass (cleared — with a notify — when done).
    busy: bool,
    shutdown: bool,
}

impl WorkerSignal {
    fn new() -> WorkerSignal {
        WorkerSignal { state: Mutex::new(WorkerState::default()), cv: Condvar::new() }
    }

    fn kick(&self) {
        self.state.lock().unwrap().dirty = true;
        self.cv.notify_all();
    }

    fn shutdown(&self) {
        self.state.lock().unwrap().shutdown = true;
        self.cv.notify_all();
    }
}

/// One live rollout session: the rollout's pinned TCG position (§3.2 made
/// stateful) plus every resume-offer pin taken through the session. `gen`
/// is the task TCG's eviction generation at which `node` was last verified
/// live — eviction of the node flips the next step to `CursorStep::Invalid`
/// instead of ever serving a stale position. `pins` unifies the old cursor
/// table with resume-offer ownership: closing (or sweeping) the session
/// releases them, so a rollout that dies mid-run can never leak a pin that
/// would block snapshot eviction forever.
struct SessionEntry {
    cache: Arc<TaskCache>,
    node: NodeId,
    /// Calls consumed so far (= `matched_calls` for the next step's miss).
    steps: usize,
    gen: u64,
    /// Refreshed on every op; drives the abandoned-session sweep.
    last_used: std::time::Instant,
    /// Resume-offer pins taken through `session_turn` and not yet released
    /// via `session_release`. (Per-call `cursor_step` pins stay owned by
    /// the caller, exactly as before — only session-scoped traffic is
    /// tracked here, so a bare-cursor client's own `release` can never
    /// race a second release from session teardown.)
    pins: Vec<NodeId>,
}

impl SessionEntry {
    /// Hand every outstanding pin back (session closed or swept).
    fn release_pins(self) {
        for node in self.pins {
            self.cache.release(node);
        }
    }
}

/// One shard's state: task map + snapshot byte store + session table +
/// worker bookkeeping.
struct ShardSlot {
    tasks: Shard,
    snapshots: SnapshotStore,
    /// Live rollout sessions for this shard's tasks. A plain mutex:
    /// session ops are O(1) probes and each rollout owns exactly one
    /// session, so the hold time is a hash probe plus one TCG child
    /// lookup.
    sessions: Mutex<HashMap<u64, SessionEntry>>,
    /// Session ops since the last op-count sweep tick.
    session_ops: AtomicU64,
    /// Snapshots the background worker destroyed (detached + dropped).
    bg_evicted: AtomicU64,
    signal: WorkerSignal,
}

impl ShardSlot {
    /// Drop every session idle longer than `ttl`, releasing its pins.
    fn sweep_idle_sessions(&self, ttl: std::time::Duration) {
        let swept: Vec<SessionEntry> = {
            let mut sessions = self.sessions.lock().unwrap();
            let dead: Vec<u64> = sessions
                .iter()
                .filter(|(_, e)| e.last_used.elapsed() >= ttl)
                .map(|(&id, _)| id)
                .collect();
            dead.into_iter().filter_map(|id| sessions.remove(&id)).collect()
        };
        // Pin releases take TCG read locks — never under the table mutex.
        for entry in swept {
            entry.release_pins();
        }
    }
}

/// Task-id-sharded cache service; implements [`CacheBackend`] in-process.
pub struct ShardedCacheService {
    router: ShardRouter,
    shards: Vec<Arc<ShardSlot>>,
    cfg: ServiceConfig,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// Dedicated idle-session sweeper, spawned when `background` is set
    /// but no byte budget exists (so no eviction workers run their timer
    /// tick). Keeps the idle sweep independent of eviction.
    sweeper: Option<std::thread::JoinHandle<()>>,
    sweep_signal: Arc<WorkerSignal>,
    /// The live spill store (shared with every shard's snapshot store) —
    /// kept so `persist_to_dir` into the live spill directory reuses the
    /// *same* writer: two stores on one manifest would let the primary's
    /// compaction discard the secondary's appended records.
    spill: Option<Arc<SpillStore>>,
    /// The content-addressed payload tier shared by every shard's snapshot
    /// store: identical sandbox states dedup to one resident (or spilled)
    /// copy, and spill fault-ins go through one LRU fault cache.
    payloads: Arc<PayloadStore>,
    /// Cursor id allocator (0 is the "unsupported/failed" sentinel).
    next_cursor: AtomicU64,
    /// Replication op-log (PR 8), present when
    /// [`ServiceConfig::replicate_window`] is set. Every mutating entry
    /// point appends its op under the log guard taken *before* the
    /// mutation, so log order is apply order and a follower's sequential
    /// replay rebuilds bit-identical TCGs.
    oplog: Option<Arc<OpLog>>,
    /// Last op sequence a checkpoint into `wal_dir/checkpoint` covered —
    /// the checkpoint half of the WAL retention floor.
    checkpoint_seq: AtomicU64,
    /// Crash recoveries performed at construction (0 or 1: a checkpoint
    /// warm-start and/or a WAL replay that restored state).
    recoveries: AtomicU64,
}

impl ShardedCacheService {
    /// `n_shards` shards of default-policy task caches.
    pub fn new(n_shards: usize) -> ShardedCacheService {
        Self::with_factory(n_shards, Arc::new(TaskCache::with_defaults))
    }

    /// `n_shards` shards whose task caches come from `factory` (no byte
    /// budgets, no spill tier, no background workers).
    pub fn with_factory(n_shards: usize, factory: CacheFactory) -> ShardedCacheService {
        Self::with_config(ServiceConfig { shards: n_shards, ..Default::default() }, factory)
            .expect("config without a spill dir cannot fail")
    }

    /// Full snapshot-lifecycle construction. Fails only when the spill
    /// directory cannot be created.
    pub fn with_config(
        cfg: ServiceConfig,
        factory: CacheFactory,
    ) -> std::io::Result<ShardedCacheService> {
        let n = cfg.shards.max(1);
        let spill = match &cfg.spill_dir {
            Some(dir) => Some(Arc::new(SpillStore::open(dir)?)),
            None => None,
        };
        // One payload store for the whole service: dedup and the fault
        // cache work across shards (and across tasks) by construction.
        let payloads =
            Arc::new(PayloadStore::new(spill.clone(), cfg.fault_cache_bytes));
        let shards: Vec<Arc<ShardSlot>> = (0..n)
            .map(|i| {
                let snapshots = SnapshotStore::with_payloads(
                    i as u64 + 1,
                    n as u64,
                    Arc::clone(&payloads),
                );
                Arc::new(ShardSlot {
                    tasks: Shard::from_factory(Arc::clone(&factory)),
                    snapshots,
                    sessions: Mutex::new(HashMap::new()),
                    session_ops: AtomicU64::new(0),
                    bg_evicted: AtomicU64::new(0),
                    signal: WorkerSignal::new(),
                })
            })
            .collect();
        let mut svc = ShardedCacheService {
            router: ShardRouter::new(n),
            shards,
            cfg,
            workers: Vec::new(),
            sweeper: None,
            sweep_signal: Arc::new(WorkerSignal::new()),
            spill,
            payloads,
            next_cursor: AtomicU64::new(1),
            oplog: None,
            checkpoint_seq: AtomicU64::new(0),
            recoveries: AtomicU64::new(0),
        };
        if let Some(wdir) = svc.cfg.wal_dir.clone() {
            let opts = WalOptions {
                segment_bytes: svc.cfg.wal_segment_bytes,
                fsync_every: svc.cfg.wal_fsync_every,
                ..WalOptions::default()
            };
            let (wal, recovered) = Wal::open(&wdir, opts)?;
            // Recovery ladder: warm-start the anchored checkpoint first
            // (its `wal_seq` stamp names the op sequence it covers), then
            // replay every durable WAL record at or past that sequence.
            // Together they rebuild the exact pre-crash state up to the
            // last fsynced record — nothing double-applied, nothing lost.
            let ckpt = wdir.join("checkpoint");
            let mut ckpt_seq = 0u64;
            let mut recovered_any = false;
            if ckpt.join("tcgs.json").is_file() {
                let (loaded, seq) = svc.warm_start_with_seq(&ckpt)?;
                ckpt_seq = seq;
                recovered_any = loaded > 0 || seq > 0;
            }
            for (i, op) in recovered.ops.iter().enumerate() {
                if recovered.start_seq + i as u64 >= ckpt_seq {
                    svc.apply_op(op.clone());
                    recovered_any = true;
                }
            }
            if recovered_any {
                svc.recoveries.store(1, Ordering::Relaxed);
            }
            svc.checkpoint_seq.store(ckpt_seq, Ordering::Relaxed);
            // A WAL implies an op-log even without replication: the log
            // guard is what serializes append order with apply order.
            let window = svc.cfg.replicate_window.unwrap_or(DEFAULT_OPLOG_WINDOW);
            let start = recovered.next_seq().max(ckpt_seq);
            svc.oplog =
                Some(Arc::new(OpLog::with_wal(window, Some(Arc::new(wal)), start)));
        } else {
            svc.oplog = svc.cfg.replicate_window.map(|w| Arc::new(OpLog::new(w)));
        }
        if svc.cfg.background {
            if svc.cfg.bounded() {
                svc.spawn_workers();
            } else {
                // No byte budgets means no eviction workers, but the
                // idle-session sweep must still tick: without it an
                // unbudgeted service reclaims abandoned sessions only on
                // op-count thresholds, so on a quiet shard they linger
                // (and keep their resume pins) forever.
                svc.spawn_sweeper();
            }
        }
        Ok(svc)
    }

    fn spawn_workers(&mut self) {
        for (i, slot) in self.shards.iter().enumerate() {
            let slot = Arc::clone(slot);
            let all: Vec<Arc<ShardSlot>> = self.shards.clone();
            let cfg = self.cfg.clone();
            let spill = self.spill.clone();
            let oplog = self.oplog.clone();
            let handle = std::thread::Builder::new()
                .name(format!("tvcache-evict-{i}"))
                .spawn(move || loop {
                    {
                        let mut st = slot.signal.state.lock().unwrap();
                        while !st.dirty && !st.shutdown {
                            // Timer tick: an idle worker periodically sweeps
                            // its shard's session table, so abandoned
                            // sessions (and their resume pins) are reclaimed
                            // even on a shard that never goes over budget
                            // and never fills its table.
                            let (next, timeout) = slot
                                .signal
                                .cv
                                .wait_timeout(st, cfg.session_sweep_tick)
                                .unwrap();
                            st = next;
                            if timeout.timed_out() && !st.dirty && !st.shutdown {
                                drop(st);
                                if let Some(d) = fault::worker_stall() {
                                    std::thread::sleep(d);
                                }
                                slot.sweep_idle_sessions(cfg.session_idle_ttl);
                                st = slot.signal.state.lock().unwrap();
                            }
                        }
                        if st.shutdown {
                            break;
                        }
                        st.dirty = false;
                        st.busy = true;
                    }
                    if let Some(d) = fault::worker_stall() {
                        std::thread::sleep(d);
                    }
                    drain_slot(&slot, &all, &cfg, spill.as_deref(), oplog.as_deref());
                    let mut st = slot.signal.state.lock().unwrap();
                    st.busy = false;
                    slot.signal.cv.notify_all();
                })
                .expect("spawn eviction worker");
            self.workers.push(handle);
        }
    }

    /// Spawn the dedicated idle-session sweeper: a single timer thread
    /// that walks every shard at `session_sweep_tick`. Only used when no
    /// eviction workers exist (they run the same sweep on their idle
    /// tick); an injected worker stall delays a tick but never skips it.
    fn spawn_sweeper(&mut self) {
        let shards: Vec<Arc<ShardSlot>> = self.shards.clone();
        let signal = Arc::clone(&self.sweep_signal);
        let ttl = self.cfg.session_idle_ttl;
        let tick = self.cfg.session_sweep_tick;
        let handle = std::thread::Builder::new()
            .name("tvcache-sweep".into())
            .spawn(move || loop {
                {
                    let st = signal.state.lock().unwrap();
                    let (st, _) = signal.cv.wait_timeout(st, tick).unwrap();
                    if st.shutdown {
                        break;
                    }
                }
                if let Some(d) = fault::worker_stall() {
                    std::thread::sleep(d);
                }
                for slot in &shards {
                    slot.sweep_idle_sessions(ttl);
                }
            })
            .expect("spawn session sweeper");
        self.sweeper = Some(handle);
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Whether the spill tier has tripped into resident-only mode (a disk
    /// write fault disables further spilling; resident snapshots and
    /// destroy-eviction keep working). `false` when no spill dir is set.
    pub fn spill_degraded(&self) -> bool {
        self.spill.as_ref().is_some_and(|s| s.degraded())
    }

    /// The shared content-addressed payload tier (white-box access for
    /// tests and benches: dedup/fault-cache counters, payload counts).
    pub fn payload_store(&self) -> &Arc<PayloadStore> {
        &self.payloads
    }

    /// The replication op-log, when this service is a primary
    /// ([`ServiceConfig::replicate_window`] set).
    pub fn oplog(&self) -> Option<&Arc<OpLog>> {
        self.oplog.as_ref()
    }

    /// Whether follower replication was requested. A WAL-only primary
    /// keeps an op-log too (durability needs the same sequence
    /// discipline), but nothing tails it — `/drain` must not wait for
    /// follower acks then.
    pub fn replication_enabled(&self) -> bool {
        self.cfg.replicate_window.is_some()
    }

    /// Lock the op-log around a mutation (no-op `None` when replication is
    /// off). Held across apply + append so log order is apply order.
    fn log_guard(&self) -> Option<LogGuard<'_>> {
        self.oplog.as_ref().map(|l| l.begin())
    }

    /// Apply one replicated op pulled from a primary's log (follower
    /// replay). Ops must be applied in sequence order with no gaps — node
    /// ids replay identically because the TCG arena never reuses them.
    /// Returns `false` for an op that could not take effect here (e.g. a
    /// key-only attach whose payload bytes aged off the primary's window
    /// before this follower pulled them); callers count those — they
    /// degrade a snapshot, never correctness.
    pub fn apply_op(&self, op: Op) -> bool {
        match op {
            Op::Insert { task, traj } => {
                self.task(&task).record_trajectory(&traj);
                true
            }
            Op::Record { task, node, call, result } => {
                self.task(&task).cursor_record_at(node, &call, &result).is_some()
            }
            Op::Attach {
                task,
                node,
                id,
                key,
                bytes,
                byte_len,
                serialize_cost,
                restore_cost,
            } => {
                let slot = self.slot(&task);
                if !slot.snapshots.adopt_replicated(
                    id,
                    key,
                    bytes.as_ref().map(|b| b.to_vec()),
                    byte_len,
                    serialize_cost,
                    restore_cost,
                ) {
                    return false;
                }
                let freed = slot
                    .tasks
                    .task(&task)
                    .attach_snapshot(node, SnapshotRef { id, bytes: byte_len, restore_cost });
                // Mirror `store_snapshot`: a count-budget prune (or an
                // attach to a vanished node) hands refs back — drop them.
                for f in freed {
                    slot.snapshots.remove(f.id);
                }
                true
            }
            Op::Release { task, node } => {
                // Pins are not replicated, so this is a saturating no-op on
                // a fresh follower — kept so a promoted follower starts
                // from released state.
                self.task(&task).release(node);
                true
            }
            Op::WarmFork { task, node, warm } => {
                self.task(&task).set_warm_fork(node, warm);
                true
            }
            Op::EvictSnapshot { task, node } => {
                let slot = self.slot(&task);
                if let Some(sref) = slot.tasks.task(&task).detach_snapshot_if_unpinned(node) {
                    slot.snapshots.remove(sref.id);
                }
                true
            }
            Op::EvictNode { task, node } => {
                let slot = self.slot(&task);
                if let Some(freed) = slot.tasks.task(&task).remove_subtree_if_unpinned(node) {
                    for sref in freed {
                        slot.snapshots.remove(sref.id);
                    }
                }
                true
            }
        }
    }

    fn slot(&self, task: &str) -> &ShardSlot {
        &self.shards[self.router.route(task)]
    }

    /// The per-task cache (white-box access for tests and the server).
    pub fn task(&self, task: &str) -> Arc<TaskCache> {
        self.slot(task).tasks.task(task)
    }

    /// All task ids across all shards.
    pub fn task_ids(&self) -> Vec<String> {
        let mut ids = Vec::new();
        for s in &self.shards {
            ids.extend(s.tasks.task_ids());
        }
        ids
    }

    pub fn task_count(&self) -> usize {
        self.shards.iter().map(|s| s.tasks.len()).sum()
    }

    /// Stored snapshots across all shards (both tiers).
    pub fn snapshot_count(&self) -> usize {
        self.shards.iter().map(|s| s.snapshots.len()).sum()
    }

    pub fn snapshot_bytes(&self) -> u64 {
        self.shards.iter().map(|s| s.snapshots.total_bytes()).sum()
    }

    /// Bytes held in memory (what the byte budgets bound).
    pub fn resident_bytes(&self) -> u64 {
        self.shards.iter().map(|s| s.snapshots.resident_bytes()).sum()
    }

    /// Snapshots currently demoted to the disk tier.
    pub fn spilled_count(&self) -> usize {
        self.shards.iter().map(|s| s.snapshots.spilled_count()).sum()
    }

    /// White-box: is `task`'s snapshot `id` currently in the resident tier?
    /// (Property tests of the pin/spill interaction.)
    pub fn snapshot_is_resident(&self, task: &str, id: u64) -> bool {
        self.slot(task).snapshots.is_resident(id)
    }

    /// Fetch a snapshot by id alone (legacy `/snapshot?id=` fetches that
    /// carry no task). The strided id space makes the owning shard
    /// computable; warm-started ids from a run with a different shard
    /// count may land elsewhere, so a miss falls back to scanning.
    pub fn fetch_snapshot_any(&self, id: u64) -> Option<SandboxSnapshot> {
        if id == 0 {
            return None;
        }
        let shard = ((id - 1) % self.shards.len() as u64) as usize;
        self.shards[shard].snapshots.get(id).or_else(|| {
            self.shards
                .iter()
                .enumerate()
                .filter(|(i, _)| *i != shard)
                .find_map(|(_, s)| s.snapshots.get(id))
        })
    }

    /// Run the background-eviction drain synchronously on every shard
    /// (deterministic; property tests and `background: false` configs).
    pub fn drain_over_budget(&self) {
        for slot in &self.shards {
            drain_slot(slot, &self.shards, &self.cfg, self.spill.as_deref(), self.oplog.as_deref());
        }
    }

    /// Block until every background eviction worker is idle with no
    /// pending kick — the point at which TCGs and shard stores are
    /// mutually consistent for white-box inspection.
    pub fn quiesce(&self) {
        if self.workers.is_empty() {
            return;
        }
        for slot in &self.shards {
            let mut st = slot.signal.state.lock().unwrap();
            while st.dirty || st.busy {
                st = slot.signal.cv.wait(st).unwrap();
            }
        }
    }

    /// White-box eviction of one node's snapshot (tests of the resume-offer
    /// eviction race). Returns `true` if a snapshot was detached + dropped.
    pub fn evict_snapshot(&self, task: &str, node: NodeId) -> bool {
        let slot = self.slot(task);
        let mut log = self.log_guard();
        match slot.tasks.task(task).detach_snapshot_if_unpinned(node) {
            Some(sref) => {
                slot.snapshots.remove(sref.id);
                if let Some(g) = log.as_mut() {
                    g.push(Op::EvictSnapshot { task: task.to_string(), node });
                }
                true
            }
            None => false,
        }
    }

    /// White-box removal of a node's whole subtree (tests of cursor
    /// invalidation): drops the nodes *and* their snapshot bytes, so any
    /// cursor pinned inside the subtree reports `Invalid` on its next step.
    /// Refuses when the subtree is refcount-pinned.
    pub fn evict_node(&self, task: &str, node: NodeId) -> bool {
        let slot = self.slot(task);
        let mut log = self.log_guard();
        match slot.tasks.task(task).remove_subtree_if_unpinned(node) {
            Some(freed) => {
                for sref in freed {
                    slot.snapshots.remove(sref.id);
                }
                if let Some(g) = log.as_mut() {
                    g.push(Op::EvictNode { task: task.to_string(), node });
                }
                true
            }
            None => false,
        }
    }

    /// Live rollout sessions across all shards (diagnostics; a steady
    /// non-zero count after every rollout finished means leaked sessions).
    pub fn session_count(&self) -> usize {
        self.shards.iter().map(|s| s.sessions.lock().unwrap().len()).sum()
    }

    /// Resume pins currently owned by session entries across all shards.
    pub fn session_pin_count(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.sessions.lock().unwrap().values().map(|e| e.pins.len()).sum::<usize>())
            .sum()
    }

    /// Sweep every shard's idle sessions now (deterministic tests).
    pub fn sweep_idle_sessions(&self) {
        for slot in &self.shards {
            slot.sweep_idle_sessions(self.cfg.session_idle_ttl);
        }
    }

    /// Op-count tick of the periodic session sweep: every
    /// [`ServiceConfig::session_sweep_every_ops`] session ops on a shard,
    /// sweep its idle sessions — the table is reclaimed on busy shards
    /// without waiting for the cap or the background timer.
    fn session_op_tick(&self, slot: &ShardSlot) {
        let every = self.cfg.session_sweep_every_ops;
        if every == 0 {
            return;
        }
        let n = slot.session_ops.fetch_add(1, Ordering::Relaxed) + 1;
        if n % every == 0 {
            slot.sweep_idle_sessions(self.cfg.session_idle_ttl);
        }
    }

    // The session ops snapshot the entry under the table mutex, run the
    // TCG operation with the mutex *released* (a task's TCG write-lock
    // stall must not block other tasks' sessions on the same shard), then
    // re-lock briefly to write the advanced position back. A session has
    // exactly one owning rollout, so the unlocked window admits no lost
    // update — and an eviction landing in that window is caught by the
    // next step's generation/liveness check, exactly as it would be after
    // the op.

    /// Shared core of [`SessionBackend::cursor_step`] and the turn path:
    /// one incremental step. With `session_pin` a miss's resume pin is
    /// registered on the session entry (released on close/sweep if the
    /// client never does); without it the pin stays caller-owned, exactly
    /// as the bare per-call cursor protocol always worked.
    fn step_session(
        &self,
        task: &str,
        cursor: u64,
        call: &ToolCall,
        session_pin: bool,
    ) -> CursorStep {
        let slot = self.slot(task);
        self.session_op_tick(slot);
        let snapshot = {
            let sessions = slot.sessions.lock().unwrap();
            sessions
                .get(&cursor)
                .map(|e| (Arc::clone(&e.cache), e.node, e.steps, e.gen))
        };
        let Some((cache, node, steps, gen)) = snapshot else {
            return CursorStep::Invalid;
        };
        let (step, new_node, new_gen) = cache.cursor_step_at(node, steps, gen, call);
        if !matches!(step, CursorStep::Invalid) {
            // Hit or miss: the call is consumed either way (a miss is
            // executed and then `cursor_record`ed by the caller).
            let mut entry_gone = false;
            {
                let mut sessions = slot.sessions.lock().unwrap();
                match sessions.get_mut(&cursor) {
                    Some(e) => {
                        e.node = new_node;
                        e.gen = new_gen;
                        e.steps = steps + 1;
                        e.last_used = std::time::Instant::now();
                        if session_pin {
                            if let CursorStep::Miss(m) = &step {
                                if let Some((pin, _, _)) = m.resume {
                                    e.pins.push(pin);
                                }
                            }
                        }
                    }
                    None => entry_gone = true,
                }
            }
            if entry_gone && session_pin {
                // The sweep (or a close) removed the entry in the unlocked
                // window: nobody would ever release the pin the step just
                // took — hand it back now. The offer still reaches the
                // caller, degraded to the legacy unpinned contract (a
                // fetch that loses an eviction race replays).
                if let CursorStep::Miss(m) = &step {
                    if let Some((pin, _, _)) = m.resume {
                        cache.release(pin);
                    }
                }
            }
        }
        step
    }

    /// Evaluate a turn's speculative probes at the session's current
    /// position. Non-advancing, stat-free, pin-free (see
    /// [`TaskCache::probe_stateless`]); a dead session answers nothing.
    fn probe_session(
        &self,
        task: &str,
        cursor: u64,
        probes: &[ToolCall],
    ) -> Vec<Option<ToolResult>> {
        if probes.is_empty() {
            return Vec::new();
        }
        let slot = self.slot(task);
        let snapshot = {
            let sessions = slot.sessions.lock().unwrap();
            sessions.get(&cursor).map(|e| (Arc::clone(&e.cache), e.node))
        };
        let Some((cache, node)) = snapshot else {
            return vec![None; probes.len()];
        };
        probes.iter().map(|p| cache.probe_stateless(node, p)).collect()
    }

    fn kick_if_over_budget(&self, shard: usize) {
        if self.workers.is_empty() {
            return;
        }
        let over_shard = self
            .cfg
            .shard_byte_budget
            .is_some_and(|b| self.shards[shard].snapshots.resident_bytes() > b);
        let over_global =
            self.cfg.global_byte_budget.is_some_and(|b| self.resident_bytes() > b);
        if over_global {
            // Every shard sheds its own worst snapshots.
            for s in &self.shards {
                s.signal.kick();
            }
        } else if over_shard {
            self.shards[shard].signal.kick();
        }
    }

    /// Persist every task's TCG and snapshot payloads under `dir` so a
    /// later run can [`ShardedCacheService::warm_start_from_dir`]. The
    /// payloads reuse the spill format (`snap-<id>.bin` + manifest);
    /// `tcgs.json` is written atomically last.
    pub fn persist_to_dir(&self, dir: &Path) -> std::io::Result<()> {
        // Persisting into the live spill directory reuses the service's
        // own store (one writer, one compaction authority: a second store
        // on the same manifest could have its appends discarded by the
        // primary's compaction rewrite, and its fd stranded on the
        // unlinked inode). Any other destination gets a fresh
        // append-only writer.
        // Canonicalize before comparing: "./out/spill", a symlink, or a
        // trailing-dot spelling of the live spill dir must not sneak a
        // second writer onto the same manifest.
        let canon = |p: &Path| std::fs::canonicalize(p).unwrap_or_else(|_| p.to_path_buf());
        let dir_canon = canon(dir);
        let own = self.spill.as_ref().filter(|s| canon(s.dir()) == dir_canon).cloned();
        let opened;
        let spill: &SpillStore = match &own {
            Some(s) => s.as_ref(),
            None => {
                opened = SpillStore::open_append_only(dir)?;
                &opened
            }
        };
        // A consistent cut (PR 9): hold the op-log guard across the whole
        // state capture, so the stamped `wal_seq` names exactly the
        // mutation boundary this snapshot reflects — recovery warm-starts
        // it and replays the WAL from that sequence, with nothing
        // double-applied and nothing lost in between.
        let log = self.oplog.as_ref().map(|l| l.begin());
        let wal_seq = log.as_ref().map(|g| g.next_seq());
        let mut tasks_json = Vec::new();
        for slot in &self.shards {
            let mut ids = slot.tasks.task_ids();
            ids.sort();
            for tid in ids {
                let tc = slot.tasks.task(&tid);
                for (_, sref) in tc.snapshotted_nodes() {
                    // Already spilled into this very directory (keyed or
                    // legacy file name): the bytes are in place — append
                    // the manifest record only (no re-read/re-write, no
                    // fault-counter pollution).
                    if let Some(s) = slot.snapshots.spilled_slot(sref.id) {
                        let in_dir =
                            s.path.parent().map(canon).is_some_and(|p| p == dir_canon);
                        if in_dir {
                            spill.record(&tid, sref.id, &s, sref.restore_cost)?;
                            continue;
                        }
                    }
                    if let (Some(key), Some(snap)) =
                        (slot.snapshots.content_key(sref.id), slot.snapshots.get(sref.id))
                    {
                        // Content-keyed write: a payload shared by many
                        // handles lands on disk once. The manifest records
                        // the ref's original restore cost — not the
                        // fault-penalized one `get` reports.
                        spill.write_keyed(
                            &tid,
                            sref.id,
                            key,
                            &snap.bytes,
                            snap.serialize_cost,
                            sref.restore_cost,
                        )?;
                    }
                }
                tasks_json.push(Json::obj(vec![
                    ("task", Json::str(tid.as_str())),
                    ("tcg", tc.to_persistent_json()),
                ]));
            }
        }
        let mut fields = vec![("tasks", Json::Arr(tasks_json))];
        if let Some(seq) = wal_seq {
            // Anchor the checkpoint to the log: recovery replays the WAL
            // from exactly this sequence.
            fields.push(("wal_seq", Json::num(seq as f64)));
        }
        let doc = Json::obj(fields).to_string();
        let tmp = dir.join("tcgs.json.tmp");
        std::fs::write(&tmp, doc)?;
        // Durability, not just atomicity: fsync the tmp file before the
        // rename (so the rename never publishes a hole after a crash) and
        // the directory after it (so the rename itself survives).
        std::fs::File::open(&tmp)?.sync_all()?;
        std::fs::rename(tmp, dir.join("tcgs.json"))?;
        std::fs::File::open(dir)?.sync_all()?;
        // A persist into the WAL's anchored checkpoint directory advances
        // the retention floor: ops below min(checkpoint, follower acks)
        // can never be needed again — recovery replays from the checkpoint
        // and no follower will re-request acked ops. Any other destination
        // is an ordinary export and retains nothing.
        if let (Some(g), Some(oplog)) = (log.as_ref(), self.oplog.as_ref()) {
            let is_ckpt = self
                .cfg
                .wal_dir
                .as_ref()
                .is_some_and(|w| canon(&w.join("checkpoint")) == dir_canon);
            if let (true, Some(wal)) = (is_ckpt, oplog.wal()) {
                let seq = g.next_seq();
                // Everything below the cut becomes durable before the
                // segments holding it become deletable.
                wal.sync();
                self.checkpoint_seq.store(seq, Ordering::Relaxed);
                let acked = oplog.acked();
                // acked == 0 means no follower ever pulled: the checkpoint
                // alone sets the floor, or a replication-less primary
                // would pin every segment forever.
                let floor = if acked == 0 { seq } else { seq.min(acked) };
                wal.retain_below(floor);
            }
        }
        Ok(())
    }

    /// The op sequence the last checkpoint into `wal_dir/checkpoint`
    /// covered (0 before the first one).
    pub fn checkpoint_seq(&self) -> u64 {
        self.checkpoint_seq.load(Ordering::Relaxed)
    }

    /// Warm-start: merge a persisted cache state from `dir` into this
    /// service — TCGs are rebuilt per task and snapshot refs re-attached
    /// as *spilled* entries (payloads stay on disk until a resume faults
    /// them in). Only refs whose manifest record and payload file survived
    /// are attached, so a run killed mid-spill recovers consistently.
    /// Returns the number of tasks loaded.
    pub fn warm_start_from_dir(&self, dir: &Path) -> std::io::Result<usize> {
        self.warm_start_with_seq(dir).map(|(loaded, _)| loaded)
    }

    /// [`ShardedCacheService::warm_start_from_dir`] plus the checkpoint's
    /// stamped WAL sequence (`wal_seq`; 0 when absent — a pre-WAL or
    /// replication-less persist): crash recovery replays the durable log
    /// from exactly that sequence.
    pub fn warm_start_with_seq(&self, dir: &Path) -> std::io::Result<(usize, u64)> {
        let records = spill::load_manifest(dir);
        let text = std::fs::read_to_string(dir.join("tcgs.json"))?;
        let doc = json::parse(&text)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
        let Some(tasks) = doc.get("tasks").and_then(Json::as_arr) else {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "tcgs.json missing tasks",
            ));
        };
        // Crash hygiene: a run killed mid-compaction (or mid-spill) leaves
        // a stray `manifest.jsonl.tmp` and orphaned `snap-*` files that no
        // surviving manifest record references. Sweep them now — before
        // this sweep they lingered until the *next* compaction rewrite.
        spill::sweep_orphans(dir, &records);
        let mut loaded = 0usize;
        for entry in tasks {
            let (Some(tid), Some(tcg_json)) =
                (entry.get("task").and_then(Json::as_str), entry.get("tcg"))
            else {
                continue;
            };
            let slot = self.slot(tid);
            let tc = slot.tasks.task(tid);
            // Attach a ref only when its payload survived in the manifest
            // AND the id is not already live in this service's store —
            // warm-starting into a non-empty service must never alias a
            // reloaded ref onto someone else's payload.
            let keep =
                |id: u64| records.contains_key(&id) && !slot.snapshots.contains(id);
            let (attached, ok) = tc.load_persistent_json(tcg_json, &keep);
            // Register every ref that made it onto the TCG — also on a
            // partial (malformed mid-entry) load, so no ref dangles.
            for (_, sref) in attached {
                if let Some(r) = records.get(&sref.id) {
                    slot.snapshots.adopt_spilled(sref.id, r.slot(dir));
                }
            }
            if ok {
                loaded += 1;
            }
        }
        // Future ids must clear every reloaded id, whatever shard count the
        // persisting run used.
        let max_id = records.keys().copied().max().unwrap_or(0);
        for slot in &self.shards {
            slot.snapshots.reserve_through(max_id);
        }
        Ok((loaded, doc.get("wal_seq").and_then(Json::as_u64).unwrap_or(0)))
    }

    /// Serialize this primary's full live state for a follower bootstrap
    /// (`GET /bootstrap`): every task's TCG, every snapshot handle, and
    /// each content payload exactly once — stamped with the op sequence
    /// the capture reflects, all under one op-log guard, so the follower
    /// can resume tailing `/replicate?from=<seq>` with no gap and no
    /// overlap. `None` when this service keeps no op-log (nothing to
    /// resume from).
    pub fn bootstrap_doc(&self) -> Option<Json> {
        let log = self.oplog.as_ref()?.begin();
        let seq = log.next_seq();
        let mut tasks_json = Vec::new();
        let mut snaps_json = Vec::new();
        let mut shipped: HashSet<ContentKey> = HashSet::new();
        for slot in &self.shards {
            let mut ids = slot.tasks.task_ids();
            ids.sort();
            for tid in ids {
                let tc = slot.tasks.task(&tid);
                for (_, sref) in tc.snapshotted_nodes() {
                    let (Some(key), Some(snap)) =
                        (slot.snapshots.content_key(sref.id), slot.snapshots.get(sref.id))
                    else {
                        continue;
                    };
                    // Payload bytes ship once per content key; the other
                    // handles carry the key alone and re-bind on adoption.
                    let bytes = if shipped.insert(key) {
                        Json::str(hex_encode(&snap.bytes))
                    } else {
                        Json::Null
                    };
                    snaps_json.push(Json::obj(vec![
                        ("task", Json::str(tid.as_str())),
                        ("id", Json::num(sref.id as f64)),
                        ("key", Json::str(key.to_hex())),
                        ("bytes", bytes),
                        ("byte_len", Json::num(sref.bytes as f64)),
                        ("serialize_cost", Json::num(snap.serialize_cost)),
                        ("restore_cost", Json::num(sref.restore_cost)),
                    ]));
                }
                tasks_json.push(Json::obj(vec![
                    ("task", Json::str(tid.as_str())),
                    ("tcg", tc.to_persistent_json()),
                ]));
            }
        }
        Some(Json::obj(vec![
            ("seq", Json::num(seq as f64)),
            ("shards", Json::num(self.shards.len() as f64)),
            ("tasks", Json::Arr(tasks_json)),
            ("snaps", Json::Arr(snaps_json)),
        ]))
    }

    /// Install a [`ShardedCacheService::bootstrap_doc`] onto this follower:
    /// snapshot payloads are adopted first, then each task's cache is
    /// *replaced* by the checkpointed graph with the primary's node ids
    /// preserved verbatim (every replicated op about to be tailed names
    /// them). Returns the op sequence to resume tailing from; `None` means
    /// the doc is unusable here (malformed, or a shard-count mismatch —
    /// snapshot id striding would diverge).
    pub fn adopt_bootstrap(&self, doc: &Json) -> Option<u64> {
        let seq = doc.get("seq").and_then(Json::as_u64)?;
        let shards = doc.get("shards").and_then(Json::as_u64)? as usize;
        if shards != self.shards.len() {
            return None;
        }
        let tasks = doc.get("tasks").and_then(Json::as_arr)?;
        // Payloads before graphs, so each TCG load's keep-check sees every
        // adopted id.
        let mut max_id = 0u64;
        for s in doc.get("snaps").and_then(Json::as_arr).unwrap_or(&[]) {
            let (Some(tid), Some(id), Some(key)) = (
                s.get("task").and_then(Json::as_str),
                s.get("id").and_then(Json::as_u64),
                s.get("key").and_then(Json::as_str).and_then(ContentKey::from_hex),
            ) else {
                continue;
            };
            let bytes = s.get("bytes").and_then(Json::as_str).and_then(hex_decode);
            let byte_len = s.get("byte_len").and_then(Json::as_u64).unwrap_or(0);
            let ser = s.get("serialize_cost").and_then(Json::as_f64).unwrap_or(0.0);
            let rc = s.get("restore_cost").and_then(Json::as_f64).unwrap_or(0.0);
            if self.slot(tid).snapshots.adopt_replicated(id, key, bytes, byte_len, ser, rc)
            {
                max_id = max_id.max(id);
            }
        }
        for entry in tasks {
            let (Some(tid), Some(tcg_json)) =
                (entry.get("task").and_then(Json::as_str), entry.get("tcg"))
            else {
                continue;
            };
            let slot = self.slot(tid);
            // What the partial replay attached but the checkpoint no
            // longer carries was evicted on the primary while this
            // follower was gapped: its store entries must go too.
            let stale: Vec<u64> = slot
                .tasks
                .task(tid)
                .snapshotted_nodes()
                .into_iter()
                .map(|(_, s)| s.id)
                .collect();
            // Replace, never merge: the old graph may hold nodes the
            // primary evicted, and ids must line up exactly for the tail.
            let tc = slot.tasks.replace(tid);
            let keep = |id: u64| slot.snapshots.contains(id);
            let (attached, _) = tc.load_bootstrap_json(tcg_json, &keep);
            let kept: HashSet<u64> = attached.iter().map(|(_, s)| s.id).collect();
            for id in stale {
                if !kept.contains(&id) {
                    slot.snapshots.remove(id);
                }
            }
        }
        for slot in &self.shards {
            slot.snapshots.reserve_through(max_id);
        }
        Some(seq)
    }
}

/// Lowercase hex of `bytes` (bootstrap payload transport).
fn hex_encode(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

/// Inverse of [`hex_encode`]; `None` on any malformation.
fn hex_decode(s: &str) -> Option<Vec<u8>> {
    if s.len() % 2 != 0 {
        return None;
    }
    (0..s.len() / 2)
        .map(|i| u8::from_str_radix(s.get(2 * i..2 * i + 2)?, 16).ok())
        .collect()
}

impl Drop for ShardedCacheService {
    fn drop(&mut self) {
        for slot in &self.shards {
            slot.signal.shutdown();
        }
        self.sweep_signal.shutdown();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        if let Some(h) = self.sweeper.take() {
            let _ = h.join();
        }
    }
}

/// Drain one shard until its (and the global) resident-byte budget holds:
/// repeatedly demote the worst keep-score unpinned resident snapshot —
/// to the spill tier when configured, otherwise detach + destroy. Victim
/// order is deterministic (score, then snapshot id).
///
/// Candidates are deliberately re-scored after every demotion: destroying
/// a snapshot changes the recreation cost (and subtree shape) of its
/// neighbours, so a one-shot sorted list would evict against stale scores.
/// The rescans run on the background worker, off every request path.
/// Bytes per MiB — the unit of the keep-score byte term (see
/// [`EvictionPolicy::keep_score`](super::eviction::EvictionPolicy)).
const MIB: f64 = 1048576.0;

fn drain_slot(
    slot: &ShardSlot,
    all: &[Arc<ShardSlot>],
    cfg: &ServiceConfig,
    spill: Option<&SpillStore>,
    oplog: Option<&OpLog>,
) {
    let mut skip: HashSet<u64> = HashSet::new();
    loop {
        // A degraded spill store (a write fault demoted it to
        // resident-only mode) falls back to destroy-eviction: budgets
        // still hold, at recreation cost instead of fault-in cost. The
        // flag is re-read every iteration — it can flip mid-drain.
        let spill_enabled = spill.is_some_and(|s| !s.degraded());
        let over_shard = cfg
            .shard_byte_budget
            .is_some_and(|b| slot.snapshots.resident_bytes() > b);
        let over_global = cfg.global_byte_budget.is_some_and(|b| {
            all.iter().map(|s| s.snapshots.resident_bytes()).sum::<u64>() > b
        });
        if !over_shard && !over_global {
            break;
        }
        // Content keys pinned anywhere (any task, any shard). Spilling
        // demotes the shared *payload*, not just the victim handle — so a
        // candidate whose content key is pinned through some other handle
        // must be skipped, or the pinned snapshot's bytes would leave
        // memory out from under its holder. Recollected every iteration,
        // like the candidate scores: pins move while we drain.
        let pinned_keys: HashSet<ContentKey> = if spill_enabled {
            let mut keys = HashSet::new();
            for s in all {
                for tid in s.tasks.task_ids() {
                    for pref in s.tasks.task(&tid).pinned_snapshot_refs() {
                        if let Some(k) = s.snapshots.content_key(pref.id) {
                            keys.insert(k);
                        }
                    }
                }
            }
            keys
        } else {
            // Destroying a handle only drops a refcount; a shared payload
            // survives for its pinned referents, so no cross-task guard is
            // needed on this path.
            HashSet::new()
        };
        let mut task_ids = slot.tasks.task_ids();
        task_ids.sort();
        // (score, cache, task id, node, ref) of the worst keeper so far.
        let mut best = None;
        for tid in &task_ids {
            let tc = slot.tasks.task(tid);
            for (score, node, sref) in tc.eviction_candidates() {
                if skip.contains(&sref.id) || !slot.snapshots.is_resident(sref.id) {
                    continue;
                }
                if spill_enabled
                    && slot
                        .snapshots
                        .content_key(sref.id)
                        .is_some_and(|k| pinned_keys.contains(&k))
                {
                    continue;
                }
                // Byte accounting charges a shared payload once, so the
                // keep-score's byte penalty must not count it once *per
                // handle*: give shared-payload candidates the byte term
                // back — evicting one of their handles reclaims (at most)
                // a fraction of those bytes, and the payload is serving
                // several positions per resident byte.
                let score = if tc.eviction.byte_weight != 0.0
                    && slot.snapshots.payload_shared(sref.id)
                {
                    score + tc.eviction.byte_weight * sref.bytes as f64 / MIB
                } else {
                    score
                };
                let better = match &best {
                    None => true,
                    Some((bs, _, _, _, bref)) => {
                        score.total_cmp(bs).then(sref.id.cmp(&bref.id))
                            == std::cmp::Ordering::Less
                    }
                };
                if better {
                    best = Some((score, Arc::clone(&tc), tid.clone(), node, sref));
                }
            }
        }
        let Some((_, tc, tid, node, sref)) = best else {
            break; // everything pinned / spilled / skipped: cannot enforce
        };
        if spill_enabled {
            // Demote to disk: the TCG ref stays, resumes fault back in.
            if !slot.snapshots.spill(&tid, sref.id, sref.restore_cost) {
                skip.insert(sref.id);
            }
        } else {
            // Destroy-eviction mutates the TCG, so it rides the op-log:
            // followers replay the exact same evictions instead of running
            // their own budget sweeps.
            let mut log = oplog.map(|l| l.begin());
            if tc.detach_snapshot_if_unpinned(node).is_some() {
                slot.snapshots.remove(sref.id);
                slot.bg_evicted.fetch_add(1, Ordering::Relaxed);
                if let Some(g) = log.as_mut() {
                    g.push(Op::EvictSnapshot { task: tid.clone(), node });
                }
            } else {
                skip.insert(sref.id); // pinned since candidate listing
            }
        }
    }
}

impl CacheBackend for ShardedCacheService {
    fn lookup(&self, task: &str, q: &[ToolCall]) -> Lookup {
        self.task(task).lookup(q)
    }

    fn insert(&self, task: &str, traj: &[(ToolCall, ToolResult)]) -> Option<NodeId> {
        let mut log = self.log_guard();
        let node = self.task(task).record_trajectory(traj);
        if let Some(g) = log.as_mut() {
            g.push(Op::Insert { task: task.to_string(), traj: traj.to_vec() });
        }
        Some(node)
    }

    fn release(&self, task: &str, node: NodeId) {
        let mut log = self.log_guard();
        self.task(task).release(node);
        if let Some(g) = log.as_mut() {
            g.push(Op::Release { task: task.to_string(), node });
        }
    }

    fn should_snapshot(&self, task: &str, costs: SnapshotCosts) -> bool {
        self.task(task).should_snapshot(costs)
    }

    fn store_snapshot(&self, task: &str, node: NodeId, snap: SandboxSnapshot) -> u64 {
        let shard = self.router.route(task);
        let slot = &self.shards[shard];
        let bytes = snap.size();
        let restore_cost = snap.restore_cost;
        let serialize_cost = snap.serialize_cost;
        let mut log = self.log_guard();
        // Payload bytes ride the log once per content key per window; the
        // key is marked shipped at push time, so a *failed* attach below
        // never poisons it. The one copy is an `Arc<[u8]>`, shared by the
        // WAL frame, every follower pull, and the window entry — nothing
        // downstream deep-clones under the log mutex.
        let logged = log.as_ref().map(|g| {
            let key = ContentKey::of(&snap.bytes);
            let payload: Option<Arc<[u8]>> =
                g.wants_bytes(&key).then(|| Arc::from(&snap.bytes[..]));
            (key, payload)
        });
        let id = slot.snapshots.insert(snap);
        let freed = slot
            .tasks
            .task(task)
            .attach_snapshot(node, SnapshotRef { id, bytes, restore_cost });
        // Eviction decisions and byte reclamation stay within this shard.
        // If the attach itself was rejected (node evicted concurrently) or
        // the budget immediately pruned the new snapshot, its ref is in
        // `freed`: drop the bytes and report failure with id 0.
        let mut attached = true;
        for f in freed {
            if f.id == id {
                attached = false;
            }
            slot.snapshots.remove(f.id);
        }
        if attached {
            if let (Some(g), Some((key, payload))) = (log.as_mut(), logged) {
                g.push(Op::Attach {
                    task: task.to_string(),
                    node,
                    id,
                    key,
                    bytes: payload,
                    byte_len: bytes,
                    serialize_cost,
                    restore_cost,
                });
            }
            // Byte budgets are enforced off this hot path: flag the
            // background worker and return immediately.
            self.kick_if_over_budget(shard);
            id
        } else {
            0
        }
    }

    fn fetch_snapshot(&self, task: &str, id: u64) -> Option<SandboxSnapshot> {
        self.slot(task).snapshots.get(id)
    }

    fn set_warm_fork(&self, task: &str, node: NodeId, warm: bool) {
        let mut log = self.log_guard();
        self.task(task).set_warm_fork(node, warm);
        if let Some(g) = log.as_mut() {
            g.push(Op::WarmFork { task: task.to_string(), node, warm });
        }
    }

    fn has_warm_fork(&self, task: &str, node: NodeId) -> bool {
        self.task(task).has_warm_fork(node)
    }

    fn stats(&self, task: &str) -> CacheStats {
        self.task(task).stats()
    }

    fn service_stats(&self) -> BackendStats {
        let mut agg = BackendStats {
            shards: self.shards.len(),
            snapshots: self.snapshot_count(),
            snapshot_bytes: self.snapshot_bytes(),
            ..Default::default()
        };
        for s in &self.shards {
            agg.spilled_snapshots += s.snapshots.spilled_count();
            agg.spilled_bytes += s.snapshots.spilled_bytes();
            agg.spills += s.snapshots.spill_count();
            agg.spill_faults += s.snapshots.fault_count();
            agg.bg_evictions += s.bg_evicted.load(Ordering::Relaxed);
            for id in s.tasks.task_ids() {
                let st = s.tasks.task(&id).stats();
                agg.tasks += 1;
                agg.lookups += st.lookups;
                agg.hits += st.hits;
            }
        }
        // The payload tier is service-global (shared by every shard), so
        // its counters are read once, not summed per shard.
        agg.dedup_hits = self.payloads.dedup_hits();
        agg.dedup_resident_bytes_saved = self.payloads.dedup_resident_bytes_saved();
        agg.fault_cache_hits = self.payloads.fault_cache_hits();
        agg.fault_cache_misses = self.payloads.fault_cache_misses();
        agg.fault_cache_evictions = self.payloads.fault_cache_evictions();
        // Degradation health: whether the spill tier has demoted itself to
        // resident-only mode, and how many faults the (test/chaos-only)
        // injector has fired process-wide.
        agg.spill_degraded = self.spill_degraded();
        agg.injected_faults = fault::injected_total();
        // Durability counters (PR 9): op-log append volume and the WAL's
        // segment/fsync/byte meters. `replicate_bytes_shipped` is a wire
        // counter the HTTP server fills in; in-process it stays 0.
        if let Some(log) = &self.oplog {
            agg.oplog_appended = log.appended();
            if let Some(wal) = log.wal() {
                agg.wal_segments = wal.segment_count();
                agg.wal_fsyncs = wal.fsyncs();
                agg.wal_appended_bytes = wal.appended_bytes();
                agg.wal_degraded = wal.degraded();
            }
        }
        agg.recoveries = self.recoveries.load(Ordering::Relaxed);
        agg
    }

    fn persist(&self, dir: &str) -> bool {
        self.persist_to_dir(Path::new(dir)).is_ok()
    }

    fn warm_start(&self, dir: &str) -> bool {
        self.warm_start_from_dir(Path::new(dir)).is_ok()
    }
}

impl SessionBackend for ShardedCacheService {
    fn capabilities(&self) -> Capabilities {
        Capabilities::V2
    }

    fn cursor_open(&self, task: &str) -> u64 {
        let slot = self.slot(task);
        self.session_op_tick(slot);
        let cache = slot.tasks.task(task);
        let gen = cache.eviction_generation();
        let id = self.next_cursor.fetch_add(1, Ordering::Relaxed);
        let entry = SessionEntry {
            cache,
            node: super::tcg::ROOT,
            steps: 0,
            gen,
            last_used: std::time::Instant::now(),
            pins: Vec::new(),
        };
        let mut sessions = slot.sessions.lock().unwrap();
        if sessions.len() >= self.cfg.max_sessions_per_shard {
            // Sweep sessions whose rollouts died without closing; if the
            // table is still full, refuse — the client falls back to
            // full-prefix lookups for this rollout.
            drop(sessions);
            slot.sweep_idle_sessions(self.cfg.session_idle_ttl);
            sessions = slot.sessions.lock().unwrap();
        }
        // Admission check and insert under one guard: the cap is a strict
        // bound, never overshot by concurrent opens racing the check.
        if sessions.len() >= self.cfg.max_sessions_per_shard {
            return 0;
        }
        sessions.insert(id, entry);
        id
    }

    fn cursor_step(&self, task: &str, cursor: u64, call: &ToolCall) -> CursorStep {
        self.step_session(task, cursor, call, false)
    }

    fn cursor_record(
        &self,
        task: &str,
        cursor: u64,
        call: &ToolCall,
        result: &ToolResult,
    ) -> Option<NodeId> {
        let slot = self.slot(task);
        self.session_op_tick(slot);
        let snapshot = {
            let sessions = slot.sessions.lock().unwrap();
            sessions.get(&cursor).map(|e| (Arc::clone(&e.cache), e.node))
        };
        // Unknown cursor or a record conflict is `None` — a *failed*
        // record, distinct from `Some(0)` (a successful no-op record at
        // ROOT): callers must never pin or snapshot-attach a failure.
        let (cache, node) = snapshot?;
        let mut log = self.log_guard();
        match cache.cursor_record_at(node, call, result) {
            Some((new_node, gen)) => {
                // The op carries the *pre*-record position: replaying it
                // re-derives `new_node` deterministically (ids are never
                // reused), so followers need no cursor table at all.
                if let Some(g) = log.as_mut() {
                    g.push(Op::Record {
                        task: task.to_string(),
                        node,
                        call: call.clone(),
                        result: result.clone(),
                    });
                }
                drop(log);
                let mut sessions = slot.sessions.lock().unwrap();
                if let Some(e) = sessions.get_mut(&cursor) {
                    e.node = new_node;
                    e.gen = gen;
                    e.last_used = std::time::Instant::now();
                }
                Some(new_node)
            }
            None => None,
        }
    }

    fn cursor_seek(&self, task: &str, cursor: u64, node: NodeId, steps: usize) -> bool {
        let slot = self.slot(task);
        self.session_op_tick(slot);
        let snapshot = {
            let sessions = slot.sessions.lock().unwrap();
            sessions.get(&cursor).map(|e| Arc::clone(&e.cache))
        };
        let Some(cache) = snapshot else {
            return false;
        };
        match cache.cursor_seek_check(node) {
            Some(gen) => {
                let mut sessions = slot.sessions.lock().unwrap();
                match sessions.get_mut(&cursor) {
                    Some(e) => {
                        e.node = node;
                        e.steps = steps;
                        e.gen = gen;
                        e.last_used = std::time::Instant::now();
                        true
                    }
                    None => false, // closed concurrently
                }
            }
            None => false,
        }
    }

    fn cursor_close(&self, task: &str, cursor: u64) {
        let entry = self.slot(task).sessions.lock().unwrap().remove(&cursor);
        if let Some(entry) = entry {
            // Closing releases everything the session still owns — the
            // RolloutSession Drop guarantee's server half.
            entry.release_pins();
        }
    }

    /// Known narrow race: if the idle sweep reclaimed this session (and
    /// released its pins) while the client stalled, the client's late
    /// release lands here with no entry and still decrements once —
    /// potentially returning a pin some *other* rollout holds on the same
    /// node. The exposure window needs a rollout idle past the TTL that
    /// then resumes; the consequence is the legacy unpinned-offer contract
    /// (the other rollout's fetch may lose an eviction race and degrade to
    /// replay — correct output, lost optimization), the same hazard the
    /// pre-session wire protocol accepted on every offer.
    fn session_release(&self, task: &str, cursor: u64, node: NodeId) {
        let slot = self.slot(task);
        if cursor != 0 {
            let mut sessions = slot.sessions.lock().unwrap();
            if let Some(e) = sessions.get_mut(&cursor) {
                if let Some(i) = e.pins.iter().position(|&p| p == node) {
                    // The session no longer owns this pin: close/sweep
                    // must not release it a second time.
                    e.pins.swap_remove(i);
                }
            }
        }
        let mut log = self.log_guard();
        slot.tasks.task(task).release(node);
        if let Some(g) = log.as_mut() {
            g.push(Op::Release { task: task.to_string(), node });
        }
    }

    fn session_turn(&self, task: &str, cursor: u64, batch: &TurnBatch) -> TurnReply {
        let cursor = if cursor == 0 {
            // Session open piggybacks on the first turn frame.
            self.cursor_open(task)
        } else {
            cursor
        };
        if cursor == 0 {
            return TurnReply::refused(batch);
        }
        let (step, recorded) = match &batch.op {
            TurnOp::None => (None, None),
            TurnOp::Step(call) => {
                // Turn-path resume pins are session-owned: the entry
                // remembers them so close/sweep releases whatever the
                // client never did.
                (Some(self.step_session(task, cursor, call, true)), None)
            }
            TurnOp::Record(call, result) => {
                (None, self.cursor_record(task, cursor, call, result))
            }
        };
        // Probes run at the position *after* the op, so they predict the
        // rollout's next stateless calls.
        let probes = self.probe_session(task, cursor, &batch.probes);
        TurnReply { cursor, probes, step, recorded }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sf(s: &str) -> ToolCall {
        ToolCall::new("t", s)
    }

    fn traj(calls: &[&str]) -> Vec<(ToolCall, ToolResult)> {
        calls
            .iter()
            .map(|c| (sf(c), ToolResult::new(format!("out-{c}"), 1.0)))
            .collect()
    }

    fn snap(n: usize) -> SandboxSnapshot {
        SandboxSnapshot { bytes: vec![7u8; n], serialize_cost: 0.1, restore_cost: 0.2 }
    }

    /// Distinct-content snapshot: byte-accounting tests want every payload
    /// unique, so content-dedup stays out of their arithmetic.
    fn snapf(fill: u8, n: usize) -> SandboxSnapshot {
        SandboxSnapshot { bytes: vec![fill; n], serialize_cost: 0.1, restore_cost: 0.2 }
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("tvcache-svc-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn routes_tasks_and_isolates_them() {
        let svc = ShardedCacheService::new(4);
        svc.insert("task-a", &traj(&["x", "y"]));
        assert!(svc.lookup("task-a", &[sf("x"), sf("y")]).is_hit());
        assert!(!svc.lookup("task-b", &[sf("x"), sf("y")]).is_hit());
        assert_eq!(svc.task_count(), 2);
        assert_eq!(svc.stats("task-a").hits, 1);
        assert_eq!(svc.stats("task-b").hits, 0);
    }

    #[test]
    fn same_task_maps_to_same_cache() {
        let svc = ShardedCacheService::new(8);
        let a1 = svc.task("t");
        let a2 = svc.task("t");
        assert!(Arc::ptr_eq(&a1, &a2));
    }

    #[test]
    fn snapshot_store_fetch_and_global_id_uniqueness() {
        let svc = ShardedCacheService::new(4);
        let mut ids = std::collections::HashSet::new();
        for i in 0..32 {
            let task = format!("task-{i}");
            let node = svc.insert(&task, &traj(&["a"])).unwrap();
            let id = svc.store_snapshot(&task, node, snap(10 + i));
            assert!(id >= 1);
            assert!(ids.insert(id), "snapshot id {id} reused across shards");
            let got = svc.fetch_snapshot(&task, id).unwrap();
            assert_eq!(got.size() as usize, 10 + i);
            assert_eq!(svc.fetch_snapshot_any(id).unwrap().size() as usize, 10 + i);
        }
        assert_eq!(svc.snapshot_count(), 32);
        assert!(svc.snapshot_bytes() > 0);
    }

    #[test]
    fn eviction_reclaims_shard_store_bytes() {
        let factory: CacheFactory = Arc::new(|| {
            TaskCache::new(
                crate::cache::LpmConfig::default(),
                crate::cache::SnapshotPolicy::default(),
                crate::cache::EvictionPolicy { max_snapshots: 2, ..Default::default() },
            )
        });
        let svc = ShardedCacheService::with_factory(1, factory);
        for i in 0..5 {
            let node = svc.insert("t", &traj(&["p", &format!("leaf{i}")])).unwrap();
            svc.store_snapshot("t", node, snapf(i as u8, 100));
        }
        // Budget 2 ⇒ 3 evicted; evicted bytes must leave the shard store.
        assert_eq!(svc.snapshot_count(), 2);
        assert_eq!(svc.snapshot_bytes(), 200);
    }

    #[test]
    fn store_snapshot_to_missing_node_returns_zero_and_leaks_nothing() {
        let svc = ShardedCacheService::new(2);
        svc.insert("t", &traj(&["a"]));
        let id = svc.store_snapshot("t", 999, snap(16));
        assert_eq!(id, 0, "attach to a vanished node must report failure");
        assert_eq!(svc.snapshot_count(), 0, "orphaned bytes must be dropped");
    }

    #[test]
    fn resume_offer_pins_until_release() {
        let svc = ShardedCacheService::new(2);
        let node = svc.insert("t", &traj(&["a", "b"])).unwrap();
        svc.store_snapshot("t", node, snap(8));
        let Lookup::Miss(m) = svc.lookup("t", &[sf("a"), sf("b"), sf("z")]) else {
            panic!("expected miss")
        };
        let (resume, _, _) = m.resume.unwrap();
        assert_eq!(resume, node);
        svc.release("t", resume);
        assert_eq!(svc.stats("t").snapshot_resumes, 1);
    }

    #[test]
    fn warm_fork_roundtrip() {
        let svc = ShardedCacheService::new(3);
        let node = svc.insert("t", &traj(&["a"])).unwrap();
        assert!(!svc.has_warm_fork("t", node));
        svc.set_warm_fork("t", node, true);
        assert!(svc.has_warm_fork("t", node));
    }

    #[test]
    fn service_stats_aggregate_across_shards() {
        let svc = ShardedCacheService::new(4);
        for i in 0..10 {
            let task = format!("task-{i}");
            svc.insert(&task, &traj(&["a"]));
            assert!(svc.lookup(&task, &[sf("a")]).is_hit());
        }
        let agg = svc.service_stats();
        assert_eq!(agg.shards, 4);
        assert_eq!(agg.tasks, 10);
        assert_eq!(agg.lookups, 10);
        assert_eq!(agg.hits, 10);
    }

    #[test]
    fn over_budget_drain_spills_worst_snapshots_and_resumes_fault_in() {
        let dir = tmpdir("drain-spill");
        let cfg = ServiceConfig {
            shards: 1,
            shard_byte_budget: Some(250),
            spill_dir: Some(dir.clone()),
            background: false, // deterministic: drained by hand
            ..Default::default()
        };
        let svc = ShardedCacheService::with_config(cfg, Arc::new(TaskCache::with_defaults))
            .unwrap();
        let mut nodes = Vec::new();
        for i in 0..5 {
            let node = svc.insert("t", &traj(&["p", &format!("leaf{i}")])).unwrap();
            assert!(svc.store_snapshot("t", node, snapf(i as u8, 100)) > 0);
            nodes.push(node);
        }
        assert_eq!(svc.resident_bytes(), 500);
        svc.drain_over_budget();
        assert!(svc.resident_bytes() <= 250, "{}", svc.resident_bytes());
        // Nothing destroyed: all five remain stored, three on disk.
        assert_eq!(svc.snapshot_count(), 5);
        assert_eq!(svc.spilled_count(), 3);
        assert_eq!(svc.snapshot_bytes(), 500);
        // Every snapshot — resident or spilled — still fetches.
        for (node, _) in svc.task("t").snapshotted_nodes() {
            let leaf = nodes.iter().position(|&n| n == node).unwrap();
            let q = [sf("p"), sf(&format!("leaf{leaf}")), sf("zz")];
            let Lookup::Miss(m) = svc.lookup("t", &q) else {
                panic!("expected miss")
            };
            let (rnode, sref, _) = m.resume.expect("spilled node still offers resume");
            assert_eq!(rnode, node);
            assert!(svc.fetch_snapshot("t", sref.id).is_some(), "fault-in failed");
            svc.release("t", rnode);
        }
        let agg = svc.service_stats();
        assert_eq!(agg.spills, 3);
        assert!(agg.spill_faults >= 3);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn background_worker_drains_without_blocking_insert() {
        let dir = tmpdir("bg");
        let cfg = ServiceConfig {
            shards: 2,
            shard_byte_budget: Some(300),
            spill_dir: Some(dir.clone()),
            background: true,
            ..Default::default()
        };
        let svc = Arc::new(
            ShardedCacheService::with_config(cfg, Arc::new(TaskCache::with_defaults))
                .unwrap(),
        );
        for i in 0..24 {
            let task = format!("task-{i}");
            let node = svc.insert(&task, &traj(&["a", "b"])).unwrap();
            svc.store_snapshot(&task, node, snapf(i as u8, 100));
        }
        // The worker runs asynchronously; wait for it to go idle, then
        // verify the budget converged without losing any snapshot.
        svc.quiesce();
        for s in &svc.shards {
            assert!(
                s.snapshots.resident_bytes() <= 300,
                "worker failed to drain shard below budget"
            );
        }
        assert_eq!(svc.snapshot_count(), 24, "spill must not destroy snapshots");
        drop(svc); // Drop joins the workers: must not hang.
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn destroy_eviction_when_no_spill_dir() {
        let cfg = ServiceConfig {
            shards: 1,
            shard_byte_budget: Some(150),
            ..Default::default()
        };
        let svc = ShardedCacheService::with_config(cfg, Arc::new(TaskCache::with_defaults))
            .unwrap();
        for i in 0..4 {
            let node = svc.insert("t", &traj(&["p", &format!("leaf{i}")])).unwrap();
            svc.store_snapshot("t", node, snapf(i as u8, 100));
        }
        svc.drain_over_budget();
        assert!(svc.resident_bytes() <= 150);
        assert_eq!(svc.spilled_count(), 0);
        assert!(svc.snapshot_count() <= 1);
        assert!(svc.service_stats().bg_evictions >= 3);
    }

    #[test]
    fn global_budget_drains_across_shards() {
        let cfg = ServiceConfig {
            shards: 4,
            global_byte_budget: Some(350),
            ..Default::default()
        };
        let svc = ShardedCacheService::with_config(cfg, Arc::new(TaskCache::with_defaults))
            .unwrap();
        for i in 0..8 {
            let task = format!("task-{i}");
            let node = svc.insert(&task, &traj(&["a"])).unwrap();
            svc.store_snapshot(&task, node, snapf(i as u8, 100));
        }
        assert_eq!(svc.resident_bytes(), 800);
        svc.drain_over_budget();
        assert!(svc.resident_bytes() <= 350, "{}", svc.resident_bytes());
    }

    #[test]
    fn persist_and_warm_start_roundtrip() {
        let dir = tmpdir("persist");
        let svc = ShardedCacheService::new(4);
        let node = svc.insert("t1", &traj(&["a", "b"])).unwrap();
        let id = svc.store_snapshot("t1", node, snap(64));
        svc.insert("t2", &traj(&["x"]));
        assert!(svc.lookup("t1", &[sf("a"), sf("b")]).is_hit());
        svc.persist_to_dir(&dir).unwrap();

        // A fresh service — different shard count on purpose — warm-starts.
        let fresh = ShardedCacheService::new(2);
        assert_eq!(fresh.warm_start_from_dir(&dir).unwrap(), 2);
        assert!(fresh.lookup("t1", &[sf("a"), sf("b")]).is_hit());
        assert!(fresh.lookup("t2", &[sf("x")]).is_hit());
        // The snapshot ref survived as a spilled entry and faults in.
        let got = fresh.fetch_snapshot("t1", id).expect("payload reloads from disk");
        assert_eq!(got.size(), 64);
        assert_eq!(fresh.fetch_snapshot_any(id).unwrap().size(), 64);
        // New snapshot ids never collide with reloaded ones.
        let n2 = fresh.insert("t9", &traj(&["q"])).unwrap();
        let id2 = fresh.store_snapshot("t9", n2, snap(8));
        assert!(id2 > id, "fresh id {id2} collides with reloaded space ≤ {id}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn warm_start_missing_dir_fails_cleanly() {
        let svc = ShardedCacheService::new(2);
        assert!(!CacheBackend::warm_start(&svc, "/nonexistent/tvcache-warmstart"));
    }

    // ---- stateful lookup cursors ----

    #[test]
    fn cursor_walk_hits_recorded_chain_and_stats_match_legacy() {
        let svc = ShardedCacheService::new(4);
        svc.insert("t", &traj(&["a", "b", "c"]));
        let cur = svc.cursor_open("t");
        assert!(cur != 0);
        for (i, c) in ["a", "b", "c"].iter().enumerate() {
            match svc.cursor_step("t", cur, &sf(c)) {
                crate::cache::CursorStep::Hit { result, .. } => {
                    assert_eq!(result.output, format!("out-{c}"), "step {i}");
                }
                s => panic!("step {i}: {s:?}"),
            }
        }
        svc.cursor_close("t", cur);
        assert_eq!(svc.session_count(), 0, "close must drop the table entry");
        let stats = svc.stats("t");
        assert_eq!(stats.lookups, 3);
        assert_eq!(stats.hits, 3);
    }

    #[test]
    fn cursor_miss_record_extends_graph_like_full_insert() {
        let svc = ShardedCacheService::new(2);
        let cur = svc.cursor_open("t");
        let mut node = 0;
        for c in ["x", "y", "z"] {
            let call = sf(c);
            match svc.cursor_step("t", cur, &call) {
                crate::cache::CursorStep::Miss(_) => {}
                s => panic!("cold cache must miss: {s:?}"),
            }
            node = svc
                .cursor_record("t", cur, &call, &ToolResult::new(format!("out-{c}"), 1.0))
                .expect("record at a live cursor must succeed");
            assert!(node != 0);
        }
        // The incrementally recorded chain equals a full insert.
        assert_eq!(svc.insert("t", &traj(&["x", "y", "z"])), Some(node));
        assert!(svc.lookup("t", &[sf("x"), sf("y"), sf("z")]).is_hit());
        assert_eq!(svc.stats("t").inserts, 3);
    }

    #[test]
    fn cursor_miss_pins_resume_until_release() {
        let svc = ShardedCacheService::new(2);
        let node = svc.insert("t", &traj(&["a", "b"])).unwrap();
        svc.store_snapshot("t", node, snap(8));
        let cur = svc.cursor_open("t");
        assert!(svc.cursor_step("t", cur, &sf("a")).is_hit());
        assert!(svc.cursor_step("t", cur, &sf("b")).is_hit());
        let crate::cache::CursorStep::Miss(m) = svc.cursor_step("t", cur, &sf("zz")) else {
            panic!("divergent step must miss")
        };
        let (rnode, _, replay_from) = m.resume.expect("snapshot offered");
        assert_eq!((rnode, replay_from), (node, 2));
        assert_eq!(m.matched_calls, 2);
        assert_eq!(svc.task("t").pinned_node_count(), 1, "offer must pin");
        svc.release("t", rnode);
        assert_eq!(svc.task("t").pinned_node_count(), 0);
    }

    #[test]
    fn evicted_cursor_node_invalidates_then_seek_recovers() {
        let svc = ShardedCacheService::new(2);
        svc.insert("t", &traj(&["a", "b"]));
        let cur = svc.cursor_open("t");
        assert!(svc.cursor_step("t", cur, &sf("a")).is_hit());
        assert!(svc.cursor_step("t", cur, &sf("b")).is_hit());
        // Evict the subtree the cursor sits in (node of "b" = depth 2).
        let b = match svc.lookup("t", &[sf("a"), sf("b")]) {
            Lookup::Hit { node, .. } => node,
            m => panic!("{m:?}"),
        };
        assert!(svc.evict_node("t", b));
        assert_eq!(
            svc.cursor_step("t", cur, &sf("c")),
            crate::cache::CursorStep::Invalid,
            "a step at an evicted node must invalidate, never serve stale state"
        );
        // Seek to a live ancestor re-arms the cursor.
        let a = match svc.lookup("t", &[sf("a")]) {
            Lookup::Hit { node, .. } => node,
            m => panic!("{m:?}"),
        };
        assert!(svc.cursor_seek("t", cur, a, 1));
        assert!(matches!(
            svc.cursor_step("t", cur, &sf("c")),
            crate::cache::CursorStep::Miss(_)
        ));
        // Seeking to the dead node fails.
        assert!(!svc.cursor_seek("t", cur, b, 2));
    }

    #[test]
    fn cursor_table_cap_refuses_new_cursors_when_full() {
        let cfg = ServiceConfig { shards: 1, max_sessions_per_shard: 2, ..Default::default() };
        let svc = ShardedCacheService::with_config(cfg, Arc::new(TaskCache::with_defaults))
            .unwrap();
        let a = svc.cursor_open("t");
        let b = svc.cursor_open("t");
        assert!(a != 0 && b != 0);
        // Fresh (recently used) cursors are never swept: the table is full,
        // so the next open refuses and the client falls back to full-prefix
        // lookups.
        assert_eq!(svc.cursor_open("t"), 0);
        svc.cursor_close("t", a);
        assert!(svc.cursor_open("t") != 0, "freed capacity must be reusable");
    }

    #[test]
    fn unknown_cursor_ids_are_safe() {
        let svc = ShardedCacheService::new(2);
        svc.insert("t", &traj(&["a"]));
        assert_eq!(svc.cursor_step("t", 999, &sf("a")), crate::cache::CursorStep::Invalid);
        assert!(
            svc.cursor_record("t", 999, &sf("a"), &ToolResult::new("r", 1.0)).is_none(),
            "an unknown cursor is a *failed* record, not a ROOT record"
        );
        assert!(!svc.cursor_seek("t", 999, 1, 1));
        svc.cursor_close("t", 999); // no-op, no panic
        let batch = TurnBatch { probes: vec![sf("a")], op: TurnOp::Step(sf("a")) };
        let reply = svc.session_turn("t", 999, &batch);
        assert_eq!(reply.step, Some(crate::cache::CursorStep::Invalid));
        svc.session_release("t", 999, 1); // unknown session: plain release
    }

    // ---- session API v2 ----

    #[test]
    fn session_turn_opens_steps_probes_and_records_in_one_frame() {
        let svc = ShardedCacheService::new(2);
        svc.insert(
            "t",
            &[
                (sf("a"), ToolResult::new("out-a", 1.0)),
                (ToolCall::stateless("t", "peek"), ToolResult::new("peeked", 0.1)),
            ],
        );
        // Turn 1: cursor 0 opens a session; step hits; probes answered at
        // the post-step position.
        let batch = TurnBatch {
            probes: vec![ToolCall::stateless("t", "peek"), ToolCall::stateless("t", "nope")],
            op: TurnOp::Step(sf("a")),
        };
        let r1 = svc.session_turn("t", 0, &batch);
        assert!(r1.cursor != 0, "first frame must open the session");
        assert!(matches!(r1.step, Some(crate::cache::CursorStep::Hit { .. })));
        assert_eq!(r1.probes.len(), 2);
        assert_eq!(r1.probes[0].as_ref().unwrap().output, "peeked");
        assert_eq!(r1.probes[1], None, "unknown probe must be unanswered");

        // Turn 2: step miss; turn 3: record advances the chain.
        let r2 = svc.session_turn(
            "t",
            r1.cursor,
            &TurnBatch { probes: Vec::new(), op: TurnOp::Step(sf("b")) },
        );
        assert!(matches!(r2.step, Some(crate::cache::CursorStep::Miss(_))));
        let r3 = svc.session_turn(
            "t",
            r1.cursor,
            &TurnBatch {
                probes: Vec::new(),
                op: TurnOp::Record(sf("b"), ToolResult::new("out-b", 1.0)),
            },
        );
        let node = r3.recorded.unwrap();
        assert!(node != 0);
        assert!(svc.lookup("t", &[sf("a"), sf("b")]).is_hit());
        // Probe traffic must not have perturbed the stats: 3 real lookups
        // (1 legacy + turn steps), with the legacy lookup hitting too.
        svc.cursor_close("t", r1.cursor);
        assert_eq!(svc.session_count(), 0);
    }

    #[test]
    fn probes_do_not_touch_stats_or_pins() {
        let svc = ShardedCacheService::new(2);
        let node = svc
            .insert(
                "t",
                &[
                    (sf("a"), ToolResult::new("out-a", 1.0)),
                    (ToolCall::stateless("t", "peek"), ToolResult::new("peeked", 0.1)),
                ],
            )
            .unwrap();
        svc.store_snapshot("t", node, snap(8));
        let r1 = svc.session_turn(
            "t",
            0,
            &TurnBatch {
                probes: vec![ToolCall::stateless("t", "peek")],
                op: TurnOp::Step(sf("a")),
            },
        );
        assert!(r1.probes[0].is_some());
        let stats = svc.stats("t");
        assert_eq!(stats.lookups, 1, "only the step counts as a lookup");
        assert_eq!(stats.hits, 1);
        assert_eq!(svc.task("t").pinned_node_count(), 0, "probes must never pin");
        svc.cursor_close("t", r1.cursor);
    }

    #[test]
    fn idle_session_sweep_runs_on_op_ticks_and_releases_pins() {
        let cfg = ServiceConfig {
            shards: 1,
            session_idle_ttl: std::time::Duration::from_millis(40),
            session_sweep_every_ops: 8,
            ..Default::default()
        };
        let svc = ShardedCacheService::with_config(cfg, Arc::new(TaskCache::with_defaults))
            .unwrap();
        let node = svc.insert("t", &traj(&["a", "b"])).unwrap();
        svc.store_snapshot("t", node, snap(8));

        // An abandoned session holding a pin: walk to the snapshotted node,
        // then a divergent turn-path step miss pins the resume offer.
        let dead = svc.session_turn(
            "t",
            0,
            &TurnBatch { probes: Vec::new(), op: TurnOp::Step(sf("a")) },
        );
        assert!(dead.cursor != 0);
        for step in ["b", "zz"] {
            svc.session_turn(
                "t",
                dead.cursor,
                &TurnBatch { probes: Vec::new(), op: TurnOp::Step(sf(step)) },
            );
        }
        assert_eq!(svc.task("t").pinned_node_count(), 1, "turn miss offer pins");
        assert_eq!(svc.session_count(), 1);

        // Let it go idle, then generate op traffic well below the table
        // cap: the op-count tick alone must sweep it — no cap pressure.
        std::thread::sleep(std::time::Duration::from_millis(60));
        for _ in 0..9 {
            let _ = svc.cursor_step("t", 0xDEAD, &sf("a")); // unknown id: cheap op
        }
        assert_eq!(svc.session_count(), 0, "op-tick sweep must reclaim the idle session");
        assert_eq!(svc.task("t").pinned_node_count(), 0, "sweep must release its pins");
    }

    /// Regression: the periodic idle-session sweep used to run only on the
    /// eviction workers' timer tick, so a `background: true` service with
    /// no byte budgets (⇒ no eviction workers) reclaimed idle sessions
    /// only on op-count ticks — on a quiet shard, never. The dedicated
    /// sweeper thread must reclaim them with zero op traffic.
    #[test]
    fn idle_sessions_swept_without_eviction_workers() {
        let cfg = ServiceConfig {
            shards: 2,
            background: true, // but no byte budget: no eviction workers
            session_idle_ttl: std::time::Duration::from_millis(30),
            session_sweep_tick: std::time::Duration::from_millis(20),
            session_sweep_every_ops: 0, // op-count tick off: timer or bust
            ..Default::default()
        };
        let svc = ShardedCacheService::with_config(cfg, Arc::new(TaskCache::with_defaults))
            .unwrap();
        assert!(svc.workers.is_empty(), "unbudgeted service must spawn no workers");
        assert!(svc.sweeper.is_some(), "unbudgeted background service needs a sweeper");
        let cur = svc.cursor_open("t");
        assert!(cur != 0);
        assert_eq!(svc.session_count(), 1);
        // No further ops at all: only the dedicated timer can sweep.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while svc.session_count() != 0 && std::time::Instant::now() < deadline {
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        assert_eq!(svc.session_count(), 0, "sweeper must reclaim the idle session");
        drop(svc); // Drop joins the sweeper: must not hang.
    }

    #[test]
    fn capabilities_advertise_everything_in_process() {
        let svc = ShardedCacheService::new(1);
        assert_eq!(svc.capabilities(), crate::cache::Capabilities::V2);
    }

    #[test]
    fn persist_into_live_spill_dir_shares_the_writer_and_keeps_spilling() {
        // Regression: persisting into the service's *own* spill directory
        // must reuse the primary manifest writer — a second store on the
        // same file could have its records discarded by the primary's
        // compaction (and its fd stranded by the atomic rename).
        let dir = tmpdir("persist-live");
        let cfg = ServiceConfig {
            shards: 1,
            shard_byte_budget: Some(150),
            spill_dir: Some(dir.clone()),
            background: false,
            ..Default::default()
        };
        let svc = ShardedCacheService::with_config(cfg, Arc::new(TaskCache::with_defaults))
            .unwrap();
        for i in 0..3 {
            let node = svc.insert("t", &traj(&["p", &format!("leaf{i}")])).unwrap();
            assert!(svc.store_snapshot("t", node, snapf(i as u8, 100)) > 0);
        }
        svc.drain_over_budget(); // spills into `dir`
        assert!(svc.spilled_count() >= 2);
        svc.persist_to_dir(&dir).unwrap();

        // Post-persist spills still reach the same manifest (the writer
        // was never replaced or stranded), and a warm start sees every
        // payload.
        let node = svc.insert("t", &traj(&["p", "leaf-late"])).unwrap();
        assert!(svc.store_snapshot("t", node, snapf(9, 100)) > 0);
        svc.drain_over_budget();
        // Persist recorded every snapshot (both tiers) and the post-persist
        // spill appended through the same writer: one record per snapshot.
        let records = spill::load_manifest(&dir);
        assert_eq!(records.len(), svc.snapshot_count(), "manifest lost a record");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    // ---- content-addressed payload tier ----

    #[test]
    fn identical_payloads_dedup_across_tasks_and_shards() {
        let svc = ShardedCacheService::new(4);
        let mut ids = Vec::new();
        for i in 0..6 {
            let task = format!("task-{i}");
            let node = svc.insert(&task, &traj(&["a"])).unwrap();
            let id = svc.store_snapshot(&task, node, snap(256));
            assert!(id > 0);
            ids.push((task, id));
        }
        // Six handles, one resident copy: the bytes are charged once
        // service-wide, whatever shard each task routed to.
        assert_eq!(svc.snapshot_count(), 6);
        assert_eq!(svc.resident_bytes(), 256);
        assert_eq!(svc.payload_store().payload_count(), 1);
        let agg = svc.service_stats();
        assert_eq!(agg.dedup_hits, 5);
        assert_eq!(agg.dedup_resident_bytes_saved, 5 * 256);
        for (task, id) in &ids {
            assert_eq!(svc.fetch_snapshot(task, *id).unwrap().size(), 256);
        }
    }

    #[test]
    fn drain_never_spills_a_payload_pinned_through_another_task() {
        let dir = tmpdir("pin-shared");
        let cfg = ServiceConfig {
            shards: 1,
            shard_byte_budget: Some(50),
            spill_dir: Some(dir.clone()),
            background: false,
            ..Default::default()
        };
        let svc = ShardedCacheService::with_config(cfg, Arc::new(TaskCache::with_defaults))
            .unwrap();
        // Task A pins its snapshot through a resume offer; task B holds an
        // unpinned handle of the *same content*.
        let a = svc.insert("task-a", &traj(&["a", "b"])).unwrap();
        assert!(svc.store_snapshot("task-a", a, snap(100)) > 0);
        let b = svc.insert("task-b", &traj(&["x"])).unwrap();
        assert!(svc.store_snapshot("task-b", b, snap(100)) > 0);
        let Lookup::Miss(m) = svc.lookup("task-a", &[sf("a"), sf("b"), sf("z")]) else {
            panic!("expected miss")
        };
        let (pin, _, _) = m.resume.expect("snapshot offered");
        // Over budget (100 > 50), but the only payload's content key is
        // pinned via task A: spilling task B's handle would demote the
        // shared payload out from under the pinned holder — it must stay.
        svc.drain_over_budget();
        assert_eq!(svc.spilled_count(), 0, "pinned content key must not spill");
        assert_eq!(svc.resident_bytes(), 100);
        svc.release("task-a", pin);
        // Released: the payload is fair game, and demoting either handle
        // demotes both (one payload, one disk write).
        svc.drain_over_budget();
        assert_eq!(svc.spilled_count(), 2);
        assert_eq!(svc.resident_bytes(), 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn warm_start_sweeps_crash_leftovers() {
        let dir = tmpdir("sweep");
        let svc = ShardedCacheService::new(1);
        let node = svc.insert("t", &traj(&["a"])).unwrap();
        let id = svc.store_snapshot("t", node, snap(32));
        svc.persist_to_dir(&dir).unwrap();
        // Simulate a crash mid-compaction: a half-written manifest rewrite
        // plus payload files no surviving manifest record references.
        std::fs::write(dir.join("manifest.jsonl.tmp"), b"{trunc").unwrap();
        std::fs::write(dir.join("snap-999.bin"), b"orphan").unwrap();
        std::fs::write(dir.join("snap-777.tmp"), b"orphan").unwrap();
        let fresh = ShardedCacheService::new(1);
        assert_eq!(fresh.warm_start_from_dir(&dir).unwrap(), 1);
        assert!(!dir.join("manifest.jsonl.tmp").exists(), "stray tmp must be swept");
        assert!(!dir.join("snap-999.bin").exists(), "orphaned payload must be swept");
        assert!(!dir.join("snap-777.tmp").exists(), "orphaned spill tmp must be swept");
        // The live payload survived the sweep and still faults in.
        assert_eq!(fresh.fetch_snapshot("t", id).unwrap().size(), 32);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn oplog_replay_builds_identical_state_on_a_follower() {
        let primary = ShardedCacheService::with_config(
            ServiceConfig {
                shards: 2,
                replicate_window: Some(1024),
                ..Default::default()
            },
            Arc::new(TaskCache::with_defaults),
        )
        .unwrap();
        // Bulk insert + snapshot on one task…
        let n1 = primary.insert("t1", &traj(&["a", "b"])).unwrap();
        assert!(primary.store_snapshot("t1", n1, snap(64)) >= 1);
        // …a cursor-session record chain on another…
        let c = primary.cursor_open("t2");
        assert!(c != 0);
        let r1 = ToolResult::new("out-x", 1.0);
        let r2 = ToolResult::new("out-y", 1.0);
        primary.cursor_record("t2", c, &sf("x"), &r1).unwrap();
        primary.cursor_record("t2", c, &sf("y"), &r2).unwrap();
        // …a warm-fork mark, and a second snapshot with *identical* bytes
        // (its payload must ride the log only once).
        primary.set_warm_fork("t1", n1, true);
        let n2 = primary.insert("t1", &traj(&["a", "c"])).unwrap();
        assert!(primary.store_snapshot("t1", n2, snap(64)) >= 1);

        let log = primary.oplog().expect("replicate_window set");
        let (start, _next, ops) = log.read_from(0, 10_000);
        assert_eq!(start, 0);
        let with_bytes = ops
            .iter()
            .filter(|o| matches!(o, Op::Attach { bytes: Some(_), .. }))
            .count();
        let key_only = ops
            .iter()
            .filter(|o| matches!(o, Op::Attach { bytes: None, .. }))
            .count();
        assert_eq!((with_bytes, key_only), (1, 1), "payload ships once per key");

        // A fresh follower replays the log in order and converges.
        let follower = ShardedCacheService::new(2);
        for op in ops {
            assert!(follower.apply_op(op), "every op must apply on a gapless replay");
        }
        assert!(follower.lookup("t1", &[sf("a"), sf("b")]).is_hit());
        assert!(follower.lookup("t2", &[sf("x"), sf("y")]).is_hit());
        assert!(follower.has_warm_fork("t1", n1));
        assert_eq!(follower.snapshot_count(), primary.snapshot_count());
        assert_eq!(
            follower.payload_store().payload_count(),
            1,
            "identical bytes must dedup into one payload on the follower too"
        );
        assert_eq!(follower.session_count(), 0, "cursor tables are not replicated");
    }

    // ---- durable WAL + crash recovery (PR 9) ----

    fn wal_cfg(wdir: &Path) -> ServiceConfig {
        ServiceConfig {
            shards: 2,
            wal_dir: Some(wdir.to_path_buf()),
            wal_segment_bytes: 512,
            ..Default::default()
        }
    }

    #[test]
    fn wal_replay_restores_state_across_restart() {
        let wdir = tmpdir("wal-restart");
        let svc = ShardedCacheService::with_config(
            wal_cfg(&wdir),
            Arc::new(TaskCache::with_defaults),
        )
        .unwrap();
        let n = svc.insert("t1", &traj(&["a", "b"])).unwrap();
        let id = svc.store_snapshot("t1", n, snap(48));
        assert!(id > 0);
        svc.set_warm_fork("t1", n, true);
        svc.insert("t2", &traj(&["x"]));
        let seq = svc.oplog().unwrap().next_seq();
        assert_eq!(seq, 4, "insert + attach + warm-fork + insert");
        drop(svc);

        let svc = ShardedCacheService::with_config(
            wal_cfg(&wdir),
            Arc::new(TaskCache::with_defaults),
        )
        .unwrap();
        assert!(svc.lookup("t1", &[sf("a"), sf("b")]).is_hit());
        assert!(svc.lookup("t2", &[sf("x")]).is_hit());
        assert!(svc.has_warm_fork("t1", n));
        assert_eq!(svc.fetch_snapshot("t1", id).unwrap().size(), 48);
        let agg = svc.service_stats();
        assert_eq!(agg.recoveries, 1);
        assert!(agg.wal_appended_bytes > 0);
        assert_eq!(
            svc.oplog().unwrap().next_seq(),
            seq,
            "the log resumes at the recovered sequence, never at 0"
        );
        std::fs::remove_dir_all(&wdir).unwrap();
    }

    #[test]
    fn checkpoint_anchors_recovery_and_advances_retention() {
        let wdir = tmpdir("wal-ckpt");
        let svc = ShardedCacheService::with_config(
            wal_cfg(&wdir),
            Arc::new(TaskCache::with_defaults),
        )
        .unwrap();
        for i in 0..8 {
            svc.insert("t", &traj(&["p", &format!("leaf{i}")])).unwrap();
        }
        let wal_segments_before =
            svc.oplog().unwrap().wal().unwrap().segment_count();
        assert!(wal_segments_before > 1, "512-byte segments must have rotated");
        svc.persist_to_dir(&wdir.join("checkpoint")).unwrap();
        assert_eq!(svc.checkpoint_seq(), 8);
        assert!(
            svc.oplog().unwrap().wal().unwrap().segment_count() < wal_segments_before,
            "a checkpoint must let retention delete sealed segments"
        );
        svc.insert("t", &traj(&["p", "tail"])).unwrap();
        drop(svc);

        // Restart: checkpoint warm-start + WAL replay of the tail.
        let svc = ShardedCacheService::with_config(
            wal_cfg(&wdir),
            Arc::new(TaskCache::with_defaults),
        )
        .unwrap();
        for i in 0..8 {
            assert!(
                svc.lookup("t", &[sf("p"), sf(&format!("leaf{i}"))]).is_hit(),
                "checkpointed leaf{i} must survive"
            );
        }
        assert!(
            svc.lookup("t", &[sf("p"), sf("tail")]).is_hit(),
            "the post-checkpoint tail replays from the WAL"
        );
        assert_eq!(svc.oplog().unwrap().next_seq(), 9);
        assert_eq!(svc.service_stats().recoveries, 1);
        std::fs::remove_dir_all(&wdir).unwrap();
    }

    #[test]
    fn bootstrap_doc_installs_on_a_follower_with_node_ids_preserved() {
        let primary = ShardedCacheService::with_config(
            ServiceConfig { shards: 2, replicate_window: Some(4), ..Default::default() },
            Arc::new(TaskCache::with_defaults),
        )
        .unwrap();
        // Enough history to overflow the tiny window, an eviction to punch
        // a hole in the node-id space, and a snapshot to carry payloads.
        for i in 0..6 {
            primary.insert("t", &traj(&["p", &format!("leaf{i}")])).unwrap();
        }
        let doomed = match primary.lookup("t", &[sf("p"), sf("leaf0")]) {
            Lookup::Hit { node, .. } => node,
            m => panic!("{m:?}"),
        };
        assert!(primary.evict_node("t", doomed));
        let n = primary.insert("t", &traj(&["p", "post-hole"])).unwrap();
        assert!(primary.store_snapshot("t", n, snap(64)) > 0);

        let doc = primary.bootstrap_doc().unwrap();
        let follower = ShardedCacheService::new(2);
        let seq = follower.adopt_bootstrap(&doc).unwrap();
        assert_eq!(seq, primary.oplog().unwrap().next_seq());
        for i in 1..6 {
            assert!(follower.lookup("t", &[sf("p"), sf(&format!("leaf{i}"))]).is_hit());
        }
        assert!(!follower.lookup("t", &[sf("p"), sf("leaf0")]).is_hit());
        assert_eq!(follower.snapshot_count(), 1, "payload adopted with the graph");

        // The proof that ids survived verbatim: ops recorded *after* the
        // bootstrap cut name primary node ids and must replay cleanly.
        primary.set_warm_fork("t", n, true);
        let (start, _, ops) = primary.oplog().unwrap().read_from(seq, 64);
        assert_eq!(start, seq, "no gap at the resume point");
        for op in ops {
            assert!(follower.apply_op(op));
        }
        assert!(follower.has_warm_fork("t", n));

        // A shard-count mismatch must refuse, not corrupt.
        let odd = ShardedCacheService::new(3);
        assert_eq!(odd.adopt_bootstrap(&doc), None);
    }
}
