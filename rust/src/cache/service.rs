//! The in-process sharded cache service (§4.5, Figure 8a).
//!
//! N independent shards, routed by `hash(task_id)`. Each shard owns its own
//! task map **and** its own snapshot store, so there is no global lock
//! anywhere on the lookup *or* the snapshot path: two tasks on different
//! shards never contend, and two tasks on the same shard only share the
//! shard's task-map lock (a read lock in the steady state) and that shard's
//! snapshot-store mutex.
//!
//! Per-shard snapshot stores use a strided id space (shard `i` of `N` hands
//! out ids `i+1, i+1+N, …`), so snapshot ids stay globally unique and
//! `fetch_snapshot` can verify routing.

use std::sync::Arc;

use super::backend::{BackendStats, CacheBackend};
use super::key::{ToolCall, ToolResult};
use super::lpm::Lookup;
use super::shard::{CacheFactory, Shard, ShardRouter};
use super::snapshot::{SnapshotCosts, SnapshotStore};
use super::store::{CacheStats, TaskCache};
use super::tcg::{NodeId, SnapshotRef};
use crate::sandbox::SandboxSnapshot;

/// One shard's state: task map + snapshot byte store.
struct ShardSlot {
    tasks: Shard,
    snapshots: SnapshotStore,
}

/// Task-id-sharded cache service; implements [`CacheBackend`] in-process.
pub struct ShardedCacheService {
    router: ShardRouter,
    shards: Vec<ShardSlot>,
}

impl ShardedCacheService {
    /// `n_shards` shards of default-policy task caches.
    pub fn new(n_shards: usize) -> ShardedCacheService {
        Self::with_factory(n_shards, Arc::new(TaskCache::with_defaults))
    }

    /// `n_shards` shards whose task caches come from `factory`.
    pub fn with_factory(n_shards: usize, factory: CacheFactory) -> ShardedCacheService {
        let n = n_shards.max(1);
        let shards = (0..n)
            .map(|i| ShardSlot {
                tasks: Shard::from_factory(Arc::clone(&factory)),
                snapshots: SnapshotStore::new(i as u64 + 1, n as u64),
            })
            .collect();
        ShardedCacheService { router: ShardRouter::new(n), shards }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    fn slot(&self, task: &str) -> &ShardSlot {
        &self.shards[self.router.route(task)]
    }

    /// The per-task cache (white-box access for tests and the server).
    pub fn task(&self, task: &str) -> Arc<TaskCache> {
        self.slot(task).tasks.task(task)
    }

    /// All task ids across all shards.
    pub fn task_ids(&self) -> Vec<String> {
        let mut ids = Vec::new();
        for s in &self.shards {
            ids.extend(s.tasks.task_ids());
        }
        ids
    }

    pub fn task_count(&self) -> usize {
        self.shards.iter().map(|s| s.tasks.len()).sum()
    }

    /// Stored snapshots across all shards.
    pub fn snapshot_count(&self) -> usize {
        self.shards.iter().map(|s| s.snapshots.len()).sum()
    }

    pub fn snapshot_bytes(&self) -> u64 {
        self.shards.iter().map(|s| s.snapshots.total_bytes()).sum()
    }

    /// Fetch a snapshot by id alone (legacy `/snapshot?id=` fetches that
    /// carry no task). The strided id space makes the owning shard
    /// computable, so this is still a single-store probe.
    pub fn fetch_snapshot_any(&self, id: u64) -> Option<SandboxSnapshot> {
        if id == 0 {
            return None;
        }
        let shard = ((id - 1) % self.shards.len() as u64) as usize;
        self.shards[shard].snapshots.get(id)
    }
}

impl CacheBackend for ShardedCacheService {
    fn lookup(&self, task: &str, q: &[ToolCall]) -> Lookup {
        self.task(task).lookup(q)
    }

    fn insert(&self, task: &str, traj: &[(ToolCall, ToolResult)]) -> NodeId {
        self.task(task).record_trajectory(traj)
    }

    fn release(&self, task: &str, node: NodeId) {
        self.task(task).release(node);
    }

    fn should_snapshot(&self, task: &str, costs: SnapshotCosts) -> bool {
        self.task(task).should_snapshot(costs)
    }

    fn store_snapshot(&self, task: &str, node: NodeId, snap: SandboxSnapshot) -> u64 {
        let slot = self.slot(task);
        let bytes = snap.size();
        let restore_cost = snap.restore_cost;
        let id = slot.snapshots.insert(snap);
        let freed = slot
            .tasks
            .task(task)
            .attach_snapshot(node, SnapshotRef { id, bytes, restore_cost });
        // Eviction decisions and byte reclamation stay within this shard.
        // If the attach itself was rejected (node evicted concurrently) or
        // the budget immediately pruned the new snapshot, its ref is in
        // `freed`: drop the bytes and report failure with id 0.
        let mut attached = true;
        for f in freed {
            if f.id == id {
                attached = false;
            }
            slot.snapshots.remove(f.id);
        }
        if attached {
            id
        } else {
            0
        }
    }

    fn fetch_snapshot(&self, task: &str, id: u64) -> Option<SandboxSnapshot> {
        self.slot(task).snapshots.get(id)
    }

    fn set_warm_fork(&self, task: &str, node: NodeId, warm: bool) {
        self.task(task).set_warm_fork(node, warm);
    }

    fn has_warm_fork(&self, task: &str, node: NodeId) -> bool {
        self.task(task).has_warm_fork(node)
    }

    fn stats(&self, task: &str) -> CacheStats {
        self.task(task).stats()
    }

    fn service_stats(&self) -> BackendStats {
        let mut agg = BackendStats {
            shards: self.shards.len(),
            snapshots: self.snapshot_count(),
            snapshot_bytes: self.snapshot_bytes(),
            ..Default::default()
        };
        for s in &self.shards {
            for id in s.tasks.task_ids() {
                let st = s.tasks.task(&id).stats();
                agg.tasks += 1;
                agg.lookups += st.lookups;
                agg.hits += st.hits;
            }
        }
        agg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sf(s: &str) -> ToolCall {
        ToolCall::new("t", s)
    }

    fn traj(calls: &[&str]) -> Vec<(ToolCall, ToolResult)> {
        calls
            .iter()
            .map(|c| (sf(c), ToolResult::new(format!("out-{c}"), 1.0)))
            .collect()
    }

    fn snap(n: usize) -> SandboxSnapshot {
        SandboxSnapshot { bytes: vec![7u8; n], serialize_cost: 0.1, restore_cost: 0.2 }
    }

    #[test]
    fn routes_tasks_and_isolates_them() {
        let svc = ShardedCacheService::new(4);
        svc.insert("task-a", &traj(&["x", "y"]));
        assert!(svc.lookup("task-a", &[sf("x"), sf("y")]).is_hit());
        assert!(!svc.lookup("task-b", &[sf("x"), sf("y")]).is_hit());
        assert_eq!(svc.task_count(), 2);
        assert_eq!(svc.stats("task-a").hits, 1);
        assert_eq!(svc.stats("task-b").hits, 0);
    }

    #[test]
    fn same_task_maps_to_same_cache() {
        let svc = ShardedCacheService::new(8);
        let a1 = svc.task("t");
        let a2 = svc.task("t");
        assert!(Arc::ptr_eq(&a1, &a2));
    }

    #[test]
    fn snapshot_store_fetch_and_global_id_uniqueness() {
        let svc = ShardedCacheService::new(4);
        let mut ids = std::collections::HashSet::new();
        for i in 0..32 {
            let task = format!("task-{i}");
            let node = svc.insert(&task, &traj(&["a"]));
            let id = svc.store_snapshot(&task, node, snap(10 + i));
            assert!(id >= 1);
            assert!(ids.insert(id), "snapshot id {id} reused across shards");
            let got = svc.fetch_snapshot(&task, id).unwrap();
            assert_eq!(got.size() as usize, 10 + i);
            assert_eq!(svc.fetch_snapshot_any(id).unwrap().size() as usize, 10 + i);
        }
        assert_eq!(svc.snapshot_count(), 32);
        assert!(svc.snapshot_bytes() > 0);
    }

    #[test]
    fn eviction_reclaims_shard_store_bytes() {
        let factory: CacheFactory = Arc::new(|| {
            TaskCache::new(
                crate::cache::LpmConfig::default(),
                crate::cache::SnapshotPolicy::default(),
                crate::cache::EvictionPolicy { max_snapshots: 2, ..Default::default() },
            )
        });
        let svc = ShardedCacheService::with_factory(1, factory);
        for i in 0..5 {
            let node = svc.insert("t", &traj(&["p", &format!("leaf{i}")]));
            svc.store_snapshot("t", node, snap(100));
        }
        // Budget 2 ⇒ 3 evicted; evicted bytes must leave the shard store.
        assert_eq!(svc.snapshot_count(), 2);
        assert_eq!(svc.snapshot_bytes(), 200);
    }

    #[test]
    fn store_snapshot_to_missing_node_returns_zero_and_leaks_nothing() {
        let svc = ShardedCacheService::new(2);
        svc.insert("t", &traj(&["a"]));
        let id = svc.store_snapshot("t", 999, snap(16));
        assert_eq!(id, 0, "attach to a vanished node must report failure");
        assert_eq!(svc.snapshot_count(), 0, "orphaned bytes must be dropped");
    }

    #[test]
    fn resume_offer_pins_until_release() {
        let svc = ShardedCacheService::new(2);
        let node = svc.insert("t", &traj(&["a", "b"]));
        svc.store_snapshot("t", node, snap(8));
        let Lookup::Miss(m) = svc.lookup("t", &[sf("a"), sf("b"), sf("z")]) else {
            panic!("expected miss")
        };
        let (resume, _, _) = m.resume.unwrap();
        assert_eq!(resume, node);
        svc.release("t", resume);
        assert_eq!(svc.stats("t").snapshot_resumes, 1);
    }

    #[test]
    fn warm_fork_roundtrip() {
        let svc = ShardedCacheService::new(3);
        let node = svc.insert("t", &traj(&["a"]));
        assert!(!svc.has_warm_fork("t", node));
        svc.set_warm_fork("t", node, true);
        assert!(svc.has_warm_fork("t", node));
    }

    #[test]
    fn service_stats_aggregate_across_shards() {
        let svc = ShardedCacheService::new(4);
        for i in 0..10 {
            let task = format!("task-{i}");
            svc.insert(&task, &traj(&["a"]));
            assert!(svc.lookup(&task, &[sf("a")]).is_hit());
        }
        let agg = svc.service_stats();
        assert_eq!(agg.shards, 4);
        assert_eq!(agg.tasks, 10);
        assert_eq!(agg.lookups, 10);
        assert_eq!(agg.hits, 10);
    }
}
