//! The in-process sharded cache service (§4.5, Figure 8a).
//!
//! N independent shards, routed by `hash(task_id)`. Each shard owns its own
//! task map **and** its own snapshot store, so there is no global lock
//! anywhere on the lookup *or* the snapshot path: two tasks on different
//! shards never contend, and two tasks on the same shard only share the
//! shard's task-map lock (a read lock in the steady state) and that shard's
//! snapshot-store mutex.
//!
//! Per-shard snapshot stores use a strided id space (shard `i` of `N` hands
//! out ids `i+1, i+1+N, …`), so snapshot ids stay globally unique and
//! `fetch_snapshot` can verify routing.
//!
//! # Snapshot lifecycle (byte budgets, background eviction, spill)
//!
//! [`ServiceConfig`] adds byte-accounted budgets on top of the per-task
//! count budget: a per-shard and a global resident-byte budget. Budgets are
//! enforced *off the hot path* — `store_snapshot` only flags the shard's
//! background worker, which drains the over-budget store by demoting the
//! worst-scoring unpinned snapshots (cost-aware [`EvictionPolicy`] score)
//! either to the disk spill tier (`spill_dir` set — the TCG ref survives
//! and a later resume faults the payload back in) or out of existence.
//! `persist_to_dir`/`warm_start_from_dir` reuse the spill format so a new
//! run reloads the previous run's TCGs + payloads and starts epoch 0 warm.

use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use super::backend::{BackendStats, CacheBackend};
use super::key::{ToolCall, ToolResult};
use super::lpm::{CursorStep, Lookup};
use super::shard::{CacheFactory, Shard, ShardRouter};
use super::snapshot::{SnapshotCosts, SnapshotStore};
use super::spill::{self, SpillStore};
use super::store::{CacheStats, TaskCache};
use super::tcg::{NodeId, SnapshotRef};
use crate::sandbox::SandboxSnapshot;
use crate::util::json::{self, Json};

/// Snapshot-lifecycle configuration for a sharded service.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    pub shards: usize,
    /// Resident-byte budget per shard store (`None` = unbounded).
    pub shard_byte_budget: Option<u64>,
    /// Resident-byte budget across all shards (`None` = unbounded).
    pub global_byte_budget: Option<u64>,
    /// Spill directory: over-budget payloads are demoted to disk here
    /// instead of destroyed. `None` = background eviction destroys.
    pub spill_dir: Option<PathBuf>,
    /// Spawn one background eviction worker per shard. When `false` the
    /// caller drives enforcement with [`ShardedCacheService::drain_over_budget`]
    /// (deterministic; what the property tests use).
    pub background: bool,
    /// Upper bound on live lookup cursors per shard. A `cursor_open` that
    /// finds the table full first sweeps entries idle longer than
    /// [`CURSOR_IDLE_TTL`] (remote rollouts that died without closing),
    /// then refuses (returns 0) if still full — the client transparently
    /// falls back to full-prefix lookups, so this is a memory bound, not
    /// a correctness gate.
    pub max_cursors_per_shard: usize,
}

/// A cursor untouched for this long is presumed abandoned (its rollout
/// died without `/cursor_close`) and may be swept when a shard's cursor
/// table hits [`ServiceConfig::max_cursors_per_shard`].
pub const CURSOR_IDLE_TTL: std::time::Duration = std::time::Duration::from_secs(900);

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            shards: 1,
            shard_byte_budget: None,
            global_byte_budget: None,
            spill_dir: None,
            background: false,
            max_cursors_per_shard: 8192,
        }
    }
}

impl ServiceConfig {
    fn bounded(&self) -> bool {
        self.shard_byte_budget.is_some() || self.global_byte_budget.is_some()
    }
}

/// Wakes a shard's background eviction worker.
struct WorkerSignal {
    state: Mutex<WorkerState>,
    cv: Condvar,
}

#[derive(Default)]
struct WorkerState {
    dirty: bool,
    /// Worker is inside a drain pass (cleared — with a notify — when done).
    busy: bool,
    shutdown: bool,
}

impl WorkerSignal {
    fn new() -> WorkerSignal {
        WorkerSignal { state: Mutex::new(WorkerState::default()), cv: Condvar::new() }
    }

    fn kick(&self) {
        self.state.lock().unwrap().dirty = true;
        self.cv.notify_all();
    }

    fn shutdown(&self) {
        self.state.lock().unwrap().shutdown = true;
        self.cv.notify_all();
    }
}

/// One live lookup cursor: the rollout's pinned TCG position (§3.2 made
/// stateful). `gen` is the task TCG's eviction generation at which `node`
/// was last verified live — eviction of the node flips the next step to
/// `CursorStep::Invalid` instead of ever serving a stale position.
struct CursorEntry {
    cache: Arc<TaskCache>,
    node: NodeId,
    /// Calls consumed so far (= `matched_calls` for the next step's miss).
    steps: usize,
    gen: u64,
    /// Refreshed on every op; drives the abandoned-cursor sweep.
    last_used: std::time::Instant,
}

/// One shard's state: task map + snapshot byte store + cursor table +
/// worker bookkeeping.
struct ShardSlot {
    tasks: Shard,
    snapshots: SnapshotStore,
    /// Live lookup cursors for this shard's tasks. A plain mutex: cursor
    /// ops are O(1) probes and each rollout owns exactly one cursor, so
    /// the hold time is a hash probe plus one TCG child lookup.
    cursors: Mutex<HashMap<u64, CursorEntry>>,
    /// Snapshots the background worker destroyed (detached + dropped).
    bg_evicted: AtomicU64,
    signal: WorkerSignal,
}

/// Task-id-sharded cache service; implements [`CacheBackend`] in-process.
pub struct ShardedCacheService {
    router: ShardRouter,
    shards: Vec<Arc<ShardSlot>>,
    cfg: ServiceConfig,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// Cursor id allocator (0 is the "unsupported/failed" sentinel).
    next_cursor: AtomicU64,
}

impl ShardedCacheService {
    /// `n_shards` shards of default-policy task caches.
    pub fn new(n_shards: usize) -> ShardedCacheService {
        Self::with_factory(n_shards, Arc::new(TaskCache::with_defaults))
    }

    /// `n_shards` shards whose task caches come from `factory` (no byte
    /// budgets, no spill tier, no background workers).
    pub fn with_factory(n_shards: usize, factory: CacheFactory) -> ShardedCacheService {
        Self::with_config(ServiceConfig { shards: n_shards, ..Default::default() }, factory)
            .expect("config without a spill dir cannot fail")
    }

    /// Full snapshot-lifecycle construction. Fails only when the spill
    /// directory cannot be created.
    pub fn with_config(
        cfg: ServiceConfig,
        factory: CacheFactory,
    ) -> std::io::Result<ShardedCacheService> {
        let n = cfg.shards.max(1);
        let spill = match &cfg.spill_dir {
            Some(dir) => Some(Arc::new(SpillStore::open(dir)?)),
            None => None,
        };
        let shards: Vec<Arc<ShardSlot>> = (0..n)
            .map(|i| {
                let snapshots = match &spill {
                    Some(s) => {
                        SnapshotStore::with_spill(i as u64 + 1, n as u64, Arc::clone(s))
                    }
                    None => SnapshotStore::new(i as u64 + 1, n as u64),
                };
                Arc::new(ShardSlot {
                    tasks: Shard::from_factory(Arc::clone(&factory)),
                    snapshots,
                    cursors: Mutex::new(HashMap::new()),
                    bg_evicted: AtomicU64::new(0),
                    signal: WorkerSignal::new(),
                })
            })
            .collect();
        let mut svc = ShardedCacheService {
            router: ShardRouter::new(n),
            shards,
            cfg,
            workers: Vec::new(),
            next_cursor: AtomicU64::new(1),
        };
        if svc.cfg.background && svc.cfg.bounded() {
            svc.spawn_workers();
        }
        Ok(svc)
    }

    fn spawn_workers(&mut self) {
        for (i, slot) in self.shards.iter().enumerate() {
            let slot = Arc::clone(slot);
            let all: Vec<Arc<ShardSlot>> = self.shards.clone();
            let cfg = self.cfg.clone();
            let handle = std::thread::Builder::new()
                .name(format!("tvcache-evict-{i}"))
                .spawn(move || loop {
                    {
                        let mut st = slot.signal.state.lock().unwrap();
                        while !st.dirty && !st.shutdown {
                            st = slot.signal.cv.wait(st).unwrap();
                        }
                        if st.shutdown {
                            break;
                        }
                        st.dirty = false;
                        st.busy = true;
                    }
                    drain_slot(&slot, &all, &cfg);
                    let mut st = slot.signal.state.lock().unwrap();
                    st.busy = false;
                    slot.signal.cv.notify_all();
                })
                .expect("spawn eviction worker");
            self.workers.push(handle);
        }
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    fn slot(&self, task: &str) -> &ShardSlot {
        &self.shards[self.router.route(task)]
    }

    /// The per-task cache (white-box access for tests and the server).
    pub fn task(&self, task: &str) -> Arc<TaskCache> {
        self.slot(task).tasks.task(task)
    }

    /// All task ids across all shards.
    pub fn task_ids(&self) -> Vec<String> {
        let mut ids = Vec::new();
        for s in &self.shards {
            ids.extend(s.tasks.task_ids());
        }
        ids
    }

    pub fn task_count(&self) -> usize {
        self.shards.iter().map(|s| s.tasks.len()).sum()
    }

    /// Stored snapshots across all shards (both tiers).
    pub fn snapshot_count(&self) -> usize {
        self.shards.iter().map(|s| s.snapshots.len()).sum()
    }

    pub fn snapshot_bytes(&self) -> u64 {
        self.shards.iter().map(|s| s.snapshots.total_bytes()).sum()
    }

    /// Bytes held in memory (what the byte budgets bound).
    pub fn resident_bytes(&self) -> u64 {
        self.shards.iter().map(|s| s.snapshots.resident_bytes()).sum()
    }

    /// Snapshots currently demoted to the disk tier.
    pub fn spilled_count(&self) -> usize {
        self.shards.iter().map(|s| s.snapshots.spilled_count()).sum()
    }

    /// Fetch a snapshot by id alone (legacy `/snapshot?id=` fetches that
    /// carry no task). The strided id space makes the owning shard
    /// computable; warm-started ids from a run with a different shard
    /// count may land elsewhere, so a miss falls back to scanning.
    pub fn fetch_snapshot_any(&self, id: u64) -> Option<SandboxSnapshot> {
        if id == 0 {
            return None;
        }
        let shard = ((id - 1) % self.shards.len() as u64) as usize;
        self.shards[shard].snapshots.get(id).or_else(|| {
            self.shards
                .iter()
                .enumerate()
                .filter(|(i, _)| *i != shard)
                .find_map(|(_, s)| s.snapshots.get(id))
        })
    }

    /// Run the background-eviction drain synchronously on every shard
    /// (deterministic; property tests and `background: false` configs).
    pub fn drain_over_budget(&self) {
        for slot in &self.shards {
            drain_slot(slot, &self.shards, &self.cfg);
        }
    }

    /// Block until every background eviction worker is idle with no
    /// pending kick — the point at which TCGs and shard stores are
    /// mutually consistent for white-box inspection.
    pub fn quiesce(&self) {
        if self.workers.is_empty() {
            return;
        }
        for slot in &self.shards {
            let mut st = slot.signal.state.lock().unwrap();
            while st.dirty || st.busy {
                st = slot.signal.cv.wait(st).unwrap();
            }
        }
    }

    /// White-box eviction of one node's snapshot (tests of the resume-offer
    /// eviction race). Returns `true` if a snapshot was detached + dropped.
    pub fn evict_snapshot(&self, task: &str, node: NodeId) -> bool {
        let slot = self.slot(task);
        match slot.tasks.task(task).detach_snapshot_if_unpinned(node) {
            Some(sref) => {
                slot.snapshots.remove(sref.id);
                true
            }
            None => false,
        }
    }

    /// White-box removal of a node's whole subtree (tests of cursor
    /// invalidation): drops the nodes *and* their snapshot bytes, so any
    /// cursor pinned inside the subtree reports `Invalid` on its next step.
    /// Refuses when the subtree is refcount-pinned.
    pub fn evict_node(&self, task: &str, node: NodeId) -> bool {
        let slot = self.slot(task);
        match slot.tasks.task(task).remove_subtree_if_unpinned(node) {
            Some(freed) => {
                for sref in freed {
                    slot.snapshots.remove(sref.id);
                }
                true
            }
            None => false,
        }
    }

    /// Live cursors across all shards (diagnostics; a steady non-zero
    /// count after every rollout finished means leaked cursors).
    pub fn cursor_count(&self) -> usize {
        self.shards.iter().map(|s| s.cursors.lock().unwrap().len()).sum()
    }

    fn kick_if_over_budget(&self, shard: usize) {
        if self.workers.is_empty() {
            return;
        }
        let over_shard = self
            .cfg
            .shard_byte_budget
            .is_some_and(|b| self.shards[shard].snapshots.resident_bytes() > b);
        let over_global =
            self.cfg.global_byte_budget.is_some_and(|b| self.resident_bytes() > b);
        if over_global {
            // Every shard sheds its own worst snapshots.
            for s in &self.shards {
                s.signal.kick();
            }
        } else if over_shard {
            self.shards[shard].signal.kick();
        }
    }

    /// Persist every task's TCG and snapshot payloads under `dir` so a
    /// later run can [`ShardedCacheService::warm_start_from_dir`]. The
    /// payloads reuse the spill format (`snap-<id>.bin` + manifest);
    /// `tcgs.json` is written atomically last.
    pub fn persist_to_dir(&self, dir: &Path) -> std::io::Result<()> {
        let spill = SpillStore::open(dir)?;
        let mut tasks_json = Vec::new();
        for slot in &self.shards {
            let mut ids = slot.tasks.task_ids();
            ids.sort();
            for tid in ids {
                let tc = slot.tasks.task(&tid);
                for (_, sref) in tc.snapshotted_nodes() {
                    // Already spilled into this very directory: the bytes
                    // are in place — append the manifest record only (no
                    // re-read/re-write, no fault-counter pollution).
                    if let Some(s) = slot.snapshots.spilled_slot(sref.id) {
                        if s.path == spill::payload_path(dir, sref.id) {
                            spill.record(&tid, sref.id, &s, sref.restore_cost)?;
                            continue;
                        }
                    }
                    if let Some(snap) = slot.snapshots.get(sref.id) {
                        // The manifest records the ref's original restore
                        // cost — not the fault-penalized one `get` reports.
                        spill.write(&tid, sref.id, &snap, sref.restore_cost)?;
                    }
                }
                tasks_json.push(Json::obj(vec![
                    ("task", Json::str(tid.as_str())),
                    ("tcg", tc.to_persistent_json()),
                ]));
            }
        }
        let doc = Json::obj(vec![("tasks", Json::Arr(tasks_json))]).to_string();
        let tmp = dir.join("tcgs.json.tmp");
        std::fs::write(&tmp, doc)?;
        std::fs::rename(tmp, dir.join("tcgs.json"))
    }

    /// Warm-start: merge a persisted cache state from `dir` into this
    /// service — TCGs are rebuilt per task and snapshot refs re-attached
    /// as *spilled* entries (payloads stay on disk until a resume faults
    /// them in). Only refs whose manifest record and payload file survived
    /// are attached, so a run killed mid-spill recovers consistently.
    /// Returns the number of tasks loaded.
    pub fn warm_start_from_dir(&self, dir: &Path) -> std::io::Result<usize> {
        let records = spill::load_manifest(dir);
        let text = std::fs::read_to_string(dir.join("tcgs.json"))?;
        let doc = json::parse(&text)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
        let Some(tasks) = doc.get("tasks").and_then(Json::as_arr) else {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "tcgs.json missing tasks",
            ));
        };
        let mut loaded = 0usize;
        for entry in tasks {
            let (Some(tid), Some(tcg_json)) =
                (entry.get("task").and_then(Json::as_str), entry.get("tcg"))
            else {
                continue;
            };
            let slot = self.slot(tid);
            let tc = slot.tasks.task(tid);
            // Attach a ref only when its payload survived in the manifest
            // AND the id is not already live in this service's store —
            // warm-starting into a non-empty service must never alias a
            // reloaded ref onto someone else's payload.
            let keep =
                |id: u64| records.contains_key(&id) && !slot.snapshots.contains(id);
            let (attached, ok) = tc.load_persistent_json(tcg_json, &keep);
            // Register every ref that made it onto the TCG — also on a
            // partial (malformed mid-entry) load, so no ref dangles.
            for (_, sref) in attached {
                if let Some(r) = records.get(&sref.id) {
                    slot.snapshots.adopt_spilled(sref.id, r.slot(dir));
                }
            }
            if ok {
                loaded += 1;
            }
        }
        // Future ids must clear every reloaded id, whatever shard count the
        // persisting run used.
        let max_id = records.keys().copied().max().unwrap_or(0);
        for slot in &self.shards {
            slot.snapshots.reserve_through(max_id);
        }
        Ok(loaded)
    }
}

impl Drop for ShardedCacheService {
    fn drop(&mut self) {
        for slot in &self.shards {
            slot.signal.shutdown();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Drain one shard until its (and the global) resident-byte budget holds:
/// repeatedly demote the worst keep-score unpinned resident snapshot —
/// to the spill tier when configured, otherwise detach + destroy. Victim
/// order is deterministic (score, then snapshot id).
///
/// Candidates are deliberately re-scored after every demotion: destroying
/// a snapshot changes the recreation cost (and subtree shape) of its
/// neighbours, so a one-shot sorted list would evict against stale scores.
/// The rescans run on the background worker, off every request path.
fn drain_slot(slot: &ShardSlot, all: &[Arc<ShardSlot>], cfg: &ServiceConfig) {
    let mut skip: HashSet<u64> = HashSet::new();
    loop {
        let over_shard = cfg
            .shard_byte_budget
            .is_some_and(|b| slot.snapshots.resident_bytes() > b);
        let over_global = cfg.global_byte_budget.is_some_and(|b| {
            all.iter().map(|s| s.snapshots.resident_bytes()).sum::<u64>() > b
        });
        if !over_shard && !over_global {
            break;
        }
        let mut task_ids = slot.tasks.task_ids();
        task_ids.sort();
        // (score, cache, task id, node, ref) of the worst keeper so far.
        let mut best = None;
        for tid in &task_ids {
            let tc = slot.tasks.task(tid);
            for (score, node, sref) in tc.eviction_candidates() {
                if skip.contains(&sref.id) || !slot.snapshots.is_resident(sref.id) {
                    continue;
                }
                let better = match &best {
                    None => true,
                    Some((bs, _, _, _, bref)) => {
                        score.total_cmp(bs).then(sref.id.cmp(&bref.id))
                            == std::cmp::Ordering::Less
                    }
                };
                if better {
                    best = Some((score, Arc::clone(&tc), tid.clone(), node, sref));
                }
            }
        }
        let Some((_, tc, tid, node, sref)) = best else {
            break; // everything pinned / spilled / skipped: cannot enforce
        };
        if cfg.spill_dir.is_some() {
            // Demote to disk: the TCG ref stays, resumes fault back in.
            if !slot.snapshots.spill(&tid, sref.id, sref.restore_cost) {
                skip.insert(sref.id);
            }
        } else if tc.detach_snapshot_if_unpinned(node).is_some() {
            slot.snapshots.remove(sref.id);
            slot.bg_evicted.fetch_add(1, Ordering::Relaxed);
        } else {
            skip.insert(sref.id); // pinned since candidate listing
        }
    }
}

impl CacheBackend for ShardedCacheService {
    fn lookup(&self, task: &str, q: &[ToolCall]) -> Lookup {
        self.task(task).lookup(q)
    }

    fn insert(&self, task: &str, traj: &[(ToolCall, ToolResult)]) -> NodeId {
        self.task(task).record_trajectory(traj)
    }

    fn cursor_open(&self, task: &str) -> u64 {
        let slot = self.slot(task);
        let cache = slot.tasks.task(task);
        let gen = cache.eviction_generation();
        let mut cursors = slot.cursors.lock().unwrap();
        if cursors.len() >= self.cfg.max_cursors_per_shard {
            // Sweep cursors whose rollouts died without closing; if the
            // table is still full, refuse — the client falls back to
            // full-prefix lookups for this rollout.
            cursors.retain(|_, e| e.last_used.elapsed() < CURSOR_IDLE_TTL);
            if cursors.len() >= self.cfg.max_cursors_per_shard {
                return 0;
            }
        }
        let id = self.next_cursor.fetch_add(1, Ordering::Relaxed);
        cursors.insert(
            id,
            CursorEntry {
                cache,
                node: super::tcg::ROOT,
                steps: 0,
                gen,
                last_used: std::time::Instant::now(),
            },
        );
        id
    }

    // The cursor ops snapshot the entry under the table mutex, run the TCG
    // operation with the mutex *released* (a task's TCG write-lock stall
    // must not block other tasks' cursors on the same shard), then re-lock
    // briefly to write the advanced position back. A cursor has exactly
    // one owning rollout, so the unlocked window admits no lost update —
    // and an eviction landing in that window is caught by the next step's
    // generation/liveness check, exactly as it would be after the op.

    fn cursor_step(&self, task: &str, cursor: u64, call: &ToolCall) -> CursorStep {
        let slot = self.slot(task);
        let snapshot = {
            let cursors = slot.cursors.lock().unwrap();
            cursors
                .get(&cursor)
                .map(|e| (Arc::clone(&e.cache), e.node, e.steps, e.gen))
        };
        let Some((cache, node, steps, gen)) = snapshot else {
            return CursorStep::Invalid;
        };
        let (step, new_node, new_gen) = cache.cursor_step_at(node, steps, gen, call);
        if !matches!(step, CursorStep::Invalid) {
            // Hit or miss: the call is consumed either way (a miss is
            // executed and then `cursor_record`ed by the caller).
            let mut cursors = slot.cursors.lock().unwrap();
            if let Some(e) = cursors.get_mut(&cursor) {
                e.node = new_node;
                e.gen = new_gen;
                e.steps = steps + 1;
                e.last_used = std::time::Instant::now();
            }
        }
        step
    }

    fn cursor_record(
        &self,
        task: &str,
        cursor: u64,
        call: &ToolCall,
        result: &ToolResult,
    ) -> NodeId {
        let slot = self.slot(task);
        let snapshot = {
            let cursors = slot.cursors.lock().unwrap();
            cursors.get(&cursor).map(|e| (Arc::clone(&e.cache), e.node))
        };
        let Some((cache, node)) = snapshot else {
            return 0;
        };
        match cache.cursor_record_at(node, call, result) {
            Some((new_node, gen)) => {
                let mut cursors = slot.cursors.lock().unwrap();
                if let Some(e) = cursors.get_mut(&cursor) {
                    e.node = new_node;
                    e.gen = gen;
                    e.last_used = std::time::Instant::now();
                }
                new_node
            }
            None => 0,
        }
    }

    fn cursor_seek(&self, task: &str, cursor: u64, node: NodeId, steps: usize) -> bool {
        let slot = self.slot(task);
        let snapshot = {
            let cursors = slot.cursors.lock().unwrap();
            cursors.get(&cursor).map(|e| Arc::clone(&e.cache))
        };
        let Some(cache) = snapshot else {
            return false;
        };
        match cache.cursor_seek_check(node) {
            Some(gen) => {
                let mut cursors = slot.cursors.lock().unwrap();
                match cursors.get_mut(&cursor) {
                    Some(e) => {
                        e.node = node;
                        e.steps = steps;
                        e.gen = gen;
                        e.last_used = std::time::Instant::now();
                        true
                    }
                    None => false, // closed concurrently
                }
            }
            None => false,
        }
    }

    fn cursor_close(&self, task: &str, cursor: u64) {
        self.slot(task).cursors.lock().unwrap().remove(&cursor);
    }

    fn release(&self, task: &str, node: NodeId) {
        self.task(task).release(node);
    }

    fn should_snapshot(&self, task: &str, costs: SnapshotCosts) -> bool {
        self.task(task).should_snapshot(costs)
    }

    fn store_snapshot(&self, task: &str, node: NodeId, snap: SandboxSnapshot) -> u64 {
        let shard = self.router.route(task);
        let slot = &self.shards[shard];
        let bytes = snap.size();
        let restore_cost = snap.restore_cost;
        let id = slot.snapshots.insert(snap);
        let freed = slot
            .tasks
            .task(task)
            .attach_snapshot(node, SnapshotRef { id, bytes, restore_cost });
        // Eviction decisions and byte reclamation stay within this shard.
        // If the attach itself was rejected (node evicted concurrently) or
        // the budget immediately pruned the new snapshot, its ref is in
        // `freed`: drop the bytes and report failure with id 0.
        let mut attached = true;
        for f in freed {
            if f.id == id {
                attached = false;
            }
            slot.snapshots.remove(f.id);
        }
        if attached {
            // Byte budgets are enforced off this hot path: flag the
            // background worker and return immediately.
            self.kick_if_over_budget(shard);
            id
        } else {
            0
        }
    }

    fn fetch_snapshot(&self, task: &str, id: u64) -> Option<SandboxSnapshot> {
        self.slot(task).snapshots.get(id)
    }

    fn set_warm_fork(&self, task: &str, node: NodeId, warm: bool) {
        self.task(task).set_warm_fork(node, warm);
    }

    fn has_warm_fork(&self, task: &str, node: NodeId) -> bool {
        self.task(task).has_warm_fork(node)
    }

    fn stats(&self, task: &str) -> CacheStats {
        self.task(task).stats()
    }

    fn service_stats(&self) -> BackendStats {
        let mut agg = BackendStats {
            shards: self.shards.len(),
            snapshots: self.snapshot_count(),
            snapshot_bytes: self.snapshot_bytes(),
            ..Default::default()
        };
        for s in &self.shards {
            agg.spilled_snapshots += s.snapshots.spilled_count();
            agg.spilled_bytes += s.snapshots.spilled_bytes();
            agg.spills += s.snapshots.spill_count();
            agg.spill_faults += s.snapshots.fault_count();
            agg.bg_evictions += s.bg_evicted.load(Ordering::Relaxed);
            for id in s.tasks.task_ids() {
                let st = s.tasks.task(&id).stats();
                agg.tasks += 1;
                agg.lookups += st.lookups;
                agg.hits += st.hits;
            }
        }
        agg
    }

    fn persist(&self, dir: &str) -> bool {
        self.persist_to_dir(Path::new(dir)).is_ok()
    }

    fn warm_start(&self, dir: &str) -> bool {
        self.warm_start_from_dir(Path::new(dir)).is_ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sf(s: &str) -> ToolCall {
        ToolCall::new("t", s)
    }

    fn traj(calls: &[&str]) -> Vec<(ToolCall, ToolResult)> {
        calls
            .iter()
            .map(|c| (sf(c), ToolResult::new(format!("out-{c}"), 1.0)))
            .collect()
    }

    fn snap(n: usize) -> SandboxSnapshot {
        SandboxSnapshot { bytes: vec![7u8; n], serialize_cost: 0.1, restore_cost: 0.2 }
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("tvcache-svc-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn routes_tasks_and_isolates_them() {
        let svc = ShardedCacheService::new(4);
        svc.insert("task-a", &traj(&["x", "y"]));
        assert!(svc.lookup("task-a", &[sf("x"), sf("y")]).is_hit());
        assert!(!svc.lookup("task-b", &[sf("x"), sf("y")]).is_hit());
        assert_eq!(svc.task_count(), 2);
        assert_eq!(svc.stats("task-a").hits, 1);
        assert_eq!(svc.stats("task-b").hits, 0);
    }

    #[test]
    fn same_task_maps_to_same_cache() {
        let svc = ShardedCacheService::new(8);
        let a1 = svc.task("t");
        let a2 = svc.task("t");
        assert!(Arc::ptr_eq(&a1, &a2));
    }

    #[test]
    fn snapshot_store_fetch_and_global_id_uniqueness() {
        let svc = ShardedCacheService::new(4);
        let mut ids = std::collections::HashSet::new();
        for i in 0..32 {
            let task = format!("task-{i}");
            let node = svc.insert(&task, &traj(&["a"]));
            let id = svc.store_snapshot(&task, node, snap(10 + i));
            assert!(id >= 1);
            assert!(ids.insert(id), "snapshot id {id} reused across shards");
            let got = svc.fetch_snapshot(&task, id).unwrap();
            assert_eq!(got.size() as usize, 10 + i);
            assert_eq!(svc.fetch_snapshot_any(id).unwrap().size() as usize, 10 + i);
        }
        assert_eq!(svc.snapshot_count(), 32);
        assert!(svc.snapshot_bytes() > 0);
    }

    #[test]
    fn eviction_reclaims_shard_store_bytes() {
        let factory: CacheFactory = Arc::new(|| {
            TaskCache::new(
                crate::cache::LpmConfig::default(),
                crate::cache::SnapshotPolicy::default(),
                crate::cache::EvictionPolicy { max_snapshots: 2, ..Default::default() },
            )
        });
        let svc = ShardedCacheService::with_factory(1, factory);
        for i in 0..5 {
            let node = svc.insert("t", &traj(&["p", &format!("leaf{i}")]));
            svc.store_snapshot("t", node, snap(100));
        }
        // Budget 2 ⇒ 3 evicted; evicted bytes must leave the shard store.
        assert_eq!(svc.snapshot_count(), 2);
        assert_eq!(svc.snapshot_bytes(), 200);
    }

    #[test]
    fn store_snapshot_to_missing_node_returns_zero_and_leaks_nothing() {
        let svc = ShardedCacheService::new(2);
        svc.insert("t", &traj(&["a"]));
        let id = svc.store_snapshot("t", 999, snap(16));
        assert_eq!(id, 0, "attach to a vanished node must report failure");
        assert_eq!(svc.snapshot_count(), 0, "orphaned bytes must be dropped");
    }

    #[test]
    fn resume_offer_pins_until_release() {
        let svc = ShardedCacheService::new(2);
        let node = svc.insert("t", &traj(&["a", "b"]));
        svc.store_snapshot("t", node, snap(8));
        let Lookup::Miss(m) = svc.lookup("t", &[sf("a"), sf("b"), sf("z")]) else {
            panic!("expected miss")
        };
        let (resume, _, _) = m.resume.unwrap();
        assert_eq!(resume, node);
        svc.release("t", resume);
        assert_eq!(svc.stats("t").snapshot_resumes, 1);
    }

    #[test]
    fn warm_fork_roundtrip() {
        let svc = ShardedCacheService::new(3);
        let node = svc.insert("t", &traj(&["a"]));
        assert!(!svc.has_warm_fork("t", node));
        svc.set_warm_fork("t", node, true);
        assert!(svc.has_warm_fork("t", node));
    }

    #[test]
    fn service_stats_aggregate_across_shards() {
        let svc = ShardedCacheService::new(4);
        for i in 0..10 {
            let task = format!("task-{i}");
            svc.insert(&task, &traj(&["a"]));
            assert!(svc.lookup(&task, &[sf("a")]).is_hit());
        }
        let agg = svc.service_stats();
        assert_eq!(agg.shards, 4);
        assert_eq!(agg.tasks, 10);
        assert_eq!(agg.lookups, 10);
        assert_eq!(agg.hits, 10);
    }

    #[test]
    fn over_budget_drain_spills_worst_snapshots_and_resumes_fault_in() {
        let dir = tmpdir("drain-spill");
        let cfg = ServiceConfig {
            shards: 1,
            shard_byte_budget: Some(250),
            spill_dir: Some(dir.clone()),
            background: false, // deterministic: drained by hand
            ..Default::default()
        };
        let svc = ShardedCacheService::with_config(cfg, Arc::new(TaskCache::with_defaults))
            .unwrap();
        let mut nodes = Vec::new();
        for i in 0..5 {
            let node = svc.insert("t", &traj(&["p", &format!("leaf{i}")]));
            assert!(svc.store_snapshot("t", node, snap(100)) > 0);
            nodes.push(node);
        }
        assert_eq!(svc.resident_bytes(), 500);
        svc.drain_over_budget();
        assert!(svc.resident_bytes() <= 250, "{}", svc.resident_bytes());
        // Nothing destroyed: all five remain stored, three on disk.
        assert_eq!(svc.snapshot_count(), 5);
        assert_eq!(svc.spilled_count(), 3);
        assert_eq!(svc.snapshot_bytes(), 500);
        // Every snapshot — resident or spilled — still fetches.
        for (node, _) in svc.task("t").snapshotted_nodes() {
            let leaf = nodes.iter().position(|&n| n == node).unwrap();
            let q = [sf("p"), sf(&format!("leaf{leaf}")), sf("zz")];
            let Lookup::Miss(m) = svc.lookup("t", &q) else {
                panic!("expected miss")
            };
            let (rnode, sref, _) = m.resume.expect("spilled node still offers resume");
            assert_eq!(rnode, node);
            assert!(svc.fetch_snapshot("t", sref.id).is_some(), "fault-in failed");
            svc.release("t", rnode);
        }
        let agg = svc.service_stats();
        assert_eq!(agg.spills, 3);
        assert!(agg.spill_faults >= 3);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn background_worker_drains_without_blocking_insert() {
        let dir = tmpdir("bg");
        let cfg = ServiceConfig {
            shards: 2,
            shard_byte_budget: Some(300),
            spill_dir: Some(dir.clone()),
            background: true,
            ..Default::default()
        };
        let svc = Arc::new(
            ShardedCacheService::with_config(cfg, Arc::new(TaskCache::with_defaults))
                .unwrap(),
        );
        for i in 0..24 {
            let task = format!("task-{i}");
            let node = svc.insert(&task, &traj(&["a", "b"]));
            svc.store_snapshot(&task, node, snap(100));
        }
        // The worker runs asynchronously; wait for it to go idle, then
        // verify the budget converged without losing any snapshot.
        svc.quiesce();
        for s in &svc.shards {
            assert!(
                s.snapshots.resident_bytes() <= 300,
                "worker failed to drain shard below budget"
            );
        }
        assert_eq!(svc.snapshot_count(), 24, "spill must not destroy snapshots");
        drop(svc); // Drop joins the workers: must not hang.
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn destroy_eviction_when_no_spill_dir() {
        let cfg = ServiceConfig {
            shards: 1,
            shard_byte_budget: Some(150),
            ..Default::default()
        };
        let svc = ShardedCacheService::with_config(cfg, Arc::new(TaskCache::with_defaults))
            .unwrap();
        for i in 0..4 {
            let node = svc.insert("t", &traj(&["p", &format!("leaf{i}")]));
            svc.store_snapshot("t", node, snap(100));
        }
        svc.drain_over_budget();
        assert!(svc.resident_bytes() <= 150);
        assert_eq!(svc.spilled_count(), 0);
        assert!(svc.snapshot_count() <= 1);
        assert!(svc.service_stats().bg_evictions >= 3);
    }

    #[test]
    fn global_budget_drains_across_shards() {
        let cfg = ServiceConfig {
            shards: 4,
            global_byte_budget: Some(350),
            ..Default::default()
        };
        let svc = ShardedCacheService::with_config(cfg, Arc::new(TaskCache::with_defaults))
            .unwrap();
        for i in 0..8 {
            let task = format!("task-{i}");
            let node = svc.insert(&task, &traj(&["a"]));
            svc.store_snapshot(&task, node, snap(100));
        }
        assert_eq!(svc.resident_bytes(), 800);
        svc.drain_over_budget();
        assert!(svc.resident_bytes() <= 350, "{}", svc.resident_bytes());
    }

    #[test]
    fn persist_and_warm_start_roundtrip() {
        let dir = tmpdir("persist");
        let svc = ShardedCacheService::new(4);
        let node = svc.insert("t1", &traj(&["a", "b"]));
        let id = svc.store_snapshot("t1", node, snap(64));
        svc.insert("t2", &traj(&["x"]));
        assert!(svc.lookup("t1", &[sf("a"), sf("b")]).is_hit());
        svc.persist_to_dir(&dir).unwrap();

        // A fresh service — different shard count on purpose — warm-starts.
        let fresh = ShardedCacheService::new(2);
        assert_eq!(fresh.warm_start_from_dir(&dir).unwrap(), 2);
        assert!(fresh.lookup("t1", &[sf("a"), sf("b")]).is_hit());
        assert!(fresh.lookup("t2", &[sf("x")]).is_hit());
        // The snapshot ref survived as a spilled entry and faults in.
        let got = fresh.fetch_snapshot("t1", id).expect("payload reloads from disk");
        assert_eq!(got.size(), 64);
        assert_eq!(fresh.fetch_snapshot_any(id).unwrap().size(), 64);
        // New snapshot ids never collide with reloaded ones.
        let n2 = fresh.insert("t9", &traj(&["q"]));
        let id2 = fresh.store_snapshot("t9", n2, snap(8));
        assert!(id2 > id, "fresh id {id2} collides with reloaded space ≤ {id}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn warm_start_missing_dir_fails_cleanly() {
        let svc = ShardedCacheService::new(2);
        assert!(!CacheBackend::warm_start(&svc, "/nonexistent/tvcache-warmstart"));
    }

    // ---- stateful lookup cursors ----

    #[test]
    fn cursor_walk_hits_recorded_chain_and_stats_match_legacy() {
        let svc = ShardedCacheService::new(4);
        svc.insert("t", &traj(&["a", "b", "c"]));
        let cur = svc.cursor_open("t");
        assert!(cur != 0);
        for (i, c) in ["a", "b", "c"].iter().enumerate() {
            match svc.cursor_step("t", cur, &sf(c)) {
                crate::cache::CursorStep::Hit { result, .. } => {
                    assert_eq!(result.output, format!("out-{c}"), "step {i}");
                }
                s => panic!("step {i}: {s:?}"),
            }
        }
        svc.cursor_close("t", cur);
        assert_eq!(svc.cursor_count(), 0, "close must drop the table entry");
        let stats = svc.stats("t");
        assert_eq!(stats.lookups, 3);
        assert_eq!(stats.hits, 3);
    }

    #[test]
    fn cursor_miss_record_extends_graph_like_full_insert() {
        let svc = ShardedCacheService::new(2);
        let cur = svc.cursor_open("t");
        let mut node = 0;
        for c in ["x", "y", "z"] {
            let call = sf(c);
            match svc.cursor_step("t", cur, &call) {
                crate::cache::CursorStep::Miss(_) => {}
                s => panic!("cold cache must miss: {s:?}"),
            }
            node = svc.cursor_record("t", cur, &call, &ToolResult::new(format!("out-{c}"), 1.0));
            assert!(node != 0, "record at a live cursor must succeed");
        }
        // The incrementally recorded chain equals a full insert.
        assert_eq!(svc.insert("t", &traj(&["x", "y", "z"])), node);
        assert!(svc.lookup("t", &[sf("x"), sf("y"), sf("z")]).is_hit());
        assert_eq!(svc.stats("t").inserts, 3);
    }

    #[test]
    fn cursor_miss_pins_resume_until_release() {
        let svc = ShardedCacheService::new(2);
        let node = svc.insert("t", &traj(&["a", "b"]));
        svc.store_snapshot("t", node, snap(8));
        let cur = svc.cursor_open("t");
        assert!(svc.cursor_step("t", cur, &sf("a")).is_hit());
        assert!(svc.cursor_step("t", cur, &sf("b")).is_hit());
        let crate::cache::CursorStep::Miss(m) = svc.cursor_step("t", cur, &sf("zz")) else {
            panic!("divergent step must miss")
        };
        let (rnode, _, replay_from) = m.resume.expect("snapshot offered");
        assert_eq!((rnode, replay_from), (node, 2));
        assert_eq!(m.matched_calls, 2);
        assert_eq!(svc.task("t").pinned_node_count(), 1, "offer must pin");
        svc.release("t", rnode);
        assert_eq!(svc.task("t").pinned_node_count(), 0);
    }

    #[test]
    fn evicted_cursor_node_invalidates_then_seek_recovers() {
        let svc = ShardedCacheService::new(2);
        svc.insert("t", &traj(&["a", "b"]));
        let cur = svc.cursor_open("t");
        assert!(svc.cursor_step("t", cur, &sf("a")).is_hit());
        assert!(svc.cursor_step("t", cur, &sf("b")).is_hit());
        // Evict the subtree the cursor sits in (node of "b" = depth 2).
        let b = match svc.lookup("t", &[sf("a"), sf("b")]) {
            Lookup::Hit { node, .. } => node,
            m => panic!("{m:?}"),
        };
        assert!(svc.evict_node("t", b));
        assert_eq!(
            svc.cursor_step("t", cur, &sf("c")),
            crate::cache::CursorStep::Invalid,
            "a step at an evicted node must invalidate, never serve stale state"
        );
        // Seek to a live ancestor re-arms the cursor.
        let a = match svc.lookup("t", &[sf("a")]) {
            Lookup::Hit { node, .. } => node,
            m => panic!("{m:?}"),
        };
        assert!(svc.cursor_seek("t", cur, a, 1));
        assert!(matches!(
            svc.cursor_step("t", cur, &sf("c")),
            crate::cache::CursorStep::Miss(_)
        ));
        // Seeking to the dead node fails.
        assert!(!svc.cursor_seek("t", cur, b, 2));
    }

    #[test]
    fn cursor_table_cap_refuses_new_cursors_when_full() {
        let cfg = ServiceConfig { shards: 1, max_cursors_per_shard: 2, ..Default::default() };
        let svc = ShardedCacheService::with_config(cfg, Arc::new(TaskCache::with_defaults))
            .unwrap();
        let a = svc.cursor_open("t");
        let b = svc.cursor_open("t");
        assert!(a != 0 && b != 0);
        // Fresh (recently used) cursors are never swept: the table is full,
        // so the next open refuses and the client falls back to full-prefix
        // lookups.
        assert_eq!(svc.cursor_open("t"), 0);
        svc.cursor_close("t", a);
        assert!(svc.cursor_open("t") != 0, "freed capacity must be reusable");
    }

    #[test]
    fn unknown_cursor_ids_are_safe() {
        let svc = ShardedCacheService::new(2);
        svc.insert("t", &traj(&["a"]));
        assert_eq!(svc.cursor_step("t", 999, &sf("a")), crate::cache::CursorStep::Invalid);
        assert_eq!(svc.cursor_record("t", 999, &sf("a"), &ToolResult::new("r", 1.0)), 0);
        assert!(!svc.cursor_seek("t", 999, 1, 1));
        svc.cursor_close("t", 999); // no-op, no panic
    }
}
