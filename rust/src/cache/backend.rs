//! The cache access surface for TVCACHE: the narrow per-call
//! [`CacheBackend`] core plus the [`SessionBackend`] extension (capability
//! negotiation, stateful lookup cursors, turn-level batched ops).
//!
//! Everything that talks to the cache — the `ToolCallExecutor` (through
//! its owned `RolloutSession`), the HTTP server handlers, the simulated
//! and concurrent training loops, and the figure benches — programs
//! against these traits. Two implementations ship:
//!
//! * [`super::ShardedCacheService`] — in-process, task-id-sharded (§4.5):
//!   N independent shards, each owning its own task map *and* its own
//!   snapshot store, so no lock is global.
//! * [`crate::client::RemoteBinding`] — the HTTP wire binding to a TVCACHE
//!   server (which itself fronts a `ShardedCacheService`).
//!
//! Every method takes the task id: per §3.1 each task has an independent
//! TCG, and the task id is what the shard router hashes (Figure 8a).

use super::key::{ToolCall, ToolResult};
use super::lpm::{CursorStep, Lookup};
use super::snapshot::SnapshotCosts;
use super::store::CacheStats;
use super::tcg::NodeId;
use crate::sandbox::SandboxSnapshot;
use crate::util::json::Json;

/// Service-wide aggregate statistics (all tasks, all shards), including the
/// snapshot-lifecycle counters: spill-tier occupancy, disk fault-ins, and
/// background evictions.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct BackendStats {
    pub shards: usize,
    pub tasks: usize,
    pub lookups: u64,
    pub hits: u64,
    /// Stored snapshots across both tiers (resident + spilled).
    pub snapshots: usize,
    /// Bytes across both tiers.
    pub snapshot_bytes: u64,
    /// Snapshots currently demoted to the disk spill tier.
    pub spilled_snapshots: usize,
    pub spilled_bytes: u64,
    /// Lifetime demotions to disk.
    pub spills: u64,
    /// Lifetime fault-ins from disk.
    pub spill_faults: u64,
    /// Snapshots the background worker destroyed (no spill tier).
    pub bg_evictions: u64,
    /// Inserts (or warm-start adopts) whose content key was already stored
    /// — the payload tier served a shared copy instead of a new one.
    pub dedup_hits: u64,
    /// Resident bytes the content-addressed payload tier is currently
    /// saving: Σ over resident payloads of `len × (referents − 1)`.
    pub dedup_resident_bytes_saved: u64,
    /// Spill fault-ins served from the LRU fault cache (no disk read).
    pub fault_cache_hits: u64,
    /// Spill fault-ins that had to read the payload from disk.
    pub fault_cache_misses: u64,
    /// Fault-cache entries evicted to stay under its byte budget.
    pub fault_cache_evictions: u64,
    /// Remote transport retries (client-side; 0 for in-process backends).
    pub remote_retries: u64,
    /// Circuit-breaker trips: closed/half-open → open transitions.
    pub breaker_opens: u64,
    /// Circuit-breaker recovery probes: open → half-open transitions.
    pub breaker_half_opens: u64,
    /// Circuit-breaker recoveries: half-open/open → closed transitions.
    pub breaker_closes: u64,
    /// The spill tier hit a disk I/O error and demoted itself to
    /// resident-only mode (spilling disabled for the process lifetime).
    pub spill_degraded: bool,
    /// Faults injected by the deterministic fault harness (0 outside
    /// fault-injection runs).
    pub injected_faults: u64,
    /// Client-side endpoint failovers: breaker-open → promote-a-follower
    /// transitions (0 for in-process backends and single-endpoint
    /// bindings).
    pub failovers: u64,
    /// Answers (or promotion offers) rejected by the epoch fence because
    /// they carried an epoch below the highest one this client has seen —
    /// the split-brain guard firing against a revived stale primary.
    pub epoch_rejects: u64,
    /// How many ops this server still trails its primary by (0 on a
    /// primary; grows without bound on a follower that froze on a
    /// replication gap).
    pub replica_lag_ops: u64,
    /// The server's fencing epoch (1 for a fresh primary; promotion bumps
    /// past every epoch the old primary could have stamped).
    pub epoch: u64,
    /// Ops appended to the in-memory op-log (and, when a WAL is attached,
    /// to the durable log — the two never diverge by construction).
    pub oplog_appended: u64,
    /// Response bytes shipped over `/replicate` to tailing followers.
    pub replicate_bytes_shipped: u64,
    /// WAL segment files currently on disk (0 without `--wal-dir`).
    pub wal_segments: u64,
    /// Group fsyncs the WAL flusher has issued.
    pub wal_fsyncs: u64,
    /// Lifetime bytes framed into the WAL (record payloads + headers).
    pub wal_appended_bytes: u64,
    /// Whether the WAL tripped into sticky degraded mode (a write fault):
    /// the service keeps serving, but appends stopped reaching disk.
    pub wal_degraded: bool,
    /// Crash recoveries this process performed at startup: checkpoint
    /// warm-starts plus WAL replays that restored at least one op.
    pub recoveries: u64,
}

impl BackendStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shards", Json::num(self.shards as f64)),
            ("tasks", Json::num(self.tasks as f64)),
            ("lookups", Json::num(self.lookups as f64)),
            ("hits", Json::num(self.hits as f64)),
            ("snapshots", Json::num(self.snapshots as f64)),
            ("snapshot_bytes", Json::num(self.snapshot_bytes as f64)),
            ("spilled_snapshots", Json::num(self.spilled_snapshots as f64)),
            ("spilled_bytes", Json::num(self.spilled_bytes as f64)),
            ("spills", Json::num(self.spills as f64)),
            ("spill_faults", Json::num(self.spill_faults as f64)),
            ("bg_evictions", Json::num(self.bg_evictions as f64)),
            // Payload-tier counters (PR 5) — appended after the PR 4
            // fields, so position-insensitive JSON readers see the same
            // layout they always did.
            ("dedup_hits", Json::num(self.dedup_hits as f64)),
            (
                "dedup_resident_bytes_saved",
                Json::num(self.dedup_resident_bytes_saved as f64),
            ),
            ("fault_cache_hits", Json::num(self.fault_cache_hits as f64)),
            ("fault_cache_misses", Json::num(self.fault_cache_misses as f64)),
            ("fault_cache_evictions", Json::num(self.fault_cache_evictions as f64)),
            // Degradation counters (PR 7) — appended last, same
            // position-insensitive compatibility contract as above.
            ("remote_retries", Json::num(self.remote_retries as f64)),
            ("breaker_opens", Json::num(self.breaker_opens as f64)),
            ("breaker_half_opens", Json::num(self.breaker_half_opens as f64)),
            ("breaker_closes", Json::num(self.breaker_closes as f64)),
            ("spill_degraded", Json::Bool(self.spill_degraded)),
            ("injected_faults", Json::num(self.injected_faults as f64)),
            // Replication + failover counters (PR 8) — appended last,
            // same position-insensitive compatibility contract as above.
            ("failovers", Json::num(self.failovers as f64)),
            ("epoch_rejects", Json::num(self.epoch_rejects as f64)),
            ("replica_lag_ops", Json::num(self.replica_lag_ops as f64)),
            ("epoch", Json::num(self.epoch as f64)),
            // Durability counters (PR 9) — appended last, same
            // position-insensitive compatibility contract as above.
            ("oplog_appended", Json::num(self.oplog_appended as f64)),
            (
                "replicate_bytes_shipped",
                Json::num(self.replicate_bytes_shipped as f64),
            ),
            ("wal_segments", Json::num(self.wal_segments as f64)),
            ("wal_fsyncs", Json::num(self.wal_fsyncs as f64)),
            ("wal_appended_bytes", Json::num(self.wal_appended_bytes as f64)),
            ("wal_degraded", Json::Bool(self.wal_degraded)),
            ("recoveries", Json::num(self.recoveries as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Option<BackendStats> {
        // Sentinel key: an arbitrary 200 JSON body must not parse as an
        // all-zero (idle-looking) stats object.
        v.get("shards")?;
        let g = |k: &str| v.get(k).and_then(Json::as_u64).unwrap_or(0);
        Some(BackendStats {
            shards: g("shards") as usize,
            tasks: g("tasks") as usize,
            lookups: g("lookups"),
            hits: g("hits"),
            snapshots: g("snapshots") as usize,
            snapshot_bytes: g("snapshot_bytes"),
            spilled_snapshots: g("spilled_snapshots") as usize,
            spilled_bytes: g("spilled_bytes"),
            spills: g("spills"),
            spill_faults: g("spill_faults"),
            bg_evictions: g("bg_evictions"),
            // Absent on pre-payload-tier servers: `unwrap_or(0)` keeps the
            // parse backward compatible.
            dedup_hits: g("dedup_hits"),
            dedup_resident_bytes_saved: g("dedup_resident_bytes_saved"),
            fault_cache_hits: g("fault_cache_hits"),
            fault_cache_misses: g("fault_cache_misses"),
            fault_cache_evictions: g("fault_cache_evictions"),
            // Absent on pre-degradation-layer servers.
            remote_retries: g("remote_retries"),
            breaker_opens: g("breaker_opens"),
            breaker_half_opens: g("breaker_half_opens"),
            breaker_closes: g("breaker_closes"),
            spill_degraded: v
                .get("spill_degraded")
                .and_then(Json::as_bool)
                .unwrap_or(false),
            injected_faults: g("injected_faults"),
            // Absent on pre-replication servers.
            failovers: g("failovers"),
            epoch_rejects: g("epoch_rejects"),
            replica_lag_ops: g("replica_lag_ops"),
            epoch: g("epoch"),
            // Absent on pre-WAL servers.
            oplog_appended: g("oplog_appended"),
            replicate_bytes_shipped: g("replicate_bytes_shipped"),
            wal_segments: g("wal_segments"),
            wal_fsyncs: g("wal_fsyncs"),
            wal_appended_bytes: g("wal_appended_bytes"),
            wal_degraded: v.get("wal_degraded").and_then(Json::as_bool).unwrap_or(false),
            recoveries: g("recoveries"),
        })
    }
}

/// Capability set a backend advertises (the `/capabilities` handshake).
///
/// Negotiated **once** — at session open for the HTTP binding, statically
/// for the in-process service — instead of magic-byte sniffing or
/// try-and-fall-back probing on every request. A backend that advertises
/// nothing (the default for decorators and legacy servers that 404 the
/// handshake) keeps every caller on the per-call full-prefix path, which
/// every [`CacheBackend`] supports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Capabilities {
    /// Understands the binary wire codec on the hot endpoints.
    pub binary: bool,
    /// Supports stateful lookup cursors (`cursor_open` returns real ids).
    pub cursors: bool,
    /// Supports turn-level batched ops (`session_turn`, `/session_turn`).
    pub turn_batch: bool,
    /// Runs the content-addressed payload tier (cross-task snapshot dedup
    /// + spill fault cache) and reports its counters in `/stats`.
    pub payload_dedup: bool,
}

impl Capabilities {
    /// Protocol generation carried by the handshake frames.
    pub const PROTO_V2: u64 = 2;

    /// Everything this codebase implements (the v2 server / in-process
    /// service).
    pub const V2: Capabilities = Capabilities {
        binary: true,
        cursors: true,
        turn_batch: true,
        payload_dedup: true,
    };

    /// What a pre-handshake server is assumed to speak when `/capabilities`
    /// fails: binary + cursors existed before negotiation (magic-byte
    /// sniffed), turn batching and the payload tier did not.
    pub const LEGACY: Capabilities = Capabilities {
        binary: true,
        cursors: true,
        turn_batch: false,
        payload_dedup: false,
    };

    /// A backend that only implements the narrow [`CacheBackend`] core.
    pub const CORE: Capabilities = Capabilities {
        binary: false,
        cursors: false,
        turn_batch: false,
        payload_dedup: false,
    };
}

/// The stateful half of a [`TurnBatch`]: at most one cursor step *or*
/// record per turn frame (a record's result is only known after client-side
/// execution, so a single turn can never carry both for the same call).
#[derive(Debug, Clone, PartialEq)]
pub enum TurnOp {
    /// Probe-only frame (no stateful op this turn).
    None,
    /// Incremental lookup of the turn's delta call (`cursor_step`).
    Step(ToolCall),
    /// Record the executed delta and advance (`cursor_record`).
    Record(ToolCall, ToolResult),
}

/// One reasoning turn's batched cache traffic: several speculative
/// *stateless* probes plus at most one stateful step/record, shipped as a
/// single `/session_turn` wire frame instead of N per-call round trips.
///
/// Probes are evaluated at the session's position *after* the op applies
/// and never advance the cursor, touch statistics, or pin resume offers —
/// they are pure hints. An unanswered probe (backend without native
/// batching) simply means the later real call does its own lookup, so
/// hit/miss decisions are identical with probes on or off.
#[derive(Debug, Clone, PartialEq)]
pub struct TurnBatch {
    /// Speculative stateless lookups (mutating calls are never probed).
    pub probes: Vec<ToolCall>,
    pub op: TurnOp,
}

/// Reply to a [`TurnBatch`].
#[derive(Debug, Clone, PartialEq)]
pub struct TurnReply {
    /// The session id the ops ran under (a frame sent with cursor 0 opens a
    /// session and returns its id here). 0 = the backend refused or does
    /// not support sessions — the caller falls back to the per-call path.
    pub cursor: u64,
    /// Per probe: the cached stateless result, or `None` (miss *or*
    /// unanswered — the two are deliberately indistinguishable: a probe
    /// miss must never suppress the later real lookup).
    pub probes: Vec<Option<ToolResult>>,
    /// Outcome of a [`TurnOp::Step`], if the batch carried one.
    pub step: Option<CursorStep>,
    /// Node id of a successful [`TurnOp::Record`]. `None` means the batch
    /// carried no record op *or* the record failed — the caller knows
    /// which op it sent, so `None` after sending a record means "fall
    /// back to a full insert". (`Some(0)` from a legacy server is also a
    /// refused record and takes the same fallback.)
    pub recorded: Option<NodeId>,
}

impl TurnReply {
    /// The "no session" reply: every op unanswered, caller falls back.
    pub fn refused(batch: &TurnBatch) -> TurnReply {
        TurnReply {
            cursor: 0,
            probes: vec![None; batch.probes.len()],
            step: match batch.op {
                TurnOp::Step(_) => Some(CursorStep::Invalid),
                _ => None,
            },
            recorded: None,
        }
    }
}

/// The narrow per-call cache surface (Figure 4's client↔service API).
///
/// Everything here is a self-contained request: no server-side state ties
/// one call to the next, so any decorator or transport can implement it.
/// The stateful rollout-scoped surface (cursors, turn batching, capability
/// negotiation) lives on the [`SessionBackend`] extension; rollout code
/// should not drive these methods by hand — open a
/// [`crate::client::RolloutSession`] instead and let the handle sequence
/// the lifecycle.
pub trait CacheBackend: Send + Sync {
    /// §3.2 LPM lookup of `q` (last element = the call being looked up).
    /// A miss with a resume offer may pin the resume node (§3.4); the
    /// caller must [`CacheBackend::release`] it once it is done with the
    /// offer (after forking, or on abandoning it). The in-process service
    /// pins until release; the HTTP binding's offers are unpinned
    /// server-side (a wire refcount could leak on a lost response), so
    /// there `release` is a saturating no-op and a fetch that loses an
    /// eviction race degrades to replay.
    fn lookup(&self, task: &str, q: &[ToolCall]) -> Lookup;

    /// Upsert an executed trajectory (`/put`); returns the id of the final
    /// state-mutating node on the path. `None` means the backend was
    /// unreachable (remote transport failure after retries) — *not* an
    /// empty path: a trajectory with no state-mutating call reports
    /// `Some(0)` (the ROOT id). Callers must never pin, release, or
    /// snapshot-attach a failed insert, which is exactly why the failure
    /// sentinel is a distinct variant instead of the old `0`.
    fn insert(&self, task: &str, traj: &[(ToolCall, ToolResult)]) -> Option<NodeId>;

    /// Decrement `node`'s sandbox refcount (client done forking).
    fn release(&self, task: &str, node: NodeId);

    /// §3.3 selective-snapshot decision for the given cost estimates.
    fn should_snapshot(&self, task: &str, costs: SnapshotCosts) -> bool;

    /// Store serialized sandbox state for `node`; returns the snapshot id
    /// (0 = the store refused / transport failed).
    fn store_snapshot(&self, task: &str, node: NodeId, snap: SandboxSnapshot) -> u64;

    /// Fetch snapshot bytes previously stored for this task.
    fn fetch_snapshot(&self, task: &str, id: u64) -> Option<SandboxSnapshot>;

    /// Mark a background fork of `node`'s sandbox warm / consumed (§3.3).
    fn set_warm_fork(&self, task: &str, node: NodeId, warm: bool);

    fn has_warm_fork(&self, task: &str, node: NodeId) -> bool;

    /// Per-task statistics (the `/stats?task=` payload).
    fn stats(&self, task: &str) -> CacheStats;

    /// Aggregate statistics across every task and shard.
    fn service_stats(&self) -> BackendStats;

    /// Persist every task's TCG and snapshot payloads under `dir` (a
    /// server-local path for the HTTP binding) so a later run can
    /// [`CacheBackend::warm_start`] from it. Returns `true` on success.
    fn persist(&self, dir: &str) -> bool;

    /// Warm-start: merge a previously persisted cache state from `dir`
    /// into this backend — trajectories, hit counts, and snapshot refs
    /// (payloads stay on disk until a resume faults them in) — so epoch 0
    /// of a new run starts warm. Returns `true` on success.
    fn warm_start(&self, dir: &str) -> bool;

    /// Is the backend currently degraded — e.g. the remote binding's
    /// circuit breaker is open because the cache service stopped
    /// answering? While this reports `true`, executors bypass the cache
    /// entirely (execute tools directly, no lookups or records); the
    /// implementation owns probing for recovery. Default: never degraded.
    fn degraded(&self) -> bool {
        false
    }

    /// Per-task view of [`CacheBackend::degraded`]. A cluster router is
    /// degraded for the tasks placed on a broken group while every other
    /// group keeps serving; single-node backends have one answer for all
    /// tasks, so the default just forwards.
    fn degraded_for(&self, _task: &str) -> bool {
        self.degraded()
    }
}

/// The session extension of [`CacheBackend`]: rollout-scoped state the
/// backend keeps between calls — stateful lookup cursors, turn-level
/// batched ops, and the capability handshake that negotiates them.
///
/// Every default here reports "unsupported", so a decorator (or any
/// backend that only cares about the per-call core) opts in with an empty
/// `impl SessionBackend for T {}` and callers transparently stay on the
/// full-prefix path. Rollouts should not call these methods directly:
/// [`crate::client::RolloutSession`] (opened via
/// [`open_session`](crate::client::open_session)) owns the task binding,
/// the cursor position, and all pinned resume refs, and releases
/// everything on `finish()` or `Drop` — so a panicking rollout can never
/// leak server-side state.
pub trait SessionBackend: CacheBackend {
    /// What this backend speaks. Resolved once per binding (the HTTP
    /// implementation performs the `/capabilities` handshake on first use
    /// and caches the answer), never per request.
    fn capabilities(&self) -> Capabilities {
        Capabilities::CORE
    }

    /// Monotonic backend identity. A multi-endpoint binding bumps this on
    /// every failover: cursor ids are allocated per *server*, so after a
    /// failover a session's cached id may name (or collide with) a
    /// different rollout's session on the new server. Sessions compare
    /// this against the generation they opened under and silently drop a
    /// cursor from an older one — never step, seek, or close it. Backends
    /// that can't change identity mid-run keep the default 0.
    fn backend_generation(&self) -> u64 {
        0
    }

    /// Per-task view of [`SessionBackend::capabilities`]. A cluster router
    /// answers with the capabilities of the group the ring places `task`
    /// on; single-node backends forward to the binding-wide answer.
    fn capabilities_for(&self, _task: &str) -> Capabilities {
        self.capabilities()
    }

    /// Per-task view of [`SessionBackend::backend_generation`]. A cluster
    /// router bumps only the failed group's generation on failover, so
    /// sessions sticky to healthy groups never drop their cursors.
    fn generation_for(&self, _task: &str) -> u64 {
        self.backend_generation()
    }

    // ---- stateful lookup cursors (the O(1)-per-call hot path) ----
    //
    // A rollout opens one cursor, then sends only the *delta* — the single
    // new `ToolCall` — per lookup instead of its full history: the backend
    // pins the rollout's TCG position, so a step is one child-index probe
    // and the wire carries O(1) bytes per call rather than O(L). Eviction
    // of a cursor's node invalidates it safely: the next step reports
    // `CursorStep::Invalid` and the caller falls back to the full-prefix
    // `lookup`/`insert` pair, then re-seeks.

    /// Open a cursor at the TCG root for a new rollout of `task`.
    /// Returns 0 when the backend does not support cursors (or the
    /// transport failed) — the caller must then use full-prefix lookups.
    fn cursor_open(&self, _task: &str) -> u64 {
        0
    }

    /// Incremental lookup of the single delta `call` at the cursor's
    /// position. Hit/miss payloads (including the §3.4 resume-offer pin
    /// contract) are identical to [`CacheBackend::lookup`] of the full
    /// prefix; `Invalid` means the cursor lost its node and the caller
    /// must fall back (and may [`SessionBackend::cursor_seek`] afterwards).
    fn cursor_step(&self, _task: &str, _cursor: u64, _call: &ToolCall) -> CursorStep {
        CursorStep::Invalid
    }

    /// Record the single executed delta at the cursor's position and
    /// advance it — the incremental counterpart of
    /// [`CacheBackend::insert`]. Returns the final state-mutating node id
    /// (the new cursor position); `None` when the cursor is invalid, the
    /// backend does not support cursors, or the transport failed — the
    /// caller falls back to a full insert + seek. As with `insert`,
    /// `Some(0)` is a *successful* record whose path carries no
    /// state-mutating call, never a failure sentinel.
    fn cursor_record(
        &self,
        _task: &str,
        _cursor: u64,
        _call: &ToolCall,
        _result: &ToolResult,
    ) -> Option<NodeId> {
        None
    }

    /// Re-seat a cursor on `node` with `steps` calls consumed — used after
    /// a fallback full-prefix lookup/insert re-established the position.
    /// Returns `false` when the node is gone or the cursor is unknown.
    fn cursor_seek(&self, _task: &str, _cursor: u64, _node: NodeId, _steps: usize) -> bool {
        false
    }

    /// Close a cursor (rollout finished): drop the session entry and
    /// release every resume pin it still holds. Unknown ids are a no-op.
    fn cursor_close(&self, _task: &str, _cursor: u64) {}

    /// Release a resume pin taken *through this session* (the session
    /// table forgets the pin, so closing the session later cannot
    /// double-release it). Pins taken outside any session route through
    /// here too — the default is a plain [`CacheBackend::release`].
    fn session_release(&self, task: &str, _cursor: u64, node: NodeId) {
        self.release(task, node);
    }

    /// One reasoning turn's batched ops in a single round trip. A `cursor`
    /// of 0 opens a session first (the open piggybacks on the first turn
    /// frame — no separate round trip). The default emulates the batch
    /// over the per-call cursor surface and leaves every probe unanswered,
    /// which keeps decorators and legacy backends correct: probes are
    /// hints, so an unanswered probe only costs the later real lookup.
    fn session_turn(&self, task: &str, cursor: u64, batch: &TurnBatch) -> TurnReply {
        let cursor = if cursor == 0 { self.cursor_open(task) } else { cursor };
        if cursor == 0 {
            return TurnReply::refused(batch);
        }
        let (step, recorded) = match &batch.op {
            TurnOp::None => (None, None),
            TurnOp::Step(call) => (Some(self.cursor_step(task, cursor, call)), None),
            TurnOp::Record(call, result) => {
                (None, self.cursor_record(task, cursor, call, result))
            }
        };
        TurnReply { cursor, probes: vec![None; batch.probes.len()], step, recorded }
    }
}
