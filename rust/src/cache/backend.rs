//! The `CacheBackend` trait: the single access surface for TVCACHE.
//!
//! Everything that talks to the cache — the `ToolCallExecutor`, the HTTP
//! server handlers, the simulated and concurrent training loops, and the
//! figure benches — programs against this trait. Two implementations ship:
//!
//! * [`super::ShardedCacheService`] — in-process, task-id-sharded (§4.5):
//!   N independent shards, each owning its own task map *and* its own
//!   snapshot store, so no lock is global.
//! * [`crate::client::RemoteBinding`] — the HTTP wire binding to a TVCACHE
//!   server (which itself fronts a `ShardedCacheService`).
//!
//! Every method takes the task id: per §3.1 each task has an independent
//! TCG, and the task id is what the shard router hashes (Figure 8a).

use super::key::{ToolCall, ToolResult};
use super::lpm::{CursorStep, Lookup};
use super::snapshot::SnapshotCosts;
use super::store::CacheStats;
use super::tcg::NodeId;
use crate::sandbox::SandboxSnapshot;
use crate::util::json::Json;

/// Service-wide aggregate statistics (all tasks, all shards), including the
/// snapshot-lifecycle counters: spill-tier occupancy, disk fault-ins, and
/// background evictions.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct BackendStats {
    pub shards: usize,
    pub tasks: usize,
    pub lookups: u64,
    pub hits: u64,
    /// Stored snapshots across both tiers (resident + spilled).
    pub snapshots: usize,
    /// Bytes across both tiers.
    pub snapshot_bytes: u64,
    /// Snapshots currently demoted to the disk spill tier.
    pub spilled_snapshots: usize,
    pub spilled_bytes: u64,
    /// Lifetime demotions to disk.
    pub spills: u64,
    /// Lifetime fault-ins from disk.
    pub spill_faults: u64,
    /// Snapshots the background worker destroyed (no spill tier).
    pub bg_evictions: u64,
}

impl BackendStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shards", Json::num(self.shards as f64)),
            ("tasks", Json::num(self.tasks as f64)),
            ("lookups", Json::num(self.lookups as f64)),
            ("hits", Json::num(self.hits as f64)),
            ("snapshots", Json::num(self.snapshots as f64)),
            ("snapshot_bytes", Json::num(self.snapshot_bytes as f64)),
            ("spilled_snapshots", Json::num(self.spilled_snapshots as f64)),
            ("spilled_bytes", Json::num(self.spilled_bytes as f64)),
            ("spills", Json::num(self.spills as f64)),
            ("spill_faults", Json::num(self.spill_faults as f64)),
            ("bg_evictions", Json::num(self.bg_evictions as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Option<BackendStats> {
        // Sentinel key: an arbitrary 200 JSON body must not parse as an
        // all-zero (idle-looking) stats object.
        v.get("shards")?;
        let g = |k: &str| v.get(k).and_then(Json::as_u64).unwrap_or(0);
        Some(BackendStats {
            shards: g("shards") as usize,
            tasks: g("tasks") as usize,
            lookups: g("lookups"),
            hits: g("hits"),
            snapshots: g("snapshots") as usize,
            snapshot_bytes: g("snapshot_bytes"),
            spilled_snapshots: g("spilled_snapshots") as usize,
            spilled_bytes: g("spilled_bytes"),
            spills: g("spills"),
            spill_faults: g("spill_faults"),
            bg_evictions: g("bg_evictions"),
        })
    }
}

/// The cache access surface (Figure 4's client↔service API as one trait).
pub trait CacheBackend: Send + Sync {
    /// §3.2 LPM lookup of `q` (last element = the call being looked up).
    /// A miss with a resume offer may pin the resume node (§3.4); the
    /// caller must [`CacheBackend::release`] it once it is done with the
    /// offer (after forking, or on abandoning it). The in-process service
    /// pins until release; the HTTP binding's offers are unpinned
    /// server-side (a wire refcount could leak on a lost response), so
    /// there `release` is a saturating no-op and a fetch that loses an
    /// eviction race degrades to replay.
    fn lookup(&self, task: &str, q: &[ToolCall]) -> Lookup;

    /// Upsert an executed trajectory (`/put`); returns the id of the final
    /// state-mutating node on the path.
    fn insert(&self, task: &str, traj: &[(ToolCall, ToolResult)]) -> NodeId;

    // ---- stateful lookup cursors (the O(1)-per-call hot path) ----
    //
    // A rollout opens one cursor, then sends only the *delta* — the single
    // new `ToolCall` — per lookup instead of its full history: the backend
    // pins the rollout's TCG position, so a step is one child-index probe
    // and the wire carries O(1) bytes per call rather than O(L). Eviction
    // of a cursor's node invalidates it safely: the next step reports
    // `CursorStep::Invalid` and the caller falls back to the full-prefix
    // `lookup`/`insert` pair, then re-seeks. The default implementations
    // make cursors an *optional capability*: a backend (or decorator) that
    // does not override them reports "unsupported" (`cursor_open` → 0) and
    // callers transparently stay on the full-prefix path.

    /// Open a cursor at the TCG root for a new rollout of `task`.
    /// Returns 0 when the backend does not support cursors (or the
    /// transport failed) — the caller must then use full-prefix lookups.
    fn cursor_open(&self, _task: &str) -> u64 {
        0
    }

    /// Incremental lookup of the single delta `call` at the cursor's
    /// position. Hit/miss payloads (including the §3.4 resume-offer pin
    /// contract) are identical to [`CacheBackend::lookup`] of the full
    /// prefix; `Invalid` means the cursor lost its node and the caller
    /// must fall back (and may [`CacheBackend::cursor_seek`] afterwards).
    fn cursor_step(&self, _task: &str, _cursor: u64, _call: &ToolCall) -> CursorStep {
        CursorStep::Invalid
    }

    /// Record the single executed delta at the cursor's position and
    /// advance it — the incremental counterpart of
    /// [`CacheBackend::insert`]. Returns the final state-mutating node id
    /// (the new cursor position), or 0 when the cursor is invalid / the
    /// transport failed (fall back to a full insert + seek).
    fn cursor_record(
        &self,
        _task: &str,
        _cursor: u64,
        _call: &ToolCall,
        _result: &ToolResult,
    ) -> NodeId {
        0
    }

    /// Re-seat a cursor on `node` with `steps` calls consumed — used after
    /// a fallback full-prefix lookup/insert re-established the position.
    /// Returns `false` when the node is gone or the cursor is unknown.
    fn cursor_seek(&self, _task: &str, _cursor: u64, _node: NodeId, _steps: usize) -> bool {
        false
    }

    /// Close a cursor (rollout finished). Unknown ids are a no-op.
    fn cursor_close(&self, _task: &str, _cursor: u64) {}

    /// Decrement `node`'s sandbox refcount (client done forking).
    fn release(&self, task: &str, node: NodeId);

    /// §3.3 selective-snapshot decision for the given cost estimates.
    fn should_snapshot(&self, task: &str, costs: SnapshotCosts) -> bool;

    /// Store serialized sandbox state for `node`; returns the snapshot id
    /// (0 = the store refused / transport failed).
    fn store_snapshot(&self, task: &str, node: NodeId, snap: SandboxSnapshot) -> u64;

    /// Fetch snapshot bytes previously stored for this task.
    fn fetch_snapshot(&self, task: &str, id: u64) -> Option<SandboxSnapshot>;

    /// Mark a background fork of `node`'s sandbox warm / consumed (§3.3).
    fn set_warm_fork(&self, task: &str, node: NodeId, warm: bool);

    fn has_warm_fork(&self, task: &str, node: NodeId) -> bool;

    /// Per-task statistics (the `/stats?task=` payload).
    fn stats(&self, task: &str) -> CacheStats;

    /// Aggregate statistics across every task and shard.
    fn service_stats(&self) -> BackendStats;

    /// Persist every task's TCG and snapshot payloads under `dir` (a
    /// server-local path for the HTTP binding) so a later run can
    /// [`CacheBackend::warm_start`] from it. Returns `true` on success.
    fn persist(&self, dir: &str) -> bool;

    /// Warm-start: merge a previously persisted cache state from `dir`
    /// into this backend — trajectories, hit counts, and snapshot refs
    /// (payloads stay on disk until a resume faults them in) — so epoch 0
    /// of a new run starts warm. Returns `true` on success.
    fn warm_start(&self, dir: &str) -> bool;
}
