//! Selective sandbox snapshotting policy (§3.3).
//!
//! TVCACHE snapshots the sandbox after a tool call only when re-executing
//! the call would cost more than serializing + later restoring a snapshot.
//! In practice this snapshots after long builds and test-suite runs but not
//! after `cat foo.py`.

/// Cost model inputs for one snapshot decision.
#[derive(Debug, Clone, Copy)]
pub struct SnapshotCosts {
    /// Seconds the tool call took to execute.
    pub exec_time: f64,
    /// Estimated seconds to serialize the sandbox now.
    pub serialize_cost: f64,
    /// Estimated seconds to restore (fork) the snapshot later.
    pub restore_cost: f64,
}

/// Policy deciding whether to store a snapshot at a TCG node.
#[derive(Debug, Clone, Copy)]
pub struct SnapshotPolicy {
    /// Multiplier on (serialize + restore) that exec_time must exceed.
    /// 1.0 reproduces the paper's rule exactly.
    pub cost_factor: f64,
    /// Never snapshot calls faster than this (filters noise).
    pub min_exec_time: f64,
    /// `true` disables snapshotting entirely (e.g. the SkyRL-SQL workload,
    /// whose tools are all read-only — §4.2).
    pub disabled: bool,
}

impl Default for SnapshotPolicy {
    fn default() -> Self {
        SnapshotPolicy { cost_factor: 1.0, min_exec_time: 0.01, disabled: false }
    }
}

impl SnapshotPolicy {
    pub fn never() -> Self {
        SnapshotPolicy { disabled: true, ..Default::default() }
    }

    /// Snapshot everything (the naive baseline ablated in the benches).
    pub fn always() -> Self {
        SnapshotPolicy { cost_factor: 0.0, min_exec_time: 0.0, disabled: false }
    }

    /// The §3.3 decision: snapshot iff re-execution is the greater evil.
    pub fn should_snapshot(&self, c: SnapshotCosts) -> bool {
        if self.disabled {
            return false;
        }
        if c.exec_time < self.min_exec_time {
            return false;
        }
        c.exec_time > self.cost_factor * (c.serialize_cost + c.restore_cost)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn costs(exec: f64) -> SnapshotCosts {
        SnapshotCosts { exec_time: exec, serialize_cost: 0.4, restore_cost: 0.6 }
    }

    #[test]
    fn snapshots_expensive_calls_only() {
        let p = SnapshotPolicy::default();
        assert!(p.should_snapshot(costs(30.0))); // test-suite run
        assert!(!p.should_snapshot(costs(0.005))); // cat foo.py
        assert!(!p.should_snapshot(costs(0.9))); // cheaper than 1.0s overhead
        assert!(p.should_snapshot(costs(1.1)));
    }

    #[test]
    fn threshold_is_serialize_plus_restore() {
        let p = SnapshotPolicy::default();
        let c = SnapshotCosts { exec_time: 2.0, serialize_cost: 1.5, restore_cost: 1.0 };
        assert!(!p.should_snapshot(c)); // 2.0 < 2.5
        let c2 = SnapshotCosts { exec_time: 3.0, ..c };
        assert!(p.should_snapshot(c2));
    }

    #[test]
    fn disabled_never_snapshots() {
        let p = SnapshotPolicy::never();
        assert!(!p.should_snapshot(costs(1e9)));
    }

    #[test]
    fn always_snapshots_anything_nonzero() {
        let p = SnapshotPolicy::always();
        assert!(p.should_snapshot(costs(0.001)));
    }

    #[test]
    fn cost_factor_scales_threshold() {
        let p = SnapshotPolicy { cost_factor: 3.0, ..Default::default() };
        assert!(!p.should_snapshot(costs(2.5))); // needs > 3.0
        assert!(p.should_snapshot(costs(3.5)));
    }
}
