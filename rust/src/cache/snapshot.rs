//! Selective sandbox snapshotting policy (§3.3) and the snapshot byte store.
//!
//! TVCACHE snapshots the sandbox after a tool call only when re-executing
//! the call would cost more than serializing + later restoring a snapshot.
//! In practice this snapshots after long builds and test-suite runs but not
//! after `cat foo.py`.
//!
//! [`SnapshotStore`] holds the serialized sandbox bytes. Each shard of the
//! sharded cache service owns its *own* store (strided id space), so the
//! snapshot path never funnels through a global lock.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::sandbox::SandboxSnapshot;

/// Cost model inputs for one snapshot decision.
#[derive(Debug, Clone, Copy)]
pub struct SnapshotCosts {
    /// Seconds the tool call took to execute.
    pub exec_time: f64,
    /// Estimated seconds to serialize the sandbox now.
    pub serialize_cost: f64,
    /// Estimated seconds to restore (fork) the snapshot later.
    pub restore_cost: f64,
}

/// Policy deciding whether to store a snapshot at a TCG node.
#[derive(Debug, Clone, Copy)]
pub struct SnapshotPolicy {
    /// Multiplier on (serialize + restore) that exec_time must exceed.
    /// 1.0 reproduces the paper's rule exactly.
    pub cost_factor: f64,
    /// Never snapshot calls faster than this (filters noise).
    pub min_exec_time: f64,
    /// `true` disables snapshotting entirely (e.g. the SkyRL-SQL workload,
    /// whose tools are all read-only — §4.2).
    pub disabled: bool,
}

impl Default for SnapshotPolicy {
    fn default() -> Self {
        SnapshotPolicy { cost_factor: 1.0, min_exec_time: 0.01, disabled: false }
    }
}

impl SnapshotPolicy {
    pub fn never() -> Self {
        SnapshotPolicy { disabled: true, ..Default::default() }
    }

    /// Snapshot everything (the naive baseline ablated in the benches).
    pub fn always() -> Self {
        SnapshotPolicy { cost_factor: 0.0, min_exec_time: 0.0, disabled: false }
    }

    /// The §3.3 decision: snapshot iff re-execution is the greater evil.
    pub fn should_snapshot(&self, c: SnapshotCosts) -> bool {
        if self.disabled {
            return false;
        }
        if c.exec_time < self.min_exec_time {
            return false;
        }
        c.exec_time > self.cost_factor * (c.serialize_cost + c.restore_cost)
    }
}

/// Store of serialized sandboxes, keyed by snapshot id.
///
/// The id returned by [`SnapshotStore::insert`] **is** the stored key — the
/// same value later passed to `get`/`remove` and embedded in
/// [`super::tcg::SnapshotRef::id`]. Ids start at `first_id` (≥ 1: id 0 is
/// the wire sentinel for "no snapshot") and advance by `stride`, so N
/// per-shard stores constructed as `SnapshotStore::new(shard + 1, N)` hand
/// out globally disjoint ids without any shared state.
#[derive(Debug)]
pub struct SnapshotStore {
    next_id: AtomicU64,
    stride: u64,
    snaps: Mutex<HashMap<u64, SandboxSnapshot>>,
}

impl Default for SnapshotStore {
    fn default() -> Self {
        SnapshotStore::new(1, 1)
    }
}

impl SnapshotStore {
    pub fn new(first_id: u64, stride: u64) -> SnapshotStore {
        assert!(first_id >= 1, "snapshot id 0 is reserved for 'no snapshot'");
        assert!(stride >= 1);
        SnapshotStore {
            next_id: AtomicU64::new(first_id),
            stride,
            snaps: Mutex::new(HashMap::new()),
        }
    }

    /// Store `snap`; the returned id is exactly the key it is stored under.
    pub fn insert(&self, snap: SandboxSnapshot) -> u64 {
        let id = self.next_id.fetch_add(self.stride, Ordering::SeqCst);
        self.snaps.lock().unwrap().insert(id, snap);
        id
    }

    pub fn get(&self, id: u64) -> Option<SandboxSnapshot> {
        self.snaps.lock().unwrap().get(&id).cloned()
    }

    pub fn contains(&self, id: u64) -> bool {
        self.snaps.lock().unwrap().contains_key(&id)
    }

    pub fn remove(&self, id: u64) {
        self.snaps.lock().unwrap().remove(&id);
    }

    pub fn len(&self) -> usize {
        self.snaps.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn total_bytes(&self) -> u64 {
        self.snaps.lock().unwrap().values().map(|s| s.size()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn costs(exec: f64) -> SnapshotCosts {
        SnapshotCosts { exec_time: exec, serialize_cost: 0.4, restore_cost: 0.6 }
    }

    #[test]
    fn snapshots_expensive_calls_only() {
        let p = SnapshotPolicy::default();
        assert!(p.should_snapshot(costs(30.0))); // test-suite run
        assert!(!p.should_snapshot(costs(0.005))); // cat foo.py
        assert!(!p.should_snapshot(costs(0.9))); // cheaper than 1.0s overhead
        assert!(p.should_snapshot(costs(1.1)));
    }

    #[test]
    fn threshold_is_serialize_plus_restore() {
        let p = SnapshotPolicy::default();
        let c = SnapshotCosts { exec_time: 2.0, serialize_cost: 1.5, restore_cost: 1.0 };
        assert!(!p.should_snapshot(c)); // 2.0 < 2.5
        let c2 = SnapshotCosts { exec_time: 3.0, ..c };
        assert!(p.should_snapshot(c2));
    }

    #[test]
    fn disabled_never_snapshots() {
        let p = SnapshotPolicy::never();
        assert!(!p.should_snapshot(costs(1e9)));
    }

    #[test]
    fn always_snapshots_anything_nonzero() {
        let p = SnapshotPolicy::always();
        assert!(p.should_snapshot(costs(0.001)));
    }

    #[test]
    fn cost_factor_scales_threshold() {
        let p = SnapshotPolicy { cost_factor: 3.0, ..Default::default() };
        assert!(!p.should_snapshot(costs(2.5))); // needs > 3.0
        assert!(p.should_snapshot(costs(3.5)));
    }

    fn snap(n: usize) -> SandboxSnapshot {
        SandboxSnapshot { bytes: vec![0u8; n], serialize_cost: 0.1, restore_cost: 0.2 }
    }

    #[test]
    fn store_id_is_the_stored_key() {
        let store = SnapshotStore::default();
        let a = store.insert(snap(10));
        let b = store.insert(snap(20));
        assert_eq!(a, 1, "ids start at 1 (0 = wire sentinel)");
        assert_eq!(b, 2);
        // The returned id addresses exactly what was inserted.
        assert_eq!(store.get(a).unwrap().size(), 10);
        assert_eq!(store.get(b).unwrap().size(), 20);
        assert_eq!(store.total_bytes(), 30);
        store.remove(a);
        assert!(store.get(a).is_none());
        assert!(!store.contains(a));
        assert_eq!(store.total_bytes(), 20);
        assert_eq!(store.len(), 1);
    }

    #[test]
    fn strided_stores_hand_out_disjoint_ids() {
        let n = 4u64;
        let stores: Vec<SnapshotStore> =
            (0..n).map(|i| SnapshotStore::new(i + 1, n)).collect();
        let mut seen = std::collections::HashSet::new();
        for store in &stores {
            for _ in 0..16 {
                let id = store.insert(snap(1));
                assert!(id >= 1);
                assert!(seen.insert(id), "id {id} handed out twice");
                assert!(store.contains(id));
            }
        }
    }

    #[test]
    fn concurrent_inserts_yield_unique_live_ids() {
        use std::sync::Arc;
        let store = Arc::new(SnapshotStore::default());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let s = Arc::clone(&store);
                std::thread::spawn(move || {
                    (0..50).map(|_| s.insert(snap(1))).collect::<Vec<u64>>()
                })
            })
            .collect();
        let mut all = Vec::new();
        for h in handles {
            all.extend(h.join().unwrap());
        }
        let unique: std::collections::HashSet<u64> = all.iter().copied().collect();
        assert_eq!(unique.len(), 200, "every insert got a distinct key");
        assert_eq!(store.len(), 200);
    }
}
