//! Selective sandbox snapshotting policy (§3.3) and the snapshot byte store.
//!
//! TVCACHE snapshots the sandbox after a tool call only when re-executing
//! the call would cost more than serializing + later restoring a snapshot.
//! In practice this snapshots after long builds and test-suite runs but not
//! after `cat foo.py`.
//!
//! [`SnapshotStore`] holds the serialized sandbox bytes. Each shard of the
//! sharded cache service owns its *own* store (strided id space), so the
//! snapshot path never funnels through a global lock. A store may carry a
//! spill tier (`cache/spill.rs`): over-budget payloads are demoted to disk
//! (`spill`) and faulted back in transparently on `get`, with a small read
//! penalty folded into the returned `restore_cost`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::spill::{SpillSlot, SpillStore, SPILL_FAULT_PENALTY};
use crate::sandbox::SandboxSnapshot;

/// Cost model inputs for one snapshot decision.
#[derive(Debug, Clone, Copy)]
pub struct SnapshotCosts {
    /// Seconds the tool call took to execute.
    pub exec_time: f64,
    /// Estimated seconds to serialize the sandbox now.
    pub serialize_cost: f64,
    /// Estimated seconds to restore (fork) the snapshot later.
    pub restore_cost: f64,
}

/// Policy deciding whether to store a snapshot at a TCG node.
#[derive(Debug, Clone, Copy)]
pub struct SnapshotPolicy {
    /// Multiplier on (serialize + restore) that exec_time must exceed.
    /// 1.0 reproduces the paper's rule exactly.
    pub cost_factor: f64,
    /// Never snapshot calls faster than this (filters noise).
    pub min_exec_time: f64,
    /// `true` disables snapshotting entirely (e.g. the SkyRL-SQL workload,
    /// whose tools are all read-only — §4.2).
    pub disabled: bool,
}

impl Default for SnapshotPolicy {
    fn default() -> Self {
        SnapshotPolicy { cost_factor: 1.0, min_exec_time: 0.01, disabled: false }
    }
}

impl SnapshotPolicy {
    pub fn never() -> Self {
        SnapshotPolicy { disabled: true, ..Default::default() }
    }

    /// Snapshot everything (the naive baseline ablated in the benches).
    pub fn always() -> Self {
        SnapshotPolicy { cost_factor: 0.0, min_exec_time: 0.0, disabled: false }
    }

    /// The §3.3 decision: snapshot iff re-execution is the greater evil.
    pub fn should_snapshot(&self, c: SnapshotCosts) -> bool {
        if self.disabled {
            return false;
        }
        if c.exec_time < self.min_exec_time {
            return false;
        }
        c.exec_time > self.cost_factor * (c.serialize_cost + c.restore_cost)
    }
}

/// One stored snapshot: payload in memory, or demoted to the disk tier.
#[derive(Debug)]
enum Slot {
    Resident(SandboxSnapshot),
    Spilled(SpillSlot),
}

/// Store of serialized sandboxes, keyed by snapshot id.
///
/// The id returned by [`SnapshotStore::insert`] **is** the stored key — the
/// same value later passed to `get`/`remove` and embedded in
/// [`super::tcg::SnapshotRef::id`]. Ids start at `first_id` (≥ 1: id 0 is
/// the wire sentinel for "no snapshot") and advance by `stride`, so N
/// per-shard stores constructed as `SnapshotStore::new(shard + 1, N)` hand
/// out globally disjoint ids without any shared state.
#[derive(Debug)]
pub struct SnapshotStore {
    next_id: AtomicU64,
    stride: u64,
    snaps: Mutex<HashMap<u64, Slot>>,
    /// Spill tier; `None` = over-budget payloads are destroyed, not demoted.
    spill: Option<Arc<SpillStore>>,
    resident_bytes: AtomicU64,
    spilled_bytes: AtomicU64,
    /// Payloads demoted to disk / faulted back in (service-stats counters).
    spills: AtomicU64,
    faults: AtomicU64,
}

impl Default for SnapshotStore {
    fn default() -> Self {
        SnapshotStore::new(1, 1)
    }
}

impl SnapshotStore {
    pub fn new(first_id: u64, stride: u64) -> SnapshotStore {
        Self::build(first_id, stride, None)
    }

    /// A store whose over-budget payloads spill to `spill` instead of dying.
    pub fn with_spill(first_id: u64, stride: u64, spill: Arc<SpillStore>) -> SnapshotStore {
        Self::build(first_id, stride, Some(spill))
    }

    fn build(first_id: u64, stride: u64, spill: Option<Arc<SpillStore>>) -> SnapshotStore {
        assert!(first_id >= 1, "snapshot id 0 is reserved for 'no snapshot'");
        assert!(stride >= 1);
        SnapshotStore {
            next_id: AtomicU64::new(first_id),
            stride,
            snaps: Mutex::new(HashMap::new()),
            spill,
            resident_bytes: AtomicU64::new(0),
            spilled_bytes: AtomicU64::new(0),
            spills: AtomicU64::new(0),
            faults: AtomicU64::new(0),
        }
    }

    /// Store `snap`; the returned id is exactly the key it is stored under.
    pub fn insert(&self, snap: SandboxSnapshot) -> u64 {
        let id = self.next_id.fetch_add(self.stride, Ordering::SeqCst);
        self.resident_bytes.fetch_add(snap.size(), Ordering::Relaxed);
        self.snaps.lock().unwrap().insert(id, Slot::Resident(snap));
        id
    }

    /// Fetch by id. A spilled payload is faulted in from disk; the returned
    /// `restore_cost` then carries the [`SPILL_FAULT_PENALTY`] read charge.
    /// `None` = never stored, removed, or the spill file is unreadable —
    /// the caller degrades to replay.
    pub fn get(&self, id: u64) -> Option<SandboxSnapshot> {
        let slot = {
            let snaps = self.snaps.lock().unwrap();
            match snaps.get(&id) {
                Some(Slot::Resident(s)) => return Some(s.clone()),
                Some(Slot::Spilled(s)) => s.clone(),
                None => return None,
            }
        };
        // Disk read happens outside the store lock.
        let mut snap = slot.fault()?;
        snap.restore_cost += SPILL_FAULT_PENALTY;
        self.faults.fetch_add(1, Ordering::Relaxed);
        Some(snap)
    }

    /// Demote `id`'s payload to the spill tier. Returns `true` if the bytes
    /// now live on disk (also when they already did). `false` when the
    /// store has no spill tier, the id is gone, or the write failed.
    /// `restore_cost` to record comes from the caller (the TCG ref), so
    /// fault penalties never compound across repeated spills.
    pub fn spill(&self, task: &str, id: u64, restore_cost: f64) -> bool {
        let Some(spill) = &self.spill else { return false };
        let payload = {
            let snaps = self.snaps.lock().unwrap();
            match snaps.get(&id) {
                Some(Slot::Resident(s)) => s.clone(),
                Some(Slot::Spilled(_)) => return true,
                None => return false,
            }
        };
        // File + manifest I/O outside the lock; swap the slot after.
        let Ok(slot) = spill.write(task, id, &payload, restore_cost) else {
            return false;
        };
        let mut snaps = self.snaps.lock().unwrap();
        match snaps.get_mut(&id) {
            Some(s @ Slot::Resident(_)) => {
                *s = Slot::Spilled(slot);
                self.resident_bytes.fetch_sub(payload.size(), Ordering::Relaxed);
                self.spilled_bytes.fetch_add(payload.size(), Ordering::Relaxed);
                self.spills.fetch_add(1, Ordering::Relaxed);
                true
            }
            Some(Slot::Spilled(_)) => true,
            None => {
                // Removed while we wrote: retract the orphaned payload.
                spill.drop_payload(id);
                false
            }
        }
    }

    /// Register a payload that already lives on disk (warm-start reload).
    pub fn adopt_spilled(&self, id: u64, slot: SpillSlot) {
        let mut snaps = self.snaps.lock().unwrap();
        if snaps.contains_key(&id) {
            return;
        }
        self.spilled_bytes.fetch_add(slot.bytes, Ordering::Relaxed);
        snaps.insert(id, Slot::Spilled(slot));
    }

    /// Advance the id allocator past `max_id` (same stride), so ids handed
    /// out after a warm-start never collide with reloaded ones.
    pub fn reserve_through(&self, max_id: u64) {
        while self.next_id.load(Ordering::SeqCst) <= max_id {
            self.next_id.fetch_add(self.stride, Ordering::SeqCst);
        }
    }

    pub fn contains(&self, id: u64) -> bool {
        self.snaps.lock().unwrap().contains_key(&id)
    }

    /// True when `id` is stored with its payload in memory.
    pub fn is_resident(&self, id: u64) -> bool {
        matches!(self.snaps.lock().unwrap().get(&id), Some(Slot::Resident(_)))
    }

    /// The on-disk location of `id` if it is currently spilled (persist
    /// fast-path: an already-spilled payload need not be re-read/re-written).
    pub fn spilled_slot(&self, id: u64) -> Option<SpillSlot> {
        match self.snaps.lock().unwrap().get(&id) {
            Some(Slot::Spilled(s)) => Some(s.clone()),
            _ => None,
        }
    }

    pub fn remove(&self, id: u64) {
        let removed = self.snaps.lock().unwrap().remove(&id);
        match removed {
            Some(Slot::Resident(s)) => {
                self.resident_bytes.fetch_sub(s.size(), Ordering::Relaxed);
            }
            Some(Slot::Spilled(s)) => {
                self.spilled_bytes.fetch_sub(s.bytes, Ordering::Relaxed);
                match &self.spill {
                    Some(spill) => spill.drop_payload(id),
                    // Adopted at warm-start (no manifest handle): deleting
                    // the payload file suffices — manifest reload discards
                    // records whose file is gone, so a destroyed snapshot
                    // can never be resurrected by a later warm-start.
                    None => {
                        let _ = std::fs::remove_file(&s.path);
                    }
                }
            }
            None => {}
        }
    }

    pub fn len(&self) -> usize {
        self.snaps.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes stored across both tiers (memory + disk).
    pub fn total_bytes(&self) -> u64 {
        self.resident_bytes() + self.spilled_bytes()
    }

    pub fn resident_bytes(&self) -> u64 {
        self.resident_bytes.load(Ordering::Relaxed)
    }

    pub fn spilled_bytes(&self) -> u64 {
        self.spilled_bytes.load(Ordering::Relaxed)
    }

    pub fn spilled_count(&self) -> usize {
        self.snaps
            .lock()
            .unwrap()
            .values()
            .filter(|s| matches!(s, Slot::Spilled(_)))
            .count()
    }

    pub fn spill_count(&self) -> u64 {
        self.spills.load(Ordering::Relaxed)
    }

    pub fn fault_count(&self) -> u64 {
        self.faults.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn costs(exec: f64) -> SnapshotCosts {
        SnapshotCosts { exec_time: exec, serialize_cost: 0.4, restore_cost: 0.6 }
    }

    #[test]
    fn snapshots_expensive_calls_only() {
        let p = SnapshotPolicy::default();
        assert!(p.should_snapshot(costs(30.0))); // test-suite run
        assert!(!p.should_snapshot(costs(0.005))); // cat foo.py
        assert!(!p.should_snapshot(costs(0.9))); // cheaper than 1.0s overhead
        assert!(p.should_snapshot(costs(1.1)));
    }

    #[test]
    fn threshold_is_serialize_plus_restore() {
        let p = SnapshotPolicy::default();
        let c = SnapshotCosts { exec_time: 2.0, serialize_cost: 1.5, restore_cost: 1.0 };
        assert!(!p.should_snapshot(c)); // 2.0 < 2.5
        let c2 = SnapshotCosts { exec_time: 3.0, ..c };
        assert!(p.should_snapshot(c2));
    }

    #[test]
    fn disabled_never_snapshots() {
        let p = SnapshotPolicy::never();
        assert!(!p.should_snapshot(costs(1e9)));
    }

    #[test]
    fn always_snapshots_anything_nonzero() {
        let p = SnapshotPolicy::always();
        assert!(p.should_snapshot(costs(0.001)));
    }

    #[test]
    fn cost_factor_scales_threshold() {
        let p = SnapshotPolicy { cost_factor: 3.0, ..Default::default() };
        assert!(!p.should_snapshot(costs(2.5))); // needs > 3.0
        assert!(p.should_snapshot(costs(3.5)));
    }

    fn snap(n: usize) -> SandboxSnapshot {
        SandboxSnapshot { bytes: vec![0u8; n], serialize_cost: 0.1, restore_cost: 0.2 }
    }

    #[test]
    fn store_id_is_the_stored_key() {
        let store = SnapshotStore::default();
        let a = store.insert(snap(10));
        let b = store.insert(snap(20));
        assert_eq!(a, 1, "ids start at 1 (0 = wire sentinel)");
        assert_eq!(b, 2);
        // The returned id addresses exactly what was inserted.
        assert_eq!(store.get(a).unwrap().size(), 10);
        assert_eq!(store.get(b).unwrap().size(), 20);
        assert_eq!(store.total_bytes(), 30);
        store.remove(a);
        assert!(store.get(a).is_none());
        assert!(!store.contains(a));
        assert_eq!(store.total_bytes(), 20);
        assert_eq!(store.len(), 1);
    }

    #[test]
    fn strided_stores_hand_out_disjoint_ids() {
        let n = 4u64;
        let stores: Vec<SnapshotStore> =
            (0..n).map(|i| SnapshotStore::new(i + 1, n)).collect();
        let mut seen = std::collections::HashSet::new();
        for store in &stores {
            for _ in 0..16 {
                let id = store.insert(snap(1));
                assert!(id >= 1);
                assert!(seen.insert(id), "id {id} handed out twice");
                assert!(store.contains(id));
            }
        }
    }

    #[test]
    fn spill_demotes_and_get_faults_back_in() {
        let dir = std::env::temp_dir()
            .join(format!("tvcache-store-spill-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spill = Arc::new(SpillStore::open(&dir).unwrap());
        let store = SnapshotStore::with_spill(1, 1, spill);
        let id = store.insert(snap(64));
        assert!(store.is_resident(id));
        assert_eq!(store.resident_bytes(), 64);

        assert!(store.spill("t", id, 0.2));
        assert!(!store.is_resident(id));
        assert!(store.contains(id));
        assert_eq!(store.resident_bytes(), 0);
        assert_eq!(store.spilled_bytes(), 64);
        assert_eq!(store.total_bytes(), 64, "spilled bytes still count as stored");
        assert_eq!(store.spilled_count(), 1);
        assert_eq!(store.spill_count(), 1);

        // Fault-in: same payload, restore cost carries the disk penalty.
        let back = store.get(id).unwrap();
        assert_eq!(back.size(), 64);
        assert!((back.restore_cost - (0.2 + SPILL_FAULT_PENALTY)).abs() < 1e-12);
        assert_eq!(store.fault_count(), 1);

        // Re-spilling an already-spilled id is a no-op success.
        assert!(store.spill("t", id, 0.2));
        assert_eq!(store.spill_count(), 1);

        // Remove retracts the disk payload too.
        store.remove(id);
        assert!(store.get(id).is_none());
        assert_eq!(store.total_bytes(), 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn spill_without_tier_refuses() {
        let store = SnapshotStore::default();
        let id = store.insert(snap(8));
        assert!(!store.spill("t", id, 0.1));
        assert!(store.is_resident(id));
    }

    #[test]
    fn reserve_through_skips_reloaded_ids() {
        let store = SnapshotStore::new(2, 4); // ids 2, 6, 10, …
        store.reserve_through(9);
        let id = store.insert(snap(1));
        assert_eq!(id, 10);
    }

    #[test]
    fn concurrent_inserts_yield_unique_live_ids() {
        use std::sync::Arc;
        let store = Arc::new(SnapshotStore::default());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let s = Arc::clone(&store);
                std::thread::spawn(move || {
                    (0..50).map(|_| s.insert(snap(1))).collect::<Vec<u64>>()
                })
            })
            .collect();
        let mut all = Vec::new();
        for h in handles {
            all.extend(h.join().unwrap());
        }
        let unique: std::collections::HashSet<u64> = all.iter().copied().collect();
        assert_eq!(unique.len(), 200, "every insert got a distinct key");
        assert_eq!(store.len(), 200);
    }
}
