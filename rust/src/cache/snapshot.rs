//! Selective sandbox snapshotting policy (§3.3) and the snapshot store.
//!
//! TVCACHE snapshots the sandbox after a tool call only when re-executing
//! the call would cost more than serializing + later restoring a snapshot.
//! In practice this snapshots after long builds and test-suite runs but not
//! after `cat foo.py`.
//!
//! [`SnapshotStore`] maps snapshot ids to *handles* — `(content_key, size,
//! costs)` — while the bytes themselves live in a content-addressed
//! [`PayloadStore`] (`cache/payload.rs`), shared across all stores of a
//! service. Each shard of the sharded cache service owns its *own* handle
//! store (strided id space), so the snapshot path never funnels through a
//! global id lock; identical sandbox states inserted by different tasks or
//! shards still collapse to one resident (or one spilled) copy. A store
//! may carry a spill tier (`cache/spill.rs`): over-budget payloads are
//! demoted to disk (`spill`) and faulted back in transparently on `get`
//! through an LRU fault cache, with a small read penalty folded into the
//! returned `restore_cost` only when the disk was actually touched.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::payload::{
    ContentKey, FetchSource, InsertOutcome, PayloadStore, SpillOutcome,
    DEFAULT_FAULT_CACHE_BYTES,
};
use super::spill::{SpillSlot, SpillStore, SPILL_FAULT_PENALTY};
use crate::sandbox::SandboxSnapshot;

/// Cost model inputs for one snapshot decision.
#[derive(Debug, Clone, Copy)]
pub struct SnapshotCosts {
    /// Seconds the tool call took to execute.
    pub exec_time: f64,
    /// Estimated seconds to serialize the sandbox now.
    pub serialize_cost: f64,
    /// Estimated seconds to restore (fork) the snapshot later.
    pub restore_cost: f64,
}

/// Policy deciding whether to store a snapshot at a TCG node.
#[derive(Debug, Clone, Copy)]
pub struct SnapshotPolicy {
    /// Multiplier on (serialize + restore) that exec_time must exceed.
    /// 1.0 reproduces the paper's rule exactly.
    pub cost_factor: f64,
    /// Never snapshot calls faster than this (filters noise).
    pub min_exec_time: f64,
    /// `true` disables snapshotting entirely (e.g. the SkyRL-SQL workload,
    /// whose tools are all read-only — §4.2).
    pub disabled: bool,
}

impl Default for SnapshotPolicy {
    fn default() -> Self {
        SnapshotPolicy { cost_factor: 1.0, min_exec_time: 0.01, disabled: false }
    }
}

impl SnapshotPolicy {
    pub fn never() -> Self {
        SnapshotPolicy { disabled: true, ..Default::default() }
    }

    /// Snapshot everything (the naive baseline ablated in the benches).
    pub fn always() -> Self {
        SnapshotPolicy { cost_factor: 0.0, min_exec_time: 0.0, disabled: false }
    }

    /// The §3.3 decision: snapshot iff re-execution is the greater evil.
    pub fn should_snapshot(&self, c: SnapshotCosts) -> bool {
        if self.disabled {
            return false;
        }
        if c.exec_time < self.min_exec_time {
            return false;
        }
        c.exec_time > self.cost_factor * (c.serialize_cost + c.restore_cost)
    }
}

/// One stored snapshot: a content-addressed reference plus the per-handle
/// cost metadata the payload table does not keep.
#[derive(Debug, Clone, Copy)]
struct Handle {
    key: ContentKey,
    bytes: u64,
    serialize_cost: f64,
    restore_cost: f64,
}

/// Store of serialized sandboxes, keyed by snapshot id.
///
/// The id returned by [`SnapshotStore::insert`] **is** the stored key — the
/// same value later passed to `get`/`remove` and embedded in
/// [`super::tcg::SnapshotRef::id`]. Ids start at `first_id` (≥ 1: id 0 is
/// the wire sentinel for "no snapshot") and advance by `stride`, so N
/// per-shard stores constructed as `SnapshotStore::with_payloads(shard + 1,
/// N, payloads)` hand out globally disjoint ids without any shared state.
///
/// Byte gauges (`resident_bytes`/`spilled_bytes`) follow the payload
/// table's charge-owner model: a payload shared with another store counts
/// against exactly one of them at a time.
#[derive(Debug)]
pub struct SnapshotStore {
    next_id: AtomicU64,
    stride: u64,
    snaps: Mutex<HashMap<u64, Handle>>,
    /// Content-addressed byte table (possibly shared across stores).
    payloads: Arc<PayloadStore>,
    /// This store's registration tag in `payloads`.
    tag: u32,
    /// Payloads demoted to disk / faulted back in *by this store*
    /// (service-stats counters).
    spills: AtomicU64,
    faults: AtomicU64,
}

impl Default for SnapshotStore {
    fn default() -> Self {
        SnapshotStore::new(1, 1)
    }
}

impl SnapshotStore {
    pub fn new(first_id: u64, stride: u64) -> SnapshotStore {
        Self::build(first_id, stride, Arc::new(PayloadStore::new(None, 0)))
    }

    /// A store whose over-budget payloads spill to `spill` instead of
    /// dying, with a default-sized fault cache over the fault-in path.
    pub fn with_spill(first_id: u64, stride: u64, spill: Arc<SpillStore>) -> SnapshotStore {
        Self::build(
            first_id,
            stride,
            Arc::new(PayloadStore::new(Some(spill), DEFAULT_FAULT_CACHE_BYTES)),
        )
    }

    /// A store over a shared payload table — how the sharded service wires
    /// its per-shard stores so identical payloads dedup across shards.
    pub fn with_payloads(
        first_id: u64,
        stride: u64,
        payloads: Arc<PayloadStore>,
    ) -> SnapshotStore {
        Self::build(first_id, stride, payloads)
    }

    fn build(first_id: u64, stride: u64, payloads: Arc<PayloadStore>) -> SnapshotStore {
        assert!(first_id >= 1, "snapshot id 0 is reserved for 'no snapshot'");
        assert!(stride >= 1);
        let tag = payloads.register();
        SnapshotStore {
            next_id: AtomicU64::new(first_id),
            stride,
            snaps: Mutex::new(HashMap::new()),
            payloads,
            tag,
            spills: AtomicU64::new(0),
            faults: AtomicU64::new(0),
        }
    }

    /// The payload table backing this store (shared across a service's
    /// shards; dedup / fault-cache counters live here).
    pub fn payloads(&self) -> &Arc<PayloadStore> {
        &self.payloads
    }

    /// Store `snap`; the returned id is exactly the key it is stored under.
    /// Content identical to an already-stored payload is shared, not
    /// copied — the dedup hit is visible via [`PayloadStore::dedup_hits`].
    pub fn insert(&self, snap: SandboxSnapshot) -> u64 {
        let id = self.next_id.fetch_add(self.stride, Ordering::SeqCst);
        let key = ContentKey::of(&snap.bytes);
        let handle = Handle {
            key,
            bytes: snap.bytes.len() as u64,
            serialize_cost: snap.serialize_cost,
            restore_cost: snap.restore_cost,
        };
        self.payloads.insert(self.tag, key, snap.bytes);
        self.snaps.lock().unwrap().insert(id, handle);
        id
    }

    /// Fetch by id. A spilled payload is faulted in through the LRU fault
    /// cache; only an actual disk read charges the [`SPILL_FAULT_PENALTY`]
    /// on the returned `restore_cost` (and counts a fault). `None` = never
    /// stored, removed, or the spill file is unreadable — the caller
    /// degrades to replay.
    pub fn get(&self, id: u64) -> Option<SandboxSnapshot> {
        let handle = *self.snaps.lock().unwrap().get(&id)?;
        let (bytes, source) = self.payloads.fetch(&handle.key)?;
        let mut restore_cost = handle.restore_cost;
        if source == FetchSource::Disk {
            restore_cost += SPILL_FAULT_PENALTY;
            self.faults.fetch_add(1, Ordering::Relaxed);
        }
        Some(SandboxSnapshot {
            bytes: (*bytes).clone(),
            serialize_cost: handle.serialize_cost,
            restore_cost,
        })
    }

    /// Demote `id`'s payload to the spill tier. Returns `true` if the bytes
    /// now live on disk (also when they already did). `false` when the
    /// store has no spill tier, the id is gone, or the write failed.
    /// `restore_cost` to record comes from the caller (the TCG ref), so
    /// fault penalties never compound across repeated spills. Spilling a
    /// shared payload demotes every handle referencing it, across all
    /// stores, at once — and writes the bytes at most once.
    pub fn spill(&self, task: &str, id: u64, restore_cost: f64) -> bool {
        let handle = {
            match self.snaps.lock().unwrap().get(&id) {
                Some(h) => *h,
                None => return false,
            }
        };
        match self.payloads.spill(handle.key, task, id, handle.serialize_cost, restore_cost) {
            SpillOutcome::Demoted => {
                self.spills.fetch_add(1, Ordering::Relaxed);
                true
            }
            SpillOutcome::AlreadySpilled => true,
            SpillOutcome::Refused | SpillOutcome::Gone | SpillOutcome::Failed => false,
        }
    }

    /// Register a payload that already lives on disk (warm-start reload).
    /// Slots that share a content key rehydrate to one shared payload.
    pub fn adopt_spilled(&self, id: u64, slot: SpillSlot) {
        let mut snaps = self.snaps.lock().unwrap();
        if snaps.contains_key(&id) {
            return;
        }
        let key = slot.key.unwrap_or_else(|| ContentKey::synthetic(id));
        let handle = Handle {
            key,
            bytes: slot.bytes,
            serialize_cost: slot.serialize_cost,
            restore_cost: slot.restore_cost,
        };
        self.payloads.adopt(self.tag, key, slot);
        snaps.insert(id, handle);
    }

    /// Register a snapshot replicated from a primary's op-log under the
    /// *primary's* id (follower replay, PR 8). The first attach of a
    /// content key in the log window carries the bytes; later attaches
    /// ship the key alone and share the already-stored payload. Returns
    /// `false` when a key-only attach references content this store has
    /// never seen (its bytes-carrying op aged off the primary's window
    /// before this follower pulled it) — the caller skips the attach and
    /// the node simply has no snapshot on this replica.
    pub fn adopt_replicated(
        &self,
        id: u64,
        key: ContentKey,
        bytes: Option<Vec<u8>>,
        byte_len: u64,
        serialize_cost: f64,
        restore_cost: f64,
    ) -> bool {
        let mut snaps = self.snaps.lock().unwrap();
        if snaps.contains_key(&id) {
            return true; // idempotent re-apply (follower re-pull)
        }
        match bytes {
            Some(b) => {
                self.payloads.insert(self.tag, key, b);
            }
            None => {
                if self.payloads.ref_total(&key) == 0 {
                    return false;
                }
                // Dedup path: the placeholder vec is dropped, the
                // reference shared with the bytes-carrying handle.
                if self.payloads.insert(self.tag, key, Vec::new()) == InsertOutcome::New {
                    // The payload vanished between check and insert; roll
                    // back the bogus empty payload rather than serve it.
                    self.payloads.release(self.tag, key, id);
                    return false;
                }
            }
        }
        snaps.insert(id, Handle { key, bytes: byte_len, serialize_cost, restore_cost });
        // Keep the local allocator ahead of every adopted id, so the ids
        // this store hands out after a promotion never collide.
        self.reserve_through(id);
        true
    }

    /// Advance the id allocator past `max_id` (same stride), so ids handed
    /// out after a warm-start never collide with reloaded ones.
    pub fn reserve_through(&self, max_id: u64) {
        while self.next_id.load(Ordering::SeqCst) <= max_id {
            self.next_id.fetch_add(self.stride, Ordering::SeqCst);
        }
    }

    pub fn contains(&self, id: u64) -> bool {
        self.snaps.lock().unwrap().contains_key(&id)
    }

    /// True when `id` is stored with its payload in memory.
    pub fn is_resident(&self, id: u64) -> bool {
        let key = match self.snaps.lock().unwrap().get(&id) {
            Some(h) => h.key,
            None => return false,
        };
        self.payloads.is_resident(&key)
    }

    /// The content key behind `id`, if stored.
    pub fn content_key(&self, id: u64) -> Option<ContentKey> {
        self.snaps.lock().unwrap().get(&id).map(|h| h.key)
    }

    /// True when `id`'s payload is referenced by more than one handle
    /// (eviction should know that dropping one referent frees nothing).
    pub fn payload_shared(&self, id: u64) -> bool {
        match self.content_key(id) {
            Some(key) => self.payloads.ref_total(&key) > 1,
            None => false,
        }
    }

    /// The on-disk location of `id` if it is currently spilled (persist
    /// fast-path: an already-spilled payload need not be re-read/re-written).
    pub fn spilled_slot(&self, id: u64) -> Option<SpillSlot> {
        let key = self.content_key(id)?;
        self.payloads.spilled_slot(&key)
    }

    /// Drop the handle; the payload's bytes (and any disk slot) are freed
    /// only when the last handle referencing them — in any store — dies.
    pub fn remove(&self, id: u64) {
        let handle = self.snaps.lock().unwrap().remove(&id);
        if let Some(h) = handle {
            self.payloads.release(self.tag, h.key, id);
        }
    }

    pub fn len(&self) -> usize {
        self.snaps.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes charged to this store across both tiers (memory + disk).
    pub fn total_bytes(&self) -> u64 {
        self.resident_bytes() + self.spilled_bytes()
    }

    pub fn resident_bytes(&self) -> u64 {
        self.payloads.resident_bytes_of(self.tag)
    }

    pub fn spilled_bytes(&self) -> u64 {
        self.payloads.spilled_bytes_of(self.tag)
    }

    /// Handles whose payload currently lives in the spill tier.
    pub fn spilled_count(&self) -> usize {
        let keys: Vec<ContentKey> =
            self.snaps.lock().unwrap().values().map(|h| h.key).collect();
        self.payloads.count_spilled(&keys)
    }

    pub fn spill_count(&self) -> u64 {
        self.spills.load(Ordering::Relaxed)
    }

    pub fn fault_count(&self) -> u64 {
        self.faults.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn costs(exec: f64) -> SnapshotCosts {
        SnapshotCosts { exec_time: exec, serialize_cost: 0.4, restore_cost: 0.6 }
    }

    #[test]
    fn snapshots_expensive_calls_only() {
        let p = SnapshotPolicy::default();
        assert!(p.should_snapshot(costs(30.0))); // test-suite run
        assert!(!p.should_snapshot(costs(0.005))); // cat foo.py
        assert!(!p.should_snapshot(costs(0.9))); // cheaper than 1.0s overhead
        assert!(p.should_snapshot(costs(1.1)));
    }

    #[test]
    fn threshold_is_serialize_plus_restore() {
        let p = SnapshotPolicy::default();
        let c = SnapshotCosts { exec_time: 2.0, serialize_cost: 1.5, restore_cost: 1.0 };
        assert!(!p.should_snapshot(c)); // 2.0 < 2.5
        let c2 = SnapshotCosts { exec_time: 3.0, ..c };
        assert!(p.should_snapshot(c2));
    }

    #[test]
    fn disabled_never_snapshots() {
        let p = SnapshotPolicy::never();
        assert!(!p.should_snapshot(costs(1e9)));
    }

    #[test]
    fn always_snapshots_anything_nonzero() {
        let p = SnapshotPolicy::always();
        assert!(p.should_snapshot(costs(0.001)));
    }

    #[test]
    fn cost_factor_scales_threshold() {
        let p = SnapshotPolicy { cost_factor: 3.0, ..Default::default() };
        assert!(!p.should_snapshot(costs(2.5))); // needs > 3.0
        assert!(p.should_snapshot(costs(3.5)));
    }

    fn snap(n: usize) -> SandboxSnapshot {
        SandboxSnapshot { bytes: vec![0u8; n], serialize_cost: 0.1, restore_cost: 0.2 }
    }

    /// A snapshot whose content is distinguishable by `fill`.
    fn snap_fill(fill: u8, n: usize) -> SandboxSnapshot {
        SandboxSnapshot { bytes: vec![fill; n], serialize_cost: 0.1, restore_cost: 0.2 }
    }

    #[test]
    fn store_id_is_the_stored_key() {
        let store = SnapshotStore::default();
        let a = store.insert(snap(10));
        let b = store.insert(snap(20));
        assert_eq!(a, 1, "ids start at 1 (0 = wire sentinel)");
        assert_eq!(b, 2);
        // The returned id addresses exactly what was inserted.
        assert_eq!(store.get(a).unwrap().size(), 10);
        assert_eq!(store.get(b).unwrap().size(), 20);
        assert_eq!(store.total_bytes(), 30);
        store.remove(a);
        assert!(store.get(a).is_none());
        assert!(!store.contains(a));
        assert_eq!(store.total_bytes(), 20);
        assert_eq!(store.len(), 1);
    }

    #[test]
    fn strided_stores_hand_out_disjoint_ids() {
        let n = 4u64;
        let stores: Vec<SnapshotStore> =
            (0..n).map(|i| SnapshotStore::new(i + 1, n)).collect();
        let mut seen = std::collections::HashSet::new();
        for store in &stores {
            for _ in 0..16 {
                let id = store.insert(snap(1));
                assert!(id >= 1);
                assert!(seen.insert(id), "id {id} handed out twice");
                assert!(store.contains(id));
            }
        }
    }

    #[test]
    fn spill_demotes_and_get_faults_back_in() {
        let dir = std::env::temp_dir()
            .join(format!("tvcache-store-spill-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spill = Arc::new(SpillStore::open(&dir).unwrap());
        let store = SnapshotStore::with_spill(1, 1, spill);
        let id = store.insert(snap(64));
        assert!(store.is_resident(id));
        assert_eq!(store.resident_bytes(), 64);

        assert!(store.spill("t", id, 0.2));
        assert!(!store.is_resident(id));
        assert!(store.contains(id));
        assert_eq!(store.resident_bytes(), 0);
        assert_eq!(store.spilled_bytes(), 64);
        assert_eq!(store.total_bytes(), 64, "spilled bytes still count as stored");
        assert_eq!(store.spilled_count(), 1);
        assert_eq!(store.spill_count(), 1);

        // Fault-in: same payload, restore cost carries the disk penalty.
        let back = store.get(id).unwrap();
        assert_eq!(back.size(), 64);
        assert!((back.restore_cost - (0.2 + SPILL_FAULT_PENALTY)).abs() < 1e-12);
        assert_eq!(store.fault_count(), 1);

        // Re-spilling an already-spilled id is a no-op success.
        assert!(store.spill("t", id, 0.2));
        assert_eq!(store.spill_count(), 1);

        // Remove retracts the disk payload too.
        store.remove(id);
        assert!(store.get(id).is_none());
        assert_eq!(store.total_bytes(), 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn spill_without_tier_refuses() {
        let store = SnapshotStore::default();
        let id = store.insert(snap(8));
        assert!(!store.spill("t", id, 0.1));
        assert!(store.is_resident(id));
    }

    #[test]
    fn reserve_through_skips_reloaded_ids() {
        let store = SnapshotStore::new(2, 4); // ids 2, 6, 10, …
        store.reserve_through(9);
        let id = store.insert(snap(1));
        assert_eq!(id, 10);
    }

    #[test]
    fn concurrent_inserts_yield_unique_live_ids() {
        use std::sync::Arc;
        let store = Arc::new(SnapshotStore::default());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let s = Arc::clone(&store);
                std::thread::spawn(move || {
                    (0..50).map(|_| s.insert(snap(1))).collect::<Vec<u64>>()
                })
            })
            .collect();
        let mut all = Vec::new();
        for h in handles {
            all.extend(h.join().unwrap());
        }
        let unique: std::collections::HashSet<u64> = all.iter().copied().collect();
        assert_eq!(unique.len(), 200, "every insert got a distinct key");
        assert_eq!(store.len(), 200);
    }

    #[test]
    fn adopt_replicated_shares_bytes_once_per_key() {
        let store = SnapshotStore::default();
        let bytes = vec![6u8; 48];
        let key = ContentKey::of(&bytes);
        assert!(store.adopt_replicated(11, key, Some(bytes.clone()), 48, 0.1, 0.2));
        // A key-only attach of the same content shares the stored payload.
        assert!(store.adopt_replicated(13, key, None, 48, 0.1, 0.2));
        assert_eq!(store.payloads().payload_count(), 1, "one resident copy");
        assert_eq!(store.get(13).unwrap().bytes, bytes);
        // Re-applying the same op (follower re-pull) is idempotent.
        assert!(store.adopt_replicated(11, key, Some(bytes.clone()), 48, 0.1, 0.2));
        assert_eq!(store.payloads().ref_total(&key), 2);
        // A key-only attach of content never shipped is refused, not
        // fabricated from thin air.
        let unseen = ContentKey::of(b"never shipped");
        assert!(!store.adopt_replicated(15, unseen, None, 9, 0.1, 0.2));
        assert!(!store.contains(15));
        // Ids handed out locally after adoption never collide.
        let fresh = store.insert(snap(4));
        assert!(fresh > 13, "allocator advanced past adopted ids, got {fresh}");
    }

    // ---- content dedup + fault cache ----

    #[test]
    fn identical_content_is_stored_once_and_shared_across_stores() {
        let payloads = Arc::new(PayloadStore::new(None, 0));
        let a = SnapshotStore::with_payloads(1, 2, Arc::clone(&payloads));
        let b = SnapshotStore::with_payloads(2, 2, Arc::clone(&payloads));

        let ia = a.insert(snap_fill(7, 100));
        let ib = b.insert(snap_fill(7, 100));
        assert_ne!(ia, ib, "handles keep distinct ids");
        assert_eq!(payloads.payload_count(), 1, "one resident copy");
        assert_eq!(payloads.dedup_hits(), 1);
        assert_eq!(payloads.dedup_resident_bytes_saved(), 100);
        assert!(a.payload_shared(ia) && b.payload_shared(ib));
        // Charged once — to the first inserter.
        assert_eq!(a.resident_bytes(), 100);
        assert_eq!(b.resident_bytes(), 0);

        // Both handles read back the same content independently.
        assert_eq!(a.get(ia).unwrap().bytes, vec![7u8; 100]);
        assert_eq!(b.get(ib).unwrap().bytes, vec![7u8; 100]);

        // Removing one referent keeps the bytes; the charge moves over.
        a.remove(ia);
        assert!(a.get(ia).is_none());
        assert_eq!(b.get(ib).unwrap().bytes, vec![7u8; 100]);
        assert_eq!(a.resident_bytes(), 0);
        assert_eq!(b.resident_bytes(), 100);
        b.remove(ib);
        assert_eq!(payloads.payload_count(), 0);
        assert_eq!(b.resident_bytes(), 0);
    }

    #[test]
    fn second_fault_in_is_served_by_the_cache_without_a_disk_read() {
        let dir = std::env::temp_dir()
            .join(format!("tvcache-store-fcache-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spill = Arc::new(SpillStore::open(&dir).unwrap());
        let store = SnapshotStore::with_spill(1, 1, spill);
        let id = store.insert(snap_fill(3, 128));
        assert!(store.spill("t", id, 0.2));

        let first = store.get(id).unwrap();
        assert!((first.restore_cost - (0.2 + SPILL_FAULT_PENALTY)).abs() < 1e-12);
        assert_eq!(store.fault_count(), 1);
        assert_eq!(store.payloads().fault_cache_misses(), 1);

        // Same spilled payload again: cache hit — no disk read, no fault,
        // no read penalty.
        let second = store.get(id).unwrap();
        assert_eq!(second.bytes, first.bytes);
        assert!((second.restore_cost - 0.2).abs() < 1e-12);
        assert_eq!(store.fault_count(), 1, "no second disk fault");
        assert_eq!(store.payloads().fault_cache_hits(), 1);
        assert_eq!(store.payloads().fault_cache_misses(), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn spilling_one_shared_handle_demotes_all_and_writes_once() {
        let dir = std::env::temp_dir()
            .join(format!("tvcache-store-shared-spill-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spill = Arc::new(SpillStore::open(&dir).unwrap());
        let payloads =
            Arc::new(PayloadStore::new(Some(Arc::clone(&spill)), DEFAULT_FAULT_CACHE_BYTES));
        let a = SnapshotStore::with_payloads(1, 2, Arc::clone(&payloads));
        let b = SnapshotStore::with_payloads(2, 2, Arc::clone(&payloads));
        let ia = a.insert(snap_fill(5, 80));
        let ib = b.insert(snap_fill(5, 80));

        assert!(a.spill("ta", ia, 0.2));
        assert_eq!(a.spill_count(), 1);
        // The shared payload is now on disk for *both* handles.
        assert!(!a.is_resident(ia) && !b.is_resident(ib));
        assert_eq!(b.spilled_count(), 1);
        // Re-spilling via the other handle is a no-op (bytes already there).
        assert!(b.spill("tb", ib, 0.2));
        assert_eq!(b.spill_count(), 0, "no second demotion happened");
        assert_eq!(b.get(ib).unwrap().bytes, vec![5u8; 80]);

        // Removing one handle keeps the shared disk payload alive.
        let path = a.spilled_slot(ia).unwrap().path;
        a.remove(ia);
        assert!(path.exists());
        assert_eq!(b.get(ib).unwrap().bytes, vec![5u8; 80]);
        b.remove(ib);
        assert!(!path.exists(), "last referent retracts the disk payload");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
