//! Content-addressed snapshot payload store with cross-task dedup and an
//! LRU fault cache over the spill tier.
//!
//! Parallel rollouts routinely reach *identical* sandbox states (same
//! files, same DB, same container layer). Before this layer every
//! [`super::SnapshotStore`] kept its own private copy of each payload, so
//! K tasks at the same state paid K× the bytes. Now every payload is keyed
//! by a 256-bit content hash ([`ContentKey`]) computed once at insert and
//! refcounted across all tasks and shards: identical states share one
//! resident (or one spilled) copy, and the per-shard stores hold
//! `(content_key, size, restore_cost)` handles instead of owned bytes.
//!
//! Accounting follows a *charge-owner* model: each payload's bytes are
//! charged to exactly one registered store (the first inserter); when that
//! store drops its last reference while others remain, the charge moves to
//! a surviving referent. A store's `resident_bytes`/`spilled_bytes` are
//! therefore sums of the payloads it is charged for — shared bytes are
//! never double-counted against the byte budget.
//!
//! Fault-ins from the spill tier go through a byte-budgeted LRU fault
//! cache: a hot spilled payload is read from disk once and served from
//! memory thereafter (no [`super::spill::SPILL_FAULT_PENALTY`] charge on a
//! cache hit). Because entries are content-addressed, a stale cache entry
//! can never serve wrong bytes — same key, same content, by construction.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::spill::{SpillSlot, SpillStore};

/// Default byte budget for the spill-tier fault cache (16 MiB).
pub const DEFAULT_FAULT_CACHE_BYTES: u64 = 16 * 1024 * 1024;

/// 256-bit content hash of a snapshot payload.
///
/// Four independently-seeded 64-bit lanes of an xxHash-style mix — not
/// cryptographic, but at 2⁻¹²⁸ collision scale for the cache's working-set
/// sizes, which is what content addressing needs here. Computed once at
/// insert; equality of keys is treated as equality of content.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ContentKey(pub [u64; 4]);

const P1: u64 = 0x9E37_79B1_85EB_CA87;
const P2: u64 = 0xC2B2_AE3D_27D4_EB4F;
const P3: u64 = 0x1656_67B1_9E37_79F9;
const P4: u64 = 0x85EB_CA77_C2B2_AE63;
const P5: u64 = 0x27D4_EB2F_1656_67C5;

/// One seeded 64-bit lane over `bytes` (xxHash64-style rounds + avalanche).
fn hash64(bytes: &[u8], seed: u64) -> u64 {
    let mut acc = seed.wrapping_add(P5).wrapping_add(bytes.len() as u64);
    let mut chunks = bytes.chunks_exact(8);
    for c in &mut chunks {
        let w = u64::from_le_bytes(c.try_into().unwrap());
        acc ^= w.wrapping_mul(P2).rotate_left(31).wrapping_mul(P1);
        acc = acc.rotate_left(27).wrapping_mul(P1).wrapping_add(P4);
    }
    for &b in chunks.remainder() {
        acc ^= (b as u64).wrapping_mul(P5);
        acc = acc.rotate_left(11).wrapping_mul(P1);
    }
    acc ^= acc >> 33;
    acc = acc.wrapping_mul(P2);
    acc ^= acc >> 29;
    acc = acc.wrapping_mul(P3);
    acc ^= acc >> 32;
    acc
}

impl ContentKey {
    /// Hash `bytes` into a key. The four lane seeds are the first four
    /// SHA-256 IV words — arbitrary, fixed, and mutually independent.
    pub fn of(bytes: &[u8]) -> ContentKey {
        ContentKey([
            hash64(bytes, 0x6A09_E667_F3BC_C908),
            hash64(bytes, 0xBB67_AE85_84CA_A73B),
            hash64(bytes, 0x3C6E_F372_FE94_F82B),
            hash64(bytes, 0xA54F_F53A_5F1D_36F1),
        ])
    }

    /// A key for a legacy (pre-content-hash) spilled payload identified
    /// only by its snapshot id. All-ones upper lanes keep synthetic keys
    /// disjoint from real hashes except at negligible probability; two
    /// legacy records never dedup against each other (distinct ids).
    pub fn synthetic(id: u64) -> ContentKey {
        ContentKey([u64::MAX, u64::MAX, u64::MAX ^ id, id])
    }

    /// 64-hex-char encoding (manifest column / payload file name).
    pub fn to_hex(&self) -> String {
        format!(
            "{:016x}{:016x}{:016x}{:016x}",
            self.0[0], self.0[1], self.0[2], self.0[3]
        )
    }

    /// Parse [`ContentKey::to_hex`] output; `None` on any malformation.
    pub fn from_hex(s: &str) -> Option<ContentKey> {
        if s.len() != 64 || !s.is_ascii() {
            return None;
        }
        let mut lanes = [0u64; 4];
        for (i, lane) in lanes.iter_mut().enumerate() {
            *lane = u64::from_str_radix(&s[i * 16..(i + 1) * 16], 16).ok()?;
        }
        Some(ContentKey(lanes))
    }
}

/// Where [`PayloadStore::fetch`] found the bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FetchSource {
    /// In the resident tier — no charge.
    Resident,
    /// In the LRU fault cache — spilled, but served from memory.
    FaultCache,
    /// Read from the spill tier on disk (the caller charges the fault
    /// penalty and counts a disk fault).
    Disk,
}

/// Outcome of [`PayloadStore::insert`] / [`PayloadStore::adopt`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InsertOutcome {
    /// First copy of this content — bytes now charged to the inserter.
    New,
    /// Content already stored: the reference was shared (a dedup hit).
    Deduped,
}

/// Outcome of [`PayloadStore::spill`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpillOutcome {
    /// This call demoted the payload from memory to disk.
    Demoted,
    /// The payload already lived on disk — a no-op success.
    AlreadySpilled,
    /// No spill tier configured.
    Refused,
    /// The payload vanished concurrently (all referents removed).
    Gone,
    /// The disk write failed.
    Failed,
}

/// One stored payload: bytes (or their on-disk slot), per-store refcounts,
/// and the store currently charged for the bytes.
#[derive(Debug)]
struct Payload {
    len: u64,
    tier: Tier,
    /// Live references per registered store tag.
    refs: HashMap<u32, u64>,
    /// The tag whose `resident_bytes`/`spilled_bytes` carry this payload.
    charged: u32,
}

#[derive(Debug)]
enum Tier {
    Resident(Arc<Vec<u8>>),
    Spilled(SpillSlot),
}

impl Payload {
    fn ref_total(&self) -> u64 {
        self.refs.values().sum()
    }
}

/// Shared table state: payloads by key plus per-tag byte gauges.
#[derive(Debug, Default)]
struct Table {
    payloads: HashMap<ContentKey, Payload>,
    resident_by: Vec<u64>,
    spilled_by: Vec<u64>,
}

/// Byte-budgeted LRU over fault-in reads: key → (bytes, LRU sequence).
#[derive(Debug)]
struct FaultCache {
    budget: u64,
    used: u64,
    seq: u64,
    map: HashMap<ContentKey, (Arc<Vec<u8>>, u64)>,
    order: BTreeMap<u64, ContentKey>,
}

impl FaultCache {
    fn new(budget: u64) -> FaultCache {
        FaultCache { budget, used: 0, seq: 0, map: HashMap::new(), order: BTreeMap::new() }
    }

    fn get(&mut self, key: &ContentKey) -> Option<Arc<Vec<u8>>> {
        let seq = self.seq + 1;
        let (bytes, old) = self.map.get_mut(key)?;
        self.order.remove(old);
        *old = seq;
        self.seq = seq;
        let out = Arc::clone(bytes);
        self.order.insert(seq, *key);
        Some(out)
    }

    /// Insert (or refresh) `key`; returns how many entries were evicted to
    /// make room. Oversized payloads are not cached at all.
    fn insert(&mut self, key: ContentKey, bytes: Arc<Vec<u8>>) -> u64 {
        let len = bytes.len() as u64;
        if len > self.budget {
            return 0;
        }
        if self.map.contains_key(&key) {
            let _ = self.get(&key); // refresh recency
            return 0;
        }
        let mut evicted = 0;
        while self.used + len > self.budget {
            let Some((&oldest, _)) = self.order.iter().next() else { break };
            let victim = self.order.remove(&oldest).unwrap();
            if let Some((b, _)) = self.map.remove(&victim) {
                self.used -= b.len() as u64;
            }
            evicted += 1;
        }
        self.seq += 1;
        self.order.insert(self.seq, key);
        self.map.insert(key, (bytes, self.seq));
        self.used += len;
        evicted
    }

    fn remove(&mut self, key: &ContentKey) {
        if let Some((bytes, seq)) = self.map.remove(key) {
            self.used -= bytes.len() as u64;
            self.order.remove(&seq);
        }
    }
}

/// The content-addressed payload table shared by every [`super::SnapshotStore`]
/// of a service, plus the spill tier handle and the fault cache.
///
/// Stores register once (getting a `tag`) and then insert/release
/// references under that tag; the table keeps per-tag byte gauges under
/// the charge-owner model described in the module docs.
#[derive(Debug)]
pub struct PayloadStore {
    table: Mutex<Table>,
    fault_cache: Mutex<FaultCache>,
    spill: Option<Arc<SpillStore>>,
    dedup_hits: AtomicU64,
    fc_hits: AtomicU64,
    fc_misses: AtomicU64,
    fc_evictions: AtomicU64,
}

impl PayloadStore {
    /// A payload table over an optional spill tier, with a fault cache of
    /// `fault_cache_bytes` (0 disables the cache).
    pub fn new(spill: Option<Arc<SpillStore>>, fault_cache_bytes: u64) -> PayloadStore {
        PayloadStore {
            table: Mutex::new(Table::default()),
            fault_cache: Mutex::new(FaultCache::new(fault_cache_bytes)),
            spill,
            dedup_hits: AtomicU64::new(0),
            fc_hits: AtomicU64::new(0),
            fc_misses: AtomicU64::new(0),
            fc_evictions: AtomicU64::new(0),
        }
    }

    /// Register a referencing store; the returned tag scopes its byte
    /// gauges and refcounts.
    pub fn register(&self) -> u32 {
        let mut t = self.table.lock().unwrap();
        t.resident_by.push(0);
        t.spilled_by.push(0);
        (t.resident_by.len() - 1) as u32
    }

    /// Whether a spill tier is attached.
    pub fn has_spill(&self) -> bool {
        self.spill.is_some()
    }

    /// The attached spill tier, if any.
    pub fn spill_store(&self) -> Option<&Arc<SpillStore>> {
        self.spill.as_ref()
    }

    /// Store one reference to `bytes` under `key` for store `tag`. If the
    /// content is already present the bytes are dropped and the reference
    /// shared ([`InsertOutcome::Deduped`]); otherwise the payload becomes
    /// resident, charged to `tag`.
    pub fn insert(&self, tag: u32, key: ContentKey, bytes: Vec<u8>) -> InsertOutcome {
        let mut t = self.table.lock().unwrap();
        let tbl = &mut *t;
        if let Some(p) = tbl.payloads.get_mut(&key) {
            *p.refs.entry(tag).or_insert(0) += 1;
            self.dedup_hits.fetch_add(1, Ordering::Relaxed);
            return InsertOutcome::Deduped;
        }
        let len = bytes.len() as u64;
        tbl.resident_by[tag as usize] += len;
        let mut refs = HashMap::new();
        refs.insert(tag, 1);
        tbl.payloads.insert(
            key,
            Payload { len, tier: Tier::Resident(Arc::new(bytes)), refs, charged: tag },
        );
        InsertOutcome::New
    }

    /// Register a reference to a payload that already lives on disk
    /// (warm-start reload). A key already present simply gains a shared
    /// reference — deduped payloads rehydrate shared.
    pub fn adopt(&self, tag: u32, key: ContentKey, slot: SpillSlot) -> InsertOutcome {
        let mut t = self.table.lock().unwrap();
        let tbl = &mut *t;
        if let Some(p) = tbl.payloads.get_mut(&key) {
            *p.refs.entry(tag).or_insert(0) += 1;
            self.dedup_hits.fetch_add(1, Ordering::Relaxed);
            return InsertOutcome::Deduped;
        }
        let len = slot.bytes;
        tbl.spilled_by[tag as usize] += len;
        let mut refs = HashMap::new();
        refs.insert(tag, 1);
        tbl.payloads.insert(key, Payload { len, tier: Tier::Spilled(slot), refs, charged: tag });
        InsertOutcome::New
    }

    /// Fetch the bytes behind `key`, faulting from disk through the LRU
    /// fault cache when spilled. The [`FetchSource`] tells the caller
    /// whether a disk read actually happened.
    pub fn fetch(&self, key: &ContentKey) -> Option<(Arc<Vec<u8>>, FetchSource)> {
        let slot = {
            let t = self.table.lock().unwrap();
            match t.payloads.get(key) {
                None => return None,
                Some(p) => match &p.tier {
                    Tier::Resident(b) => return Some((Arc::clone(b), FetchSource::Resident)),
                    Tier::Spilled(s) => s.clone(),
                },
            }
        };
        if let Some(hit) = self.fault_cache.lock().unwrap().get(key) {
            self.fc_hits.fetch_add(1, Ordering::Relaxed);
            return Some((hit, FetchSource::FaultCache));
        }
        self.fc_misses.fetch_add(1, Ordering::Relaxed);
        // Disk read outside both locks.
        let snap = slot.fault()?;
        let bytes = Arc::new(snap.bytes);
        let evicted = self.fault_cache.lock().unwrap().insert(*key, Arc::clone(&bytes));
        self.fc_evictions.fetch_add(evicted, Ordering::Relaxed);
        Some((bytes, FetchSource::Disk))
    }

    /// Drop store `tag`'s reference (handle `id`) to `key`. Bytes are only
    /// freed — and the disk slot only retracted — when the *last* referent
    /// across all stores dies; losing the charging store's last reference
    /// while others remain moves the charge to a survivor.
    pub fn release(&self, tag: u32, key: ContentKey, id: u64) {
        enum Disk {
            None,
            DropRecord,
            DropPayloadAt(std::path::PathBuf),
            RemoveFile(std::path::PathBuf),
        }
        let mut action = Disk::None;
        {
            let mut t = self.table.lock().unwrap();
            let tbl = &mut *t;
            let Some(p) = tbl.payloads.get_mut(&key) else { return };
            match p.refs.get_mut(&tag) {
                Some(n) if *n > 0 => *n -= 1,
                _ => return, // tag held no reference: nothing to release
            }
            if p.ref_total() == 0 {
                let p = tbl.payloads.remove(&key).unwrap();
                match p.tier {
                    Tier::Resident(_) => {
                        let g = &mut tbl.resident_by[p.charged as usize];
                        *g = g.saturating_sub(p.len);
                    }
                    Tier::Spilled(slot) => {
                        let g = &mut tbl.spilled_by[p.charged as usize];
                        *g = g.saturating_sub(p.len);
                        action = match &self.spill {
                            Some(sp) if slot.path.parent() == Some(sp.dir()) => {
                                Disk::DropPayloadAt(slot.path)
                            }
                            // Adopted from a foreign dir (or no tier):
                            // deleting the file suffices — manifest reload
                            // discards records whose file is gone.
                            _ => Disk::RemoveFile(slot.path),
                        };
                    }
                }
                self.fault_cache.lock().unwrap().remove(&key);
            } else {
                let resident = matches!(p.tier, Tier::Resident(_));
                if p.refs.get(&tag) == Some(&0) {
                    p.refs.remove(&tag);
                    if p.charged == tag {
                        // Move the byte charge to a surviving referent.
                        let new = *p.refs.keys().next().unwrap();
                        let len = p.len;
                        p.charged = new;
                        let gauges = if resident {
                            &mut tbl.resident_by
                        } else {
                            &mut tbl.spilled_by
                        };
                        gauges[tag as usize] = gauges[tag as usize].saturating_sub(len);
                        gauges[new as usize] += len;
                    }
                }
                if !resident {
                    action = Disk::DropRecord;
                }
            }
        }
        match (action, &self.spill) {
            (Disk::DropPayloadAt(path), Some(sp)) => sp.drop_payload_at(id, &path),
            (Disk::DropRecord, Some(sp)) => sp.drop_record(id),
            (Disk::RemoveFile(path), _) => {
                let _ = std::fs::remove_file(path);
            }
            _ => {}
        }
    }

    /// Demote `key`'s payload to the spill tier, recording handle `id` in
    /// the manifest. The byte write is skipped when the content already
    /// has a live disk slot (cross-task spill dedup).
    pub fn spill(
        &self,
        key: ContentKey,
        task: &str,
        id: u64,
        serialize_cost: f64,
        restore_cost: f64,
    ) -> SpillOutcome {
        let Some(sp) = &self.spill else { return SpillOutcome::Refused };
        let bytes = {
            let t = self.table.lock().unwrap();
            match t.payloads.get(&key) {
                None => return SpillOutcome::Gone,
                Some(p) => match &p.tier {
                    Tier::Spilled(_) => return SpillOutcome::AlreadySpilled,
                    Tier::Resident(b) => Arc::clone(b),
                },
            }
        };
        // File + manifest I/O outside the table lock; swap the tier after.
        let Ok(slot) = sp.write_keyed(task, id, key, &bytes, serialize_cost, restore_cost)
        else {
            return SpillOutcome::Failed;
        };
        let mut retract = false;
        let out = {
            let mut t = self.table.lock().unwrap();
            let tbl = &mut *t;
            match tbl.payloads.get_mut(&key) {
                None => {
                    // All referents vanished while we wrote: retract.
                    retract = true;
                    SpillOutcome::Gone
                }
                Some(p) => {
                    if matches!(p.tier, Tier::Spilled(_)) {
                        // A concurrent spill (same content, another handle)
                        // won; our record stays — it names the same file.
                        SpillOutcome::AlreadySpilled
                    } else {
                        let len = p.len;
                        let charged = p.charged as usize;
                        p.tier = Tier::Spilled(slot);
                        tbl.resident_by[charged] =
                            tbl.resident_by[charged].saturating_sub(len);
                        tbl.spilled_by[charged] += len;
                        SpillOutcome::Demoted
                    }
                }
            }
        };
        if retract {
            sp.drop_payload(id);
        }
        out
    }

    /// True when `key` is stored with its bytes in memory.
    pub fn is_resident(&self, key: &ContentKey) -> bool {
        matches!(
            self.table.lock().unwrap().payloads.get(key).map(|p| &p.tier),
            Some(Tier::Resident(_))
        )
    }

    /// How many of `keys` currently live in the spill tier (one table lock
    /// for the whole batch; duplicates count once per occurrence).
    pub fn count_spilled(&self, keys: &[ContentKey]) -> usize {
        let t = self.table.lock().unwrap();
        keys.iter()
            .filter(|k| matches!(t.payloads.get(k).map(|p| &p.tier), Some(Tier::Spilled(_))))
            .count()
    }

    /// The on-disk slot behind `key`, when spilled.
    pub fn spilled_slot(&self, key: &ContentKey) -> Option<SpillSlot> {
        match self.table.lock().unwrap().payloads.get(key).map(|p| &p.tier) {
            Some(Tier::Spilled(s)) => Some(s.clone()),
            _ => None,
        }
    }

    /// Total live references to `key` across all stores (0 = absent).
    pub fn ref_total(&self, key: &ContentKey) -> u64 {
        self.table
            .lock()
            .unwrap()
            .payloads
            .get(key)
            .map(|p| p.ref_total())
            .unwrap_or(0)
    }

    /// Resident bytes charged to store `tag`.
    pub fn resident_bytes_of(&self, tag: u32) -> u64 {
        self.table.lock().unwrap().resident_by[tag as usize]
    }

    /// Spilled bytes charged to store `tag`.
    pub fn spilled_bytes_of(&self, tag: u32) -> u64 {
        self.table.lock().unwrap().spilled_by[tag as usize]
    }

    /// Distinct payloads currently stored.
    pub fn payload_count(&self) -> usize {
        self.table.lock().unwrap().payloads.len()
    }

    /// Lifetime inserts/adopts that shared an existing payload.
    pub fn dedup_hits(&self) -> u64 {
        self.dedup_hits.load(Ordering::Relaxed)
    }

    /// Resident bytes avoided right now by sharing: Σ len × (refs − 1)
    /// over resident payloads.
    pub fn dedup_resident_bytes_saved(&self) -> u64 {
        let t = self.table.lock().unwrap();
        t.payloads
            .values()
            .filter(|p| matches!(p.tier, Tier::Resident(_)))
            .map(|p| p.len * p.ref_total().saturating_sub(1))
            .sum()
    }

    /// Fault-ins served from the LRU fault cache (no disk read).
    pub fn fault_cache_hits(&self) -> u64 {
        self.fc_hits.load(Ordering::Relaxed)
    }

    /// Fault-ins that had to read the spill tier.
    pub fn fault_cache_misses(&self) -> u64 {
        self.fc_misses.load(Ordering::Relaxed)
    }

    /// Entries evicted from the fault cache to respect its byte budget.
    pub fn fault_cache_evictions(&self) -> u64 {
        self.fc_evictions.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn content_key_is_stable_and_content_sensitive() {
        let a = ContentKey::of(b"hello sandbox");
        assert_eq!(a, ContentKey::of(b"hello sandbox"));
        assert_ne!(a, ContentKey::of(b"hello sandboy"));
        assert_ne!(a, ContentKey::of(b"hello sandbox "));
        assert_ne!(ContentKey::of(b""), ContentKey::of(b"\0"));
        // Length is mixed in: a prefix never collides with its extension.
        assert_ne!(ContentKey::of(&[0u8; 8]), ContentKey::of(&[0u8; 16]));
    }

    #[test]
    fn hex_roundtrip_and_rejects() {
        let k = ContentKey::of(b"roundtrip me");
        let hex = k.to_hex();
        assert_eq!(hex.len(), 64);
        assert_eq!(ContentKey::from_hex(&hex), Some(k));
        assert_eq!(ContentKey::from_hex("abc"), None);
        assert_eq!(ContentKey::from_hex(&"g".repeat(64)), None);
        let synth = ContentKey::synthetic(42);
        assert_eq!(ContentKey::from_hex(&synth.to_hex()), Some(synth));
        assert_ne!(ContentKey::synthetic(1), ContentKey::synthetic(2));
    }

    #[test]
    fn dedup_shares_one_resident_copy_and_charges_once() {
        let store = PayloadStore::new(None, 0);
        let a = store.register();
        let b = store.register();
        let key = ContentKey::of(&[9u8; 100]);
        assert_eq!(store.insert(a, key, vec![9u8; 100]), InsertOutcome::New);
        assert_eq!(store.insert(b, key, vec![9u8; 100]), InsertOutcome::Deduped);
        assert_eq!(store.insert(b, key, vec![9u8; 100]), InsertOutcome::Deduped);
        assert_eq!(store.dedup_hits(), 2);
        assert_eq!(store.ref_total(&key), 3);
        assert_eq!(store.payload_count(), 1);
        assert_eq!(store.resident_bytes_of(a), 100, "charged to the first inserter");
        assert_eq!(store.resident_bytes_of(b), 0, "shared bytes are not double-charged");
        assert_eq!(store.dedup_resident_bytes_saved(), 200);

        // Dropping the charging store's last ref moves the charge.
        store.release(a, key, 1);
        assert_eq!(store.ref_total(&key), 2);
        assert_eq!(store.resident_bytes_of(a), 0);
        assert_eq!(store.resident_bytes_of(b), 100);
        assert!(store.is_resident(&key));

        store.release(b, key, 2);
        store.release(b, key, 3);
        assert_eq!(store.ref_total(&key), 0);
        assert_eq!(store.resident_bytes_of(b), 0);
        assert_eq!(store.payload_count(), 0);
        assert!(store.fetch(&key).is_none());
    }

    #[test]
    fn fetch_reports_where_bytes_came_from() {
        let dir = std::env::temp_dir()
            .join(format!("tvcache-payload-fetch-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spill = Arc::new(SpillStore::open(&dir).unwrap());
        let store = PayloadStore::new(Some(spill), 1024);
        let tag = store.register();
        let key = ContentKey::of(&[4u8; 64]);
        store.insert(tag, key, vec![4u8; 64]);
        assert_eq!(store.fetch(&key).unwrap().1, FetchSource::Resident);

        assert_eq!(store.spill(key, "t", 1, 0.1, 0.2), SpillOutcome::Demoted);
        assert_eq!(store.spill(key, "t", 1, 0.1, 0.2), SpillOutcome::AlreadySpilled);
        assert_eq!(store.resident_bytes_of(tag), 0);
        assert_eq!(store.spilled_bytes_of(tag), 64);

        // First fault reads disk; the second is served by the LRU cache.
        let (bytes, src) = store.fetch(&key).unwrap();
        assert_eq!(src, FetchSource::Disk);
        assert_eq!(*bytes, vec![4u8; 64]);
        let (bytes, src) = store.fetch(&key).unwrap();
        assert_eq!(src, FetchSource::FaultCache);
        assert_eq!(*bytes, vec![4u8; 64]);
        assert_eq!(store.fault_cache_misses(), 1);
        assert_eq!(store.fault_cache_hits(), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fault_cache_evicts_lru_within_budget() {
        let mut fc = FaultCache::new(100);
        let (a, b, c) = (
            ContentKey::of(b"a"),
            ContentKey::of(b"b"),
            ContentKey::of(b"c"),
        );
        assert_eq!(fc.insert(a, Arc::new(vec![0; 60])), 0);
        assert_eq!(fc.insert(b, Arc::new(vec![0; 40])), 0);
        // Touch `a` so `b` is the LRU victim.
        assert!(fc.get(&a).is_some());
        assert_eq!(fc.insert(c, Arc::new(vec![0; 40])), 1);
        assert!(fc.get(&b).is_none(), "LRU entry evicted");
        assert!(fc.get(&a).is_some());
        assert!(fc.get(&c).is_some());
        assert!(fc.used <= 100);
        // Oversized payloads are passed through, not cached.
        assert_eq!(fc.insert(ContentKey::of(b"big"), Arc::new(vec![0; 101])), 0);
        assert!(fc.get(&ContentKey::of(b"big")).is_none());
    }

    #[test]
    fn shared_spilled_payload_keeps_its_file_until_last_referent_dies() {
        let dir = std::env::temp_dir()
            .join(format!("tvcache-payload-shared-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spill = Arc::new(SpillStore::open(&dir).unwrap());
        let store = PayloadStore::new(Some(Arc::clone(&spill)), 0);
        let tag = store.register();
        let key = ContentKey::of(&[8u8; 32]);
        store.insert(tag, key, vec![8u8; 32]);
        store.insert(tag, key, vec![8u8; 32]);
        assert_eq!(store.spill(key, "t", 1, 0.1, 0.2), SpillOutcome::Demoted);
        let path = store.spilled_slot(&key).unwrap().path;
        assert!(path.exists());

        store.release(tag, key, 1);
        assert!(path.exists(), "file must survive while a referent remains");
        assert!(store.fetch(&key).is_some());
        store.release(tag, key, 2);
        assert!(!path.exists(), "last release retracts the disk slot");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
