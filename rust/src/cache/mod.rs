//! The TVCACHE core (§3): tool call graph, longest-prefix matching,
//! selective snapshotting, refcount-guarded eviction, and task sharding.

pub mod eviction;
pub mod key;
pub mod lpm;
pub mod shard;
pub mod snapshot;
pub mod store;
pub mod tcg;

pub use eviction::EvictionPolicy;
pub use key::{ToolCall, ToolResult};
pub use lpm::{Lookup, LpmConfig, Miss};
pub use shard::{Shard, ShardRouter};
pub use snapshot::{SnapshotCosts, SnapshotPolicy};
pub use store::{CacheStats, TaskCache};
pub use tcg::{NodeId, SnapshotRef, Tcg, ROOT};
