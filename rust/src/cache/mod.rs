//! The TVCACHE core (§3): tool call graph, longest-prefix matching,
//! selective snapshotting, refcount-guarded byte-budgeted eviction with a
//! spill-to-disk tier, and task sharding — unified behind the
//! [`CacheBackend`] trait, whose in-process implementation is the
//! [`ShardedCacheService`].

pub mod backend;
pub mod eviction;
pub mod key;
pub mod lpm;
pub mod oplog;
pub mod payload;
pub mod service;
pub mod shard;
pub mod snapshot;
pub mod spill;
pub mod store;
pub mod tcg;
pub mod wal;

pub use backend::{
    BackendStats, CacheBackend, Capabilities, SessionBackend, TurnBatch, TurnOp, TurnReply,
};
pub use eviction::{enforce_budget, recreation_cost, EvictionPolicy};
pub use key::{ToolCall, ToolResult};
pub use lpm::{CursorStep, Lookup, LpmConfig, Miss};
pub use oplog::{Op, OpLog, DEFAULT_OPLOG_WINDOW};
pub use payload::{ContentKey, FetchSource, PayloadStore, DEFAULT_FAULT_CACHE_BYTES};
pub use service::{ServiceConfig, ShardedCacheService};
pub use shard::{CacheFactory, Shard, ShardRouter};
pub use snapshot::{SnapshotCosts, SnapshotPolicy, SnapshotStore};
pub use spill::{SpillSlot, SpillStore, SPILL_FAULT_PENALTY};
pub use store::{CacheStats, TaskCache};
pub use tcg::{NodeId, SnapshotRef, Tcg, ROOT};
pub use wal::{Wal, WalOptions, DEFAULT_FSYNC_EVERY, DEFAULT_SEGMENT_BYTES};
