//! The TVCACHE server (§3.4, Figure 4): an HTTP service fronting the
//! in-process [`ShardedCacheService`] — per-task TCGs and sandbox snapshots
//! sharded by `hash(task_id)` (§4.5), each shard with its own task map and
//! snapshot store, so no request path holds a global lock.
//!
//! Endpoints (mirroring the paper's API):
//!
//! * `POST /get`           — LPM lookup (hit, or miss + resume info);
//!   **binary or JSON** body (first-byte sniff, see [`crate::wire`])
//! * `POST /prefix_match`  — JSON alias of `/get` (legacy clients)
//! * `POST /put`           — insert an executed trajectory (binary or JSON)
//! * `POST /release`       — decrement a node's sandbox refcount (binary
//!   or JSON)
//! * `POST /cursor_open`   — open a lookup cursor for a rollout (binary)
//! * `POST /cursor_step`   — O(1) incremental lookup of the delta call
//!   (binary; the hot endpoint)
//! * `POST /cursor_record` — record the executed delta at the cursor
//!   (binary)
//! * `POST /cursor_seek`   — re-seat a cursor after a fallback (binary)
//! * `POST /cursor_close`  — drop a cursor (binary)
//! * `POST /capabilities`  — binary capability handshake: negotiated once
//!   per binding instead of sniffing every request (`GET` = JSON debug view)
//! * `POST /session_turn`  — one reasoning turn's batched ops: speculative
//!   stateless probes + at most one stateful step/record, in one frame
//! * `POST /session_release` — return a session-owned resume pin (binary)
//! * `POST /snapshot`      — store a serialized sandbox for a node
//! * `GET  /snapshot`      — fetch snapshot bytes (`?task=&id=`)
//! * `POST /warm`          — mark a node's background fork warm
//! * `GET  /warm`          — query a node's warm-fork flag (`?task=&node=`)
//! * `POST /persist`       — persist all TCGs + snapshot payloads (`{dir}`)
//! * `POST /warm_start`    — warm-start from a persisted dir (`{dir}`)
//! * `GET  /stats`         — per-task (`?task=`) or service-wide statistics
//!   (service-wide includes spill-tier occupancy / fault / eviction counters)
//! * `GET  /viz`           — TCG structure as JSON (Figure 9)
//! * `GET  /ping`          — liveness
//! * `GET  /replicate`     — pull a batch of op-log entries (`?from=<seq>`,
//!   binary; primaries only — see below)
//! * `GET  /bootstrap`     — seq-stamped checkpoint of the whole service
//!   state (JSON; primaries with an op-log only): a window-gapped follower
//!   installs it and resumes tailing from its `seq` instead of freezing
//! * `POST /promote`       — promote a follower to primary (bumps the
//!   fencing epoch); idempotent no-op on a server that is already primary
//! * `POST /drain`         — graceful shutdown: stop admitting sessions,
//!   wait (bounded) for the follower to catch up, optionally persist
//!
//! # Replication
//!
//! A primary built with [`crate::cache::ServiceConfig::replicate_window`]
//! records every state mutation in a sequence-numbered op-log. A warm
//! follower ([`serve_follower`]) tails that log over `GET /replicate` on a
//! background thread and applies the ops into its own service, staying
//! read-only (mutating endpoints answer `503`) until `POST /promote` flips
//! it. Every sealed binary response carries the server's fencing epoch in
//! its trailer; promotion bumps the epoch past anything the old primary
//! could have stamped, so clients that already failed over reject a revived
//! stale primary's answers (split-brain guard).
//!
//! The hot endpoints speak the length-prefixed binary codec of
//! [`crate::wire`]; the cold admin endpoints (`/stats`, `/persist`,
//! `/warm_start`, `/viz`, `/snapshot`, `/warm`) remain JSON and stay the
//! authoritative human-debuggable surface. Every handler programs against
//! the [`CacheBackend`] trait — the same surface the executor and the
//! training loops use in-process.

use std::net::SocketAddr;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::cache::key::{trajectory_from_json, trajectory_json_into, ToolCall};
use crate::cache::{
    CacheBackend, CacheFactory, Capabilities, CursorStep, Lookup, SessionBackend,
    ShardedCacheService, TaskCache, ToolResult, TurnReply,
};
use crate::sandbox::SandboxSnapshot;
use crate::util::fault;
use crate::util::http::{Handler, HttpClient, Request, Response, Server};
use crate::util::json::{self, Json};
use crate::wire;

/// Default shard count for a served cache (Figure 8a's scaling knob).
pub const DEFAULT_SHARDS: usize = 8;

/// Largest number of ops one `GET /replicate` reply carries. Bounds the
/// reply frame; a far-behind follower simply pulls again.
pub const REPLICATE_BATCH_MAX: usize = 512;

/// How long `POST /drain` waits for the follower to acknowledge the whole
/// log before giving up and reporting `caught_up: false`.
const DRAIN_DEADLINE: Duration = Duration::from_secs(2);

/// Default idle tick of a follower's tail thread: how long it sleeps when
/// caught up (or the primary is unreachable) before the next pull
/// (`--follow-tick-ms`).
pub const DEFAULT_FOLLOW_TICK: Duration = Duration::from_millis(5);

/// Shared server state: the sharded cache service plus HTTP plumbing.
pub struct CacheService {
    sharded: ShardedCacheService,
    /// Fencing epoch stamped into every sealed binary response. Fresh
    /// primaries (and unpromoted followers, which echo what they will bump
    /// past) start at 1; `POST /promote` sets it above every epoch the old
    /// primary could have used.
    epoch: AtomicU64,
    /// Read-only warm follower until `/promote` flips it.
    follower: AtomicBool,
    /// `/drain` was called: no new sessions are admitted.
    draining: AtomicBool,
    /// Follower tail state: next op-log sequence to apply.
    applied: AtomicU64,
    /// The primary's `next` sequence as of the last successful pull — the
    /// lag gauge's other leg.
    primary_next: AtomicU64,
    /// Highest epoch seen from the primary while tailing; promotion bumps
    /// past it.
    primary_epoch: AtomicU64,
    /// Set when replay can never be trusted again (the primary's shard
    /// count differs, or a window gap could not be bootstrapped over):
    /// application stops permanently, lag keeps growing, promotion still
    /// works but the operator sees `replica_frozen` in `/stats`.
    frozen: AtomicBool,
    /// Checkpoint installs this follower performed after a window gap
    /// (`GET /bootstrap`). A PR 8 follower froze instead.
    bootstraps: AtomicU64,
    /// Bytes of `/replicate` reply frames this primary shipped.
    replicate_bytes: AtomicU64,
    /// Replicated ops that could not take effect here (e.g. a key-only
    /// attach whose payload bytes this follower never saw). Snapshot
    /// availability degrades; correctness does not.
    skipped_ops: AtomicU64,
    tail_stop: Arc<AtomicBool>,
    tail_thread: Mutex<Option<std::thread::JoinHandle<()>>>,
    /// Stable node identity (cluster mode, `--node-id`): echoed in the
    /// extended `/capabilities` handshake and the debug surfaces so a
    /// router can assert it reached the node its ring chose. Set once at
    /// startup, before traffic.
    node_id: std::sync::OnceLock<String>,
    /// Cluster placement guard (cluster mode, `--cluster-map`): the shared
    /// ring plus this node's group index. While set, task-bearing requests
    /// whose task the ring places on *another* group answer
    /// `421 Misdirected Request` instead of silently caching here.
    guard: std::sync::OnceLock<ClusterGuard>,
    /// Requests rejected by the placement guard.
    misroutes: AtomicU64,
}

/// The server half of cluster placement: which group of `map` this node
/// belongs to.
struct ClusterGuard {
    map: crate::cluster::ClusterMap,
    group: usize,
}

impl CacheService {
    pub fn new() -> Arc<CacheService> {
        Self::with_shards(DEFAULT_SHARDS)
    }

    pub fn with_shards(shards: usize) -> Arc<CacheService> {
        Self::with_service(ShardedCacheService::new(shards))
    }

    /// Custom per-task cache policies (used by benches).
    pub fn with_factory(shards: usize, factory: CacheFactory) -> Arc<CacheService> {
        Self::with_service(ShardedCacheService::with_factory(shards, factory))
    }

    /// Front an already-built sharded service (spill/budget-configured).
    pub fn with_service(sharded: ShardedCacheService) -> Arc<CacheService> {
        Arc::new(CacheService {
            sharded,
            epoch: AtomicU64::new(1),
            follower: AtomicBool::new(false),
            draining: AtomicBool::new(false),
            applied: AtomicU64::new(0),
            primary_next: AtomicU64::new(0),
            primary_epoch: AtomicU64::new(0),
            frozen: AtomicBool::new(false),
            bootstraps: AtomicU64::new(0),
            replicate_bytes: AtomicU64::new(0),
            skipped_ops: AtomicU64::new(0),
            tail_stop: Arc::new(AtomicBool::new(false)),
            tail_thread: Mutex::new(None),
            node_id: std::sync::OnceLock::new(),
            guard: std::sync::OnceLock::new(),
            misroutes: AtomicU64::new(0),
        })
    }

    /// Configure this node's stable cluster identity (first write wins;
    /// call before serving traffic).
    pub fn set_node_id(&self, id: impl Into<String>) {
        let _ = self.node_id.set(id.into());
    }

    /// This node's configured cluster identity, if any.
    pub fn node_id(&self) -> Option<&str> {
        self.node_id.get().map(|s| s.as_str())
    }

    /// Arm the cluster placement guard: reject task-bearing requests the
    /// ring places on a group other than `group` (first write wins; call
    /// before serving traffic).
    pub fn set_cluster_guard(&self, map: crate::cluster::ClusterMap, group: usize) {
        let _ = self.guard.set(ClusterGuard { map, group });
    }

    /// Requests rejected by the placement guard so far.
    pub fn misroutes(&self) -> u64 {
        self.misroutes.load(Ordering::Relaxed)
    }

    /// The current fencing epoch.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Whether this server is still an unpromoted (read-only) follower.
    pub fn is_follower(&self) -> bool {
        self.follower.load(Ordering::Acquire)
    }

    /// Follower lag in ops: how far the primary's log tip is ahead of what
    /// this server has applied (0 on a primary; on a primary *with* a log,
    /// how far its own follower's acks trail the tip).
    pub fn replica_lag_ops(&self) -> u64 {
        if self.follower.load(Ordering::Acquire) {
            self.primary_next
                .load(Ordering::Acquire)
                .saturating_sub(self.applied.load(Ordering::Acquire))
        } else {
            match self.sharded.oplog() {
                Some(log) => log.next_seq().saturating_sub(log.acked()),
                None => 0,
            }
        }
    }

    /// Ops this follower had to skip during replay (payload aged off the
    /// primary's window before we pulled it).
    pub fn skipped_ops(&self) -> u64 {
        self.skipped_ops.load(Ordering::Relaxed)
    }

    fn stop_tail(&self) {
        self.tail_stop.store(true, Ordering::Release);
        if let Some(t) = self.tail_thread.lock().unwrap().take() {
            let _ = t.join();
        }
    }

    /// The trait surface every handler dispatches through.
    pub fn backend(&self) -> &dyn CacheBackend {
        &self.sharded
    }

    /// The session extension surface (cursors, turn batches, capability
    /// negotiation).
    pub fn session_backend(&self) -> &dyn SessionBackend {
        &self.sharded
    }

    /// White-box access to a per-task cache (tests, persistence jobs).
    pub fn task(&self, id: &str) -> Arc<TaskCache> {
        self.sharded.task(id)
    }

    pub fn shard_count(&self) -> usize {
        self.sharded.shard_count()
    }

    /// Stored snapshots across all shards.
    pub fn snapshot_count(&self) -> usize {
        self.sharded.snapshot_count()
    }

    /// Live rollout sessions across all shards (leak diagnostics).
    pub fn session_count(&self) -> usize {
        self.sharded.session_count()
    }

    /// Resume pins owned by server-side session entries (leak diagnostics).
    pub fn session_pin_count(&self) -> usize {
        self.sharded.session_pin_count()
    }

    /// White-box eviction of one node's snapshot (tests of the unpinned
    /// resume-offer race — see the comment in `lookup`).
    pub fn evict_snapshot(&self, task: &str, node: usize) -> bool {
        self.sharded.evict_snapshot(task, node)
    }

    /// White-box removal of a node's subtree (tests of cursor
    /// invalidation mid-rollout).
    pub fn evict_node(&self, task: &str, node: usize) -> bool {
        self.sharded.evict_node(task, node)
    }

    fn handle(&self, req: &Request) -> Response {
        // Unpromoted followers are read-only replicas: every mutating
        // endpoint answers 503 until `/promote`. Reads (`/get`, `/stats`,
        // `/snapshot` fetches, …) stay available for warm-up checks.
        if self.follower.load(Ordering::Acquire) && req.method == "POST" {
            // `/get` and `/prefix_match` are reads that arrive as POSTs
            // (their transient offer pin is returned before replying).
            let mutating = !matches!(
                req.path.as_str(),
                "/get" | "/prefix_match" | "/capabilities" | "/promote" | "/drain" | "/persist"
            );
            if mutating {
                return Response::text_static(503, "follower (read-only until promoted)");
            }
        }
        if let Some(rejection) = self.reject_misrouted(req) {
            return rejection;
        }
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/ping") => Response::text_static(200, "pong"),
            ("GET", "/replicate") => self.replicate(req),
            ("GET", "/bootstrap") => self.bootstrap(),
            ("POST", "/promote") => self.promote(),
            ("POST", "/drain") => self.drain(req),
            // Hot endpoints sniff the first body byte: the binary codec's
            // magic never collides with JSON's `{`.
            ("POST", "/get") if wire::is_binary(&req.body) => self.lookup_bin(req),
            ("POST", "/get") | ("POST", "/prefix_match") => self.lookup(req),
            ("POST", "/put") if wire::is_binary(&req.body) => self.put_bin(req),
            ("POST", "/put") => self.put(req),
            ("POST", "/release") if wire::is_binary(&req.body) => self.release_bin(req),
            ("POST", "/release") => self.release(req),
            ("POST", "/cursor_open") => self.cursor_open(req),
            ("POST", "/cursor_step") => self.cursor_step(req),
            ("POST", "/cursor_record") => self.cursor_record(req),
            ("POST", "/cursor_seek") => self.cursor_seek(req),
            ("POST", "/cursor_close") => self.cursor_close(req),
            ("POST", "/capabilities") => self.capabilities(req),
            ("GET", "/capabilities") => self.capabilities_json(),
            ("POST", "/session_turn") => self.session_turn(req),
            ("POST", "/session_release") => self.session_release(req),
            ("POST", "/snapshot") => self.store_snapshot(req),
            ("GET", "/snapshot") => self.fetch_snapshot(req),
            ("POST", "/warm") => self.set_warm(req),
            ("GET", "/warm") => self.get_warm(req),
            ("POST", "/persist") => self.persist(req),
            ("POST", "/warm_start") => self.warm_start(req),
            ("GET", "/stats") => self.stats(req),
            ("GET", "/viz") => self.viz(req),
            _ => Response::not_found(),
        }
    }

    /// Cluster placement guard: a task-bearing request whose task the ring
    /// places on another group is answered `421 Misdirected Request` — a
    /// misconfigured or stale router must never silently populate the
    /// wrong node's cache (its inserts would be invisible to every
    /// correctly-routed lookup, and its lookups would miss forever while
    /// looking healthy). Inert unless [`CacheService::set_cluster_guard`]
    /// armed it. Requests whose task cannot be peeked fall through to the
    /// endpoint's own decoder, which rejects them with the usual 400.
    fn reject_misrouted(&self, req: &Request) -> Option<Response> {
        let g = self.guard.get()?;
        // Only the task-bearing cache surface is guarded; admin and
        // replication endpoints are node-scoped by design (a follower
        // pulls `/replicate` regardless of task placement).
        let guarded = matches!(
            req.path.as_str(),
            "/get"
                | "/prefix_match"
                | "/put"
                | "/release"
                | "/cursor_open"
                | "/cursor_step"
                | "/cursor_record"
                | "/cursor_seek"
                | "/cursor_close"
                | "/session_turn"
                | "/session_release"
                | "/snapshot"
                | "/warm"
        );
        if !guarded {
            return None;
        }
        // Every binary request frame leads with the task string; JSON
        // bodies carry a "task" field; the GET forms take `?task=`.
        let task: Option<String> = if wire::is_binary(&req.body) {
            wire::Reader::request(&req.body).and_then(|mut r| r.str().map(str::to_string))
        } else if req.method == "GET" {
            req.query.get("task").cloned()
        } else {
            json::parse(req.body_str())
                .ok()
                .and_then(|v| v.get("task").and_then(|t| t.as_str()).map(str::to_string))
        };
        if g.map.group_for(task.as_deref()?) == g.group {
            return None;
        }
        self.misroutes.fetch_add(1, Ordering::Relaxed);
        Some(Response::text_static(421, "misrouted task: the cluster map places it elsewhere"))
    }

    // ---- replication & failover ------------------------------------------

    /// `GET /replicate?from=<seq>`: one batch of op-log entries starting at
    /// `from` (≤ [`REPLICATE_BATCH_MAX`] ops). A request at `from` also
    /// acknowledges every op below it — the follower only advances its pull
    /// position past ops it has applied — which is what `/drain` waits on.
    fn replicate(&self, req: &Request) -> Response {
        let Some(log) = self.sharded.oplog() else {
            return Response::bad_request_static("replication is not enabled (no op-log)");
        };
        let Some(from) = req.query.get("from").and_then(|s| s.parse::<u64>().ok()) else {
            return Response::bad_request_static("missing from");
        };
        log.note_ack(from);
        let (start, next, ops) = log.read_from(from, REPLICATE_BATCH_MAX);
        let mut buf = Vec::with_capacity(64);
        wire::enc_replicate_resp(
            &mut buf,
            start,
            next,
            self.sharded.shard_count() as u64,
            &ops,
            self.epoch(),
        );
        self.replicate_bytes.fetch_add(buf.len() as u64, Ordering::Relaxed);
        Response::binary(buf)
    }

    /// `GET /bootstrap`: a seq-stamped JSON checkpoint of the entire
    /// service state (every TCG with primary node ids, every snapshot
    /// handle, each content payload once). A follower whose pull position
    /// fell off the op-log window installs it and resumes tailing
    /// `/replicate?from=<seq>` — no gap, no overlap.
    fn bootstrap(&self) -> Response {
        match self.sharded.bootstrap_doc() {
            Some(doc) => Response::json(doc.to_string()),
            None => Response::bad_request_static("replication is not enabled (no op-log)"),
        }
    }

    /// `POST /promote`: flip a follower into a writable primary. The new
    /// epoch is one past everything this server has seen — its own and the
    /// old primary's — so no response the old primary ever sealed can
    /// outrank the new line. A server that is *already* primary reports its
    /// current epoch without bumping: a revived stale primary answering
    /// `/promote` therefore keeps its old (fenced) epoch instead of
    /// hijacking the promotion.
    fn promote(&self) -> Response {
        let promoted = self.follower.swap(false, Ordering::AcqRel);
        if promoted {
            self.stop_tail();
            let new = self
                .primary_epoch
                .load(Ordering::Acquire)
                .max(self.epoch.load(Ordering::Acquire))
                + 1;
            self.epoch.store(new, Ordering::Release);
        }
        Response::json(
            Json::obj(vec![
                ("epoch", Json::num(self.epoch() as f64)),
                ("promoted", Json::Bool(promoted)),
            ])
            .to_string(),
        )
    }

    /// `POST /drain`: graceful shutdown. Stops admitting sessions, waits
    /// (bounded) for the follower's pulls to acknowledge the whole op-log,
    /// then optionally persists (`{"dir": …}` body). The caller stops the
    /// process afterwards; existing sessions keep answering meanwhile.
    fn drain(&self, req: &Request) -> Response {
        self.draining.store(true, Ordering::Release);
        let (caught_up, final_seq) = match self.sharded.oplog() {
            Some(log) => {
                let target = log.next_seq();
                // A WAL-only primary has no follower to wait for; its
                // drain duty is durability, not catch-up.
                let caught_up = if self.sharded.replication_enabled() {
                    let deadline = Instant::now() + DRAIN_DEADLINE;
                    loop {
                        if log.acked() >= target {
                            break true;
                        }
                        if Instant::now() >= deadline {
                            break false;
                        }
                        std::thread::sleep(Duration::from_millis(2));
                    }
                } else {
                    true
                };
                if let Some(wal) = log.wal() {
                    // Everything appended so far reaches the disk before
                    // the caller stops the process.
                    wal.sync();
                }
                (caught_up, target)
            }
            None => (true, 0),
        };
        let persisted = match json::parse(req.body_str()) {
            Ok(body) => body
                .get("dir")
                .and_then(|d| d.as_str())
                .map(|dir| self.backend().persist(dir)),
            Err(_) => None, // empty/absent body: drain without persisting
        };
        let mut fields = vec![
            ("caught_up", Json::Bool(caught_up)),
            ("final_seq", Json::num(final_seq as f64)),
        ];
        if let Some(ok) = persisted {
            fields.push(("persisted", Json::Bool(ok)));
        }
        Response::json(Json::obj(fields).to_string())
    }

    // ---- binary hot path -------------------------------------------------

    /// The resume-offer unpinning every wire lookup applies (see the long
    /// comment in [`CacheService::lookup`]): the HTTP protocol cannot carry
    /// a reliable distributed refcount, so the pin taken by the lookup is
    /// returned before the response leaves the server.
    fn unpin_offer(&self, task: &str, resume: &Option<(usize, crate::cache::SnapshotRef, usize)>) {
        if let Some((node, _, _)) = resume {
            self.backend().release(task, *node);
        }
    }

    fn lookup_bin(&self, req: &Request) -> Response {
        let decoded = (|| {
            let mut r = wire::Reader::request(&req.body)?;
            let task = r.str()?.to_string();
            let n = r.varint()? as usize;
            let mut q = Vec::with_capacity(n.min(1024));
            for _ in 0..n {
                q.push(r.call()?);
            }
            r.done().then_some((task, q))
        })();
        let Some((task, q)) = decoded else {
            return Response::bad_request_static("bad lookup frame");
        };
        if q.is_empty() {
            return Response::bad_request_static("empty trajectory");
        }
        let out = self.backend().lookup(&task, &q);
        if let Lookup::Miss(m) = &out {
            self.unpin_offer(&task, &m.resume);
        }
        let mut buf = Vec::with_capacity(64);
        wire::enc_lookup_resp(&mut buf, &out, self.epoch());
        Response::binary(buf)
    }

    fn put_bin(&self, req: &Request) -> Response {
        let decoded = (|| {
            let mut r = wire::Reader::request(&req.body)?;
            let task = r.str()?.to_string();
            let n = r.varint()? as usize;
            let mut traj = Vec::with_capacity(n.min(1024));
            for _ in 0..n {
                let call = r.call()?;
                let result = r.result()?;
                traj.push((call, result));
            }
            r.done().then_some((task, traj))
        })();
        let Some((task, traj)) = decoded else {
            return Response::bad_request_static("bad put frame");
        };
        // In-process inserts cannot fail; 0 is the wire's ROOT/failure
        // sentinel either way.
        let node = self.backend().insert(&task, &traj).unwrap_or(0);
        let mut buf = Vec::with_capacity(21);
        wire::enc_u64_resp(&mut buf, node as u64, self.epoch());
        Response::binary(buf)
    }

    fn release_bin(&self, req: &Request) -> Response {
        let decoded = (|| {
            let mut r = wire::Reader::request(&req.body)?;
            let task = r.str()?.to_string();
            let node = r.varint()? as usize;
            r.done().then_some((task, node))
        })();
        let Some((task, node)) = decoded else {
            return Response::bad_request_static("bad release frame");
        };
        self.backend().release(&task, node);
        self.empty_sealed()
    }

    /// An empty binary reply still gets the epoch trailer, so every sealed
    /// response a v2 client reads carries the fence.
    fn empty_sealed(&self) -> Response {
        let mut buf = Vec::with_capacity(wire::RESP_TRAILER);
        wire::seal_resp(&mut buf, self.epoch());
        Response::binary(buf)
    }

    fn cursor_open(&self, req: &Request) -> Response {
        let decoded = (|| {
            let mut r = wire::Reader::request(&req.body)?;
            let task = r.str()?.to_string();
            r.done().then_some(task)
        })();
        let Some(task) = decoded else {
            return Response::bad_request_static("bad cursor_open frame");
        };
        // A draining server admits no new sessions; 0 is the wire's
        // refusal sentinel and clients fall back to stateless lookups.
        let id = if self.draining.load(Ordering::Acquire) {
            0
        } else {
            self.session_backend().cursor_open(&task)
        };
        let mut buf = Vec::with_capacity(21);
        wire::enc_u64_resp(&mut buf, id, self.epoch());
        Response::binary(buf)
    }

    fn cursor_step(&self, req: &Request) -> Response {
        let decoded = (|| {
            let mut r = wire::Reader::request(&req.body)?;
            let task = r.str()?.to_string();
            let cursor = r.varint()?;
            let call = r.call()?;
            r.done().then_some((task, cursor, call))
        })();
        let Some((task, cursor, call)) = decoded else {
            return Response::bad_request_static("bad cursor_step frame");
        };
        let out = self.session_backend().cursor_step(&task, cursor, &call);
        if let CursorStep::Miss(m) = &out {
            // Same unpinned-offer contract as every wire lookup.
            self.unpin_offer(&task, &m.resume);
        }
        let mut buf = Vec::with_capacity(64);
        wire::enc_step_resp(&mut buf, &out, self.epoch());
        Response::binary(buf)
    }

    fn cursor_record(&self, req: &Request) -> Response {
        let decoded = (|| {
            let mut r = wire::Reader::request(&req.body)?;
            let task = r.str()?.to_string();
            let cursor = r.varint()?;
            let call = r.call()?;
            let result = r.result()?;
            r.done().then_some((task, cursor, call, result))
        })();
        let Some((task, cursor, call, result)) = decoded else {
            return Response::bad_request_static("bad cursor_record frame");
        };
        // A failed record (unknown cursor / conflict) encodes as the wire's
        // 0 sentinel — v2 clients treat it as refused unless the position
        // can legally be ROOT.
        let node = self
            .session_backend()
            .cursor_record(&task, cursor, &call, &result)
            .unwrap_or(0);
        let mut buf = Vec::with_capacity(21);
        wire::enc_u64_resp(&mut buf, node as u64, self.epoch());
        Response::binary(buf)
    }

    fn cursor_seek(&self, req: &Request) -> Response {
        let decoded = (|| {
            let mut r = wire::Reader::request(&req.body)?;
            let task = r.str()?.to_string();
            let cursor = r.varint()?;
            let node = r.varint()? as usize;
            let steps = r.varint()? as usize;
            r.done().then_some((task, cursor, node, steps))
        })();
        let Some((task, cursor, node, steps)) = decoded else {
            return Response::bad_request_static("bad cursor_seek frame");
        };
        let ok = self.session_backend().cursor_seek(&task, cursor, node, steps);
        let mut buf = Vec::with_capacity(13);
        wire::enc_bool_resp(&mut buf, ok, self.epoch());
        Response::binary(buf)
    }

    fn cursor_close(&self, req: &Request) -> Response {
        let decoded = (|| {
            let mut r = wire::Reader::request(&req.body)?;
            let task = r.str()?.to_string();
            let cursor = r.varint()?;
            r.done().then_some((task, cursor))
        })();
        let Some((task, cursor)) = decoded else {
            return Response::bad_request_static("bad cursor_close frame");
        };
        self.session_backend().cursor_close(&task, cursor);
        self.empty_sealed()
    }

    // ---- session API v2 --------------------------------------------------

    /// The binary capability handshake: a client hello (protocol
    /// generation) answered with what this server speaks. Negotiated once
    /// per binding, replacing per-request magic-byte guessing for v2
    /// clients; old clients never call this and keep being sniffed.
    fn capabilities(&self, req: &Request) -> Response {
        let Some((client_proto, expect_node)) = wire::dec_hello_any(&req.body) else {
            return Response::bad_request_static("bad hello frame");
        };
        // Node-identity assertion (cluster mode): a client that names the
        // node it expects — and reaches a node configured with a different
        // identity — is misrouted. Caught here, at the handshake, before
        // any cache traffic lands on the wrong group.
        if let (Some(expect), Some(actual)) = (expect_node, self.node_id()) {
            if !expect.is_empty() && expect != actual {
                self.misroutes.fetch_add(1, Ordering::Relaxed);
                return Response::text_static(421, "node identity mismatch");
            }
        }
        let proto = client_proto.min(Capabilities::PROTO_V2);
        let mut buf = Vec::with_capacity(16);
        let caps = self.session_backend().capabilities();
        if expect_node.is_some() {
            // Extended hello → extended reply (a plain client keeps the
            // strictly-decoded plain frame it has always gotten).
            wire::enc_caps_resp_ext(
                &mut buf,
                proto,
                &caps,
                self.node_id().unwrap_or(""),
                self.epoch(),
            );
        } else {
            wire::enc_caps_resp(&mut buf, proto, &caps, self.epoch());
        }
        Response::binary(buf)
    }

    /// Human-debuggable view of the handshake (`GET /capabilities`),
    /// including the degradation health bits operators check first when a
    /// cache misbehaves.
    fn capabilities_json(&self) -> Response {
        let caps = self.session_backend().capabilities();
        Response::json(
            Json::obj(vec![
                ("proto", Json::num(Capabilities::PROTO_V2 as f64)),
                ("binary", Json::Bool(caps.binary)),
                ("cursors", Json::Bool(caps.cursors)),
                ("turn_batch", Json::Bool(caps.turn_batch)),
                ("payload_dedup", Json::Bool(caps.payload_dedup)),
                ("spill_degraded", Json::Bool(self.sharded.spill_degraded())),
                (
                    "injected_faults",
                    Json::num(crate::util::fault::injected_total() as f64),
                ),
                ("epoch", Json::num(self.epoch() as f64)),
                (
                    "role",
                    Json::str(if self.is_follower() { "follower" } else { "primary" }),
                ),
                ("node_id", Json::str(self.node_id().unwrap_or(""))),
                ("misroutes", Json::num(self.misroutes() as f64)),
            ])
            .to_string(),
        )
    }

    /// One reasoning turn in one round trip: probes + at most one stateful
    /// step/record. Unlike the legacy per-call lookups, a turn's step-miss
    /// resume offer stays *pinned* — the pin is owned by the server-side
    /// session entry, and close/sweep releases whatever the client never
    /// did, so a lost response bounds the leak by the session lifetime.
    fn session_turn(&self, req: &Request) -> Response {
        let Some((task, cursor, batch)) = wire::dec_turn_req(&req.body) else {
            return Response::bad_request_static("bad turn frame");
        };
        // Draining: a turn that would open a new session is refused; turns
        // on existing sessions keep completing until the caller shuts down.
        let reply = if cursor == 0 && self.draining.load(Ordering::Acquire) {
            TurnReply::refused(&batch)
        } else {
            self.session_backend().session_turn(&task, cursor, &batch)
        };
        let mut buf = Vec::with_capacity(64);
        wire::enc_turn_resp(&mut buf, &reply, self.epoch());
        Response::binary(buf)
    }

    /// Return a session-owned resume pin (`task, cursor, node`).
    fn session_release(&self, req: &Request) -> Response {
        let decoded = (|| {
            let mut r = wire::Reader::request(&req.body)?;
            let task = r.str()?.to_string();
            let cursor = r.varint()?;
            let node = r.varint()? as usize;
            r.done().then_some((task, cursor, node))
        })();
        let Some((task, cursor, node)) = decoded else {
            return Response::bad_request_static("bad session_release frame");
        };
        self.session_backend().session_release(&task, cursor, node);
        self.empty_sealed()
    }

    // ---- legacy JSON path ------------------------------------------------

    fn parse_body(req: &Request) -> Result<Json, Response> {
        json::parse(req.body_str())
            .map_err(|e| Response::bad_request(format!("bad json: {e}")))
    }

    fn task_of(body: &Json) -> Result<&str, Response> {
        body.get("task")
            .and_then(|t| t.as_str())
            .ok_or_else(|| Response::bad_request("missing task"))
    }

    fn lookup(&self, req: &Request) -> Response {
        let body = match Self::parse_body(req) {
            Ok(b) => b,
            Err(r) => return r,
        };
        let task = match Self::task_of(&body) {
            Ok(t) => t,
            Err(r) => return r,
        };
        let Some(traj) = body.get("trajectory").and_then(trajectory_from_json) else {
            return Response::bad_request("missing trajectory");
        };
        if traj.is_empty() {
            return Response::bad_request("empty trajectory");
        }
        let out = match self.backend().lookup(task, &traj) {
            Lookup::Hit { node, result } => Json::obj(vec![
                ("hit", Json::Bool(true)),
                ("node", Json::num(node as f64)),
                ("result", result.to_json()),
            ]),
            Lookup::Miss(m) => {
                let mut fields = vec![
                    ("hit", Json::Bool(false)),
                    ("matched_node", Json::num(m.matched_node as f64)),
                    ("matched_calls", Json::num(m.matched_calls as f64)),
                ];
                if let Some((node, snap, replay_from)) = m.resume {
                    // The wire protocol cannot carry a reliable distributed
                    // refcount: a response lost after the lookup pinned the
                    // node would leak the pin — and block that snapshot's
                    // eviction — forever. Resume offers over HTTP are
                    // therefore unpinned (the lookup's pin is returned
                    // before replying); a client whose later fetch loses
                    // the eviction race degrades gracefully to replay
                    // (`fetch_snapshot` → None), and its `/release` is a
                    // saturating no-op.
                    self.backend().release(task, node);
                    fields.push((
                        "resume",
                        Json::obj(vec![
                            ("node", Json::num(node as f64)),
                            ("snap_id", Json::num(snap.id as f64)),
                            ("restore_cost", Json::num(snap.restore_cost)),
                            ("replay_from", Json::num(replay_from as f64)),
                        ]),
                    ));
                }
                Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
            }
        };
        Response::json(out.to_string())
    }

    fn put(&self, req: &Request) -> Response {
        let body = match Self::parse_body(req) {
            Ok(b) => b,
            Err(r) => return r,
        };
        let task = match Self::task_of(&body) {
            Ok(t) => t,
            Err(r) => return r,
        };
        let Some(entries) = body.get("trajectory").and_then(|t| t.as_arr()) else {
            return Response::bad_request("missing trajectory");
        };
        let mut traj = Vec::with_capacity(entries.len());
        for e in entries {
            let (Some(call), Some(result)) = (
                e.get("call").and_then(ToolCall::from_json),
                e.get("result").and_then(ToolResult::from_json),
            ) else {
                return Response::bad_request("bad trajectory entry");
            };
            traj.push((call, result));
        }
        let node = self.backend().insert(task, &traj).unwrap_or(0);
        Response::json(Json::obj(vec![("node", Json::num(node as f64))]).to_string())
    }

    fn release(&self, req: &Request) -> Response {
        let body = match Self::parse_body(req) {
            Ok(b) => b,
            Err(r) => return r,
        };
        let task = match Self::task_of(&body) {
            Ok(t) => t,
            Err(r) => return r,
        };
        let Some(node) = body.get("node").and_then(|n| n.as_u64()) else {
            return Response::bad_request("missing node");
        };
        self.backend().release(task, node as usize);
        Response::json_static("{}")
    }

    fn store_snapshot(&self, req: &Request) -> Response {
        let body = match Self::parse_body(req) {
            Ok(b) => b,
            Err(r) => return r,
        };
        let task = match Self::task_of(&body) {
            Ok(t) => t,
            Err(r) => return r,
        };
        let (Some(node), Some(hex), Some(ser), Some(rest)) = (
            body.get("node").and_then(|n| n.as_u64()),
            body.get("bytes_hex").and_then(|b| b.as_str()),
            body.get("serialize_cost").and_then(|c| c.as_f64()),
            body.get("restore_cost").and_then(|c| c.as_f64()),
        ) else {
            return Response::bad_request("missing snapshot fields");
        };
        let Some(bytes) = hex_decode(hex) else {
            return Response::bad_request("bad hex");
        };
        let snap = SandboxSnapshot { bytes, serialize_cost: ser, restore_cost: rest };
        let id = self.backend().store_snapshot(task, node as usize, snap);
        Response::json(Json::obj(vec![("id", Json::num(id as f64))]).to_string())
    }

    fn fetch_snapshot(&self, req: &Request) -> Response {
        let Some(id) = req.query.get("id").and_then(|s| s.parse::<u64>().ok()) else {
            return Response::bad_request("missing id");
        };
        let snap = match req.query.get("task") {
            Some(task) => self.backend().fetch_snapshot(task, id),
            // Legacy fetches carry no task; the strided id space still
            // identifies the owning shard.
            None => self.sharded.fetch_snapshot_any(id),
        };
        match snap {
            Some(s) => Response::json(
                Json::obj(vec![
                    ("bytes_hex", Json::str(hex_encode(&s.bytes))),
                    ("serialize_cost", Json::num(s.serialize_cost)),
                    ("restore_cost", Json::num(s.restore_cost)),
                ])
                .to_string(),
            ),
            None => Response::not_found(),
        }
    }

    fn set_warm(&self, req: &Request) -> Response {
        let body = match Self::parse_body(req) {
            Ok(b) => b,
            Err(r) => return r,
        };
        let task = match Self::task_of(&body) {
            Ok(t) => t,
            Err(r) => return r,
        };
        let (Some(node), Some(warm)) = (
            body.get("node").and_then(|n| n.as_u64()),
            body.get("warm").and_then(|w| w.as_bool()),
        ) else {
            return Response::bad_request("missing node/warm");
        };
        self.backend().set_warm_fork(task, node as usize, warm);
        Response::json_static("{}")
    }

    fn get_warm(&self, req: &Request) -> Response {
        let (Some(task), Some(node)) = (
            req.query.get("task"),
            req.query.get("node").and_then(|s| s.parse::<u64>().ok()),
        ) else {
            return Response::bad_request("missing task/node");
        };
        let warm = self.backend().has_warm_fork(task, node as usize);
        Response::json(Json::obj(vec![("warm", Json::Bool(warm))]).to_string())
    }

    /// `{dir}` body → persist / warm-start the whole service state. The
    /// directory is a *server-local* path (the snapshot lifecycle's
    /// warm-start tier, not a client upload). Like the rest of the wire
    /// protocol this is unauthenticated — a client that can reach the
    /// port can direct writes/reads at any path the server process can
    /// touch, so bind trusted interfaces only (the paper's deployment
    /// model: the cache server lives inside the training cluster).
    fn lifecycle(&self, req: &Request, warm: bool) -> Response {
        let body = match Self::parse_body(req) {
            Ok(b) => b,
            Err(r) => return r,
        };
        let Some(dir) = body.get("dir").and_then(|d| d.as_str()) else {
            return Response::bad_request("missing dir");
        };
        let ok = if warm {
            self.backend().warm_start(dir)
        } else {
            self.backend().persist(dir)
        };
        if ok {
            Response::json_static("{\"ok\":true}")
        } else {
            Response::json_static("{\"ok\":false}")
        }
    }

    fn persist(&self, req: &Request) -> Response {
        self.lifecycle(req, false)
    }

    fn warm_start(&self, req: &Request) -> Response {
        self.lifecycle(req, true)
    }

    fn stats(&self, req: &Request) -> Response {
        match req.query.get("task") {
            Some(task) => Response::json(self.backend().stats(task).to_json().to_string()),
            None => {
                let mut s = self.backend().service_stats();
                s.epoch = self.epoch();
                s.replica_lag_ops = self.replica_lag_ops();
                s.replicate_bytes_shipped = self.replicate_bytes.load(Ordering::Relaxed);
                let mut v = s.to_json();
                if let Json::Obj(fields) = &mut v {
                    let role = if self.is_follower() { "follower" } else { "primary" };
                    fields.insert("role".to_string(), Json::str(role));
                    fields.insert(
                        "replica_frozen".to_string(),
                        Json::Bool(self.frozen.load(Ordering::Acquire)),
                    );
                    fields.insert(
                        "replica_bootstraps".to_string(),
                        Json::num(self.bootstraps.load(Ordering::Relaxed) as f64),
                    );
                    fields.insert(
                        "replica_skipped_ops".to_string(),
                        Json::num(self.skipped_ops() as f64),
                    );
                    fields.insert(
                        "draining".to_string(),
                        Json::Bool(self.draining.load(Ordering::Acquire)),
                    );
                    fields.insert(
                        "node_id".to_string(),
                        Json::str(self.node_id().unwrap_or("")),
                    );
                    fields.insert(
                        "misroutes".to_string(),
                        Json::num(self.misroutes() as f64),
                    );
                }
                Response::json(v.to_string())
            }
        }
    }

    fn viz(&self, req: &Request) -> Response {
        match req.query.get("task") {
            Some(task) => Response::json(self.task(task).viz_json().to_string()),
            None => Response::bad_request("missing task"),
        }
    }
}

/// Start a TVCACHE server on `addr` with the default shard count; returns
/// the HTTP server handle and the shared service (for white-box assertions).
pub fn serve(addr: &str, workers: usize) -> std::io::Result<(Server, Arc<CacheService>)> {
    serve_with(addr, workers, DEFAULT_SHARDS)
}

/// Start a TVCACHE server with an explicit shard count.
pub fn serve_with(
    addr: &str,
    workers: usize,
    shards: usize,
) -> std::io::Result<(Server, Arc<CacheService>)> {
    serve_service(addr, workers, ShardedCacheService::new(shards))
}

/// Start a TVCACHE server fronting an already-built sharded service (the
/// way to serve a byte-budgeted / spill-tiered configuration).
pub fn serve_service(
    addr: &str,
    workers: usize,
    sharded: ShardedCacheService,
) -> std::io::Result<(Server, Arc<CacheService>)> {
    let service = CacheService::with_service(sharded);
    let svc = Arc::clone(&service);
    let handler: Handler = Arc::new(move |req: &Request| svc.handle(req));
    let server = Server::bind(addr, workers, handler)?;
    Ok((server, service))
}

/// Start a warm follower on `addr`: a background thread tails `primary`'s
/// op-log over `GET /replicate` and applies every op into `sharded` (which
/// must have the primary's shard count — replay is topology-faithful).
/// Mutating endpoints answer 503 until `POST /promote` flips the server
/// into a writable primary with a bumped fencing epoch.
pub fn serve_follower(
    addr: &str,
    workers: usize,
    sharded: ShardedCacheService,
    primary: SocketAddr,
) -> std::io::Result<(Server, Arc<CacheService>)> {
    serve_follower_with_tick(addr, workers, sharded, primary, DEFAULT_FOLLOW_TICK)
}

/// [`serve_follower`] with an explicit idle tick: how long the tail thread
/// sleeps when it is caught up (or the primary is unreachable) before the
/// next `GET /replicate` pull. Lower = fresher replica; higher = fewer
/// idle pulls against the primary.
pub fn serve_follower_with_tick(
    addr: &str,
    workers: usize,
    sharded: ShardedCacheService,
    primary: SocketAddr,
    tick: Duration,
) -> std::io::Result<(Server, Arc<CacheService>)> {
    let service = CacheService::with_service(sharded);
    service.follower.store(true, Ordering::Release);
    spawn_tail(&service, primary, tick);
    let svc = Arc::clone(&service);
    let handler: Handler = Arc::new(move |req: &Request| svc.handle(req));
    let server = Server::bind(addr, workers, handler)?;
    Ok((server, service))
}

fn spawn_tail(service: &Arc<CacheService>, primary: SocketAddr, tick: Duration) {
    let stop = Arc::clone(&service.tail_stop);
    // The thread holds only a Weak: a dropped service ends the tail rather
    // than the tail keeping the service alive forever.
    let weak = Arc::downgrade(service);
    let handle = std::thread::Builder::new()
        .name("tvcache-replica-tail".into())
        .spawn(move || {
            // Tight deadlines: a dead primary must not wedge a pull (or a
            // later promotion, which joins this thread) behind long waits.
            let mut client = HttpClient::with_deadlines(
                primary,
                Duration::from_millis(500),
                Duration::from_secs(1),
            );
            while !stop.load(Ordering::Acquire) {
                let Some(svc) = weak.upgrade() else { break };
                let idle = tail_once(&svc, &mut client);
                drop(svc);
                if idle {
                    std::thread::sleep(tick);
                }
            }
        })
        .expect("spawn replica tail thread");
    *service.tail_thread.lock().unwrap() = Some(handle);
}

/// One replication pull. Returns `true` when the loop should idle before
/// the next pull (caught up, transport error, or frozen).
fn tail_once(svc: &CacheService, client: &mut HttpClient) -> bool {
    if svc.frozen.load(Ordering::Acquire) {
        return true;
    }
    // Deterministic chaos seam: a dropped pull is only ever a retry.
    if fault::replicate_fails() {
        return true;
    }
    let from = svc.applied.load(Ordering::Acquire);
    let body = match client.get(&format!("/replicate?from={from}")) {
        Ok((200, body)) => body,
        // A dead or erroring primary: keep polling — the client side
        // decides when to promote us, not the replica itself.
        _ => return true,
    };
    let Some(batch) = wire::dec_replicate_resp(&body) else {
        return true; // garbled frame: drop it and re-pull
    };
    // Epoch fence: never apply ops from a primary older than one already
    // seen (a revived stale primary on a reused address).
    if batch.epoch < svc.primary_epoch.load(Ordering::Acquire) {
        return true;
    }
    svc.primary_epoch.fetch_max(batch.epoch, Ordering::AcqRel);
    if batch.shards != svc.sharded.shard_count() as u64 {
        // Replay is only faithful on an identical shard topology.
        svc.frozen.store(true, Ordering::Release);
        return true;
    }
    svc.primary_next.store(batch.next, Ordering::Release);
    if batch.start > from {
        // The primary's window slid past our position: replay would skip
        // mutations. Instead of freezing forever (the PR 8 behavior),
        // install the primary's seq-stamped checkpoint and resume tailing
        // from there.
        return !bootstrap_once(svc, client);
    }
    let mut seq = batch.start;
    for op in batch.ops {
        if seq >= from {
            if !svc.sharded.apply_op(op) {
                svc.skipped_ops.fetch_add(1, Ordering::Relaxed);
            }
            svc.applied.store(seq + 1, Ordering::Release);
        }
        seq += 1;
    }
    svc.applied.load(Ordering::Acquire) >= batch.next
}

/// Install the primary's `GET /bootstrap` checkpoint: replace this
/// follower's state with it and jump the apply position to its stamped
/// sequence. Returns `true` on success (pull again immediately — the
/// live tail resumes from the checkpoint's seq). A transport failure or
/// garbled document is retried on the next tick; a document this replica
/// cannot adopt (shard-count mismatch) freezes it — replay can never be
/// faithful here.
fn bootstrap_once(svc: &CacheService, client: &mut HttpClient) -> bool {
    let doc = match client.get("/bootstrap") {
        Ok((200, body)) => {
            match std::str::from_utf8(&body).ok().and_then(|s| json::parse(s).ok()) {
                Some(doc) => doc,
                None => return false, // garbled: retry next tick
            }
        }
        // The primary answered but has no checkpoint to give (no op-log —
        // it cannot be the primary we were tailing): freeze.
        Ok(_) => {
            svc.frozen.store(true, Ordering::Release);
            return false;
        }
        Err(_) => return false, // transport: retry next tick
    };
    match svc.sharded.adopt_bootstrap(&doc) {
        Some(seq) => {
            svc.applied.store(seq, Ordering::Release);
            svc.bootstraps.fetch_add(1, Ordering::Relaxed);
            true
        }
        None => {
            // Topology mismatch (or a malformed doc from a well-formed
            // frame): this replica's state can never be trusted again.
            svc.frozen.store(true, Ordering::Release);
            false
        }
    }
}

impl Drop for CacheService {
    fn drop(&mut self) {
        self.stop_tail();
    }
}

pub fn hex_encode(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

pub fn hex_decode(s: &str) -> Option<Vec<u8>> {
    if s.len() % 2 != 0 {
        return None;
    }
    (0..s.len())
        .step_by(2)
        .map(|i| u8::from_str_radix(&s[i..i + 2], 16).ok())
        .collect()
}

/// Serialize a legacy JSON lookup request body (shared with the client and
/// the fig10 wire-bytes accounting). Builds the string directly — no `Json`
/// tree, no `tool`/`args` clones.
pub fn lookup_body(task: &str, traj: &[ToolCall]) -> String {
    let mut out = String::with_capacity(24 + traj.len() * 56);
    out.push_str("{\"task\":");
    json::escape_str(task, &mut out);
    out.push_str(",\"trajectory\":");
    trajectory_json_into(traj, &mut out);
    out.push('}');
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::http::HttpClient;

    fn call(s: &str) -> ToolCall {
        ToolCall::new("bash", s)
    }

    fn put_body(task: &str, traj: &[(&str, &str)]) -> String {
        let entries: Vec<Json> = traj
            .iter()
            .map(|(c, r)| {
                Json::obj(vec![
                    ("call", call(c).to_json()),
                    ("result", ToolResult::new(*r, 1.0).to_json()),
                ])
            })
            .collect();
        Json::obj(vec![
            ("task", Json::str(task)),
            ("trajectory", Json::Arr(entries)),
        ])
        .to_string()
    }

    #[test]
    fn http_roundtrip_put_then_hit() {
        let (server, _svc) = serve("127.0.0.1:0", 2).unwrap();
        let mut c = HttpClient::connect(server.addr());

        let (status, body) = c
            .post("/prefix_match", lookup_body("t1", &[call("a")]).as_bytes())
            .unwrap();
        assert_eq!(status, 200);
        let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("hit").unwrap().as_bool(), Some(false));

        let (status, _) = c
            .post("/put", put_body("t1", &[("a", "ra"), ("b", "rb")]).as_bytes())
            .unwrap();
        assert_eq!(status, 200);

        let (_, body) = c
            .post("/get", lookup_body("t1", &[call("a"), call("b")]).as_bytes())
            .unwrap();
        let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("hit").unwrap().as_bool(), Some(true));
        assert_eq!(
            v.get("result").unwrap().get("output").unwrap().as_str(),
            Some("rb")
        );
    }

    #[test]
    fn tasks_are_isolated_across_shards() {
        let (server, _svc) = serve_with("127.0.0.1:0", 2, 4).unwrap();
        let mut c = HttpClient::connect(server.addr());
        c.post("/put", put_body("taskA", &[("x", "rx")]).as_bytes()).unwrap();
        let (_, body) = c
            .post("/get", lookup_body("taskB", &[call("x")]).as_bytes())
            .unwrap();
        let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("hit").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn snapshot_store_and_fetch_over_http() {
        let (server, svc) = serve("127.0.0.1:0", 2).unwrap();
        let mut c = HttpClient::connect(server.addr());
        c.post("/put", put_body("t", &[("a", "ra")]).as_bytes()).unwrap();
        // Node 1 is "a" (first insert).
        let snap_body = Json::obj(vec![
            ("task", Json::str("t")),
            ("node", Json::num(1.0)),
            ("bytes_hex", Json::str(hex_encode(b"state-bytes"))),
            ("serialize_cost", Json::num(0.5)),
            ("restore_cost", Json::num(0.7)),
        ])
        .to_string();
        let (status, body) = c.post("/snapshot", snap_body.as_bytes()).unwrap();
        assert_eq!(status, 200);
        let id = json::parse(std::str::from_utf8(&body).unwrap())
            .unwrap()
            .get("id")
            .unwrap()
            .as_u64()
            .unwrap();
        assert_eq!(svc.snapshot_count(), 1);

        // Fetch with and without the task routing hint.
        for path in [format!("/snapshot?task=t&id={id}"), format!("/snapshot?id={id}")] {
            let (status, body) = c.get(&path).unwrap();
            assert_eq!(status, 200);
            let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
            assert_eq!(
                hex_decode(v.get("bytes_hex").unwrap().as_str().unwrap()).unwrap(),
                b"state-bytes"
            );
        }

        // A subsequent prefix_match miss on a longer trajectory must offer
        // the snapshot as the resume point.
        let (_, body) = c
            .post(
                "/prefix_match",
                lookup_body("t", &[call("a"), call("new")]).as_bytes(),
            )
            .unwrap();
        let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("hit").unwrap().as_bool(), Some(false));
        let resume = v.get("resume").expect("resume offered");
        assert_eq!(resume.get("snap_id").unwrap().as_u64(), Some(id));
        assert_eq!(resume.get("replay_from").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn stats_and_viz_endpoints() {
        let (server, _svc) = serve("127.0.0.1:0", 2).unwrap();
        let mut c = HttpClient::connect(server.addr());
        c.post("/put", put_body("t", &[("a", "ra")]).as_bytes()).unwrap();
        c.post("/get", lookup_body("t", &[call("a")]).as_bytes()).unwrap();
        let (_, body) = c.get("/stats?task=t").unwrap();
        let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("hits").unwrap().as_u64(), Some(1));
        // Service-wide aggregate includes the shard count.
        let (_, body) = c.get("/stats").unwrap();
        let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("shards").unwrap().as_u64(), Some(DEFAULT_SHARDS as u64));
        assert_eq!(v.get("lookups").unwrap().as_u64(), Some(1));
        let (_, body) = c.get("/viz?task=t").unwrap();
        let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("nodes").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn warm_fork_roundtrip_over_http() {
        let (server, _svc) = serve("127.0.0.1:0", 2).unwrap();
        let mut c = HttpClient::connect(server.addr());
        c.post("/put", put_body("t", &[("a", "ra")]).as_bytes()).unwrap();
        let (_, body) = c.get("/warm?task=t&node=1").unwrap();
        let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("warm").unwrap().as_bool(), Some(false));
        let warm_body = Json::obj(vec![
            ("task", Json::str("t")),
            ("node", Json::num(1.0)),
            ("warm", Json::Bool(true)),
        ])
        .to_string();
        c.post("/warm", warm_body.as_bytes()).unwrap();
        let (_, body) = c.get("/warm?task=t&node=1").unwrap();
        let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("warm").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn malformed_requests_rejected() {
        let (server, _svc) = serve("127.0.0.1:0", 2).unwrap();
        let mut c = HttpClient::connect(server.addr());
        let (status, _) = c.post("/get", b"not json").unwrap();
        assert_eq!(status, 400);
        let (status, _) = c.post("/get", b"{}").unwrap();
        assert_eq!(status, 400);
        let (status, _) = c.get("/nope").unwrap();
        assert_eq!(status, 404);
    }

    #[test]
    fn binary_protocol_roundtrip_and_json_coexistence() {
        use crate::wire;
        let (server, _svc) = serve("127.0.0.1:0", 2).unwrap();
        let mut c = HttpClient::connect(server.addr());

        // Binary /put.
        let traj = vec![
            (call("a"), ToolResult::new("ra", 1.0)),
            (call("b"), ToolResult::new("rb", 2.0)),
        ];
        let mut buf = Vec::new();
        wire::enc_insert(&mut buf, "bt", &traj);
        let (status, body) = c.post("/put", &buf).unwrap();
        assert_eq!(status, 200);
        let node = wire::dec_u64_resp(&body).unwrap();
        assert!(node > 0);

        // Binary /get hits what binary /put recorded…
        buf.clear();
        wire::enc_lookup(&mut buf, "bt", &[call("a"), call("b")]);
        let (status, body) = c.post("/get", &buf).unwrap();
        assert_eq!(status, 200);
        match wire::dec_lookup_resp(&body).unwrap() {
            Lookup::Hit { result, .. } => assert_eq!(result.output, "rb"),
            m => panic!("expected binary hit, got {m:?}"),
        }

        // …and the legacy JSON endpoint sees the same cache.
        let (_, body) = c
            .post("/get", lookup_body("bt", &[call("a"), call("b")]).as_bytes())
            .unwrap();
        let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("hit").unwrap().as_bool(), Some(true));

        // Binary /release is a 200 no-op on an unpinned node.
        buf.clear();
        wire::enc_release(&mut buf, "bt", node as usize);
        let (status, _) = c.post("/release", &buf).unwrap();
        assert_eq!(status, 200);

        // Truncated binary frames are 400s, not panics.
        buf.clear();
        wire::enc_lookup(&mut buf, "bt", &[call("a")]);
        let (status, _) = c.post("/get", &buf[..buf.len() - 2]).unwrap();
        assert_eq!(status, 400);
    }

    #[test]
    fn cursor_endpoints_drive_a_full_rollout() {
        use crate::wire;
        let (server, svc) = serve("127.0.0.1:0", 2).unwrap();
        let mut c = HttpClient::connect(server.addr());
        let mut buf = Vec::new();

        wire::enc_cursor_open(&mut buf, "ct");
        let (_, body) = c.post("/cursor_open", &buf).unwrap();
        let cur = wire::dec_u64_resp(&body).unwrap();
        assert!(cur > 0);

        // Miss → record, twice; then replay the chain as hits.
        for cmd in ["make", "make test"] {
            buf.clear();
            wire::enc_cursor_step(&mut buf, "ct", cur, &call(cmd));
            let (_, body) = c.post("/cursor_step", &buf).unwrap();
            assert!(matches!(
                wire::dec_step_resp(&body).unwrap(),
                crate::cache::CursorStep::Miss(_)
            ));
            buf.clear();
            wire::enc_cursor_record(&mut buf, "ct", cur, &call(cmd), &ToolResult::new(cmd, 1.0));
            let (_, body) = c.post("/cursor_record", &buf).unwrap();
            assert!(wire::dec_u64_resp(&body).unwrap() > 0);
        }
        buf.clear();
        wire::enc_cursor_seek(&mut buf, "ct", cur, 0, 0);
        let (_, body) = c.post("/cursor_seek", &buf).unwrap();
        assert_eq!(wire::dec_bool_resp(&body), Some(true));
        for cmd in ["make", "make test"] {
            buf.clear();
            wire::enc_cursor_step(&mut buf, "ct", cur, &call(cmd));
            let (_, body) = c.post("/cursor_step", &buf).unwrap();
            match wire::dec_step_resp(&body).unwrap() {
                crate::cache::CursorStep::Hit { result, .. } => {
                    assert_eq!(result.output, cmd)
                }
                s => panic!("warm chain must hit: {s:?}"),
            }
        }

        buf.clear();
        wire::enc_cursor_close(&mut buf, "ct", cur);
        let (status, _) = c.post("/cursor_close", &buf).unwrap();
        assert_eq!(status, 200);
        // Stats flowed through the cursor path like any lookup.
        assert_eq!(svc.task("ct").stats().lookups, 4);
        assert_eq!(svc.task("ct").stats().hits, 2);
    }

    fn replicated_pair() -> (Server, Arc<CacheService>, Server, Arc<CacheService>) {
        let cfg = crate::cache::ServiceConfig {
            shards: 2,
            replicate_window: Some(4096),
            ..Default::default()
        };
        let primary = ShardedCacheService::with_config(cfg, Arc::new(TaskCache::with_defaults))
            .unwrap();
        let (psrv, psvc) = serve_service("127.0.0.1:0", 2, primary).unwrap();
        let follower = ShardedCacheService::with_factory(2, Arc::new(TaskCache::with_defaults));
        let (fsrv, fsvc) = serve_follower("127.0.0.1:0", 2, follower, psrv.addr()).unwrap();
        (psrv, psvc, fsrv, fsvc)
    }

    /// Poll the follower (over HTTP, so offer pins are returned) until a
    /// lookup hits or the deadline passes.
    fn await_hit(c: &mut HttpClient, task: &str, traj: &[ToolCall]) {
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let (_, body) = c.post("/get", lookup_body(task, traj).as_bytes()).unwrap();
            let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
            if v.get("hit").and_then(|h| h.as_bool()) == Some(true) {
                return;
            }
            assert!(Instant::now() < deadline, "follower never replicated {task}");
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    #[test]
    fn follower_tails_the_primary_and_promotion_fences_the_epoch() {
        let (psrv, _psvc, fsrv, fsvc) = replicated_pair();
        let mut pc = HttpClient::connect(psrv.addr());
        let mut fc = HttpClient::connect(fsrv.addr());
        pc.post("/put", put_body("t", &[("a", "ra"), ("b", "rb")]).as_bytes()).unwrap();
        await_hit(&mut fc, "t", &[call("a"), call("b")]);

        // Pre-promotion the follower is read-only…
        let (status, _) = fc.post("/put", put_body("x", &[("q", "r")]).as_bytes()).unwrap();
        assert_eq!(status, 503);
        // …and reports its role.
        let (_, body) = fc.get("/stats").unwrap();
        let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("role").unwrap().as_str(), Some("follower"));

        let (status, body) = fc.post("/promote", b"").unwrap();
        assert_eq!(status, 200);
        let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("promoted").unwrap().as_bool(), Some(true));
        let epoch = v.get("epoch").unwrap().as_u64().unwrap();
        assert!(epoch >= 2, "promotion must bump past the primary's epoch");
        assert!(!fsvc.is_follower());

        // Idempotent: promoting a primary reports, never re-bumps.
        let (_, body) = fc.post("/promote", b"").unwrap();
        let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("promoted").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("epoch").unwrap().as_u64(), Some(epoch));

        // Writable now, and sealed replies carry the bumped epoch.
        let traj = vec![(call("c"), ToolResult::new("rc", 1.0))];
        let mut buf = Vec::new();
        wire::enc_insert(&mut buf, "t2", &traj);
        let (status, body) = fc.post("/put", &buf).unwrap();
        assert_eq!(status, 200);
        assert_eq!(wire::resp_epoch(&body), Some(epoch));
        assert!(wire::dec_u64_resp(&body).unwrap() > 0);
    }

    #[test]
    fn drain_waits_for_the_follower_and_refuses_new_sessions() {
        let (psrv, _psvc, _fsrv, fsvc) = replicated_pair();
        let mut pc = HttpClient::connect(psrv.addr());
        pc.post("/put", put_body("t", &[("a", "ra")]).as_bytes()).unwrap();

        let (status, body) = pc.post("/drain", b"").unwrap();
        assert_eq!(status, 200);
        let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("caught_up").unwrap().as_bool(), Some(true));
        assert!(v.get("final_seq").unwrap().as_u64().unwrap() >= 1);

        // New sessions are refused after drain…
        let mut buf = Vec::new();
        wire::enc_cursor_open(&mut buf, "t");
        let (_, body) = pc.post("/cursor_open", &buf).unwrap();
        assert_eq!(wire::dec_u64_resp(&body), Some(0));
        // …while plain reads keep answering.
        let (_, body) =
            pc.post("/get", lookup_body("t", &[call("a")]).as_bytes()).unwrap();
        let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("hit").unwrap().as_bool(), Some(true));
        // The follower acknowledged the whole log before drain returned.
        assert_eq!(fsvc.replica_lag_ops(), 0);
        assert_eq!(fsvc.skipped_ops(), 0);
    }

    #[test]
    fn replicate_without_an_oplog_is_rejected() {
        let (server, _svc) = serve("127.0.0.1:0", 2).unwrap();
        let mut c = HttpClient::connect(server.addr());
        let (status, _) = c.get("/replicate?from=0").unwrap();
        assert_eq!(status, 400);
    }

    #[test]
    fn hex_roundtrip() {
        let data: Vec<u8> = (0..=255).collect();
        assert_eq!(hex_decode(&hex_encode(&data)).unwrap(), data);
        assert!(hex_decode("abc").is_none());
        assert!(hex_decode("zz").is_none());
    }

    #[test]
    fn bootstrap_endpoint_returns_a_seq_stamped_checkpoint() {
        let (psrv, _psvc, _fsrv, _fsvc) = replicated_pair();
        let mut pc = HttpClient::connect(psrv.addr());
        pc.post("/put", put_body("t", &[("a", "ra")]).as_bytes()).unwrap();
        let (status, body) = pc.get("/bootstrap").unwrap();
        assert_eq!(status, 200);
        let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert!(v.get("seq").unwrap().as_u64().unwrap() >= 1);
        assert_eq!(v.get("shards").unwrap().as_u64(), Some(2));
        assert_eq!(v.get("tasks").unwrap().as_arr().unwrap().len(), 1);

        // A server without an op-log has nothing to bootstrap from.
        let (server, _svc) = serve("127.0.0.1:0", 2).unwrap();
        let mut c = HttpClient::connect(server.addr());
        let (status, _) = c.get("/bootstrap").unwrap();
        assert_eq!(status, 400);
    }

    #[test]
    fn gapped_follower_bootstraps_to_zero_lag_instead_of_freezing() {
        // A tiny op-log window, filled well past capacity before the
        // follower exists: its first pull at from=0 observes a gap, which
        // froze the replica permanently in PR 8.
        let cfg = crate::cache::ServiceConfig {
            shards: 2,
            replicate_window: Some(4),
            ..Default::default()
        };
        let primary =
            ShardedCacheService::with_config(cfg, Arc::new(TaskCache::with_defaults)).unwrap();
        let (psrv, _psvc) = serve_service("127.0.0.1:0", 2, primary).unwrap();
        let mut pc = HttpClient::connect(psrv.addr());
        for i in 0..16 {
            let t = format!("t{i}");
            pc.post("/put", put_body(&t, &[("a", "ra"), ("b", "rb")]).as_bytes()).unwrap();
        }
        let follower = ShardedCacheService::with_factory(2, Arc::new(TaskCache::with_defaults));
        let (fsrv, fsvc) = serve_follower_with_tick(
            "127.0.0.1:0",
            2,
            follower,
            psrv.addr(),
            Duration::from_millis(2),
        )
        .unwrap();
        let mut fc = HttpClient::connect(fsrv.addr());

        // State the window no longer covers arrives via the checkpoint…
        await_hit(&mut fc, "t0", &[call("a"), call("b")]);
        // …and the live tail resumed past it.
        pc.post("/put", put_body("tail", &[("z", "rz")]).as_bytes()).unwrap();
        await_hit(&mut fc, "tail", &[call("z")]);
        let deadline = Instant::now() + Duration::from_secs(5);
        while fsvc.replica_lag_ops() != 0 {
            assert!(Instant::now() < deadline, "follower never reached zero lag");
            std::thread::sleep(Duration::from_millis(2));
        }

        let (_, body) = fc.get("/stats").unwrap();
        let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("replica_frozen").unwrap().as_bool(), Some(false));
        assert!(v.get("replica_bootstraps").unwrap().as_u64().unwrap() >= 1);
        assert_eq!(v.get("replica_skipped_ops").unwrap().as_u64(), Some(0));
        // The primary accounted the bytes it shipped tailing.
        let (_, body) = pc.get("/stats").unwrap();
        let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert!(v.get("replicate_bytes_shipped").unwrap().as_u64().unwrap() > 0);
    }

    #[test]
    fn shard_mismatched_bootstrap_still_freezes_the_follower() {
        let cfg = crate::cache::ServiceConfig {
            shards: 2,
            replicate_window: Some(2),
            ..Default::default()
        };
        let primary =
            ShardedCacheService::with_config(cfg, Arc::new(TaskCache::with_defaults)).unwrap();
        let (psrv, _psvc) = serve_service("127.0.0.1:0", 2, primary).unwrap();
        let mut pc = HttpClient::connect(psrv.addr());
        for i in 0..8 {
            pc.post("/put", put_body(&format!("t{i}"), &[("a", "ra")]).as_bytes()).unwrap();
        }
        // Wrong shard count: the gap triggers a bootstrap attempt, whose
        // adoption is refused — the replica freezes rather than diverge.
        let follower = ShardedCacheService::with_factory(3, Arc::new(TaskCache::with_defaults));
        let (fsrv, _fsvc) = serve_follower_with_tick(
            "127.0.0.1:0",
            2,
            follower,
            psrv.addr(),
            Duration::from_millis(2),
        )
        .unwrap();
        let mut fc = HttpClient::connect(fsrv.addr());
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let (_, body) = fc.get("/stats").unwrap();
            let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
            if v.get("replica_frozen").unwrap().as_bool() == Some(true) {
                break;
            }
            assert!(Instant::now() < deadline, "mismatched follower never froze");
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    #[test]
    fn drain_and_persist_drive_end_to_end_through_the_remote_binding() {
        use crate::client::RemoteBinding;
        let (psrv, _psvc, _fsrv, _fsvc) = replicated_pair();
        let b = RemoteBinding::connect(psrv.addr());
        let traj = vec![(call("a"), ToolResult::new("ra", 1.0))];
        assert!(b.insert("t", &traj).unwrap() > 0);

        let dir = std::env::temp_dir().join(format!(
            "tvcache-drain-binding-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let report = b.drain(Some(dir.to_str().unwrap())).expect("drain must answer");
        assert!(report.caught_up, "follower acks the whole log before drain returns");
        assert!(report.final_seq >= 1);
        assert_eq!(report.persisted, Some(true));

        // The drained server refuses new sessions but still answers reads.
        assert_eq!(b.cursor_open("t"), 0);
        assert!(matches!(b.lookup("t", &[call("a")]), Lookup::Hit { .. }));

        // The persisted state warm-starts a fresh service.
        let fresh = ShardedCacheService::new(2);
        assert!(fresh.warm_start(dir.to_str().unwrap()));
        assert!(matches!(fresh.lookup("t", &[call("a")]), Lookup::Hit { .. }));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
