//! The TVCACHE server (§3.4, Figure 4): an HTTP service fronting the
//! in-process [`ShardedCacheService`] — per-task TCGs and sandbox snapshots
//! sharded by `hash(task_id)` (§4.5), each shard with its own task map and
//! snapshot store, so no request path holds a global lock.
//!
//! Endpoints (mirroring the paper's API):
//!
//! * `POST /get`           — exact-match lookup (hit or plain miss)
//! * `POST /prefix_match`  — full LPM lookup (hit, or miss + resume info)
//! * `POST /put`           — insert an executed trajectory
//! * `POST /release`       — decrement a node's sandbox refcount
//! * `POST /snapshot`      — store a serialized sandbox for a node
//! * `GET  /snapshot`      — fetch snapshot bytes (`?task=&id=`)
//! * `POST /warm`          — mark a node's background fork warm
//! * `GET  /warm`          — query a node's warm-fork flag (`?task=&node=`)
//! * `POST /persist`       — persist all TCGs + snapshot payloads (`{dir}`)
//! * `POST /warm_start`    — warm-start from a persisted dir (`{dir}`)
//! * `GET  /stats`         — per-task (`?task=`) or service-wide statistics
//!   (service-wide includes spill-tier occupancy / fault / eviction counters)
//! * `GET  /viz`           — TCG structure as JSON (Figure 9)
//! * `GET  /ping`          — liveness
//!
//! Every handler programs against the [`CacheBackend`] trait — the same
//! surface the executor and the training loops use in-process.

use std::sync::Arc;

use crate::cache::key::{trajectory_from_json, trajectory_to_json, ToolCall};
use crate::cache::{
    CacheBackend, CacheFactory, Lookup, ShardedCacheService, TaskCache, ToolResult,
};
use crate::sandbox::SandboxSnapshot;
use crate::util::http::{Handler, Request, Response, Server};
use crate::util::json::{self, Json};

/// Default shard count for a served cache (Figure 8a's scaling knob).
pub const DEFAULT_SHARDS: usize = 8;

/// Shared server state: the sharded cache service plus HTTP plumbing.
pub struct CacheService {
    sharded: ShardedCacheService,
}

impl CacheService {
    pub fn new() -> Arc<CacheService> {
        Self::with_shards(DEFAULT_SHARDS)
    }

    pub fn with_shards(shards: usize) -> Arc<CacheService> {
        Arc::new(CacheService { sharded: ShardedCacheService::new(shards) })
    }

    /// Custom per-task cache policies (used by benches).
    pub fn with_factory(shards: usize, factory: CacheFactory) -> Arc<CacheService> {
        Arc::new(CacheService {
            sharded: ShardedCacheService::with_factory(shards, factory),
        })
    }

    /// Front an already-built sharded service (spill/budget-configured).
    pub fn with_service(sharded: ShardedCacheService) -> Arc<CacheService> {
        Arc::new(CacheService { sharded })
    }

    /// The trait surface every handler dispatches through.
    pub fn backend(&self) -> &dyn CacheBackend {
        &self.sharded
    }

    /// White-box access to a per-task cache (tests, persistence jobs).
    pub fn task(&self, id: &str) -> Arc<TaskCache> {
        self.sharded.task(id)
    }

    pub fn shard_count(&self) -> usize {
        self.sharded.shard_count()
    }

    /// Stored snapshots across all shards.
    pub fn snapshot_count(&self) -> usize {
        self.sharded.snapshot_count()
    }

    /// White-box eviction of one node's snapshot (tests of the unpinned
    /// resume-offer race — see the comment in `lookup`).
    pub fn evict_snapshot(&self, task: &str, node: usize) -> bool {
        self.sharded.evict_snapshot(task, node)
    }

    fn handle(&self, req: &Request) -> Response {
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/ping") => Response::text(200, "pong"),
            ("POST", "/get") | ("POST", "/prefix_match") => self.lookup(req),
            ("POST", "/put") => self.put(req),
            ("POST", "/release") => self.release(req),
            ("POST", "/snapshot") => self.store_snapshot(req),
            ("GET", "/snapshot") => self.fetch_snapshot(req),
            ("POST", "/warm") => self.set_warm(req),
            ("GET", "/warm") => self.get_warm(req),
            ("POST", "/persist") => self.persist(req),
            ("POST", "/warm_start") => self.warm_start(req),
            ("GET", "/stats") => self.stats(req),
            ("GET", "/viz") => self.viz(req),
            _ => Response::not_found(),
        }
    }

    fn parse_body(req: &Request) -> Result<Json, Response> {
        json::parse(req.body_str())
            .map_err(|e| Response::bad_request(format!("bad json: {e}")))
    }

    fn task_of(body: &Json) -> Result<&str, Response> {
        body.get("task")
            .and_then(|t| t.as_str())
            .ok_or_else(|| Response::bad_request("missing task"))
    }

    fn lookup(&self, req: &Request) -> Response {
        let body = match Self::parse_body(req) {
            Ok(b) => b,
            Err(r) => return r,
        };
        let task = match Self::task_of(&body) {
            Ok(t) => t,
            Err(r) => return r,
        };
        let Some(traj) = body.get("trajectory").and_then(trajectory_from_json) else {
            return Response::bad_request("missing trajectory");
        };
        if traj.is_empty() {
            return Response::bad_request("empty trajectory");
        }
        let out = match self.backend().lookup(task, &traj) {
            Lookup::Hit { node, result } => Json::obj(vec![
                ("hit", Json::Bool(true)),
                ("node", Json::num(node as f64)),
                ("result", result.to_json()),
            ]),
            Lookup::Miss(m) => {
                let mut fields = vec![
                    ("hit", Json::Bool(false)),
                    ("matched_node", Json::num(m.matched_node as f64)),
                    ("matched_calls", Json::num(m.matched_calls as f64)),
                ];
                if let Some((node, snap, replay_from)) = m.resume {
                    // The wire protocol cannot carry a reliable distributed
                    // refcount: a response lost after the lookup pinned the
                    // node would leak the pin — and block that snapshot's
                    // eviction — forever. Resume offers over HTTP are
                    // therefore unpinned (the lookup's pin is returned
                    // before replying); a client whose later fetch loses
                    // the eviction race degrades gracefully to replay
                    // (`fetch_snapshot` → None), and its `/release` is a
                    // saturating no-op.
                    self.backend().release(task, node);
                    fields.push((
                        "resume",
                        Json::obj(vec![
                            ("node", Json::num(node as f64)),
                            ("snap_id", Json::num(snap.id as f64)),
                            ("restore_cost", Json::num(snap.restore_cost)),
                            ("replay_from", Json::num(replay_from as f64)),
                        ]),
                    ));
                }
                Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
            }
        };
        Response::json(out.to_string())
    }

    fn put(&self, req: &Request) -> Response {
        let body = match Self::parse_body(req) {
            Ok(b) => b,
            Err(r) => return r,
        };
        let task = match Self::task_of(&body) {
            Ok(t) => t,
            Err(r) => return r,
        };
        let Some(entries) = body.get("trajectory").and_then(|t| t.as_arr()) else {
            return Response::bad_request("missing trajectory");
        };
        let mut traj = Vec::with_capacity(entries.len());
        for e in entries {
            let (Some(call), Some(result)) = (
                e.get("call").and_then(ToolCall::from_json),
                e.get("result").and_then(ToolResult::from_json),
            ) else {
                return Response::bad_request("bad trajectory entry");
            };
            traj.push((call, result));
        }
        let node = self.backend().insert(task, &traj);
        Response::json(Json::obj(vec![("node", Json::num(node as f64))]).to_string())
    }

    fn release(&self, req: &Request) -> Response {
        let body = match Self::parse_body(req) {
            Ok(b) => b,
            Err(r) => return r,
        };
        let task = match Self::task_of(&body) {
            Ok(t) => t,
            Err(r) => return r,
        };
        let Some(node) = body.get("node").and_then(|n| n.as_u64()) else {
            return Response::bad_request("missing node");
        };
        self.backend().release(task, node as usize);
        Response::json("{}".to_string())
    }

    fn store_snapshot(&self, req: &Request) -> Response {
        let body = match Self::parse_body(req) {
            Ok(b) => b,
            Err(r) => return r,
        };
        let task = match Self::task_of(&body) {
            Ok(t) => t,
            Err(r) => return r,
        };
        let (Some(node), Some(hex), Some(ser), Some(rest)) = (
            body.get("node").and_then(|n| n.as_u64()),
            body.get("bytes_hex").and_then(|b| b.as_str()),
            body.get("serialize_cost").and_then(|c| c.as_f64()),
            body.get("restore_cost").and_then(|c| c.as_f64()),
        ) else {
            return Response::bad_request("missing snapshot fields");
        };
        let Some(bytes) = hex_decode(hex) else {
            return Response::bad_request("bad hex");
        };
        let snap = SandboxSnapshot { bytes, serialize_cost: ser, restore_cost: rest };
        let id = self.backend().store_snapshot(task, node as usize, snap);
        Response::json(Json::obj(vec![("id", Json::num(id as f64))]).to_string())
    }

    fn fetch_snapshot(&self, req: &Request) -> Response {
        let Some(id) = req.query.get("id").and_then(|s| s.parse::<u64>().ok()) else {
            return Response::bad_request("missing id");
        };
        let snap = match req.query.get("task") {
            Some(task) => self.backend().fetch_snapshot(task, id),
            // Legacy fetches carry no task; the strided id space still
            // identifies the owning shard.
            None => self.sharded.fetch_snapshot_any(id),
        };
        match snap {
            Some(s) => Response::json(
                Json::obj(vec![
                    ("bytes_hex", Json::str(hex_encode(&s.bytes))),
                    ("serialize_cost", Json::num(s.serialize_cost)),
                    ("restore_cost", Json::num(s.restore_cost)),
                ])
                .to_string(),
            ),
            None => Response::not_found(),
        }
    }

    fn set_warm(&self, req: &Request) -> Response {
        let body = match Self::parse_body(req) {
            Ok(b) => b,
            Err(r) => return r,
        };
        let task = match Self::task_of(&body) {
            Ok(t) => t,
            Err(r) => return r,
        };
        let (Some(node), Some(warm)) = (
            body.get("node").and_then(|n| n.as_u64()),
            body.get("warm").and_then(|w| w.as_bool()),
        ) else {
            return Response::bad_request("missing node/warm");
        };
        self.backend().set_warm_fork(task, node as usize, warm);
        Response::json("{}".to_string())
    }

    fn get_warm(&self, req: &Request) -> Response {
        let (Some(task), Some(node)) = (
            req.query.get("task"),
            req.query.get("node").and_then(|s| s.parse::<u64>().ok()),
        ) else {
            return Response::bad_request("missing task/node");
        };
        let warm = self.backend().has_warm_fork(task, node as usize);
        Response::json(Json::obj(vec![("warm", Json::Bool(warm))]).to_string())
    }

    /// `{dir}` body → persist / warm-start the whole service state. The
    /// directory is a *server-local* path (the snapshot lifecycle's
    /// warm-start tier, not a client upload). Like the rest of the wire
    /// protocol this is unauthenticated — a client that can reach the
    /// port can direct writes/reads at any path the server process can
    /// touch, so bind trusted interfaces only (the paper's deployment
    /// model: the cache server lives inside the training cluster).
    fn lifecycle(&self, req: &Request, warm: bool) -> Response {
        let body = match Self::parse_body(req) {
            Ok(b) => b,
            Err(r) => return r,
        };
        let Some(dir) = body.get("dir").and_then(|d| d.as_str()) else {
            return Response::bad_request("missing dir");
        };
        let ok = if warm {
            self.backend().warm_start(dir)
        } else {
            self.backend().persist(dir)
        };
        Response::json(Json::obj(vec![("ok", Json::Bool(ok))]).to_string())
    }

    fn persist(&self, req: &Request) -> Response {
        self.lifecycle(req, false)
    }

    fn warm_start(&self, req: &Request) -> Response {
        self.lifecycle(req, true)
    }

    fn stats(&self, req: &Request) -> Response {
        match req.query.get("task") {
            Some(task) => Response::json(self.backend().stats(task).to_json().to_string()),
            None => Response::json(self.backend().service_stats().to_json().to_string()),
        }
    }

    fn viz(&self, req: &Request) -> Response {
        match req.query.get("task") {
            Some(task) => Response::json(self.task(task).viz_json().to_string()),
            None => Response::bad_request("missing task"),
        }
    }
}

/// Start a TVCACHE server on `addr` with the default shard count; returns
/// the HTTP server handle and the shared service (for white-box assertions).
pub fn serve(addr: &str, workers: usize) -> std::io::Result<(Server, Arc<CacheService>)> {
    serve_with(addr, workers, DEFAULT_SHARDS)
}

/// Start a TVCACHE server with an explicit shard count.
pub fn serve_with(
    addr: &str,
    workers: usize,
    shards: usize,
) -> std::io::Result<(Server, Arc<CacheService>)> {
    serve_service(addr, workers, ShardedCacheService::new(shards))
}

/// Start a TVCACHE server fronting an already-built sharded service (the
/// way to serve a byte-budgeted / spill-tiered configuration).
pub fn serve_service(
    addr: &str,
    workers: usize,
    sharded: ShardedCacheService,
) -> std::io::Result<(Server, Arc<CacheService>)> {
    let service = CacheService::with_service(sharded);
    let svc = Arc::clone(&service);
    let handler: Handler = Arc::new(move |req: &Request| svc.handle(req));
    let server = Server::bind(addr, workers, handler)?;
    Ok((server, service))
}

pub fn hex_encode(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

pub fn hex_decode(s: &str) -> Option<Vec<u8>> {
    if s.len() % 2 != 0 {
        return None;
    }
    (0..s.len())
        .step_by(2)
        .map(|i| u8::from_str_radix(&s[i..i + 2], 16).ok())
        .collect()
}

/// Serialize a lookup request body (shared with the client).
pub fn lookup_body(task: &str, traj: &[ToolCall]) -> String {
    Json::obj(vec![
        ("task", Json::str(task)),
        ("trajectory", trajectory_to_json(traj)),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::http::HttpClient;

    fn call(s: &str) -> ToolCall {
        ToolCall::new("bash", s)
    }

    fn put_body(task: &str, traj: &[(&str, &str)]) -> String {
        let entries: Vec<Json> = traj
            .iter()
            .map(|(c, r)| {
                Json::obj(vec![
                    ("call", call(c).to_json()),
                    ("result", ToolResult::new(*r, 1.0).to_json()),
                ])
            })
            .collect();
        Json::obj(vec![
            ("task", Json::str(task)),
            ("trajectory", Json::Arr(entries)),
        ])
        .to_string()
    }

    #[test]
    fn http_roundtrip_put_then_hit() {
        let (server, _svc) = serve("127.0.0.1:0", 2).unwrap();
        let mut c = HttpClient::connect(server.addr());

        let (status, body) = c
            .post("/prefix_match", lookup_body("t1", &[call("a")]).as_bytes())
            .unwrap();
        assert_eq!(status, 200);
        let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("hit").unwrap().as_bool(), Some(false));

        let (status, _) = c
            .post("/put", put_body("t1", &[("a", "ra"), ("b", "rb")]).as_bytes())
            .unwrap();
        assert_eq!(status, 200);

        let (_, body) = c
            .post("/get", lookup_body("t1", &[call("a"), call("b")]).as_bytes())
            .unwrap();
        let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("hit").unwrap().as_bool(), Some(true));
        assert_eq!(
            v.get("result").unwrap().get("output").unwrap().as_str(),
            Some("rb")
        );
    }

    #[test]
    fn tasks_are_isolated_across_shards() {
        let (server, _svc) = serve_with("127.0.0.1:0", 2, 4).unwrap();
        let mut c = HttpClient::connect(server.addr());
        c.post("/put", put_body("taskA", &[("x", "rx")]).as_bytes()).unwrap();
        let (_, body) = c
            .post("/get", lookup_body("taskB", &[call("x")]).as_bytes())
            .unwrap();
        let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("hit").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn snapshot_store_and_fetch_over_http() {
        let (server, svc) = serve("127.0.0.1:0", 2).unwrap();
        let mut c = HttpClient::connect(server.addr());
        c.post("/put", put_body("t", &[("a", "ra")]).as_bytes()).unwrap();
        // Node 1 is "a" (first insert).
        let snap_body = Json::obj(vec![
            ("task", Json::str("t")),
            ("node", Json::num(1.0)),
            ("bytes_hex", Json::str(hex_encode(b"state-bytes"))),
            ("serialize_cost", Json::num(0.5)),
            ("restore_cost", Json::num(0.7)),
        ])
        .to_string();
        let (status, body) = c.post("/snapshot", snap_body.as_bytes()).unwrap();
        assert_eq!(status, 200);
        let id = json::parse(std::str::from_utf8(&body).unwrap())
            .unwrap()
            .get("id")
            .unwrap()
            .as_u64()
            .unwrap();
        assert_eq!(svc.snapshot_count(), 1);

        // Fetch with and without the task routing hint.
        for path in [format!("/snapshot?task=t&id={id}"), format!("/snapshot?id={id}")] {
            let (status, body) = c.get(&path).unwrap();
            assert_eq!(status, 200);
            let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
            assert_eq!(
                hex_decode(v.get("bytes_hex").unwrap().as_str().unwrap()).unwrap(),
                b"state-bytes"
            );
        }

        // A subsequent prefix_match miss on a longer trajectory must offer
        // the snapshot as the resume point.
        let (_, body) = c
            .post(
                "/prefix_match",
                lookup_body("t", &[call("a"), call("new")]).as_bytes(),
            )
            .unwrap();
        let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("hit").unwrap().as_bool(), Some(false));
        let resume = v.get("resume").expect("resume offered");
        assert_eq!(resume.get("snap_id").unwrap().as_u64(), Some(id));
        assert_eq!(resume.get("replay_from").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn stats_and_viz_endpoints() {
        let (server, _svc) = serve("127.0.0.1:0", 2).unwrap();
        let mut c = HttpClient::connect(server.addr());
        c.post("/put", put_body("t", &[("a", "ra")]).as_bytes()).unwrap();
        c.post("/get", lookup_body("t", &[call("a")]).as_bytes()).unwrap();
        let (_, body) = c.get("/stats?task=t").unwrap();
        let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("hits").unwrap().as_u64(), Some(1));
        // Service-wide aggregate includes the shard count.
        let (_, body) = c.get("/stats").unwrap();
        let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("shards").unwrap().as_u64(), Some(DEFAULT_SHARDS as u64));
        assert_eq!(v.get("lookups").unwrap().as_u64(), Some(1));
        let (_, body) = c.get("/viz?task=t").unwrap();
        let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("nodes").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn warm_fork_roundtrip_over_http() {
        let (server, _svc) = serve("127.0.0.1:0", 2).unwrap();
        let mut c = HttpClient::connect(server.addr());
        c.post("/put", put_body("t", &[("a", "ra")]).as_bytes()).unwrap();
        let (_, body) = c.get("/warm?task=t&node=1").unwrap();
        let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("warm").unwrap().as_bool(), Some(false));
        let warm_body = Json::obj(vec![
            ("task", Json::str("t")),
            ("node", Json::num(1.0)),
            ("warm", Json::Bool(true)),
        ])
        .to_string();
        c.post("/warm", warm_body.as_bytes()).unwrap();
        let (_, body) = c.get("/warm?task=t&node=1").unwrap();
        let v = json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        assert_eq!(v.get("warm").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn malformed_requests_rejected() {
        let (server, _svc) = serve("127.0.0.1:0", 2).unwrap();
        let mut c = HttpClient::connect(server.addr());
        let (status, _) = c.post("/get", b"not json").unwrap();
        assert_eq!(status, 400);
        let (status, _) = c.post("/get", b"{}").unwrap();
        assert_eq!(status, 400);
        let (status, _) = c.get("/nope").unwrap();
        assert_eq!(status, 404);
    }

    #[test]
    fn hex_roundtrip() {
        let data: Vec<u8> = (0..=255).collect();
        assert_eq!(hex_decode(&hex_encode(&data)).unwrap(), data);
        assert!(hex_decode("abc").is_none());
        assert!(hex_decode("zz").is_none());
    }
}
