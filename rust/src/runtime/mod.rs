//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) and
//! executes them on the CPU PJRT client.
//!
//! The PJRT-backed implementation lives in `pjrt` behind the `pjrt` cargo
//! feature: it is the only code in the crate that needs the external `xla`
//! crate, which the offline toolchain does not ship. Without the feature a
//! stub `AgentRuntime` with the identical API compiles in; every call
//! returns a [`RuntimeError`] telling the operator to rebuild with
//! `--features pjrt` (after vendoring the `xla` crate).
//!
//! Python never runs at post-training time either way: `make artifacts`
//! lowers the Layer-2 JAX graphs (which call the Layer-1 Pallas kernels) to
//! HLO text once; the PJRT build compiles them and threads the flat
//! parameter vector through init → forward → train_step.

use std::path::Path;

use crate::util::json::{self, Json};

/// Runtime-layer error (artifact loading, shape checks, PJRT failures).
#[derive(Debug, Clone)]
pub struct RuntimeError(pub String);

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "runtime: {}", self.0)
    }
}

impl std::error::Error for RuntimeError {}

pub type Result<T> = std::result::Result<T, RuntimeError>;

pub(crate) fn rerr(msg: impl Into<String>) -> RuntimeError {
    RuntimeError(msg.into())
}

/// Model metadata written by `python/compile/aot.py`.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub param_count: usize,
    pub vocab: usize,
    pub seq: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub rollout_batch: usize,
    pub train_batch: usize,
    pub use_pallas: bool,
}

impl ModelMeta {
    pub fn load(dir: &Path) -> Result<ModelMeta> {
        let path = dir.join("meta.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            rerr(format!("reading {} — run `make artifacts` ({e})", path.display()))
        })?;
        let v = json::parse(&text).map_err(|e| rerr(format!("meta.json: {e}")))?;
        let get = |k: &str| -> Result<f64> {
            v.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| rerr(format!("meta.json missing {k}")))
        };
        Ok(ModelMeta {
            param_count: get("param_count")? as usize,
            vocab: get("vocab")? as usize,
            seq: get("seq")? as usize,
            d_model: get("d_model")? as usize,
            n_layers: get("n_layers")? as usize,
            rollout_batch: get("rollout_batch")? as usize,
            train_batch: get("train_batch")? as usize,
            use_pallas: v.get("use_pallas").and_then(Json::as_bool).unwrap_or(true),
        })
    }
}

// The real backend needs BOTH features: `pjrt` selects the runtime and
// `xla` (which requires vendoring the external `xla` crate into
// Cargo.toml) pulls in the C-API bindings. `--features pjrt` alone keeps
// the stub, so CI can compile-check the pjrt feature surface without the
// vendored crate.
#[cfg(all(feature = "pjrt", feature = "xla"))]
mod pjrt;
#[cfg(all(feature = "pjrt", feature = "xla"))]
pub use pjrt::AgentRuntime;

#[cfg(not(all(feature = "pjrt", feature = "xla")))]
mod stub {
    use super::{rerr, ModelMeta, Result};
    use std::path::Path;

    const MSG: &str =
        "built without the `pjrt`+`xla` features — vendor the `xla` crate and \
         rebuild with `cargo build --features pjrt,xla` to run the PJRT artifacts";

    /// API-compatible stand-in for the PJRT-backed runtime.
    pub struct AgentRuntime {
        pub meta: ModelMeta,
        pub params: Vec<f32>,
    }

    impl AgentRuntime {
        pub fn load(_dir: impl AsRef<Path>) -> Result<AgentRuntime> {
            Err(rerr(MSG))
        }

        pub fn platform(&self) -> String {
            "stub".to_string()
        }

        pub fn init_params(&mut self, _seed: i32) -> Result<()> {
            Err(rerr(MSG))
        }

        pub fn forward(&self, _tokens: &[i32], _lens: &[i32]) -> Result<Vec<Vec<f32>>> {
            Err(rerr(MSG))
        }

        pub fn train_step(&mut self, _batch: &crate::train::PackedBatch) -> Result<f32> {
            Err(rerr(MSG))
        }
    }
}

#[cfg(not(all(feature = "pjrt", feature = "xla")))]
pub use stub::AgentRuntime;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_meta_load_reports_missing_dir() {
        let err = ModelMeta::load(Path::new("/nonexistent/artifacts")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }

    #[cfg(not(all(feature = "pjrt", feature = "xla")))]
    #[test]
    fn stub_runtime_fails_with_guidance() {
        let err = AgentRuntime::load("artifacts").unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
