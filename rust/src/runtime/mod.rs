//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) and
//! executes them on the CPU PJRT client.
//!
//! This is the only module that touches the `xla` crate. Python never runs
//! at post-training time: `make artifacts` lowered the Layer-2 JAX graphs
//! (which call the Layer-1 Pallas kernels) to HLO text once; here we
//! compile them (`HloModuleProto::from_text_file` → `client.compile`) and
//! thread the flat parameter vector through init → forward → train_step.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::{self, Json};

/// Model metadata written by `python/compile/aot.py`.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub param_count: usize,
    pub vocab: usize,
    pub seq: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub rollout_batch: usize,
    pub train_batch: usize,
    pub use_pallas: bool,
}

impl ModelMeta {
    pub fn load(dir: &Path) -> Result<ModelMeta> {
        let text = std::fs::read_to_string(dir.join("meta.json"))
            .with_context(|| format!("reading {}/meta.json — run `make artifacts`", dir.display()))?;
        let v = json::parse(&text).map_err(|e| anyhow!("meta.json: {e}"))?;
        let get = |k: &str| -> Result<f64> {
            v.get(k).and_then(Json::as_f64).ok_or_else(|| anyhow!("meta.json missing {k}"))
        };
        Ok(ModelMeta {
            param_count: get("param_count")? as usize,
            vocab: get("vocab")? as usize,
            seq: get("seq")? as usize,
            d_model: get("d_model")? as usize,
            n_layers: get("n_layers")? as usize,
            rollout_batch: get("rollout_batch")? as usize,
            train_batch: get("train_batch")? as usize,
            use_pallas: v.get("use_pallas").and_then(Json::as_bool).unwrap_or(true),
        })
    }
}

/// The agent runtime: compiled executables + parameter/optimizer state.
pub struct AgentRuntime {
    client: xla::PjRtClient,
    init: xla::PjRtLoadedExecutable,
    fwd: xla::PjRtLoadedExecutable,
    train: xla::PjRtLoadedExecutable,
    pub meta: ModelMeta,
    pub params: Vec<f32>,
    m_state: Vec<f32>,
    v_state: Vec<f32>,
    step: f32,
}

impl AgentRuntime {
    /// Load and compile all three artifacts from `dir` (e.g. `artifacts/`).
    pub fn load(dir: impl AsRef<Path>) -> Result<AgentRuntime> {
        let dir = dir.as_ref();
        let meta = ModelMeta::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let compile = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path: PathBuf = dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(client.compile(&comp)?)
        };
        let init = compile("agent_init")?;
        let fwd = compile("agent_fwd")?;
        let train = compile("agent_train")?;
        let p = meta.param_count;
        Ok(AgentRuntime {
            client,
            init,
            fwd,
            train,
            meta,
            params: vec![0.0; p],
            m_state: vec![0.0; p],
            v_state: vec![0.0; p],
            step: 0.0,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Initialize parameters from a seed (runs `agent_init.hlo.txt`).
    pub fn init_params(&mut self, seed: i32) -> Result<()> {
        let seed_lit = xla::Literal::vec1(&[seed]);
        let out = self.init.execute::<xla::Literal>(&[seed_lit])?[0][0].to_literal_sync()?;
        let tuple = out.to_tuple1()?;
        self.params = tuple.to_vec::<f32>()?;
        anyhow::ensure!(
            self.params.len() == self.meta.param_count,
            "param count mismatch: {} vs meta {}",
            self.params.len(),
            self.meta.param_count
        );
        self.m_state = vec![0.0; self.params.len()];
        self.v_state = vec![0.0; self.params.len()];
        self.step = 0.0;
        Ok(())
    }

    /// Next-token logits for a batch of token prefixes.
    /// `tokens`: `[rollout_batch][seq]` (padded), `lens`: per-row lengths.
    /// Returns `[rollout_batch][vocab]` logits.
    pub fn forward(&self, tokens: &[i32], lens: &[i32]) -> Result<Vec<Vec<f32>>> {
        let b = self.meta.rollout_batch;
        let t = self.meta.seq;
        anyhow::ensure!(tokens.len() == b * t, "tokens shape");
        anyhow::ensure!(lens.len() == b, "lens shape");
        let params = xla::Literal::vec1(&self.params);
        let tok = xla::Literal::vec1(tokens).reshape(&[b as i64, t as i64])?;
        let lens_l = xla::Literal::vec1(lens);
        let out = self.fwd.execute::<xla::Literal>(&[params, tok, lens_l])?[0][0]
            .to_literal_sync()?;
        let logits = out.to_tuple1()?.to_vec::<f32>()?;
        let v = self.meta.vocab;
        anyhow::ensure!(logits.len() == b * v, "logits shape");
        Ok(logits.chunks(v).map(|c| c.to_vec()).collect())
    }

    /// One GRPO/Adam step (runs `agent_train.hlo.txt`); returns the loss.
    pub fn train_step(&mut self, batch: &crate::train::PackedBatch) -> Result<f32> {
        let bt = self.meta.train_batch;
        let t = self.meta.seq;
        anyhow::ensure!(batch.batch == bt && batch.seq == t, "batch shape mismatch");
        self.step += 1.0;
        let params = xla::Literal::vec1(&self.params);
        let m = xla::Literal::vec1(&self.m_state);
        let v = xla::Literal::vec1(&self.v_state);
        let step = xla::Literal::vec1(&[self.step]);
        let tok = xla::Literal::vec1(&batch.tokens).reshape(&[bt as i64, t as i64])?;
        let mask = xla::Literal::vec1(&batch.mask).reshape(&[bt as i64, t as i64])?;
        let adv = xla::Literal::vec1(&batch.adv);
        let out = self
            .train
            .execute::<xla::Literal>(&[params, m, v, step, tok, mask, adv])?[0][0]
            .to_literal_sync()?;
        let parts = out.to_tuple()?;
        anyhow::ensure!(parts.len() == 4, "train_step returns 4 outputs");
        self.params = parts[0].to_vec::<f32>()?;
        self.m_state = parts[1].to_vec::<f32>()?;
        self.v_state = parts[2].to_vec::<f32>()?;
        let loss = parts[3].to_vec::<f32>()?;
        Ok(loss[0])
    }
}
