//! PJRT-backed `AgentRuntime` (requires the `pjrt` feature + `xla` crate).

use std::path::PathBuf;

use super::{rerr, ModelMeta, Result};

fn xe<T, E: std::fmt::Debug>(r: std::result::Result<T, E>) -> Result<T> {
    r.map_err(|e| rerr(format!("{e:?}")))
}

/// The agent runtime: compiled executables + parameter/optimizer state.
pub struct AgentRuntime {
    client: xla::PjRtClient,
    init: xla::PjRtLoadedExecutable,
    fwd: xla::PjRtLoadedExecutable,
    train: xla::PjRtLoadedExecutable,
    pub meta: ModelMeta,
    pub params: Vec<f32>,
    m_state: Vec<f32>,
    v_state: Vec<f32>,
    step: f32,
}

impl AgentRuntime {
    /// Load and compile all three artifacts from `dir` (e.g. `artifacts/`).
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<AgentRuntime> {
        let dir = dir.as_ref();
        let meta = ModelMeta::load(dir)?;
        let client = xe(xla::PjRtClient::cpu())?;
        let compile = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path: PathBuf = dir.join(format!("{name}.hlo.txt"));
            let proto = xe(xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| rerr("bad path"))?,
            ))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            xe(client.compile(&comp))
        };
        let init = compile("agent_init")?;
        let fwd = compile("agent_fwd")?;
        let train = compile("agent_train")?;
        let p = meta.param_count;
        Ok(AgentRuntime {
            client,
            init,
            fwd,
            train,
            meta,
            params: vec![0.0; p],
            m_state: vec![0.0; p],
            v_state: vec![0.0; p],
            step: 0.0,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Initialize parameters from a seed (runs `agent_init.hlo.txt`).
    pub fn init_params(&mut self, seed: i32) -> Result<()> {
        let seed_lit = xla::Literal::vec1(&[seed]);
        let out = xe(xe(self.init.execute::<xla::Literal>(&[seed_lit]))?[0][0]
            .to_literal_sync())?;
        let tuple = xe(out.to_tuple1())?;
        self.params = xe(tuple.to_vec::<f32>())?;
        if self.params.len() != self.meta.param_count {
            return Err(rerr(format!(
                "param count mismatch: {} vs meta {}",
                self.params.len(),
                self.meta.param_count
            )));
        }
        self.m_state = vec![0.0; self.params.len()];
        self.v_state = vec![0.0; self.params.len()];
        self.step = 0.0;
        Ok(())
    }

    /// Next-token logits for a batch of token prefixes.
    /// `tokens`: `[rollout_batch][seq]` (padded), `lens`: per-row lengths.
    /// Returns `[rollout_batch][vocab]` logits.
    pub fn forward(&self, tokens: &[i32], lens: &[i32]) -> Result<Vec<Vec<f32>>> {
        let b = self.meta.rollout_batch;
        let t = self.meta.seq;
        if tokens.len() != b * t {
            return Err(rerr("tokens shape"));
        }
        if lens.len() != b {
            return Err(rerr("lens shape"));
        }
        let params = xla::Literal::vec1(&self.params);
        let tok = xe(xla::Literal::vec1(tokens).reshape(&[b as i64, t as i64]))?;
        let lens_l = xla::Literal::vec1(lens);
        let out = xe(xe(self.fwd.execute::<xla::Literal>(&[params, tok, lens_l]))?[0][0]
            .to_literal_sync())?;
        let logits = xe(xe(out.to_tuple1())?.to_vec::<f32>())?;
        let v = self.meta.vocab;
        if logits.len() != b * v {
            return Err(rerr("logits shape"));
        }
        Ok(logits.chunks(v).map(|c| c.to_vec()).collect())
    }

    /// One GRPO/Adam step (runs `agent_train.hlo.txt`); returns the loss.
    pub fn train_step(&mut self, batch: &crate::train::PackedBatch) -> Result<f32> {
        let bt = self.meta.train_batch;
        let t = self.meta.seq;
        if batch.batch != bt || batch.seq != t {
            return Err(rerr("batch shape mismatch"));
        }
        self.step += 1.0;
        let params = xla::Literal::vec1(&self.params);
        let m = xla::Literal::vec1(&self.m_state);
        let v = xla::Literal::vec1(&self.v_state);
        let step = xla::Literal::vec1(&[self.step]);
        let tok = xe(xla::Literal::vec1(&batch.tokens).reshape(&[bt as i64, t as i64]))?;
        let mask = xe(xla::Literal::vec1(&batch.mask).reshape(&[bt as i64, t as i64]))?;
        let adv = xla::Literal::vec1(&batch.adv);
        let out = xe(xe(self
            .train
            .execute::<xla::Literal>(&[params, m, v, step, tok, mask, adv]))?[0][0]
            .to_literal_sync())?;
        let parts = xe(out.to_tuple())?;
        if parts.len() != 4 {
            return Err(rerr("train_step returns 4 outputs"));
        }
        self.params = xe(parts[0].to_vec::<f32>())?;
        self.m_state = xe(parts[1].to_vec::<f32>())?;
        self.v_state = xe(parts[2].to_vec::<f32>())?;
        let loss = xe(parts[3].to_vec::<f32>())?;
        Ok(loss[0])
    }
}
