//! SQL sandbox: a from-scratch mini SQL engine over in-memory tables.
//!
//! Substitution for SkyRL-SQL's cloud-hosted SQLite (DESIGN.md §3). The
//! engine supports the read-only query surface the workload exercises:
//!
//! ```sql
//! SELECT col, ... | COUNT(*) | SUM(col) | AVG(col)
//! FROM table [JOIN table2 ON t1.col = t2.col]
//! [WHERE col <op> value [AND ...]]
//! [GROUP BY col] [ORDER BY col [DESC]] [LIMIT n]
//! ```
//!
//! All tools are read-only ⇒ stateless (`will_mutate_state` = false), which
//! is exactly the paper's §4.2 configuration (snapshotting disabled, prefix
//! matching over an effectively flat graph). Latency charges the simulated
//! 55.8 ms network RTT plus a per-row scan cost.

use std::collections::BTreeMap;
use std::fmt;

use super::env::{SandboxFactory, SandboxSnapshot, ToolExecutionEnvironment};
use super::latency::SqlLatency;
use crate::cache::{ToolCall, ToolResult};
use crate::util::rng::{fnv1a, Rng};

/// A database value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Str(String),
    Null,
}

impl Value {
    fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Float(f) => Some(*f),
            _ => None,
        }
    }

    fn cmp_key(&self) -> (u8, f64, &str) {
        match self {
            Value::Null => (0, 0.0, ""),
            Value::Int(i) => (1, *i as f64, ""),
            Value::Float(f) => (1, *f, ""),
            Value::Str(s) => (2, 0.0, s),
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Str(s) => write!(f, "{s}"),
            Value::Null => write!(f, "NULL"),
        }
    }
}

/// A table: column names + rows.
#[derive(Debug, Clone)]
pub struct Table {
    pub name: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<Value>>,
}

impl Table {
    pub fn col_index(&self, name: &str) -> Option<usize> {
        // Accept both `col` and `table.col`.
        let bare = name.rsplit('.').next().unwrap_or(name);
        self.columns.iter().position(|c| c == bare || c == name)
    }
}

/// An in-memory database.
#[derive(Debug, Clone, Default)]
pub struct Database {
    pub tables: BTreeMap<String, Table>,
}

impl Database {
    /// Synthesize a deterministic database for a task seed: a star schema
    /// in the spirit of SkyRL-SQL's data-processing tasks.
    pub fn synthesize(seed: u64) -> Database {
        let mut rng = Rng::new(seed.wrapping_mul(0x9E37_79B9));
        let mut db = Database::default();
        let n_customers = 40 + rng.below(60) as usize;
        let n_orders = 200 + rng.below(400) as usize;
        let regions = ["north", "south", "east", "west"];
        let species = ["pig", "cow", "hen", "goat", "sheep"];

        let customers = Table {
            name: "customers".into(),
            columns: vec!["id".into(), "name".into(), "region".into(), "age".into()],
            rows: (0..n_customers)
                .map(|i| {
                    vec![
                        Value::Int(i as i64),
                        Value::Str(format!("cust_{i}")),
                        Value::Str(regions[rng.below(4) as usize].into()),
                        Value::Int(18 + rng.below(60) as i64),
                    ]
                })
                .collect(),
        };
        let orders = Table {
            name: "orders".into(),
            columns: vec![
                "id".into(),
                "customer_id".into(),
                "amount".into(),
                "status".into(),
            ],
            rows: (0..n_orders)
                .map(|i| {
                    vec![
                        Value::Int(i as i64),
                        Value::Int(rng.below(n_customers as u64) as i64),
                        Value::Float((rng.below(10_000) as f64) / 100.0),
                        Value::Str(
                            ["open", "shipped", "returned"][rng.below(3) as usize].into(),
                        ),
                    ]
                })
                .collect(),
        };
        // The paper's running example table.
        let animals = Table {
            name: "animals".into(),
            columns: vec!["id".into(), "species".into(), "age".into(), "name".into()],
            rows: (0..(30 + rng.below(40)))
                .map(|i| {
                    vec![
                        Value::Int(i as i64),
                        Value::Str(species[rng.below(5) as usize].into()),
                        Value::Int(1 + rng.below(15) as i64),
                        Value::Str(format!("animal_{i}")),
                    ]
                })
                .collect(),
        };
        db.tables.insert("customers".into(), customers);
        db.tables.insert("orders".into(), orders);
        db.tables.insert("animals".into(), animals);
        db
    }

    /// Total rows scanned estimate for latency accounting.
    fn scan_size(&self, table: &str) -> usize {
        self.tables.get(table).map(|t| t.rows.len()).unwrap_or(0)
    }
}

// ---------------------------------------------------------------------------
// Query AST + parser
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, PartialEq)]
enum Selector {
    Columns(Vec<String>),
    CountStar,
    Sum(String),
    Avg(String),
}

#[derive(Debug, Clone, PartialEq)]
enum CmpOp {
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
    Like,
}

#[derive(Debug, Clone)]
struct Condition {
    column: String,
    op: CmpOp,
    value: Value,
}

#[derive(Debug, Clone)]
struct Query {
    select: Selector,
    from: String,
    join: Option<(String, String, String)>, // (table2, left_col, right_col)
    conditions: Vec<Condition>,
    group_by: Option<String>,
    order_by: Option<(String, bool)>, // (col, desc)
    limit: Option<usize>,
}

/// SQL errors surface as tool output (the agent sees them, like a real DB).
#[derive(Debug, Clone, PartialEq)]
pub struct SqlError(pub String);

impl fmt::Display for SqlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "SQL error: {}", self.0)
    }
}

fn tokenize(sql: &str) -> Vec<String> {
    let mut tokens = Vec::new();
    let mut cur = String::new();
    let mut chars = sql.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '\'' | '"' => {
                // String literal (keep quotes to mark type).
                let mut lit = String::from("'");
                for c2 in chars.by_ref() {
                    if c2 == c {
                        break;
                    }
                    lit.push(c2);
                }
                lit.push('\'');
                tokens.push(lit);
            }
            ' ' | '\t' | '\n' | ',' | ';' => {
                if !cur.is_empty() {
                    tokens.push(std::mem::take(&mut cur));
                }
                if c == ',' {
                    tokens.push(",".into());
                }
            }
            '(' | ')' => {
                if !cur.is_empty() {
                    tokens.push(std::mem::take(&mut cur));
                }
                tokens.push(c.to_string());
            }
            '<' | '>' | '=' | '!' => {
                if !cur.is_empty() {
                    tokens.push(std::mem::take(&mut cur));
                }
                let mut op = c.to_string();
                if chars.peek() == Some(&'=') {
                    op.push('=');
                    chars.next();
                }
                tokens.push(op);
            }
            _ => cur.push(c),
        }
    }
    if !cur.is_empty() {
        tokens.push(cur);
    }
    tokens
}

fn parse_query(sql: &str) -> Result<Query, SqlError> {
    let tokens = tokenize(sql);
    let mut pos = 0;
    let kw = |t: &str, want: &str| t.eq_ignore_ascii_case(want);
    let next = |pos: &mut usize| -> Option<String> {
        let t = tokens.get(*pos).cloned();
        if t.is_some() {
            *pos += 1;
        }
        t
    };

    let t = next(&mut pos).ok_or_else(|| SqlError("empty query".into()))?;
    if !kw(&t, "SELECT") {
        return Err(SqlError("only SELECT is supported".into()));
    }

    // Selector
    let select = {
        let first = next(&mut pos).ok_or_else(|| SqlError("missing selector".into()))?;
        if kw(&first, "COUNT") {
            expect(&tokens, &mut pos, "(")?;
            expect(&tokens, &mut pos, "*")?;
            expect(&tokens, &mut pos, ")")?;
            Selector::CountStar
        } else if kw(&first, "SUM") || kw(&first, "AVG") {
            expect(&tokens, &mut pos, "(")?;
            let col = next(&mut pos).ok_or_else(|| SqlError("missing agg column".into()))?;
            expect(&tokens, &mut pos, ")")?;
            if kw(&first, "SUM") {
                Selector::Sum(col)
            } else {
                Selector::Avg(col)
            }
        } else if first == "*" {
            Selector::Columns(vec!["*".into()])
        } else {
            let mut cols = vec![first];
            while tokens.get(pos).map(|t| t == ",").unwrap_or(false) {
                pos += 1;
                cols.push(next(&mut pos).ok_or_else(|| SqlError("bad column list".into()))?);
            }
            Selector::Columns(cols)
        }
    };

    let t = next(&mut pos).ok_or_else(|| SqlError("missing FROM".into()))?;
    if !kw(&t, "FROM") {
        return Err(SqlError(format!("expected FROM, got {t}")));
    }
    let from = next(&mut pos).ok_or_else(|| SqlError("missing table".into()))?;

    let mut query = Query {
        select,
        from,
        join: None,
        conditions: Vec::new(),
        group_by: None,
        order_by: None,
        limit: None,
    };

    while let Some(t) = next(&mut pos) {
        if kw(&t, "JOIN") {
            let table2 = next(&mut pos).ok_or_else(|| SqlError("missing join table".into()))?;
            let on = next(&mut pos).ok_or_else(|| SqlError("missing ON".into()))?;
            if !kw(&on, "ON") {
                return Err(SqlError("expected ON".into()));
            }
            let left = next(&mut pos).ok_or_else(|| SqlError("missing join col".into()))?;
            expect(&tokens, &mut pos, "=")?;
            let right = next(&mut pos).ok_or_else(|| SqlError("missing join col".into()))?;
            query.join = Some((table2, left, right));
        } else if kw(&t, "WHERE") || kw(&t, "AND") {
            let column = next(&mut pos).ok_or_else(|| SqlError("missing condition col".into()))?;
            let op_t = next(&mut pos).ok_or_else(|| SqlError("missing operator".into()))?;
            let op = match op_t.to_ascii_uppercase().as_str() {
                "=" | "==" => CmpOp::Eq,
                "!=" | "<>" => CmpOp::Ne,
                "<" => CmpOp::Lt,
                "<=" => CmpOp::Le,
                ">" => CmpOp::Gt,
                ">=" => CmpOp::Ge,
                "LIKE" => CmpOp::Like,
                o => return Err(SqlError(format!("bad operator {o}"))),
            };
            let raw = next(&mut pos).ok_or_else(|| SqlError("missing value".into()))?;
            let value = parse_value(&raw);
            query.conditions.push(Condition { column, op, value });
        } else if kw(&t, "GROUP") {
            let by = next(&mut pos).ok_or_else(|| SqlError("missing BY".into()))?;
            if !kw(&by, "BY") {
                return Err(SqlError("expected BY".into()));
            }
            query.group_by = Some(next(&mut pos).ok_or_else(|| SqlError("missing group col".into()))?);
        } else if kw(&t, "ORDER") {
            let by = next(&mut pos).ok_or_else(|| SqlError("missing BY".into()))?;
            if !kw(&by, "BY") {
                return Err(SqlError("expected BY".into()));
            }
            let col = next(&mut pos).ok_or_else(|| SqlError("missing order col".into()))?;
            let desc = tokens
                .get(pos)
                .map(|t| kw(t, "DESC"))
                .unwrap_or(false);
            if desc {
                pos += 1;
            } else if tokens.get(pos).map(|t| kw(t, "ASC")).unwrap_or(false) {
                pos += 1;
            }
            query.order_by = Some((col, desc));
        } else if kw(&t, "LIMIT") {
            let n = next(&mut pos).ok_or_else(|| SqlError("missing limit".into()))?;
            query.limit =
                Some(n.parse().map_err(|_| SqlError(format!("bad limit {n}")))?);
        } else {
            return Err(SqlError(format!("unexpected token {t}")));
        }
    }
    Ok(query)
}

fn expect(tokens: &[String], pos: &mut usize, want: &str) -> Result<(), SqlError> {
    match tokens.get(*pos) {
        Some(t) if t == want || t.eq_ignore_ascii_case(want) => {
            *pos += 1;
            Ok(())
        }
        other => Err(SqlError(format!("expected {want}, got {other:?}"))),
    }
}

fn parse_value(raw: &str) -> Value {
    if let Some(s) = raw.strip_prefix('\'') {
        return Value::Str(s.trim_end_matches('\'').to_string());
    }
    if let Ok(i) = raw.parse::<i64>() {
        return Value::Int(i);
    }
    if let Ok(f) = raw.parse::<f64>() {
        return Value::Float(f);
    }
    if raw.eq_ignore_ascii_case("NULL") {
        return Value::Null;
    }
    Value::Str(raw.to_string())
}

// ---------------------------------------------------------------------------
// Executor
// ---------------------------------------------------------------------------

/// Execute a query; returns (formatted dataframe output, rows scanned).
pub fn execute_query(db: &Database, sql: &str) -> Result<(String, usize), SqlError> {
    let q = parse_query(sql)?;
    let base = db
        .tables
        .get(&q.from)
        .ok_or_else(|| SqlError(format!("no such table: {}", q.from)))?;
    let mut scanned = base.rows.len();

    // Materialize the working relation (base or join product).
    let (columns, mut rows): (Vec<String>, Vec<Vec<Value>>) = match &q.join {
        None => (base.columns.clone(), base.rows.clone()),
        Some((t2_name, left, right)) => {
            let t2 = db
                .tables
                .get(t2_name)
                .ok_or_else(|| SqlError(format!("no such table: {t2_name}")))?;
            scanned += t2.rows.len();
            let li = base
                .col_index(left)
                .or_else(|| t2.col_index(left).map(|_| usize::MAX))
                .ok_or_else(|| SqlError(format!("no such column: {left}")))?;
            // Normalize: left col belongs to base, right col to t2.
            let (li, ri) = if li != usize::MAX {
                (
                    li,
                    t2.col_index(right)
                        .ok_or_else(|| SqlError(format!("no such column: {right}")))?,
                )
            } else {
                (
                    base.col_index(right)
                        .ok_or_else(|| SqlError(format!("no such column: {right}")))?,
                    t2.col_index(left)
                        .ok_or_else(|| SqlError(format!("no such column: {left}")))?,
                )
            };
            let mut cols = base.columns.clone();
            cols.extend(t2.columns.iter().map(|c| format!("{t2_name}.{c}")));
            let mut out = Vec::new();
            for r1 in &base.rows {
                for r2 in &t2.rows {
                    if r1[li] == r2[ri] {
                        let mut row = r1.clone();
                        row.extend(r2.iter().cloned());
                        out.push(row);
                    }
                }
            }
            (cols, out)
        }
    };

    let col_index = |name: &str| -> Result<usize, SqlError> {
        let bare = name.rsplit('.').next().unwrap_or(name);
        columns
            .iter()
            .position(|c| c == name || c == bare || c.rsplit('.').next() == Some(bare))
            .ok_or_else(|| SqlError(format!("no such column: {name}")))
    };

    // WHERE
    for cond in &q.conditions {
        let ci = col_index(&cond.column)?;
        rows.retain(|r| matches_cond(&r[ci], &cond.op, &cond.value));
    }

    // GROUP BY (only meaningful with aggregates or a single group column).
    if let Some(gcol) = &q.group_by {
        let gi = col_index(gcol)?;
        let mut groups: BTreeMap<String, Vec<Vec<Value>>> = BTreeMap::new();
        for r in rows {
            groups.entry(r[gi].to_string()).or_default().push(r);
        }
        let mut out_rows = Vec::new();
        for (key, members) in groups {
            let agg = aggregate(&q.select, &members, &col_index)?;
            out_rows.push(vec![Value::Str(key), agg]);
        }
        let header = vec![gcol.clone(), selector_name(&q.select)];
        return Ok((format_table(&header, &out_rows, q.limit), scanned));
    }

    // Aggregates without grouping.
    match &q.select {
        Selector::CountStar | Selector::Sum(_) | Selector::Avg(_) => {
            let agg = aggregate(&q.select, &rows, &col_index)?;
            let header = vec![selector_name(&q.select)];
            return Ok((format_table(&header, &[vec![agg]], None), scanned));
        }
        Selector::Columns(_) => {}
    }

    // ORDER BY
    if let Some((ocol, desc)) = &q.order_by {
        let oi = col_index(ocol)?;
        rows.sort_by(|a, b| {
            let ka = a[oi].cmp_key();
            let kb = b[oi].cmp_key();
            ka.partial_cmp(&kb).unwrap_or(std::cmp::Ordering::Equal)
        });
        if *desc {
            rows.reverse();
        }
    }

    // Projection
    let Selector::Columns(cols) = &q.select else { unreachable!() };
    let (header, projected): (Vec<String>, Vec<Vec<Value>>) = if cols == &["*".to_string()] {
        (columns.clone(), rows)
    } else {
        let idxs: Vec<usize> =
            cols.iter().map(|c| col_index(c)).collect::<Result<_, _>>()?;
        (
            cols.clone(),
            rows.into_iter()
                .map(|r| idxs.iter().map(|&i| r[i].clone()).collect())
                .collect(),
        )
    };
    Ok((format_table(&header, &projected, q.limit), scanned))
}

fn matches_cond(v: &Value, op: &CmpOp, target: &Value) -> bool {
    match op {
        CmpOp::Eq => values_eq(v, target),
        CmpOp::Ne => !values_eq(v, target),
        CmpOp::Like => match (v, target) {
            (Value::Str(s), Value::Str(pat)) => {
                let pat = pat.trim_matches('%');
                s.contains(pat)
            }
            _ => false,
        },
        _ => {
            let (a, b) = match (v.as_f64(), target.as_f64()) {
                (Some(a), Some(b)) => (a, b),
                _ => return false,
            };
            match op {
                CmpOp::Lt => a < b,
                CmpOp::Le => a <= b,
                CmpOp::Gt => a > b,
                CmpOp::Ge => a >= b,
                _ => unreachable!(),
            }
        }
    }
}

fn values_eq(a: &Value, b: &Value) -> bool {
    match (a.as_f64(), b.as_f64()) {
        (Some(x), Some(y)) => x == y,
        _ => a == b,
    }
}

fn aggregate(
    sel: &Selector,
    rows: &[Vec<Value>],
    col_index: &dyn Fn(&str) -> Result<usize, SqlError>,
) -> Result<Value, SqlError> {
    match sel {
        Selector::CountStar | Selector::Columns(_) => Ok(Value::Int(rows.len() as i64)),
        Selector::Sum(c) => {
            let i = col_index(c)?;
            Ok(Value::Float(rows.iter().filter_map(|r| r[i].as_f64()).sum()))
        }
        Selector::Avg(c) => {
            let i = col_index(c)?;
            let vals: Vec<f64> = rows.iter().filter_map(|r| r[i].as_f64()).collect();
            if vals.is_empty() {
                Ok(Value::Null)
            } else {
                Ok(Value::Float(vals.iter().sum::<f64>() / vals.len() as f64))
            }
        }
    }
}

fn selector_name(sel: &Selector) -> String {
    match sel {
        Selector::CountStar => "COUNT(*)".into(),
        Selector::Sum(c) => format!("SUM({c})"),
        Selector::Avg(c) => format!("AVG({c})"),
        Selector::Columns(_) => "rows".into(),
    }
}

/// Render rows as the dataframe-style text the agent observes (truncated at
/// 50 rows like the SkyRL-SQL prompt specifies).
fn format_table(header: &[String], rows: &[Vec<Value>], limit: Option<usize>) -> String {
    let cap = limit.unwrap_or(usize::MAX).min(50);
    let mut out = String::new();
    out.push_str(&header.join(" | "));
    out.push('\n');
    for (i, r) in rows.iter().enumerate() {
        if i >= cap {
            out.push_str(&format!("... ({} more rows truncated)\n", rows.len() - cap));
            break;
        }
        let cells: Vec<String> = r.iter().map(|v| v.to_string()).collect();
        out.push_str(&cells.join(" | "));
        out.push('\n');
    }
    out
}

// ---------------------------------------------------------------------------
// Sandbox wrapper
// ---------------------------------------------------------------------------

/// SQL sandbox: a database instance + the simulated network.
pub struct SqlSandbox {
    seed: u64,
    db: Database,
    latency: SqlLatency,
    running: bool,
}

impl SqlSandbox {
    pub fn new(seed: u64) -> SqlSandbox {
        SqlSandbox {
            seed,
            db: Database::synthesize(seed),
            latency: SqlLatency::default(),
            running: false,
        }
    }

    pub fn database(&self) -> &Database {
        &self.db
    }
}

impl ToolExecutionEnvironment for SqlSandbox {
    fn start(&mut self) -> f64 {
        self.running = true;
        0.05 // connection setup
    }

    fn stop(&mut self) -> f64 {
        self.running = false;
        0.01
    }

    fn execute(&mut self, call: &ToolCall) -> ToolResult {
        let (output, scanned) = match execute_query(&self.db, &call.args) {
            Ok((o, s)) => (o, s),
            Err(e) => (e.to_string(), self.db.scan_size("customers")),
        };
        let exec_time = self.latency.query(self.seed, &call.args, scanned);
        ToolResult { output, exec_time, api_tokens: 0 }
    }

    fn fork(&self) -> Box<dyn ToolExecutionEnvironment> {
        Box::new(SqlSandbox {
            seed: self.seed,
            db: self.db.clone(),
            latency: self.latency,
            running: true,
        })
    }

    fn snapshot(&self) -> SandboxSnapshot {
        // Read-only workload: a snapshot is just the seed (the DB is
        // reconstructible); costs are negligible, and the workload disables
        // snapshotting anyway (§4.2).
        SandboxSnapshot {
            bytes: self.seed.to_le_bytes().to_vec(),
            serialize_cost: 0.001,
            restore_cost: 0.001,
        }
    }

    fn will_mutate_state(&self, _call: &ToolCall) -> bool {
        false // the workload is all SELECTs (§4.2)
    }

    fn state_fingerprint(&self) -> u64 {
        // DB is immutable: fingerprint is the seed.
        fnv1a(&self.seed.to_le_bytes())
    }
}

/// Factory for SQL sandboxes.
pub struct SqlFactory;

impl SandboxFactory for SqlFactory {
    fn create(&self, task_seed: u64) -> Box<dyn ToolExecutionEnvironment> {
        let mut sb = SqlSandbox::new(task_seed);
        sb.start();
        Box::new(sb)
    }

    fn restore(&self, snap: &SandboxSnapshot) -> Box<dyn ToolExecutionEnvironment> {
        let mut bytes = [0u8; 8];
        bytes.copy_from_slice(&snap.bytes[..8]);
        let mut sb = SqlSandbox::new(u64::from_le_bytes(bytes));
        sb.start();
        Box::new(sb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn db() -> Database {
        let mut db = Database::default();
        db.tables.insert(
            "animals".into(),
            Table {
                name: "animals".into(),
                columns: vec!["id".into(), "species".into(), "age".into()],
                rows: vec![
                    vec![Value::Int(0), Value::Str("pig".into()), Value::Int(3)],
                    vec![Value::Int(1), Value::Str("pig".into()), Value::Int(5)],
                    vec![Value::Int(2), Value::Str("cow".into()), Value::Int(7)],
                    vec![Value::Int(3), Value::Str("hen".into()), Value::Int(1)],
                ],
            },
        );
        db.tables.insert(
            "farms".into(),
            Table {
                name: "farms".into(),
                columns: vec!["animal_id".into(), "farm".into()],
                rows: vec![
                    vec![Value::Int(0), Value::Str("green".into())],
                    vec![Value::Int(1), Value::Str("blue".into())],
                    vec![Value::Int(2), Value::Str("green".into())],
                ],
            },
        );
        db
    }

    fn run(sql: &str) -> String {
        execute_query(&db(), sql).unwrap().0
    }

    #[test]
    fn count_star_with_where() {
        // The paper's worked example: how many pigs are in the farm?
        let out = run("SELECT COUNT(*) FROM animals WHERE species = 'pig'");
        assert!(out.contains("COUNT(*)"));
        assert!(out.lines().nth(1).unwrap().contains('2'), "{out}");
    }

    #[test]
    fn select_star() {
        let out = run("SELECT * FROM animals");
        assert_eq!(out.lines().count(), 5); // header + 4 rows
    }

    #[test]
    fn projection_and_order() {
        let out = run("SELECT species FROM animals ORDER BY age DESC");
        let rows: Vec<&str> = out.lines().skip(1).collect();
        assert_eq!(rows, vec!["cow", "pig", "pig", "hen"]);
    }

    #[test]
    fn numeric_comparisons() {
        assert!(run("SELECT COUNT(*) FROM animals WHERE age > 3").contains('2'));
        assert!(run("SELECT COUNT(*) FROM animals WHERE age >= 3").contains('3'));
        assert!(run("SELECT COUNT(*) FROM animals WHERE age != 3").contains('3'));
    }

    #[test]
    fn and_conditions() {
        let out = run("SELECT COUNT(*) FROM animals WHERE species = 'pig' AND age > 4");
        assert!(out.lines().nth(1).unwrap().contains('1'), "{out}");
    }

    #[test]
    fn sum_and_avg() {
        let out = run("SELECT SUM(age) FROM animals");
        assert!(out.contains("16"), "{out}");
        let out = run("SELECT AVG(age) FROM animals WHERE species = 'pig'");
        assert!(out.contains('4'), "{out}");
    }

    #[test]
    fn group_by_counts() {
        let out = run("SELECT COUNT(*) FROM animals GROUP BY species");
        // cow 1, hen 1, pig 2 — BTreeMap order is alphabetical.
        let rows: Vec<&str> = out.lines().skip(1).collect();
        assert_eq!(rows.len(), 3);
        assert!(rows[2].starts_with("pig") && rows[2].contains('2'), "{out}");
    }

    #[test]
    fn join_on_foreign_key() {
        // animals 0 (pig) and 2 (cow) are on the green farm.
        let out = run(
            "SELECT species FROM animals JOIN farms ON id = animal_id WHERE farm = 'green'",
        );
        assert!(out.contains("pig") && out.contains("cow"), "{out}");
        let count =
            run("SELECT COUNT(*) FROM animals JOIN farms ON id = animal_id WHERE farm = 'green'");
        assert!(count.lines().nth(1).unwrap().contains('2'), "{count}");
    }

    #[test]
    fn limit_truncates() {
        let out = run("SELECT * FROM animals LIMIT 2");
        assert!(out.contains("2 more rows truncated"), "{out}");
    }

    #[test]
    fn like_operator() {
        let out = run("SELECT COUNT(*) FROM animals WHERE species LIKE '%ig%'");
        assert!(out.lines().nth(1).unwrap().contains('2'), "{out}");
    }

    #[test]
    fn errors_are_reported_not_panicked() {
        let e = execute_query(&db(), "SELECT * FROM nope").unwrap_err();
        assert!(e.0.contains("no such table"));
        let e = execute_query(&db(), "DROP TABLE animals").unwrap_err();
        assert!(e.0.contains("only SELECT"));
        let e = execute_query(&db(), "SELECT zzz FROM animals").unwrap_err();
        assert!(e.0.contains("no such column"));
    }

    #[test]
    fn sandbox_is_stateless_and_deterministic() {
        let mut a = SqlSandbox::new(7);
        let mut b = SqlSandbox::new(7);
        a.start();
        b.start();
        let call = ToolCall::stateless("sql", "SELECT COUNT(*) FROM customers");
        let ra = a.execute(&call);
        let rb = b.execute(&call);
        assert_eq!(ra.output, rb.output);
        assert_eq!(ra.exec_time, rb.exec_time);
        assert!(!a.will_mutate_state(&call));
        assert_eq!(a.state_fingerprint(), b.state_fingerprint());
        // Executing queries doesn't change the fingerprint.
        let fp = a.state_fingerprint();
        a.execute(&ToolCall::stateless("sql", "SELECT * FROM orders"));
        assert_eq!(a.state_fingerprint(), fp);
    }

    #[test]
    fn synthesized_dbs_differ_by_seed() {
        let a = Database::synthesize(1);
        let b = Database::synthesize(2);
        assert_ne!(
            a.tables["orders"].rows.len(),
            b.tables["orders"].rows.len()
        );
    }

    #[test]
    fn latency_is_msec_scale() {
        let mut sb = SqlSandbox::new(3);
        sb.start();
        let r = sb.execute(&ToolCall::stateless("sql", "SELECT COUNT(*) FROM orders"));
        assert!(r.exec_time > 0.03 && r.exec_time < 0.3, "{}", r.exec_time);
    }
}
