//! Container-manager simulator (Appendix E): reproduces the Docker-compose
//! scaling pathologies of Figure 13 and the fixes TVCACHE applies.
//!
//! The model captures the three documented bottlenecks:
//!
//! 1. **Per-sandbox bridge-network creation** — Docker Compose creates a
//!    dedicated network per sandbox (expensive, serialized in dockerd).
//!    Fix: pre-create a pool and reuse (`Precreate networks`).
//! 2. **Unnecessary networks** — most tasks need none; a compose-file check
//!    (services > 1 or exposed ports) skips allocation (`Selective`).
//! 3. **Kernel-level contention** — past a concurrency saturation point,
//!    cgroup syscalls time out and creations fail. Fix: cap in-flight
//!    creations at the observed saturation (`Rate-limited` = tvcache).

use crate::util::rng::Rng;

/// The four configurations of Figure 13.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ManagerConfig {
    /// Default terminal-bench harness.
    Baseline,
    /// + pre-created bridge-network pool.
    PrecreateNetworks,
    /// + allocate networks only for compose files that need them.
    SelectiveNetworks,
    /// + rate-limited fork pipeline (the full TVCACHE configuration).
    RateLimited,
}

/// Cost/contention parameters (calibrated to Figure 13's shape).
#[derive(Debug, Clone, Copy)]
pub struct ContainerParams {
    /// Base container create cost (seconds, cgroups + rootfs).
    pub create_base: f64,
    /// Bridge-network creation cost (seconds, serialized in the daemon).
    pub network_create: f64,
    /// Fraction of tasks whose compose file actually needs a network.
    pub network_needed_frac: f64,
    /// Concurrency at which kernel contention starts.
    pub saturation: usize,
    /// Per-extra-inflight penalty factor past saturation (quadratic).
    pub contention_penalty: f64,
    /// In-flight creations past which requests *fail* (timeouts).
    pub failure_threshold: usize,
}

impl Default for ContainerParams {
    fn default() -> Self {
        ContainerParams {
            create_base: 0.35,
            network_create: 0.9,
            network_needed_frac: 0.25,
            saturation: 24,
            contention_penalty: 0.004,
            failure_threshold: 96,
        }
    }
}

/// Result of a batch of concurrent fork requests.
#[derive(Debug, Clone)]
pub struct ForkBatchResult {
    pub requested: usize,
    pub succeeded: usize,
    pub failed: usize,
    /// Total wall-clock seconds the batch took.
    pub elapsed: f64,
    /// Successful creations per second.
    pub rate: f64,
}

/// The simulated container manager.
pub struct ContainerManager {
    pub config: ManagerConfig,
    pub params: ContainerParams,
    network_pool: usize,
    rng: Rng,
}

impl ContainerManager {
    pub fn new(config: ManagerConfig, params: ContainerParams, seed: u64) -> Self {
        ContainerManager {
            config,
            params,
            // The pool is sized generously at startup in the fixed configs.
            network_pool: 256,
            rng: Rng::new(seed),
        }
    }

    /// Effective per-container network cost under this config.
    fn network_cost(&mut self) -> f64 {
        match self.config {
            ManagerConfig::Baseline => self.params.network_create,
            ManagerConfig::PrecreateNetworks => {
                // Reuse from the pool: cheap attach, occasional refill.
                if self.network_pool > 0 {
                    self.network_pool -= 1;
                    0.02
                } else {
                    self.params.network_create
                }
            }
            ManagerConfig::SelectiveNetworks | ManagerConfig::RateLimited => {
                // Only a fraction of tasks needs a network at all; those
                // attach from the pool.
                if self.rng.f64() < self.params.network_needed_frac {
                    0.02
                } else {
                    0.0
                }
            }
        }
    }

    /// Simulate `n` concurrent fork (container-create) requests and return
    /// the achieved throughput — one point of Figure 13.
    pub fn fork_batch(&mut self, n: usize) -> ForkBatchResult {
        // Rate-limiting caps effective concurrency at the saturation point.
        let effective_inflight = match self.config {
            ManagerConfig::RateLimited => n.min(self.params.saturation),
            _ => n,
        };

        let mut succeeded = 0usize;
        let mut failed = 0usize;
        let mut total_work = 0.0; // aggregate seconds of daemon work

        for _ in 0..n {
            // Failures: kernel timeouts once in-flight far exceeds saturation
            // (never in the rate-limited config).
            let overload = effective_inflight as f64 / self.params.failure_threshold as f64;
            let fail_p = if matches!(self.config, ManagerConfig::RateLimited) {
                0.0
            } else {
                ((overload - 1.0).max(0.0) * 0.6).min(0.9)
            };
            if self.rng.f64() < fail_p {
                failed += 1;
                // Failed creations still burn daemon time (timeout).
                total_work += self.params.create_base * 2.0;
                continue;
            }
            let mut cost = self.params.create_base + self.network_cost();
            // Contention: quadratic penalty past the saturation knee.
            let excess = effective_inflight.saturating_sub(self.params.saturation);
            cost += self.params.contention_penalty * (excess * excess) as f64
                / self.params.saturation as f64;
            total_work += cost;
            succeeded += 1;
        }

        // Parallelism: the daemon overlaps work up to the effective
        // concurrency, but network creation serializes in the baseline.
        let parallelism = match self.config {
            ManagerConfig::Baseline => (effective_inflight as f64).min(4.0),
            ManagerConfig::PrecreateNetworks => (effective_inflight as f64).min(12.0),
            ManagerConfig::SelectiveNetworks => (effective_inflight as f64).min(16.0),
            ManagerConfig::RateLimited => (effective_inflight as f64).min(16.0),
        };
        let elapsed = total_work / parallelism.max(1.0);
        ForkBatchResult {
            requested: n,
            succeeded,
            failed,
            elapsed,
            rate: if elapsed > 0.0 { succeeded as f64 / elapsed } else { 0.0 },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rate(config: ManagerConfig, n: usize) -> f64 {
        let mut m = ContainerManager::new(config, ContainerParams::default(), 42);
        m.fork_batch(n).rate
    }

    #[test]
    fn figure13_config_ordering_at_scale() {
        // At high fork counts the paper's ordering must hold:
        // baseline < precreate < selective ≤ tvcache(rate-limited)
        let n = 256;
        let base = rate(ManagerConfig::Baseline, n);
        let pre = rate(ManagerConfig::PrecreateNetworks, n);
        let sel = rate(ManagerConfig::SelectiveNetworks, n);
        let tv = rate(ManagerConfig::RateLimited, n);
        assert!(base < pre, "base {base} pre {pre}");
        assert!(pre < sel, "pre {pre} sel {sel}");
        assert!(sel < tv * 1.05, "sel {sel} tv {tv}"); // tvcache at least matches
    }

    #[test]
    fn baseline_degrades_with_scale() {
        let small = rate(ManagerConfig::Baseline, 16);
        let large = rate(ManagerConfig::Baseline, 512);
        assert!(large < small, "baseline should degrade: {small} -> {large}");
    }

    #[test]
    fn rate_limited_sustains_throughput() {
        let small = rate(ManagerConfig::RateLimited, 32);
        let large = rate(ManagerConfig::RateLimited, 640);
        assert!(
            large > small * 0.7,
            "rate-limited should sustain: {small} -> {large}"
        );
    }

    #[test]
    fn unlimited_configs_fail_past_threshold() {
        let mut m = ContainerManager::new(
            ManagerConfig::SelectiveNetworks,
            ContainerParams::default(),
            7,
        );
        let r = m.fork_batch(400);
        assert!(r.failed > 0, "expected failures at 400 concurrent forks");
        let mut m2 =
            ContainerManager::new(ManagerConfig::RateLimited, ContainerParams::default(), 7);
        let r2 = m2.fork_batch(400);
        assert_eq!(r2.failed, 0, "rate-limited config must not fail");
    }

    #[test]
    fn all_requests_accounted() {
        for cfg in [
            ManagerConfig::Baseline,
            ManagerConfig::PrecreateNetworks,
            ManagerConfig::SelectiveNetworks,
            ManagerConfig::RateLimited,
        ] {
            let mut m = ContainerManager::new(cfg, ContainerParams::default(), 3);
            let r = m.fork_batch(200);
            assert_eq!(r.succeeded + r.failed, 200);
        }
    }
}
