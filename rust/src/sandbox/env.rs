//! The `ToolExecutionEnvironment` abstraction (§3.4 "Sandbox lifecycle").
//!
//! Each workload implements this trait by defining `start`, `stop`, `fork`,
//! and `execute`, exactly as the paper's client library specifies, plus
//! `snapshot`/`restore` (Docker-commit analogue) and `will_mutate_state`
//! (Appendix B annotation hook).
//!
//! Execution is *simulated-latency, real-state*: `execute` really mutates an
//! in-memory model of the sandbox (filesystem, database, media store) and
//! returns the output a real tool would produce, while the reported
//! `exec_time` is drawn from a paper-calibrated latency model. Under a
//! virtual clock the experiment charges that latency to simulated time; the
//! state machine itself — what the correctness guarantee is about — is real.

use crate::cache::{ToolCall, ToolResult};

/// Serialized sandbox state (Docker `commit` analogue).
#[derive(Debug, Clone)]
pub struct SandboxSnapshot {
    /// Opaque serialized state.
    pub bytes: Vec<u8>,
    /// Seconds the serialization took (charged to the critical path, §3.3).
    pub serialize_cost: f64,
    /// Seconds restoring this snapshot takes (charged at fork time).
    pub restore_cost: f64,
}

impl SandboxSnapshot {
    pub fn size(&self) -> u64 {
        self.bytes.len() as u64
    }
}

/// The sandbox interface every workload implements.
pub trait ToolExecutionEnvironment: Send {
    /// Bring the sandbox up. Returns start-up latency in seconds (container
    /// creation — the overhead proactive forking hides, Appendix F).
    fn start(&mut self) -> f64;

    /// Tear the sandbox down. Returns the stop latency in seconds.
    fn stop(&mut self) -> f64;

    /// Execute one tool call, mutating sandbox state; the returned
    /// [`ToolResult::exec_time`] is the simulated execution latency.
    fn execute(&mut self, call: &ToolCall) -> ToolResult;

    /// Deep-copy this sandbox (Docker fork: commit + run). The returned
    /// environment is already started.
    fn fork(&self) -> Box<dyn ToolExecutionEnvironment>;

    /// Serialize current state.
    fn snapshot(&self) -> SandboxSnapshot;

    /// `will_mutate_state()` (Appendix B): whether this call can modify the
    /// sandbox. Conservative default: everything mutates.
    fn will_mutate_state(&self, _call: &ToolCall) -> bool {
        true
    }

    /// A fingerprint of the full mutable state — used by the correctness
    /// property tests (identical trajectories ⇒ identical fingerprints).
    fn state_fingerprint(&self) -> u64;
}

/// Factory for creating fresh sandboxes and restoring snapshots; one per
/// workload (terminal / sql / video). Object-safe so the executor can hold
/// `Box<dyn SandboxFactory>`.
pub trait SandboxFactory: Send + Sync {
    /// A clean root sandbox for `task_seed` (already started).
    fn create(&self, task_seed: u64) -> Box<dyn ToolExecutionEnvironment>;

    /// Rehydrate a snapshot into a running sandbox.
    fn restore(&self, snap: &SandboxSnapshot) -> Box<dyn ToolExecutionEnvironment>;
}
