//! Sandbox substrates: the `ToolExecutionEnvironment` abstraction plus the
//! three workload sandboxes (terminal / SQL / video) and the container
//! manager simulator. See DESIGN.md §3 for the paper→simulation mapping.

pub mod container;
pub mod env;
pub mod latency;
pub mod sql;
pub mod terminal;
pub mod video;

pub use container::{ContainerManager, ContainerParams, ForkBatchResult, ManagerConfig};
pub use env::{SandboxFactory, SandboxSnapshot, ToolExecutionEnvironment};
pub use sql::{SqlFactory, SqlSandbox};
pub use terminal::{TerminalFactory, TerminalSandbox, TerminalTask};
pub use video::{VideoFactory, VideoSandbox};
