//! Terminal sandbox: an in-memory filesystem + shell-command interpreter.
//!
//! Substitution for terminal-bench's Docker containers (DESIGN.md §3): a
//! stateful machine whose tool calls are shell commands. The interpreter
//! covers the command families the paper's agents actually issue — file
//! reads/writes, patching, package installs, builds, test runs — with
//! realistic state-dependence: `cat foo.py` after `patch foo.py` returns
//! the patched content (the paper's §1 staleness example), `make test`
//! passes iff the task's bug has been fixed, builds fail before `pip
//! install` of a required package, and so on.

use std::collections::{BTreeMap, BTreeSet};

use super::env::{SandboxFactory, SandboxSnapshot, ToolExecutionEnvironment};
use super::latency::{ContainerCosts, TerminalLatency};
use crate::cache::{ToolCall, ToolResult};
use crate::util::json::{self, Json};
use crate::util::rng::fnv1a;

/// Task definition: initial files, the bug, and what fixes it.
#[derive(Debug, Clone)]
pub struct TerminalTask {
    pub seed: u64,
    /// Initial filesystem contents.
    pub files: BTreeMap<String, String>,
    /// File containing the bug.
    pub buggy_file: String,
    /// Broken line that must be replaced…
    pub bug_pattern: String,
    /// …with this fix for `make test` to pass.
    pub fix_pattern: String,
    /// Package that must be installed before `make` succeeds.
    pub required_package: Option<String>,
    /// Latency scale (easy = 1.0, medium ≈ 2.2).
    pub latency_scale: f64,
}

impl TerminalTask {
    /// Generate a synthetic debugging task from a seed (workloads module
    /// builds the per-difficulty distributions on top of this).
    pub fn generate(seed: u64, medium: bool) -> TerminalTask {
        let mut files = BTreeMap::new();
        let buggy_file = format!("src/module_{}.py", seed % 7);
        files.insert(
            "README.md".to_string(),
            format!("# task-{seed}\nFix the failing test suite."),
        );
        files.insert(
            "Makefile".to_string(),
            "all: build\ntest: build\n\trun_tests".to_string(),
        );
        let bug_pattern = format!("return x - {}", seed % 9 + 1);
        let fix_pattern = format!("return x + {}", seed % 9 + 1);
        files.insert(
            buggy_file.clone(),
            format!("def compute(x):\n    {bug_pattern}\n"),
        );
        files.insert(
            "tests/test_module.py".to_string(),
            format!("from module import compute\nassert compute(1) == 1 + {}\n", seed % 9 + 1),
        );
        let required_package =
            if medium || seed % 3 == 0 { Some(format!("libdep{}", seed % 5)) } else { None };
        TerminalTask {
            seed,
            files,
            buggy_file,
            bug_pattern,
            fix_pattern,
            required_package,
            latency_scale: if medium { 2.2 } else { 1.0 },
        }
    }
}

/// The mutable sandbox state (what snapshots serialize).
#[derive(Debug, Clone, PartialEq)]
struct State {
    files: BTreeMap<String, String>,
    env_vars: BTreeMap<String, String>,
    cwd: String,
    packages: BTreeSet<String>,
    built: bool,
    running: bool,
}

/// A terminal sandbox for one task.
pub struct TerminalSandbox {
    task: TerminalTask,
    state: State,
    latency: TerminalLatency,
    costs: ContainerCosts,
}

impl TerminalSandbox {
    pub fn new(task: TerminalTask) -> TerminalSandbox {
        let state = State {
            files: task.files.clone(),
            env_vars: BTreeMap::new(),
            cwd: "/app".to_string(),
            packages: BTreeSet::new(),
            built: false,
            running: false,
        };
        let latency = TerminalLatency { scale: task.latency_scale };
        TerminalSandbox { task, state, latency, costs: ContainerCosts::default() }
    }

    fn resolve(&self, path: &str) -> String {
        if path.starts_with('/') {
            path.trim_start_matches('/').to_string()
        } else {
            path.to_string()
        }
    }

    /// Whether the bug has been fixed (drives `make test` and the reward).
    pub fn tests_pass(&self) -> bool {
        self.state
            .files
            .get(&self.task.buggy_file)
            .map(|c| c.contains(&self.task.fix_pattern))
            .unwrap_or(false)
    }

    pub fn is_built(&self) -> bool {
        self.state.built
    }

    /// Interpret one shell command; returns (output, state_mutated).
    fn interpret(&mut self, cmd: &str) -> (String, bool) {
        let cmd = cmd.trim();
        let (head, rest) = cmd.split_once(' ').unwrap_or((cmd, ""));
        match head {
            "ls" => {
                let mut names: Vec<&str> =
                    self.state.files.keys().map(|s| s.as_str()).collect();
                names.sort();
                (names.join("\n"), false)
            }
            "cat" => {
                let path = self.resolve(rest.trim());
                match self.state.files.get(&path) {
                    Some(c) => (c.clone(), false),
                    None => (format!("cat: {path}: No such file or directory"), false),
                }
            }
            "grep" => {
                let mut parts = rest.split_whitespace();
                let pat = parts.next().unwrap_or("").trim_matches('"');
                let path = self.resolve(parts.next().unwrap_or(""));
                match self.state.files.get(&path) {
                    Some(c) => (
                        c.lines().filter(|l| l.contains(pat)).collect::<Vec<_>>().join("\n"),
                        false,
                    ),
                    None => (format!("grep: {path}: No such file"), false),
                }
            }
            "echo" => {
                // echo text > file | echo text >> file | echo text
                if let Some((text, path)) = rest.split_once(">>") {
                    let path = self.resolve(path.trim());
                    let text = text.trim().trim_matches('"').to_string();
                    self.state
                        .files
                        .entry(path)
                        .and_modify(|c| {
                            c.push('\n');
                            c.push_str(&text);
                        })
                        .or_insert(text);
                    (String::new(), true)
                } else if let Some((text, path)) = rest.split_once('>') {
                    let path = self.resolve(path.trim());
                    self.state
                        .files
                        .insert(path, text.trim().trim_matches('"').to_string());
                    (String::new(), true)
                } else {
                    (rest.trim_matches('"').to_string(), false)
                }
            }
            "rm" => {
                let path = self.resolve(rest.trim().trim_start_matches("-f "));
                let existed = self.state.files.remove(&path).is_some();
                (
                    if existed { String::new() } else { format!("rm: {path}: No such file") },
                    existed,
                )
            }
            "cp" => {
                let mut parts = rest.split_whitespace();
                let from = self.resolve(parts.next().unwrap_or(""));
                let to = self.resolve(parts.next().unwrap_or(""));
                match self.state.files.get(&from).cloned() {
                    Some(c) => {
                        self.state.files.insert(to, c);
                        (String::new(), true)
                    }
                    None => (format!("cp: {from}: No such file"), false),
                }
            }
            "cd" => {
                self.state.cwd = rest.trim().to_string();
                (String::new(), true)
            }
            "export" => {
                if let Some((k, v)) = rest.split_once('=') {
                    self.state.env_vars.insert(k.trim().to_string(), v.trim().to_string());
                    (String::new(), true)
                } else {
                    ("export: bad assignment".to_string(), false)
                }
            }
            "pwd" => (self.state.cwd.clone(), false),
            "env" => (
                self.state
                    .env_vars
                    .iter()
                    .map(|(k, v)| format!("{k}={v}"))
                    .collect::<Vec<_>>()
                    .join("\n"),
                false,
            ),
            // `patch <file> s/<old>/<new>/` — the agent's repair primitive.
            "patch" => {
                let mut parts = rest.splitn(2, ' ');
                let path = self.resolve(parts.next().unwrap_or(""));
                let spec = parts.next().unwrap_or("");
                let Some(body) = spec.strip_prefix("s/") else {
                    return ("patch: bad substitution spec".to_string(), false);
                };
                let mut halves = body.splitn(2, '/');
                let old = halves.next().unwrap_or("");
                let new = halves.next().unwrap_or("").trim_end_matches('/');
                match self.state.files.get_mut(&path) {
                    Some(content) if content.contains(old) => {
                        *content = content.replace(old, new);
                        self.state.built = false; // source changed
                        (format!("patched {path}"), true)
                    }
                    Some(_) => (format!("patch: pattern not found in {path}"), false),
                    None => (format!("patch: {path}: No such file"), false),
                }
            }
            "pip" | "apt-get" => {
                // pip install <pkg>
                let pkg = rest.trim_start_matches("install").trim().to_string();
                if pkg.is_empty() {
                    ("usage: install <package>".to_string(), false)
                } else {
                    let new = self.state.packages.insert(pkg.clone());
                    (format!("Successfully installed {pkg}"), new)
                }
            }
            "make" => {
                let target = rest.trim();
                if target == "test" {
                    if !self.state.built {
                        return ("make: *** build first (run `make`)".to_string(), false);
                    }
                    if self.tests_pass() {
                        ("ran 12 tests: 12 passed".to_string(), false)
                    } else {
                        (
                            format!(
                                "ran 12 tests: 11 passed, 1 FAILED\nAssertionError in {}",
                                self.task.buggy_file
                            ),
                            false,
                        )
                    }
                } else {
                    // plain build; may require a package
                    if let Some(dep) = &self.task.required_package {
                        if !self.state.packages.contains(dep) {
                            return (
                                format!("make: *** missing dependency: {dep}"),
                                false,
                            );
                        }
                    }
                    self.state.built = true;
                    ("build OK".to_string(), true)
                }
            }
            "python" | "sh" | "./run" => {
                let out = if self.state.built {
                    format!("exit 0 ({})", fnv1a(rest.as_bytes()) % 100)
                } else {
                    "ModuleNotFoundError: build artifacts missing".to_string()
                };
                (out, false)
            }
            "mkdir" | "touch" => {
                let path = self.resolve(rest.trim().trim_start_matches("-p "));
                self.state.files.entry(path).or_default();
                (String::new(), true)
            }
            other => (format!("{other}: command not found"), false),
        }
    }

    fn serialize_state(&self) -> Vec<u8> {
        let files: Vec<Json> = self
            .state
            .files
            .iter()
            .map(|(k, v)| Json::obj(vec![("p", Json::str(k.clone())), ("c", Json::str(v.clone()))]))
            .collect();
        let envs: Vec<Json> = self
            .state
            .env_vars
            .iter()
            .map(|(k, v)| Json::obj(vec![("k", Json::str(k.clone())), ("v", Json::str(v.clone()))]))
            .collect();
        let pkgs: Vec<Json> =
            self.state.packages.iter().map(|p| Json::str(p.clone())).collect();
        Json::obj(vec![
            ("seed", Json::num(self.task.seed as f64)),
            ("medium", Json::Bool(self.task.latency_scale > 1.5)),
            ("files", Json::Arr(files)),
            ("env", Json::Arr(envs)),
            ("pkgs", Json::Arr(pkgs)),
            ("cwd", Json::str(self.state.cwd.clone())),
            ("built", Json::Bool(self.state.built)),
        ])
        .to_string()
        .into_bytes()
    }

    fn deserialize_state(bytes: &[u8]) -> Option<TerminalSandbox> {
        let text = std::str::from_utf8(bytes).ok()?;
        let v = json::parse(text).ok()?;
        let seed = v.get("seed")?.as_u64()?;
        let medium = v.get("medium")?.as_bool()?;
        let task = TerminalTask::generate(seed, medium);
        let mut sb = TerminalSandbox::new(task);
        sb.state.files = v
            .get("files")?
            .as_arr()?
            .iter()
            .filter_map(|f| {
                Some((f.get("p")?.as_str()?.to_string(), f.get("c")?.as_str()?.to_string()))
            })
            .collect();
        sb.state.env_vars = v
            .get("env")?
            .as_arr()?
            .iter()
            .filter_map(|f| {
                Some((f.get("k")?.as_str()?.to_string(), f.get("v")?.as_str()?.to_string()))
            })
            .collect();
        sb.state.packages = v
            .get("pkgs")?
            .as_arr()?
            .iter()
            .filter_map(|p| p.as_str().map(String::from))
            .collect();
        sb.state.cwd = v.get("cwd")?.as_str()?.to_string();
        sb.state.built = v.get("built")?.as_bool()?;
        sb.state.running = true;
        Some(sb)
    }
}

impl ToolExecutionEnvironment for TerminalSandbox {
    fn start(&mut self) -> f64 {
        self.state.running = true;
        self.costs.start
    }

    fn stop(&mut self) -> f64 {
        self.state.running = false;
        self.costs.stop
    }

    fn execute(&mut self, call: &ToolCall) -> ToolResult {
        let (output, _mutated) = self.interpret(&call.args);
        let exec_time = self.latency.sample(self.task.seed, &call.args);
        ToolResult { output, exec_time, api_tokens: 0 }
    }

    fn fork(&self) -> Box<dyn ToolExecutionEnvironment> {
        let mut forked = TerminalSandbox {
            task: self.task.clone(),
            state: self.state.clone(),
            latency: self.latency,
            costs: self.costs,
        };
        forked.state.running = true;
        Box::new(forked)
    }

    fn snapshot(&self) -> SandboxSnapshot {
        let bytes = self.serialize_state();
        let kb = bytes.len() as f64 / 1024.0;
        SandboxSnapshot {
            serialize_cost: self.costs.commit_base + self.costs.commit_per_kb * kb,
            restore_cost: self.costs.restore_base + self.costs.commit_per_kb * kb,
            bytes,
        }
    }

    fn will_mutate_state(&self, call: &ToolCall) -> bool {
        // Conservative default for bash (Appendix B): everything mutates
        // except a small allowlist of obvious reads.
        let c = call.args.trim();
        !(c.starts_with("ls") || c.starts_with("cat ") || c.starts_with("grep ")
            || c.starts_with("pwd") || c.starts_with("env"))
    }

    fn state_fingerprint(&self) -> u64 {
        let mut h = fnv1a(self.state.cwd.as_bytes());
        for (k, v) in &self.state.files {
            h ^= fnv1a(k.as_bytes()).rotate_left(1) ^ fnv1a(v.as_bytes());
        }
        for (k, v) in &self.state.env_vars {
            h ^= fnv1a(k.as_bytes()).rotate_left(7) ^ fnv1a(v.as_bytes()).rotate_left(3);
        }
        for p in &self.state.packages {
            h ^= fnv1a(p.as_bytes()).rotate_left(13);
        }
        h ^ (self.state.built as u64)
    }
}

/// Factory for terminal sandboxes.
pub struct TerminalFactory {
    pub medium: bool,
}

impl SandboxFactory for TerminalFactory {
    fn create(&self, task_seed: u64) -> Box<dyn ToolExecutionEnvironment> {
        let mut sb = TerminalSandbox::new(TerminalTask::generate(task_seed, self.medium));
        sb.start();
        Box::new(sb)
    }

    fn restore(&self, snap: &SandboxSnapshot) -> Box<dyn ToolExecutionEnvironment> {
        Box::new(
            TerminalSandbox::deserialize_state(&snap.bytes)
                .expect("corrupt terminal snapshot"),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sandbox() -> TerminalSandbox {
        let mut sb = TerminalSandbox::new(TerminalTask::generate(1, false));
        sb.start();
        sb
    }

    fn run(sb: &mut TerminalSandbox, cmd: &str) -> String {
        sb.execute(&ToolCall::new("bash", cmd)).output
    }

    #[test]
    fn cat_reflects_patch_staleness_example() {
        // The paper's §1 motivating example: cat → patch → cat must differ.
        let mut sb = sandbox();
        let f = sb.task.buggy_file.clone();
        let before = run(&mut sb, &format!("cat {f}"));
        let old = sb.task.bug_pattern.clone();
        let new = sb.task.fix_pattern.clone();
        run(&mut sb, &format!("patch {f} s/{old}/{new}/"));
        let after = run(&mut sb, &format!("cat {f}"));
        assert_ne!(before, after);
        assert!(after.contains(&new));
    }

    #[test]
    fn make_test_fails_until_fixed() {
        let mut sb = sandbox();
        // Install dep if needed, build, test: should fail.
        if let Some(dep) = sb.task.required_package.clone() {
            run(&mut sb, &format!("pip install {dep}"));
        }
        run(&mut sb, "make");
        let out = run(&mut sb, "make test");
        assert!(out.contains("FAILED"), "{out}");
        // Apply the fix, rebuild, re-test: should pass.
        let f = sb.task.buggy_file.clone();
        let (old, new) = (sb.task.bug_pattern.clone(), sb.task.fix_pattern.clone());
        run(&mut sb, &format!("patch {f} s/{old}/{new}/"));
        run(&mut sb, "make");
        let out = run(&mut sb, "make test");
        assert!(out.contains("12 passed"), "{out}");
        assert!(sb.tests_pass());
    }

    #[test]
    fn build_requires_package() {
        let mut sb = TerminalSandbox::new(TerminalTask::generate(3, true)); // medium ⇒ dep
        sb.start();
        let out = run(&mut sb, "make");
        assert!(out.contains("missing dependency"), "{out}");
        let dep = sb.task.required_package.clone().unwrap();
        run(&mut sb, &format!("pip install {dep}"));
        assert_eq!(run(&mut sb, "make"), "build OK");
    }

    #[test]
    fn fork_is_deep_copy() {
        let mut sb = sandbox();
        run(&mut sb, "echo hello > note.txt");
        let mut fork = sb.fork();
        let fp_before = sb.state_fingerprint();
        // Mutate the fork: original must be unaffected.
        fork.execute(&ToolCall::new("bash", "echo bye > note.txt"));
        assert_eq!(sb.state_fingerprint(), fp_before);
        assert_ne!(fork.state_fingerprint(), fp_before);
        assert_eq!(run(&mut sb, "cat note.txt"), "hello");
    }

    #[test]
    fn snapshot_restore_roundtrip() {
        let mut sb = sandbox();
        run(&mut sb, "echo data > f.txt");
        run(&mut sb, "export MODE=fast");
        run(&mut sb, "pip install numpy");
        let snap = sb.snapshot();
        assert!(snap.serialize_cost > 0.0 && snap.restore_cost > 0.0);
        let factory = TerminalFactory { medium: false };
        let mut restored = factory.restore(&snap);
        assert_eq!(restored.state_fingerprint(), sb.state_fingerprint());
        assert_eq!(
            restored.execute(&ToolCall::new("bash", "cat f.txt")).output,
            "data"
        );
    }

    #[test]
    fn same_trajectory_same_fingerprint() {
        let cmds = ["echo a > x", "pip install numpy", "make", "cat x"];
        let mut a = sandbox();
        let mut b = sandbox();
        for c in cmds {
            run(&mut a, c);
            run(&mut b, c);
        }
        assert_eq!(a.state_fingerprint(), b.state_fingerprint());
    }

    #[test]
    fn latency_deterministic_and_classed() {
        let mut sb = sandbox();
        let t1 = sb.execute(&ToolCall::new("bash", "make test")).exec_time;
        let t2 = sb.execute(&ToolCall::new("bash", "make test")).exec_time;
        assert_eq!(t1, t2);
        let cheap = sb.execute(&ToolCall::new("bash", "cat README.md")).exec_time;
        assert!(cheap < t1);
    }

    #[test]
    fn will_mutate_state_annotations() {
        let sb = sandbox();
        assert!(!sb.will_mutate_state(&ToolCall::new("bash", "cat x")));
        assert!(!sb.will_mutate_state(&ToolCall::new("bash", "ls")));
        assert!(sb.will_mutate_state(&ToolCall::new("bash", "echo a > x")));
        assert!(sb.will_mutate_state(&ToolCall::new("bash", "make")));
    }

    #[test]
    fn unknown_command_reports_error_without_mutation() {
        let mut sb = sandbox();
        let fp = sb.state_fingerprint();
        let out = run(&mut sb, "frobnicate --all");
        assert!(out.contains("command not found"));
        assert_eq!(sb.state_fingerprint(), fp);
    }
}
