//! Paper-calibrated tool-latency models.
//!
//! Figure 2 / Table 2 / Figure 11 give per-workload latency scales: terminal
//! tool calls have a ~8.7 s (easy) / ~18.7 s (medium) median with heavy
//! tails (p99 > 90% of rollout time); SQL reads take ~56.6 ms round-trip;
//! EgoSchema tools range from milliseconds (load/preprocess hit path) to
//! tens of seconds (object-memory agent loops). Latencies are sampled
//! deterministically from the call descriptor + a stream seed, so repeated
//! executions of the same call in the same state report identical costs —
//! which the selective-snapshot policy and the benches rely on.

use crate::util::rng::{fnv1a, Rng};

/// A lognormal latency distribution with a floor.
#[derive(Debug, Clone, Copy)]
pub struct LatencyDist {
    /// Underlying lognormal mu (of seconds).
    pub mu: f64,
    /// Underlying lognormal sigma.
    pub sigma: f64,
    /// Added constant (network RTT, dispatch overhead).
    pub floor: f64,
}

impl LatencyDist {
    pub const fn new(mu: f64, sigma: f64, floor: f64) -> Self {
        LatencyDist { mu, sigma, floor }
    }

    /// Deterministic sample for a given key (call descriptor hash).
    pub fn sample(&self, seed: u64, key: &str) -> f64 {
        let mut rng = Rng::new(seed ^ fnv1a(key.as_bytes()));
        self.floor + rng.lognormal(self.mu, self.sigma)
    }

    /// Median of the distribution (floor + e^mu).
    pub fn median(&self) -> f64 {
        self.floor + self.mu.exp()
    }
}

/// Latency model for the terminal workload, calibrated to Table 2.
/// `scale` distinguishes easy (1.0) from medium (~2.2) tasks.
#[derive(Debug, Clone, Copy)]
pub struct TerminalLatency {
    pub scale: f64,
}

impl TerminalLatency {
    /// Classify a shell command into a latency class.
    pub fn classify(cmd: &str) -> LatencyDist {
        let c = cmd.trim();
        // Heavy operations first: compilation, test suites, installs.
        if c.starts_with("make test") || c.starts_with("pytest") || c.contains("run_tests") {
            LatencyDist::new(2.6, 0.8, 0.5) // ~14 s median, heavy tail
        } else if c.starts_with("make") || c.contains("gcc") || c.contains("cargo build") {
            LatencyDist::new(2.2, 0.7, 0.5) // ~9.5 s median
        } else if c.starts_with("pip install") || c.starts_with("apt-get") {
            LatencyDist::new(1.9, 0.6, 0.5) // ~7 s median
        } else if c.starts_with("git clone") {
            LatencyDist::new(1.6, 0.5, 0.3)
        } else if c.starts_with("python") || c.starts_with("./") {
            LatencyDist::new(0.8, 0.9, 0.1) // script runs: wide spread
        } else {
            // cheap file ops: cat/ls/echo/grep/cd/export/mkdir/rm/cp/patch
            LatencyDist::new(-2.5, 0.5, 0.02) // ~100 ms
        }
    }

    pub fn sample(&self, seed: u64, cmd: &str) -> f64 {
        TerminalLatency::classify(cmd).sample(seed, cmd) * self.scale
    }
}

/// Container lifecycle costs (Docker analogue; Appendix E/F).
#[derive(Debug, Clone, Copy)]
pub struct ContainerCosts {
    pub start: f64,
    pub stop: f64,
    pub commit_per_kb: f64,
    pub commit_base: f64,
    pub restore_base: f64,
}

impl Default for ContainerCosts {
    fn default() -> Self {
        // Calibrated so that cold start+stop ≈ 7 s/rollout — the overhead
        // Appendix F attributes most of TVCACHE's win to.
        ContainerCosts {
            start: 4.0,
            stop: 1.5,
            commit_per_kb: 0.002,
            commit_base: 0.8,
            restore_base: 1.2,
        }
    }
}

/// SQL workload: 55.8 ms median RTT (§4.2) + per-row scan cost.
#[derive(Debug, Clone, Copy)]
pub struct SqlLatency {
    pub rtt: f64,
    pub per_row_scanned: f64,
}

impl Default for SqlLatency {
    fn default() -> Self {
        SqlLatency { rtt: 0.0558, per_row_scanned: 2e-6 }
    }
}

impl SqlLatency {
    /// Total query latency given rows scanned. A cache hit skips all of it
    /// and costs only the cache get (~6.5 ms, §4.2).
    pub fn query(&self, seed: u64, sql: &str, rows_scanned: usize) -> f64 {
        let mut rng = Rng::new(seed ^ fnv1a(sql.as_bytes()));
        // RTT jitter: lognormal around the median.
        let rtt = self.rtt * rng.lognormal(0.0, 0.15);
        rtt + rows_scanned as f64 * self.per_row_scanned
    }
}

/// EgoSchema tool latencies (Figure 11 distributions).
pub fn ego_tool_latency(tool: &str) -> LatencyDist {
    match tool {
        // Fast filesystem copies (preprocessed data reuse — Appendix D).
        "load_video" => LatencyDist::new(-2.0, 0.3, 0.05),
        "preprocess" => LatencyDist::new(-1.6, 0.4, 0.05),
        // Retrieval over precomputed embeddings.
        "segment_localization" => LatencyDist::new(0.3, 0.4, 0.2),
        "caption_retrieval" => LatencyDist::new(0.9, 0.5, 0.3), // OpenAI API
        "visual_question_answering" => LatencyDist::new(1.3, 0.5, 0.4),
        // Internal agent loop with an OpenAI model: the slowest (Fig 11).
        "object_memory_querying" => LatencyDist::new(2.3, 0.6, 1.0),
        _ => LatencyDist::new(0.0, 0.5, 0.1),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_sampling() {
        let d = LatencyDist::new(1.0, 0.5, 0.1);
        assert_eq!(d.sample(42, "make"), d.sample(42, "make"));
        assert_ne!(d.sample(42, "make"), d.sample(43, "make"));
        assert_ne!(d.sample(42, "make"), d.sample(42, "make test"));
    }

    #[test]
    fn terminal_classes_ordered_by_cost() {
        let cat = TerminalLatency::classify("cat foo.py").median();
        let install = TerminalLatency::classify("pip install numpy").median();
        let build = TerminalLatency::classify("make all").median();
        let test = TerminalLatency::classify("make test").median();
        assert!(cat < install && install < build && build < test);
        assert!(cat < 0.5, "cat median {cat}");
        assert!(test > 10.0, "test median {test}");
    }

    #[test]
    fn medium_scale_slower_than_easy() {
        let easy = TerminalLatency { scale: 1.0 };
        let med = TerminalLatency { scale: 2.2 };
        assert!(med.sample(1, "make") > easy.sample(1, "make"));
    }

    #[test]
    fn sql_latency_near_paper_median() {
        let l = SqlLatency::default();
        let mut total = 0.0;
        for i in 0..200 {
            total += l.query(i, &format!("SELECT {i}"), 100);
        }
        let mean = total / 200.0;
        assert!((mean - 0.0566).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn ego_object_memory_is_slowest() {
        let omq = ego_tool_latency("object_memory_querying").median();
        for t in ["load_video", "preprocess", "segment_localization", "caption_retrieval"] {
            assert!(ego_tool_latency(t).median() < omq, "{t}");
        }
    }
}
